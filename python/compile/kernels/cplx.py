"""Complex arithmetic on (re, im) pairs of f64 arrays.

The xla crate's PJRT bridge exchanges plain f64 tensors, so the whole
compile path represents complex values as explicit (re, im) pairs. These
helpers keep the L2 model readable; everything is shape-polymorphic.
"""

from __future__ import annotations

import jax.numpy as jnp

Pair = tuple  # (re, im), each a jnp.ndarray


def cpair(re, im) -> Pair:
    return (jnp.asarray(re), jnp.asarray(im))


def cadd(a: Pair, b: Pair) -> Pair:
    return (a[0] + b[0], a[1] + b[1])


def csub(a: Pair, b: Pair) -> Pair:
    return (a[0] - b[0], a[1] - b[1])


def cneg(a: Pair) -> Pair:
    return (-a[0], -a[1])


def cmul(a: Pair, b: Pair) -> Pair:
    return (a[0] * b[0] - a[1] * b[1], a[0] * b[1] + a[1] * b[0])


def cscale(a: Pair, s) -> Pair:
    return (a[0] * s, a[1] * s)


def cabs2(a: Pair):
    return a[0] * a[0] + a[1] * a[1]


def cinv(a: Pair, guard=None) -> Pair:
    """1/a. With `guard`, entries where |a|² == 0 (or guard == 0) yield 0
    instead of inf — used for masked/padded lanes."""
    d = cabs2(a)
    if guard is None:
        s = 1.0 / d
    else:
        ok = (d > 0) & (guard > 0)
        s = jnp.where(ok, 1.0 / jnp.where(ok, d, 1.0), 0.0)
    return (a[0] * s, -a[1] * s)


def cpowers(a: Pair, n: int) -> Pair:
    """Stacked powers [a^0, a^1, …, a^n] along a new trailing axis:
    returns (re, im) each of shape `a.shape + (n+1,)`.

    Cumulative products (n multiplications), mirroring the `powi_table`
    of the Rust layer so both layers agree bit-for-bit in structure."""
    re = [jnp.ones_like(a[0])]
    im = [jnp.zeros_like(a[1])]
    for _ in range(n):
        nr = re[-1] * a[0] - im[-1] * a[1]
        ni = re[-1] * a[1] + im[-1] * a[0]
        re.append(nr)
        im.append(ni)
    return (jnp.stack(re, axis=-1), jnp.stack(im, axis=-1))


def cmatmul_const(a: Pair, m) -> Pair:
    """(complex batch) @ (real constant matrix), the MXU-shaped core:
    a has shape [..., K], m is [K, L] real; result [..., L]."""
    return (a[0] @ m, a[1] @ m)
