"""L1 Pallas kernel: near-field direct evaluation (P2P, Algorithm 3.7).

The P2P phase is the single most expensive part of the algorithm
(43 % of GPU runtime in Table 5.1), so it is the primary L1 kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA kernel
stages source points through a 64-slot *shared-memory cache* per thread
block, one block per box. Here, the near-field sources of each box are
pre-gathered by XLA into a padded `[B, S]` layout (S = Knear·nmax) and the
Pallas grid walks one box tile per step; `BlockSpec` places the box's
targets `[1, nmax]` and its gathered sources `[1, S]` in VMEM, replacing
the manual cache, and the `[nmax, S]` pairwise tile is evaluated on the
VPU in one vectorized sweep — there is no intra-tile synchronization to
manage at all, which is the part of the CUDA code the paper spends
Algorithm 3.7 on.

VMEM at the default config (nmax=64, S=16·64=1024): 7 operand rows
(~60 kB) plus the f64 [64, 1024] pair tile ≈ 3 × 0.5 MB — comfortably
inside the ~16 MB/core budget; see DESIGN.md §7 for the footprint table.

`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; correctness is validated against `ref.p2p_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _p2p_kernel(tx_ref, ty_ref, sx_ref, sy_ref, gre_ref, gim_ref, sm_ref,
                ore_ref, oim_ref):
    # one grid step = one leaf box
    tx = tx_ref[...]  # [1, n]
    ty = ty_ref[...]
    sx = sx_ref[...]  # [1, S]
    sy = sy_ref[...]
    gre = gre_ref[...]
    gim = gim_ref[...]
    sm = sm_ref[...]

    n = tx.shape[1]
    # pairwise tile [n, S]: z_s − z_t
    dx = sx - tx.reshape(n, 1)
    dy = sy - ty.reshape(n, 1)
    den = dx * dx + dy * dy
    ok = (den > 0) & (sm > 0)
    w = jnp.where(ok, 1.0 / jnp.where(ok, den, 1.0), 0.0)
    # Γ · conj(z_s − z_t) / |z_s − z_t|²
    phi_re = ((gre * dx + gim * dy) * w).sum(axis=1)
    phi_im = ((gim * dx - gre * dy) * w).sum(axis=1)
    ore_ref[...] = phi_re.reshape(1, n)
    oim_ref[...] = phi_im.reshape(1, n)


def p2p_pallas(tx, ty, sx, sy, gre, gim, smask):
    """Near-field potentials.

    tx, ty: targets [B, n]; sx…smask: gathered sources [B, S].
    Returns (phi_re, phi_im), each [B, n].
    """
    b, n = tx.shape
    s = sx.shape[1]
    tgt_spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    src_spec = pl.BlockSpec((1, s), lambda i: (i, 0))
    return pl.pallas_call(
        _p2p_kernel,
        grid=(b,),
        in_specs=[tgt_spec, tgt_spec, src_spec, src_spec, src_spec, src_spec,
                  src_spec],
        out_specs=[tgt_spec, tgt_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), tx.dtype),
            jax.ShapeDtypeStruct((b, n), tx.dtype),
        ],
        interpret=True,
    )(tx, ty, sx, sy, gre, gim, smask)
