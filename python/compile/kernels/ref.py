"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has its reference here, written in the
most transparent formulation possible; pytest pins kernel == ref across
shapes and seeds (hypothesis sweeps), and the Rust `expansion::matrices`
tests pin the same linear maps on the coordinator side.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from math import comb


def m2l_structure_matrix(p: int) -> np.ndarray:
    """The constant M2L core `T[l, k] = C(k+l-1, l)` (column 0 zero —
    `a_0` is handled outside; the harness kernel is harmonic, a_0 = 0).
    Must match `fmm2d::expansion::matrices::m2l_matrix`."""
    t = np.zeros((p + 1, p + 1), dtype=np.float64)
    for l in range(p + 1):
        for k in range(1, p + 1):
            t[l, k] = comb(k + l - 1, l)
    return t


def m2m_structure_matrix(p: int) -> np.ndarray:
    """`S[l, k] = C(l-1, k-1)` for 1 <= k <= l (else 0)."""
    s = np.zeros((p + 1, p + 1), dtype=np.float64)
    for l in range(1, p + 1):
        for k in range(1, l + 1):
            s[l, k] = comb(l - 1, k - 1)
    return s


def l2l_structure_matrix(p: int) -> np.ndarray:
    """`U[l, k] = (-1)^{k-l} C(k, l)` for k >= l (else 0)."""
    u = np.zeros((p + 1, p + 1), dtype=np.float64)
    for l in range(p + 1):
        for k in range(l, p + 1):
            u[l, k] = ((-1.0) ** (k - l)) * comb(k, l)
    return u


def m2l_core_ref(ahat_re, ahat_im, p: int):
    """Reference for the M2L core: `b̂ = â @ T^T` on pre-scaled
    coefficients, shapes [I, p+1] -> [I, p+1]."""
    t = jnp.asarray(m2l_structure_matrix(p).T)
    return ahat_re @ t, ahat_im @ t


def p2p_ref(tx, ty, sx, sy, gre, gim, smask):
    """Reference near-field evaluation.

    Shapes: targets [B, n], gathered sources [B, S]; returns [B, n] pair.
    Contribution of source s at target t: Γ_s / (z_s − z_t); zero-distance
    pairs (self interactions and padded lanes) contribute 0.
    """
    dx = sx[:, None, :] - tx[:, :, None]  # [B, n, S]
    dy = sy[:, None, :] - ty[:, :, None]
    den = dx * dx + dy * dy
    ok = (den > 0) & (smask[:, None, :] > 0)
    w = jnp.where(ok, 1.0 / jnp.where(ok, den, 1.0), 0.0)
    gr = gre[:, None, :]
    gi = gim[:, None, :]
    # Γ · conj(z_s − z_t) / |z_s − z_t|²
    phi_re = ((gr * dx + gi * dy) * w).sum(axis=-1)
    phi_im = ((gi * dx - gr * dy) * w).sum(axis=-1)
    return phi_re, phi_im


def direct_ref(px, py, gre, gim):
    """O(N²) direct summation at the sources themselves ([N] arrays)."""
    dx = px[None, :] - px[:, None]
    dy = py[None, :] - py[:, None]
    den = dx * dx + dy * dy
    ok = den > 0
    w = jnp.where(ok, 1.0 / jnp.where(ok, den, 1.0), 0.0)
    phi_re = ((gre[None, :] * dx + gim[None, :] * dy) * w).sum(axis=-1)
    phi_im = ((gim[None, :] * dx - gre[None, :] * dy) * w).sum(axis=-1)
    return phi_re, phi_im
