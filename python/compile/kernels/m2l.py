"""L1 Pallas kernel: the M2L translation core.

Second-hottest phase of Table 5.1 (11 %). The paper evaluates each M2L
shift as a triangular recurrence in shared memory (Algorithm 3.6, two
threads per shift). The TPU re-think (DESIGN.md §Hardware-Adaptation):
the scaled shift *is* multiplication by a constant structure matrix
`T[l,k] = C(k+l−1, l)` — pre-scale and post-scale are diagonal. So the
core becomes a batched `[I, p+1] × [p+1, p+1]` real matmul (4 per complex
batch), exactly the MXU's shape. `T` is baked into the kernel as a
compile-time constant, the analogue of the paper keeping the shift
stencil in registers/shared memory.

The batch dimension I (all M2L interactions of one level) is tiled by
`TILE_I` rows per grid step; at p = 17 a tile holds 2·128·18 f64 ≈ 37 kB —
VMEM-trivial, and the matmul is MXU-eligible (the padding from p+1 = 18 to
the 128-lane MXU tile is what a production TPU kernel would accept at
this p, amortized across the 4 real matmuls).

`interpret=True` (CPU PJRT cannot run Mosaic); validated against
`ref.m2l_core_ref` and transitively against the Rust recurrence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_I = 128


def _kernel(are_ref, aim_ref, tt_ref, ore_ref, oim_ref):
    # tt is the transposed structure matrix; Pallas requires constants to be
    # plumbed as inputs, so `m2l_core_pallas` feeds it as a (grid-invariant)
    # operand — the BlockSpec maps every grid step to the same [p+1, p+1]
    # block, i.e. it stays resident in VMEM across the batch sweep.
    tt = tt_ref[...]
    ore_ref[...] = jnp.dot(are_ref[...], tt, precision="highest")
    oim_ref[...] = jnp.dot(aim_ref[...], tt, precision="highest")


def m2l_core_pallas(ahat_re, ahat_im, p: int):
    """Apply the constant M2L core to pre-scaled coefficients.

    ahat_*: [I, p+1] (I padded to a multiple of TILE_I internally).
    Returns (bhat_re, bhat_im): [I, p+1].
    """
    i, w = ahat_re.shape
    assert w == p + 1
    pad = (-i) % TILE_I
    if pad:
        ahat_re = jnp.pad(ahat_re, ((0, pad), (0, 0)))
        ahat_im = jnp.pad(ahat_im, ((0, pad), (0, 0)))
    rows = ahat_re.shape[0]
    tt = jnp.asarray(ref.m2l_structure_matrix(p).T)
    spec = pl.BlockSpec((TILE_I, p + 1), lambda t: (t, 0))
    mat_spec = pl.BlockSpec((p + 1, p + 1), lambda t: (0, 0))
    out_re, out_im = pl.pallas_call(
        _kernel,
        grid=(rows // TILE_I,),
        in_specs=[spec, spec, mat_spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, p + 1), ahat_re.dtype),
            jax.ShapeDtypeStruct((rows, p + 1), ahat_im.dtype),
        ],
        interpret=True,
    )(ahat_re, ahat_im, tt)
    return out_re[:i], out_im[:i]
