"""L2: the full FMM computational phase as one fused JAX function.

Given the pyramid packed into fixed-shape tensors (positions, strengths,
masks, per-level centers and padded interaction lists — produced by the
Rust `packing` module at run time, or by `treepack.py` in tests), this
computes P2M → M2M↑ → (M2L + P2L) → L2L↓ → (L2P + M2P) → P2P and returns
the potential at every particle slot.

The static pyramid layout (4^l boxes per level, children of box b at
4b..4b+4) is what makes a *fixed-shape* formulation possible at all — the
adaptivity lives entirely in the box geometry and the interaction lists,
not in the shapes. This mirrors the paper's observation that the
asymmetric mesh admits "a static layout of memory" (§2), which it needs
for CUDA and we need for AOT-compiled XLA.

Kernel: harmonic (Eq. 5.1) ⇒ a_0 ≡ 0 throughout; the log-kernel a_0
paths exist on the Rust side, which owns the general-kernel serial code.

Python here is build-time only: `aot.py` lowers `fmm_eval` to HLO text
once per configuration; nothing in this package runs at request time.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from .kernels import cplx, ref
from .kernels.m2l import m2l_core_pallas
from .kernels.p2p import p2p_pallas


@dataclass(frozen=True)
class PackConfig:
    """Static shape configuration of one AOT artifact."""

    levels: int          # pyramid refinement levels L (leaves = 4^L)
    p: int               # expansion order
    nmax: int            # particle slots per leaf box
    kfar: tuple          # M2L list pad per level 1..L
    knear: int           # near-field list pad (finest level, self included)
    ksp: int             # P2L/M2P list pad (finest level)

    @property
    def n_leaves(self) -> int:
        return 4 ** self.levels

    @property
    def nbtot(self) -> int:
        """Total boxes over levels 0..L (centers array length)."""
        return (4 ** (self.levels + 1) - 1) // 3

    def level_offset(self, l: int) -> int:
        return (4 ** l - 1) // 3

    def input_specs(self):
        """Ordered (name, shape, dtype) list — the artifact ABI recorded in
        the .meta manifest and consumed by the Rust runtime."""
        nl, nmax = self.n_leaves, self.nmax
        specs = [
            ("pos_re", (nl, nmax), "f64"),
            ("pos_im", (nl, nmax), "f64"),
            ("gam_re", (nl, nmax), "f64"),
            ("gam_im", (nl, nmax), "f64"),
            ("mask", (nl, nmax), "f64"),
            ("ctr_re", (self.nbtot,), "f64"),
            ("ctr_im", (self.nbtot,), "f64"),
        ]
        for l in range(1, self.levels + 1):
            specs.append((f"m2l_idx_{l}", (4 ** l, self.kfar[l - 1]), "i32"))
        specs += [
            ("near_idx", (nl, self.knear), "i32"),
            ("p2l_idx", (nl, self.ksp), "i32"),
            ("m2p_idx", (nl, self.ksp), "i32"),
        ]
        return specs

    def example_args(self):
        """ShapeDtypeStructs for jax.jit(...).lower()."""
        dt = {"f64": jnp.float64, "i32": jnp.int32}
        return [
            jax.ShapeDtypeStruct(shape, dt[dtype])
            for (_, shape, dtype) in self.input_specs()
        ]


def _gather_safe(idx):
    """(safe_index, valid_f64) for -1-padded gather lists."""
    valid = (idx >= 0).astype(jnp.float64)
    safe = jnp.maximum(idx, 0)
    return safe, valid


def _powers_masked(vec, valid, n):
    """Powers of a complex pair `vec` masked to 1 where invalid (avoids
    inf/NaN leaking through 0·inf)."""
    re = jnp.where(valid > 0, vec[0], 1.0)
    im = jnp.where(valid > 0, vec[1], 0.0)
    return cplx.cpowers((re, im), n)


def fmm_eval(cfg: PackConfig, *args, use_pallas: bool = True):
    """The fused FMM computational phase. Returns (pot_re, pot_im),
    each [4^L, nmax] in the leaf/slot layout of the inputs."""
    names = [s[0] for s in cfg.input_specs()]
    a = dict(zip(names, args))
    L, p, nmax, nl = cfg.levels, cfg.p, cfg.nmax, cfg.n_leaves

    pos = (a["pos_re"], a["pos_im"])
    gam = (a["gam_re"], a["gam_im"])
    mask = a["mask"]

    # per-level center pairs
    ctr = []
    for l in range(L + 1):
        off, nb = cfg.level_offset(l), 4 ** l
        ctr.append((a["ctr_re"][off:off + nb], a["ctr_im"][off:off + nb]))

    s_mat = jnp.asarray(ref.m2m_structure_matrix(p).T)
    u_mat = jnp.asarray(ref.l2l_structure_matrix(p).T)

    # ---- P2M: leaf multipole expansions --------------------------------
    # a_j = −Σ_i Γ_i t_i^{j−1},  t = z_i − z_box
    t = cplx.csub(pos, (ctr[L][0][:, None], ctr[L][1][:, None]))
    tp = _powers_masked(t, mask, p - 1)          # [nl, nmax, p]
    gm = (gam[0] * mask, gam[1] * mask)
    term = cplx.cmul((gm[0][..., None], gm[1][..., None]), tp)
    coeff_hi = (-term[0].sum(axis=1), -term[1].sum(axis=1))  # a_1..a_p
    zero_col = jnp.zeros((nl, 1), dtype=jnp.float64)
    mult = {L: (jnp.concatenate([zero_col, coeff_hi[0]], axis=1),
                jnp.concatenate([zero_col, coeff_hi[1]], axis=1))}

    # ---- M2M: upward pass ----------------------------------------------
    for l in range(L, 0, -1):
        nb = 4 ** l
        par = jnp.arange(nb) // 4
        zc = ctr[l]
        zp = (ctr[l - 1][0][par], ctr[l - 1][1][par])
        d = cplx.csub(zc, zp)                    # [nb]
        dinv = cplx.cinv(d)
        dpow = cplx.cpowers(d, p)                # [nb, p+1]
        dipow = cplx.cpowers(dinv, p)
        ahat = cplx.cmul(mult[l], dipow)
        core = cplx.cmatmul_const(ahat, s_mat)
        shifted = cplx.cmul(core, dpow)          # [nb, p+1]
        parent = (shifted[0].reshape(nb // 4, 4, p + 1).sum(axis=1),
                  shifted[1].reshape(nb // 4, 4, p + 1).sum(axis=1))
        mult[l - 1] = parent

    # ---- M2L (+ P2L): far field into local expansions -------------------
    local = {}
    for l in range(1, L + 1):
        nb = 4 ** l
        idx = a[f"m2l_idx_{l}"]
        safe, valid = _gather_safe(idx)          # [nb, K]
        asrc = (mult[l][0][safe], mult[l][1][safe])   # [nb, K, p+1]
        zsrc = (ctr[l][0][safe], ctr[l][1][safe])
        r = cplx.csub((ctr[l][0][:, None], ctr[l][1][:, None]), zsrc)
        ripow = _powers_masked(cplx.cinv(r, valid), valid, p)  # r^{-k}
        ahat = cplx.cmul(asrc, ripow)
        flat = (ahat[0].reshape(-1, p + 1), ahat[1].reshape(-1, p + 1))
        if use_pallas:
            bhat = m2l_core_pallas(flat[0], flat[1], p)
        else:
            bhat = ref.m2l_core_ref(flat[0], flat[1], p)
        bhat = (bhat[0].reshape(nb, -1, p + 1), bhat[1].reshape(nb, -1, p + 1))
        alt = jnp.asarray([(-1.0) ** j for j in range(p + 1)])
        scale = ripow[0] * alt, ripow[1] * alt
        b = cplx.cmul(bhat, scale)
        w = valid[..., None]
        local[l] = ((b[0] * w).sum(axis=1), (b[1] * w).sum(axis=1))

    # P2L: particles of strongly-coupled larger boxes → local expansions,
    # b_l += Σ Γ / t^{l+1},  t = z_src_particle − z_dst_center
    safe, valid = _gather_safe(a["p2l_idx"])     # [nl, ksp]
    spos = (pos[0][safe], pos[1][safe])          # [nl, ksp, nmax]
    sgam = (gam[0][safe], gam[1][safe])
    smask = mask[safe] * valid[..., None]
    tt = cplx.csub(spos, (ctr[L][0][:, None, None], ctr[L][1][:, None, None]))
    tinv = cplx.cinv(tt, smask)
    tipow = _powers_masked(tinv, smask, p + 1)   # t^{-(l+1)} at slot l+1
    gmask = (sgam[0] * smask, sgam[1] * smask)
    contrib = cplx.cmul((gmask[0][..., None], gmask[1][..., None]),
                        (tipow[0][..., 1:], tipow[1][..., 1:]))
    p2l_add = (contrib[0].sum(axis=(1, 2)), contrib[1].sum(axis=(1, 2)))
    local[L] = (local[L][0] + p2l_add[0], local[L][1] + p2l_add[1])

    # ---- L2L: downward pass ---------------------------------------------
    for l in range(1, L):
        nb = 4 ** (l + 1)
        par = jnp.arange(nb) // 4
        bp = (local[l][0][par], local[l][1][par])
        zp = (ctr[l][0][par], ctr[l][1][par])
        r = cplx.csub(zp, ctr[l + 1])            # z_p − z_c
        rpow = cplx.cpowers(r, p)
        ripow = cplx.cpowers(cplx.cinv(r), p)
        bhat = cplx.cmul(bp, rpow)
        core = cplx.cmatmul_const(bhat, u_mat)
        add = cplx.cmul(core, ripow)
        local[l + 1] = (local[l + 1][0] + add[0], local[l + 1][1] + add[1])

    # ---- L2P: evaluate local expansions at the particles ----------------
    w = cplx.csub(pos, (ctr[L][0][:, None], ctr[L][1][:, None]))
    acc = (jnp.broadcast_to(local[L][0][:, p][:, None], (nl, nmax)),
           jnp.broadcast_to(local[L][1][:, p][:, None], (nl, nmax)))
    for j in range(p - 1, -1, -1):
        acc = cplx.cmul(acc, w)
        acc = (acc[0] + local[L][0][:, j][:, None],
               acc[1] + local[L][1][:, j][:, None])
    phi = acc

    # M2P: multipoles of strongly-coupled smaller boxes evaluated directly
    safe, valid = _gather_safe(a["m2p_idx"])     # [nl, ksp]
    am = (mult[L][0][safe], mult[L][1][safe])    # [nl, ksp, p+1]
    zsrc = (ctr[L][0][safe], ctr[L][1][safe])
    t = cplx.csub((pos[0][:, None, :], pos[1][:, None, :]),
                  (zsrc[0][..., None], zsrc[1][..., None]))  # [nl, ksp, nmax]
    vmask = valid[..., None] * mask[:, None, :]
    it = cplx.cinv(t, vmask)
    macc = (jnp.zeros_like(it[0]), jnp.zeros_like(it[1]))
    for j in range(p, 0, -1):
        macc = (macc[0] + am[0][..., j][..., None],
                macc[1] + am[1][..., j][..., None])
        macc = cplx.cmul(macc, it)
    phi = (phi[0] + (macc[0] * vmask).sum(axis=1),
           phi[1] + (macc[1] * vmask).sum(axis=1))

    # ---- P2P: near field (L1 Pallas kernel) ------------------------------
    safe, valid = _gather_safe(a["near_idx"])    # [nl, knear]
    sx = pos[0][safe].reshape(nl, -1)            # [nl, knear·nmax]
    sy = pos[1][safe].reshape(nl, -1)
    gre = gam[0][safe].reshape(nl, -1)
    gim = gam[1][safe].reshape(nl, -1)
    sm = (mask[safe] * valid[..., None]).reshape(nl, -1)
    if use_pallas:
        near = p2p_pallas(pos[0], pos[1], sx, sy, gre, gim, sm)
    else:
        near = ref.p2p_ref(pos[0], pos[1], sx, sy, gre, gim, sm)
    phi = (phi[0] + near[0], phi[1] + near[1])

    return phi[0] * mask, phi[1] * mask


def direct_eval(px, py, gre, gim):
    """O(N²) direct-summation model (the break-even baseline artifact)."""
    return ref.direct_ref(px, py, gre, gim)


def make_fmm_fn(cfg: PackConfig, use_pallas: bool = True):
    """The jit-able single-config entry point for AOT lowering."""
    return partial(fmm_eval, cfg, use_pallas=use_pallas)


# Named artifact configurations (kept in sync with DESIGN.md §4 and the
# Rust runtime's expectations; `aot.py` emits one HLO per entry).
ARTIFACT_CONFIGS = {
    # Two pad buckets per depth: `_tight` fits near-uniform inputs with
    # minimal padded work; the wide default absorbs the paper's worst case
    # (σ=0.1 normal cloud, Fig. 5.8). The Rust runtime picks the smallest
    # artifact whose pads fit the actual tree (EXPERIMENTS.md §Perf L2).
    "fmm_l2_p8": PackConfig(levels=2, p=8, nmax=32, kfar=(4, 16), knear=16,
                            ksp=8),
    "fmm_l3_p17_tight": PackConfig(levels=3, p=17, nmax=64,
                                   kfar=(4, 16, 48), knear=20, ksp=10),
    "fmm_l3_p17": PackConfig(levels=3, p=17, nmax=64, kfar=(8, 24, 64),
                             knear=32, ksp=40),
    "fmm_l4_p17_tight": PackConfig(levels=4, p=17, nmax=64,
                                   kfar=(4, 16, 48, 56), knear=20, ksp=12),
    "fmm_l4_p17": PackConfig(levels=4, p=17, nmax=64, kfar=(8, 24, 64, 72),
                             knear=32, ksp=48),
}

DIRECT_N = 2048
