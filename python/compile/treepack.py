"""Test-side pyramid builder + packer (numpy), mirroring the Rust
`tree`/`connectivity`/`packing` modules.

Used by pytest to exercise the fused model without the Rust coordinator,
and by `aot.py` smoke checks. The semantics (median splits twice per box,
eccentricity-guided axis, θ-criterion recursion from parent strong lists,
finest-level P2L/M2P extraction) match the Rust implementation; exact
tie-breaking may differ — irrelevant, since both sides feed whatever tree
they built through the same HLO.
"""

from __future__ import annotations

import numpy as np

from .model import PackConfig


def _split_axis(rect):
    x0, y0, x1, y1 = rect
    return 0 if (x1 - x0) >= (y1 - y0) else 1


def _median_split(pts, order, rect):
    """Partition `order` (indices into pts) around the median along the
    rect's major axis. Returns (left, right, rect_left, rect_right)."""
    ax = _split_axis(rect)
    coords = pts[order, ax]
    n = len(order)
    mid = n // 2
    part = np.argpartition(coords, mid) if n > 1 else np.arange(n)
    order = order[part]
    if n > 1:
        lo_max = pts[order[:mid], ax].max() if mid else rect[ax]
        hi_min = pts[order[mid:], ax].min()
        cut = 0.5 * (lo_max + hi_min)
    else:
        cut = coords[0] if n else rect[ax]
    x0, y0, x1, y1 = rect
    if ax == 0:
        ra, rb = (x0, y0, cut, y1), (cut, y0, x1, y1)
    else:
        ra, rb = (x0, y0, x1, cut), (x0, cut, x1, y1)
    return order[:mid], order[mid:], ra, rb


class Pyramid:
    def __init__(self, pts, levels):
        n = len(pts)
        assert n >= 4 ** levels, "fewer particles than leaf boxes"
        self.levels = levels
        self.rects = [[(pts[:, 0].min(), pts[:, 1].min(),
                        pts[:, 0].max(), pts[:, 1].max())]]
        orders = [np.arange(n)]
        for l in range(levels):
            next_rects, next_orders = [], []
            for rect, order in zip(self.rects[l], orders):
                la, lb, ra, rb = _median_split(pts, order, rect)
                a0, a1, ra0, ra1 = _median_split(pts, la, ra)
                b0, b1, rb0, rb1 = _median_split(pts, lb, rb)
                next_rects += [ra0, ra1, rb0, rb1]
                next_orders += [a0, a1, b0, b1]
            self.rects.append(next_rects)
            orders = next_orders
        self.leaf_orders = orders  # original indices per leaf

    def centers(self, l):
        r = np.asarray(self.rects[l])
        return 0.5 * (r[:, 0] + r[:, 2]), 0.5 * (r[:, 1] + r[:, 3])

    def radii(self, l):
        r = np.asarray(self.rects[l])
        return 0.5 * np.hypot(r[:, 2] - r[:, 0], r[:, 3] - r[:, 1])


def connectivity(pyr: Pyramid, theta=0.5):
    """(weak[l] lists for l=1..L, near, p2l, m2p) as python lists."""
    weak = [None]
    strong_prev = [[0]]
    for l in range(1, pyr.levels + 1):
        nb = 4 ** l
        cx, cy = pyr.centers(l)
        rad = pyr.radii(l)
        weak_l, strong_l = [], []
        for b in range(nb):
            wl, sl = [], []
            for sp in strong_prev[b // 4]:
                for c in range(4 * sp, 4 * sp + 4):
                    d = np.hypot(cx[b] - cx[c], cy[b] - cy[c])
                    big, small = max(rad[b], rad[c]), min(rad[b], rad[c])
                    if big + theta * small <= theta * d:
                        wl.append(c)
                    else:
                        sl.append(c)
            weak_l.append(wl)
            strong_l.append(sl)
        weak.append(weak_l)
        strong_prev = strong_l

    nb = 4 ** pyr.levels
    cx, cy = pyr.centers(pyr.levels)
    rad = pyr.radii(pyr.levels)
    near, p2l, m2p = [], [], []
    for b in range(nb):
        nl_, pl_, ml_ = [], [], []
        for s in strong_prev[b]:
            if s == b:
                nl_.append(s)
                continue
            d = np.hypot(cx[b] - cx[s], cy[b] - cy[s])
            big, small = max(rad[b], rad[s]), min(rad[b], rad[s])
            if small + theta * big <= theta * d and rad[s] != rad[b]:
                (pl_ if rad[s] > rad[b] else ml_).append(s)
            else:
                nl_.append(s)
        near.append(nl_)
        p2l.append(pl_)
        m2p.append(ml_)
    return weak, near, p2l, m2p


def required_config(pyr: Pyramid, weak, near, p2l, m2p, p: int) -> PackConfig:
    """Smallest PackConfig that holds this tree."""
    kfar = tuple(max(1, max(len(w) for w in weak[l]))
                 for l in range(1, pyr.levels + 1))
    return PackConfig(
        levels=pyr.levels,
        p=p,
        nmax=max(len(o) for o in pyr.leaf_orders),
        kfar=kfar,
        knear=max(len(x) for x in near),
        ksp=max(1, max(max((len(x) for x in p2l), default=0),
                       max((len(x) for x in m2p), default=0))),
    )


def pack(pts, gam, pyr: Pyramid, cfg: PackConfig, weak, near, p2l, m2p):
    """Produce the model's input arrays (dict keyed by spec name)."""
    nl, nmax = cfg.n_leaves, cfg.nmax
    out = {
        "pos_re": np.zeros((nl, nmax)),
        "pos_im": np.zeros((nl, nmax)),
        "gam_re": np.zeros((nl, nmax)),
        "gam_im": np.zeros((nl, nmax)),
        "mask": np.zeros((nl, nmax)),
    }
    for b, order in enumerate(pyr.leaf_orders):
        k = len(order)
        assert k <= nmax, f"box {b}: {k} > nmax={nmax}"
        out["pos_re"][b, :k] = pts[order, 0]
        out["pos_im"][b, :k] = pts[order, 1]
        out["gam_re"][b, :k] = gam[order].real
        out["gam_im"][b, :k] = gam[order].imag
        out["mask"][b, :k] = 1.0

    ctr_re = np.zeros(cfg.nbtot)
    ctr_im = np.zeros(cfg.nbtot)
    for l in range(cfg.levels + 1):
        cx, cy = pyr.centers(l)
        off = cfg.level_offset(l)
        ctr_re[off:off + 4 ** l] = cx
        ctr_im[off:off + 4 ** l] = cy
    out["ctr_re"], out["ctr_im"] = ctr_re, ctr_im

    def pad_lists(lists, k):
        arr = np.full((len(lists), k), -1, dtype=np.int32)
        for i, row in enumerate(lists):
            assert len(row) <= k, f"row {i}: {len(row)} > pad {k}"
            arr[i, :len(row)] = row
        return arr

    for l in range(1, cfg.levels + 1):
        out[f"m2l_idx_{l}"] = pad_lists(weak[l], cfg.kfar[l - 1])
    out["near_idx"] = pad_lists(near, cfg.knear)
    out["p2l_idx"] = pad_lists(p2l, cfg.ksp)
    out["m2p_idx"] = pad_lists(m2p, cfg.ksp)
    return out


def pack_points(pts, gam, levels, p, cfg=None, theta=0.5):
    """End-to-end: build pyramid + connectivity, pack to `cfg` (or the
    minimal config). Returns (cfg, args_list, unpack) where `unpack`
    scatters a [nl, nmax] result back to input order."""
    pyr = Pyramid(pts, levels)
    weak, near, p2l, m2p = connectivity(pyr, theta)
    need = required_config(pyr, weak, near, p2l, m2p, p)
    if cfg is None:
        cfg = need
    else:
        assert cfg.levels == levels and cfg.nmax >= need.nmax
        assert all(a >= b for a, b in zip(cfg.kfar, need.kfar)), \
            f"kfar {need.kfar} exceeds config {cfg.kfar}"
        assert cfg.knear >= need.knear and cfg.ksp >= need.ksp
    packed = pack(pts, gam, pyr, cfg, weak, near, p2l, m2p)
    args = [packed[name] for (name, _, _) in cfg.input_specs()]

    def unpack(grid):
        res = np.zeros(len(pts), dtype=grid.dtype)
        for b, order in enumerate(pyr.leaf_orders):
            res[order] = np.asarray(grid)[b, :len(order)]
        return res

    return cfg, args, unpack
