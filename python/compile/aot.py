"""AOT lowering: JAX model → HLO *text* artifacts + .meta manifests.

HLO text (not `.serialize()`d protos) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts] [--only NAME]
Idempotent: artifacts are rewritten only when missing or when this
package's sources are newer (`make artifacts` relies on that).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model
from .model import ARTIFACT_CONFIGS, DIRECT_N, PackConfig, make_fmm_fn


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    `print_large_constants=True` is ESSENTIAL: the default printer elides
    any constant larger than a few elements as `constant({...})`, which the
    downstream HLO parser silently accepts as zeros — the baked shift
    structure matrices would vanish and the artifact would compute garbage
    (found the hard way; pinned by test_hlo_text_contains_constants).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_fmm(cfg: PackConfig, use_pallas: bool) -> str:
    fn = make_fmm_fn(cfg, use_pallas=use_pallas)
    lowered = jax.jit(fn).lower(*cfg.example_args())
    return to_hlo_text(lowered)


#: Problem slots per batched artifact. The Rust batch planner issues one
#: dispatch per shape-compatible group (`rust/src/batch/`); a narrower
#: group is padded with empty problems (zero masks, -1 gather lists) that
#: are numerically inert. A group *wider* than this is NOT auto-split —
#: artifact selection errors, so cap the group with `--batch-size 8` or
#: emit a wider bucket here (see DESIGN.md §4).
BATCH_SLOTS = 8


def lower_fmm_batched(cfg: PackConfig, batch: int, use_pallas: bool) -> str:
    """Lower the single-problem model vmapped over a leading `batch` axis.

    Every input/output of the per-problem ABI gains one leading axis of
    length `batch` — exactly the stacked layout `packing::pack_fmm_batch`
    produces on the Rust side. The manifest keeps the *per-problem* shapes
    and records the slot count in the `batch` field (the ABI contract of
    `rust/src/packing/ArtifactMeta`)."""
    fn = make_fmm_fn(cfg, use_pallas=use_pallas)
    args = [
        jax.ShapeDtypeStruct((batch,) + tuple(spec.shape), spec.dtype)
        for spec in cfg.example_args()
    ]
    lowered = jax.jit(jax.vmap(fn)).lower(*args)
    return to_hlo_text(lowered)


def lower_direct(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jax.numpy.float64)
    lowered = jax.jit(model.direct_eval).lower(spec, spec, spec, spec)
    return to_hlo_text(lowered)


def fmm_meta(name: str, cfg: PackConfig, variant: str = "jnp", batch: int = 0) -> dict:
    meta = {
        "name": name,
        "kind": "fmm",
        # 'jnp': hot spots lowered from the pure-jnp reference — the fast
        #   execution variant on the CPU PJRT backend (interpret-mode
        #   Pallas lowers to while-loops the old CPU runtime executes
        #   slowly; see EXPERIMENTS.md §Perf L2).
        # 'pallas': hot spots lowered THROUGH the L1 Pallas kernels — the
        #   TPU-design artifact; numerically identical (pinned by
        #   runtime_e2e::pallas_variant_matches_jnp_variant).
        "variant": variant,
        "levels": cfg.levels,
        "p": cfg.p,
        "nmax": cfg.nmax,
        "kfar": list(cfg.kfar),
        "knear": cfg.knear,
        "ksp": cfg.ksp,
        "nbtot": cfg.nbtot,
        "inputs": [
            {"name": n_, "shape": list(shape), "dtype": dt}
            for (n_, shape, dt) in cfg.input_specs()
        ],
        "outputs": [
            {"name": "pot_re", "shape": [cfg.n_leaves, cfg.nmax], "dtype": "f64"},
            {"name": "pot_im", "shape": [cfg.n_leaves, cfg.nmax], "dtype": "f64"},
        ],
    }
    if batch:
        # grouped artifact: per-problem shapes above, `batch` slots stacked
        # along a leading axis (consumed by runtime::run_fmm_group)
        meta["batch"] = batch
    return meta


def direct_meta(name: str, n: int) -> dict:
    return {
        "name": name,
        "kind": "direct",
        "n": n,
        "inputs": [
            {"name": k, "shape": [n], "dtype": "f64"}
            for k in ("pos_re", "pos_im", "gam_re", "gam_im")
        ],
        "outputs": [
            {"name": "pot_re", "shape": [n], "dtype": "f64"},
            {"name": "pot_im", "shape": [n], "dtype": "f64"},
        ],
    }


def _sources_mtime() -> float:
    pkg = Path(__file__).parent
    return max(f.stat().st_mtime for f in pkg.rglob("*.py"))


def emit(out_dir: Path, only: str | None = None, force: bool = False) -> int:
    out_dir.mkdir(parents=True, exist_ok=True)
    stale_after = _sources_mtime()
    jobs = []
    for name, cfg in ARTIFACT_CONFIGS.items():
        jobs.append((name, "fmm-jnp", cfg))
        if not name.endswith("_tight"):
            # the TPU-design (Pallas) variant tracks the wide bucket only —
            # it exists for layer-parity validation, not fast CPU execution
            jobs.append((f"{name}_pallas", "fmm-pallas", cfg))
            # grouped artifact for the batch subsystem: same wide bucket,
            # BATCH_SLOTS problems stacked along a leading axis, manifest
            # field "batch" (the Rust side already consumes it)
            jobs.append((f"{name}_b{BATCH_SLOTS}", "fmm-batch", cfg))
    jobs.append((f"direct_n{DIRECT_N}", "direct", DIRECT_N))
    written = 0
    for name, kind, payload in jobs:
        if only and name != only:
            continue
        hlo_path = out_dir / f"{name}.hlo.txt"
        meta_path = out_dir / f"{name}.meta.json"
        if (not force and hlo_path.exists() and meta_path.exists()
                and hlo_path.stat().st_mtime >= stale_after):
            print(f"[aot] {name}: up to date")
            continue
        print(f"[aot] lowering {name} …", flush=True)
        if kind == "fmm-jnp":
            text = lower_fmm(payload, use_pallas=False)
            meta = fmm_meta(name, payload, "jnp")
        elif kind == "fmm-pallas":
            text = lower_fmm(payload, use_pallas=True)
            meta = fmm_meta(name, payload, "pallas")
        elif kind == "fmm-batch":
            text = lower_fmm_batched(payload, BATCH_SLOTS, use_pallas=False)
            meta = fmm_meta(name, payload, "jnp", batch=BATCH_SLOTS)
        else:
            text = lower_direct(payload)
            meta = direct_meta(name, payload)
        hlo_path.write_text(text)
        meta_path.write_text(json.dumps(meta, indent=1))
        print(f"[aot] wrote {hlo_path} ({len(text) / 1e6:.1f} MB)")
        written += 1
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=str(Path(__file__).parents[2] / "artifacts"))
    ap.add_argument("--only", default=None, help="emit a single artifact")
    ap.add_argument("--force", action="store_true")
    # tolerated for Makefile compatibility
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = Path(args.out).parent if args.out else Path(args.out_dir)
    emit(out_dir, args.only, args.force)
    print("[aot] done", file=sys.stderr)


if __name__ == "__main__":
    main()
