"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle,
hypothesis-swept over shapes and seeds. This is the CORE correctness
signal of the compile path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref
from compile.kernels.m2l import m2l_core_pallas
from compile.kernels.p2p import p2p_pallas


def rand_p2p_case(rng, b, n, s):
    tx = rng.uniform(size=(b, n))
    ty = rng.uniform(size=(b, n))
    sx = rng.uniform(size=(b, s))
    sy = rng.uniform(size=(b, s))
    gre = rng.normal(size=(b, s))
    gim = rng.normal(size=(b, s))
    sm = (rng.uniform(size=(b, s)) > 0.2).astype(np.float64)
    return tx, ty, sx, sy, gre, gim, sm


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 6),
    n=st.sampled_from([1, 7, 16, 32]),
    k=st.integers(1, 5),
)
def test_p2p_pallas_matches_ref(seed, b, n, k):
    rng = np.random.default_rng(seed)
    case = rand_p2p_case(rng, b, n, k * n)
    got = p2p_pallas(*map(jnp.asarray, case))
    want = ref.p2p_ref(*map(jnp.asarray, case))
    np.testing.assert_allclose(got[0], want[0], rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-12, atol=1e-12)


def test_p2p_self_exclusion():
    # a target coinciding with a source contributes nothing (the FMM feeds
    # each box its own particles through the near list)
    tx = jnp.asarray([[0.25, 0.75]])
    ty = jnp.asarray([[0.5, 0.5]])
    sx, sy = tx, ty  # sources identical to targets
    gre = jnp.ones((1, 2))
    gim = jnp.zeros((1, 2))
    sm = jnp.ones((1, 2))
    pr, pi = p2p_pallas(tx, ty, sx, sy, gre, gim, sm)
    # Φ(z0) = 1/(z1−z0) = 1/0.5 = 2, Φ(z1) = −2
    np.testing.assert_allclose(pr, [[2.0, -2.0]], atol=1e-13)
    np.testing.assert_allclose(pi, [[0.0, 0.0]], atol=1e-13)


def test_p2p_mask_blocks_contributions():
    rng = np.random.default_rng(0)
    tx, ty, sx, sy, gre, gim, _ = rand_p2p_case(rng, 2, 8, 24)
    sm0 = np.zeros((2, 24))
    pr, pi = p2p_pallas(*map(jnp.asarray, (tx, ty, sx, sy, gre, gim, sm0)))
    assert float(jnp.abs(pr).max()) == 0.0
    assert float(jnp.abs(pi).max()) == 0.0


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    i=st.sampled_from([1, 5, 128, 130, 257]),
    p=st.sampled_from([1, 2, 8, 17, 42]),
)
def test_m2l_core_pallas_matches_ref(seed, i, p):
    rng = np.random.default_rng(seed)
    are = rng.normal(size=(i, p + 1))
    aim = rng.normal(size=(i, p + 1))
    got = m2l_core_pallas(jnp.asarray(are), jnp.asarray(aim), p)
    want = ref.m2l_core_ref(jnp.asarray(are), jnp.asarray(aim), p)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-12, atol=1e-12)
    assert got[0].shape == (i, p + 1)


def test_m2l_structure_matrix_values():
    # T[l,k] = C(k+l-1, l); spot-check against hand values at p=3
    t = ref.m2l_structure_matrix(3)
    assert t[0, 1] == 1 and t[0, 2] == 1 and t[0, 3] == 1
    assert t[1, 1] == 1 and t[1, 2] == 2 and t[1, 3] == 3
    assert t[2, 2] == 3 and t[2, 3] == 6
    assert (t[:, 0] == 0).all()


def test_structure_matrices_consistency():
    # M2M and L2L matrices are triangular with Pascal entries
    s = ref.m2m_structure_matrix(5)
    u = ref.l2l_structure_matrix(5)
    assert s[3, 2] == 2  # C(2,1)
    assert u[1, 3] == 3  # (-1)^2 C(3,1)
    assert u[0, 1] == -1
    # strictly triangular structure
    assert np.allclose(np.triu(s, 1), 0)
    assert np.allclose(np.tril(u, -1), 0)


def test_m2l_end_to_end_vs_taylor():
    """Full M2L (pre-scale → pallas core → post-scale) against a brute
    Taylor re-expansion, the same cross-check as the Rust tests."""
    rng = np.random.default_rng(7)
    p = 17
    a = np.zeros(p + 1, complex)
    a[1:] = rng.normal(size=p) + 1j * rng.normal(size=p)
    zi, zo = 0.1 + 0.2j, 1.4 - 0.6j
    r = zo - zi
    # reference local coefficients (series form)
    from math import comb
    b_ref = np.array([
        (-1.0) ** l / r ** l
        * sum(comb(k + l - 1, l) * a[k] / r ** k for k in range(1, p + 1))
        for l in range(p + 1)
    ])
    # kernel path
    ahat = np.array([a[k] / r ** k for k in range(p + 1)])
    bre, bim = m2l_core_pallas(
        jnp.asarray(ahat.real)[None, :], jnp.asarray(ahat.imag)[None, :], p
    )
    bhat = np.asarray(bre[0]) + 1j * np.asarray(bim[0])
    b_got = np.array([(-1.0) ** l / r ** l * bhat[l] for l in range(p + 1)])
    np.testing.assert_allclose(b_got, b_ref, rtol=1e-10)
