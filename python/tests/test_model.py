"""L2 model correctness: the fused FMM pipeline vs O(N²) direct
summation, over the paper's three point distributions."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_enable_x64", True)

from compile import treepack
from compile.kernels import ref
from compile.model import ARTIFACT_CONFIGS, PackConfig, fmm_eval


def sample(dist, n, rng):
    if dist == "uniform":
        pts = rng.uniform(size=(n, 2))
    elif dist == "normal":
        pts = np.empty((n, 2))
        i = 0
        while i < n:
            cand = rng.normal(0.5, 0.1, size=(n, 2))
            ok = cand[((cand >= 0) & (cand <= 1)).all(axis=1)]
            take = min(len(ok), n - i)
            pts[i:i + take] = ok[:take]
            i += take
    elif dist == "layer":
        x = rng.uniform(size=(n, 1))
        y = np.empty((n, 1))
        i = 0
        while i < n:
            cand = rng.normal(0.5, 0.05, size=(n, 1))
            ok = cand[(cand[:, 0] >= 0) & (cand[:, 0] <= 1)]
            take = min(len(ok), n - i)
            y[i:i + take, 0] = ok[:take, 0]
            i += take
        pts = np.hstack([x, y])
    gam = rng.normal(size=n) + 1j * rng.normal(size=n)
    return pts, gam


def direct_np(pts, gam):
    z = pts[:, 0] + 1j * pts[:, 1]
    dz = z[None, :] - z[:, None]  # z_j − z_i
    with np.errstate(divide="ignore", invalid="ignore"):
        inv = np.where(dz != 0, 1.0 / np.where(dz != 0, dz, 1.0), 0.0)
    return (gam[None, :] * inv).sum(axis=1)


def run_model(pts, gam, levels, p, use_pallas, cfg=None):
    cfg, args, unpack = treepack.pack_points(pts, gam, levels, p, cfg=cfg)
    out_re, out_im = fmm_eval(cfg, *map(jnp.asarray, args),
                              use_pallas=use_pallas)
    return unpack(np.asarray(out_re)) + 1j * unpack(np.asarray(out_im))


@pytest.mark.parametrize("dist", ["uniform", "normal", "layer"])
def test_fmm_matches_direct(dist):
    rng = np.random.default_rng(42)
    pts, gam = sample(dist, 600, rng)
    phi = run_model(pts, gam, levels=2, p=17, use_pallas=False)
    exact = direct_np(pts, gam)
    err = np.abs(phi - exact).max() / np.abs(exact).max()
    assert err < 1e-5, f"{dist}: rel err {err:.2e}"


def test_fmm_pallas_equals_jnp_path():
    """The Pallas kernels and the jnp reference produce the same fused
    pipeline output to near machine precision."""
    rng = np.random.default_rng(3)
    pts, gam = sample("uniform", 400, rng)
    a = run_model(pts, gam, levels=2, p=10, use_pallas=True)
    b = run_model(pts, gam, levels=2, p=10, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-11, atol=1e-11)


def test_accuracy_improves_with_p():
    rng = np.random.default_rng(5)
    pts, gam = sample("uniform", 500, rng)
    exact = direct_np(pts, gam)
    errs = []
    for p in (4, 8, 16):
        phi = run_model(pts, gam, levels=2, p=p, use_pallas=False)
        errs.append(np.abs(phi - exact).max() / np.abs(exact).max())
    assert errs[1] < errs[0] and errs[2] < errs[1], errs
    assert errs[2] < 1e-4


def test_three_levels_deep_tree():
    rng = np.random.default_rng(11)
    pts, gam = sample("normal", 1500, rng)
    phi = run_model(pts, gam, levels=3, p=17, use_pallas=False)
    exact = direct_np(pts, gam)
    err = np.abs(phi - exact).max() / np.abs(exact).max()
    assert err < 2e-5, f"rel err {err:.2e}"


def test_padded_artifact_config_matches_minimal():
    """Running under a padded named config equals the minimal config:
    padding slots are inert."""
    rng = np.random.default_rng(13)
    pts, gam = sample("uniform", 500, rng)
    a = run_model(pts, gam, 2, 8, use_pallas=False)
    b = run_model(pts, gam, 2, 8, use_pallas=False,
                  cfg=ARTIFACT_CONFIGS["fmm_l2_p8"])
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


def test_direct_ref_matches_numpy():
    rng = np.random.default_rng(17)
    pts, gam = sample("uniform", 200, rng)
    pr, pi = ref.direct_ref(*map(jnp.asarray, (
        pts[:, 0], pts[:, 1], gam.real, gam.imag)))
    exact = direct_np(pts, gam)
    np.testing.assert_allclose(np.asarray(pr) + 1j * np.asarray(pi), exact,
                               rtol=1e-11, atol=1e-11)


def test_input_specs_abi_stable():
    """The artifact ABI (input order) the Rust runtime hardcodes against."""
    cfg = ARTIFACT_CONFIGS["fmm_l3_p17"]
    names = [s[0] for s in cfg.input_specs()]
    assert names == [
        "pos_re", "pos_im", "gam_re", "gam_im", "mask", "ctr_re", "ctr_im",
        "m2l_idx_1", "m2l_idx_2", "m2l_idx_3",
        "near_idx", "p2l_idx", "m2p_idx",
    ]
    assert cfg.nbtot == 1 + 4 + 16 + 64
