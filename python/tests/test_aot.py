"""AOT emitter contracts: manifest shape of batched (grouped) artifacts.

The Rust runtime consumes the `batch` manifest field
(`rust/src/packing/ArtifactMeta`): per-problem `inputs`/`outputs` shapes
plus a leading slot axis on the executable. These tests pin that ABI
without paying for a full HLO lowering of every config.
"""

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot
from compile.model import ARTIFACT_CONFIGS


def test_single_problem_meta_has_no_batch_field():
    cfg = ARTIFACT_CONFIGS["fmm_l2_p8"]
    meta = aot.fmm_meta("fmm_l2_p8", cfg, "jnp")
    assert "batch" not in meta


def test_batched_meta_keeps_per_problem_shapes():
    cfg = ARTIFACT_CONFIGS["fmm_l2_p8"]
    meta = aot.fmm_meta("fmm_l2_p8_b8", cfg, "jnp", batch=aot.BATCH_SLOTS)
    assert meta["batch"] == aot.BATCH_SLOTS
    # the manifest records *per-problem* shapes; the slot axis lives only
    # on the executable (pack_fmm_batch prepends it)
    by_name = {s["name"]: s["shape"] for s in meta["inputs"]}
    assert by_name["pos_re"] == [cfg.n_leaves, cfg.nmax]
    assert by_name["near_idx"] == [cfg.n_leaves, cfg.knear]
    assert meta["outputs"][0]["shape"] == [cfg.n_leaves, cfg.nmax]


def test_batched_lowering_carries_leading_slot_axis():
    cfg = ARTIFACT_CONFIGS["fmm_l2_p8"]
    text = aot.lower_fmm_batched(cfg, aot.BATCH_SLOTS, use_pallas=False)
    # the vmapped executable consumes [batch] + per-problem shape
    assert f"f64[{aot.BATCH_SLOTS},{cfg.n_leaves},{cfg.nmax}]" in text
