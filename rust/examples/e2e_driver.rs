//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! A 2-D point-vortex *simulation served through the AOT path*: the Rust
//! coordinator (L3) builds the adaptive tree each step, packs it, executes
//! the AOT-compiled XLA artifact (L2 model whose hot spots are the L1
//! Pallas kernels) through PJRT, and advances the dynamics — Python never
//! runs. Each step's result is cross-validated against the serial CPU
//! engine, demonstrating the paper's headline property that the two codes
//! have *identical accuracy* (§4.5), and the run is recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Needs a build with the `pjrt` feature (a stub main explains otherwise).
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example e2e_driver`

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "e2e_driver drives the PJRT runtime, which is disabled in this build; \
         rebuild with `cargo run --release --features pjrt --example e2e_driver`"
    );
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn main() -> fmm2d::util::error::Result<()> {
    use fmm2d::complex::C64;
    use fmm2d::config::FmmConfig;
    use fmm2d::connectivity::Connectivity;
    use fmm2d::ensure;
    use fmm2d::expansion::Kernel;
    use fmm2d::fmm::{evaluate_on_tree, FmmOptions};
    use fmm2d::runtime::Runtime;
    use fmm2d::tree::Pyramid;
    use fmm2d::util::rng::Pcg64;
    use fmm2d::util::stats::Summary;
    use fmm2d::workload;

    let mut rt = Runtime::new(None)?;
    if rt.available().is_empty() {
        fmm2d::bail!("no artifacts found — run `make artifacts` first");
    }
    println!("platform: {} | artifacts: {:?}", rt.platform(), rt.available());

    // workload sized for the l4 artifact (256 leaf boxes, nmax 64)
    let n = 12_000;
    let levels = 4;
    let mut rng = Pcg64::seed_from_u64(99);
    let (mut points, gammas) = workload::normal_cloud(n, 0.12, &mut rng);
    // bucketed executable selection: the smallest artifact whose pads fit
    let pyr0 = Pyramid::build(&points, &gammas, levels)?;
    let con0 = Connectivity::build(&pyr0, 0.5);
    let exe = rt.fmm_artifact_for_tree(&pyr0, &con0)?;
    println!(
        "artifact {} (levels={}, p={}, nmax={})",
        exe.meta.name, exe.meta.levels, exe.meta.p, exe.meta.nmax
    );

    let opts = FmmOptions {
        cfg: FmmConfig {
            p: exe.meta.p,
            levels_override: Some(levels),
            ..FmmConfig::default()
        },
        kernel: Kernel::Harmonic,
        symmetric_p2p: true,
        threads: Some(1),
        topo_threads: None,
        ..FmmOptions::default()
    };

    let steps = 5;
    let dt = 1.0e-3;
    let mut exec_times = Vec::new();
    let mut agreements = Vec::new();
    println!("step   exec[ms]   total[ms]   |xla − serial|/|serial|");
    for step in 0..steps {
        // L3: topological phase
        let pyr = Pyramid::build(&points, &gammas, levels)?;
        let con = Connectivity::build(&pyr, opts.cfg.theta);

        // L2+L1 through PJRT
        let (phi_xla, stats) = exe.run_fmm(&pyr, &con)?;

        // cross-validate against the serial engine on the same tree
        let (phi_leaf, _, _) = evaluate_on_tree(&pyr, &con, &opts);
        let phi_serial = pyr.unpermute(&phi_leaf);
        let agree = phi_xla
            .iter()
            .zip(&phi_serial)
            .map(|(a, b)| (*a - *b).abs() / b.abs().max(1e-12))
            .fold(0.0f64, f64::max);
        agreements.push(agree);
        exec_times.push(stats.execute_s);
        println!(
            "{step:>4} {:>10.1} {:>11.1} {agree:>18.3e}",
            stats.execute_s * 1e3,
            stats.total() * 1e3
        );
        ensure!(agree < 1e-9, "layers disagree at step {step}");

        // advance the vortex system with the XLA-computed field
        let scale = dt / (2.0 * std::f64::consts::PI);
        for (z, phi) in points.iter_mut().zip(&phi_xla) {
            *z += C64::new(phi.im, phi.re).scale(scale);
            // keep particles inside the artifact's domain assumptions
            z.re = z.re.clamp(0.0, 1.0);
            z.im = z.im.clamp(0.0, 1.0);
        }
    }

    let s = Summary::of(&exec_times);
    println!(
        "\n{steps} steps of N = {n}: execute median {:.1} ms (spread ±{:.0}%), \
         max layer disagreement {:.2e}",
        s.median * 1e3,
        100.0 * s.rel_spread(),
        agreements.iter().fold(0.0f64, |a, &b| a.max(b))
    );
    println!("e2e_driver OK — record this line in EXPERIMENTS.md §End-to-end");
    Ok(())
}
