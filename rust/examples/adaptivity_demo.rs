//! Adaptivity demo (paper §5.4, Figs. 2.1/5.8/5.9): build the asymmetric
//! pyramid over the paper's three point distributions and show how the
//! mesh, the interaction lists and the runtime respond to non-uniformity.
//!
//! Run: `cargo run --release --example adaptivity_demo`

use fmm2d::config::FmmConfig;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{evaluate_on_tree, FmmOptions};
use fmm2d::topology::{self, TopologyOptions};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

fn main() {
    let n = 60_000;
    let cfg = FmmConfig::new(17, 45);
    let levels = cfg.levels_for(n);
    println!("N = {n}, levels = {levels}, θ = {}", cfg.theta);
    println!(
        "{:<18} {:>9} {:>9} {:>7} {:>7} {:>7} {:>10} {:>9}",
        "distribution", "near/box", "weak/box", "p2l", "m2p", "ecc", "time[ms]", "vs uni"
    );

    let mut uniform_time = 0.0;
    for dist in [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.1 },
        Distribution::Normal { sigma: 0.02 },
        Distribution::Layer { sigma: 0.1 },
        Distribution::Layer { sigma: 0.02 },
    ] {
        let mut rng = Pcg64::seed_from_u64(1);
        let (pts, gs) = dist.generate(n, &mut rng);
        // the unified topology layer (parallel engine, all cores)
        let topo = topology::build(&pts, &gs, levels, &TopologyOptions::default())
            .expect("demo workloads satisfy the pyramid invariants");
        let (pyr, con) = (&topo.pyramid, &topo.connectivity);

        // mesh diagnostics: average in-degrees and box eccentricity
        let nl = pyr.n_leaves() as f64;
        let near_avg = con.near.len() as f64 / nl;
        let weak_avg = con.weak[levels].len() as f64 / nl;
        let ecc_max = pyr.rects[levels]
            .iter()
            .map(|r| r.eccentricity())
            .fold(0.0, f64::max);

        let opts = FmmOptions {
            cfg,
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads: None,
            topo_threads: None,
            ..FmmOptions::default()
        };
        let t = std::time::Instant::now();
        let (_, _, _) = evaluate_on_tree(pyr, con, &opts);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        if dist == Distribution::Uniform {
            uniform_time = ms;
        }

        println!(
            "{:<18} {near_avg:>9.1} {weak_avg:>9.1} {:>7} {:>7} {ecc_max:>7.1} {ms:>10.1} {:>8.2}x",
            dist.name(),
            con.p2l.len(),
            con.m2p.len(),
            ms / uniform_time
        );

        // the pyramid keeps populations balanced regardless of clustering —
        // the defining property of asymmetric adaptivity (§2)
        let sizes: Vec<usize> = (0..pyr.n_leaves()).map(|b| pyr.leaf(b).len()).collect();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(hi - lo <= 4, "{}: unbalanced leaves {lo}..{hi}", dist.name());
    }
    println!("\nall leaf populations stayed balanced (pyramid invariant) — adaptivity_demo OK");
}
