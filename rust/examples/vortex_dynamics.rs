//! Vortex dynamics: the application domain that motivated the paper's code
//! (the authors' vortex-method work on vertical-axis wind turbines).
//!
//! Two counter-rotating Gaussian vortex patches form a dipole that
//! self-propels: the complex potential `Φ(z) = Σ Γ_j/(z_j − z)` of
//! Eq. (5.1) yields the induced velocity `(u, v) = (Im Φ, Re Φ)/2π` for
//! real circulations. We integrate with forward Euler, using the FMM for
//! every right-hand side, and monitor the invariants the exact dynamics
//! conserves (total circulation, linear impulse).
//!
//! Run: `cargo run --release --example vortex_dynamics`

use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{evaluate, FmmOptions};
use fmm2d::util::rng::Pcg64;

fn induced_velocities(points: &[C64], gammas: &[C64], opts: &FmmOptions) -> Vec<C64> {
    let out = evaluate(points, gammas, opts).expect("valid vortex workload");
    let scale = 1.0 / (2.0 * std::f64::consts::PI);
    out.potentials
        .iter()
        .map(|phi| C64::new(phi.im, phi.re).scale(scale))
        .collect()
}

fn total_circulation(gammas: &[C64]) -> f64 {
    gammas.iter().map(|g| g.re).sum()
}

fn linear_impulse(points: &[C64], gammas: &[C64]) -> C64 {
    points
        .iter()
        .zip(gammas)
        .map(|(&z, &g)| z.scale(g.re))
        .sum()
}

fn main() {
    let n_per_patch = 4_000;
    let mut rng = Pcg64::seed_from_u64(7);

    // two patches of opposite circulation — a self-propelling dipole
    let mut points = Vec::with_capacity(2 * n_per_patch);
    let mut gammas = Vec::with_capacity(2 * n_per_patch);
    for (cx, sign) in [(0.35, 1.0), (0.65, -1.0)] {
        for _ in 0..n_per_patch {
            points.push(C64::new(
                rng.normal_with(cx, 0.04),
                rng.normal_with(0.5, 0.04),
            ));
            gammas.push(C64::new(sign / n_per_patch as f64, 0.0));
        }
    }

    let opts = FmmOptions {
        cfg: FmmConfig::new(17, 45),
        kernel: Kernel::Harmonic,
        symmetric_p2p: true,
        threads: None,
        topo_threads: None,
        ..FmmOptions::default()
    };

    let gamma0 = total_circulation(&gammas);
    let imp0 = linear_impulse(&points, &gammas);
    println!("step   dipole-y-center   |impulse drift|");

    let dt = 2.0e-3;
    let steps = 25;
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        if step % 5 == 0 {
            let com_y: f64 = points
                .iter()
                .zip(&gammas)
                .map(|(z, g)| z.im * g.re.abs())
                .sum::<f64>()
                / gammas.iter().map(|g| g.re.abs()).sum::<f64>();
            let drift = (linear_impulse(&points, &gammas) - imp0).abs();
            println!("{step:>4} {com_y:>16.6} {drift:>16.3e}");
        }
        let vel = induced_velocities(&points, &gammas, &opts);
        for (z, v) in points.iter_mut().zip(&vel) {
            *z += v.scale(dt);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "{steps} FMM evaluations of N = {} in {elapsed:.2} s ({:.1} ms each)",
        points.len(),
        elapsed / steps as f64 * 1e3
    );

    // conservation checks
    assert_eq!(total_circulation(&gammas), gamma0);
    let drift = (linear_impulse(&points, &gammas) - imp0).abs();
    assert!(drift < 5e-3, "impulse drift {drift:.3e}");
    println!("vortex_dynamics OK (impulse drift {drift:.2e})");
}
