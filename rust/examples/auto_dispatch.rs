//! Autotuned dispatch: calibrate a cost-model profile on this machine,
//! let the dispatcher pick the engine per problem and per batch group,
//! and print the decisions with predicted vs measured times.
//!
//! Run: `cargo run --release --example auto_dispatch`

use std::sync::Arc;

use fmm2d::batch::{self, BatchEngine, BatchOptions, BatchProblem};
use fmm2d::config::FmmConfig;
use fmm2d::dispatch::{
    evaluate_auto, CalibrationOptions, CalibrationProfile, DispatchReport, Dispatcher, Problem,
};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload;

fn main() {
    // 1. calibrate: a short pass of real evaluations measures per-phase
    //    CPU throughput for the serial and pooled engines (quick sizes —
    //    a couple of seconds; `fmm2d calibrate` persists this to disk)
    let profile = CalibrationProfile::measure(&CalibrationOptions {
        quick: true,
        ..CalibrationOptions::default()
    })
    .expect("calibration workloads are valid");
    println!("{}", profile.summary());
    let dispatcher = Dispatcher::new(profile);

    // 2. per-problem selection: tiny problems stay on the serial driver
    //    (no pool fan-out overhead), large ones go to the pool
    let cfg = FmmConfig::default();
    for n in [300usize, 5_000, 80_000] {
        let decision = dispatcher.select(&Problem::from_config(&cfg, n));
        println!(
            "n = {n:>6}: {} (predicted {:.2} ms; serial {:.2} ms, pooled {:.2} ms)",
            decision.choice,
            decision.predicted_s * 1e3,
            decision.cost.serial_s * 1e3,
            decision.cost.pooled_s * 1e3,
        );
    }

    // 3. one auto evaluation end to end, decision + measurement included
    let mut rng = Pcg64::seed_from_u64(7);
    let (points, gammas) = workload::uniform_square(30_000, &mut rng);
    let (out, decision) =
        evaluate_auto(&points, &gammas, &Default::default(), &dispatcher).expect("valid workload");
    println!(
        "auto evaluation of {} points: {}",
        out.potentials.len(),
        DispatchReport {
            decisions: vec![decision],
        }
        .render()
    );

    // 4. a homogeneous batch: the dispatcher resolves the engine per
    //    group, and the batch output carries the full report
    let problems: Vec<BatchProblem> = (0..24)
        .map(|_| {
            let (points, gammas) = workload::uniform_square(2_000, &mut rng);
            BatchProblem { points, gammas }
        })
        .collect();
    let batch_out = batch::run(
        &problems,
        &BatchOptions {
            engine: BatchEngine::Auto,
            dispatcher: Some(Arc::new(dispatcher)),
            ..BatchOptions::default()
        },
    )
    .expect("CPU batch engines cannot fail");
    println!(
        "batch of {} problems in {} groups:",
        batch_out.stats.n_problems, batch_out.stats.n_groups
    );
    println!(
        "{}",
        batch_out.report.expect("auto batches carry a report").render()
    );
    println!("auto_dispatch OK");
}
