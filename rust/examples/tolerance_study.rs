//! Tolerance study: the p ↔ TOL relation of the paper (§2, §5.1):
//! `p ~ log TOL / log θ`, i.e. error ≈ θ^p; p = 17 ⇒ TOL ≈ 1e-6 at
//! θ = 1/2. Also demonstrates the log-kernel extension (a_0 ≠ 0 paths).
//!
//! Run: `cargo run --release --example tolerance_study`

use fmm2d::config::FmmConfig;
use fmm2d::direct;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{evaluate, FmmOptions};
use fmm2d::util::rng::Pcg64;
use fmm2d::util::stats::max_rel_error;
use fmm2d::workload;

fn measured_tol(kernel: Kernel, p: usize, pts: &[fmm2d::C64], gs: &[fmm2d::C64]) -> f64 {
    let opts = FmmOptions {
        cfg: FmmConfig {
            p,
            levels_override: Some(3),
            ..FmmConfig::default()
        },
        kernel,
        symmetric_p2p: true,
        threads: None,
        topo_threads: None,
        ..FmmOptions::default()
    };
    let out = evaluate(pts, gs, &opts).expect("valid workload");
    let exact = direct::eval_symmetric(kernel, pts, gs);
    match kernel {
        Kernel::Harmonic => {
            let a: Vec<f64> = out.potentials.iter().map(|c| c.abs()).collect();
            let e: Vec<f64> = exact.iter().map(|c| c.abs()).collect();
            max_rel_error(&a, &e, 1e-12)
        }
        Kernel::Log => {
            let a: Vec<f64> = out.potentials.iter().map(|c| c.re).collect();
            let e: Vec<f64> = exact.iter().map(|c| c.re).collect();
            max_rel_error(&a, &e, 1e-12)
        }
    }
}

fn main() {
    let n = 4_000;
    let mut rng = Pcg64::seed_from_u64(3);
    let (pts, mut gs) = workload::uniform_square(n, &mut rng);

    println!("{:>4} {:>14} {:>14} {:>14}", "p", "harmonic", "log-kernel", "theta^p");
    let mut harmonic_at_17 = 1.0;
    for p in [5, 9, 13, 17, 21, 25] {
        let tol_h = measured_tol(Kernel::Harmonic, p, &pts, &gs);
        // log kernel requires real strengths (branch-cut coupling otherwise)
        let mut gs_real = gs.clone();
        for g in gs_real.iter_mut() {
            g.im = 0.0;
        }
        let tol_l = measured_tol(Kernel::Log, p, &pts, &gs_real);
        let bound = 0.5f64.powi(p as i32);
        println!("{p:>4} {tol_h:>14.3e} {tol_l:>14.3e} {bound:>14.3e}");
        if p == 17 {
            harmonic_at_17 = tol_h;
        }
    }
    // the paper's quoted operating point
    assert!(
        harmonic_at_17 < 1e-5,
        "p = 17 should deliver ≈ 1e-6 (got {harmonic_at_17:.2e})"
    );
    // suppress unused warning (gs consumed via clones)
    let _ = &mut gs;
    println!("\np = 17 ⇒ TOL ≈ 1e-6 confirmed (paper §5.1) — tolerance_study OK");
}
