//! Quickstart: evaluate the harmonic potential (paper Eq. 5.1) of 20 000
//! random vortices with the adaptive FMM and check it against direct
//! summation.
//!
//! Run: `cargo run --release --example quickstart`

use fmm2d::config::FmmConfig;
use fmm2d::direct;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{evaluate, FmmOptions, PHASE_NAMES};
use fmm2d::util::rng::Pcg64;
use fmm2d::util::stats::max_rel_error;
use fmm2d::workload;

fn main() {
    let n = 20_000;
    let mut rng = Pcg64::seed_from_u64(42);
    let (points, gammas) = workload::uniform_square(n, &mut rng);

    // p = 17 gives a relative tolerance of about 1e-6 (paper §5.1);
    // N_d = 45 sources per box is the paper's GPU-optimal population.
    let opts = FmmOptions {
        cfg: FmmConfig::new(17, 45),
        kernel: Kernel::Harmonic,
        symmetric_p2p: true,
        // the multithreaded engine with all available cores (Some(1) would
        // select the paper's serial reference driver); the topological
        // phase follows suit through the parallel topology engine
        threads: None,
        topo_threads: None,
        ..FmmOptions::default()
    };

    let out = evaluate(&points, &gammas, &opts).expect("valid workload");
    println!("evaluated {n} potentials in {:.1} ms", out.times.total() * 1e3);
    for (i, name) in PHASE_NAMES.iter().enumerate() {
        println!("  {name:<8} {:>8.3} ms", out.times.0[i] * 1e3);
    }

    // verify against O(N²) direct summation
    let exact = direct::eval_symmetric(Kernel::Harmonic, &points, &gammas);
    let approx: Vec<f64> = out.potentials.iter().map(|c| c.abs()).collect();
    let exact_abs: Vec<f64> = exact.iter().map(|c| c.abs()).collect();
    let err = max_rel_error(&approx, &exact_abs, 1e-12);
    println!("max relative error vs direct: {err:.2e} (target ≈ 1e-6 at p = 17)");
    assert!(err < 1e-5);
    println!("quickstart OK");
}
