//! The asymmetric-adaptive pyramid (paper §2 and §3.2).
//!
//! Boxes are split *twice in succession* close to the median of the particle
//! positions, so level `l` always holds exactly `4^l` boxes with (near)
//! equal population — a balanced *pyramid* rather than a general tree. The
//! split direction follows the eccentricity of the box (the θ-criterion is
//! rotationally invariant, so square-ish boxes minimize interactions).
//!
//! The output arranges particles so that every leaf box owns a contiguous
//! slice — the static memory layout that both the serial driver and the
//! data-parallel packing rely on.

pub mod partition;

use crate::complex::C64;
use crate::geometry::Rect;
use partition::{median_split, median_split_gpu_model, SortStats};

/// Which partitioning engine builds the pyramid: the serial quickselect
/// (paper §4.1) or the functional model of the CUDA scheme (Algorithms
/// 3.1/3.2) whose [`SortStats`] feed the GPU cost simulator. Both produce
/// identical median splits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionEngine {
    #[default]
    Cpu,
    GpuModel,
}

/// Index arithmetic of the pyramid: boxes of level `l` are numbered
/// `0..4^l`; the children of box `b` are `4b..4b+4` at the next level.
#[inline]
pub fn boxes_at_level(l: usize) -> usize {
    1usize << (2 * l)
}

/// Parent of box `b` (at level `l ≥ 1`).
#[inline]
pub fn parent_of(b: usize) -> usize {
    b >> 2
}

/// First child of box `b`.
#[inline]
pub fn first_child_of(b: usize) -> usize {
    b << 2
}

/// One particle record carried through the partitioning permutation.
#[derive(Clone, Copy, Debug)]
pub struct Particle {
    pub pos: C64,
    pub gamma: C64,
    /// Index into the caller's original arrays.
    pub orig: u32,
}

/// The fully built pyramid.
#[derive(Clone, Debug)]
pub struct Pyramid {
    /// Number of refinement levels `L` (leaf level). Level 0 is the root.
    pub levels: usize,
    /// Box rectangles per level: `rects[l]` has `4^l` entries.
    pub rects: Vec<Vec<Rect>>,
    /// Particles permuted to leaf order (leaf `b` owns
    /// `starts[b]..starts[b+1]`).
    pub particles: Vec<Particle>,
    /// Leaf slice offsets, length `4^L + 1`.
    pub starts: Vec<usize>,
    /// Statistics of the partitioning phase (fed to the GPU cost model).
    pub sort_stats: SortStats,
}

impl Pyramid {
    /// Build the pyramid over `points`/`gammas` with `levels ≥ 1`
    /// refinements. Points may lie anywhere; the root box is their bounding
    /// box (the paper rejects samples into the unit square before calling —
    /// see [`crate::workload`]).
    pub fn build(points: &[C64], gammas: &[C64], levels: usize) -> Self {
        Self::build_with(points, gammas, levels, PartitionEngine::Cpu)
    }

    /// [`Pyramid::build`] with an explicit partitioning engine.
    pub fn build_with(
        points: &[C64],
        gammas: &[C64],
        levels: usize,
        engine: PartitionEngine,
    ) -> Self {
        assert_eq!(points.len(), gammas.len());
        assert!(levels >= 1, "pyramid needs at least one refinement level");
        assert!(
            points.len() >= boxes_at_level(levels),
            "fewer particles ({}) than leaf boxes ({}); lower the level count",
            points.len(),
            boxes_at_level(levels)
        );
        let mut particles: Vec<Particle> = points
            .iter()
            .zip(gammas)
            .enumerate()
            .map(|(i, (&pos, &gamma))| Particle {
                pos,
                gamma,
                orig: i as u32,
            })
            .collect();

        let root = Rect::bounding(points);
        let mut rects: Vec<Vec<Rect>> = vec![vec![root]];
        let mut stats = SortStats::default();

        // ranges of the current level's boxes into `particles`
        let mut starts: Vec<usize> = vec![0, particles.len()];
        for l in 0..levels {
            let nb = boxes_at_level(l);
            let mut next_rects = Vec::with_capacity(nb * 4);
            let mut next_starts = Vec::with_capacity(nb * 4 + 1);
            next_starts.push(0);
            for b in 0..nb {
                let (lo, hi) = (starts[b], starts[b + 1]);
                let rect = rects[l][b];
                let quads = split_box_in_four(&mut particles[lo..hi], rect, engine, &mut stats);
                for (qrect, qlen) in quads {
                    next_rects.push(qrect);
                    next_starts.push(next_starts.last().unwrap() + qlen);
                }
            }
            debug_assert_eq!(*next_starts.last().unwrap(), particles.len());
            rects.push(next_rects);
            starts = next_starts;
        }

        Pyramid {
            levels,
            rects,
            particles,
            starts,
            sort_stats: stats,
        }
    }

    /// Number of leaf boxes `4^L`.
    #[inline]
    pub fn n_leaves(&self) -> usize {
        boxes_at_level(self.levels)
    }

    /// Particles of leaf box `b`.
    #[inline]
    pub fn leaf(&self, b: usize) -> &[Particle] {
        &self.particles[self.starts[b]..self.starts[b + 1]]
    }

    /// Largest leaf population (the `nmax` of the static packing).
    pub fn max_leaf_len(&self) -> usize {
        (0..self.n_leaves())
            .map(|b| self.starts[b + 1] - self.starts[b])
            .max()
            .unwrap_or(0)
    }

    /// Centers of the boxes at level `l`.
    pub fn centers(&self, l: usize) -> Vec<C64> {
        self.rects[l].iter().map(|r| r.center()).collect()
    }

    /// Scatter a leaf-ordered per-particle vector back to original order.
    pub fn unpermute(&self, leaf_ordered: &[C64]) -> Vec<C64> {
        debug_assert_eq!(leaf_ordered.len(), self.particles.len());
        let mut out = vec![C64::new(0.0, 0.0); leaf_ordered.len()];
        for (p, &v) in self.particles.iter().zip(leaf_ordered) {
            out[p.orig as usize] = v;
        }
        out
    }
}

/// Split one box's particles into four quadrant boxes: one median split
/// along the box's major axis, then one median split of each half along the
/// half's own major axis ("all boxes are split twice in succession", §2).
/// Returns the four (rect, count) pairs in order.
fn split_box_in_four(
    part: &mut [Particle],
    rect: Rect,
    engine: PartitionEngine,
    stats: &mut SortStats,
) -> [(Rect, usize); 4] {
    let split = match engine {
        PartitionEngine::Cpu => median_split,
        PartitionEngine::GpuModel => median_split_gpu_model,
    };
    let axis0 = rect.split_axis();
    let (cut0, mid) = split(part, axis0, stats);
    let (ra, rb) = rect.split_at(axis0, cut0);

    let (pa, pb) = part.split_at_mut(mid);
    let axis_a = ra.split_axis();
    let (cut_a, mid_a) = split(pa, axis_a, stats);
    let (ra0, ra1) = ra.split_at(axis_a, cut_a);

    let axis_b = rb.split_axis();
    let (cut_b, mid_b) = split(pb, axis_b, stats);
    let (rb0, rb1) = rb.split_at(axis_b, cut_b);

    [
        (ra0, mid_a),
        (ra1, pa.len() - mid_a),
        (rb0, mid_b),
        (rb1, pb.len() - mid_b),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn uniform(n: usize, seed: u64) -> (Vec<C64>, Vec<C64>) {
        let mut r = Pcg64::seed_from_u64(seed);
        workload::uniform_square(n, &mut r)
    }

    #[test]
    fn pyramid_shape() {
        let (pts, gs) = uniform(1000, 1);
        let t = Pyramid::build(&pts, &gs, 3);
        assert_eq!(t.n_leaves(), 64);
        assert_eq!(t.rects[0].len(), 1);
        assert_eq!(t.rects[1].len(), 4);
        assert_eq!(t.rects[3].len(), 64);
        assert_eq!(t.starts.len(), 65);
        assert_eq!(t.starts[64], 1000);
    }

    #[test]
    fn leaves_are_balanced() {
        // median splits: every leaf within ±1 of every other after each
        // halving => leaf sizes in {floor, ceil} of repeated halving.
        let (pts, gs) = uniform(1003, 2);
        let t = Pyramid::build(&pts, &gs, 3);
        let sizes: Vec<usize> = (0..64).map(|b| t.leaf(b).len()).collect();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(hi - lo <= 2, "sizes spread too wide: lo={lo} hi={hi}");
        assert_eq!(sizes.iter().sum::<usize>(), 1003);
    }

    #[test]
    fn particles_inside_their_leaf_rect() {
        let (pts, gs) = uniform(2000, 3);
        let t = Pyramid::build(&pts, &gs, 3);
        for b in 0..t.n_leaves() {
            let r = t.rects[3][b];
            for p in t.leaf(b) {
                assert!(
                    r.contains(p.pos),
                    "particle {:?} outside leaf rect {r:?}",
                    p.pos
                );
            }
        }
    }

    #[test]
    fn permutation_is_bijective() {
        let (pts, gs) = uniform(777, 4);
        let t = Pyramid::build(&pts, &gs, 2);
        let mut seen = vec![false; 777];
        for p in &t.particles {
            assert!(!seen[p.orig as usize], "duplicate orig index");
            seen[p.orig as usize] = true;
            // and the payload moved with the index
            assert_eq!(p.pos, pts[p.orig as usize]);
            assert_eq!(p.gamma, gs[p.orig as usize]);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unpermute_roundtrip() {
        let (pts, gs) = uniform(512, 5);
        let t = Pyramid::build(&pts, &gs, 2);
        let leaf_vals: Vec<C64> = t.particles.iter().map(|p| p.pos).collect();
        let back = t.unpermute(&leaf_vals);
        assert_eq!(back, pts);
    }

    #[test]
    fn child_rects_tile_parent() {
        let (pts, gs) = uniform(4096, 6);
        let t = Pyramid::build(&pts, &gs, 3);
        for l in 0..3 {
            for b in 0..boxes_at_level(l) {
                let parent = t.rects[l][b];
                let kids = &t.rects[l + 1][4 * b..4 * b + 4];
                let area: f64 = kids
                    .iter()
                    .map(|k| k.width() * k.height())
                    .sum();
                let parea = parent.width() * parent.height();
                assert!(
                    (area - parea).abs() < 1e-12 * parea.max(1e-300),
                    "level {l} box {b}"
                );
                for k in kids {
                    assert!(k.x0 >= parent.x0 - 1e-15 && k.x1 <= parent.x1 + 1e-15);
                    assert!(k.y0 >= parent.y0 - 1e-15 && k.y1 <= parent.y1 + 1e-15);
                }
            }
        }
    }

    #[test]
    fn index_arithmetic() {
        assert_eq!(boxes_at_level(0), 1);
        assert_eq!(boxes_at_level(4), 256);
        assert_eq!(parent_of(7), 1);
        assert_eq!(first_child_of(3), 12);
        for b in 0..64 {
            assert_eq!(parent_of(first_child_of(b)), b);
        }
    }

    #[test]
    fn nonuniform_normal_distribution_builds() {
        let mut r = Pcg64::seed_from_u64(7);
        let (pts, gs) = workload::normal_cloud(3000, 0.1, &mut r);
        let t = Pyramid::build(&pts, &gs, 4);
        assert_eq!(t.starts[t.n_leaves()], 3000);
        let sizes: Vec<usize> = (0..t.n_leaves()).map(|b| t.leaf(b).len()).collect();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        // adaptivity: populations stay balanced even for clustered input
        assert!(hi - lo <= 3, "lo={lo} hi={hi}");
    }

    #[test]
    #[should_panic(expected = "fewer particles")]
    fn too_few_particles_panics() {
        let (pts, gs) = uniform(10, 8);
        Pyramid::build(&pts, &gs, 3);
    }
}
