//! The asymmetric-adaptive pyramid (paper §2 and §3.2).
//!
//! Boxes are split *twice in succession* close to the median of the particle
//! positions, so level `l` always holds exactly `4^l` boxes with (near)
//! equal population — a balanced *pyramid* rather than a general tree. The
//! split direction follows the eccentricity of the box (the θ-criterion is
//! rotationally invariant, so square-ish boxes minimize interactions).
//!
//! The output arranges particles so that every leaf box owns a contiguous
//! slice — the static memory layout that both the serial driver and the
//! data-parallel packing rely on.
//!
//! The build itself runs serially ([`Pyramid::build`] /
//! [`Pyramid::build_with`]) or sharded over worker threads — scoped
//! spawns ([`Pyramid::build_threaded`]) or the persistent pool
//! ([`Pyramid::build_on_pool`]): within a level every box owns a disjoint
//! `particles[lo..hi]` slice, so the per-box `split_box_in_four` calls
//! fan out with the same writer-side-ownership discipline as
//! [`crate::fmm::parallel`], and per-thread [`SortStats`] merge in worker
//! order. All paths produce bit-identical pyramids
//! (`tests/topology_parity.rs`); [`crate::topology`] selects between them.

pub mod partition;

use crate::complex::C64;
use crate::geometry::Rect;
use crate::util::error::Result;
use crate::util::pool::WorkerPool;
use crate::util::threadpool::{ranges, scoped_map, split_lengths_mut};
use partition::{median_split, median_split_gpu_model, SortStats};

/// Which partitioning engine builds the pyramid: the serial quickselect
/// (paper §4.1) or the functional model of the CUDA scheme (Algorithms
/// 3.1/3.2) whose [`SortStats`] feed the GPU cost simulator. Both produce
/// identical median splits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionEngine {
    #[default]
    Cpu,
    GpuModel,
}

/// Largest refinement depth a pyramid will accept: `4^16` leaf boxes is
/// already far past any point count this code targets, and bounding the
/// depth here keeps the `4^l` index arithmetic away from shift overflow
/// when a hostile `levels` arrives from an API boundary.
pub const MAX_LEVELS: usize = 16;

/// Index arithmetic of the pyramid: boxes of level `l` are numbered
/// `0..4^l`; the children of box `b` are `4b..4b+4` at the next level.
#[inline]
pub fn boxes_at_level(l: usize) -> usize {
    1usize << (2 * l)
}

/// Parent of box `b` (at level `l ≥ 1`).
#[inline]
pub fn parent_of(b: usize) -> usize {
    b >> 2
}

/// First child of box `b`.
#[inline]
pub fn first_child_of(b: usize) -> usize {
    b << 2
}

/// One particle record carried through the partitioning permutation.
#[derive(Clone, Copy, Debug)]
pub struct Particle {
    pub pos: C64,
    pub gamma: C64,
    /// Index into the caller's original arrays.
    pub orig: u32,
}

/// The fully built pyramid.
#[derive(Clone, Debug)]
pub struct Pyramid {
    /// Number of refinement levels `L` (leaf level). Level 0 is the root.
    pub levels: usize,
    /// Box rectangles per level: `rects[l]` has `4^l` entries.
    pub rects: Vec<Vec<Rect>>,
    /// Particles permuted to leaf order (leaf `b` owns
    /// `starts[b]..starts[b+1]`).
    pub particles: Vec<Particle>,
    /// Leaf slice offsets, length `4^L + 1`.
    pub starts: Vec<usize>,
    /// Statistics of the partitioning phase (fed to the GPU cost model).
    pub sort_stats: SortStats,
}

impl Pyramid {
    /// Build the pyramid over `points`/`gammas` with `levels ≥ 1`
    /// refinements. Points may lie anywhere; the root box is their bounding
    /// box (the paper rejects samples into the unit square before calling —
    /// see [`crate::workload`]).
    ///
    /// Errors (instead of panicking) when the inputs cannot form a pyramid:
    /// mismatched array lengths, `levels == 0` or `levels > `
    /// [`MAX_LEVELS`], fewer particles than leaf boxes, or any non-finite
    /// coordinate/strength (which would otherwise NaN-poison the answer).
    pub fn build(points: &[C64], gammas: &[C64], levels: usize) -> Result<Self> {
        Self::build_with(points, gammas, levels, PartitionEngine::Cpu)
    }

    /// [`Pyramid::build`] with an explicit partitioning engine.
    pub fn build_with(
        points: &[C64],
        gammas: &[C64],
        levels: usize,
        engine: PartitionEngine,
    ) -> Result<Self> {
        let _sp = crate::obs::span("topo", "pyramid");
        let (mut particles, root) = Self::validated_particles(points, gammas, levels)?;
        let mut rects: Vec<Vec<Rect>> = vec![vec![root]];
        let mut stats = SortStats::default();

        // ranges of the current level's boxes into `particles`
        let mut starts: Vec<usize> = vec![0, particles.len()];
        for l in 0..levels {
            let nb = boxes_at_level(l);
            let mut next_rects = Vec::with_capacity(nb * 4);
            let mut next_starts = Vec::with_capacity(nb * 4 + 1);
            next_starts.push(0);
            for b in 0..nb {
                let (lo, hi) = (starts[b], starts[b + 1]);
                let rect = rects[l][b];
                let quads = split_box_in_four(&mut particles[lo..hi], rect, engine, &mut stats);
                for (qrect, qlen) in quads {
                    next_rects.push(qrect);
                    next_starts.push(next_starts.last().unwrap() + qlen);
                }
            }
            debug_assert_eq!(*next_starts.last().unwrap(), particles.len());
            rects.push(next_rects);
            starts = next_starts;
        }

        Ok(Pyramid {
            levels,
            rects,
            particles,
            starts,
            sort_stats: stats,
        })
    }

    /// [`Pyramid::build_with`] sharded over `threads` scoped workers.
    ///
    /// Per level, the boxes are split into contiguous ranges and each
    /// worker owns the disjoint particle slice of its boxes (the same
    /// writer-side ownership as [`crate::fmm::parallel`] — no locks). The
    /// per-box splits are independent and deterministic, and per-thread
    /// [`SortStats`] merge in worker order, so the result is bit-identical
    /// to the serial build for every thread count
    /// (`tests/topology_parity.rs`). `threads ≤ 1` falls back to the
    /// serial path.
    pub fn build_threaded(
        points: &[C64],
        gammas: &[C64],
        levels: usize,
        engine: PartitionEngine,
        threads: usize,
    ) -> Result<Self> {
        Self::build_parallel(points, gammas, levels, engine, threads, None)
    }

    /// [`Pyramid::build_threaded`] executing its per-level fan-outs on a
    /// persistent [`WorkerPool`] instead of scoped spawns — bit-identical
    /// output, zero thread spawns.
    pub fn build_on_pool(
        points: &[C64],
        gammas: &[C64],
        levels: usize,
        engine: PartitionEngine,
        threads: usize,
        pool: &WorkerPool,
    ) -> Result<Self> {
        Self::build_parallel(
            points,
            gammas,
            levels,
            engine,
            threads.min(pool.n_workers()),
            Some(pool),
        )
    }

    fn build_parallel(
        points: &[C64],
        gammas: &[C64],
        levels: usize,
        engine: PartitionEngine,
        threads: usize,
        pool: Option<&WorkerPool>,
    ) -> Result<Self> {
        if threads <= 1 {
            return Self::build_with(points, gammas, levels, engine);
        }
        // oversized requests (thread counts are caller input) clamp to the
        // machine: more workers than cores only adds spawn/join overhead
        let threads = threads.min(crate::util::threadpool::available_threads().max(1));
        if threads <= 1 {
            return Self::build_with(points, gammas, levels, engine);
        }
        let _sp = crate::obs::span("topo", "pyramid").arg("threads", threads as f64);
        let (mut particles, root) = Self::validated_particles(points, gammas, levels)?;
        let mut rects: Vec<Vec<Rect>> = vec![vec![root]];
        let mut stats = SortStats::default();

        let mut starts: Vec<usize> = vec![0, particles.len()];
        for l in 0..levels {
            let nb = boxes_at_level(l);
            let workers = threads.min(nb);
            let level_rects: &[Rect] = &rects[l];
            let starts_ref: &[usize] = &starts;
            let parts: Vec<(Vec<(Rect, usize)>, SortStats)> = if workers > 1 {
                let rs = ranges(nb, workers);
                let lens: Vec<usize> = rs
                    .iter()
                    .map(|r| starts_ref[r.end] - starts_ref[r.start])
                    .collect();
                let chunks = split_lengths_mut(&mut particles, &lens);
                let items: Vec<_> = rs.into_iter().zip(chunks).collect();
                match pool {
                    Some(p) => p.map_items(items, |(r, chunk)| {
                        split_box_range(r, chunk, starts_ref, level_rects, engine)
                    }),
                    None => scoped_map(items, |(r, chunk)| {
                        split_box_range(r, chunk, starts_ref, level_rects, engine)
                    }),
                }
            } else {
                vec![split_box_range(
                    0..nb,
                    &mut particles,
                    starts_ref,
                    level_rects,
                    engine,
                )]
            };

            let mut next_rects = Vec::with_capacity(nb * 4);
            let mut next_starts = Vec::with_capacity(nb * 4 + 1);
            next_starts.push(0usize);
            for (quads, st) in parts {
                for (qrect, qlen) in quads {
                    next_rects.push(qrect);
                    next_starts.push(next_starts.last().unwrap() + qlen);
                }
                stats.merge(&st);
            }
            debug_assert_eq!(*next_starts.last().unwrap(), particles.len());
            rects.push(next_rects);
            starts = next_starts;
        }

        Ok(Pyramid {
            levels,
            rects,
            particles,
            starts,
            sort_stats: stats,
        })
    }

    /// Shared input validation of the build entry points: returns the
    /// permutation-carrying particle records and the root bounding box.
    fn validated_particles(
        points: &[C64],
        gammas: &[C64],
        levels: usize,
    ) -> Result<(Vec<Particle>, Rect)> {
        crate::ensure!(
            points.len() == gammas.len(),
            "points ({}) and strengths ({}) differ in length",
            points.len(),
            gammas.len()
        );
        crate::ensure!(levels >= 1, "pyramid needs at least one refinement level");
        crate::ensure!(
            levels <= MAX_LEVELS,
            "levels ({levels}) exceeds the supported maximum ({MAX_LEVELS})"
        );
        crate::ensure!(
            points.len() >= boxes_at_level(levels),
            "fewer particles ({}) than leaf boxes ({}); lower the level count",
            points.len(),
            boxes_at_level(levels)
        );
        // A single non-finite coordinate poisons `Rect::bounding` (NaN box
        // extents) and from there every potential in the answer; a
        // non-finite strength poisons silently. Reject both up front so no
        // engine ever returns NaN-poisoned potentials for bad input.
        if let Some(i) = points.iter().position(|q| !q.re.is_finite() || !q.im.is_finite()) {
            crate::bail!(
                "non-finite coordinate at index {i}: ({}, {})",
                points[i].re,
                points[i].im
            );
        }
        if let Some(i) = gammas.iter().position(|g| !g.re.is_finite() || !g.im.is_finite()) {
            crate::bail!(
                "non-finite strength at index {i}: ({}, {})",
                gammas[i].re,
                gammas[i].im
            );
        }
        let particles = points
            .iter()
            .zip(gammas)
            .enumerate()
            .map(|(i, (&pos, &gamma))| Particle {
                pos,
                gamma,
                orig: i as u32,
            })
            .collect();
        Ok((particles, Rect::bounding(points)))
    }

    /// Number of leaf boxes `4^L`.
    #[inline]
    pub fn n_leaves(&self) -> usize {
        boxes_at_level(self.levels)
    }

    /// Particles of leaf box `b`.
    #[inline]
    pub fn leaf(&self, b: usize) -> &[Particle] {
        &self.particles[self.starts[b]..self.starts[b + 1]]
    }

    /// Largest leaf population (the `nmax` of the static packing).
    pub fn max_leaf_len(&self) -> usize {
        (0..self.n_leaves())
            .map(|b| self.starts[b + 1] - self.starts[b])
            .max()
            .unwrap_or(0)
    }

    /// Centers of the boxes at level `l`.
    pub fn centers(&self, l: usize) -> Vec<C64> {
        self.rects[l].iter().map(|r| r.center()).collect()
    }

    /// Scatter a leaf-ordered per-particle vector back to original order.
    pub fn unpermute(&self, leaf_ordered: &[C64]) -> Vec<C64> {
        debug_assert_eq!(leaf_ordered.len(), self.particles.len());
        let mut out = vec![C64::new(0.0, 0.0); leaf_ordered.len()];
        for (p, &v) in self.particles.iter().zip(leaf_ordered) {
            out[p.orig as usize] = v;
        }
        out
    }

    /// Structural validation of a fully built pyramid (DESIGN.md §8):
    ///
    /// * shape — `rects[l]` has `4^l` entries for every `l ≤ L`, and
    ///   `starts` is a well-formed exclusive scan over the leaves
    ///   (`starts[0] == 0`, monotone, `starts[4^L] == n`);
    /// * geometry — every box rectangle is finite and non-degenerate, and
    ///   each child rectangle lies inside its parent (the median splits
    ///   tile, they never leak);
    /// * containment — every particle of leaf `b` lies inside
    ///   `rects[L][b]` (closed intervals: a particle on a shared split
    ///   boundary belongs to both sides' closures);
    /// * permutation — the `orig` indices are a bijection onto `0..n`, so
    ///   [`Pyramid::unpermute`] is lossless.
    ///
    /// O(N + boxes) — cheap enough for the parity suites, which run it on
    /// every debug-mode [`crate::topology::build`]; release callers reach
    /// it through `--check`.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(self.levels >= 1, "pyramid must have at least one level");
        crate::ensure!(
            self.rects.len() == self.levels + 1,
            "rects has {} levels, expected {}",
            self.rects.len(),
            self.levels + 1
        );
        for (l, rl) in self.rects.iter().enumerate() {
            crate::ensure!(
                rl.len() == boxes_at_level(l),
                "level {l} has {} rects, expected {}",
                rl.len(),
                boxes_at_level(l)
            );
            for (b, r) in rl.iter().enumerate() {
                crate::ensure!(
                    r.x0.is_finite() && r.x1.is_finite() && r.y0.is_finite() && r.y1.is_finite(),
                    "box l={l} b={b} has non-finite bounds"
                );
                crate::ensure!(
                    r.x1 >= r.x0 && r.y1 >= r.y0,
                    "box l={l} b={b} is degenerate"
                );
                if l > 0 {
                    let p = &self.rects[l - 1][parent_of(b)];
                    crate::ensure!(
                        r.x0 >= p.x0 && r.x1 <= p.x1 && r.y0 >= p.y0 && r.y1 <= p.y1,
                        "box l={l} b={b} leaks outside its parent"
                    );
                }
            }
        }

        let nl = self.n_leaves();
        let n = self.particles.len();
        crate::ensure!(
            self.starts.len() == nl + 1,
            "starts has {} entries, expected {}",
            self.starts.len(),
            nl + 1
        );
        crate::ensure!(self.starts[0] == 0, "starts[0] must be 0");
        for b in 0..nl {
            crate::ensure!(
                self.starts[b] <= self.starts[b + 1],
                "starts not monotone at leaf {b}"
            );
        }
        crate::ensure!(
            self.starts[nl] == n,
            "starts ends at {}, expected the particle count {n}",
            self.starts[nl]
        );

        for b in 0..nl {
            let r = &self.rects[self.levels][b];
            for (k, p) in self.leaf(b).iter().enumerate() {
                crate::ensure!(
                    r.contains(p.pos),
                    "particle {k} of leaf {b} lies outside its box"
                );
            }
        }

        let mut seen = vec![false; n];
        for p in &self.particles {
            let o = p.orig as usize;
            crate::ensure!(o < n, "orig index {o} out of range 0..{n}");
            crate::ensure!(!seen[o], "orig index {o} appears twice");
            seen[o] = true;
        }
        Ok(())
    }
}

/// Split one box's particles into four quadrant boxes: one median split
/// along the box's major axis, then one median split of each half along the
/// half's own major axis ("all boxes are split twice in succession", §2).
/// Returns the four (rect, count) pairs in order.
fn split_box_in_four(
    part: &mut [Particle],
    rect: Rect,
    engine: PartitionEngine,
    stats: &mut SortStats,
) -> [(Rect, usize); 4] {
    let split = match engine {
        PartitionEngine::Cpu => median_split,
        PartitionEngine::GpuModel => median_split_gpu_model,
    };
    let axis0 = rect.split_axis();
    let (cut0, mid) = split(part, axis0, stats);
    let (ra, rb) = rect.split_at(axis0, cut0);

    let (pa, pb) = part.split_at_mut(mid);
    let axis_a = ra.split_axis();
    let (cut_a, mid_a) = split(pa, axis_a, stats);
    let (ra0, ra1) = ra.split_at(axis_a, cut_a);

    let axis_b = rb.split_axis();
    let (cut_b, mid_b) = split(pb, axis_b, stats);
    let (rb0, rb1) = rb.split_at(axis_b, cut_b);

    [
        (ra0, mid_a),
        (ra1, pa.len() - mid_a),
        (rb0, mid_b),
        (rb1, pb.len() - mid_b),
    ]
}

/// Split every box of `r` (whose particles tile `chunk` contiguously) in
/// four, returning the child `(rect, count)` quads in box order plus this
/// worker's partitioning statistics — the per-thread unit of the parallel
/// build.
fn split_box_range(
    r: std::ops::Range<usize>,
    chunk: &mut [Particle],
    starts: &[usize],
    rects: &[Rect],
    engine: PartitionEngine,
) -> (Vec<(Rect, usize)>, SortStats) {
    let lens: Vec<usize> = (r.start..r.end).map(|b| starts[b + 1] - starts[b]).collect();
    let mut stats = SortStats::default();
    let mut quads = Vec::with_capacity(lens.len() * 4);
    for (sub, b) in split_lengths_mut(chunk, &lens).into_iter().zip(r) {
        quads.extend_from_slice(&split_box_in_four(sub, rects[b], engine, &mut stats));
    }
    (quads, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn uniform(n: usize, seed: u64) -> (Vec<C64>, Vec<C64>) {
        let mut r = Pcg64::seed_from_u64(seed);
        workload::uniform_square(n, &mut r)
    }

    #[test]
    fn non_finite_inputs_are_rejected_not_poisoned() {
        let (mut pts, gs) = uniform(1000, 11);
        pts[500] = C64::new(f64::NAN, 0.25);
        let err = format!("{:#}", Pyramid::build(&pts, &gs, 3).unwrap_err());
        assert!(err.contains("non-finite coordinate at index 500"), "{err}");
        let (pts, mut gs) = uniform(1000, 12);
        gs[7] = C64::new(0.1, f64::INFINITY);
        let err = format!("{:#}", Pyramid::build(&pts, &gs, 3).unwrap_err());
        assert!(err.contains("non-finite strength at index 7"), "{err}");
    }

    #[test]
    fn absurd_level_counts_are_rejected() {
        let (pts, gs) = uniform(64, 13);
        assert!(Pyramid::build(&pts, &gs, MAX_LEVELS + 1).is_err());
        assert!(Pyramid::build(&pts, &gs, usize::MAX / 2).is_err());
    }

    #[test]
    fn pyramid_shape() {
        let (pts, gs) = uniform(1000, 1);
        let t = Pyramid::build(&pts, &gs, 3).unwrap();
        assert_eq!(t.n_leaves(), 64);
        assert_eq!(t.rects[0].len(), 1);
        assert_eq!(t.rects[1].len(), 4);
        assert_eq!(t.rects[3].len(), 64);
        assert_eq!(t.starts.len(), 65);
        assert_eq!(t.starts[64], 1000);
    }

    #[test]
    fn leaves_are_balanced() {
        // median splits: every leaf within ±1 of every other after each
        // halving => leaf sizes in {floor, ceil} of repeated halving.
        let (pts, gs) = uniform(1003, 2);
        let t = Pyramid::build(&pts, &gs, 3).unwrap();
        let sizes: Vec<usize> = (0..64).map(|b| t.leaf(b).len()).collect();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(hi - lo <= 2, "sizes spread too wide: lo={lo} hi={hi}");
        assert_eq!(sizes.iter().sum::<usize>(), 1003);
    }

    #[test]
    fn particles_inside_their_leaf_rect() {
        let (pts, gs) = uniform(2000, 3);
        let t = Pyramid::build(&pts, &gs, 3).unwrap();
        for b in 0..t.n_leaves() {
            let r = t.rects[3][b];
            for p in t.leaf(b) {
                assert!(
                    r.contains(p.pos),
                    "particle {:?} outside leaf rect {r:?}",
                    p.pos
                );
            }
        }
    }

    #[test]
    fn permutation_is_bijective() {
        let (pts, gs) = uniform(777, 4);
        let t = Pyramid::build(&pts, &gs, 2).unwrap();
        let mut seen = vec![false; 777];
        for p in &t.particles {
            assert!(!seen[p.orig as usize], "duplicate orig index");
            seen[p.orig as usize] = true;
            // and the payload moved with the index
            assert_eq!(p.pos, pts[p.orig as usize]);
            assert_eq!(p.gamma, gs[p.orig as usize]);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unpermute_roundtrip() {
        let (pts, gs) = uniform(512, 5);
        let t = Pyramid::build(&pts, &gs, 2).unwrap();
        let leaf_vals: Vec<C64> = t.particles.iter().map(|p| p.pos).collect();
        let back = t.unpermute(&leaf_vals);
        assert_eq!(back, pts);
    }

    #[test]
    fn child_rects_tile_parent() {
        let (pts, gs) = uniform(4096, 6);
        let t = Pyramid::build(&pts, &gs, 3).unwrap();
        for l in 0..3 {
            for b in 0..boxes_at_level(l) {
                let parent = t.rects[l][b];
                let kids = &t.rects[l + 1][4 * b..4 * b + 4];
                let area: f64 = kids
                    .iter()
                    .map(|k| k.width() * k.height())
                    .sum();
                let parea = parent.width() * parent.height();
                assert!(
                    (area - parea).abs() < 1e-12 * parea.max(1e-300),
                    "level {l} box {b}"
                );
                for k in kids {
                    assert!(k.x0 >= parent.x0 - 1e-15 && k.x1 <= parent.x1 + 1e-15);
                    assert!(k.y0 >= parent.y0 - 1e-15 && k.y1 <= parent.y1 + 1e-15);
                }
            }
        }
    }

    #[test]
    fn index_arithmetic() {
        assert_eq!(boxes_at_level(0), 1);
        assert_eq!(boxes_at_level(4), 256);
        assert_eq!(parent_of(7), 1);
        assert_eq!(first_child_of(3), 12);
        for b in 0..64 {
            assert_eq!(parent_of(first_child_of(b)), b);
        }
    }

    #[test]
    fn nonuniform_normal_distribution_builds() {
        let mut r = Pcg64::seed_from_u64(7);
        let (pts, gs) = workload::normal_cloud(3000, 0.1, &mut r);
        let t = Pyramid::build(&pts, &gs, 4).unwrap();
        assert_eq!(t.starts[t.n_leaves()], 3000);
        let sizes: Vec<usize> = (0..t.n_leaves()).map(|b| t.leaf(b).len()).collect();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        // adaptivity: populations stay balanced even for clustered input
        assert!(hi - lo <= 3, "lo={lo} hi={hi}");
    }

    #[test]
    fn invalid_inputs_error_instead_of_panicking() {
        let (pts, gs) = uniform(10, 8);
        let err = Pyramid::build(&pts, &gs, 3).unwrap_err().to_string();
        assert!(err.contains("fewer particles"), "got: {err}");
        let err = Pyramid::build(&pts, &gs, 0).unwrap_err().to_string();
        assert!(err.contains("refinement level"), "got: {err}");
        let err = Pyramid::build(&pts, &gs[..9], 1).unwrap_err().to_string();
        assert!(err.contains("differ in length"), "got: {err}");
    }

    #[test]
    fn threaded_build_is_bit_identical_to_serial() {
        let mut r = Pcg64::seed_from_u64(12);
        let (pts, gs) = workload::normal_cloud(2000, 0.08, &mut r);
        for engine in [PartitionEngine::Cpu, PartitionEngine::GpuModel] {
            let serial = Pyramid::build_with(&pts, &gs, 3, engine).unwrap();
            for nt in [2usize, 3, 8, 999] {
                let par = Pyramid::build_threaded(&pts, &gs, 3, engine, nt).unwrap();
                assert_eq!(serial.starts, par.starts, "{engine:?} t={nt}");
                for (a, b) in serial.particles.iter().zip(&par.particles) {
                    assert_eq!(a.orig, b.orig, "{engine:?} t={nt}");
                    assert_eq!(a.pos, b.pos);
                }
                for l in 0..=3 {
                    for (ra, rb) in serial.rects[l].iter().zip(&par.rects[l]) {
                        assert_eq!(ra.x0, rb.x0);
                        assert_eq!(ra.x1, rb.x1);
                        assert_eq!(ra.y0, rb.y0);
                        assert_eq!(ra.y1, rb.y1);
                    }
                }
                assert_eq!(serial.sort_stats.splits, par.sort_stats.splits);
                assert_eq!(
                    serial.sort_stats.elements_visited,
                    par.sort_stats.elements_visited
                );
                assert_eq!(serial.sort_stats.passes, par.sort_stats.passes);
                assert_eq!(serial.sort_stats.scattered, par.sort_stats.scattered);
            }
        }
    }

    #[test]
    fn pool_build_is_bit_identical_to_serial() {
        let mut r = Pcg64::seed_from_u64(13);
        let (pts, gs) = workload::normal_cloud(1500, 0.1, &mut r);
        let pool = crate::util::pool::WorkerPool::new(3, false);
        let serial = Pyramid::build(&pts, &gs, 3).unwrap();
        let pooled =
            Pyramid::build_on_pool(&pts, &gs, 3, PartitionEngine::Cpu, 3, &pool).unwrap();
        assert_eq!(serial.starts, pooled.starts);
        for (a, b) in serial.particles.iter().zip(&pooled.particles) {
            assert_eq!(a.orig, b.orig);
            assert_eq!(a.pos, b.pos);
        }
        assert_eq!(serial.sort_stats.splits, pooled.sort_stats.splits);
    }
}
