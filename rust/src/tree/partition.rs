//! Median partitioning — the "sorting" half of the topological phase
//! (paper §3.2, Algorithms 3.1/3.2, and the CPU variant of §4.1).
//!
//! Two interchangeable engines produce identical splits (same median
//! position; both place the lower half left of the upper half):
//!
//! * [`median_split`] — the serial engine: quickselect with
//!   *median-of-three* pivoting, in place, as the paper's CPU code does;
//! * [`median_split_gpu_model`] — a faithful *functional model* of the GPU
//!   engine of Algorithms 3.1/3.2: pivot chosen by sorting a 32-element
//!   sample and interpolating toward the global median, two-pass
//!   count-then-scatter splits (temporary buffer, like the CUDA code), loop
//!   until ≤ 32 elements remain, then a final small sort. It records the
//!   pass/element counters the GPU cost simulator consumes. (The real CUDA
//!   kernel is non-deterministic across blocks; the model is sequential and
//!   deterministic, which the paper itself needs for its comparisons —
//!   §5: "the sorting was performed on the CPU to ensure identical trees".)

use super::Particle;
use crate::geometry::Axis;

/// Work counters of the partitioning phase, consumed by `gpusim`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SortStats {
    /// Number of `median_split` invocations (boxes × 3 per level — one
    /// parent split + two half splits).
    pub splits: usize,
    /// Total elements inspected across all partition passes.
    pub elements_visited: usize,
    /// Total partition passes (quickselect rounds / GPU split kernels).
    pub passes: usize,
    /// Elements moved through the two-pass scatter (GPU model only).
    pub scattered: usize,
}

impl SortStats {
    /// Fold another accumulator into this one. Every field is a plain sum,
    /// so merging per-thread partials in worker order reproduces the serial
    /// totals exactly — the property the parallel pyramid build
    /// ([`crate::tree::Pyramid::build_threaded`]) relies on.
    pub fn merge(&mut self, other: &SortStats) {
        self.splits += other.splits;
        self.elements_visited += other.elements_visited;
        self.passes += other.passes;
        self.scattered += other.scattered;
    }
}

#[inline]
fn coord(p: &Particle, axis: Axis) -> f64 {
    match axis {
        Axis::X => p.pos.re,
        Axis::Y => p.pos.im,
    }
}

/// Partition `part` around its median coordinate along `axis`.
///
/// On return, `part[..mid]` all have coordinate ≤ every element of
/// `part[mid..]` (with `mid = len/2`), and the returned cut coordinate
/// separates the two groups geometrically (midway between the bounding
/// coordinates of the halves). Returns `(cut, mid)`.
///
/// Degenerate inputs (empty/single-element) return a trivial split.
pub fn median_split(part: &mut [Particle], axis: Axis, stats: &mut SortStats) -> (f64, usize) {
    stats.splits += 1;
    let n = part.len();
    if n <= 1 {
        let c = part.first().map(|p| coord(p, axis)).unwrap_or(0.0);
        return (c, n / 2);
    }
    let mid = n / 2;
    quickselect(part, mid, axis, stats);
    let cut = cut_between(part, mid, axis);
    (cut, mid)
}

/// Geometric cut coordinate: midway between the max of the lower half and
/// the min of the upper half (so both child rectangles contain their
/// particles strictly).
fn cut_between(part: &[Particle], mid: usize, axis: Axis) -> f64 {
    let lo_max = part[..mid]
        .iter()
        .map(|p| coord(p, axis))
        .fold(f64::NEG_INFINITY, f64::max);
    let hi_min = part[mid..]
        .iter()
        .map(|p| coord(p, axis))
        .fold(f64::INFINITY, f64::min);
    if lo_max.is_finite() && hi_min.is_finite() {
        0.5 * (lo_max + hi_min)
    } else if hi_min.is_finite() {
        hi_min
    } else {
        lo_max
    }
}

/// In-place quickselect: after the call, `part[k]` is the k-th order
/// statistic along `axis` and the slice is partitioned around it.
/// Median-of-three pivoting as in the paper's CPU code (§4.1, citing
/// Sedgewick). Falls back to insertion-style scan for tiny ranges.
fn quickselect(part: &mut [Particle], k: usize, axis: Axis, stats: &mut SortStats) {
    let (mut lo, mut hi) = (0usize, part.len());
    // invariant: the k-th element lies in part[lo..hi]
    while hi - lo > 8 {
        stats.passes += 1;
        stats.elements_visited += hi - lo;
        let pivot = median_of_three(part, lo, hi, axis);
        // Hoare-style partition around the pivot *value*
        let (mut i, mut j) = (lo, hi - 1);
        loop {
            while coord(&part[i], axis) < pivot {
                i += 1;
            }
            while coord(&part[j], axis) > pivot {
                j -= 1;
            }
            if i >= j {
                break;
            }
            part.swap(i, j);
            i += 1;
            if j == 0 {
                break;
            }
            j -= 1;
        }
        // elements equal to the pivot may straddle; j is the last index of
        // the lower region
        let split = j + 1;
        if k < split {
            hi = split;
        } else if split > lo {
            lo = split;
        } else {
            // no progress (all elements equal / adversarial): scan directly
            break;
        }
    }
    // small range: selection sort the remainder (≤ 8 elements typical)
    stats.elements_visited += (hi - lo) * (hi - lo);
    let sub = &mut part[lo..hi];
    for i in 0..sub.len() {
        let mut min = i;
        for j in i + 1..sub.len() {
            if coord(&sub[j], axis) < coord(&sub[min], axis) {
                min = j;
            }
        }
        sub.swap(i, min);
    }
}

fn median_of_three(part: &[Particle], lo: usize, hi: usize, axis: Axis) -> f64 {
    let a = coord(&part[lo], axis);
    let b = coord(&part[(lo + hi) / 2], axis);
    let c = coord(&part[hi - 1], axis);
    // median of a, b, c
    a.max(b).min(a.max(c)).min(b.max(c))
}

/// Functional model of the GPU partitioning (Algorithms 3.1/3.2).
///
/// Behaviourally: same contract as [`median_split`]. Operationally it
/// mirrors the CUDA scheme — pivot from a sorted 32-sample with
/// rank interpolation, two-pass count+scatter through a temporary buffer,
/// keep the half containing the median, switch to the direct small-array
/// path at ≤ `SINGLE_LIMIT` elements — and tallies `SortStats` accordingly.
pub fn median_split_gpu_model(
    part: &mut [Particle],
    axis: Axis,
    stats: &mut SortStats,
) -> (f64, usize) {
    const SAMPLE: usize = 32;
    stats.splits += 1;
    let n = part.len();
    if n <= 1 {
        let c = part.first().map(|p| coord(p, axis)).unwrap_or(0.0);
        return (c, n / 2);
    }
    let mid = n / 2;

    // the active window [lo, hi) known to contain the median
    let (mut lo, mut hi) = (0usize, n);
    let mut scratch: Vec<Particle> = Vec::with_capacity(n);
    while hi - lo > SAMPLE {
        stats.passes += 1;
        stats.elements_visited += hi - lo;

        // --- determine_pivot_32: sort a strided 32-sample, then pick the
        // sample element whose *relative rank* matches the rank of the
        // median within the active window (line 2 of Algorithm 3.1).
        let len = hi - lo;
        let mut sample: Vec<f64> = (0..SAMPLE)
            .map(|i| coord(&part[lo + i * len / SAMPLE], axis))
            .collect();
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let target_rank = (mid - lo) as f64 / len as f64;
        let idx = ((target_rank * SAMPLE as f64) as usize).min(SAMPLE - 1);
        let pivot = sample[idx];

        // --- split_around_pivot: two-pass count + scatter via scratch
        scratch.clear();
        let mut below = 0usize;
        for p in &part[lo..hi] {
            if coord(p, axis) < pivot {
                below += 1;
            }
        }
        // scatter pass: stable placement below/above the pivot
        scratch.resize(len, part[lo]);
        let (mut bi, mut ai) = (0usize, below);
        for p in &part[lo..hi] {
            if coord(p, axis) < pivot {
                scratch[bi] = *p;
                bi += 1;
            } else {
                scratch[ai] = *p;
                ai += 1;
            }
        }
        part[lo..hi].copy_from_slice(&scratch);
        stats.scattered += len;

        // --- keep_part_containing_median
        let split = lo + below;
        if mid < split {
            hi = split;
        } else if split > lo {
            lo = split;
        } else {
            // pivot was the minimum: shrink by the (empty) lower part is
            // impossible, so fall through to the small path to guarantee
            // progress (matches the CUDA code's bad-pivot handling)
            break;
        }
    }

    // --- determine_median_32 / split_on_single_block: small direct select
    stats.elements_visited += (hi - lo) * (hi - lo);
    let sub = &mut part[lo..hi];
    sub.sort_by(|a, b| coord(a, axis).partial_cmp(&coord(b, axis)).unwrap());

    let cut = cut_between(part, mid, axis);
    (cut, mid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complex::C64;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn mk(vals: &[(f64, f64)]) -> Vec<Particle> {
        vals.iter()
            .enumerate()
            .map(|(i, &(x, y))| Particle {
                pos: C64::new(x, y),
                gamma: C64::new(1.0, 0.0),
                orig: i as u32,
            })
            .collect()
    }

    fn random_parts(r: &mut Pcg64, n: usize) -> Vec<Particle> {
        (0..n)
            .map(|i| Particle {
                pos: C64::new(r.uniform(), r.uniform()),
                gamma: C64::new(1.0, 0.0),
                orig: i as u32,
            })
            .collect()
    }

    fn check_split(part: &[Particle], mid: usize, cut: f64, axis: Axis) {
        let lo_max = part[..mid]
            .iter()
            .map(|p| coord(p, axis))
            .fold(f64::NEG_INFINITY, f64::max);
        let hi_min = part[mid..]
            .iter()
            .map(|p| coord(p, axis))
            .fold(f64::INFINITY, f64::min);
        assert!(
            lo_max <= hi_min,
            "halves overlap: lo_max={lo_max} hi_min={hi_min}"
        );
        assert!(cut >= lo_max && cut <= hi_min, "cut outside gap");
    }

    #[test]
    fn median_split_basic() {
        let mut p = mk(&[(0.9, 0.0), (0.1, 0.0), (0.5, 0.0), (0.3, 0.0), (0.7, 0.0)]);
        let mut st = SortStats::default();
        let (cut, mid) = median_split(&mut p, Axis::X, &mut st);
        assert_eq!(mid, 2);
        check_split(&p, mid, cut, Axis::X);
    }

    #[test]
    fn median_split_property_random() {
        prop::forall(
            prop::Config::default(),
            |r| {
                let n = 2 + r.below(500) as usize;
                random_parts(r, n)
            },
            |parts| {
                for axis in [Axis::X, Axis::Y] {
                    let mut p = parts.clone();
                    let mut st = SortStats::default();
                    let (cut, mid) = median_split(&mut p, axis, &mut st);
                    if mid != p.len() / 2 {
                        return Err(format!("mid {} != {}", mid, p.len() / 2));
                    }
                    let lo_max = p[..mid]
                        .iter()
                        .map(|q| coord(q, axis))
                        .fold(f64::NEG_INFINITY, f64::max);
                    let hi_min = p[mid..]
                        .iter()
                        .map(|q| coord(q, axis))
                        .fold(f64::INFINITY, f64::min);
                    if lo_max > hi_min {
                        return Err(format!("overlap {lo_max} > {hi_min}"));
                    }
                    if !(cut >= lo_max && cut <= hi_min) {
                        return Err("cut outside gap".into());
                    }
                    // permutation check
                    let mut seen: Vec<bool> = vec![false; p.len()];
                    for q in p.iter() {
                        if seen[q.orig as usize] {
                            return Err("duplicated element".into());
                        }
                        seen[q.orig as usize] = true;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gpu_model_agrees_with_cpu_on_median_position() {
        prop::forall(
            prop::Config { cases: 40, ..Default::default() },
            |r| {
                let n = 40 + r.below(3000) as usize;
                random_parts(r, n)
            },
            |parts| {
                let mut a = parts.clone();
                let mut b = parts.clone();
                let mut st = SortStats::default();
                let (_, ma) = median_split(&mut a, Axis::X, &mut st);
                let (_, mb) = median_split_gpu_model(&mut b, Axis::X, &mut st);
                if ma != mb {
                    return Err(format!("mid mismatch {ma} vs {mb}"));
                }
                // the *sets* in each half must agree (order may differ)
                let key = |p: &Particle| (p.pos.re * 1e9) as i64;
                let mut la: Vec<i64> = a[..ma].iter().map(key).collect();
                let mut lb: Vec<i64> = b[..mb].iter().map(key).collect();
                la.sort_unstable();
                lb.sort_unstable();
                if la != lb {
                    return Err("half contents differ".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn duplicates_handled() {
        let mut p = mk(&[(0.5, 0.0); 64]);
        let mut st = SortStats::default();
        let (_, mid) = median_split(&mut p, Axis::X, &mut st);
        assert_eq!(mid, 32);
        let mut q = mk(&[(0.5, 0.0); 64]);
        let (_, mid2) = median_split_gpu_model(&mut q, Axis::X, &mut st);
        assert_eq!(mid2, 32);
    }

    #[test]
    fn tiny_inputs() {
        let mut st = SortStats::default();
        let mut empty: Vec<Particle> = vec![];
        let (_, m0) = median_split(&mut empty, Axis::X, &mut st);
        assert_eq!(m0, 0);
        let mut one = mk(&[(0.3, 0.1)]);
        let (_, m1) = median_split(&mut one, Axis::Y, &mut st);
        assert_eq!(m1, 0);
        let mut two = mk(&[(0.9, 0.0), (0.1, 0.0)]);
        let (cut, m2) = median_split(&mut two, Axis::X, &mut st);
        assert_eq!(m2, 1);
        assert_eq!(two[0].pos.re, 0.1);
        assert!((0.1..=0.9).contains(&cut));
    }

    #[test]
    fn merge_is_fieldwise_sum() {
        let a = SortStats {
            splits: 3,
            elements_visited: 100,
            passes: 7,
            scattered: 40,
        };
        let mut b = SortStats {
            splits: 1,
            elements_visited: 11,
            passes: 2,
            scattered: 5,
        };
        b.merge(&a);
        assert_eq!(b.splits, 4);
        assert_eq!(b.elements_visited, 111);
        assert_eq!(b.passes, 9);
        assert_eq!(b.scattered, 45);
    }

    #[test]
    fn stats_accumulate() {
        let mut r = Pcg64::seed_from_u64(9);
        let mut p = random_parts(&mut r, 10_000);
        let mut st = SortStats::default();
        median_split(&mut p, Axis::X, &mut st);
        assert_eq!(st.splits, 1);
        assert!(st.passes > 0);
        assert!(st.elements_visited >= 10_000);
        let mut q = random_parts(&mut r, 10_000);
        let mut st2 = SortStats::default();
        median_split_gpu_model(&mut q, Axis::X, &mut st2);
        assert!(st2.scattered >= 10_000);
    }
}
