//! `fmm2d` — CLI of the adaptive-FMM reproduction.
//!
//! Subcommands regenerate every table/figure of the paper (§5), validate
//! accuracy, run one-off evaluations through any engine (serial CPU,
//! multithreaded CPU, or the AOT-compiled XLA path behind the `pjrt`
//! feature), and report the GPU-model calibration.

use fmm2d::bail;
use fmm2d::config::FmmConfig;
use fmm2d::dispatch::{
    CalibrationOptions, CalibrationProfile, DispatchReport, Dispatcher, Engine, EngineChoice,
};
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{self, CpuEngine, FmmOptions, PhaseTimes, PHASE_NAMES};
use fmm2d::harness::{self, HarnessOpts};
use fmm2d::util::cli::Args;
use fmm2d::util::error::{Context, Result};
use fmm2d::util::stats::max_rel_error;
use fmm2d::workload::Distribution;

const USAGE: &str = "\
fmm2d — adaptive fast multipole methods (Goude & Engblom 2012 reproduction)

USAGE: fmm2d <command> [options]

Experiment regeneration (DESIGN.md §3; all accept --full --seed S --gtx480
--threads T --pin — T=1 (default) is the paper's serial CPU baseline, T>1 or
--threads 0 (all cores) regenerates with the multithreaded engine):
  table5-1      GPU time distribution
  fig5-1        per-phase speedup vs N_d
  fig5-2        normalized total time vs N_d (optima ~35 CPU / ~45 GPU)
  fig5-3        speedup vs p (M2L occupancy cliff at 42)
  fig5-4        optimal N_d vs p
  fig5-5        time vs N, FMM vs direct (break-even)
  fig5-6        overall speedup vs N
  fig5-7        per-phase speedup vs N
  fig5-8        three distributions, time vs N
  fig5-9        robustness of adaptivity vs sigma
  all           run every experiment above in sequence

Validation & tools:
  validate      TOL vs p against direct summation (Eq. 5.3)
  ablate-theta  θ sweep: work mix / time / accuracy (design-choice ablation)
  ablate-shifts M2L kernel variants: recurrence vs unscaled vs matrix
  calibrate     GPU cost-model report vs the paper's headline ratios, then
                the dispatch calibration pass: measures per-phase CPU
                throughput (serial + pooled per worker count) and writes
                the JSON profile `--engine auto` reads [--quick: small
                sizes, dispatch profile only — the CI smoke configuration]
                [--profile FILE] [--threads T: calibrate one pooled count]
  run           one evaluation: --n --p --nd --dist uniform|normal|layer
                [--sigma S] [--engine serial|parallel|taskgraph|xla|auto]
                [--profile FILE] [--threads T] [--topo-threads T] [--pin]
                [--check] [--log-kernel]
  batch         evaluate --count K problems of --n points each in grouped
                fixed-shape dispatches: [--nmin A --nmax B] (size spread —
                heterogeneous shapes form multiple groups) [--batch-size G]
                [--engine serial|parallel|taskgraph|xla|auto] [--profile FILE]
                [--p --nd --dist --sigma
                --seed --threads --topo-threads --pin] [--no-overlap: build all
                topologies before dispatching instead of overlapping them
                with group execution] [--check] (parity vs sequential runs)
  batch-bench   batched vs sequential throughput table, incl. overlapped
                vs sequential topology prologue and the dispatcher's
                predicted batch time (--full --seed --threads)
  topo-bench    Sort/Connect serial vs parallel vs compute per N (--full
                --seed --threads)
  pool-bench    per-phase wall-clock: persistent worker pool vs scoped
                spawn-per-phase engine vs serial, per N, plus the
                dispatcher's predicted totals and the task-graph engine's
                wall-clock + phase-overlap ratio (--full --seed; --threads T
                pins one worker count, default sweeps; --pin)
  dispatch-bench predicted vs measured time per candidate engine and the
                auto choice, for single problems and batch groups (--full
                --seed --threads --pin)
  bench-suite   strict perf baseline: fixed matrix (sizes × distributions ×
                serial/parallel/taskgraph), warmup + median of --reps R (default 5),
                written to results/BENCH_<date>.json and compared against
                the newest earlier record (or --baseline FILE) as per-case
                ratios (--full --seed --threads --pin --out FILE)
  kernel-bench  per-kernel GFLOP/s of the tiled P2P accumulators and the
                blocked M2L panel vs a measured roofline (FMA-chain compute
                roof + streaming memory roof, DESIGN.md §10); --quick is the
                CI smoke size (--seed)
  artifacts     list available AOT artifacts (needs --features pjrt)

Serving & load generation (DESIGN.md §11):
  serve         long-lived daemon: line-delimited JSON requests on stdin
                (replies on stdout, stats on stderr), or TCP with --listen
                ADDR. In-flight requests coalesce into (levels,p) groups
                flushed on size or deadline; overload sheds with
                `overloaded` + retry_after_ms; panics are isolated per
                group (pool rebuilt, group split, engine degraded
                taskgraph→pooled→serial). [--engine
                serial|parallel|taskgraph|auto] [--threads T] [--topo-threads
                T] [--pin] [--profile FILE] [--max-group G] [--max-queue Q]
                [--max-n N] [--deadline-ms D] [--flush-fraction F]
                [--verbose] [--faults SPEC: arm deterministic failpoints,
                needs a --features failpoints build]
  loadgen       paced open-loop load test + audit: every request must be
                answered exactly once and every `ok` digest must match an
                offline evaluation bit for bit (nonzero exit otherwise).
                [--rps R] [--duration-s S] [--mix 300:3,900:1] [--burst B:
                unpaced mid-run burst, default --max-queue when --faults
                is armed] [--dist D --sigma S --seed S] [--deadline-ms D]
                [--engine E --threads T --pin --profile FILE] [--max-group
                G --max-queue Q --max-n N] [--quick: CI smoke preset]
                [--connect ADDR: drive a remote daemon instead of an
                in-process one] [--faults SPEC] [--no-digest-check]
                [--metrics: fetch the daemon's metric registry via the
                {\"op\":\"stats\"} wire request and reconcile it against the
                client-side exactly-once ledger]

Observability (DESIGN.md §12; flags accepted by every subcommand):
  --trace FILE  arm the flight recorder for this invocation and write a
                Chrome trace-event JSON timeline (load in Perfetto or
                chrome://tracing): per-phase spans, task-graph tasks,
                pool-worker occupancy, batch groups, serve lifecycle,
                dispatch predicted-vs-measured drift
  --log-level L stderr verbosity: error|warn|info|debug (default info);
                diagnostics are structured key=value lines
  trace-report FILE
                summarize any --trace file: per-phase busy/wall, worker
                occupancy, task-graph critical path, serve and dispatch
                tallies

The default engine is `parallel` with all available cores; --threads T caps
the worker count (T=1 falls back to the serial reference driver). Multicore
runs execute on a persistent worker pool (threads spawned once per
process); --pin pins worker i to core i (best-effort, Linux). `taskgraph`
runs the same pool through the dependency-graph scheduler: no phase
barriers, P2P overlaps the multipole chain, results stay bitwise-identical
to `parallel` (DESIGN.md §9). The
topological phase (Sort/Connect) follows --threads through the parallel
topology engine; --topo-threads T overrides it independently (T=1 serial
build, T=0 all cores). `--engine auto` resolves the engine per problem and
per batch group from the calibrated cost model (run `calibrate` once; the
decision, predicted and measured times print as a dispatch report;
--profile overrides the default ~/.cache/fmm2d/profile.json). The xla
engine and `artifacts` need a binary built with `--features pjrt`.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    match dispatch(&cmd, &argv[1..]) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

/// `--threads T` → engine thread count: `T = 0` means "all cores" (`None`),
/// absent means `default`.
fn threads_arg(args: &Args, default: Option<usize>) -> Result<Option<usize>> {
    Ok(match args.get("threads") {
        None => default,
        Some(s) => match s.parse::<usize>().map_err(|e| fmm2d::anyhow!("--threads {s}: {e}"))? {
            0 => None,
            t => Some(t),
        },
    })
}

/// `--topo-threads T` → Sort/Connect worker count: `T = 0` means "all
/// cores", absent means "follow --threads" (`None`).
fn topo_threads_arg(args: &Args) -> Result<Option<usize>> {
    Ok(match args.get("topo-threads") {
        None => None,
        Some(s) => match s
            .parse::<usize>()
            .map_err(|e| fmm2d::anyhow!("--topo-threads {s}: {e}"))?
        {
            0 => Some(fmm2d::util::threadpool::available_threads()),
            t => Some(t),
        },
    })
}

fn harness_opts(args: &Args) -> Result<HarnessOpts> {
    Ok(HarnessOpts {
        full: args.flag("full"),
        seed: args.get_or("seed", HarnessOpts::default().seed)?,
        gtx480: args.flag("gtx480"),
        threads: threads_arg(args, HarnessOpts::default().threads)?,
        pin: args.flag("pin"),
    })
}

fn run_figure(name: &str, o: &HarnessOpts) {
    match name {
        "table5-1" => {
            let (text, record) = harness::table5_1(o);
            println!("{text}");
            record.save("table5_1");
        }
        "fig5-1" => {
            let t = harness::fig5_1(o);
            println!("{}", t.render());
            t.save("fig5_1");
        }
        "fig5-2" => {
            let t = harness::fig5_2(o);
            println!("{}", t.render());
            t.save("fig5_2");
        }
        "fig5-3" => {
            let t = harness::fig5_3(o);
            println!("{}", t.render());
            t.save("fig5_3");
        }
        "fig5-4" => {
            let (t, (a, b)) = harness::fig5_4(o);
            println!("{}", t.render());
            println!("linear fit: opt_Nd_gpu ≈ {a:.1} + {b:.2}·p (paper: ~linear growth)");
            t.save("fig5_4");
        }
        "fig5-5" => {
            let (t, be) = harness::fig5_5(o);
            println!("{}", t.render());
            println!("GPU FMM/direct break-even ≈ N = {be:.0} (paper: ≈ 3500)");
            t.save("fig5_5");
        }
        "fig5-6" => {
            let t = harness::fig5_6(o);
            println!("{}", t.render());
            t.save("fig5_6");
        }
        "fig5-7" => {
            let t = harness::fig5_7(o);
            println!("{}", t.render());
            t.save("fig5_7");
        }
        "fig5-8" => {
            let t = harness::fig5_8(o);
            println!("{}", t.render());
            t.save("fig5_8");
        }
        "fig5-9" => {
            let t = harness::fig5_9(o);
            println!("{}", t.render());
            t.save("fig5_9");
        }
        _ => unreachable!(),
    }
}

fn dispatch(cmd: &str, rest: &[String]) -> Result<()> {
    if cmd == "trace-report" {
        if rest.len() != 1 || rest[0].starts_with("--") {
            bail!("usage: fmm2d trace-report FILE  (FILE: a Chrome trace written by --trace)");
        }
        print!(
            "{}",
            fmm2d::obs::report::render_file(std::path::Path::new(&rest[0]))?
        );
        return Ok(());
    }
    let args = Args::parse(rest)?;
    // cross-cutting observability options, accepted by every subcommand
    // (check_known treats them as globally known)
    if let Some(l) = args.get("log-level") {
        fmm2d::obs::log::set_level(fmm2d::obs::log::Level::parse(l)?);
    }
    let trace = args.get("trace").map(std::path::PathBuf::from);
    if trace.is_some() {
        fmm2d::obs::enable(&fmm2d::obs::ObsOptions::default());
    }
    let out = run_command(cmd, &args);
    if let Some(path) = &trace {
        // write the trace even when the command failed: a partial timeline
        // is exactly what diagnosing the failure needs
        match fmm2d::obs::write_chrome_file(path) {
            Ok(tr) => eprintln!(
                "[trace: {} span(s) from {} thread(s) written to {}{}]",
                tr.spans.len(),
                tr.threads.len(),
                path.display(),
                if tr.dropped > 0 {
                    format!(" ({} dropped)", tr.dropped)
                } else {
                    String::new()
                }
            ),
            Err(e) => eprintln!("[trace: writing {} failed: {e:#}]", path.display()),
        }
    }
    out
}

fn run_command(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "table5-1" | "fig5-1" | "fig5-2" | "fig5-3" | "fig5-4" | "fig5-5" | "fig5-6"
        | "fig5-7" | "fig5-8" | "fig5-9" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            run_figure(cmd, &harness_opts(args)?);
        }
        "all" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            let o = harness_opts(args)?;
            for name in [
                "table5-1", "fig5-1", "fig5-2", "fig5-3", "fig5-4", "fig5-5", "fig5-6",
                "fig5-7", "fig5-8", "fig5-9",
            ] {
                eprintln!("=== {name} ===");
                run_figure(name, &o);
            }
        }
        "validate" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            let t = harness::validate(&harness_opts(args)?);
            println!("{}", t.render());
            t.save("validate");
        }
        "ablate-theta" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            let t = harness::ablate_theta(&harness_opts(args)?);
            println!("{}", t.render());
            t.save("ablate_theta");
        }
        "ablate-shifts" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            let t = harness::ablate_shift_kernels(&harness_opts(args)?);
            println!("{}", t.render());
            t.save("ablate_shifts");
        }
        "calibrate" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin", "quick", "profile"])?;
            let o = harness_opts(args)?;
            let quick = args.flag("quick");
            if !quick {
                println!("{}", harness::calibrate(&o));
            }
            // dispatch calibration: measure CPU phase throughputs and
            // persist the profile `--engine auto` reads
            let copts = CalibrationOptions {
                quick,
                seed: o.seed,
                pin: o.pin,
                // an explicit --threads T calibrates the pooled engine at
                // that single worker count; default sweeps
                worker_counts: match (args.get("threads").is_some(), o.threads) {
                    (true, Some(t)) => vec![t],
                    _ => Vec::new(),
                },
            };
            let profile = CalibrationProfile::measure(&copts)?;
            println!("{}", profile.summary());
            let path = match args.get("profile") {
                Some(p) => std::path::PathBuf::from(p),
                None => CalibrationProfile::default_path(),
            };
            profile.save(&path)?;
            println!("[dispatch profile saved to {}]", path.display());
        }
        "dispatch-bench" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            // like batch-bench: engine comparisons default to all cores
            let mut o = harness_opts(args)?;
            if args.get("threads").is_none() {
                o.threads = None;
            }
            for (i, t) in harness::dispatch_bench(&o).iter().enumerate() {
                println!("{}", t.render());
                t.save(&format!("dispatch_bench_{i}"));
            }
        }
        "run" => cmd_run(args)?,
        "batch" => cmd_batch(args)?,
        "batch-bench" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            // unlike the figure harness (serial-baseline default), a
            // throughput comparison defaults to all cores; an explicit
            // --threads (including --threads 1) is honored as given
            let mut o = harness_opts(args)?;
            if args.get("threads").is_none() {
                o.threads = None;
            }
            let t = harness::batch_throughput(&o);
            println!("{}", t.render());
            t.save("batch_throughput");
        }
        "topo-bench" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            // like batch-bench: a throughput comparison defaults to all
            // cores; an explicit --threads is honored as given
            let mut o = harness_opts(args)?;
            if args.get("threads").is_none() {
                o.threads = None;
            }
            let t = harness::topo_bench(&o);
            println!("{}", t.render());
            t.save("topo_bench");
        }
        "pool-bench" => {
            args.check_known(&["full", "seed", "gtx480", "threads", "pin"])?;
            // --threads absent = sweep worker counts (None); an explicit
            // --threads T measures that single count, with T = 0 keeping
            // its crate-wide "all cores" meaning (one all-core table)
            let mut o = harness_opts(args)?;
            o.threads = match args.get("threads") {
                None => None,
                Some("0") => Some(fmm2d::util::threadpool::available_threads()),
                Some(_) => o.threads,
            };
            for (i, t) in harness::pool_bench(&o).iter().enumerate() {
                println!("{}", t.render());
                t.save(&format!("pool_bench_{i}"));
            }
        }
        "bench-suite" => cmd_bench_suite(args)?,
        "kernel-bench" => {
            use fmm2d::harness::kernelbench::{self, KernelBenchOpts};
            args.check_known(&["quick", "seed"])?;
            let opts = KernelBenchOpts {
                quick: args.flag("quick"),
                seed: args.get_or("seed", KernelBenchOpts::default().seed)?,
            };
            print!("{}", kernelbench::run(&opts).render());
        }
        "artifacts" => cmd_artifacts()?,
        "serve" => cmd_serve(args)?,
        "loadgen" => cmd_loadgen(args)?,
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => bail!("unknown command '{other}'; see `fmm2d help`"),
    }
    Ok(())
}

fn cmd_bench_suite(args: &Args) -> Result<()> {
    use fmm2d::harness::benchsuite::{self, BenchRecord, BenchSuiteOpts};

    args.check_known(&["full", "seed", "reps", "threads", "pin", "out", "baseline"])?;
    let opts = BenchSuiteOpts {
        full: args.flag("full"),
        seed: args.get_or("seed", BenchSuiteOpts::default().seed)?,
        reps: args.get_or("reps", BenchSuiteOpts::default().reps)?,
        threads: threads_arg(args, None)?,
        pin: args.flag("pin"),
    };
    if opts.reps == 0 {
        bail!("--reps must be at least 1");
    }
    let record = benchsuite::run(&opts)?;
    print!("{}", record.render());

    let out_dir = std::path::Path::new("results");
    let path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => record.default_path(out_dir),
    };
    // resolve the baseline before writing, so today's record never
    // compares against itself
    let baseline = match args.get("baseline") {
        Some(p) => Some(
            BenchRecord::load(std::path::Path::new(p))
                .with_context(|| format!("loading --baseline {p}"))?,
        ),
        None => match benchsuite::find_baseline(out_dir, &record.date) {
            Some(found) => Some(
                BenchRecord::load(&found)
                    .with_context(|| format!("loading baseline {}", found.display()))?,
            ),
            None => None,
        },
    };
    record.save(&path)?;
    println!("[bench record saved to {}]", path.display());
    match baseline {
        Some(base) => {
            let (report, _worst) = benchsuite::compare(&record, &base);
            print!("{report}");
        }
        None => println!("no earlier BENCH_*.json found; this run is the baseline"),
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts() -> Result<()> {
    let rt = fmm2d::runtime::Runtime::new(None)?;
    println!("artifact dir: {}", rt.artifact_dir().display());
    for name in rt.available() {
        println!("  {name}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts() -> Result<()> {
    bail!(
        "the `artifacts` command needs the PJRT runtime, which is disabled \
         in this build; rebuild with `cargo build --release --features pjrt`"
    );
}

/// The `ServeOptions` shared by `cmd_serve` and `cmd_loadgen`: engine +
/// thread resolution identical to `run` (serial forces one worker), queue
/// and deadline knobs from the common flag set.
fn serve_options_from_args(args: &Args) -> Result<fmm2d::serve::ServeOptions> {
    use fmm2d::serve::ServeOptions;
    let engine: Engine = args.get_or("engine", Engine::Parallel)?;
    if engine == Engine::Xla {
        bail!("serve runs the CPU engines; --engine xla is not a serve target");
    }
    let threads = match engine {
        Engine::Serial => Some(1),
        _ => threads_arg(args, None)?,
    };
    let dispatcher = if engine == Engine::Auto {
        Some(std::sync::Arc::new(dispatcher_from_args(args)?))
    } else {
        None
    };
    let defaults = ServeOptions::default();
    Ok(ServeOptions {
        fmm: FmmOptions {
            threads,
            topo_threads: topo_threads_arg(args)?,
            pin: args.flag("pin"),
            ..FmmOptions::default()
        },
        engine,
        dispatcher,
        max_group: args.get_or("max-group", defaults.max_group)?,
        max_queue: args.get_or("max-queue", defaults.max_queue)?,
        max_points: args.get_or("max-n", defaults.max_points)?,
        default_deadline_ms: args.get_or("deadline-ms", defaults.default_deadline_ms)?,
        flush_fraction: args.get_or("flush-fraction", defaults.flush_fraction)?,
        verbose: args.flag("verbose"),
        ..defaults
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "listen",
        "engine",
        "threads",
        "topo-threads",
        "pin",
        "profile",
        "max-group",
        "max-queue",
        "max-n",
        "deadline-ms",
        "flush-fraction",
        "faults",
        "verbose",
    ])?;
    if let Some(spec) = args.get("faults") {
        fmm2d::util::failpoint::arm(spec)?;
        eprintln!("fmm2d serve: failpoints armed: {spec}");
    }
    let opts = serve_options_from_args(args)?;
    match args.get("listen") {
        Some(addr) => fmm2d::serve::run_tcp(addr, opts)?,
        None => {
            fmm2d::serve::run_stdin(opts)?;
        }
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    use fmm2d::serve::loadgen::{self, LoadgenOptions};
    args.check_known(&[
        "rps",
        "duration-s",
        "mix",
        "dist",
        "sigma",
        "seed",
        "deadline-ms",
        "engine",
        "threads",
        "topo-threads",
        "pin",
        "profile",
        "max-group",
        "max-queue",
        "max-n",
        "flush-fraction",
        "burst",
        "quick",
        "faults",
        "connect",
        "no-digest-check",
        "metrics",
        "verbose",
    ])?;
    let quick = args.flag("quick");
    let defaults = LoadgenOptions::default();
    // --quick is the CI smoke preset: short, small problems, tight
    // deadlines — enough traffic to exercise grouping and shedding while
    // staying subsecond-scale
    let (d_rps, d_dur, d_mix, d_deadline) = if quick {
        (40.0, 1.5, "300:3,900:1".to_string(), 400)
    } else {
        (
            defaults.rps,
            defaults.duration_s,
            String::new(),
            defaults.deadline_ms,
        )
    };
    let sigma: f64 = args.get_or("sigma", 0.1)?;
    let faults = args.get("faults").map(str::to_string);
    let mut serve = serve_options_from_args(args)?;
    serve.default_deadline_ms = args.get_or("deadline-ms", d_deadline)?;
    let mix = match args.get("mix") {
        Some(spec) => loadgen::parse_mix(spec)?,
        None if !d_mix.is_empty() => loadgen::parse_mix(&d_mix)?,
        None => defaults.mix.clone(),
    };
    // under injected faults the interesting regime is a saturated queue:
    // default the burst to the admission bound so shedding must happen
    let default_burst = if faults.is_some() { serve.max_queue } else { 0 };
    let opts = LoadgenOptions {
        rps: args.get_or("rps", d_rps)?,
        duration_s: args.get_or("duration-s", d_dur)?,
        mix,
        dist: Distribution::from_name(args.get("dist").unwrap_or("uniform"), sigma)?,
        seed: args.get_or("seed", defaults.seed)?,
        deadline_ms: args.get_or("deadline-ms", d_deadline)?,
        burst: args.get_or("burst", default_burst)?,
        serve,
        connect: args.get("connect").map(str::to_string),
        faults,
        digest_check: !args.flag("no-digest-check"),
        metrics: args.flag("metrics"),
    };
    let report = loadgen::run(&opts)?;
    println!("{}", report.render());
    report.gate()
}

/// The dispatcher of an `--engine auto` invocation: an explicit
/// `--profile` must load (errors surface), otherwise the default profile
/// location with a built-in fallback.
fn dispatcher_from_args(args: &Args) -> Result<Dispatcher> {
    match args.get("profile") {
        Some(p) => Dispatcher::load(std::path::Path::new(p))
            .with_context(|| format!("loading --profile {p}")),
        None => Ok(Dispatcher::load_or_default(None)),
    }
}

fn print_phase_times(times: &PhaseTimes) {
    println!("{:<8} {:>12} ", "phase", "seconds");
    for (i, name) in PHASE_NAMES.iter().enumerate() {
        println!("{name:<8} {:>12.6}", times.0[i]);
    }
    println!("{:<8} {:>12.6}", "total", times.total());
}

fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(&[
        "n", "p", "nd", "dist", "sigma", "engine", "check", "seed", "log-kernel", "levels",
        "threads", "topo-threads", "pin", "profile",
    ])?;
    let n: usize = args.get_or("n", 10_000)?;
    let p: usize = args.get_or("p", 17)?;
    let nd: usize = args.get_or("nd", 45)?;
    let seed: u64 = args.get_or("seed", 1)?;
    let sigma: f64 = args.get_or("sigma", 0.1)?;
    // from_name also validates σ (finite, positive, bounded) at the CLI
    // boundary — the same check `serve` applies to wire requests
    let dist = Distribution::from_name(args.get("dist").unwrap_or("uniform"), sigma)?;
    let kernel = if args.flag("log-kernel") {
        Kernel::Log
    } else {
        Kernel::Harmonic
    };
    // one FromStr impl owns the engine-name list for `run` and `batch`
    let engine: Engine = args.get_or("engine", Engine::Parallel)?;
    let threads = match engine {
        // --engine serial forces the reference driver; otherwise --threads T
        // caps the workers (default: all cores; `auto` treats it as the
        // pooled candidate's worker cap)
        Engine::Serial => Some(1),
        _ => threads_arg(args, None)?,
    };
    // topology workers follow the engine unless --topo-threads overrides
    let topo_threads = topo_threads_arg(args)?;

    let (pts, mut gs) = harness::workload_for(dist, n, seed);
    if kernel == Kernel::Log {
        for g in gs.iter_mut() {
            g.im = 0.0; // log kernel: real strengths (see fmm tests)
        }
    }
    let mut cfg = FmmConfig {
        p,
        n_per_box: nd,
        ..FmmConfig::default()
    };
    if let Some(l) = args.get("levels") {
        cfg.levels_override = Some(l.parse()?);
    }
    let levels = cfg.levels_for(n);
    let opts = FmmOptions {
        cfg,
        kernel,
        symmetric_p2p: true,
        threads,
        topo_threads,
        pin: args.flag("pin"),
        cpu_engine: match engine {
            // the pipelined engine replaces the barrier engine in-place;
            // every other selector keeps the barrier default
            Engine::TaskGraph => CpuEngine::TaskGraph,
            _ => CpuEngine::Barrier,
        },
        ..FmmOptions::default()
    };
    println!(
        "n={n} p={p} N_d={nd} levels={levels} dist={} kernel={kernel:?} engine={engine} \
         threads={}",
        dist.name(),
        opts.effective_threads(),
    );

    let potentials = match engine {
        Engine::Serial | Engine::Parallel | Engine::TaskGraph => {
            let out = fmm::evaluate(&pts, &gs, &opts)?;
            print_phase_times(&out.times);
            out.potentials
        }
        Engine::Xla => run_xla_engine(&pts, &gs, &opts, levels, p)?,
        Engine::Auto => {
            // resolve the engine from the calibrated cost model, run it,
            // and report the decision with predicted vs measured time
            let dispatcher = dispatcher_from_args(args)?;
            let problem = fmm2d::dispatch::Problem::from_config(&opts.cfg, pts.len());
            let mut decision = dispatcher.select_capped(&problem, opts.threads);
            let potentials = if decision.choice == EngineChoice::Xla {
                let t0 = std::time::Instant::now();
                let pots = run_xla_engine(&pts, &gs, &opts, levels, p)?;
                decision.measured_s = Some(t0.elapsed().as_secs_f64());
                pots
            } else {
                // the shared choice-to-execution mapping (times included)
                let out = fmm2d::dispatch::execute_cpu_choice(&pts, &gs, &opts, &mut decision)?;
                print_phase_times(&out.times);
                out.potentials
            };
            println!(
                "{}",
                DispatchReport {
                    decisions: vec![decision],
                }
                .render()
            );
            potentials
        }
    };

    if args.flag("check") {
        if n > 30_000 {
            bail!("--check is O(N²); use n ≤ 30000");
        }
        // structural validators (debug builds run these inside every
        // topology::build; --check extends the coverage to release)
        let topo = fmm2d::topology::build(&pts, &gs, levels, &opts.topology_options())?;
        topo.pyramid.validate()?;
        topo.connectivity.validate(&topo.pyramid)?;
        println!("structural validators: pyramid + connectivity OK");
        let exact = fmm2d::direct::eval_symmetric(kernel, &pts, &gs);
        let (a, e): (Vec<f64>, Vec<f64>) = if kernel == Kernel::Harmonic {
            (
                potentials.iter().map(|c| c.abs()).collect(),
                exact.iter().map(|c| c.abs()).collect(),
            )
        } else {
            (
                potentials.iter().map(|c| c.re).collect(),
                exact.iter().map(|c| c.re).collect(),
            )
        };
        let err = max_rel_error(&a, &e, 1e-12);
        println!("max relative error vs direct (Eq. 5.3): {err:.3e}");
    }
    Ok(())
}

fn cmd_batch(args: &Args) -> Result<()> {
    use fmm2d::batch::{self, BatchEngine, BatchOptions, BatchProblem};

    args.check_known(&[
        "count",
        "n",
        "nmin",
        "nmax",
        "batch-size",
        "engine",
        "p",
        "nd",
        "dist",
        "sigma",
        "seed",
        "threads",
        "topo-threads",
        "pin",
        "no-overlap",
        "check",
        "profile",
    ])?;
    let count: usize = args.get_or("count", 64)?;
    let n: usize = args.get_or("n", 2000)?;
    let nmin: usize = args.get_or("nmin", n)?;
    let nmax: usize = args.get_or("nmax", n)?;
    if count == 0 {
        bail!("--count must be at least 1");
    }
    if nmin > nmax {
        bail!("--nmin {nmin} exceeds --nmax {nmax}");
    }
    let p: usize = args.get_or("p", 17)?;
    let nd: usize = args.get_or("nd", 45)?;
    let seed: u64 = args.get_or("seed", 1)?;
    let sigma: f64 = args.get_or("sigma", 0.1)?;
    let dist = Distribution::from_name(
        args.get_choice("dist", &["uniform", "normal", "layer"], "uniform")?
            .as_str(),
        sigma,
    )?;
    // the same FromStr impl as `run` parses the engine; BatchEngine is its
    // one-to-one image (From<Engine>)
    let cli_engine: Engine = args.get_or("engine", Engine::Parallel)?;
    let engine = BatchEngine::from(cli_engine);
    let dispatcher = if cli_engine == Engine::Auto {
        Some(std::sync::Arc::new(dispatcher_from_args(args)?))
    } else {
        None
    };
    let threads = threads_arg(args, None)?;
    let topo_threads = topo_threads_arg(args)?;

    // deterministic linear size spread over [nmin, nmax]
    let problem_size = |i: usize| {
        if count == 1 {
            nmax
        } else {
            nmin + i * (nmax - nmin) / (count - 1)
        }
    };
    let problems: Vec<BatchProblem> = (0..count)
        .map(|i| {
            let (points, gammas) =
                harness::workload_for(dist, problem_size(i), seed.wrapping_add(i as u64));
            BatchProblem { points, gammas }
        })
        .collect();

    let opts = BatchOptions {
        fmm: FmmOptions {
            cfg: FmmConfig {
                p,
                n_per_box: nd,
                ..FmmConfig::default()
            },
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads,
            topo_threads,
            pin: args.flag("pin"),
            ..FmmOptions::default()
        },
        engine,
        max_group: args.get_or("batch-size", 0)?,
        overlap: !args.flag("no-overlap"),
        dispatcher,
    };
    let out = batch::run(&problems, &opts)?;
    let s = &out.stats;
    println!(
        "problems={} groups={} dispatches={} total_points={} engine={cli_engine} threads={}",
        s.n_problems,
        s.n_groups,
        s.dispatches,
        out.counts.n,
        opts.fmm.effective_threads(),
    );
    println!("{:<8} {:>12}", "phase", "seconds");
    for (i, name) in PHASE_NAMES.iter().enumerate() {
        println!("{name:<8} {:>12.6}", s.times.0[i]);
    }
    println!("{:<8} {:>12.6}", "wall", s.wall_s);
    println!(
        "throughput: {:.1} problems/s, {:.3e} points/s",
        s.n_problems as f64 / s.wall_s.max(1e-12),
        out.counts.n as f64 / s.wall_s.max(1e-12),
    );
    if engine == BatchEngine::Xla {
        println!(
            "xla: upload {:.6} execute {:.6} download {:.6}",
            s.upload_s, s.execute_s, s.download_s
        );
    }
    if let Some(report) = &out.report {
        println!("{}", report.render());
    }

    if args.flag("check") {
        if nmax > 30_000 {
            bail!("--check runs a sequential FMM per problem; use --nmax ≤ 30000");
        }
        // the CPU engines reduce in the serial driver's order (parity to
        // 1e-12); the XLA artifacts reduce in padded fixed-shape order and
        // legitimately deviate more (runtime_e2e accepts 1e-9 on this path)
        let xla_involved = engine == BatchEngine::Xla
            || out.report.as_ref().is_some_and(|r| {
                r.decisions
                    .iter()
                    .any(|d| d.choice == EngineChoice::Xla)
            });
        let tol = if xla_involved { 1e-9 } else { 1e-12 };
        let mut worst = 0.0f64;
        for (i, pr) in problems.iter().enumerate() {
            // structural validators on every problem's topology (debug
            // builds also run them inside topology::build itself)
            let levels = opts.fmm.cfg.levels_for(pr.points.len());
            let topo = fmm2d::topology::build(
                &pr.points,
                &pr.gammas,
                levels,
                &opts.fmm.topology_options(),
            )?;
            topo.pyramid.validate()?;
            topo.connectivity.validate(&topo.pyramid)?;
            let seq = fmm::evaluate(
                &pr.points,
                &pr.gammas,
                &FmmOptions {
                    threads: Some(1),
                    ..opts.fmm.clone()
                },
            )?;
            for (a, b) in out.potentials[i].iter().zip(&seq.potentials) {
                let d = (*a - *b).abs() / a.abs().max(1.0);
                worst = worst.max(d);
            }
        }
        println!("max relative deviation vs sequential per-problem runs: {worst:.3e}");
        if worst > tol {
            bail!("batch parity check failed: {worst:.3e} > {tol:.0e}");
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn run_xla_engine(
    pts: &[fmm2d::C64],
    gs: &[fmm2d::C64],
    opts: &FmmOptions,
    levels: usize,
    p: usize,
) -> Result<Vec<fmm2d::C64>> {
    use fmm2d::runtime::Runtime;
    use fmm2d::topology;

    if opts.kernel != Kernel::Harmonic {
        bail!("the XLA artifacts are compiled for the harmonic kernel");
    }
    let mut rt = Runtime::new(None)?;
    // the topological phase honors --threads/--topo-threads like the CPU
    // engines (the artifact only runs the computational phase)
    let topo = topology::build(pts, gs, levels, &opts.topology_options())?;
    let (pyr, con) = (topo.pyramid, topo.connectivity);
    let exe = rt.fmm_artifact_for_tree(&pyr, &con)?;
    if exe.meta.p != p {
        eprintln!(
            "note: artifact {} uses p={} (compiled-in); --p {p} ignored",
            exe.meta.name, exe.meta.p
        );
    }
    let (pot, stats) = exe.run_fmm(&pyr, &con)?;
    println!("artifact: {} (platform {})", exe.meta.name, rt.platform());
    println!("upload   {:>12.6}", stats.upload_s);
    println!("execute  {:>12.6}", stats.execute_s);
    println!("download {:>12.6}", stats.download_s);
    println!("total    {:>12.6}", stats.total());
    Ok(pot)
}

#[cfg(not(feature = "pjrt"))]
fn run_xla_engine(
    _pts: &[fmm2d::C64],
    _gs: &[fmm2d::C64],
    _opts: &FmmOptions,
    _levels: usize,
    _p: usize,
) -> Result<Vec<fmm2d::C64>> {
    bail!(
        "--engine xla needs the PJRT runtime, which is disabled in this \
         build; rebuild with `cargo build --release --features pjrt`"
    );
}
