//! Connectivity of the FMM mesh — the "connecting" half of the topological
//! phase (paper §2, §3.2, §4.3).
//!
//! For every level the boxes are classified pairwise as *weakly* coupled
//! (well separated under the θ-criterion ⇒ M2L interaction) or *strongly*
//! coupled (deferred to the children; at the finest level resolved by P2P,
//! or by the one-sided P2L/M2P shortcuts when the r↔R-interchanged
//! criterion admits them).
//!
//! Lists are **directed** (an entry per *destination* box), the layout the
//! paper chooses for its GPU code (§4.3: twice the memory, no write
//! conflicts); the serial CPU driver exploits symmetry by visiting only
//! ordered pairs (the paper's one-directional CPU lists, §4.3).
//!
//! Storage is CSR-style (offset + data arrays) per level: the connectivity
//! of large trees is in the tens of millions of entries, and `Vec<Vec<_>>`
//! overhead dominated profile traces in early versions (see EXPERIMENTS.md
//! §Perf).
//!
//! The build runs serially ([`Connectivity::build`]) or sharded over
//! worker threads — scoped spawns ([`Connectivity::build_threaded`]) or
//! the persistent pool ([`Connectivity::build_on_pool`]): per level, the
//! destination boxes are classified in a two-pass count-then-fill CSR
//! scheme — pass 1 classifies each worker's contiguous destination range
//! into thread-local buffers with per-box degrees (computable
//! independently per box from the previous level's strong list), an
//! exclusive scan over the degrees fixes the global offsets, and pass 2
//! fills the disjoint `data` slices lock-free. Both paths produce
//! byte-identical [`AdjList`]s (`tests/topology_parity.rs`);
//! [`crate::topology`] selects between them.

use crate::geometry::{theta_criterion, theta_criterion_interchanged, Rect};
use crate::tree::{boxes_at_level, first_child_of, Pyramid};
use crate::util::pool::WorkerPool;
use crate::util::threadpool::{ranges, scoped_map, split_lengths_mut};
use std::ops::Range;

/// Directed adjacency for one interaction kind at one level, CSR layout:
/// sources of destination box `b` are `data[offsets[b]..offsets[b+1]]`.
#[derive(Clone, Debug, Default)]
pub struct AdjList {
    pub offsets: Vec<u32>,
    pub data: Vec<u32>,
}

impl AdjList {
    pub fn with_boxes(nb: usize) -> Self {
        AdjList {
            offsets: vec![0; nb + 1],
            data: Vec::new(),
        }
    }

    #[inline]
    pub fn n_boxes(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn sources(&self, b: usize) -> &[u32] {
        &self.data[self.offsets[b] as usize..self.offsets[b + 1] as usize]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Largest in-degree (the padding width of the static packing).
    pub fn max_degree(&self) -> usize {
        (0..self.n_boxes())
            .map(|b| self.sources(b).len())
            .max()
            .unwrap_or(0)
    }

}

/// Full connectivity of a pyramid.
#[derive(Clone, Debug)]
pub struct Connectivity {
    /// θ used to build the lists.
    pub theta: f64,
    /// Weak (M2L) lists per level `1..=L` (index 0 is the — always empty —
    /// root level, kept so `weak[l]` aligns with `pyramid.rects[l]`).
    pub weak: Vec<AdjList>,
    /// Strong lists at the finest level after P2L/M2P extraction: the P2P
    /// near field. Directed; contains the box itself.
    pub near: AdjList,
    /// Finest-level P2L shortcuts: `p2l.sources(b)` are boxes whose
    /// *particles* are absorbed into `b`'s local expansion.
    pub p2l: AdjList,
    /// Finest-level M2P shortcuts: `m2p.sources(b)` are boxes whose
    /// *multipole expansion* is evaluated directly at `b`'s points.
    pub m2p: AdjList,
    /// Pairwise θ-criterion evaluations performed (GPU cost model input).
    pub checks: usize,
}

#[inline]
fn well_separated(a: &Rect, b: &Rect, theta: f64) -> bool {
    let d = (a.center() - b.center()).abs();
    theta_criterion(a.radius(), b.radius(), d, theta)
}

impl Connectivity {
    /// Classify all levels of `pyr` under the θ-criterion.
    ///
    /// Per level `l`, the candidate sources of box `b` are exactly the
    /// children of the strong list of `b`'s parent (§2) — the recursion
    /// starts from the root being strongly coupled to itself.
    pub fn build(pyr: &Pyramid, theta: f64) -> Self {
        let _sp = crate::obs::span("topo", "classify");
        let levels = pyr.levels;
        let mut checks = 0usize;

        let mut weak: Vec<AdjList> = Vec::with_capacity(levels + 1);
        weak.push(AdjList::with_boxes(1)); // root level: no weak pairs

        // strong lists of the previous level; root strongly coupled to itself
        let mut strong_prev = AdjList {
            offsets: vec![0, 1],
            data: vec![0],
        };

        for l in 1..=levels {
            let nb = boxes_at_level(l);
            let rects = &pyr.rects[l];
            let mut weak_l = AdjList {
                offsets: Vec::with_capacity(nb + 1),
                data: Vec::new(),
            };
            weak_l.offsets.push(0);
            let mut strong_l = AdjList {
                offsets: Vec::with_capacity(nb + 1),
                data: Vec::new(),
            };
            strong_l.offsets.push(0);

            for b in 0..nb {
                let parent = b >> 2;
                for &sp in strong_prev.sources(parent) {
                    let c0 = first_child_of(sp as usize);
                    for c in c0..c0 + 4 {
                        checks += 1;
                        if well_separated(&rects[b], &rects[c], theta) {
                            weak_l.data.push(c as u32);
                        } else {
                            strong_l.data.push(c as u32);
                        }
                    }
                }
                weak_l.offsets.push(weak_l.data.len() as u32);
                strong_l.offsets.push(strong_l.data.len() as u32);
            }
            weak.push(weak_l);
            strong_prev = strong_l;
        }

        // Finest level: split the remaining strong pairs into near-field
        // (P2P) and the interchanged-criterion shortcuts (P2L / M2P).
        let nb = boxes_at_level(levels);
        let rects = &pyr.rects[levels];
        let mut near = AdjList::with_boxes(0);
        let mut p2l = AdjList::with_boxes(0);
        let mut m2p = AdjList::with_boxes(0);
        near.offsets = vec![0];
        p2l.offsets = vec![0];
        m2p.offsets = vec![0];
        for b in 0..nb {
            for &s in strong_prev.sources(b) {
                let su = s as usize;
                if su == b {
                    near.data.push(s);
                    continue;
                }
                let (rb, rs) = (rects[b].radius(), rects[su].radius());
                let d = (rects[b].center() - rects[su].center()).abs();
                checks += 1;
                if theta_criterion_interchanged(rb, rs, d, theta) {
                    // one-sided expansions are admissible for this pair
                    if rs > rb {
                        // source box is the larger: its particles reach b
                        // only through b's local expansion
                        p2l.data.push(s);
                    } else if rs < rb {
                        // source box is the smaller: its multipole is valid
                        // on all of b
                        m2p.data.push(s);
                    } else {
                        // equal radii: interchanged == plain criterion,
                        // which failed ⇒ unreachable, keep P2P for safety
                        near.data.push(s);
                    }
                } else {
                    near.data.push(s);
                }
            }
            near.offsets.push(near.data.len() as u32);
            p2l.offsets.push(p2l.data.len() as u32);
            m2p.offsets.push(m2p.data.len() as u32);
        }

        Connectivity {
            theta,
            weak,
            near,
            p2l,
            m2p,
            checks,
        }
    }

    /// [`Connectivity::build`] sharded over `threads` scoped workers.
    ///
    /// Per level, the destination boxes are partitioned into contiguous
    /// ranges; pass 1 classifies every range into thread-local CSR
    /// fragments (per-box degrees + concatenated source lists — degrees
    /// are computable independently per box because every box only reads
    /// the *previous* level's strong list), an exclusive scan over the
    /// degrees fixes the global offsets, and pass 2 copies the fragments
    /// into their disjoint `data` slices lock-free. Classification order
    /// within each box matches the serial loop, and fragments concatenate
    /// in box order, so the resulting [`AdjList`]s are byte-identical to
    /// [`Connectivity::build`] for every thread count
    /// (`tests/topology_parity.rs`). `threads ≤ 1` falls back to the
    /// serial path.
    pub fn build_threaded(pyr: &Pyramid, theta: f64, threads: usize) -> Self {
        Self::build_parallel(pyr, theta, threads, None)
    }

    /// [`Connectivity::build_threaded`] executing its fan-outs on a
    /// persistent [`WorkerPool`] instead of scoped spawns — byte-identical
    /// output, zero thread spawns.
    pub fn build_on_pool(pyr: &Pyramid, theta: f64, threads: usize, pool: &WorkerPool) -> Self {
        Self::build_parallel(pyr, theta, threads.min(pool.n_workers()), Some(pool))
    }

    /// Structural validation of the built lists against their pyramid
    /// (DESIGN.md §8):
    ///
    /// * CSR well-formedness — every list has `n_boxes + 1` offsets
    ///   starting at 0, monotone, ending at `data.len()`, with every
    ///   source index in range;
    /// * shape — `weak[l]` aligns with `pyr.rects[l]` for every level
    ///   (`4^l` boxes, root level empty), and the finest-level lists cover
    ///   exactly the leaves;
    /// * symmetry — the weak (M2L) lists and the P2P near field are
    ///   symmetric, and the near field contains each box itself;
    /// * exclusivity — no finest-level pair is classified both weak (M2L)
    ///   and near (P2P);
    /// * duality — `(dst, src) ∈ p2l ⟺ (src, dst) ∈ m2p` (the larger
    ///   box's particles feed the smaller's local expansion; the smaller's
    ///   multipole is evaluated in the larger).
    ///
    /// Wired into debug-mode [`crate::topology::build`] and the `--check`
    /// paths of `run`/`batch`.
    pub fn validate(&self, pyr: &Pyramid) -> crate::util::error::Result<()> {
        fn check_csr(name: &str, adj: &AdjList, nb: usize) -> crate::util::error::Result<()> {
            crate::ensure!(
                adj.offsets.len() == nb + 1,
                "{name}: {} offsets for {nb} boxes",
                adj.offsets.len()
            );
            crate::ensure!(adj.offsets[0] == 0, "{name}: offsets must start at 0");
            for b in 0..nb {
                crate::ensure!(
                    adj.offsets[b] <= adj.offsets[b + 1],
                    "{name}: offsets not monotone at box {b}"
                );
            }
            crate::ensure!(
                adj.offsets[nb] as usize == adj.data.len(),
                "{name}: offsets end at {}, data has {} entries",
                adj.offsets[nb],
                adj.data.len()
            );
            for &s in &adj.data {
                crate::ensure!(
                    (s as usize) < nb,
                    "{name}: source {s} out of range 0..{nb}"
                );
            }
            Ok(())
        }

        let levels = pyr.levels;
        crate::ensure!(
            self.weak.len() == levels + 1,
            "{} weak levels for a {levels}-level pyramid",
            self.weak.len()
        );
        crate::ensure!(self.weak[0].is_empty(), "root level must have no weak pairs");
        for (l, w) in self.weak.iter().enumerate() {
            check_csr(&format!("weak[{l}]"), w, boxes_at_level(l))?;
            crate::ensure!(is_symmetric(w), "weak[{l}] is not symmetric");
        }

        let nl = pyr.n_leaves();
        check_csr("near", &self.near, nl)?;
        check_csr("p2l", &self.p2l, nl)?;
        check_csr("m2p", &self.m2p, nl)?;
        crate::ensure!(is_symmetric(&self.near), "near field is not symmetric");
        for b in 0..nl {
            crate::ensure!(
                self.near.sources(b).contains(&(b as u32)),
                "near field of box {b} is missing the box itself"
            );
            for &s in self.near.sources(b) {
                crate::ensure!(
                    !self.weak[levels].sources(b).contains(&s),
                    "pair ({b}, {s}) classified both near (P2P) and weak (M2L)"
                );
            }
        }

        let mut p2l_pairs: Vec<(u32, u32)> = Vec::new();
        let mut m2p_pairs: Vec<(u32, u32)> = Vec::new();
        for b in 0..nl {
            for &s in self.p2l.sources(b) {
                p2l_pairs.push((b as u32, s));
            }
            for &s in self.m2p.sources(b) {
                m2p_pairs.push((s, b as u32));
            }
        }
        p2l_pairs.sort_unstable();
        m2p_pairs.sort_unstable();
        crate::ensure!(
            p2l_pairs == m2p_pairs,
            "p2l/m2p are not duals ({} vs {} pairs)",
            p2l_pairs.len(),
            m2p_pairs.len()
        );
        Ok(())
    }

    fn build_parallel(
        pyr: &Pyramid,
        theta: f64,
        threads: usize,
        pool: Option<&WorkerPool>,
    ) -> Self {
        // oversized requests clamp to the machine (see Pyramid::build_threaded)
        let threads = threads.min(crate::util::threadpool::available_threads().max(1));
        if threads <= 1 {
            return Self::build(pyr, theta);
        }
        let _sp = crate::obs::span("topo", "classify").arg("threads", threads as f64);
        let levels = pyr.levels;
        let mut checks = 0usize;

        let mut weak: Vec<AdjList> = Vec::with_capacity(levels + 1);
        weak.push(AdjList::with_boxes(1)); // root level: no weak pairs

        let mut strong_prev = AdjList {
            offsets: vec![0, 1],
            data: vec![0],
        };

        for l in 1..=levels {
            let nb = boxes_at_level(l);
            let rects: &[Rect] = &pyr.rects[l];
            let workers = threads.min(nb);
            let shards: Vec<LevelShard> = if workers > 1 {
                let strong_prev = &strong_prev;
                let items = ranges(nb, workers);
                match pool {
                    Some(p) => p.map_items(items, |r| {
                        classify_level_range(r, rects, strong_prev, theta)
                    }),
                    None => scoped_map(items, |r| {
                        classify_level_range(r, rects, strong_prev, theta)
                    }),
                }
            } else {
                vec![classify_level_range(0..nb, rects, &strong_prev, theta)]
            };
            checks += shards.iter().map(|sh| sh.checks).sum::<usize>();
            let mut weak_frags = Vec::with_capacity(shards.len());
            let mut strong_frags = Vec::with_capacity(shards.len());
            for sh in shards {
                weak_frags.push((sh.weak_deg, sh.weak));
                strong_frags.push((sh.strong_deg, sh.strong));
            }
            weak.push(assemble_csr(nb, weak_frags, workers > 1, pool));
            strong_prev = assemble_csr(nb, strong_frags, workers > 1, pool);
        }

        // Finest level: near/P2L/M2P split, same count-then-fill scheme.
        let nb = boxes_at_level(levels);
        let rects: &[Rect] = &pyr.rects[levels];
        let workers = threads.min(nb);
        let shards: Vec<FinestShard> = if workers > 1 {
            let strong_prev = &strong_prev;
            let items = ranges(nb, workers);
            match pool {
                Some(p) => p.map_items(items, |r| {
                    classify_finest_range(r, rects, strong_prev, theta)
                }),
                None => scoped_map(items, |r| {
                    classify_finest_range(r, rects, strong_prev, theta)
                }),
            }
        } else {
            vec![classify_finest_range(0..nb, rects, &strong_prev, theta)]
        };
        checks += shards.iter().map(|sh| sh.checks).sum::<usize>();
        let mut near_frags = Vec::with_capacity(shards.len());
        let mut p2l_frags = Vec::with_capacity(shards.len());
        let mut m2p_frags = Vec::with_capacity(shards.len());
        for sh in shards {
            near_frags.push((sh.near_deg, sh.near));
            p2l_frags.push((sh.p2l_deg, sh.p2l));
            m2p_frags.push((sh.m2p_deg, sh.m2p));
        }
        let near = assemble_csr(nb, near_frags, workers > 1, pool);
        let p2l = assemble_csr(nb, p2l_frags, workers > 1, pool);
        let m2p = assemble_csr(nb, m2p_frags, workers > 1, pool);

        Connectivity {
            theta,
            weak,
            near,
            p2l,
            m2p,
            checks,
        }
    }

    /// Total M2L interactions across all levels.
    pub fn total_weak(&self) -> usize {
        self.weak.iter().map(|w| w.len()).sum()
    }

    /// Total near-field (P2P) box pairs, self included.
    pub fn total_near(&self) -> usize {
        self.near.len()
    }
}

/// One worker's pass-1 output over a contiguous destination range of an
/// interior level: thread-local CSR fragments (per-box degrees plus the
/// concatenated sources, in box order) for the weak and strong lists.
struct LevelShard {
    weak_deg: Vec<u32>,
    weak: Vec<u32>,
    strong_deg: Vec<u32>,
    strong: Vec<u32>,
    checks: usize,
}

fn classify_level_range(
    r: Range<usize>,
    rects: &[Rect],
    strong_prev: &AdjList,
    theta: f64,
) -> LevelShard {
    let n = r.end - r.start;
    let mut sh = LevelShard {
        weak_deg: Vec::with_capacity(n),
        weak: Vec::new(),
        strong_deg: Vec::with_capacity(n),
        strong: Vec::new(),
        checks: 0,
    };
    for b in r {
        let parent = b >> 2;
        let (w0, s0) = (sh.weak.len(), sh.strong.len());
        for &sp in strong_prev.sources(parent) {
            let c0 = first_child_of(sp as usize);
            for c in c0..c0 + 4 {
                sh.checks += 1;
                if well_separated(&rects[b], &rects[c], theta) {
                    sh.weak.push(c as u32);
                } else {
                    sh.strong.push(c as u32);
                }
            }
        }
        sh.weak_deg.push((sh.weak.len() - w0) as u32);
        sh.strong_deg.push((sh.strong.len() - s0) as u32);
    }
    sh
}

/// One worker's pass-1 output over a contiguous destination range of the
/// finest level: near-field (P2P) plus the P2L/M2P shortcut lists.
struct FinestShard {
    near_deg: Vec<u32>,
    near: Vec<u32>,
    p2l_deg: Vec<u32>,
    p2l: Vec<u32>,
    m2p_deg: Vec<u32>,
    m2p: Vec<u32>,
    checks: usize,
}

fn classify_finest_range(
    r: Range<usize>,
    rects: &[Rect],
    strong_prev: &AdjList,
    theta: f64,
) -> FinestShard {
    let n = r.end - r.start;
    let mut sh = FinestShard {
        near_deg: Vec::with_capacity(n),
        near: Vec::new(),
        p2l_deg: Vec::with_capacity(n),
        p2l: Vec::new(),
        m2p_deg: Vec::with_capacity(n),
        m2p: Vec::new(),
        checks: 0,
    };
    for b in r {
        let (n0, p0, m0) = (sh.near.len(), sh.p2l.len(), sh.m2p.len());
        for &s in strong_prev.sources(b) {
            let su = s as usize;
            if su == b {
                sh.near.push(s);
                continue;
            }
            let (rb, rs) = (rects[b].radius(), rects[su].radius());
            let d = (rects[b].center() - rects[su].center()).abs();
            sh.checks += 1;
            if theta_criterion_interchanged(rb, rs, d, theta) {
                if rs > rb {
                    sh.p2l.push(s);
                } else if rs < rb {
                    sh.m2p.push(s);
                } else {
                    sh.near.push(s);
                }
            } else {
                sh.near.push(s);
            }
        }
        sh.near_deg.push((sh.near.len() - n0) as u32);
        sh.p2l_deg.push((sh.p2l.len() - p0) as u32);
        sh.m2p_deg.push((sh.m2p.len() - m0) as u32);
    }
    sh
}

/// Below this many total entries the pass-2 fill runs serially: a scoped
/// thread costs more to spawn/join than it saves on a small memcpy, and
/// shallow levels have only a few dozen entries per fragment.
const PARALLEL_FILL_MIN: usize = 1 << 16;

/// Pass 2 of the count-then-fill build: an exclusive scan over the per-box
/// degrees (in fragment = box order) fixes the offsets, then each worker's
/// fragment is copied into its disjoint slice of the global `data` array —
/// lock-free, since the fragments tile the array contiguously. Lists below
/// [`PARALLEL_FILL_MIN`] entries copy serially regardless; the parallel
/// fill runs on the pool when one is supplied, on scoped spawns otherwise.
fn assemble_csr(
    nb: usize,
    fragments: Vec<(Vec<u32>, Vec<u32>)>,
    parallel_fill: bool,
    pool: Option<&WorkerPool>,
) -> AdjList {
    let mut offsets = Vec::with_capacity(nb + 1);
    offsets.push(0u32);
    let mut acc = 0u32;
    for (deg, _) in &fragments {
        for &d in deg {
            acc += d;
            offsets.push(acc);
        }
    }
    debug_assert_eq!(offsets.len(), nb + 1);
    let mut data = vec![0u32; acc as usize];
    let lens: Vec<usize> = fragments.iter().map(|(_, d)| d.len()).collect();
    let slices = split_lengths_mut(&mut data, &lens);
    if parallel_fill && acc as usize >= PARALLEL_FILL_MIN {
        type FillItem<'a> = (&'a mut [u32], &'a (Vec<u32>, Vec<u32>));
        let items: Vec<FillItem> = slices.into_iter().zip(&fragments).collect();
        match pool {
            Some(p) => {
                p.map_items(items, |(dst, (_, src)): FillItem| dst.copy_from_slice(src));
            }
            None => {
                scoped_map(items, |(dst, (_, src)): FillItem| dst.copy_from_slice(src));
            }
        }
    } else {
        for (dst, (_, src)) in slices.into_iter().zip(&fragments) {
            dst.copy_from_slice(src);
        }
    }
    AdjList { offsets, data }
}

/// Undirected view of a directed adjacency: used by tests/CPU symmetry.
pub fn is_symmetric(adj: &AdjList) -> bool {
    use std::collections::HashSet;
    let mut set = HashSet::with_capacity(adj.len());
    for b in 0..adj.n_boxes() {
        for &s in adj.sources(b) {
            set.insert((b as u32, s));
        }
    }
    set.iter().all(|&(b, s)| set.contains(&(s, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn build(n: usize, levels: usize, seed: u64) -> (Pyramid, Connectivity) {
        let mut r = Pcg64::seed_from_u64(seed);
        let (pts, gs) = workload::uniform_square(n, &mut r);
        let pyr = Pyramid::build(&pts, &gs, levels).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        (pyr, con)
    }

    #[test]
    fn every_pair_classified_exactly_once_per_level() {
        // For each box b at level l, the union weak(b) ∪ strong-descendants
        // must cover exactly the children of parent's strong list. We check
        // the complementary invariant: every same-level pair is either weak
        // at some ancestor level, or in exactly one of near/p2l/m2p at the
        // finest level — via potential contribution accounting in the fmm
        // integration tests. Here: no box pair is both weak and near.
        let (pyr, con) = build(2000, 3, 1);
        let l = pyr.levels;
        for b in 0..pyr.n_leaves() {
            let weak: std::collections::HashSet<u32> =
                con.weak[l].sources(b).iter().copied().collect();
            for &s in con.near.sources(b) {
                assert!(!weak.contains(&s), "box {b}: {s} both weak and near");
            }
            for &s in con.p2l.sources(b) {
                assert!(!weak.contains(&s), "box {b}: {s} both weak and p2l");
            }
        }
    }

    #[test]
    fn weak_pairs_satisfy_theta_criterion() {
        let (pyr, con) = build(3000, 3, 2);
        for l in 1..=pyr.levels {
            for b in 0..boxes_at_level(l) {
                for &s in con.weak[l].sources(b) {
                    let (ra, rb_) = (
                        pyr.rects[l][b].radius(),
                        pyr.rects[l][s as usize].radius(),
                    );
                    let d =
                        (pyr.rects[l][b].center() - pyr.rects[l][s as usize].center()).abs();
                    assert!(
                        theta_criterion(ra, rb_, d, 0.5),
                        "level {l}: weak pair ({b},{s}) not well separated"
                    );
                }
            }
        }
    }

    #[test]
    fn near_field_contains_self_and_is_symmetric() {
        let (pyr, con) = build(1500, 3, 3);
        for b in 0..pyr.n_leaves() {
            assert!(
                con.near.sources(b).contains(&(b as u32)),
                "box {b} missing itself"
            );
        }
        assert!(is_symmetric(&con.near), "P2P near field must be symmetric");
    }

    #[test]
    fn p2l_m2p_are_duals() {
        // (dst, src) ∈ p2l  ⟺  (src, dst) ∈ m2p: the larger box's particles
        // go into the smaller's local expansion, and symmetrically the
        // smaller's multipole is evaluated in the larger.
        let mut r = Pcg64::seed_from_u64(4);
        let (pts, gs) = workload::normal_cloud(4000, 0.1, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 4).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        let mut p2l_pairs: Vec<(u32, u32)> = Vec::new();
        for b in 0..pyr.n_leaves() {
            for &s in con.p2l.sources(b) {
                p2l_pairs.push((b as u32, s));
            }
        }
        let mut m2p_pairs: Vec<(u32, u32)> = Vec::new();
        for b in 0..pyr.n_leaves() {
            for &s in con.m2p.sources(b) {
                m2p_pairs.push((s, b as u32)); // (smaller, larger) orientation
            }
        }
        p2l_pairs.sort_unstable();
        m2p_pairs.sort_unstable();
        assert_eq!(p2l_pairs, m2p_pairs);
        // non-uniform clouds actually exercise the shortcut
        // (uniform meshes rarely do)
        assert!(
            !p2l_pairs.is_empty(),
            "normal cloud at 4 levels should produce P2L pairs"
        );
    }

    #[test]
    fn theta_tightness_tradeoffs() {
        // Smaller θ ⇒ well-separation is harder ⇒ more pairs stay strongly
        // coupled: the near field (P2P) grows, and fewer pairs are weak at
        // the coarse levels (work is pushed down the tree — the total weak
        // count may well *increase*).
        let mut r = Pcg64::seed_from_u64(5);
        let (pts, gs) = workload::uniform_square(2000, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
        let loose = Connectivity::build(&pyr, 0.8);
        let tight = Connectivity::build(&pyr, 0.3);
        assert!(
            tight.total_near() > loose.total_near(),
            "near θ=0.3: {} !> θ=0.8: {}",
            tight.total_near(),
            loose.total_near()
        );
        assert!(
            loose.weak[1].len() >= tight.weak[1].len(),
            "level-1 weak θ=0.8: {} !>= θ=0.3: {}",
            loose.weak[1].len(),
            tight.weak[1].len()
        );
    }

    #[test]
    fn uniform_mesh_interaction_list_sizes_reasonable() {
        // For θ=1/2 on a uniform mesh the M2L list of an interior box is
        // bounded (paper §2 estimates ~π((1+θ)/θ)² ≈ 28 for θ=1/2; with the
        // 2-level parent-strong recursion the practical bound is ~40–60).
        let (pyr, con) = build(4096 * 45 / 16, 3, 6);
        let l = pyr.levels;
        let max_deg = con.weak[l].max_degree();
        assert!(max_deg >= 8, "suspiciously few weak pairs: {max_deg}");
        assert!(max_deg <= 80, "weak lists exploded: {max_deg}");
        // near field of an interior box on a uniform mesh: ≤ ~a dozen
        assert!(con.near.max_degree() <= 24, "{}", con.near.max_degree());
    }

    #[test]
    fn threaded_build_is_byte_identical_to_serial() {
        let mut r = Pcg64::seed_from_u64(8);
        let (pts, gs) = workload::normal_cloud(3000, 0.08, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
        let serial = Connectivity::build(&pyr, 0.5);
        for nt in [2usize, 3, 7, 1000] {
            let par = Connectivity::build_threaded(&pyr, 0.5, nt);
            assert_eq!(serial.checks, par.checks, "t={nt}");
            for l in 0..=pyr.levels {
                assert_eq!(serial.weak[l].offsets, par.weak[l].offsets, "t={nt} l={l}");
                assert_eq!(serial.weak[l].data, par.weak[l].data, "t={nt} l={l}");
            }
            for (name, a, b) in [
                ("near", &serial.near, &par.near),
                ("p2l", &serial.p2l, &par.p2l),
                ("m2p", &serial.m2p, &par.m2p),
            ] {
                assert_eq!(a.offsets, b.offsets, "t={nt} {name}");
                assert_eq!(a.data, b.data, "t={nt} {name}");
            }
        }
    }

    #[test]
    fn pool_build_is_byte_identical_to_serial() {
        let mut r = Pcg64::seed_from_u64(9);
        let (pts, gs) = workload::normal_cloud(2000, 0.1, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
        let serial = Connectivity::build(&pyr, 0.5);
        let pool = crate::util::pool::WorkerPool::new(3, false);
        let pooled = Connectivity::build_on_pool(&pyr, 0.5, 3, &pool);
        assert_eq!(serial.checks, pooled.checks);
        for l in 0..=pyr.levels {
            assert_eq!(serial.weak[l].offsets, pooled.weak[l].offsets);
            assert_eq!(serial.weak[l].data, pooled.weak[l].data);
        }
        assert_eq!(serial.near.data, pooled.near.data);
        assert_eq!(serial.p2l.data, pooled.p2l.data);
        assert_eq!(serial.m2p.data, pooled.m2p.data);
    }

    #[test]
    fn checks_counter_counts_work() {
        let (_, con) = build(1000, 2, 7);
        // at least 4 children × 1 parent-strong × 16 level-1 boxes
        assert!(con.checks >= 16 * 4);
    }
}
