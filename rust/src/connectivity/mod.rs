//! Connectivity of the FMM mesh — the "connecting" half of the topological
//! phase (paper §2, §3.2, §4.3).
//!
//! For every level the boxes are classified pairwise as *weakly* coupled
//! (well separated under the θ-criterion ⇒ M2L interaction) or *strongly*
//! coupled (deferred to the children; at the finest level resolved by P2P,
//! or by the one-sided P2L/M2P shortcuts when the r↔R-interchanged
//! criterion admits them).
//!
//! Lists are **directed** (an entry per *destination* box), the layout the
//! paper chooses for its GPU code (§4.3: twice the memory, no write
//! conflicts); the serial CPU driver exploits symmetry by visiting only
//! ordered pairs (the paper's one-directional CPU lists, §4.3).
//!
//! Storage is CSR-style (offset + data arrays) per level: the connectivity
//! of large trees is in the tens of millions of entries, and `Vec<Vec<_>>`
//! overhead dominated profile traces in early versions (see EXPERIMENTS.md
//! §Perf).

use crate::geometry::{theta_criterion, theta_criterion_interchanged, Rect};
use crate::tree::{boxes_at_level, first_child_of, Pyramid};

/// Directed adjacency for one interaction kind at one level, CSR layout:
/// sources of destination box `b` are `data[offsets[b]..offsets[b+1]]`.
#[derive(Clone, Debug, Default)]
pub struct AdjList {
    pub offsets: Vec<u32>,
    pub data: Vec<u32>,
}

impl AdjList {
    pub fn with_boxes(nb: usize) -> Self {
        AdjList {
            offsets: vec![0; nb + 1],
            data: Vec::new(),
        }
    }

    #[inline]
    pub fn n_boxes(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn sources(&self, b: usize) -> &[u32] {
        &self.data[self.offsets[b] as usize..self.offsets[b + 1] as usize]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Largest in-degree (the padding width of the static packing).
    pub fn max_degree(&self) -> usize {
        (0..self.n_boxes())
            .map(|b| self.sources(b).len())
            .max()
            .unwrap_or(0)
    }

}

/// Full connectivity of a pyramid.
#[derive(Clone, Debug)]
pub struct Connectivity {
    /// θ used to build the lists.
    pub theta: f64,
    /// Weak (M2L) lists per level `1..=L` (index 0 is the — always empty —
    /// root level, kept so `weak[l]` aligns with `pyramid.rects[l]`).
    pub weak: Vec<AdjList>,
    /// Strong lists at the finest level after P2L/M2P extraction: the P2P
    /// near field. Directed; contains the box itself.
    pub near: AdjList,
    /// Finest-level P2L shortcuts: `p2l.sources(b)` are boxes whose
    /// *particles* are absorbed into `b`'s local expansion.
    pub p2l: AdjList,
    /// Finest-level M2P shortcuts: `m2p.sources(b)` are boxes whose
    /// *multipole expansion* is evaluated directly at `b`'s points.
    pub m2p: AdjList,
    /// Pairwise θ-criterion evaluations performed (GPU cost model input).
    pub checks: usize,
}

#[inline]
fn well_separated(a: &Rect, b: &Rect, theta: f64) -> bool {
    let d = (a.center() - b.center()).abs();
    theta_criterion(a.radius(), b.radius(), d, theta)
}

impl Connectivity {
    /// Classify all levels of `pyr` under the θ-criterion.
    ///
    /// Per level `l`, the candidate sources of box `b` are exactly the
    /// children of the strong list of `b`'s parent (§2) — the recursion
    /// starts from the root being strongly coupled to itself.
    pub fn build(pyr: &Pyramid, theta: f64) -> Self {
        let levels = pyr.levels;
        let mut checks = 0usize;

        let mut weak: Vec<AdjList> = Vec::with_capacity(levels + 1);
        weak.push(AdjList::with_boxes(1)); // root level: no weak pairs

        // strong lists of the previous level; root strongly coupled to itself
        let mut strong_prev = AdjList {
            offsets: vec![0, 1],
            data: vec![0],
        };

        for l in 1..=levels {
            let nb = boxes_at_level(l);
            let rects = &pyr.rects[l];
            let mut weak_l = AdjList {
                offsets: Vec::with_capacity(nb + 1),
                data: Vec::new(),
            };
            weak_l.offsets.push(0);
            let mut strong_l = AdjList {
                offsets: Vec::with_capacity(nb + 1),
                data: Vec::new(),
            };
            strong_l.offsets.push(0);

            for b in 0..nb {
                let parent = b >> 2;
                for &sp in strong_prev.sources(parent) {
                    let c0 = first_child_of(sp as usize);
                    for c in c0..c0 + 4 {
                        checks += 1;
                        if well_separated(&rects[b], &rects[c], theta) {
                            weak_l.data.push(c as u32);
                        } else {
                            strong_l.data.push(c as u32);
                        }
                    }
                }
                weak_l.offsets.push(weak_l.data.len() as u32);
                strong_l.offsets.push(strong_l.data.len() as u32);
            }
            weak.push(weak_l);
            strong_prev = strong_l;
        }

        // Finest level: split the remaining strong pairs into near-field
        // (P2P) and the interchanged-criterion shortcuts (P2L / M2P).
        let nb = boxes_at_level(levels);
        let rects = &pyr.rects[levels];
        let mut near = AdjList::with_boxes(0);
        let mut p2l = AdjList::with_boxes(0);
        let mut m2p = AdjList::with_boxes(0);
        near.offsets = vec![0];
        p2l.offsets = vec![0];
        m2p.offsets = vec![0];
        for b in 0..nb {
            for &s in strong_prev.sources(b) {
                let su = s as usize;
                if su == b {
                    near.data.push(s);
                    continue;
                }
                let (rb, rs) = (rects[b].radius(), rects[su].radius());
                let d = (rects[b].center() - rects[su].center()).abs();
                checks += 1;
                if theta_criterion_interchanged(rb, rs, d, theta) {
                    // one-sided expansions are admissible for this pair
                    if rs > rb {
                        // source box is the larger: its particles reach b
                        // only through b's local expansion
                        p2l.data.push(s);
                    } else if rs < rb {
                        // source box is the smaller: its multipole is valid
                        // on all of b
                        m2p.data.push(s);
                    } else {
                        // equal radii: interchanged == plain criterion,
                        // which failed ⇒ unreachable, keep P2P for safety
                        near.data.push(s);
                    }
                } else {
                    near.data.push(s);
                }
            }
            near.offsets.push(near.data.len() as u32);
            p2l.offsets.push(p2l.data.len() as u32);
            m2p.offsets.push(m2p.data.len() as u32);
        }

        Connectivity {
            theta,
            weak,
            near,
            p2l,
            m2p,
            checks,
        }
    }

    /// Total M2L interactions across all levels.
    pub fn total_weak(&self) -> usize {
        self.weak.iter().map(|w| w.len()).sum()
    }

    /// Total near-field (P2P) box pairs, self included.
    pub fn total_near(&self) -> usize {
        self.near.len()
    }
}

/// Undirected view of a directed adjacency: used by tests/CPU symmetry.
pub fn is_symmetric(adj: &AdjList) -> bool {
    use std::collections::HashSet;
    let mut set = HashSet::with_capacity(adj.len());
    for b in 0..adj.n_boxes() {
        for &s in adj.sources(b) {
            set.insert((b as u32, s));
        }
    }
    set.iter().all(|&(b, s)| set.contains(&(s, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn build(n: usize, levels: usize, seed: u64) -> (Pyramid, Connectivity) {
        let mut r = Pcg64::seed_from_u64(seed);
        let (pts, gs) = workload::uniform_square(n, &mut r);
        let pyr = Pyramid::build(&pts, &gs, levels);
        let con = Connectivity::build(&pyr, 0.5);
        (pyr, con)
    }

    #[test]
    fn every_pair_classified_exactly_once_per_level() {
        // For each box b at level l, the union weak(b) ∪ strong-descendants
        // must cover exactly the children of parent's strong list. We check
        // the complementary invariant: every same-level pair is either weak
        // at some ancestor level, or in exactly one of near/p2l/m2p at the
        // finest level — via potential contribution accounting in the fmm
        // integration tests. Here: no box pair is both weak and near.
        let (pyr, con) = build(2000, 3, 1);
        let l = pyr.levels;
        for b in 0..pyr.n_leaves() {
            let weak: std::collections::HashSet<u32> =
                con.weak[l].sources(b).iter().copied().collect();
            for &s in con.near.sources(b) {
                assert!(!weak.contains(&s), "box {b}: {s} both weak and near");
            }
            for &s in con.p2l.sources(b) {
                assert!(!weak.contains(&s), "box {b}: {s} both weak and p2l");
            }
        }
    }

    #[test]
    fn weak_pairs_satisfy_theta_criterion() {
        let (pyr, con) = build(3000, 3, 2);
        for l in 1..=pyr.levels {
            for b in 0..boxes_at_level(l) {
                for &s in con.weak[l].sources(b) {
                    let (ra, rb_) = (
                        pyr.rects[l][b].radius(),
                        pyr.rects[l][s as usize].radius(),
                    );
                    let d =
                        (pyr.rects[l][b].center() - pyr.rects[l][s as usize].center()).abs();
                    assert!(
                        theta_criterion(ra, rb_, d, 0.5),
                        "level {l}: weak pair ({b},{s}) not well separated"
                    );
                }
            }
        }
    }

    #[test]
    fn near_field_contains_self_and_is_symmetric() {
        let (pyr, con) = build(1500, 3, 3);
        for b in 0..pyr.n_leaves() {
            assert!(
                con.near.sources(b).contains(&(b as u32)),
                "box {b} missing itself"
            );
        }
        assert!(is_symmetric(&con.near), "P2P near field must be symmetric");
    }

    #[test]
    fn p2l_m2p_are_duals() {
        // (dst, src) ∈ p2l  ⟺  (src, dst) ∈ m2p: the larger box's particles
        // go into the smaller's local expansion, and symmetrically the
        // smaller's multipole is evaluated in the larger.
        let mut r = Pcg64::seed_from_u64(4);
        let (pts, gs) = workload::normal_cloud(4000, 0.1, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 4);
        let con = Connectivity::build(&pyr, 0.5);
        let mut p2l_pairs: Vec<(u32, u32)> = Vec::new();
        for b in 0..pyr.n_leaves() {
            for &s in con.p2l.sources(b) {
                p2l_pairs.push((b as u32, s));
            }
        }
        let mut m2p_pairs: Vec<(u32, u32)> = Vec::new();
        for b in 0..pyr.n_leaves() {
            for &s in con.m2p.sources(b) {
                m2p_pairs.push((s, b as u32)); // (smaller, larger) orientation
            }
        }
        p2l_pairs.sort_unstable();
        m2p_pairs.sort_unstable();
        assert_eq!(p2l_pairs, m2p_pairs);
        // non-uniform clouds actually exercise the shortcut
        // (uniform meshes rarely do)
        assert!(
            !p2l_pairs.is_empty(),
            "normal cloud at 4 levels should produce P2L pairs"
        );
    }

    #[test]
    fn theta_tightness_tradeoffs() {
        // Smaller θ ⇒ well-separation is harder ⇒ more pairs stay strongly
        // coupled: the near field (P2P) grows, and fewer pairs are weak at
        // the coarse levels (work is pushed down the tree — the total weak
        // count may well *increase*).
        let mut r = Pcg64::seed_from_u64(5);
        let (pts, gs) = workload::uniform_square(2000, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3);
        let loose = Connectivity::build(&pyr, 0.8);
        let tight = Connectivity::build(&pyr, 0.3);
        assert!(
            tight.total_near() > loose.total_near(),
            "near θ=0.3: {} !> θ=0.8: {}",
            tight.total_near(),
            loose.total_near()
        );
        assert!(
            loose.weak[1].len() >= tight.weak[1].len(),
            "level-1 weak θ=0.8: {} !>= θ=0.3: {}",
            loose.weak[1].len(),
            tight.weak[1].len()
        );
    }

    #[test]
    fn uniform_mesh_interaction_list_sizes_reasonable() {
        // For θ=1/2 on a uniform mesh the M2L list of an interior box is
        // bounded (paper §2 estimates ~π((1+θ)/θ)² ≈ 28 for θ=1/2; with the
        // 2-level parent-strong recursion the practical bound is ~40–60).
        let (pyr, con) = build(4096 * 45 / 16, 3, 6);
        let l = pyr.levels;
        let max_deg = con.weak[l].max_degree();
        assert!(max_deg >= 8, "suspiciously few weak pairs: {max_deg}");
        assert!(max_deg <= 80, "weak lists exploded: {max_deg}");
        // near field of an interior box on a uniform mesh: ≤ ~a dozen
        assert!(con.near.max_degree() <= 24, "{}", con.near.max_degree());
    }

    #[test]
    fn checks_counter_counts_work() {
        let (_, con) = build(1000, 2, 7);
        // at least 4 children × 1 parent-strong × 16 level-1 boxes
        assert!(con.checks >= 16 * 4);
    }
}
