//! Execution of a planned batch: build trees, dispatch groups, unpack.
//!
//! The runner owns the whole request path of a batch evaluation. The
//! topological phase (Sort + Connect) stays on the CPU per problem — the
//! same substitution the paper itself makes to guarantee identical trees —
//! and everything downstream is dispatched **per group**: one pooled CPU
//! execution or one batched XLA invocation per
//! [`BatchGroup`](super::plan::BatchGroup), never one per problem.

use std::time::Instant;

use crate::complex::C64;
use crate::connectivity::Connectivity;
use crate::fmm::{self, FmmOptions, Phase, PhaseTimes, WorkCounts};
use crate::tree::Pyramid;
use crate::util::error::Result;

use super::plan::{BatchPlan, ProblemShape};

/// One FMM problem of a batch: source points plus strengths.
#[derive(Clone, Debug)]
pub struct BatchProblem {
    pub points: Vec<C64>,
    pub gammas: Vec<C64>,
}

/// Which backend executes the grouped dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchEngine {
    /// The serial reference driver, one problem after another (baseline).
    Serial,
    /// Batch-size-aware CPU dispatch: groups with at least as many members
    /// as workers stream through one shared scoped pool
    /// ([`fmm::parallel::evaluate_trees_pooled`]); smaller groups fall
    /// back to the per-problem multithreaded engine so a lone large
    /// problem still uses every core.
    Parallel,
    /// The XLA/PJRT runtime: one batched `run_raw` per group (needs the
    /// `pjrt` feature and artifacts compiled with a batch dimension).
    Xla,
}

/// Options of one batch run.
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// Per-problem FMM options (p, N_d, θ, kernel, threads).
    pub fmm: FmmOptions,
    pub engine: BatchEngine,
    /// Maximum problems per dispatch group (`0` = unbounded; the CLI's
    /// `--batch-size`).
    pub max_group: usize,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            fmm: FmmOptions::default(),
            engine: BatchEngine::Parallel,
            max_group: 0,
        }
    }
}

/// Aggregated accounting of one batch run.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    pub n_problems: usize,
    pub n_groups: usize,
    /// Execution dispatches issued (one per group).
    pub dispatches: usize,
    /// Wall-clock per phase summed across all problems.
    pub times: PhaseTimes,
    /// Wall-clock of the whole batch run (build + dispatch + unpack).
    pub wall_s: f64,
    /// XLA engine only: aggregated runtime timings (zero on CPU engines).
    pub upload_s: f64,
    pub execute_s: f64,
    pub download_s: f64,
}

/// Result of one batch run.
#[derive(Clone, Debug)]
pub struct BatchOutput {
    /// Per problem, the potential at every input point in the caller's
    /// original order — `potentials[i]` always answers `problems[i]`.
    pub potentials: Vec<Vec<C64>>,
    /// Work counts aggregated over the whole batch
    /// ([`WorkCounts::absorb`]).
    pub counts: WorkCounts,
    pub stats: BatchStats,
}

/// Evaluate a batch of problems in grouped, shape-compatible dispatches.
///
/// Per-problem potentials match sequential per-problem runs to ≤ 1e-12
/// relative error on the CPU engines (`tests/batch_parity.rs`); the XLA
/// engine's padded reduction order deviates up to ~1e-9.
pub fn run(problems: &[BatchProblem], opts: &BatchOptions) -> Result<BatchOutput> {
    if cfg!(not(feature = "pjrt")) && opts.engine == BatchEngine::Xla {
        crate::bail!(
            "BatchEngine::Xla needs the PJRT runtime, which is disabled in \
             this build; rebuild with `cargo build --release --features pjrt`"
        );
    }
    let wall = Instant::now();
    let mut stats = BatchStats {
        n_problems: problems.len(),
        ..Default::default()
    };
    let mut potentials: Vec<Vec<C64>> = vec![Vec::new(); problems.len()];
    let mut counts = WorkCounts::default();
    let mut times_per_problem: Vec<PhaseTimes> = vec![PhaseTimes::default(); problems.len()];

    // ---- topological phase, per problem (kept on the CPU — the paper's
    // own substitution for guaranteeing identical trees) ----------------
    let mut trees: Vec<(Pyramid, Connectivity)> = Vec::with_capacity(problems.len());
    for (i, pr) in problems.iter().enumerate() {
        let levels = opts.fmm.cfg.levels_for(pr.points.len());
        let t = Instant::now();
        let pyr = Pyramid::build(&pr.points, &pr.gammas, levels);
        times_per_problem[i].0[Phase::Sort as usize] = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let con = Connectivity::build(&pyr, opts.fmm.cfg.theta);
        times_per_problem[i].0[Phase::Connect as usize] = t.elapsed().as_secs_f64();
        trees.push((pyr, con));
    }

    // ---- plan: group by compatible artifact shape ----------------------
    let shapes: Vec<ProblemShape> = trees
        .iter()
        .map(|(pyr, _)| ProblemShape {
            levels: pyr.levels,
            p: opts.fmm.cfg.p,
            nmax: pyr.max_leaf_len(),
        })
        .collect();
    let plan = BatchPlan::group(&shapes, opts.max_group);
    stats.n_groups = plan.n_groups();

    // ---- dispatch: one execution per group -----------------------------
    match opts.engine {
        BatchEngine::Serial | BatchEngine::Parallel => {
            for group in &plan.groups {
                let members: Vec<(&Pyramid, &Connectivity)> = group
                    .members
                    .iter()
                    .map(|&i| (&trees[i].0, &trees[i].1))
                    .collect();
                let results = dispatch_cpu(&members, opts);
                stats.dispatches += 1;
                for (&i, (phi_leaf, t, c)) in group.members.iter().zip(results) {
                    potentials[i] = trees[i].0.unpermute(&phi_leaf);
                    times_per_problem[i].add(&t);
                    counts.absorb(&c);
                }
            }
        }
        BatchEngine::Xla => {
            run_xla(&trees, &plan, &mut potentials, &mut counts, &mut stats)?
        }
    }

    for t in &times_per_problem {
        stats.times.add(t);
    }
    stats.wall_s = wall.elapsed().as_secs_f64();
    Ok(BatchOutput {
        potentials,
        counts,
        stats,
    })
}

/// CPU dispatch of one group (see [`BatchEngine`] for the selection rule).
fn dispatch_cpu(
    members: &[(&Pyramid, &Connectivity)],
    opts: &BatchOptions,
) -> Vec<(Vec<C64>, PhaseTimes, WorkCounts)> {
    match opts.engine {
        BatchEngine::Serial => members
            .iter()
            .map(|&(pyr, con)| fmm::evaluate_on_tree_serial(pyr, con, &opts.fmm))
            .collect(),
        BatchEngine::Parallel => {
            let nt = opts.fmm.effective_threads();
            if members.len() >= nt.max(2) {
                fmm::parallel::evaluate_trees_pooled(members, &opts.fmm, nt)
            } else {
                members
                    .iter()
                    .map(|&(pyr, con)| fmm::evaluate_on_tree(pyr, con, &opts.fmm))
                    .collect()
            }
        }
        BatchEngine::Xla => unreachable!("XLA dispatch is handled by run_xla"),
    }
}

/// XLA dispatch of the whole batch: one compiled artifact and one batched
/// `run_raw` per group. Phase times cannot be instrumented inside the
/// artifact, so per-problem counts come from [`fmm::structural_counts`]
/// and timing lands in the upload/execute/download stats.
#[cfg(feature = "pjrt")]
fn run_xla(
    trees: &[(Pyramid, Connectivity)],
    plan: &BatchPlan,
    potentials: &mut [Vec<C64>],
    counts: &mut WorkCounts,
    stats: &mut BatchStats,
) -> Result<()> {
    let mut rt = crate::runtime::Runtime::new(None)?;
    for group in &plan.groups {
        let members: Vec<(&Pyramid, &Connectivity)> = group
            .members
            .iter()
            .map(|&i| (&trees[i].0, &trees[i].1))
            .collect();
        let exe = rt.fmm_artifact_for_group(&members)?;
        let (pots, rs) = exe.run_fmm_group(&members)?;
        stats.dispatches += 1;
        stats.upload_s += rs.upload_s;
        stats.execute_s += rs.execute_s;
        stats.download_s += rs.download_s;
        for (&i, phi) in group.members.iter().zip(pots) {
            potentials[i] = phi;
            counts.absorb(&fmm::structural_counts(&trees[i].0, &trees[i].1, exe.meta.p));
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_xla(
    _trees: &[(Pyramid, Connectivity)],
    _plan: &BatchPlan,
    _potentials: &mut [Vec<C64>],
    _counts: &mut WorkCounts,
    _stats: &mut BatchStats,
) -> Result<()> {
    crate::bail!(
        "BatchEngine::Xla needs the PJRT runtime, which is disabled in this \
         build; rebuild with `cargo build --release --features pjrt`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FmmConfig;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn problems_of(sizes: &[usize], seed: u64) -> Vec<BatchProblem> {
        let mut r = Pcg64::seed_from_u64(seed);
        sizes
            .iter()
            .map(|&n| {
                let (points, gammas) = workload::uniform_square(n, &mut r);
                BatchProblem { points, gammas }
            })
            .collect()
    }

    fn opts_with(engine: BatchEngine, max_group: usize) -> BatchOptions {
        BatchOptions {
            fmm: FmmOptions {
                cfg: FmmConfig {
                    p: 10,
                    ..FmmConfig::default()
                },
                threads: Some(2),
                ..FmmOptions::default()
            },
            engine,
            max_group,
        }
    }

    #[test]
    fn heterogeneous_sizes_form_multiple_groups() {
        // N_d = 45 ⇒ Eq. (5.2) gives 2 levels for the small sizes and 3
        // for the large ones: two shape classes, two groups
        let problems = problems_of(&[600, 2200, 700, 2400], 1);
        let out = run(&problems, &opts_with(BatchEngine::Parallel, 0)).unwrap();
        assert_eq!(out.stats.n_problems, 4);
        assert_eq!(out.stats.n_groups, 2);
        assert_eq!(out.stats.dispatches, 2);
        assert_eq!(out.counts.n, 600 + 2200 + 700 + 2400);
        for (pr, phi) in problems.iter().zip(&out.potentials) {
            assert_eq!(pr.points.len(), phi.len());
        }
    }

    #[test]
    fn max_group_bounds_dispatch_width() {
        let problems = problems_of(&[600, 650, 700, 750, 800], 2);
        let out = run(&problems, &opts_with(BatchEngine::Serial, 2)).unwrap();
        // one shape class of 5, split 2+2+1
        assert_eq!(out.stats.n_groups, 3);
        assert_eq!(out.stats.dispatches, 3);
    }

    #[test]
    fn empty_batch_is_fine() {
        let out = run(&[], &opts_with(BatchEngine::Parallel, 0)).unwrap();
        assert_eq!(out.stats.n_problems, 0);
        assert_eq!(out.stats.dispatches, 0);
        assert!(out.potentials.is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn xla_engine_explains_missing_feature() {
        let problems = problems_of(&[600], 3);
        let err = run(&problems, &opts_with(BatchEngine::Xla, 0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }
}
