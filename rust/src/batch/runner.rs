//! Execution of a planned batch: build trees, dispatch groups, unpack.
//!
//! The runner owns the whole request path of a batch evaluation. The
//! topological phase (Sort + Connect) stays on the CPU per problem — the
//! same substitution the paper itself makes to guarantee identical trees —
//! and everything downstream is dispatched **per group**: one pooled CPU
//! execution or one batched XLA invocation per
//! [`BatchGroup`](super::plan::BatchGroup), never one per problem.
//!
//! On the pooled CPU engine the prologue is **overlapped**
//! ([`BatchOptions::overlap`], the default): the plan is computed up front
//! — grouping only needs `(levels, p)`, and `levels` is a pure function of
//! the point count (Eq. 5.2) — and a small pool of *producer* workers
//! builds each problem's topology ([`crate::topology::build`]) in dispatch
//! order while the group runner executes the computational phases of the
//! groups whose trees are already complete. The per-problem results are
//! unchanged (same trees, same reduction order); only the wall-clock
//! interleaving differs.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use crate::complex::C64;
use crate::connectivity::Connectivity;
use crate::dispatch::{self, DispatchReport, Dispatcher, Engine, EngineChoice};
use crate::fmm::{self, FmmOptions, Phase, PhaseTimes, WorkCounts};
use crate::topology::{self, TopologyOptions};
use crate::tree::Pyramid;
use crate::util::error::Result;
use crate::util::pool::{note_spawn, WorkerPool};
use crate::util::sched::Graph;

use super::plan::{BatchGroup, BatchPlan, ProblemShape};

/// One FMM problem of a batch: source points plus strengths.
#[derive(Clone, Debug)]
pub struct BatchProblem {
    pub points: Vec<C64>,
    pub gammas: Vec<C64>,
}

/// Which backend executes the grouped dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchEngine {
    /// The serial reference driver, one problem after another (baseline).
    Serial,
    /// Batch-size-aware CPU dispatch on the shared persistent worker pool:
    /// groups with at least as many members as workers stream through one
    /// problem-claiming dispatch
    /// ([`fmm::parallel::evaluate_trees_on_pool`]); smaller groups fall
    /// back to the per-problem pooled engine so a lone large problem still
    /// uses every core. Either way, the batch spawns no threads per group.
    Parallel,
    /// The task-graph scheduler ([`crate::util::sched`]): the whole batch
    /// becomes one dependency graph — a topology node feeding a compute
    /// node per problem — run as a single dispatch on the persistent
    /// pool, so problem *i*'s computational phase overlaps problem *j*'s
    /// topology build with zero producer threads (the generalized form of
    /// the overlapped prologue). Per-problem results are identical to the
    /// serial baseline (independent problems, serial driver per compute
    /// task). Narrow groups on the sequential fallback run the
    /// per-problem task-graph engine.
    TaskGraph,
    /// The XLA/PJRT runtime: one batched `run_raw` per group (needs the
    /// `pjrt` feature and artifacts compiled with a batch dimension).
    Xla,
    /// Resolve the engine **per group** from the calibrated dispatch cost
    /// model ([`crate::dispatch`]): small groups stay on the CPU
    /// (serial or pooled), large padded groups go to the batched XLA path
    /// when the build can run it. Uses [`BatchOptions::dispatcher`] (or
    /// the default profile location) and records every decision with its
    /// predicted and measured time in [`BatchOutput::report`].
    Auto,
}

impl From<Engine> for BatchEngine {
    /// The CLI `--engine` selector maps one-to-one onto batch engines —
    /// the single parsing/mapping point shared by `run` and `batch`.
    fn from(e: Engine) -> BatchEngine {
        match e {
            Engine::Serial => BatchEngine::Serial,
            Engine::Parallel => BatchEngine::Parallel,
            Engine::TaskGraph => BatchEngine::TaskGraph,
            Engine::Xla => BatchEngine::Xla,
            Engine::Auto => BatchEngine::Auto,
        }
    }
}

/// Options of one batch run.
#[derive(Clone, Debug)]
pub struct BatchOptions {
    /// Per-problem FMM options (p, N_d, θ, kernel, threads).
    pub fmm: FmmOptions,
    pub engine: BatchEngine,
    /// Maximum problems per dispatch group (`0` = unbounded; the CLI's
    /// `--batch-size`).
    pub max_group: usize,
    /// Overlap the topology prologue with group execution on the
    /// [`BatchEngine::Parallel`] path (default `true`; the CLI's
    /// `--no-overlap` disables it for A/B timing). The `Serial` engine
    /// always runs the fully sequential prologue — it is the baseline.
    /// [`BatchEngine::Auto`] overlaps only when every group resolved to
    /// the pooled engine.
    pub overlap: bool,
    /// The dispatcher resolving [`BatchEngine::Auto`] groups. `None` (the
    /// default) loads the default profile location, falling back to the
    /// built-in rates ([`Dispatcher::load_or_default`]); ignored by the
    /// explicit engines.
    pub dispatcher: Option<std::sync::Arc<Dispatcher>>,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            fmm: FmmOptions::default(),
            engine: BatchEngine::Parallel,
            max_group: 0,
            overlap: true,
            dispatcher: None,
        }
    }
}

/// Aggregated accounting of one batch run.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    pub n_problems: usize,
    pub n_groups: usize,
    /// Execution dispatches issued (one per group).
    pub dispatches: usize,
    /// Wall-clock per phase summed across all problems.
    pub times: PhaseTimes,
    /// Wall-clock of the whole batch run (build + dispatch + unpack).
    pub wall_s: f64,
    /// XLA engine only: aggregated runtime timings (zero on CPU engines).
    pub upload_s: f64,
    pub execute_s: f64,
    pub download_s: f64,
}

/// Result of one batch run.
#[derive(Clone, Debug)]
pub struct BatchOutput {
    /// Per problem, the potential at every input point in the caller's
    /// original order — `potentials[i]` always answers `problems[i]`.
    pub potentials: Vec<Vec<C64>>,
    /// Work counts aggregated over the whole batch
    /// ([`WorkCounts::absorb`]).
    pub counts: WorkCounts,
    pub stats: BatchStats,
    /// Per-group dispatch decisions (choice, predicted vs measured time);
    /// `Some` iff the batch ran with [`BatchEngine::Auto`].
    pub report: Option<DispatchReport>,
}

/// Evaluate a batch of problems in grouped, shape-compatible dispatches.
///
/// Per-problem potentials match sequential per-problem runs to ≤ 1e-12
/// relative error on the CPU engines (`tests/batch_parity.rs`); the XLA
/// engine's padded reduction order deviates up to ~1e-9.
pub fn run(problems: &[BatchProblem], opts: &BatchOptions) -> Result<BatchOutput> {
    if cfg!(not(feature = "pjrt")) && opts.engine == BatchEngine::Xla {
        crate::bail!(
            "BatchEngine::Xla needs the PJRT runtime, which is disabled in \
             this build; rebuild with `cargo build --release --features pjrt`"
        );
    }
    let wall = Instant::now();
    let mut stats = BatchStats {
        n_problems: problems.len(),
        ..Default::default()
    };
    let mut potentials: Vec<Vec<C64>> = vec![Vec::new(); problems.len()];
    let mut counts = WorkCounts::default();
    let mut times_per_problem: Vec<PhaseTimes> = vec![PhaseTimes::default(); problems.len()];

    // ---- plan first: grouping only needs (levels, p), and `levels` is a
    // pure function of the point count (Eq. 5.2) — so the plan exists
    // before any tree does, which is what lets the prologue overlap group
    // execution. (Group `nmax` pads are refined from the actual trees at
    // dispatch time; the planner is given 0.)
    let shapes: Vec<ProblemShape> = problems
        .iter()
        .map(|pr| ProblemShape {
            levels: opts.fmm.cfg.levels_for(pr.points.len()),
            p: opts.fmm.cfg.p,
            nmax: 0,
        })
        .collect();
    let plan = BatchPlan::group(&shapes, opts.max_group);
    stats.n_groups = plan.n_groups();

    // ---- engine resolution: explicit engines apply to every group; Auto
    // asks the dispatcher per group (see `resolve_engines`)
    let (group_engines, mut report) = resolve_engines(problems, &plan, opts);
    let mut group_measured = vec![0.0f64; plan.n_groups()];

    // One persistent pool serves the whole batch — every group dispatch
    // (and, on the sequential prologue, every topology build) fans out on
    // it, so the batch performs no per-group thread spawns. A fully
    // single-threaded configuration never touches (or lazily builds) it.
    let wants_pool = group_engines
        .iter()
        .any(|e| matches!(e, BatchEngine::Parallel | BatchEngine::TaskGraph))
        && opts
            .fmm
            .effective_threads()
            .max(opts.fmm.effective_topo_threads())
            > 1;
    let pool = wants_pool.then(|| opts.fmm.shared_pool());

    // ---- topological phase + dispatch ---------------------------------
    let all_taskgraph = !group_engines.is_empty()
        && group_engines.iter().all(|e| *e == BatchEngine::TaskGraph);
    let all_parallel = !group_engines.is_empty()
        && group_engines.iter().all(|e| *e == BatchEngine::Parallel);
    let graph_pool = (all_taskgraph && opts.overlap && problems.len() > 1)
        .then(|| pool.as_deref())
        .flatten();
    if let Some(graph_pool) = graph_pool {
        run_taskgraph(
            problems,
            &plan,
            opts,
            graph_pool,
            &mut potentials,
            &mut counts,
            &mut stats,
            &mut times_per_problem,
        )?;
    } else if all_parallel && opts.overlap && problems.len() > 1 {
        run_overlapped(
            problems,
            &plan,
            opts,
            pool.as_deref(),
            &mut potentials,
            &mut counts,
            &mut stats,
            &mut times_per_problem,
            &mut group_measured,
        )?;
    } else {
        // sequential prologue (the PR-2 shape): every topology is built —
        // each with the full per-problem topology engine — before the
        // first dispatch
        let mut trees: Vec<(Pyramid, Connectivity)> = Vec::with_capacity(problems.len());
        for (i, pr) in problems.iter().enumerate() {
            let (tree, t) =
                build_problem_topology(pr, &opts.fmm, topo_threads_for(opts), pool.clone())?;
            times_per_problem[i] = t;
            trees.push(tree);
        }
        let mut xla_groups: Vec<(usize, &BatchGroup)> = Vec::new();
        for (gi, group) in plan.groups.iter().enumerate() {
            let engine = group_engines[gi];
            if engine == BatchEngine::Xla {
                xla_groups.push((gi, group));
                continue;
            }
            let members: Vec<(&Pyramid, &Connectivity)> = group
                .members
                .iter()
                .map(|&i| (&trees[i].0, &trees[i].1))
                .collect();
            let t0 = Instant::now();
            let sp = crate::obs::span("batch", "compute").arg("members", members.len() as f64);
            let results = dispatch_cpu(&members, opts, pool.as_deref(), engine);
            drop(sp);
            group_measured[gi] = t0.elapsed().as_secs_f64();
            stats.dispatches += 1;
            for (&i, (phi_leaf, t, c)) in group.members.iter().zip(results) {
                potentials[i] = trees[i].0.unpermute(&phi_leaf);
                times_per_problem[i].add(&t);
                counts.absorb(&c);
            }
        }
        if !xla_groups.is_empty() {
            run_xla(
                &trees,
                &xla_groups,
                &mut potentials,
                &mut counts,
                &mut stats,
                &mut group_measured,
            )?;
        }
    }

    if let Some(r) = &mut report {
        for (d, m) in r.decisions.iter_mut().zip(&group_measured) {
            d.measured_s = Some(*m);
            d.record_drift();
        }
    }
    for t in &times_per_problem {
        stats.times.add(t);
    }
    stats.wall_s = wall.elapsed().as_secs_f64();
    Ok(BatchOutput {
        potentials,
        counts,
        stats,
        report,
    })
}

/// Resolve the engine of every group: explicit engines broadcast;
/// [`BatchEngine::Auto`] consults the dispatcher per group (the pooled
/// candidate capped at the configured thread budget) and collects the
/// decisions into a [`DispatchReport`].
fn resolve_engines(
    problems: &[BatchProblem],
    plan: &BatchPlan,
    opts: &BatchOptions,
) -> (Vec<BatchEngine>, Option<DispatchReport>) {
    if opts.engine != BatchEngine::Auto {
        return (vec![opts.engine; plan.n_groups()], None);
    }
    let dispatcher = opts
        .dispatcher
        .clone()
        .unwrap_or_else(|| std::sync::Arc::new(Dispatcher::load_or_default(None)));
    let cap = Some(opts.fmm.effective_threads());
    let mut engines = Vec::with_capacity(plan.n_groups());
    let mut decisions = Vec::with_capacity(plan.n_groups());
    for group in &plan.groups {
        let members: Vec<dispatch::Problem> = group
            .members
            .iter()
            .map(|&i| {
                dispatch::Problem::new(
                    problems[i].points.len(),
                    group.key.levels,
                    group.key.p,
                    opts.fmm.cfg.theta,
                )
            })
            .collect();
        let decision = dispatcher.select_group_capped(&members, cap);
        engines.push(match decision.choice {
            EngineChoice::Serial => BatchEngine::Serial,
            EngineChoice::Pooled { .. } => BatchEngine::Parallel,
            EngineChoice::TaskGraph { .. } => BatchEngine::TaskGraph,
            EngineChoice::Xla => BatchEngine::Xla,
        });
        decisions.push(decision);
    }
    (engines, Some(DispatchReport { decisions }))
}

/// Topology workers per problem on the sequential-prologue path: the
/// serial batch engine keeps the fully serial baseline; the others follow
/// the per-problem FMM options.
fn topo_threads_for(opts: &BatchOptions) -> usize {
    match opts.engine {
        BatchEngine::Serial => 1,
        _ => opts.fmm.effective_topo_threads(),
    }
}

/// Build one problem's topology and return it with the Sort/Connect
/// wall-clock recorded in the problem's [`PhaseTimes`] slots. With a
/// `pool`, the parallel build fans out on it (spawn-free); the overlapped
/// prologue's producers pass `None` — they run concurrently with group
/// compute and must not contend for the compute pool.
fn build_problem_topology(
    pr: &BatchProblem,
    fmm_opts: &FmmOptions,
    threads: usize,
    pool: Option<std::sync::Arc<WorkerPool>>,
) -> Result<((Pyramid, Connectivity), PhaseTimes)> {
    let levels = fmm_opts.cfg.levels_for(pr.points.len());
    let mut topo_opts = TopologyOptions::parallel(fmm_opts.cfg.theta, threads);
    topo_opts.pool = pool;
    let _sp = crate::obs::span("batch", "prologue").arg("n", pr.points.len() as f64);
    let topo = topology::build(&pr.points, &pr.gammas, levels, &topo_opts)?;
    let mut t = PhaseTimes::default();
    t.0[Phase::Sort as usize] = topo.sort_s;
    t.0[Phase::Connect as usize] = topo.connect_s;
    Ok(((topo.pyramid, topo.connectivity), t))
}

/// The task-graph batch path: the whole batch as **one dependency graph**
/// on the persistent pool — per problem, a topology node feeding a
/// compute node — so problem *i*'s computational phase overlaps problem
/// *j*'s topology build through the same dependency-gated ready queue the
/// single-problem task-graph engine uses, with zero producer threads
/// (contrast [`run_overlapped`]'s scoped spawns). Problems are
/// independent, the topology build is the bit-identical serial engine and
/// each compute task is the serial driver, so per-problem results are
/// bitwise-identical to the sequential baseline under any schedule.
///
/// Memory: the graph does not throttle producers, so worst-case residency
/// matches the sequential prologue (every tree at once); each problem's
/// tree is dropped as soon as its compute task finishes.
#[allow(clippy::too_many_arguments)]
fn run_taskgraph(
    problems: &[BatchProblem],
    plan: &BatchPlan,
    opts: &BatchOptions,
    pool: &WorkerPool,
    potentials: &mut [Vec<C64>],
    counts: &mut WorkCounts,
    stats: &mut BatchStats,
    times_per_problem: &mut [PhaseTimes],
) -> Result<()> {
    type Built = ((Pyramid, Connectivity), PhaseTimes);
    type Out = (Vec<C64>, PhaseTimes, WorkCounts);
    let built: Vec<Mutex<Option<Result<Built>>>> =
        (0..problems.len()).map(|_| Mutex::new(None)).collect();
    let done: Vec<Mutex<Option<Out>>> = (0..problems.len()).map(|_| Mutex::new(None)).collect();
    // dispatch order: group by group, as the other prologues build
    let order: Vec<usize> = plan
        .groups
        .iter()
        .flat_map(|g| g.members.iter().copied())
        .collect();
    let nt = opts
        .fmm
        .effective_threads()
        .min(pool.n_workers())
        .max(1);
    {
        let (built, done, fmm_opts) = (&built, &done, &opts.fmm);
        let mut g = Graph::new();
        for &i in &order {
            let topo = g.node(&[]);
            g.add_task(topo, move |_ws| {
                // serial per-problem build: topology parallelism would
                // only contend with the compute tasks this build overlaps
                let b = build_problem_topology(&problems[i], fmm_opts, 1, None);
                *built[i].lock().unwrap() = Some(b);
            });
            let compute = g.node(&[topo]);
            g.add_task(compute, move |_ws| {
                let b = built[i].lock().unwrap().take();
                match b {
                    Some(Ok((tree, topo_t))) => {
                        let _sp = crate::obs::span("batch", "compute").arg("members", 1.0);
                        let (phi, t, c) = fmm::evaluate_on_tree_serial(&tree.0, &tree.1, fmm_opts);
                        let mut times = topo_t;
                        times.add(&t);
                        *done[i].lock().unwrap() = Some((tree.0.unpermute(&phi), times, c));
                    }
                    // park the error for collection after the run
                    Some(Err(e)) => *built[i].lock().unwrap() = Some(Err(e)),
                    None => {}
                }
            });
        }
        g.run(pool, nt, None);
    }
    stats.dispatches += 1;
    for i in 0..problems.len() {
        if let Some(Err(e)) = built[i].lock().unwrap().take() {
            return Err(e);
        }
        match done[i].lock().unwrap().take() {
            Some((phi, t, c)) => {
                potentials[i] = phi;
                times_per_problem[i] = t;
                counts.absorb(&c);
            }
            None => crate::bail!("task-graph batch produced no result for problem {i}"),
        }
    }
    Ok(())
}

/// The overlapped prologue of the pooled CPU path: producer workers claim
/// problems off an atomic queue *in dispatch order* and build their
/// topologies — the worker budget splits across producers, so a long
/// batch of small problems builds one per producer while a short batch of
/// large ones gets the parallel topology engine per problem — feeding the
/// group runner through a bounded channel. The consumer dispatches each
/// group as soon as its members' trees are complete, so group `g`'s
/// computational phase overlaps group `g+1`'s topology construction.
///
/// Memory: every dispatched group's trees are dropped before the next
/// group starts, and the bounded channel throttles producers whenever the
/// consumer is busy *computing* — the common steady state, where peak
/// residency is the current group plus the read-ahead window. While the
/// consumer is instead blocked waiting on one slow tree it must keep
/// draining the channel (the producer building that tree could otherwise
/// deadlock on a full channel), so the worst case — one pathologically
/// slow member early in a huge batch — degrades toward the sequential
/// prologue's residency (every tree at once), never beyond it.
#[allow(clippy::too_many_arguments)]
fn run_overlapped(
    problems: &[BatchProblem],
    plan: &BatchPlan,
    opts: &BatchOptions,
    pool: Option<&WorkerPool>,
    potentials: &mut [Vec<C64>],
    counts: &mut WorkCounts,
    stats: &mut BatchStats,
    times_per_problem: &mut [PhaseTimes],
    group_measured: &mut [f64],
) -> Result<()> {
    type Built = ((Pyramid, Connectivity), PhaseTimes);

    let order: Vec<usize> = plan
        .groups
        .iter()
        .flat_map(|g| g.members.iter().copied())
        .collect();
    // split the topology worker budget (--topo-threads, defaulting to
    // --threads) across producers: many small problems get one builder
    // each; a short batch of large problems gets few producers that each
    // run the parallel topology engine, so neither end regresses vs the
    // sequential prologue
    let topo_budget = opts.fmm.effective_topo_threads();
    let producers = topo_budget.clamp(1, order.len().max(1));
    let threads_per_problem = (topo_budget / producers).max(1);
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    // bounded: producers block once they are 2×producers trees ahead of
    // the consumer, which also bounds peak memory on huge batches
    let (tx, rx) = mpsc::sync_channel::<(usize, Result<Built>)>(2 * producers);
    let mut trees: Vec<Option<(Pyramid, Connectivity)>> =
        (0..problems.len()).map(|_| None).collect();
    let mut first_err = None;

    // xtask: allow(no-spawn) — the overlapped prologue's producer threads
    // are the one sanctioned spawn site outside the pools (they overlap
    // topology builds with pool-side evaluation; see tests/zero_spawn.rs)
    std::thread::scope(|s| {
        for _ in 0..producers {
            let tx = tx.clone();
            let (next, stop, order, fmm_opts) = (&next, &stop, &order, &opts.fmm);
            note_spawn();
            s.spawn(move || loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= order.len() {
                    break;
                }
                let i = order[k];
                // producers build without the pool: they overlap the
                // consumer's group compute, which owns the pool's workers
                let built =
                    build_problem_topology(&problems[i], fmm_opts, threads_per_problem, None);
                if tx.send((i, built)).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        'groups: for (gi, group) in plan.groups.iter().enumerate() {
            // wait for this group's trees; later groups keep building
            for &i in &group.members {
                while trees[i].is_none() {
                    match rx.recv() {
                        Ok((j, Ok((tree, t)))) => {
                            times_per_problem[j] = t;
                            trees[j] = Some(tree);
                        }
                        Ok((_, Err(e))) => {
                            stop.store(true, Ordering::Relaxed);
                            first_err = Some(e);
                            break 'groups;
                        }
                        Err(_) => {
                            // every sender gone without delivering `i` —
                            // defensive only: a producer *panic* re-raises
                            // from thread::scope at scope exit (the caller
                            // sees the panic, not this Err), so this arm
                            // guards against queue/ordering bugs, not a
                            // user-visible failure mode
                            first_err =
                                Some(crate::anyhow!("topology producers exited early"));
                            break 'groups;
                        }
                    }
                }
            }
            let members: Vec<(&Pyramid, &Connectivity)> = group
                .members
                .iter()
                .map(|&i| {
                    let (pyr, con) = trees[i].as_ref().expect("tree built above");
                    (pyr, con)
                })
                .collect();
            let t0 = Instant::now();
            let sp = crate::obs::span("batch", "compute").arg("members", members.len() as f64);
            let results = dispatch_cpu(&members, opts, pool, BatchEngine::Parallel);
            drop(sp);
            group_measured[gi] = t0.elapsed().as_secs_f64();
            stats.dispatches += 1;
            for (&i, (phi_leaf, t, c)) in group.members.iter().zip(results) {
                let (pyr, _) = trees[i].as_ref().expect("tree built above");
                potentials[i] = pyr.unpermute(&phi_leaf);
                times_per_problem[i].add(&t);
                counts.absorb(&c);
            }
            // the group is answered: free its trees before the next one
            for &i in &group.members {
                trees[i] = None;
            }
        }
        // blocking drain: unblocks any producer waiting on the bounded
        // channel (each then observes `stop`, or the exhausted queue, and
        // exits, dropping its sender); returns once all senders are gone
        for _ in rx.iter() {}
    });

    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// CPU dispatch of one group (see [`BatchEngine`] for the selection rule).
/// On the `Parallel` engine every fan-out runs on the shared persistent
/// `pool` — wide groups as one problem-claiming dispatch
/// ([`fmm::parallel::evaluate_trees_on_pool`]), narrow ones through the
/// per-problem pooled engine — so a batch performs no per-group spawns.
fn dispatch_cpu(
    members: &[(&Pyramid, &Connectivity)],
    opts: &BatchOptions,
    pool: Option<&WorkerPool>,
    engine: BatchEngine,
) -> Vec<(Vec<C64>, PhaseTimes, WorkCounts)> {
    match engine {
        BatchEngine::Serial => members
            .iter()
            .map(|&(pyr, con)| fmm::evaluate_on_tree_serial(pyr, con, &opts.fmm))
            .collect(),
        BatchEngine::Parallel | BatchEngine::TaskGraph => {
            let nt = opts.fmm.effective_threads();
            if members.len() >= nt.max(2) {
                // wide groups stream through the problem-claiming dispatch
                // on both engines — it is already barrier-free per problem
                match pool {
                    // nt == 1 degenerates to the serial loop inside the
                    // scoped variant — no fan-out at all
                    Some(p) if nt > 1 => {
                        fmm::parallel::evaluate_trees_on_pool(members, &opts.fmm, p)
                    }
                    _ => fmm::parallel::evaluate_trees_pooled(members, &opts.fmm, nt),
                }
            } else {
                let fmm_opts = FmmOptions {
                    cpu_engine: match engine {
                        BatchEngine::TaskGraph => fmm::CpuEngine::TaskGraph,
                        _ => opts.fmm.cpu_engine,
                    },
                    ..opts.fmm.clone()
                };
                members
                    .iter()
                    .map(|&(pyr, con)| fmm::evaluate_on_tree(pyr, con, &fmm_opts))
                    .collect()
            }
        }
        BatchEngine::Xla | BatchEngine::Auto => {
            unreachable!("XLA groups go through run_xla; Auto resolves before dispatch")
        }
    }
}

/// XLA dispatch of the given groups: one compiled artifact and one
/// batched `run_raw` per group. Phase times cannot be instrumented inside
/// the artifact, so per-problem counts come from
/// [`fmm::structural_counts`] and timing lands in the
/// upload/execute/download stats (plus the per-group `group_measured`
/// wall-clock feeding the dispatch report).
#[cfg(feature = "pjrt")]
fn run_xla(
    trees: &[(Pyramid, Connectivity)],
    groups: &[(usize, &BatchGroup)],
    potentials: &mut [Vec<C64>],
    counts: &mut WorkCounts,
    stats: &mut BatchStats,
    group_measured: &mut [f64],
) -> Result<()> {
    let mut rt = crate::runtime::Runtime::new(None)?;
    for &(gi, group) in groups {
        let members: Vec<(&Pyramid, &Connectivity)> = group
            .members
            .iter()
            .map(|&i| (&trees[i].0, &trees[i].1))
            .collect();
        let t0 = Instant::now();
        let exe = rt.fmm_artifact_for_group(&members)?;
        let (pots, rs) = exe.run_fmm_group(&members)?;
        group_measured[gi] = t0.elapsed().as_secs_f64();
        stats.dispatches += 1;
        stats.upload_s += rs.upload_s;
        stats.execute_s += rs.execute_s;
        stats.download_s += rs.download_s;
        for (&i, phi) in group.members.iter().zip(pots) {
            potentials[i] = phi;
            counts.absorb(&fmm::structural_counts(&trees[i].0, &trees[i].1, exe.meta.p));
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_xla(
    _trees: &[(Pyramid, Connectivity)],
    _groups: &[(usize, &BatchGroup)],
    _potentials: &mut [Vec<C64>],
    _counts: &mut WorkCounts,
    _stats: &mut BatchStats,
    _group_measured: &mut [f64],
) -> Result<()> {
    crate::bail!(
        "BatchEngine::Xla needs the PJRT runtime, which is disabled in this \
         build; rebuild with `cargo build --release --features pjrt`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FmmConfig;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn problems_of(sizes: &[usize], seed: u64) -> Vec<BatchProblem> {
        let mut r = Pcg64::seed_from_u64(seed);
        sizes
            .iter()
            .map(|&n| {
                let (points, gammas) = workload::uniform_square(n, &mut r);
                BatchProblem { points, gammas }
            })
            .collect()
    }

    fn opts_with(engine: BatchEngine, max_group: usize) -> BatchOptions {
        BatchOptions {
            fmm: FmmOptions {
                cfg: FmmConfig {
                    p: 10,
                    ..FmmConfig::default()
                },
                threads: Some(2),
                ..FmmOptions::default()
            },
            engine,
            max_group,
            ..BatchOptions::default()
        }
    }

    #[test]
    fn heterogeneous_sizes_form_multiple_groups() {
        // N_d = 45 ⇒ Eq. (5.2) gives 2 levels for the small sizes and 3
        // for the large ones: two shape classes, two groups
        let problems = problems_of(&[600, 2200, 700, 2400], 1);
        let out = run(&problems, &opts_with(BatchEngine::Parallel, 0)).unwrap();
        assert_eq!(out.stats.n_problems, 4);
        assert_eq!(out.stats.n_groups, 2);
        assert_eq!(out.stats.dispatches, 2);
        assert_eq!(out.counts.n, 600 + 2200 + 700 + 2400);
        for (pr, phi) in problems.iter().zip(&out.potentials) {
            assert_eq!(pr.points.len(), phi.len());
        }
    }

    #[test]
    fn max_group_bounds_dispatch_width() {
        let problems = problems_of(&[600, 650, 700, 750, 800], 2);
        let out = run(&problems, &opts_with(BatchEngine::Serial, 2)).unwrap();
        // one shape class of 5, split 2+2+1
        assert_eq!(out.stats.n_groups, 3);
        assert_eq!(out.stats.dispatches, 3);
    }

    #[test]
    fn overlapped_and_sequential_prologues_agree() {
        let problems = problems_of(&[600, 2200, 700, 2400, 800], 7);
        let overlapped = run(&problems, &opts_with(BatchEngine::Parallel, 0)).unwrap();
        let sequential = run(
            &problems,
            &BatchOptions {
                overlap: false,
                ..opts_with(BatchEngine::Parallel, 0)
            },
        )
        .unwrap();
        assert_eq!(overlapped.stats.n_groups, sequential.stats.n_groups);
        assert_eq!(overlapped.stats.dispatches, sequential.stats.dispatches);
        assert_eq!(overlapped.counts.n, sequential.counts.n);
        assert_eq!(overlapped.counts.p2p_pairs, sequential.counts.p2p_pairs);
        for (a, b) in overlapped.potentials.iter().zip(&sequential.potentials) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                // identical trees + identical per-problem reduction order
                assert_eq!(x.re, y.re);
                assert_eq!(x.im, y.im);
            }
        }
    }

    #[test]
    fn taskgraph_batch_matches_serial_bitwise() {
        let problems = problems_of(&[600, 2200, 700, 2400], 5);
        let serial = run(&problems, &opts_with(BatchEngine::Serial, 0)).unwrap();
        let tg = run(&problems, &opts_with(BatchEngine::TaskGraph, 0)).unwrap();
        // the whole batch is one graph dispatch
        assert_eq!(tg.stats.dispatches, 1);
        assert_eq!(serial.counts.n, tg.counts.n);
        assert_eq!(serial.counts.p2p_pairs, tg.counts.p2p_pairs);
        for (a, b) in serial.potentials.iter().zip(&tg.potentials) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                // identical trees + serial driver per compute task
                assert_eq!(x.re, y.re);
                assert_eq!(x.im, y.im);
            }
        }
    }

    #[test]
    fn taskgraph_batch_surfaces_topology_errors() {
        let mut problems = problems_of(&[600, 650], 9);
        problems.push(BatchProblem {
            points: problems[0].points[..10].to_vec(),
            gammas: problems[0].gammas[..10].to_vec(),
        });
        let mut opts = opts_with(BatchEngine::TaskGraph, 0);
        opts.fmm.cfg.levels_override = Some(3);
        let err = run(&problems, &opts).unwrap_err().to_string();
        assert!(err.contains("fewer particles"), "got: {err}");
    }

    #[test]
    fn overlapped_prologue_surfaces_topology_errors() {
        // 10 points cannot fill a 3-level pyramid: the producer's error
        // must come back as a clean Result, not a panic or a hang
        let mut problems = problems_of(&[600, 650], 8);
        problems.push(BatchProblem {
            points: problems[0].points[..10].to_vec(),
            gammas: problems[0].gammas[..10].to_vec(),
        });
        let mut opts = opts_with(BatchEngine::Parallel, 0);
        opts.fmm.cfg.levels_override = Some(3);
        let err = run(&problems, &opts).unwrap_err().to_string();
        assert!(err.contains("fewer particles"), "got: {err}");
    }

    #[test]
    fn empty_batch_is_fine() {
        let out = run(&[], &opts_with(BatchEngine::Parallel, 0)).unwrap();
        assert_eq!(out.stats.n_problems, 0);
        assert_eq!(out.stats.dispatches, 0);
        assert!(out.potentials.is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn xla_engine_explains_missing_feature() {
        let problems = problems_of(&[600], 3);
        let err = run(&problems, &opts_with(BatchEngine::Xla, 0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }
}
