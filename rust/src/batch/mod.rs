//! Packed-tensor batch execution: evaluate many small FMM problems in
//! grouped, fixed-shape dispatches.
//!
//! The paper's asymmetric adaptive discretization keeps every tensor
//! *shape* a function of `(levels, p, pads)` alone — adaptivity lives in
//! the values, never the shapes ([`crate::packing`]). Batching exploits
//! exactly that property: problems whose shapes agree can share one
//! dispatch, with per-problem variation absorbed by the same `-1`-padded
//! gather lists and zero-masked particle slots that single-problem packing
//! already uses. Amortizing the per-dispatch overhead (kernel launches on
//! the GPU, thread spawns on the CPU) across many small problems is the
//! regime where the paper's GPU code wins, and what turns this engine
//! from a one-shot evaluator into a throughput server core.
//!
//! Three layers:
//!
//! * [`BatchPlan::group`] groups problems by [`ProblemShape`] — `(levels,
//!   p)` must agree exactly, `nmax` pads up to the widest member — and
//!   splits classes at the configured `--batch-size`;
//! * [`run`] plans, builds the trees through the unified topology layer
//!   ([`crate::topology`]), and dispatches every group through the
//!   selected [`BatchEngine`]: the pooled multithreaded CPU engine
//!   ([`crate::fmm::parallel::evaluate_trees_pooled`] — one scoped worker
//!   pool per group instead of per-problem spawn) or one batched XLA
//!   execution per group (`pjrt` feature). On the pooled engine the
//!   topology prologue **overlaps** group execution by default
//!   ([`BatchOptions::overlap`]): producer workers build the next group's
//!   trees while the current group computes, so the last serial stage of
//!   the batch path is off the critical path. [`BatchEngine::Auto`]
//!   resolves the engine **per group** from the calibrated dispatch cost
//!   model ([`crate::dispatch`]) and records every decision (predicted vs
//!   measured) in [`BatchOutput::report`](runner::BatchOutput::report);
//! * per-problem potentials come back in each caller's original particle
//!   order, with aggregated [`WorkCounts`](crate::fmm::WorkCounts) (for
//!   the GPU cost model's batched-dispatch accounting) and [`BatchStats`].
//!
//! Invariants: potentials of a batched run match sequential per-problem
//! runs to ≤ 1e-12 relative error on the CPU engines
//! (`tests/batch_parity.rs`; the XLA path reduces in padded fixed-shape
//! order and may deviate up to ~1e-9, the bound `runtime_e2e` and the
//! CLI `--check` hold it to); grouping never reorders results
//! (`potentials[i]` always answers problem `i`); each group is dispatched
//! exactly once.
//!
//! ```
//! use fmm2d::batch::{BatchPlan, ProblemShape};
//! // same (levels, p) ⇒ one shared dispatch, padded to the widest member
//! let shapes = [
//!     ProblemShape { levels: 2, p: 17, nmax: 40 },
//!     ProblemShape { levels: 3, p: 17, nmax: 52 },
//!     ProblemShape { levels: 2, p: 17, nmax: 47 },
//! ];
//! let plan = BatchPlan::group(&shapes, 0);
//! assert_eq!(plan.n_groups(), 2);
//! assert_eq!(plan.groups[0].members, vec![0, 2]);
//! assert_eq!(plan.groups[0].nmax, 47);
//! ```

pub mod plan;
pub mod runner;

pub use plan::{BatchGroup, BatchPlan, GroupKey, ProblemShape};
pub use runner::{run, BatchEngine, BatchOptions, BatchOutput, BatchProblem, BatchStats};
