//! Grouping of many FMM problems into shape-compatible dispatch groups.
//!
//! The planner never looks at particle data — only at [`ProblemShape`]s.
//! Two problems can share one fixed-shape dispatch iff their `(levels, p)`
//! agree: those two numbers fix every tensor shape of the packed ABI
//! (`4^L` leaves, `(4^{L+1}−1)/3` centers, the per-level list tables, the
//! `p+1` coefficient stride). The remaining per-problem variation — leaf
//! populations, list degrees — is absorbed by pads: the group's `nmax` is
//! the maximum over its members, and the `-1`-padded gather lists of
//! [`crate::packing`] make the extra slots inert.

use std::collections::BTreeMap;

/// Shape summary of one FMM problem — everything the planner needs to
/// decide dispatch compatibility, nothing about the actual particles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProblemShape {
    /// Refinement levels `L` of the problem's pyramid.
    pub levels: usize,
    /// Expansion order `p`.
    pub p: usize,
    /// Largest leaf population — the problem's minimum `nmax` pad. Does
    /// not affect grouping; [`crate::batch::run`] plans *before* any tree
    /// exists (passing 0 here) and derives real pads from the built trees
    /// at dispatch time, so only callers that plan from built trees carry
    /// a meaningful value.
    pub nmax: usize,
}

/// The part of a [`ProblemShape`] that must agree exactly for two problems
/// to share a dispatch (`nmax` merely pads up within a group).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct GroupKey {
    pub levels: usize,
    pub p: usize,
}

/// One dispatch group: problems that execute together in one fixed-shape
/// invocation.
#[derive(Clone, Debug)]
pub struct BatchGroup {
    pub key: GroupKey,
    /// Indices into the caller's problem list, in submission order.
    pub members: Vec<usize>,
    /// Leaf-capacity pad of the group: the maximum member `nmax` (0 when
    /// the shapes were planned before the trees existed — see
    /// [`ProblemShape::nmax`]; dispatch derives real pads from the trees).
    pub nmax: usize,
}

impl BatchGroup {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// The full grouping of a batch: every problem appears in exactly one
/// group; groups are ordered by key (levels, then p), members by
/// submission order.
#[derive(Clone, Debug, Default)]
pub struct BatchPlan {
    pub groups: Vec<BatchGroup>,
}

impl BatchPlan {
    /// Group problems by compatible artifact shape. `max_group` caps the
    /// members per group (`0` = unbounded): oversized shape classes are
    /// split into consecutive chunks, each of which dispatches separately.
    ///
    /// ```
    /// use fmm2d::batch::{BatchPlan, ProblemShape};
    /// let shapes = [
    ///     ProblemShape { levels: 2, p: 17, nmax: 40 },
    ///     ProblemShape { levels: 3, p: 17, nmax: 52 },
    ///     ProblemShape { levels: 2, p: 17, nmax: 47 },
    /// ];
    /// let plan = BatchPlan::group(&shapes, 0);
    /// assert_eq!(plan.n_groups(), 2);
    /// // same-shape problems share one dispatch, padded to the widest
    /// assert_eq!(plan.groups[0].members, vec![0, 2]);
    /// assert_eq!(plan.groups[0].nmax, 47);
    /// assert_eq!(plan.groups[1].members, vec![1]);
    /// ```
    pub fn group(shapes: &[ProblemShape], max_group: usize) -> BatchPlan {
        let mut by_key: BTreeMap<GroupKey, Vec<usize>> = BTreeMap::new();
        for (i, s) in shapes.iter().enumerate() {
            by_key
                .entry(GroupKey {
                    levels: s.levels,
                    p: s.p,
                })
                .or_default()
                .push(i);
        }
        let mut groups = Vec::new();
        for (key, members) in by_key {
            let cap = if max_group == 0 {
                members.len()
            } else {
                max_group
            };
            for chunk in members.chunks(cap.max(1)) {
                groups.push(BatchGroup {
                    key,
                    members: chunk.to_vec(),
                    nmax: chunk.iter().map(|&i| shapes[i].nmax).max().unwrap_or(0),
                });
            }
        }
        BatchPlan { groups }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn n_problems(&self) -> usize {
        self.groups.iter().map(|g| g.members.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(levels: usize, p: usize, nmax: usize) -> ProblemShape {
        ProblemShape { levels, p, nmax }
    }

    #[test]
    fn groups_cover_every_problem_once() {
        let shapes = [
            shape(2, 17, 40),
            shape(3, 17, 50),
            shape(2, 17, 45),
            shape(2, 10, 45),
            shape(3, 17, 48),
        ];
        let plan = BatchPlan::group(&shapes, 0);
        assert_eq!(plan.n_problems(), shapes.len());
        let mut seen = vec![false; shapes.len()];
        for g in &plan.groups {
            for &i in &g.members {
                assert!(!seen[i], "problem {i} appears twice");
                seen[i] = true;
                assert_eq!(shapes[i].levels, g.key.levels);
                assert_eq!(shapes[i].p, g.key.p);
                assert!(shapes[i].nmax <= g.nmax, "member wider than group pad");
            }
        }
        assert!(seen.iter().all(|&s| s));
        // (levels=2,p=10), (levels=2,p=17), (levels=3,p=17)
        assert_eq!(plan.n_groups(), 3);
    }

    #[test]
    fn max_group_splits_oversized_classes() {
        let shapes = vec![shape(2, 17, 40); 5];
        let plan = BatchPlan::group(&shapes, 2);
        assert_eq!(plan.n_groups(), 3); // 2 + 2 + 1
        assert_eq!(plan.n_problems(), 5);
        assert_eq!(plan.groups[0].members, vec![0, 1]);
        assert_eq!(plan.groups[2].members, vec![4]);
    }

    #[test]
    fn empty_input_empty_plan() {
        let plan = BatchPlan::group(&[], 4);
        assert_eq!(plan.n_groups(), 0);
        assert_eq!(plan.n_problems(), 0);
    }

    #[test]
    fn group_pad_is_max_member_nmax() {
        let shapes = [shape(2, 8, 31), shape(2, 8, 64), shape(2, 8, 12)];
        let plan = BatchPlan::group(&shapes, 0);
        assert_eq!(plan.n_groups(), 1);
        assert_eq!(plan.groups[0].nmax, 64);
        assert_eq!(plan.groups[0].len(), 3);
    }
}
