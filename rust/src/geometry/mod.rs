//! Planar geometry for the multipole mesh: axis-aligned boxes, radii,
//! eccentricity and the θ-criterion (paper Eq. 2.1).

use crate::complex::C64;

/// Split axis of a box (the pyramid alternates by eccentricity, §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    X,
    Y,
}

/// An axis-aligned rectangle in the plane.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rect {
    pub x0: f64,
    pub y0: f64,
    pub x1: f64,
    pub y1: f64,
}

impl Rect {
    pub fn new(x0: f64, y0: f64, x1: f64, y1: f64) -> Self {
        debug_assert!(x1 >= x0 && y1 >= y0, "degenerate rect");
        Self { x0, y0, x1, y1 }
    }

    /// Unit square `[0,1]²` — the domain of all paper experiments.
    pub fn unit() -> Self {
        Self::new(0.0, 0.0, 1.0, 1.0)
    }

    /// Bounding box of a point set (degenerate boxes allowed).
    pub fn bounding(points: &[C64]) -> Self {
        let mut r = Rect {
            x0: f64::INFINITY,
            y0: f64::INFINITY,
            x1: f64::NEG_INFINITY,
            y1: f64::NEG_INFINITY,
        };
        for p in points {
            r.x0 = r.x0.min(p.re);
            r.x1 = r.x1.max(p.re);
            r.y0 = r.y0.min(p.im);
            r.y1 = r.y1.max(p.im);
        }
        r
    }

    #[inline]
    pub fn width(&self) -> f64 {
        self.x1 - self.x0
    }

    #[inline]
    pub fn height(&self) -> f64 {
        self.y1 - self.y0
    }

    /// Center of the box = expansion center `z0` in Eqs. (2.2)–(2.3).
    #[inline]
    pub fn center(&self) -> C64 {
        C64::new(0.5 * (self.x0 + self.x1), 0.5 * (self.y0 + self.y1))
    }

    /// Box radius: half-diagonal, the `r` of the θ-criterion. Every point of
    /// the box lies within `radius()` of `center()`, with equality at corners.
    #[inline]
    pub fn radius(&self) -> f64 {
        0.5 * (self.width() * self.width() + self.height() * self.height()).sqrt()
    }

    /// Split direction guided by eccentricity (§2: "the direction of the
    /// split is guided by the eccentricity of the box", aiming at
    /// width ≈ height since the θ-criterion is rotationally invariant).
    #[inline]
    pub fn split_axis(&self) -> Axis {
        if self.width() >= self.height() {
            Axis::X
        } else {
            Axis::Y
        }
    }

    /// Cut the rectangle at coordinate `c` along `axis`, returning
    /// (low side, high side).
    pub fn split_at(&self, axis: Axis, c: f64) -> (Rect, Rect) {
        match axis {
            Axis::X => (
                Rect::new(self.x0, self.y0, c, self.y1),
                Rect::new(c, self.y0, self.x1, self.y1),
            ),
            Axis::Y => (
                Rect::new(self.x0, self.y0, self.x1, c),
                Rect::new(self.x0, c, self.x1, self.y1),
            ),
        }
    }

    #[inline]
    pub fn contains(&self, p: C64) -> bool {
        p.re >= self.x0 && p.re <= self.x1 && p.im >= self.y0 && p.im <= self.y1
    }

    /// Eccentricity `max(w,h)/min(w,h)` (∞ for degenerate boxes).
    pub fn eccentricity(&self) -> f64 {
        let (w, h) = (self.width(), self.height());
        let (lo, hi) = if w < h { (w, h) } else { (h, w) };
        if lo == 0.0 {
            f64::INFINITY
        } else {
            hi / lo
        }
    }
}

/// The θ-criterion, Eq. (2.1): boxes with radii `r1`, `r2` at center
/// distance `d` are *well separated* iff `R + θ·r ≤ θ·d` where
/// `R = max(r1,r2)`, `r = min(r1,r2)`.
///
/// Guarantees a geometric error decay `~θ^p` for a p-term expansion of the
/// larger box evaluated inside the smaller (see [7] in the paper).
#[inline]
pub fn theta_criterion(r1: f64, r2: f64, d: f64, theta: f64) -> bool {
    let (big, small) = if r1 >= r2 { (r1, r2) } else { (r2, r1) };
    big + theta * small <= theta * d
}

/// The r↔R-interchanged test used at the finest level (§2, noted already in
/// Carrier–Greengard–Rokhlin): `r + θ·R ≤ θ·d`. When true for a strongly
/// coupled pair, the *smaller* box's multipole can be evaluated directly in
/// the larger (M2P) and the larger box's particles shifted into the
/// smaller's local expansion (P2L).
#[inline]
pub fn theta_criterion_interchanged(r1: f64, r2: f64, d: f64, theta: f64) -> bool {
    let (big, small) = if r1 >= r2 { (r1, r2) } else { (r2, r1) };
    small + theta * big <= theta * d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_basics() {
        let r = Rect::new(0.0, 0.0, 2.0, 1.0);
        assert_eq!(r.width(), 2.0);
        assert_eq!(r.height(), 1.0);
        assert_eq!(r.center(), C64::new(1.0, 0.5));
        assert!((r.radius() - 0.5 * 5.0f64.sqrt()).abs() < 1e-15);
        assert_eq!(r.split_axis(), Axis::X);
        assert!((r.eccentricity() - 2.0).abs() < 1e-15);
    }

    #[test]
    fn split_covers_parent() {
        let r = Rect::unit();
        let (a, b) = r.split_at(Axis::Y, 0.3);
        assert_eq!(a.y1, 0.3);
        assert_eq!(b.y0, 0.3);
        assert_eq!(a.x1, 1.0);
        assert!(a.contains(C64::new(0.5, 0.1)));
        assert!(b.contains(C64::new(0.5, 0.9)));
    }

    #[test]
    fn bounding_box() {
        let pts = [C64::new(0.1, 0.7), C64::new(0.9, 0.2), C64::new(0.4, 0.4)];
        let r = Rect::bounding(&pts);
        assert_eq!((r.x0, r.y0, r.x1, r.y1), (0.1, 0.2, 0.9, 0.7));
    }

    #[test]
    fn theta_criterion_basic() {
        // equal radii: need d >= r(1+θ)/θ = 3r for θ=1/2
        let th = 0.5;
        assert!(theta_criterion(1.0, 1.0, 3.0, th));
        assert!(!theta_criterion(1.0, 1.0, 2.999, th));
        // asymmetric: R=2, r=1 -> need d >= (2 + 0.5)/0.5 = 5
        assert!(theta_criterion(2.0, 1.0, 5.0, th));
        assert!(!theta_criterion(2.0, 1.0, 4.999, th));
        // symmetric in arguments
        assert_eq!(
            theta_criterion(2.0, 1.0, 4.5, th),
            theta_criterion(1.0, 2.0, 4.5, th)
        );
    }

    #[test]
    fn interchanged_is_weaker_for_unequal_radii() {
        let th = 0.5;
        // R=2, r=1: interchanged needs d >= (1 + 0.5*2)/0.5 = 4 < 5
        assert!(theta_criterion_interchanged(2.0, 1.0, 4.0, th));
        assert!(!theta_criterion(2.0, 1.0, 4.0, th));
        // equal radii: both reduce to the same test
        assert_eq!(
            theta_criterion(1.0, 1.0, 2.9, th),
            theta_criterion_interchanged(1.0, 1.0, 2.9, th)
        );
    }

    #[test]
    fn split_axis_squares_up_boxes() {
        let tall = Rect::new(0.0, 0.0, 1.0, 3.0);
        assert_eq!(tall.split_axis(), Axis::Y);
        let wide = Rect::new(0.0, 0.0, 3.0, 1.0);
        assert_eq!(wide.split_axis(), Axis::X);
    }
}
