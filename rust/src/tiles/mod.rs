//! SoA leaf tiles and the harmonic P2P micro-kernels.
//!
//! The near-field hot loops used to stream particles out of flat
//! `xs/ys/gre/gim` arrays indexed by the pyramid's leaf ranges — SoA, but
//! with box boundaries at arbitrary offsets, so every box pair paid a
//! remainder loop and the vectorizer saw ragged trip counts. This module
//! mirrors the leaf particles once per evaluation into **padded tiles**:
//!
//! ```text
//!        slot   0    1    2    ... len[b]-1 | len[b] ...  nmax-1
//!  xs[b*nmax+·] x_0  x_1  x_2  ...  x_last  | 1e200  ...  1e200   (PAD_POS)
//!  ys[b*nmax+·] y_0  y_1  y_2  ...  y_last  | 1e200  ...  1e200
//! gre[b*nmax+·] Γre  Γre  Γre  ...   Γre    |  0.0   ...   0.0
//! gim[b*nmax+·] Γim  Γim  Γim  ...   Γim    |  0.0   ...   0.0
//! ```
//!
//! where `nmax` is the maximum leaf population rounded up to a multiple of
//! [`LANE`]. Every leaf starts at a lane-aligned offset and the padded
//! slots are arithmetic no-ops for the harmonic kernel: with the sentinel
//! position `dx² + dy²` overflows to `+∞`, the reciprocal collapses to
//! `±0.0`, and the zero pad strengths multiply it away — so
//! destination-side accumulations may run over the full padded width with
//! no tail and no branch. (Padded slots must never be used for
//! *scattered* source-side writes; the symmetric kernel therefore bounds
//! its source loop to the true length and takes the scalar tail instead.)
//!
//! The micro-kernels ([`accum_harmonic`], [`accum_scatter_harmonic`],
//! [`accum_harmonic_guarded`]) share one loop shape: [`LANE`]-wide blocks
//! with **split re/im accumulator lanes** (element `j` lands in lane
//! `(j − j0) mod LANE`), an FMA (`mul_add`) reciprocal-free inner body,
//! a scalar tail continuing the lane pattern, and a **fixed-order lane
//! reduction** `(a0 + a1) + (a2 + a3)` at the end. The lane decomposition
//! is part of the kernel's contract — `tests/kernel_tiles.rs` pins it
//! bitwise against a scalar model, which certifies the loop shape the
//! vectorizer sees and keeps every engine (serial, scoped, pooled,
//! task-graph) bitwise-reproducible on the same shards (DESIGN.md §10).

use std::ops::Range;

use crate::complex::C64;
use crate::tree::Pyramid;

/// Lane width of the blocked micro-kernels (f64x4 — one AVX2 register).
pub const LANE: usize = 4;

/// Sentinel position of padded slots: large enough that `dx² + dy²`
/// overflows to `+∞` against any real coordinate (so the reciprocal is an
/// exact `±0.0`), finite so `dx` itself stays a number (`∞ − x = ∞` would
/// still work, but `∞ · 0` would not).
pub const PAD_POS: f64 = 1e200;

/// Leaf particles mirrored into padded SoA tiles, built once per
/// evaluation alongside the pyramid and shared read-only by every engine.
/// Leaf `b` owns slots `b·nmax .. (b+1)·nmax`; slot `s < len[b]`
/// holds the particle with global (leaf-ordered) index
/// `pyramid.starts[b] + s`.
#[derive(Clone, Debug)]
pub struct LeafTiles {
    /// Tile width: max leaf population rounded up to a [`LANE`] multiple.
    pub nmax: usize,
    /// True population of each leaf (`starts[b+1] − starts[b]`).
    pub len: Vec<usize>,
    /// Padded positions, real part.
    pub xs: Vec<f64>,
    /// Padded positions, imaginary part.
    pub ys: Vec<f64>,
    /// Padded strengths, real part (zero in padded slots).
    pub gre: Vec<f64>,
    /// Padded strengths, imaginary part (zero in padded slots).
    pub gim: Vec<f64>,
}

impl LeafTiles {
    /// Mirror the pyramid's (already leaf-sorted) particles into tiles.
    pub fn build(pyr: &Pyramid) -> Self {
        let nl = pyr.n_leaves();
        let nmax = round_up_lane(pyr.max_leaf_len());
        let mut xs = vec![PAD_POS; nl * nmax];
        let mut ys = vec![PAD_POS; nl * nmax];
        let mut gre = vec![0.0; nl * nmax];
        let mut gim = vec![0.0; nl * nmax];
        let mut len = Vec::with_capacity(nl);
        for b in 0..nl {
            let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
            len.push(hi - lo);
            let base = b * nmax;
            for (s, q) in pyr.particles[lo..hi].iter().enumerate() {
                xs[base + s] = q.pos.re;
                ys[base + s] = q.pos.im;
                gre[base + s] = q.gamma.re;
                gim[base + s] = q.gamma.im;
            }
        }
        Self {
            nmax,
            len,
            xs,
            ys,
            gre,
            gim,
        }
    }

    /// Number of leaf tiles.
    #[inline]
    pub fn n_leaves(&self) -> usize {
        self.len.len()
    }

    /// Slot range of leaf `b` in the flat arrays.
    #[inline]
    pub fn tile(&self, b: usize) -> Range<usize> {
        b * self.nmax..(b + 1) * self.nmax
    }
}

/// One padded SoA tile over an arbitrary point set — the [`crate::direct`]
/// baselines' counterpart of [`LeafTiles`] (a single tile holding the whole
/// input, same padding contract).
#[derive(Clone, Debug)]
pub struct PackedPoints {
    /// True point count; slots `n..padded()` hold [`PAD_POS`]/zero.
    pub n: usize,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub gre: Vec<f64>,
    pub gim: Vec<f64>,
}

impl PackedPoints {
    pub fn pack(points: &[C64], gammas: &[C64]) -> Self {
        let n = points.len();
        let padded = round_up_lane(n);
        let mut xs = vec![PAD_POS; padded];
        let mut ys = vec![PAD_POS; padded];
        let mut gre = vec![0.0; padded];
        let mut gim = vec![0.0; padded];
        for i in 0..n {
            xs[i] = points[i].re;
            ys[i] = points[i].im;
            gre[i] = gammas[i].re;
            gim[i] = gammas[i].im;
        }
        Self { n, xs, ys, gre, gim }
    }

    /// Padded width (a [`LANE`] multiple, `≥ n`).
    #[inline]
    pub fn padded(&self) -> usize {
        self.xs.len()
    }
}

/// Round `n` up to the next [`LANE`] multiple.
#[inline]
pub fn round_up_lane(n: usize) -> usize {
    n.div_ceil(LANE) * LANE
}

/// Destination-side harmonic accumulation over source slots `j0..j1`:
/// returns `Σ_j Γ_j / (z_j − z_i)` as split `(re, im)`. Safe over padded
/// slots (exact no-ops, see the module docs). Blocked [`LANE`]-wide with
/// split accumulator lanes, FMA bodies and a fixed-order lane reduction —
/// the lane semantics `tests/kernel_tiles.rs` pins bitwise.
#[inline]
pub fn accum_harmonic(
    xs: &[f64],
    ys: &[f64],
    gre: &[f64],
    gim: &[f64],
    j0: usize,
    j1: usize,
    xi: f64,
    yi: f64,
) -> (f64, f64) {
    let mut ar = [0.0f64; LANE];
    let mut ai = [0.0f64; LANE];
    let mut j = j0;
    while j + LANE <= j1 {
        for k in 0..LANE {
            let dx = xs[j + k] - xi;
            let dy = ys[j + k] - yi;
            let inv = 1.0 / dx.mul_add(dx, dy * dy);
            let rr = dx * inv;
            let ri = -(dy * inv);
            ar[k] = gre[j + k].mul_add(rr, ar[k]);
            ar[k] = (-gim[j + k]).mul_add(ri, ar[k]);
            ai[k] = gre[j + k].mul_add(ri, ai[k]);
            ai[k] = gim[j + k].mul_add(rr, ai[k]);
        }
        j += LANE;
    }
    // scalar tail, continuing the lane pattern (element j → lane (j−j0)%LANE)
    let mut k = 0;
    while j < j1 {
        let dx = xs[j] - xi;
        let dy = ys[j] - yi;
        let inv = 1.0 / dx.mul_add(dx, dy * dy);
        let rr = dx * inv;
        let ri = -(dy * inv);
        ar[k] = gre[j].mul_add(rr, ar[k]);
        ar[k] = (-gim[j]).mul_add(ri, ar[k]);
        ai[k] = gre[j].mul_add(ri, ai[k]);
        ai[k] = gim[j].mul_add(rr, ai[k]);
        j += 1;
        k += 1;
    }
    ((ar[0] + ar[1]) + (ar[2] + ar[3]), (ai[0] + ai[1]) + (ai[2] + ai[3]))
}

/// [`accum_harmonic`] with the symmetric kernel's scattered side (§4.2):
/// besides accumulating `Σ_j Γ_j/(z_j − z_i)` for the destination, each
/// source slot `j` receives `Φ_{jbase+j} −= Γ_i / (z_j − z_i)` into
/// `phr`/`phm` (global particle indexing; `jbase` maps tile slots to it).
/// Because of those real writes the loop must stop at the true source
/// population — callers pass `j1 ≤ len`, never the padded width.
#[allow(clippy::too_many_arguments)] // micro-kernel plumbing, not API
#[inline]
pub fn accum_scatter_harmonic(
    xs: &[f64],
    ys: &[f64],
    gre: &[f64],
    gim: &[f64],
    j0: usize,
    j1: usize,
    xi: f64,
    yi: f64,
    gri: f64,
    gii: f64,
    jbase: usize,
    phr: &mut [f64],
    phm: &mut [f64],
) -> (f64, f64) {
    let mut ar = [0.0f64; LANE];
    let mut ai = [0.0f64; LANE];
    let mut j = j0;
    while j + LANE <= j1 {
        for k in 0..LANE {
            let dx = xs[j + k] - xi;
            let dy = ys[j + k] - yi;
            let inv = 1.0 / dx.mul_add(dx, dy * dy);
            let rr = dx * inv;
            let ri = -(dy * inv);
            ar[k] = gre[j + k].mul_add(rr, ar[k]);
            ar[k] = (-gim[j + k]).mul_add(ri, ar[k]);
            ai[k] = gre[j + k].mul_add(ri, ai[k]);
            ai[k] = gim[j + k].mul_add(rr, ai[k]);
            // Φ_j −= Γ_i r  (Φre −= gri·rr − gii·ri; Φim −= gri·ri + gii·rr)
            let pr = gii.mul_add(ri, phr[jbase + j + k]);
            phr[jbase + j + k] = (-gri).mul_add(rr, pr);
            let pm = (-gii).mul_add(rr, phm[jbase + j + k]);
            phm[jbase + j + k] = (-gri).mul_add(ri, pm);
        }
        j += LANE;
    }
    let mut k = 0;
    while j < j1 {
        let dx = xs[j] - xi;
        let dy = ys[j] - yi;
        let inv = 1.0 / dx.mul_add(dx, dy * dy);
        let rr = dx * inv;
        let ri = -(dy * inv);
        ar[k] = gre[j].mul_add(rr, ar[k]);
        ar[k] = (-gim[j]).mul_add(ri, ar[k]);
        ai[k] = gre[j].mul_add(ri, ai[k]);
        ai[k] = gim[j].mul_add(rr, ai[k]);
        let pr = gii.mul_add(ri, phr[jbase + j]);
        phr[jbase + j] = (-gri).mul_add(rr, pr);
        let pm = (-gii).mul_add(rr, phm[jbase + j]);
        phm[jbase + j] = (-gri).mul_add(ri, pm);
        j += 1;
        k += 1;
    }
    ((ar[0] + ar[1]) + (ar[2] + ar[3]), (ai[0] + ai[1]) + (ai[2] + ai[3]))
}

/// [`accum_harmonic`] with a coincidence guard: slots whose position equals
/// `(xi, yi)` contribute nothing instead of `∞/NaN` — the separate-targets
/// case of Eq. (1.2) ([`crate::direct::eval_separate`]), where a target may
/// coincide with a source. The guard is a branchless select on `d² > 0`
/// (padded slots take the `1/∞ = 0` route, not the guard).
#[inline]
pub fn accum_harmonic_guarded(
    xs: &[f64],
    ys: &[f64],
    gre: &[f64],
    gim: &[f64],
    j0: usize,
    j1: usize,
    xi: f64,
    yi: f64,
) -> (f64, f64) {
    let mut ar = [0.0f64; LANE];
    let mut ai = [0.0f64; LANE];
    let mut j = j0;
    while j + LANE <= j1 {
        for k in 0..LANE {
            let dx = xs[j + k] - xi;
            let dy = ys[j + k] - yi;
            let d2 = dx.mul_add(dx, dy * dy);
            let inv = if d2 > 0.0 { 1.0 / d2 } else { 0.0 };
            let rr = dx * inv;
            let ri = -(dy * inv);
            ar[k] = gre[j + k].mul_add(rr, ar[k]);
            ar[k] = (-gim[j + k]).mul_add(ri, ar[k]);
            ai[k] = gre[j + k].mul_add(ri, ai[k]);
            ai[k] = gim[j + k].mul_add(rr, ai[k]);
        }
        j += LANE;
    }
    let mut k = 0;
    while j < j1 {
        let dx = xs[j] - xi;
        let dy = ys[j] - yi;
        let d2 = dx.mul_add(dx, dy * dy);
        let inv = if d2 > 0.0 { 1.0 / d2 } else { 0.0 };
        let rr = dx * inv;
        let ri = -(dy * inv);
        ar[k] = gre[j].mul_add(rr, ar[k]);
        ar[k] = (-gim[j]).mul_add(ri, ar[k]);
        ai[k] = gre[j].mul_add(ri, ai[k]);
        ai[k] = gim[j].mul_add(rr, ai[k]);
        j += 1;
        k += 1;
    }
    ((ar[0] + ar[1]) + (ar[2] + ar[3]), (ai[0] + ai[1]) + (ai[2] + ai[3]))
}

/// Destination-side **log-kernel** accumulation over source slots
/// `j0..j1`: returns `Σ_j Γ_j · ln(z_i − z_j)` as split `(re, im)`, with
/// `ln` evaluated exactly as [`C64::ln`] does (`0.5·ln(d²)` real part,
/// `atan2` imaginary part). Same blocked lane shape as [`accum_harmonic`].
///
/// Unlike the harmonic kernels, padded slots are **not** no-ops here —
/// `ln(∞) = ∞` and `0 · ∞ = NaN` — so callers must bound `j1` to the true
/// population (the scalar tail absorbs the remainder), and coincident
/// slots (`d² = 0 ⇒ ln = −∞`) must be excluded by splitting the range.
#[inline]
pub fn accum_log(
    xs: &[f64],
    ys: &[f64],
    gre: &[f64],
    gim: &[f64],
    j0: usize,
    j1: usize,
    xi: f64,
    yi: f64,
) -> (f64, f64) {
    let mut ar = [0.0f64; LANE];
    let mut ai = [0.0f64; LANE];
    let mut j = j0;
    while j + LANE <= j1 {
        for k in 0..LANE {
            let dx = xi - xs[j + k];
            let dy = yi - ys[j + k];
            let lr = 0.5 * dx.mul_add(dx, dy * dy).ln();
            let li = dy.atan2(dx);
            ar[k] = gre[j + k].mul_add(lr, ar[k]);
            ar[k] = (-gim[j + k]).mul_add(li, ar[k]);
            ai[k] = gre[j + k].mul_add(li, ai[k]);
            ai[k] = gim[j + k].mul_add(lr, ai[k]);
        }
        j += LANE;
    }
    let mut k = 0;
    while j < j1 {
        let dx = xi - xs[j];
        let dy = yi - ys[j];
        let lr = 0.5 * dx.mul_add(dx, dy * dy).ln();
        let li = dy.atan2(dx);
        ar[k] = gre[j].mul_add(lr, ar[k]);
        ar[k] = (-gim[j]).mul_add(li, ar[k]);
        ai[k] = gre[j].mul_add(li, ai[k]);
        ai[k] = gim[j].mul_add(lr, ai[k]);
        j += 1;
        k += 1;
    }
    ((ar[0] + ar[1]) + (ar[2] + ar[3]), (ai[0] + ai[1]) + (ai[2] + ai[3]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn build_tree(n: usize, levels: usize, seed: u64) -> Pyramid {
        let mut r = Pcg64::seed_from_u64(seed);
        let (pts, gs) = workload::uniform_square(n, &mut r);
        Pyramid::build(&pts, &gs, levels).unwrap()
    }

    #[test]
    fn tile_width_is_lane_aligned() {
        assert_eq!(round_up_lane(0), 0);
        assert_eq!(round_up_lane(1), LANE);
        assert_eq!(round_up_lane(LANE), LANE);
        assert_eq!(round_up_lane(LANE + 1), 2 * LANE);
        let pyr = build_tree(1000, 3, 7);
        let t = LeafTiles::build(&pyr);
        assert_eq!(t.nmax % LANE, 0);
        assert!(t.nmax >= pyr.max_leaf_len());
        assert!(t.nmax < pyr.max_leaf_len() + LANE);
        assert_eq!(t.n_leaves(), pyr.n_leaves());
        assert_eq!(t.xs.len(), t.n_leaves() * t.nmax);
    }

    #[test]
    fn tiles_mirror_particles_and_pad_the_rest() {
        // 37 particles over 16 leaves forces uneven populations: real
        // slots mirror the leaf-sorted particles, padded slots carry the
        // sentinel position and zero strength
        let pyr = build_tree(37, 2, 11);
        let t = LeafTiles::build(&pyr);
        for b in 0..t.n_leaves() {
            let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
            assert_eq!(t.len[b], hi - lo);
            let base = b * t.nmax;
            for s in 0..t.nmax {
                if s < t.len[b] {
                    let q = &pyr.particles[lo + s];
                    assert_eq!(t.xs[base + s], q.pos.re);
                    assert_eq!(t.ys[base + s], q.pos.im);
                    assert_eq!(t.gre[base + s], q.gamma.re);
                    assert_eq!(t.gim[base + s], q.gamma.im);
                } else {
                    assert_eq!(t.xs[base + s], PAD_POS);
                    assert_eq!(t.ys[base + s], PAD_POS);
                    assert_eq!(t.gre[base + s], 0.0);
                    assert_eq!(t.gim[base + s], 0.0);
                }
            }
        }
        // uneven populations actually occurred (scalar-tail boxes exist)
        assert!((0..t.n_leaves()).any(|b| t.len[b] % LANE != 0));
        // empty leaves are all-padding tiles
        if let Some(b) = (0..t.n_leaves()).find(|&b| t.len[b] == 0) {
            assert!(t.xs[t.tile(b)].iter().all(|&x| x == PAD_POS));
        }
    }

    #[test]
    fn padded_slots_are_exact_noops() {
        // a one-particle tile padded to LANE: accumulating over the full
        // padded width must equal accumulating over the single real slot
        let pts = [C64::new(0.25, 0.5)];
        let gs = [C64::new(1.5, -0.5)];
        let t = PackedPoints::pack(&pts, &gs);
        assert_eq!(t.padded(), LANE);
        let (xi, yi) = (0.75, 0.25);
        let full = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.padded(), xi, yi);
        let real = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, 1, xi, yi);
        assert_eq!(full.0, real.0);
        assert_eq!(full.1, real.1);
        // and the guarded flavor agrees on non-coincident data
        let g = accum_harmonic_guarded(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.padded(), xi, yi);
        assert_eq!(g.0, full.0);
        assert_eq!(g.1, full.1);
    }

    #[test]
    fn guarded_skips_coincident_sources() {
        let pts = [C64::new(0.5, 0.5), C64::new(0.125, 0.75)];
        let gs = [C64::new(1.0, 2.0), C64::new(-3.0, 0.5)];
        let t = PackedPoints::pack(&pts, &gs);
        // target sits exactly on source 0: only source 1 contributes
        let (ar, ai) = accum_harmonic_guarded(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.padded(), 0.5, 0.5);
        let (er, ei) = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 1, 2, 0.5, 0.5);
        assert!(ar.is_finite() && ai.is_finite());
        assert!((ar - er).abs() <= 1e-15 * er.abs().max(1.0));
        assert!((ai - ei).abs() <= 1e-15 * ei.abs().max(1.0));
    }

    #[test]
    fn log_accumulator_matches_complex_ln() {
        use crate::expansion::Kernel;
        let mut r = Pcg64::seed_from_u64(17);
        let (pts, gs) = workload::uniform_square(23, &mut r);
        let t = PackedPoints::pack(&pts, &gs);
        let (xi, yi) = (1.5, -0.25);
        let zt = C64::new(xi, yi);
        // bounded to the true population — padding is NOT a no-op under ln
        let (ar, ai) = accum_log(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.n, xi, yi);
        let mut want = C64::new(0.0, 0.0);
        for (p, g) in pts.iter().zip(&gs) {
            want += Kernel::Log.eval(zt, *p, *g);
        }
        assert!((ar - want.re).abs() <= 1e-12 * want.re.abs().max(1.0));
        assert!((ai - want.im).abs() <= 1e-12 * want.im.abs().max(1.0));
    }

    #[test]
    fn single_box_tree_builds_one_padded_tile() {
        let pyr = build_tree(1, 0, 13);
        let t = LeafTiles::build(&pyr);
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.nmax, LANE);
        // zero-length accumulation is an exact zero
        let (ar, ai) = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, 0, 0.1, 0.2);
        assert_eq!(ar, 0.0);
        assert_eq!(ai, 0.0);
    }
}
