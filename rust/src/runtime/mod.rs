//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the Rust request path (Python is never invoked here).
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (the crate's xla_extension 0.5.1
//! rejects jax ≥ 0.5's 64-bit-id serialized protos).
//!
//! Two execute paths share one compile cache:
//!
//! * **single-problem** ([`Executable::run_fmm`]): one packed tree per
//!   `execute` call;
//! * **batched** ([`Executable::run_fmm_group`]): a whole shape-compatible
//!   group of trees stacked along the leading `batch` axis of a batched
//!   artifact ([`crate::packing::pack_fmm_batch`]) and executed in ONE
//!   `run_raw` — the dispatch-amortization path that the batch subsystem
//!   ([`crate::batch`]) routes through. Artifact selection widens the pad
//!   requirements over every group member ([`Runtime::fmm_artifact_for_group`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::complex::C64;
use crate::connectivity::Connectivity;
use crate::packing::{self, ArtifactMeta, PackedFmm, Tensor};
use crate::tree::Pyramid;

/// Timing breakdown of one runtime invocation (the "total time includes the
/// time to copy data" accounting of §5).
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    /// Host→device marshalling (Literal construction).
    pub upload_s: f64,
    /// Executable run time.
    pub execute_s: f64,
    /// Device→host copy + unpacking.
    pub download_s: f64,
}

impl RunStats {
    pub fn total(&self) -> f64 {
        self.upload_s + self.execute_s + self.download_s
    }

    /// Accumulate another invocation's stats (batch aggregation).
    pub fn add(&mut self, other: &RunStats) {
        self.upload_s += other.upload_s;
        self.execute_s += other.execute_s;
        self.download_s += other.download_s;
    }
}

/// A compiled artifact with its manifest.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client plus a compile cache keyed by artifact
/// name. Compilation happens once per process; the request path only
/// executes.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Runtime {
    /// Create a runtime over the artifact directory (default
    /// `$FMM2D_ARTIFACTS` or `./artifacts`).
    pub fn new(dir: Option<&Path>) -> Result<Self> {
        let dir = match dir {
            Some(d) => d.to_path_buf(),
            None => std::env::var("FMM2D_ARTIFACTS")
                .map(PathBuf::from)
                .unwrap_or_else(|_| PathBuf::from("artifacts")),
        };
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all artifacts present in the directory.
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                name.strip_suffix(".hlo.txt").map(str::to_string)
            })
            .collect();
        names.sort();
        names
    }

    /// Load + compile (cached) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.dir.join(format!("{name}.meta.json"));
        if !hlo.exists() {
            bail!(
                "artifact '{name}' not found in {} — run `make artifacts`",
                self.dir.display()
            );
        }
        let meta = ArtifactMeta::load(&meta_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text of {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let entry = std::rc::Rc::new(Executable { meta, exe });
        self.cache.insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    /// Pick the FMM artifact compiled for exactly `levels` levels,
    /// preferring the fast `jnp` execution variant over the TPU-design
    /// `pallas` variant (identical numerics; see aot.py).
    pub fn fmm_artifact_for_levels(&mut self, levels: usize) -> Result<std::rc::Rc<Executable>> {
        let mut fallback = None;
        for name in self.available() {
            if let Ok(e) = self.load(&name) {
                if e.meta.kind == "fmm" && e.meta.levels == levels {
                    if !name.ends_with("_pallas") {
                        return Ok(e);
                    }
                    fallback = Some(e);
                }
            }
        }
        fallback.ok_or_else(|| {
            crate::anyhow!("no FMM artifact for {levels} levels; emit one via aot.py")
        })
    }

    /// Pick the *smallest* FMM artifact whose pads fit this tree (pad
    /// buckets, see aot.py): padded work — P2P above all — scales with the
    /// pad sizes, so tight-bucket artifacts execute several times faster on
    /// near-uniform inputs than the worst-case bucket.
    pub fn fmm_artifact_for_tree(
        &mut self,
        pyr: &Pyramid,
        con: &Connectivity,
    ) -> Result<std::rc::Rc<Executable>> {
        let need = packing::required_pads(pyr, con);
        self.fmm_artifact_for_pads(&need, 0)
    }

    /// Smallest-fitting **batched** artifact for a whole dispatch group:
    /// the pad requirements are widened over every member
    /// ([`packing::PadRequirements::merge`]) and the artifact must carry
    /// at least `problems.len()` batch slots.
    pub fn fmm_artifact_for_group(
        &mut self,
        problems: &[(&Pyramid, &Connectivity)],
    ) -> Result<std::rc::Rc<Executable>> {
        if problems.is_empty() {
            bail!("fmm_artifact_for_group: empty problem group");
        }
        let mut need = packing::required_pads(problems[0].0, problems[0].1);
        for &(pyr, con) in &problems[1..] {
            need.merge(&packing::required_pads(pyr, con));
        }
        self.fmm_artifact_for_pads(&need, problems.len())
    }

    /// Shared selection core: smallest padded-work artifact satisfying the
    /// pad envelope, with `min_batch` batch slots (`0` = single-problem
    /// artifacts only).
    fn fmm_artifact_for_pads(
        &mut self,
        need: &packing::PadRequirements,
        min_batch: usize,
    ) -> Result<std::rc::Rc<Executable>> {
        let mut best: Option<(usize, std::rc::Rc<Executable>)> = None;
        for name in self.available() {
            if name.ends_with("_pallas") {
                continue;
            }
            let Ok(e) = self.load(&name) else { continue };
            let m = &e.meta;
            let fits = m.kind == "fmm"
                && m.levels == need.levels
                && m.nmax >= need.nmax
                && m.knear >= need.knear
                && m.ksp >= need.ksp
                && m.kfar.len() == need.kfar.len()
                && m.kfar.iter().zip(&need.kfar).all(|(h, w)| h >= w)
                && (if min_batch == 0 {
                    m.batch == 0
                } else {
                    m.batch >= min_batch
                });
            if !fits {
                continue;
            }
            // padded-work proxy: the P2P pair tile dominates, then the
            // shortcut gathers, then M2L (batched artifacts scale by slots)
            let score = (m.knear * m.nmax * m.nmax
                + 2 * m.ksp * m.nmax * m.nmax
                + m.kfar.iter().sum::<usize>() * (m.p + 1))
                * m.batch.max(1);
            if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
                best = Some((score, e));
            }
        }
        best.map(|(_, e)| e).ok_or_else(|| {
            crate::anyhow!(
                "no FMM artifact fits (levels {}, nmax {}, knear {}, ksp {}, \
                 batch ≥ {}); emit a wider bucket via aot.py",
                need.levels,
                need.nmax,
                need.knear,
                need.ksp,
                min_batch
            )
        })
    }
}

fn literal_of(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(match t {
        Tensor::F64(data, _) => xla::Literal::vec1(data).reshape(&dims)?,
        Tensor::I32(data, _) => xla::Literal::vec1(data).reshape(&dims)?,
    })
}

impl Executable {
    /// Execute with packed tensors; returns the flat f64 outputs in
    /// manifest order plus timing stats.
    pub fn run_raw(&self, tensors: &[Tensor]) -> Result<(Vec<Vec<f64>>, RunStats)> {
        let mut stats = RunStats::default();
        let t = Instant::now();
        let literals: Vec<xla::Literal> = tensors
            .iter()
            .map(literal_of)
            .collect::<Result<Vec<_>>>()?;
        stats.upload_s = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        stats.execute_s = t.elapsed().as_secs_f64();

        let t = Instant::now();
        // lowered with return_tuple=True → a tuple of outputs
        let parts = root.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest declares {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        let outs = parts
            .into_iter()
            .map(|l| l.to_vec::<f64>().context("reading f64 output"))
            .collect::<Result<Vec<_>>>()?;
        stats.download_s = t.elapsed().as_secs_f64();
        Ok((outs, stats))
    }

    /// Full FMM invocation: pack a tree, execute, unpack to original order.
    pub fn run_fmm(
        &self,
        pyr: &Pyramid,
        con: &Connectivity,
    ) -> Result<(Vec<C64>, RunStats)> {
        let packed: PackedFmm = packing::pack_fmm(pyr, con, &self.meta)?;
        let (outs, stats) = self.run_raw(&packed.tensors)?;
        let pot = packing::unpack_potentials(pyr, packed.nmax, &outs[0], &outs[1]);
        Ok((pot, stats))
    }

    /// Batched FMM invocation: pack every tree of a shape-compatible group
    /// into the stacked `[batch, ...]` tensor layout and execute a
    /// **single** `run_raw` for the whole group — the per-dispatch
    /// overhead (upload, launch, sync, download) is paid once per group
    /// instead of once per problem. Returns per-problem potentials in the
    /// group's member order, each in its caller's original particle order.
    pub fn run_fmm_group(
        &self,
        problems: &[(&Pyramid, &Connectivity)],
    ) -> Result<(Vec<Vec<C64>>, RunStats)> {
        let packed = packing::pack_fmm_batch(problems, &self.meta)?;
        let (outs, stats) = self.run_raw(&packed.tensors)?;
        let pots = problems
            .iter()
            .enumerate()
            .map(|(slot, &(pyr, _))| {
                packing::unpack_potentials_slot(
                    pyr,
                    packed.nmax,
                    packed.n_leaves,
                    slot,
                    &outs[0],
                    &outs[1],
                )
            })
            .collect();
        Ok((pots, stats))
    }

    /// Direct-summation artifact invocation on `n = meta.n_direct` points.
    pub fn run_direct(&self, points: &[C64], gammas: &[C64]) -> Result<(Vec<C64>, RunStats)> {
        if self.meta.kind != "direct" {
            bail!("artifact {} is not a direct-eval artifact", self.meta.name);
        }
        let n = self.meta.n_direct;
        if points.len() != n {
            bail!(
                "direct artifact {} is compiled for n={n}, got {}",
                self.meta.name,
                points.len()
            );
        }
        let shape = vec![n];
        let tensors = vec![
            Tensor::F64(points.iter().map(|z| z.re).collect(), shape.clone()),
            Tensor::F64(points.iter().map(|z| z.im).collect(), shape.clone()),
            Tensor::F64(gammas.iter().map(|z| z.re).collect(), shape.clone()),
            Tensor::F64(gammas.iter().map(|z| z.im).collect(), shape),
        ];
        let (outs, stats) = self.run_raw(&tensors)?;
        let pot = outs[0]
            .iter()
            .zip(&outs[1])
            .map(|(&re, &im)| C64::new(re, im))
            .collect();
        Ok((pot, stats))
    }
}
