//! Double-precision complex arithmetic substrate.
//!
//! The paper's whole computational phase works in the complex plane
//! (Eqs. 2.2–2.3); this module provides the `C64` value type used throughout.
//! Built in-repo because the environment is offline (no `num-complex`), and
//! because the FMM inner loops benefit from a few bespoke helpers
//! (`powi_table`, fused multiply-accumulate shapes) that a generic complex
//! type does not expose.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex number `re + i·im` in double precision.
#[derive(Clone, Copy, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

/// The additive identity.
pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
/// The multiplicative identity.
pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };
/// The imaginary unit.
pub const I: C64 = C64 { re: 0.0, im: 1.0 };

impl C64 {
    #[inline(always)]
    pub const fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// Real number embedded in the complex plane.
    #[inline(always)]
    pub const fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    /// Squared modulus `|z|²`.
    #[inline(always)]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Modulus `|z|`.
    #[inline(always)]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Argument in `(-π, π]`.
    #[inline(always)]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    /// Complex conjugate.
    #[inline(always)]
    pub fn conj(self) -> Self {
        Self::new(self.re, -self.im)
    }

    /// Multiplicative inverse `1/z`.
    ///
    /// The FMM kernel (Eq. 5.1) is a complex reciprocal, so this is *the*
    /// innermost operation of the P2P phase. One division by `|z|²`,
    /// matching what the CUDA implementation does per pairwise interaction.
    #[inline(always)]
    pub fn recip(self) -> Self {
        let s = 1.0 / self.norm_sqr();
        Self::new(self.re * s, -self.im * s)
    }

    /// Principal branch of the complex logarithm.
    #[inline(always)]
    pub fn ln(self) -> Self {
        Self::new(0.5 * self.norm_sqr().ln(), self.arg())
    }

    /// Integer power by binary exponentiation (exact op-count independent of
    /// the argument; used for the scale factors `r^j` of Algorithms 3.4–3.6).
    pub fn powi(self, n: i32) -> Self {
        if n == 0 {
            return ONE;
        }
        if n < 0 {
            return self.powi(-n).recip();
        }
        let mut base = self;
        let mut acc = ONE;
        let mut k = n as u32;
        while k > 1 {
            if k & 1 == 1 {
                acc *= base;
            }
            base *= base;
            k >>= 1;
        }
        acc * base
    }

    /// Table of powers `[1, z, z², …, z^n]` (length `n+1`).
    ///
    /// The pre/post-scaling passes of the shift operators consume consecutive
    /// powers; building the table once replaces O(p log p) multiplications by
    /// O(p) and keeps the hot loops free of `powi` calls.
    pub fn powi_table(self, n: usize) -> Vec<C64> {
        let mut t = Vec::with_capacity(n + 1);
        let mut acc = ONE;
        t.push(acc);
        for _ in 0..n {
            acc *= self;
            t.push(acc);
        }
        t
    }

    /// Fused multiply-add shape `self + a*b` (single rounding not guaranteed;
    /// this is a *structural* helper for the inner loops).
    #[inline(always)]
    pub fn mul_add(self, a: C64, b: C64) -> Self {
        self + a * b
    }

    /// `true` when both components are finite.
    #[inline(always)]
    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }

    /// Scale by a real factor.
    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        Self::new(self.re * s, self.im * s)
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline(always)]
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline(always)]
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline(always)]
    fn div(self, o: C64) -> C64 {
        self * o.recip()
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline(always)]
    fn neg(self) -> C64 {
        C64::new(-self.re, -self.im)
    }
}

impl Mul<f64> for C64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, s: f64) -> C64 {
        self.scale(s)
    }
}

impl Div<f64> for C64 {
    type Output = C64;
    #[inline(always)]
    fn div(self, s: f64) -> C64 {
        self.scale(1.0 / s)
    }
}

impl AddAssign for C64 {
    #[inline(always)]
    fn add_assign(&mut self, o: C64) {
        *self = *self + o;
    }
}

impl SubAssign for C64 {
    #[inline(always)]
    fn sub_assign(&mut self, o: C64) {
        *self = *self - o;
    }
}

impl MulAssign for C64 {
    #[inline(always)]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl DivAssign for C64 {
    #[inline(always)]
    fn div_assign(&mut self, o: C64) {
        *self = *self / o;
    }
}

impl Sum for C64 {
    fn sum<I: Iterator<Item = C64>>(iter: I) -> C64 {
        iter.fold(ZERO, |a, b| a + b)
    }
}

impl From<f64> for C64 {
    fn from(re: f64) -> Self {
        C64::real(re)
    }
}

impl fmt::Debug for C64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:+.12e}{:+.12e}i", self.re, self.im)
    }
}

impl fmt::Display for C64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}{}i", self.re, if self.im < 0.0 { "" } else { "+" }, self.im)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: C64, b: C64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn field_axioms_spotcheck() {
        let a = C64::new(1.5, -2.25);
        let b = C64::new(-0.75, 3.0);
        let c = C64::new(0.125, 0.5);
        assert!(close((a + b) + c, a + (b + c), 1e-15));
        assert!(close((a * b) * c, a * (b * c), 1e-15));
        assert!(close(a * (b + c), a * b + a * c, 1e-15));
        assert!(close(a * ONE, a, 0.0));
        assert!(close(a + ZERO, a, 0.0));
    }

    #[test]
    fn recip_and_div() {
        let a = C64::new(3.0, -4.0);
        assert!(close(a * a.recip(), ONE, 1e-15));
        let b = C64::new(-1.0, 2.0);
        assert!(close(a / b * b, a, 1e-14));
    }

    #[test]
    fn powi_matches_repeated_mul() {
        let z = C64::new(0.8, -0.6);
        let mut acc = ONE;
        for n in 0..20 {
            assert!(close(z.powi(n), acc, 1e-13), "n={n}");
            acc *= z;
        }
        assert!(close(z.powi(-3), (z * z * z).recip(), 1e-13));
    }

    #[test]
    fn powi_table_consistent() {
        let z = C64::new(-0.3, 1.1);
        let t = z.powi_table(16);
        assert_eq!(t.len(), 17);
        for (n, v) in t.iter().enumerate() {
            assert!(close(*v, z.powi(n as i32), 1e-12), "n={n}");
        }
    }

    #[test]
    fn ln_inverts_exp_like_values() {
        // ln(r e^{iφ}) = ln r + iφ on the principal branch
        let z = C64::new(1.0, 1.0);
        let l = z.ln();
        assert!((l.re - 0.5 * 2.0f64.ln()).abs() < 1e-15);
        assert!((l.im - std::f64::consts::FRAC_PI_4).abs() < 1e-15);
    }

    #[test]
    fn conj_arg_norm() {
        let z = C64::new(3.0, 4.0);
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z.conj().im, -4.0);
        assert!((z.arg() + z.conj().arg()).abs() < 1e-15);
    }

    #[test]
    fn sum_iterator() {
        let v = [C64::new(1.0, 2.0), C64::new(-0.5, 0.5), C64::new(2.5, -1.0)];
        let s: C64 = v.iter().copied().sum();
        assert!(close(s, C64::new(3.0, 1.5), 1e-15));
    }
}
