//! Packing of the adaptive pyramid into the fixed-shape tensors consumed by
//! the AOT-compiled XLA artifacts.
//!
//! The artifact ABI is defined by `python/compile/model.py::PackConfig`
//! (input order, shapes, `-1`-padded gather lists) and recorded in each
//! artifact's `.meta.json`; this module is the Rust mirror. The static
//! pyramid layout (4^l boxes/level, contiguous children) is what makes a
//! fixed-shape ABI possible — adaptivity lives in the *values* (centers,
//! lists), never the shapes.
//!
//! The same property extends to **multi-problem batching**
//! ([`pack_fmm_batch`]): because every input shape is a function of
//! `(levels, p, pads)` alone, problems that agree on those numbers stack
//! along a new leading `batch` axis into one padded tensor layout and
//! execute in a single dispatch. Unused batch slots are *empty problems* —
//! all-zero particle grids (mask 0) and all-`-1` gather lists — so a
//! partially filled batch is numerically inert in the pad slots. Batched
//! artifacts record their slot count in the manifest's `batch` field
//! (`0`/absent = single-problem artifact); unpacking slices one problem's
//! `[4^L, nmax]` grids out of the stacked output
//! ([`unpack_potentials_slot`]).

use crate::complex::C64;
use crate::connectivity::Connectivity;
use crate::tree::{boxes_at_level, Pyramid};
use crate::util::json::Json;
use crate::bail;
use crate::util::error::{Context, Result};

/// Element type of one artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F64,
    I32,
}

/// One artifact input declaration (from `.meta.json`).
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String, // "fmm" | "direct"
    pub levels: usize,
    pub p: usize,
    pub nmax: usize,
    pub kfar: Vec<usize>,
    pub knear: usize,
    pub ksp: usize,
    pub nbtot: usize,
    /// `direct` artifacts: number of points.
    pub n_direct: usize,
    /// Leading batch dimension of a batched artifact: the number of
    /// problem slots stacked per dispatch (`0` = single-problem artifact,
    /// the default when `.meta.json` has no `batch` field). The manifest's
    /// `inputs`/`outputs` keep the *per-problem* shapes; the executable
    /// consumes `[batch] + shape` ([`pack_fmm_batch`]).
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn specs_of(j: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("meta: missing '{key}'"))?;
    arr.iter()
        .map(|e| {
            let name = e.req_str("name")?.to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .context("meta: shape")?
                .iter()
                .map(|d| d.as_usize().context("meta: dim"))
                .collect::<Result<Vec<_>>>()?;
            let dtype = match e.req_str("dtype")? {
                "f64" => DType::F64,
                "i32" => DType::I32,
                other => bail!("meta: unsupported dtype {other}"),
            };
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing .meta.json")?;
        let kind = j.req_str("kind")?.to_string();
        let (levels, p, nmax, kfar, knear, ksp, nbtot, n_direct);
        if kind == "fmm" {
            levels = j.req_usize("levels")?;
            p = j.req_usize("p")?;
            nmax = j.req_usize("nmax")?;
            kfar = j
                .get("kfar")
                .and_then(Json::as_arr)
                .context("meta: kfar")?
                .iter()
                .map(|d| d.as_usize().context("meta: kfar entry"))
                .collect::<Result<Vec<_>>>()?;
            knear = j.req_usize("knear")?;
            ksp = j.req_usize("ksp")?;
            nbtot = j.req_usize("nbtot")?;
            n_direct = 0;
        } else {
            levels = 0;
            p = 0;
            nmax = 0;
            kfar = vec![];
            knear = 0;
            ksp = 0;
            nbtot = 0;
            n_direct = j.req_usize("n")?;
        }
        Ok(ArtifactMeta {
            name: j.req_str("name")?.to_string(),
            kind,
            levels,
            p,
            nmax,
            kfar,
            knear,
            ksp,
            nbtot,
            n_direct,
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(0),
            inputs: specs_of(&j, "inputs")?,
            outputs: specs_of(&j, "outputs")?,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn n_leaves(&self) -> usize {
        boxes_at_level(self.levels)
    }
}

/// `(4^l − 1)/3`: offset of level `l` in the flattened center arrays.
pub fn level_offset(l: usize) -> usize {
    (boxes_at_level(l) - 1) / 3
}

/// One packed tensor, in artifact input order.
#[derive(Clone, Debug)]
pub enum Tensor {
    F64(Vec<f64>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F64(_, s) | Tensor::I32(_, s) => s,
        }
    }
}

/// The packed inputs of one FMM artifact invocation plus the bookkeeping
/// needed to unpack the result.
#[derive(Clone, Debug)]
pub struct PackedFmm {
    pub tensors: Vec<Tensor>,
    pub nmax: usize,
    pub n_leaves: usize,
}

/// Pad requirements of a tree (compared against the artifact pads so
/// mismatches fail with an actionable message).
#[derive(Clone, Debug, PartialEq)]
pub struct PadRequirements {
    pub levels: usize,
    pub nmax: usize,
    pub kfar: Vec<usize>,
    pub knear: usize,
    pub ksp: usize,
}

impl PadRequirements {
    /// Widen to cover `other` as well — the pad envelope of a batch group.
    /// Levels must match: the batch planner only groups problems with
    /// identical level counts.
    pub fn merge(&mut self, other: &PadRequirements) {
        debug_assert_eq!(
            self.levels, other.levels,
            "pad merge across different level counts"
        );
        self.nmax = self.nmax.max(other.nmax);
        for (a, b) in self.kfar.iter_mut().zip(&other.kfar) {
            *a = (*a).max(*b);
        }
        self.knear = self.knear.max(other.knear);
        self.ksp = self.ksp.max(other.ksp);
    }
}

/// Measure the pads a pyramid + connectivity actually need.
pub fn required_pads(pyr: &Pyramid, con: &Connectivity) -> PadRequirements {
    PadRequirements {
        levels: pyr.levels,
        nmax: pyr.max_leaf_len(),
        kfar: (1..=pyr.levels)
            .map(|l| con.weak[l].max_degree().max(1))
            .collect(),
        knear: con.near.max_degree(),
        ksp: con.p2l.max_degree().max(con.m2p.max_degree()).max(1),
    }
}

fn pad_adjacency(
    adj: &crate::connectivity::AdjList,
    nb: usize,
    k: usize,
    what: &str,
) -> Result<Tensor> {
    let mut data = vec![-1i32; nb * k];
    for b in 0..nb {
        let src = adj.sources(b);
        if src.len() > k {
            bail!(
                "{what}: box {b} needs {} entries but the artifact pads to {k}; \
                 re-emit the artifact with a larger pad (see aot.py)",
                src.len()
            );
        }
        for (i, &s) in src.iter().enumerate() {
            data[b * k + i] = s as i32;
        }
    }
    Ok(Tensor::I32(data, vec![nb, k]))
}

/// Pack a pyramid + connectivity into the tensor list of `meta`.
pub fn pack_fmm(pyr: &Pyramid, con: &Connectivity, meta: &ArtifactMeta) -> Result<PackedFmm> {
    if meta.kind != "fmm" {
        bail!("artifact {} is not an fmm artifact", meta.name);
    }
    let need = required_pads(pyr, con);
    if need.levels != meta.levels {
        bail!(
            "tree has {} levels but artifact {} was compiled for {}",
            need.levels,
            meta.name,
            meta.levels
        );
    }
    if need.nmax > meta.nmax {
        bail!(
            "largest leaf box holds {} particles but artifact pads nmax={}",
            need.nmax,
            meta.nmax
        );
    }
    if need.knear > meta.knear || need.ksp > meta.ksp {
        bail!(
            "near/shortcut lists ({}/{}) exceed artifact pads ({}/{})",
            need.knear,
            need.ksp,
            meta.knear,
            meta.ksp
        );
    }
    for (l, (&have, &want)) in meta.kfar.iter().zip(&need.kfar).enumerate() {
        if want > have {
            bail!(
                "M2L list at level {} needs pad {} but artifact has {}",
                l + 1,
                want,
                have
            );
        }
    }

    let (nl, nmax) = (meta.n_leaves(), meta.nmax);
    let mut pos_re = vec![0.0; nl * nmax];
    let mut pos_im = vec![0.0; nl * nmax];
    let mut gam_re = vec![0.0; nl * nmax];
    let mut gam_im = vec![0.0; nl * nmax];
    let mut mask = vec![0.0; nl * nmax];
    for b in 0..nl {
        for (i, q) in pyr.leaf(b).iter().enumerate() {
            let at = b * nmax + i;
            pos_re[at] = q.pos.re;
            pos_im[at] = q.pos.im;
            gam_re[at] = q.gamma.re;
            gam_im[at] = q.gamma.im;
            mask[at] = 1.0;
        }
    }

    let mut ctr_re = vec![0.0; meta.nbtot];
    let mut ctr_im = vec![0.0; meta.nbtot];
    for l in 0..=meta.levels {
        let off = level_offset(l);
        for (b, r) in pyr.rects[l].iter().enumerate() {
            let c = r.center();
            ctr_re[off + b] = c.re;
            ctr_im[off + b] = c.im;
        }
    }

    let grid = vec![nl, nmax];
    let mut tensors = vec![
        Tensor::F64(pos_re, grid.clone()),
        Tensor::F64(pos_im, grid.clone()),
        Tensor::F64(gam_re, grid.clone()),
        Tensor::F64(gam_im, grid.clone()),
        Tensor::F64(mask, grid.clone()),
        Tensor::F64(ctr_re, vec![meta.nbtot]),
        Tensor::F64(ctr_im, vec![meta.nbtot]),
    ];
    for l in 1..=meta.levels {
        tensors.push(pad_adjacency(
            &con.weak[l],
            boxes_at_level(l),
            meta.kfar[l - 1],
            "m2l",
        )?);
    }
    tensors.push(pad_adjacency(&con.near, nl, meta.knear, "near")?);
    tensors.push(pad_adjacency(&con.p2l, nl, meta.ksp, "p2l")?);
    tensors.push(pad_adjacency(&con.m2p, nl, meta.ksp, "m2p")?);

    // cross-check against the manifest's declared shapes
    if tensors.len() != meta.inputs.len() {
        bail!(
            "packed {} tensors but artifact declares {} inputs",
            tensors.len(),
            meta.inputs.len()
        );
    }
    for (t, s) in tensors.iter().zip(&meta.inputs) {
        if t.shape() != s.shape.as_slice() {
            bail!(
                "input '{}': packed shape {:?} != declared {:?}",
                s.name,
                t.shape(),
                s.shape
            );
        }
    }

    Ok(PackedFmm {
        tensors,
        nmax,
        n_leaves: nl,
    })
}

/// The packed inputs of one **batched** FMM dispatch: every input of the
/// single-problem ABI stacked along a new leading axis of length `batch`.
#[derive(Clone, Debug)]
pub struct PackedFmmBatch {
    pub tensors: Vec<Tensor>,
    pub nmax: usize,
    pub n_leaves: usize,
    /// Slots in the stacked layout (≥ the number of real problems; the
    /// tail slots are empty pad problems).
    pub batch: usize,
}

/// Pack a shape-compatible group of problems into the stacked tensor
/// layout of a batched artifact (`meta.batch ≥ problems.len()` slots).
///
/// Each problem is packed against the same per-problem shapes as
/// [`pack_fmm`] (so all single-problem pad validation applies per member),
/// then input `k` of every problem is concatenated along a new leading
/// axis of length `meta.batch`. Unused slots are filled with *empty
/// problems* — zeros for `f64` inputs (in particular an all-zero mask, so
/// the slot contributes nothing) and `-1` for the gather lists (which
/// gather nothing). A pad slot's outputs are garbage by construction and
/// are never unpacked.
pub fn pack_fmm_batch(
    problems: &[(&Pyramid, &Connectivity)],
    meta: &ArtifactMeta,
) -> Result<PackedFmmBatch> {
    if meta.kind != "fmm" {
        bail!("artifact {} is not an fmm artifact", meta.name);
    }
    if meta.batch == 0 {
        bail!(
            "artifact {} has no batch dimension; re-emit a batched artifact \
             (meta.json field 'batch') via aot.py",
            meta.name
        );
    }
    if problems.is_empty() {
        bail!("pack_fmm_batch: empty problem group");
    }
    if problems.len() > meta.batch {
        bail!(
            "group of {} problems exceeds the {} batch slots of artifact {}",
            problems.len(),
            meta.batch,
            meta.name
        );
    }
    // Preallocate the stacked buffers as empty pad problems (f64 zeros,
    // i32 -1), then pack each member directly into its slot — only one
    // per-problem pack is alive at a time, so peak transient memory is the
    // dispatch payload plus a single problem, not twice the payload.
    let mut tensors: Vec<Tensor> = meta
        .inputs
        .iter()
        .map(|spec| {
            let numel = spec.numel();
            let mut shape = Vec::with_capacity(spec.shape.len() + 1);
            shape.push(meta.batch);
            shape.extend_from_slice(&spec.shape);
            match spec.dtype {
                DType::F64 => Tensor::F64(vec![0.0; meta.batch * numel], shape),
                DType::I32 => Tensor::I32(vec![-1; meta.batch * numel], shape),
            }
        })
        .collect();
    for (slot, &(pyr, con)) in problems.iter().enumerate() {
        let pack = pack_fmm(pyr, con, meta)?;
        for (dst, src) in tensors.iter_mut().zip(&pack.tensors) {
            match (dst, src) {
                (Tensor::F64(d, _), Tensor::F64(s, _)) => {
                    d[slot * s.len()..(slot + 1) * s.len()].copy_from_slice(s);
                }
                (Tensor::I32(d, _), Tensor::I32(s, _)) => {
                    d[slot * s.len()..(slot + 1) * s.len()].copy_from_slice(s);
                }
                _ => bail!("input dtype mismatch between manifest and packed tensors"),
            }
        }
    }

    Ok(PackedFmmBatch {
        tensors,
        nmax: meta.nmax,
        n_leaves: meta.n_leaves(),
        batch: meta.batch,
    })
}

/// Scatter slot `slot` of the stacked `[batch, 4^L, nmax]` potential grids
/// back to that problem's original particle order.
pub fn unpack_potentials_slot(
    pyr: &Pyramid,
    nmax: usize,
    n_leaves: usize,
    slot: usize,
    pot_re: &[f64],
    pot_im: &[f64],
) -> Vec<C64> {
    let stride = n_leaves * nmax;
    let off = slot * stride;
    unpack_potentials(pyr, nmax, &pot_re[off..off + stride], &pot_im[off..off + stride])
}

/// Scatter the `[4^L, nmax]` potential grids back to the caller's original
/// particle order.
pub fn unpack_potentials(pyr: &Pyramid, nmax: usize, pot_re: &[f64], pot_im: &[f64]) -> Vec<C64> {
    let mut leaf_ordered = Vec::with_capacity(pyr.particles.len());
    for b in 0..pyr.n_leaves() {
        let len = pyr.starts[b + 1] - pyr.starts[b];
        for i in 0..len {
            leaf_ordered.push(C64::new(pot_re[b * nmax + i], pot_im[b * nmax + i]));
        }
    }
    pyr.unpermute(&leaf_ordered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    fn meta_for(levels: usize, p: usize, nmax: usize, kfar: &[usize], knear: usize, ksp: usize) -> ArtifactMeta {
        meta_for_batched(levels, p, nmax, kfar, knear, ksp, 0)
    }

    fn meta_for_batched(levels: usize, p: usize, nmax: usize, kfar: &[usize], knear: usize, ksp: usize, batch: usize) -> ArtifactMeta {
        // build via the same JSON path aot.py uses
        let mut inputs = vec![
            ("pos_re", vec![boxes_at_level(levels), nmax]),
            ("pos_im", vec![boxes_at_level(levels), nmax]),
            ("gam_re", vec![boxes_at_level(levels), nmax]),
            ("gam_im", vec![boxes_at_level(levels), nmax]),
            ("mask", vec![boxes_at_level(levels), nmax]),
            ("ctr_re", vec![(boxes_at_level(levels + 1) - 1) / 3]),
            ("ctr_im", vec![(boxes_at_level(levels + 1) - 1) / 3]),
        ];
        let names: Vec<String> = (1..=levels).map(|l| format!("m2l_idx_{l}")).collect();
        for (l, n) in names.iter().enumerate() {
            inputs.push((
                Box::leak(n.clone().into_boxed_str()),
                vec![boxes_at_level(l + 1), kfar[l]],
            ));
        }
        inputs.push(("near_idx", vec![boxes_at_level(levels), knear]));
        inputs.push(("p2l_idx", vec![boxes_at_level(levels), ksp]));
        inputs.push(("m2p_idx", vec![boxes_at_level(levels), ksp]));
        let specs: Vec<String> = inputs
            .iter()
            .map(|(n, s)| {
                let dt = if n.contains("idx") { "i32" } else { "f64" };
                format!(
                    "{{\"name\":\"{n}\",\"shape\":[{}],\"dtype\":\"{dt}\"}}",
                    s.iter()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                )
            })
            .collect();
        let kfar_s = kfar
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let text = format!(
            "{{\"name\":\"test\",\"kind\":\"fmm\",\"levels\":{levels},\"p\":{p},\
             \"nmax\":{nmax},\"kfar\":[{kfar_s}],\"knear\":{knear},\"ksp\":{ksp},\
             \"batch\":{batch},\"nbtot\":{},\"inputs\":[{}],\"outputs\":[]}}",
            (boxes_at_level(levels + 1) - 1) / 3,
            specs.join(",")
        );
        ArtifactMeta::parse(&text).unwrap()
    }

    fn tree(n: usize, levels: usize, seed: u64) -> (Pyramid, Connectivity) {
        let mut r = Pcg64::seed_from_u64(seed);
        let (pts, gs) = workload::uniform_square(n, &mut r);
        let pyr = Pyramid::build(&pts, &gs, levels).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        (pyr, con)
    }

    #[test]
    fn pack_shapes_and_masks() {
        let (pyr, con) = tree(500, 2, 1);
        let need = required_pads(&pyr, &con);
        let meta = meta_for(2, 8, need.nmax + 2, &need.kfar, need.knear, need.ksp);
        let packed = pack_fmm(&pyr, &con, &meta).unwrap();
        assert_eq!(packed.tensors.len(), meta.inputs.len());
        // mask counts the particles exactly
        if let Tensor::F64(mask, _) = &packed.tensors[4] {
            let total: f64 = mask.iter().sum();
            assert_eq!(total as usize, 500);
        } else {
            panic!("mask tensor has wrong dtype");
        }
        // near list entries are within range or -1
        if let Tensor::I32(idx, _) = packed.tensors.last().unwrap() {
            assert!(idx.iter().all(|&v| v >= -1 && (v as i64) < 16));
        } else {
            panic!("m2p tensor has wrong dtype");
        }
    }

    #[test]
    fn pack_rejects_insufficient_pads() {
        let (pyr, con) = tree(800, 2, 2);
        let need = required_pads(&pyr, &con);
        let meta = meta_for(2, 8, need.nmax.saturating_sub(5), &need.kfar, need.knear, need.ksp);
        let err = pack_fmm(&pyr, &con, &meta).unwrap_err().to_string();
        assert!(err.contains("nmax"), "unexpected error: {err}");
    }

    #[test]
    fn pack_rejects_level_mismatch() {
        let (pyr, con) = tree(500, 2, 3);
        let need = required_pads(&pyr, &con);
        let meta = meta_for(3, 8, 64, &[need.kfar[0], need.kfar[1], 64], 32, 8);
        let err = pack_fmm(&pyr, &con, &meta).unwrap_err().to_string();
        assert!(err.contains("levels"), "unexpected error: {err}");
    }

    #[test]
    fn unpack_roundtrip() {
        let (pyr, _) = tree(300, 2, 4);
        let nmax = pyr.max_leaf_len();
        // fabricate a grid whose value encodes the original index
        let nl = pyr.n_leaves();
        let mut pot_re = vec![0.0; nl * nmax];
        for b in 0..nl {
            for (i, q) in pyr.leaf(b).iter().enumerate() {
                pot_re[b * nmax + i] = q.orig as f64;
            }
        }
        let pot_im = vec![0.0; nl * nmax];
        let out = unpack_potentials(&pyr, nmax, &pot_re, &pot_im);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.re, i as f64);
        }
    }

    #[test]
    fn batch_pack_stacks_and_pads_empty_slots() {
        let (pyr_a, con_a) = tree(500, 2, 10);
        let (pyr_b, con_b) = tree(700, 2, 11);
        let mut need = required_pads(&pyr_a, &con_a);
        need.merge(&required_pads(&pyr_b, &con_b));
        let meta = meta_for_batched(2, 8, need.nmax, &need.kfar, need.knear, need.ksp, 3);
        let problems = [(&pyr_a, &con_a), (&pyr_b, &con_b)];
        let packed = pack_fmm_batch(&problems, &meta).unwrap();
        assert_eq!(packed.batch, 3);
        assert_eq!(packed.tensors.len(), meta.inputs.len());
        // every tensor gained a leading batch axis
        for (t, s) in packed.tensors.iter().zip(&meta.inputs) {
            assert_eq!(t.shape()[0], 3);
            assert_eq!(&t.shape()[1..], s.shape.as_slice());
        }
        // the stacked mask counts both problems' particles, pad slot empty
        if let Tensor::F64(mask, _) = &packed.tensors[4] {
            let per_slot = packed.n_leaves * packed.nmax;
            let a: f64 = mask[..per_slot].iter().sum();
            let b: f64 = mask[per_slot..2 * per_slot].iter().sum();
            let pad: f64 = mask[2 * per_slot..].iter().sum();
            assert_eq!(a as usize, 500);
            assert_eq!(b as usize, 700);
            assert_eq!(pad, 0.0);
        } else {
            panic!("mask tensor has wrong dtype");
        }
        // pad-slot gather lists gather nothing
        if let Tensor::I32(idx, _) = packed.tensors.last().unwrap() {
            let per_slot = idx.len() / 3;
            assert!(idx[2 * per_slot..].iter().all(|&v| v == -1));
        } else {
            panic!("m2p tensor has wrong dtype");
        }
    }

    #[test]
    fn batch_pack_rejects_unbatched_and_overfull() {
        let (pyr, con) = tree(500, 2, 12);
        let need = required_pads(&pyr, &con);
        let single = meta_for(2, 8, need.nmax, &need.kfar, need.knear, need.ksp);
        let problems = [(&pyr, &con)];
        let err = pack_fmm_batch(&problems, &single).unwrap_err().to_string();
        assert!(err.contains("batch"), "unexpected error: {err}");

        let one_slot =
            meta_for_batched(2, 8, need.nmax, &need.kfar, need.knear, need.ksp, 1);
        let two = [(&pyr, &con), (&pyr, &con)];
        let err = pack_fmm_batch(&two, &one_slot).unwrap_err().to_string();
        assert!(err.contains("slots"), "unexpected error: {err}");
    }

    #[test]
    fn batch_unpack_slices_one_slot() {
        let (pyr, _) = tree(300, 2, 13);
        let nmax = pyr.max_leaf_len();
        let nl = pyr.n_leaves();
        let stride = nl * nmax;
        // slot 0 is garbage, slot 1 encodes original indices
        let mut pot_re = vec![-7.0; 2 * stride];
        for b in 0..nl {
            for (i, q) in pyr.leaf(b).iter().enumerate() {
                pot_re[stride + b * nmax + i] = q.orig as f64;
            }
        }
        let pot_im = vec![0.0; 2 * stride];
        let out = unpack_potentials_slot(&pyr, nmax, nl, 1, &pot_re, &pot_im);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.re, i as f64);
        }
    }

    #[test]
    fn pad_requirements_merge_is_envelope() {
        let (pyr_a, con_a) = tree(500, 2, 14);
        let (pyr_b, con_b) = tree(900, 2, 15);
        let a = required_pads(&pyr_a, &con_a);
        let b = required_pads(&pyr_b, &con_b);
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.nmax, a.nmax.max(b.nmax));
        assert_eq!(m.knear, a.knear.max(b.knear));
        assert_eq!(m.ksp, a.ksp.max(b.ksp));
        for ((ma, aa), bb) in m.kfar.iter().zip(&a.kfar).zip(&b.kfar) {
            assert_eq!(*ma, (*aa).max(*bb));
        }
    }

    #[test]
    fn level_offset_formula() {
        assert_eq!(level_offset(0), 0);
        assert_eq!(level_offset(1), 1);
        assert_eq!(level_offset(2), 5);
        assert_eq!(level_offset(3), 21);
    }
}
