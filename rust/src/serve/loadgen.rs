//! **`fmm2d loadgen`** — deterministic open-loop load generation plus the
//! chaos gate.
//!
//! Drives a [`Server`] (in-process by default, or a remote daemon over
//! `--connect`) with a paced request stream, then audits the reply ledger:
//!
//! * **exactly-once** — every sent request got exactly one reply (`ok`,
//!   `error`, `expired`, or `overloaded`); zero lost, zero duplicated;
//! * **bit-correctness** — every `ok` digest equals the digest of an
//!   *offline* [`crate::fmm::evaluate`] of the same deterministic workload
//!   on the engine/worker-count the reply advertised (potentials are
//!   bit-reproducible per engine rung × worker count, so the daemon's
//!   answers under churn, panics, and pool rebuilds must match a quiet
//!   offline run bit for bit);
//! * **latency** — p50/p95/p99/max over the server-measured `latency_ms`.
//!
//! [`LoadgenReport::gate`] turns violations into a nonzero exit: this is
//! the acceptance gate the CI serve lane runs under `--faults` with every
//! failpoint armed.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::FmmConfig;
use crate::dispatch::Engine;
use crate::fmm::{self, CpuEngine, FmmOptions};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::workload::Distribution;

use super::protocol::{digest64, Body, EvalRequest};
use super::server::{ServeOptions, ServeStats, Server};

/// Configuration of one loadgen run.
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Target request rate (requests/second, open loop).
    pub rps: f64,
    /// Paced phase duration in seconds (`total = ceil(rps · duration)`).
    pub duration_s: f64,
    /// Problem-size mix as `(n, weight)` pairs, expanded into a
    /// deterministic weighted round-robin pattern.
    pub mix: Vec<(usize, u32)>,
    pub dist: Distribution,
    /// Base RNG seed; request `i` uses `seed + i` (distinct workloads,
    /// all reproducible offline).
    pub seed: u64,
    pub deadline_ms: u64,
    /// Extra burst of back-to-back requests injected halfway through the
    /// paced phase — pushes the queue into admission control so the shed
    /// path is exercised, not just declared.
    pub burst: usize,
    /// Server under test (ignored under `--connect`).
    pub serve: ServeOptions,
    /// Drive a remote daemon at this address instead of an in-process one.
    pub connect: Option<String>,
    /// Failpoint spec to arm before the run (in-process only).
    pub faults: Option<String>,
    /// Verify `ok` digests against offline evaluations (the expensive
    /// half of the gate; on by default).
    pub digest_check: bool,
    /// Fetch the server's metric registry (`--metrics`): over the wire
    /// via `{"op":"stats"}` on `--connect` runs, directly post-drain
    /// in-process. The snapshot is reconciled against the client ledger.
    pub metrics: bool,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        Self {
            rps: 50.0,
            duration_s: 3.0,
            mix: vec![(300, 3), (900, 1)],
            dist: Distribution::Uniform,
            seed: 1,
            deadline_ms: 2_000,
            burst: 0,
            serve: ServeOptions::default(),
            connect: None,
            faults: None,
            digest_check: true,
            metrics: false,
        }
    }
}

/// Parse a `--mix` spec like `300:3,900:1` (or bare `300,900` with unit
/// weights) into `(n, weight)` pairs.
pub fn parse_mix(spec: &str) -> Result<Vec<(usize, u32)>> {
    let mut mix = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (n_str, w_str) = match part.split_once(':') {
            Some((n, w)) => (n, w),
            None => (part, "1"),
        };
        let n: usize = n_str
            .parse()
            .with_context(|| format!("bad mix entry '{part}': n must be an integer"))?;
        let w: u32 = w_str
            .parse()
            .with_context(|| format!("bad mix entry '{part}': weight must be an integer"))?;
        crate::ensure!(n >= 4, "mix entry '{part}': n must be >= 4");
        crate::ensure!(w >= 1, "mix entry '{part}': weight must be >= 1");
        mix.push((n, w));
    }
    crate::ensure!(!mix.is_empty(), "--mix '{spec}' names no problem sizes");
    Ok(mix)
}

/// Outcome of one loadgen run; [`render`](Self::render) for humans,
/// [`gate`](Self::gate) for CI.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub sent: u64,
    pub ok: u64,
    pub errors: u64,
    pub expired: u64,
    pub shed: u64,
    /// Sent requests that never received any reply — must be zero.
    pub lost: u64,
    /// Requests answered more than once — must be zero.
    pub duplicates: u64,
    /// `ok` digests checked against offline evaluations.
    pub digest_checked: u64,
    /// Digest mismatches — must be zero.
    pub digest_mismatch: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub wall_s: f64,
    /// Completed (`ok`) requests per second of wall clock.
    pub throughput: f64,
    /// Server-side counters (in-process runs only).
    pub server: Option<ServeStats>,
    /// Metric-registry snapshot (`--metrics` runs): the `stats` payload
    /// of the `{"op":"stats"}` reply, or the in-process registry read
    /// after drain.
    pub stats: Option<Json>,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "loadgen: sent {} → ok {}, errors {}, expired {}, shed {} \
             (lost {}, duplicates {})\n\
             loadgen: latency ms p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}; \
             {:.1} ok/s over {:.2} s\n\
             loadgen: digests checked {}, mismatches {}",
            self.sent,
            self.ok,
            self.errors,
            self.expired,
            self.shed,
            self.lost,
            self.duplicates,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.max_ms,
            self.throughput,
            self.wall_s,
            self.digest_checked,
            self.digest_mismatch,
        );
        if let Some(st) = &self.server {
            s.push('\n');
            s.push_str(&st.render());
        }
        if let Some(st) = &self.stats {
            s.push_str("\nloadgen: serve metrics ");
            s.push_str(&st.to_string());
        }
        s
    }

    /// Cross-check a `--metrics` snapshot against the client-side ledger.
    /// Admission is settled by the time the snapshot is taken (every eval
    /// line precedes the stats line on the wire), so `accepted + shed`
    /// must equal `sent` unconditionally; when the snapshot is post-drain
    /// (every accepted request already answered — always true in-process)
    /// the per-status counts must agree exactly too. No-op without a
    /// snapshot.
    pub fn reconcile(&self) -> Result<()> {
        let Some(st) = &self.stats else {
            return Ok(());
        };
        let c = |name: &str| -> u64 {
            st.get("counters")
                .and_then(|c| c.get(&format!("serve.{name}")))
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64
        };
        crate::ensure!(
            c("accepted") + c("shed") == self.sent,
            "serve metrics disagree with the ledger: accepted {} + shed {} != sent {}",
            c("accepted"),
            c("shed"),
            self.sent
        );
        let answered = c("ok") + c("errors") + c("expired");
        if answered == c("accepted") {
            for (name, want) in [
                ("ok", self.ok),
                ("errors", self.errors),
                ("expired", self.expired),
                ("shed", self.shed),
            ] {
                crate::ensure!(
                    c(name) == want,
                    "serve.{name} is {} but the client ledger counted {want}",
                    c(name)
                );
            }
        }
        Ok(())
    }

    /// The chaos gate: zero lost replies, zero duplicates, zero digest
    /// mismatches, and every sent request accounted for.
    pub fn gate(&self) -> Result<()> {
        crate::ensure!(
            self.lost == 0,
            "{} request(s) never received a reply",
            self.lost
        );
        crate::ensure!(
            self.duplicates == 0,
            "{} request(s) were answered more than once",
            self.duplicates
        );
        crate::ensure!(
            self.digest_mismatch == 0,
            "{} ok repl(ies) disagree with the offline evaluation bit-for-bit",
            self.digest_mismatch
        );
        let accounted = self.ok + self.errors + self.expired + self.shed;
        crate::ensure!(
            accounted == self.sent,
            "reply ledger does not balance: sent {} but accounted {}",
            self.sent,
            accounted
        );
        Ok(())
    }
}

/// Expand the mix into the deterministic per-request size pattern.
fn size_pattern(mix: &[(usize, u32)]) -> Vec<usize> {
    let mut pat = Vec::new();
    for &(n, w) in mix {
        for _ in 0..w {
            pat.push(n);
        }
    }
    pat
}

fn request_for(i: u64, opts: &LoadgenOptions, pattern: &[usize]) -> EvalRequest {
    EvalRequest {
        id: i,
        body: Body::Generate {
            n: pattern[(i as usize) % pattern.len()],
            dist: opts.dist,
            seed: opts.seed + i,
        },
        cfg: FmmConfig::default(),
        deadline_ms: opts.deadline_ms,
        digest: true,
    }
}

/// The wire form of [`request_for`] for `--connect` runs.
fn request_line(req: &EvalRequest) -> String {
    let mut j = Json::obj();
    j.set("id", Json::Num(req.id as f64));
    if let Body::Generate { n, dist, seed } = &req.body {
        j.set("n", Json::Num(*n as f64))
            .set("seed", Json::Num(*seed as f64));
        match dist {
            Distribution::Uniform => {
                j.set("dist", Json::Str("uniform".into()));
            }
            Distribution::Normal { sigma } => {
                j.set("dist", Json::Str("normal".into()))
                    .set("sigma", Json::Num(*sigma));
            }
            Distribution::Layer { sigma } => {
                j.set("dist", Json::Str("layer".into()))
                    .set("sigma", Json::Num(*sigma));
            }
        }
    }
    j.set("deadline_ms", Json::Num(req.deadline_ms as f64))
        .set("digest", Json::Bool(true));
    j.to_string()
}

/// Run the load test and audit the ledger.
pub fn run(opts: &LoadgenOptions) -> Result<LoadgenReport> {
    crate::ensure!(opts.rps > 0.0, "--rps must be positive");
    crate::ensure!(opts.duration_s > 0.0, "--duration-s must be positive");
    let pattern = size_pattern(&opts.mix);
    crate::ensure!(!pattern.is_empty(), "--mix names no problem sizes");
    let total = (opts.rps * opts.duration_s).ceil() as u64;
    crate::ensure!(total >= 1, "rps × duration yields zero requests");

    if let Some(spec) = &opts.faults {
        crate::ensure!(
            opts.connect.is_none(),
            "--faults arms failpoints in-process; a --connect daemon arms its own via `fmm2d serve --faults`"
        );
        crate::util::failpoint::arm(spec)?;
    }

    let t0 = Instant::now();
    let (replies, server_stats, snapshot) = match &opts.connect {
        Some(addr) => (drive_tcp(addr, opts, &pattern, total)?, None, None),
        None => {
            let (replies, stats, snapshot) = drive_in_process(opts, &pattern, total)?;
            (replies, Some(stats), snapshot)
        }
    };
    let wall_s = t0.elapsed().as_secs_f64();

    // The offline verification below must run on a quiet substrate: any
    // armed failpoint would inject panics into *our* reference
    // evaluations.
    crate::util::failpoint::disarm_all();

    let mut report = audit(opts, &pattern, total, replies, wall_s)?;
    report.server = server_stats;
    if report.stats.is_none() {
        report.stats = snapshot;
    }
    report.reconcile()?;
    Ok(report)
}

/// In-process mode: one [`Server`], paced submissions from this thread,
/// the engine loop on a scoped helper. Returns every reply (including
/// shed/overloaded ones answered at submit time) plus the server's final
/// counter snapshot for [`LoadgenReport::server`].
fn drive_in_process(
    opts: &LoadgenOptions,
    pattern: &[usize],
    total: u64,
) -> Result<(Vec<Json>, ServeStats, Option<Json>)> {
    let server = Server::new(opts.serve.clone())?;
    let replies: Mutex<Vec<Json>> = Mutex::new(Vec::new());
    let push = |j: &Json| {
        replies
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(j.clone());
    };

    // xtask: allow(no-spawn) — loadgen needs the engine loop concurrent
    // with its paced submissions; scoped and joined before returning
    std::thread::scope(|s| {
        let engine = s.spawn(|| server.engine_loop(&push));
        let start = Instant::now();
        let gap = Duration::from_secs_f64(1.0 / opts.rps);
        let burst_at = total / 2;
        let mut next_id = total; // burst ids follow the paced range
        for i in 0..total {
            let target = start + gap.mul_f64(i as f64);
            std::thread::sleep(target.saturating_duration_since(Instant::now()));
            if let Err(reply) = server.submit(request_for(i, opts, pattern)) {
                push(&reply);
            }
            if i == burst_at {
                for _ in 0..opts.burst {
                    if let Err(reply) = server.submit(request_for(next_id, opts, pattern)) {
                        push(&reply);
                    }
                    next_id += 1;
                }
            }
        }
        server.drain();
        engine
            .join()
            .map_err(|_| crate::anyhow!("loadgen engine thread panicked"))
    })?;

    let stats = server.stats();
    // Post-drain snapshot: every accepted request is answered, so the
    // registry must reconcile exactly with the client ledger.
    let snapshot = opts.metrics.then(|| server.stats_json());
    Ok((
        replies.into_inner().unwrap_or_else(|p| p.into_inner()),
        stats,
        snapshot,
    ))
}

/// `--connect` mode: the same paced stream over a TCP connection; replies
/// are read by a scoped thread until the daemon closes the stream after
/// our shutdown line.
fn drive_tcp(
    addr: &str,
    opts: &LoadgenOptions,
    pattern: &[usize],
    total: u64,
) -> Result<Vec<Json>> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to daemon at {addr}"))?;
    let reader = BufReader::new(stream.try_clone().context("cloning connection")?);
    let mut writer = stream;
    let replies: Mutex<Vec<Json>> = Mutex::new(Vec::new());

    // xtask: allow(no-spawn) — reader thread for the reply stream; scoped
    // and joined before returning
    std::thread::scope(|s| -> Result<()> {
        let h = s.spawn(|| {
            let mut reader = reader;
            let mut line = String::new();
            loop {
                line.clear();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                if let Ok(j) = Json::parse(trimmed) {
                    replies.lock().unwrap_or_else(|p| p.into_inner()).push(j);
                }
            }
        });
        let start = Instant::now();
        let gap = Duration::from_secs_f64(1.0 / opts.rps);
        let burst_at = total / 2;
        let mut next_id = total;
        for i in 0..total {
            let target = start + gap.mul_f64(i as f64);
            std::thread::sleep(target.saturating_duration_since(Instant::now()));
            writeln!(writer, "{}", request_line(&request_for(i, opts, pattern)))
                .context("writing request")?;
            if i == burst_at {
                for _ in 0..opts.burst {
                    writeln!(
                        writer,
                        "{}",
                        request_line(&request_for(next_id, opts, pattern))
                    )
                    .context("writing burst request")?;
                    next_id += 1;
                }
            }
        }
        if opts.metrics {
            // Every eval line precedes this on the wire, so the snapshot
            // has final admission counters (evaluation may still be in
            // flight; reconcile() accounts for that).
            writeln!(writer, r#"{{"op":"stats"}}"#).context("writing stats request")?;
        }
        let shutdown_line = r#"{"kind":"shutdown"}"#;
        writeln!(writer, "{shutdown_line}").context("writing shutdown")?;
        writer.flush().context("flushing requests")?;
        h.join()
            .map_err(|_| crate::anyhow!("loadgen reader thread panicked"))?;
        Ok(())
    })?;

    Ok(replies.into_inner().unwrap_or_else(|p| p.into_inner()))
}

/// Audit the ledger: exactly-once accounting, digest verification against
/// offline evaluations, latency percentiles.
fn audit(
    opts: &LoadgenOptions,
    pattern: &[usize],
    total: u64,
    replies: Vec<Json>,
    wall_s: f64,
) -> Result<LoadgenReport> {
    let sent = total + opts.burst as u64;
    let mut seen = vec![0u32; sent as usize];
    let mut report = LoadgenReport {
        sent,
        wall_s,
        ..LoadgenReport::default()
    };
    let mut latencies: Vec<f64> = Vec::new();
    // Offline digest cache: the potentials depend only on the workload and
    // the engine-rung × worker-count the reply advertised, so one offline
    // evaluation per distinct (n, seed, taskgraph?, workers) settles every
    // reply that claims it.
    let mut expected: std::collections::BTreeMap<(usize, u64, bool, usize), u64> =
        std::collections::BTreeMap::new();
    for r in &replies {
        if r.get("status").and_then(Json::as_str) == Some("stats") {
            // The metrics snapshot rides the reply stream but is not part
            // of the exactly-once eval ledger.
            report.stats = r.get("stats").cloned();
            continue;
        }
        let Some(id) = r.get("id").and_then(Json::as_f64) else {
            // id:null replies are decode-error replies — loadgen never
            // sends undecodable lines, so treat one as a lost-reply bug.
            report.lost += 1;
            continue;
        };
        let id = id as u64;
        if id >= sent {
            report.duplicates += 1; // an id we never issued
            continue;
        }
        seen[id as usize] += 1;
        match r.get("status").and_then(Json::as_str) {
            Some("ok") => {
                report.ok += 1;
                if let Some(ms) = r.get("latency_ms").and_then(Json::as_f64) {
                    latencies.push(ms);
                }
                if opts.digest_check {
                    verify_digest(opts, pattern, id, r, &mut expected, &mut report)?;
                }
            }
            Some("error") => report.errors += 1,
            Some("expired") => report.expired += 1,
            Some("overloaded") => report.shed += 1,
            _ => report.errors += 1,
        }
    }
    for &count in &seen {
        if count == 0 {
            report.lost += 1;
        } else if count > 1 {
            report.duplicates += (count - 1) as u64;
        }
    }
    latencies.sort_by(f64::total_cmp);
    let pct = |q: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((latencies.len() as f64 - 1.0) * q).round() as usize;
        latencies[idx.min(latencies.len() - 1)]
    };
    report.p50_ms = pct(0.50);
    report.p95_ms = pct(0.95);
    report.p99_ms = pct(0.99);
    report.max_ms = latencies.last().copied().unwrap_or(0.0);
    report.throughput = if wall_s > 0.0 {
        report.ok as f64 / wall_s
    } else {
        0.0
    };
    Ok(report)
}

fn verify_digest(
    opts: &LoadgenOptions,
    pattern: &[usize],
    id: u64,
    reply: &Json,
    cache: &mut std::collections::BTreeMap<(usize, u64, bool, usize), u64>,
    report: &mut LoadgenReport,
) -> Result<()> {
    let got = reply
        .get("digest")
        .and_then(Json::as_str)
        .and_then(|s| u64::from_str_radix(s, 16).ok());
    let engine = reply.get("engine").and_then(Json::as_str).unwrap_or("");
    let workers = reply
        .get("workers")
        .and_then(Json::as_usize)
        .unwrap_or(1)
        .max(1);
    let Some(got) = got else {
        report.digest_mismatch += 1;
        return Ok(());
    };
    let n = pattern[(id as usize) % pattern.len()];
    let seed = opts.seed + id;
    let taskgraph = engine == "taskgraph";
    let key = (n, seed, taskgraph, workers);
    let want = match cache.get(&key) {
        Some(&d) => d,
        None => {
            // Potentials are bit-reproducible per engine flavor × worker
            // count: the pooled barrier engine at `workers` matches the
            // serial driver when workers == 1, and the taskgraph engine is
            // bitwise-identical to the barrier engine at equal counts — so
            // one Barrier evaluation per key is the reference for all
            // three rungs.
            let (pts, gs) = crate::harness::workload_for(opts.dist, n, seed);
            let fopts = FmmOptions {
                threads: Some(workers),
                cpu_engine: CpuEngine::Barrier,
                ..FmmOptions::default()
            };
            let out = fmm::evaluate(&pts, &gs, &fopts)
                .with_context(|| format!("offline reference evaluation for id {id}"))?;
            let d = digest64(&out.potentials);
            cache.insert(key, d);
            d
        }
    };
    report.digest_checked += 1;
    if got != want {
        report.digest_mismatch += 1;
    }
    Ok(())
}

/// The serve options a loadgen-driven engine choice implies (shared by
/// `cmd_loadgen` and the tests): explicit thread count so the reply
/// contract is stable, sane queue bounds for a short run.
pub fn quick_serve_options(engine: Engine, threads: Option<usize>) -> ServeOptions {
    ServeOptions {
        fmm: FmmOptions {
            threads,
            ..FmmOptions::default()
        },
        engine,
        max_queue: 128,
        ..ServeOptions::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol;

    #[test]
    fn mix_parsing() {
        assert_eq!(parse_mix("300:3,900:1").unwrap(), vec![(300, 3), (900, 1)]);
        assert_eq!(parse_mix("500").unwrap(), vec![(500, 1)]);
        assert!(parse_mix("").is_err());
        assert!(parse_mix("3:1").is_err()); // n < 4
        assert!(parse_mix("300:0").is_err());
        assert!(parse_mix("abc").is_err());
        assert_eq!(size_pattern(&[(300, 2), (900, 1)]), vec![300, 300, 900]);
    }

    #[test]
    fn request_lines_decode_back() {
        let o = LoadgenOptions::default();
        let pat = size_pattern(&o.mix);
        let line = request_line(&request_for(7, &o, &pat));
        let limits = protocol::Limits {
            max_points: 1_000_000,
            default_deadline_ms: 1_000,
        };
        match protocol::decode(&line, &limits).unwrap() {
            protocol::Request::Eval(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.n(), pat[7 % pat.len()]);
                assert_eq!(r.deadline_ms, o.deadline_ms);
                assert!(r.digest);
            }
            other => panic!("expected eval, got {other:?}"),
        }
    }

    /// End-to-end in-process smoke: a tiny run must pass its own gate
    /// (exactly-once + digest parity) with no faults armed.
    #[test]
    fn tiny_run_passes_the_gate() {
        // serialize against lib tests that arm the global failpoint sites
        #[cfg(feature = "failpoints")]
        let _fp = crate::util::failpoint::test_lock();
        let opts = LoadgenOptions {
            rps: 200.0,
            duration_s: 0.05,
            mix: vec![(300, 1)],
            deadline_ms: 30_000,
            serve: quick_serve_options(Engine::Parallel, Some(2)),
            ..LoadgenOptions::default()
        };
        let report = run(&opts).unwrap();
        assert!(report.sent >= 10);
        report.gate().unwrap();
        assert_eq!(report.ok + report.errors + report.expired + report.shed, report.sent);
        assert!(report.digest_checked >= report.ok.min(1));
        // In-process runs must surface the server-side ledger, and it has
        // to agree with the client-side one.
        let st = report.server.expect("in-process run records server stats");
        assert_eq!(st.ok, report.ok);
        assert_eq!(st.answered() + st.shed, report.sent);
    }

    /// `--metrics`: the in-process registry snapshot reconciles with the
    /// exactly-once ledger (run() enforces it; spot-check the payload).
    #[test]
    fn metrics_snapshot_reconciles_in_process() {
        #[cfg(feature = "failpoints")]
        let _fp = crate::util::failpoint::test_lock();
        let opts = LoadgenOptions {
            rps: 200.0,
            duration_s: 0.05,
            mix: vec![(300, 1)],
            deadline_ms: 30_000,
            digest_check: false,
            metrics: true,
            serve: quick_serve_options(Engine::Parallel, Some(2)),
            ..LoadgenOptions::default()
        };
        let report = run(&opts).unwrap();
        let st = report.stats.as_ref().expect("--metrics records a snapshot");
        let c = |name: &str| {
            st.get("counters")
                .and_then(|c| c.get(name))
                .and_then(Json::as_usize)
                .unwrap() as u64
        };
        assert_eq!(c("serve.ok"), report.ok);
        assert_eq!(c("serve.accepted"), report.ok + report.errors + report.expired);
        // one latency sample per ok reply
        let lat = st
            .get("histograms")
            .and_then(|h| h.get("serve.latency_ms"))
            .expect("latency histogram present");
        assert_eq!(
            lat.get("count").and_then(Json::as_usize).unwrap() as u64,
            report.ok
        );
        assert!(report.render().contains("serve metrics"));
    }
}
