//! **`fmm2d serve`** — the FMM as a fault-tolerant service.
//!
//! A long-lived daemon speaking line-delimited JSON (stdin/stdout or TCP)
//! whose core is a robustness layer over the existing engine zoo:
//!
//! * [`protocol`] — the strict wire protocol: request decoding with
//!   boundary validation (non-finite coordinates, hostile `(levels, p, θ)`
//!   ranges, oversized `n` are all structured `error` replies, never
//!   panics), reply builders, and the FNV-1a potential digest the chaos
//!   gate compares against offline `fmm2d run` evaluations.
//! * [`server`] — queueing, admission control (bounded queue depth and
//!   in-flight points; excess traffic is shed with `overloaded` +
//!   `retry_after_ms`), deadline-aware group flushing via
//!   [`crate::batch::BatchPlan`], and the panic-isolation ladder
//!   (taskgraph → pooled → serial with pool rebuild and group bisection).
//! * [`loadgen`] — `fmm2d loadgen`: a deterministic open-loop load
//!   generator + verifier that replays the daemon's `ok` digests against
//!   offline evaluations and enforces the exactly-once ledger.
//!
//! This module owns only the transport: [`serve_lines`] wires a reader and
//! a reply sink to one [`Server`], [`run_stdin`]/[`run_tcp`] bind that to
//! the process's stdio or a listening socket.
//!
//! ## Exactly-once
//!
//! Every line of input gets exactly one reply with the salvaged `id` (or
//! `id: null` when the line was too broken to carry one): decode errors
//! answer immediately from the reader; shed/draining requests answer from
//! [`Server::submit`]; accepted requests answer from the engine loop in
//! every branch of the degradation ladder. The reply writer itself sits
//! behind the `write` failpoint with bounded retries, so the chaos suite
//! also covers transient sink failures.

pub mod loadgen;
pub mod protocol;
pub mod server;

pub use loadgen::{LoadgenOptions, LoadgenReport};
pub use protocol::{decode, digest64, EvalRequest, Limits, Request};
pub use server::{ServeOptions, ServeStats, Server};

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Result of one [`serve_lines`] session.
#[derive(Clone, Copy, Debug)]
pub struct ServeOutcome {
    /// Final counter snapshot.
    pub stats: ServeStats,
    /// The session ended on an explicit `{"kind":"shutdown"}` (as opposed
    /// to EOF / a dropped connection).
    pub shutdown: bool,
}

/// Serialized reply writer shared by the reader thread (decode errors,
/// shed replies) and the engine thread (evaluation replies). One reply is
/// one line; a transient write failure (failpoint `write`) is retried a
/// bounded number of times before the attempt proceeds anyway — the
/// daemon never dies in its reply path.
struct ReplySink<W: Write> {
    out: Mutex<W>,
    retries: AtomicU64,
}

impl<W: Write> ReplySink<W> {
    fn new(out: W) -> Self {
        Self {
            out: Mutex::new(out),
            retries: AtomicU64::new(0),
        }
    }

    fn write(&self, reply: &Json) {
        let line = reply.to_string();
        let mut out = self.out.lock().unwrap_or_else(|p| p.into_inner());
        // Injected transient sink failures (failpoint `write`): retry up
        // to twice per line. The chaos gate asserts zero lost replies, so
        // this bounded loop is exactly what `--faults "write=…"` tests.
        #[cfg(feature = "failpoints")]
        {
            let mut attempts = 0;
            while attempts < 2 && crate::util::failpoint::fire("write") {
                attempts += 1;
                self.retries.fetch_add(1, Ordering::Relaxed);
            }
        }
        // A genuinely broken pipe (client went away) must not kill the
        // daemon; the remaining replies are simply undeliverable.
        let _ = writeln!(out, "{line}");
        let _ = out.flush();
    }

    fn into_inner(self) -> (W, u64) {
        let retries = self.retries.load(Ordering::Relaxed);
        (
            self.out.into_inner().unwrap_or_else(|p| p.into_inner()),
            retries,
        )
    }
}

/// Serve one session: read requests line by line from `input`, write one
/// reply line per request to `output`, until EOF or a `shutdown` request;
/// then drain the queue (every accepted request is still answered) and
/// return the final stats.
pub fn serve_lines<R: BufRead, W: Write + Send>(
    mut input: R,
    output: W,
    opts: ServeOptions,
) -> Result<ServeOutcome> {
    let server = Server::new(opts)?;
    let limits = server.limits();
    let sink = ReplySink::new(output);
    let mut shutdown = false;

    // xtask: allow(no-spawn) — the daemon's one long-lived engine thread;
    // scoped so the borrow of `server`/`sink` provably outlives it, and
    // joined before this function returns (same idiom as run_overlapped)
    std::thread::scope(|s| {
        let engine = s.spawn(|| server.engine_loop(&|reply: &Json| sink.write(reply)));
        let mut line = String::new();
        loop {
            line.clear();
            match input.read_line(&mut line) {
                Ok(0) | Err(_) => break, // EOF or dead transport: drain and exit
                Ok(_) => {}
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if trimmed.len() > protocol::MAX_LINE_BYTES {
                server.note_rejected();
                sink.write(&protocol::reply_error(
                    None,
                    &format!(
                        "request line exceeds {} bytes; send points in batches",
                        protocol::MAX_LINE_BYTES
                    ),
                ));
                continue;
            }
            match protocol::decode(trimmed, &limits) {
                Ok(Request::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Ok(Request::Eval(req)) => {
                    if let Err(reply) = server.submit(*req) {
                        sink.write(&reply);
                    }
                }
                Err(e) => {
                    server.note_rejected();
                    sink.write(&protocol::reply_error(e.id, &format!("{:#}", e.err)));
                }
            }
        }
        server.drain();
        // The engine loop exits once the queue is empty while draining;
        // a panic on the engine thread itself would be a serve bug — the
        // ladder is supposed to have absorbed it — so surface it loudly.
        engine
            .join()
            .map_err(|_| crate::anyhow!("serve engine thread panicked"))
    })?;

    let mut stats = server.stats();
    let (_out, retries) = sink.into_inner();
    stats.write_retries = retries;
    Ok(ServeOutcome { stats, shutdown })
}

/// `fmm2d serve` on stdio: one session over stdin/stdout, stats to stderr.
pub fn run_stdin(opts: ServeOptions) -> Result<ServeOutcome> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let outcome = serve_lines(stdin.lock(), stdout.lock(), opts)?;
    eprintln!("{}", outcome.stats.render());
    Ok(outcome)
}

/// `fmm2d serve --listen ADDR`: accept connections sequentially, one
/// session per connection, until a session ends with `shutdown`.
pub fn run_tcp(addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding serve listener on {addr}"))?;
    let local = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| addr.to_string());
    eprintln!("fmm2d serve: listening on {local}");
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("fmm2d serve: accept failed: {e}");
                continue;
            }
        };
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".to_string());
        let reader = BufReader::new(
            stream
                .try_clone()
                .with_context(|| format!("cloning connection from {peer}"))?,
        );
        let outcome = serve_lines(reader, stream, opts.clone())?;
        eprintln!("fmm2d serve: session from {peer} done");
        eprintln!("{}", outcome.stats.render());
        if outcome.shutdown {
            break;
        }
    }
    Ok(())
}
