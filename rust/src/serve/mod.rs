//! **`fmm2d serve`** — the FMM as a fault-tolerant service.
//!
//! A long-lived daemon speaking line-delimited JSON (stdin/stdout or TCP)
//! whose core is a robustness layer over the existing engine zoo:
//!
//! * [`protocol`] — the strict wire protocol: request decoding with
//!   boundary validation (non-finite coordinates, hostile `(levels, p, θ)`
//!   ranges, oversized `n` are all structured `error` replies, never
//!   panics), reply builders, and the FNV-1a potential digest the chaos
//!   gate compares against offline `fmm2d run` evaluations.
//! * [`server`] — queueing, admission control (bounded queue depth and
//!   in-flight points; excess traffic is shed with `overloaded` +
//!   `retry_after_ms`), deadline-aware group flushing via
//!   [`crate::batch::BatchPlan`], and the panic-isolation ladder
//!   (taskgraph → pooled → serial with pool rebuild and group bisection).
//! * [`loadgen`] — `fmm2d loadgen`: a deterministic open-loop load
//!   generator + verifier that replays the daemon's `ok` digests against
//!   offline evaluations and enforces the exactly-once ledger.
//!
//! This module owns only the transport: [`serve_lines`] wires a reader and
//! a reply sink to one [`Server`], [`run_stdin`]/[`run_tcp`] bind that to
//! the process's stdio or a listening socket.
//!
//! ## Exactly-once
//!
//! Every line of input gets exactly one reply with the salvaged `id` (or
//! `id: null` when the line was too broken to carry one): decode errors
//! answer immediately from the reader; shed/draining requests answer from
//! [`Server::submit`]; accepted requests answer from the engine loop in
//! every branch of the degradation ladder. The reply writer itself sits
//! behind the `write` failpoint with bounded retries, so the chaos suite
//! also covers transient sink failures.

pub mod loadgen;
pub mod protocol;
pub mod server;

pub use loadgen::{LoadgenOptions, LoadgenReport};
pub use protocol::{decode, digest64, EvalRequest, Limits, Request};
pub use server::{ServeOptions, ServeStats, Server};

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::Mutex;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Result of one [`serve_lines`] session.
#[derive(Clone, Copy, Debug)]
pub struct ServeOutcome {
    /// Final counter snapshot.
    pub stats: ServeStats,
    /// The session ended on an explicit `{"kind":"shutdown"}` (as opposed
    /// to EOF / a dropped connection).
    pub shutdown: bool,
}

/// Serialized reply writer shared by the reader thread (decode errors,
/// shed replies) and the engine thread (evaluation replies). One reply is
/// one line; a transient write failure (failpoint `write`) is retried a
/// bounded number of times before the attempt proceeds anyway — the
/// daemon never dies in its reply path. Retries bump the server's
/// `write_retries` counter directly, so [`Server::stats`] is live during
/// the session (single source of truth).
struct ReplySink<'s, W: Write> {
    out: Mutex<W>,
    #[cfg_attr(not(feature = "failpoints"), allow(dead_code))]
    server: &'s Server,
}

impl<'s, W: Write> ReplySink<'s, W> {
    fn new(out: W, server: &'s Server) -> Self {
        Self {
            out: Mutex::new(out),
            server,
        }
    }

    fn write(&self, reply: &Json) {
        let line = reply.to_string();
        let mut out = self.out.lock().unwrap_or_else(|p| p.into_inner());
        // Injected transient sink failures (failpoint `write`): retry up
        // to twice per line. The chaos gate asserts zero lost replies, so
        // this bounded loop is exactly what `--faults "write=…"` tests.
        #[cfg(feature = "failpoints")]
        {
            let mut attempts = 0;
            while attempts < 2 && crate::util::failpoint::fire("write") {
                attempts += 1;
                self.server.note_write_retry();
            }
        }
        // A genuinely broken pipe (client went away) must not kill the
        // daemon; the remaining replies are simply undeliverable.
        let _ = writeln!(out, "{line}");
        let _ = out.flush();
    }
}

/// Outcome of one [`read_line_bounded`] call.
enum LineRead {
    /// EOF (or a dead transport) with no pending bytes.
    Eof,
    /// `buf` holds one line, trailing newline stripped.
    Line,
    /// The line exceeded the cap; it was consumed and discarded without
    /// ever being buffered in full.
    Oversized,
}

/// Read one `\n`-terminated line, buffering at most `cap` bytes. Unlike
/// `BufRead::read_line`, an over-long line is *streamed past* — chunks are
/// consumed and dropped until its newline (or EOF) — so a hostile client
/// sending gigabytes with no newline costs a bounded buffer, not memory
/// exhaustion. This is what makes the [`protocol::MAX_LINE_BYTES`]
/// contract real at the transport layer.
fn read_line_bounded<R: BufRead>(
    input: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut oversized = false;
    loop {
        let chunk = input.fill_buf()?;
        if chunk.is_empty() {
            // EOF: flush whatever we have as a final unterminated line.
            if buf.is_empty() && !oversized {
                return Ok(LineRead::Eof);
            }
            break;
        }
        let (seg, found_nl) = match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => (&chunk[..i], true),
            None => (chunk, false),
        };
        let consume = seg.len() + usize::from(found_nl);
        if !oversized {
            if buf.len() + seg.len() > cap {
                oversized = true;
                buf.clear();
            } else {
                buf.extend_from_slice(seg);
            }
        }
        input.consume(consume);
        if found_nl {
            break;
        }
    }
    Ok(if oversized {
        LineRead::Oversized
    } else {
        LineRead::Line
    })
}

/// Serve one session: read requests line by line from `input`, write one
/// reply line per request to `output`, until EOF or a `shutdown` request;
/// then drain the queue (every accepted request is still answered) and
/// return the final stats.
pub fn serve_lines<R: BufRead, W: Write + Send>(
    mut input: R,
    output: W,
    opts: ServeOptions,
) -> Result<ServeOutcome> {
    let server = Server::new(opts)?;
    let limits = server.limits();
    let sink = ReplySink::new(output, &server);
    let mut shutdown = false;

    // xtask: allow(no-spawn) — the daemon's one long-lived engine thread;
    // scoped so the borrow of `server`/`sink` provably outlives it, and
    // joined before this function returns (same idiom as run_overlapped)
    std::thread::scope(|s| {
        let engine = s.spawn(|| server.engine_loop(&|reply: &Json| sink.write(reply)));
        let mut buf = Vec::new();
        loop {
            match read_line_bounded(&mut input, &mut buf, protocol::MAX_LINE_BYTES) {
                Ok(LineRead::Eof) | Err(_) => break, // EOF or dead transport: drain and exit
                Ok(LineRead::Oversized) => {
                    server.note_rejected();
                    sink.write(&protocol::reply_error(
                        None,
                        &format!(
                            "request line exceeds {} bytes; send points in batches",
                            protocol::MAX_LINE_BYTES
                        ),
                    ));
                    continue;
                }
                Ok(LineRead::Line) => {}
            }
            // Invalid UTF-8 degrades to replacement characters and fails
            // strict decoding below — one structured reply either way.
            let line = String::from_utf8_lossy(&buf);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            match protocol::decode(trimmed, &limits) {
                Ok(Request::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Ok(Request::Eval(req)) => {
                    if let Err(reply) = server.submit(*req) {
                        sink.write(&reply);
                    }
                }
                Ok(Request::Stats) => {
                    // Answered inline from the reader thread: the snapshot
                    // reflects everything counted up to this line, and the
                    // reply never enters the exactly-once eval ledger.
                    sink.write(&protocol::reply_stats(server.stats_json()));
                }
                Err(e) => {
                    server.note_rejected();
                    sink.write(&protocol::reply_error(e.id, &format!("{:#}", e.err)));
                }
            }
        }
        server.drain();
        // The engine loop exits once the queue is empty while draining;
        // a panic on the engine thread itself would be a serve bug — the
        // ladder is supposed to have absorbed it — so surface it loudly.
        engine
            .join()
            .map_err(|_| crate::anyhow!("serve engine thread panicked"))
    })?;

    let stats = server.stats();
    Ok(ServeOutcome { stats, shutdown })
}

/// `fmm2d serve` on stdio: one session over stdin/stdout, stats to stderr.
pub fn run_stdin(opts: ServeOptions) -> Result<ServeOutcome> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let outcome = serve_lines(stdin.lock(), stdout.lock(), opts)?;
    for line in outcome.stats.render().lines() {
        crate::obs::log::info("serve", line, &[]);
    }
    Ok(outcome)
}

/// `fmm2d serve --listen ADDR`: accept connections sequentially, one
/// session per connection, until a session ends with `shutdown`.
pub fn run_tcp(addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding serve listener on {addr}"))?;
    let local = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| addr.to_string());
    crate::obs::log::info("serve", "listening", &[("addr", local)]);
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                crate::obs::log::warn("serve", "accept failed", &[("error", e.to_string())]);
                continue;
            }
        };
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".to_string());
        let reader = BufReader::new(
            stream
                .try_clone()
                .with_context(|| format!("cloning connection from {peer}"))?,
        );
        let outcome = serve_lines(reader, stream, opts.clone())?;
        crate::obs::log::info("serve", "session done", &[("peer", peer)]);
        for line in outcome.stats.render().lines() {
            crate::obs::log::info("serve", line, &[]);
        }
        if outcome.shutdown {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read_all(input: &[u8], cap: usize) -> Vec<Result<String, &'static str>> {
        let mut r = std::io::BufReader::with_capacity(16, input);
        let mut buf = Vec::new();
        let mut lines = Vec::new();
        loop {
            match read_line_bounded(&mut r, &mut buf, cap).unwrap() {
                LineRead::Eof => break,
                LineRead::Line => lines.push(Ok(String::from_utf8(buf.clone()).unwrap())),
                LineRead::Oversized => lines.push(Err("oversized")),
            }
        }
        lines
    }

    #[test]
    fn bounded_reader_splits_lines_and_caps_length() {
        assert_eq!(
            read_all(b"ab\ncd\n", 10),
            vec![Ok("ab".to_string()), Ok("cd".to_string())]
        );
        // final line without a trailing newline still arrives
        assert_eq!(read_all(b"ab", 10), vec![Ok("ab".to_string())]);
        assert!(read_all(b"", 10).is_empty());
        // empty lines pass through (the session loop skips them)
        assert_eq!(
            read_all(b"\nx\n", 10),
            vec![Ok(String::new()), Ok("x".to_string())]
        );
    }

    #[test]
    fn bounded_reader_discards_oversized_lines_without_buffering() {
        // An over-cap line — far larger than the reader's 16-byte internal
        // buffer, so it spans many fill_buf chunks — is reported oversized
        // and fully consumed; the next line decodes normally.
        let mut input = vec![b'x'; 100];
        input.extend_from_slice(b"\nok\n");
        assert_eq!(
            read_all(&input, 8),
            vec![Err("oversized"), Ok("ok".to_string())]
        );
        // oversized with no newline before EOF: still reported, then EOF
        assert_eq!(read_all(&[b'y'; 100], 8), vec![Err("oversized")]);
        // exactly at the cap is fine
        assert_eq!(read_all(b"12345678\n", 8), vec![Ok("12345678".to_string())]);
        // one past the cap is not
        assert_eq!(read_all(b"123456789\n", 8), vec![Err("oversized")]);
    }
}
