//! Wire protocol of `fmm2d serve`: one strict-parsed JSON object per line.
//!
//! Requests are decoded with [`crate::util::json`] under the repo's strict
//! conventions — unknown fields are rejected, trailing garbage is rejected,
//! and every parameter is range-checked *at the boundary* (this module plus
//! [`crate::config::FmmConfig::validate`] /
//! [`crate::workload::Distribution::validate`]) so nothing non-finite or
//! absurd ever reaches an engine. Two request bodies exist:
//!
//! * **generator form** — `{"id":1,"n":2000,"dist":"uniform","seed":7}`:
//!   the daemon synthesizes the workload with the same
//!   [`crate::harness::workload_for`] used by `fmm2d run`, so an offline
//!   run of the same `(dist, n, seed)` is the bit-exact reference;
//! * **inline form** — `{"id":2,"points":[[x,y],…],"gammas":[[re,im],…]}`.
//!
//! A third line form, `{"op":"stats"}`, asks for a snapshot of the
//! server's metric registry (answered inline, never queued).
//!
//! Replies carry a `status` of `ok`, `error`, `overloaded`, `expired`
//! or `stats`;
//! `ok` replies report the engine rung and worker count that produced them
//! (potentials are bit-reproducible only *per engine and worker count* —
//! see `rust/README.md`), plus either the full potentials or an FNV-1a
//! [`digest64`] over their bit patterns.

use crate::complex::C64;
use crate::config::FmmConfig;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::workload::Distribution;

/// Hard cap on one request line; longer lines are rejected with an error
/// reply instead of buffering without bound.
pub const MAX_LINE_BYTES: usize = 8 << 20;

/// Fields the decoder accepts; anything else is a strict-parse error.
const KNOWN_FIELDS: [&str; 14] = [
    "id",
    "kind",
    "op",
    "n",
    "dist",
    "sigma",
    "seed",
    "points",
    "gammas",
    "p",
    "nd",
    "theta",
    "deadline_ms",
    "digest",
];

/// Boundary limits the decoder enforces (from
/// [`crate::serve::ServeOptions`]).
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Largest accepted per-request point count.
    pub max_points: usize,
    /// Deadline applied when a request names none.
    pub default_deadline_ms: u64,
}

/// One decoded request line.
#[derive(Clone, Debug)]
pub enum Request {
    Eval(Box<EvalRequest>),
    /// `{"kind":"shutdown"}` — drain the queue, answer everything, exit.
    Shutdown,
    /// `{"op":"stats"}` — reply with a snapshot of the server's metric
    /// registry. Answered inline by the reader thread (never queued), so
    /// it reflects the ledger at the moment of the request and is not
    /// itself part of the exactly-once accounting.
    Stats,
}

/// How the workload of an eval request is obtained.
#[derive(Clone, Debug)]
pub enum Body {
    /// Synthesized via [`crate::harness::workload_for`] (deterministic).
    Generate {
        n: usize,
        dist: Distribution,
        seed: u64,
    },
    /// Sent inline on the wire.
    Inline { points: Vec<C64>, gammas: Vec<C64> },
}

/// A validated evaluation request.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    /// Client-chosen correlation id, echoed on the reply.
    pub id: u64,
    pub body: Body,
    /// Validated FMM parameters (`p`, `nd`, `theta`; levels from Eq. 5.2).
    pub cfg: FmmConfig,
    /// Per-request deadline budget in milliseconds from arrival.
    pub deadline_ms: u64,
    /// Reply with a digest instead of the full potentials.
    pub digest: bool,
}

impl EvalRequest {
    /// Point count (known before any tree exists — it drives admission
    /// control and `(levels, p)` grouping).
    pub fn n(&self) -> usize {
        match &self.body {
            Body::Generate { n, .. } => *n,
            Body::Inline { points, .. } => points.len(),
        }
    }

    /// Refinement depth this request will run at (Eq. 5.2 — a pure
    /// function of `n` and `nd`, so shape groups form before any tree is
    /// built).
    pub fn levels(&self) -> usize {
        self.cfg.levels_for(self.n())
    }

    /// Produce the workload: generate deterministically or clone the
    /// inline arrays.
    pub fn materialize(&self) -> (Vec<C64>, Vec<C64>) {
        match &self.body {
            Body::Generate { n, dist, seed } => crate::harness::workload_for(*dist, *n, *seed),
            Body::Inline { points, gammas } => (points.clone(), gammas.clone()),
        }
    }
}

/// A decode failure, carrying the request id when one could be salvaged
/// from the (possibly malformed) line so the error reply still correlates.
#[derive(Debug)]
pub struct DecodeError {
    pub id: Option<u64>,
    pub err: crate::util::error::Error,
}

fn get_u64(v: &Json, key: &str) -> Result<Option<u64>> {
    match v.get(key) {
        None => Ok(None),
        Some(j) => {
            let x = j
                .as_f64()
                .ok_or_else(|| crate::anyhow!("field '{key}' must be a number"))?;
            crate::ensure!(
                x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= 9.0e15,
                "field '{key}' must be a non-negative integer (got {x})"
            );
            Ok(Some(x as u64))
        }
    }
}

fn get_f64(v: &Json, key: &str) -> Result<Option<f64>> {
    match v.get(key) {
        None => Ok(None),
        Some(j) => Ok(Some(j.as_f64().ok_or_else(|| {
            crate::anyhow!("field '{key}' must be a number")
        })?)),
    }
}

fn get_bool(v: &Json, key: &str) -> Result<bool> {
    match v.get(key) {
        None => Ok(false),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => crate::bail!("field '{key}' must be a boolean"),
    }
}

/// Parse a `[[a,b],…]` array of pairs into complex numbers, rejecting
/// anything non-finite (`1e999` parses to +inf and is caught here — no
/// NaN/inf can be smuggled through the wire into an engine).
fn get_pairs(v: &Json, key: &str, what: &str) -> Result<Vec<C64>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::anyhow!("field '{key}' must be an array of [x,y] pairs"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let pair = e
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| crate::anyhow!("{what}[{i}] must be a 2-element array"))?;
        let (a, b) = (pair[0].as_f64(), pair[1].as_f64());
        let (a, b) = match (a, b) {
            (Some(a), Some(b)) => (a, b),
            _ => crate::bail!("{what}[{i}] must hold two numbers"),
        };
        crate::ensure!(
            a.is_finite() && b.is_finite(),
            "{what}[{i}] is non-finite ({a}, {b})"
        );
        out.push(C64::new(a, b));
    }
    Ok(out)
}

fn decode_inner(line: &str, limits: &Limits) -> Result<Request> {
    let v = Json::parse(line).context("parsing request line")?;
    let Json::Obj(map) = &v else {
        crate::bail!("request must be a JSON object");
    };
    for key in map.keys() {
        crate::ensure!(
            KNOWN_FIELDS.contains(&key.as_str()),
            "unknown field '{key}' (strict protocol; known fields: {})",
            KNOWN_FIELDS.join(", ")
        );
    }
    if let Some(op) = v.get("op") {
        let name = op
            .as_str()
            .ok_or_else(|| crate::anyhow!("field 'op' must be a string"))?;
        crate::ensure!(name == "stats", "unknown op '{name}': expected stats");
        crate::ensure!(
            map.len() == 1,
            "op:stats takes no other fields (got {} fields)",
            map.len()
        );
        return Ok(Request::Stats);
    }
    match v.get("kind").map(|k| k.as_str()) {
        None => {}
        Some(Some("eval")) => {}
        Some(Some("shutdown")) => {
            crate::ensure!(
                map.len() == 1,
                "shutdown takes no other fields (got {} fields)",
                map.len()
            );
            return Ok(Request::Shutdown);
        }
        Some(Some(other)) => crate::bail!("unknown kind '{other}': expected eval|shutdown"),
        Some(None) => crate::bail!("field 'kind' must be a string"),
    }

    let id = get_u64(&v, "id")?.ok_or_else(|| crate::anyhow!("missing required field 'id'"))?;

    let body = if map.contains_key("points") || map.contains_key("gammas") {
        for banned in ["n", "dist", "sigma", "seed"] {
            crate::ensure!(
                !map.contains_key(banned),
                "field '{banned}' conflicts with inline points/gammas"
            );
        }
        let points = get_pairs(&v, "points", "points")?;
        let gammas = get_pairs(&v, "gammas", "gammas")?;
        crate::ensure!(
            points.len() == gammas.len(),
            "points ({}) and gammas ({}) differ in length",
            points.len(),
            gammas.len()
        );
        Body::Inline { points, gammas }
    } else {
        let n = get_u64(&v, "n")?.ok_or_else(|| {
            crate::anyhow!("missing field 'n' (or inline 'points'/'gammas')")
        })? as usize;
        let sigma = get_f64(&v, "sigma")?.unwrap_or(0.1);
        let dist = match v.get("dist") {
            None => Distribution::Uniform,
            Some(d) => {
                let name = d
                    .as_str()
                    .ok_or_else(|| crate::anyhow!("field 'dist' must be a string"))?;
                Distribution::from_name(name, sigma).context("field 'dist'")?
            }
        };
        let seed = get_u64(&v, "seed")?.unwrap_or(1);
        Body::Generate { n, dist, seed }
    };

    let cfg = FmmConfig {
        p: get_u64(&v, "p")?.unwrap_or(17) as usize,
        n_per_box: get_u64(&v, "nd")?.unwrap_or(45) as usize,
        theta: get_f64(&v, "theta")?.unwrap_or(0.5),
        levels_override: None,
    };
    cfg.validate()?;

    let req = EvalRequest {
        id,
        body,
        cfg,
        deadline_ms: get_u64(&v, "deadline_ms")?.unwrap_or(limits.default_deadline_ms),
        digest: get_bool(&v, "digest")?,
    };
    let n = req.n();
    crate::ensure!(n >= 4, "n must be at least 4 (got {n}): a pyramid needs 4 leaf boxes");
    crate::ensure!(
        n <= limits.max_points,
        "n ({n}) exceeds this server's per-request limit (--max-n {})",
        limits.max_points
    );
    Ok(Request::Eval(Box::new(req)))
}

/// Decode one request line. On failure the error carries any salvageable
/// `id` so the reply still correlates with the request.
pub fn decode(line: &str, limits: &Limits) -> std::result::Result<Request, DecodeError> {
    decode_inner(line, limits).map_err(|err| DecodeError {
        id: Json::parse(line)
            .ok()
            .and_then(|v| get_u64(&v, "id").ok().flatten()),
        err,
    })
}

// ---------------------------------------------------------------------------
// Replies
// ---------------------------------------------------------------------------

/// FNV-1a 64 over the little-endian bit patterns of the potentials:
/// a cheap, dependency-free digest that changes iff any output bit does,
/// rendered as 16 hex digits on the wire.
pub fn digest64(potentials: &[C64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut absorb = |x: f64, h: &mut u64| {
        for b in x.to_bits().to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for c in potentials {
        absorb(c.re, &mut h);
        absorb(c.im, &mut h);
    }
    h
}

fn base(id: u64, status: &str) -> Json {
    let mut j = Json::obj();
    j.set("id", Json::Num(id as f64))
        .set("status", Json::Str(status.into()));
    j
}

/// Successful evaluation reply: engine rung + worker count (the bit
/// reproducibility contract), measured latency, and potentials or digest.
pub fn reply_ok(
    id: u64,
    engine: &str,
    workers: usize,
    latency_ms: f64,
    potentials: &[C64],
    digest_only: bool,
) -> Json {
    let mut j = base(id, "ok");
    j.set("engine", Json::Str(engine.into()))
        .set("workers", Json::Num(workers as f64))
        .set("latency_ms", Json::Num(round3(latency_ms)));
    if digest_only {
        j.set("digest", Json::Str(format!("{:016x}", digest64(potentials))));
    } else {
        let arr = potentials
            .iter()
            .map(|c| Json::Arr(vec![Json::Num(c.re), Json::Num(c.im)]))
            .collect();
        j.set("potentials", Json::Arr(arr));
    }
    j
}

/// Structured failure reply (decode errors, validation errors, evaluation
/// errors, ladder exhaustion). `id` is null when the line was too broken
/// to salvage one.
pub fn reply_error(id: Option<u64>, msg: &str) -> Json {
    let mut j = Json::obj();
    j.set(
        "id",
        match id {
            Some(i) => Json::Num(i as f64),
            None => Json::Null,
        },
    )
    .set("status", Json::Str("error".into()))
    .set("error", Json::Str(msg.into()));
    j
}

/// Admission-control shed: the request was *not* accepted; retry after the
/// hinted backoff.
pub fn reply_overloaded(id: u64, retry_after_ms: u64) -> Json {
    let mut j = base(id, "overloaded");
    j.set("retry_after_ms", Json::Num(retry_after_ms as f64));
    j
}

/// Metrics snapshot reply for `{"op":"stats"}`. Carries no `id` and is
/// excluded from the exactly-once eval ledger (loadgen's audit skips
/// `status:"stats"` lines).
pub fn reply_stats(snapshot: Json) -> Json {
    let mut j = Json::obj();
    j.set("status", Json::Str("stats".into()))
        .set("stats", snapshot);
    j
}

/// The request was accepted but its deadline passed before (or while)
/// its group flushed; the evaluation was skipped.
pub fn reply_expired(id: u64, waited_ms: f64) -> Json {
    let mut j = base(id, "expired");
    j.set("waited_ms", Json::Num(round3(waited_ms)));
    j
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> Limits {
        Limits {
            max_points: 50_000,
            default_deadline_ms: 10_000,
        }
    }

    fn decode_err(line: &str) -> DecodeError {
        match decode(line, &limits()) {
            Err(e) => e,
            Ok(_) => panic!("expected decode error for {line}"),
        }
    }

    #[test]
    fn generator_form_decodes_with_defaults() {
        let r = decode(r#"{"id":7,"n":2000}"#, &limits()).unwrap();
        let Request::Eval(req) = r else {
            panic!("expected eval")
        };
        assert_eq!(req.id, 7);
        assert_eq!(req.n(), 2000);
        assert_eq!(req.cfg, FmmConfig::default());
        assert_eq!(req.deadline_ms, 10_000);
        assert!(!req.digest);
        assert!(matches!(
            req.body,
            Body::Generate {
                dist: Distribution::Uniform,
                seed: 1,
                ..
            }
        ));
        // levels are a pure function of (n, nd) — groups form pre-tree
        assert_eq!(req.levels(), req.cfg.levels_for(2000));
    }

    #[test]
    fn inline_form_decodes_and_matches_generator_workload() {
        let r = decode(
            r#"{"id":1,"points":[[0.1,0.2],[0.3,0.4],[0.5,0.6],[0.7,0.8]],"gammas":[[1,0],[0,1],[-1,0],[0,-1]],"digest":true}"#,
            &limits(),
        )
        .unwrap();
        let Request::Eval(req) = r else {
            panic!("expected eval")
        };
        assert_eq!(req.n(), 4);
        assert!(req.digest);
        let (pts, gs) = req.materialize();
        assert_eq!(pts[1], C64::new(0.3, 0.4));
        assert_eq!(gs[3], C64::new(0.0, -1.0));
    }

    #[test]
    fn shutdown_decodes() {
        assert!(matches!(
            decode(r#"{"kind":"shutdown"}"#, &limits()).unwrap(),
            Request::Shutdown
        ));
        // shutdown with extra fields is malformed, not silently partial
        assert!(decode(r#"{"kind":"shutdown","id":1}"#, &limits()).is_err());
    }

    #[test]
    fn stats_op_decodes_strictly() {
        assert!(matches!(
            decode(r#"{"op":"stats"}"#, &limits()).unwrap(),
            Request::Stats
        ));
        // op:stats rides alone — no id, no eval fields
        assert!(decode(r#"{"op":"stats","id":1}"#, &limits()).is_err());
        assert!(decode(r#"{"op":"flush"}"#, &limits()).is_err());
        assert!(decode(r#"{"op":1}"#, &limits()).is_err());
        let reply = reply_stats(Json::obj()).to_string();
        assert!(reply.contains(r#""status":"stats""#), "{reply}");
        assert!(Json::parse(&reply).is_ok());
    }

    #[test]
    fn strict_errors_carry_salvaged_ids() {
        // truncated line: unparsable, no id salvageable
        assert_eq!(decode_err(r#"{"id":3,"n":100"#).id, None);
        // unknown field: parsable, id salvaged
        let e = decode_err(r#"{"id":3,"n":1000,"bogus":1}"#);
        assert_eq!(e.id, Some(3));
        assert!(format!("{:#}", e.err).contains("unknown field 'bogus'"));
        // wrong top-level type
        assert_eq!(decode_err("[1,2]").id, None);
        // missing id
        assert!(format!("{:#}", decode_err(r#"{"n":1000}"#).err).contains("'id'"));
    }

    #[test]
    fn boundary_validation_rejects_hostile_parameters() {
        for bad in [
            r#"{"id":1,"n":0}"#,                           // too few points
            r#"{"id":1,"n":3}"#,                           // below 4-leaf floor
            r#"{"id":1,"n":100000}"#,                      // over max_points
            r#"{"id":1,"n":1000,"p":0}"#,                  // p out of range
            r#"{"id":1,"n":1000,"p":200}"#,                // p out of range
            r#"{"id":1,"n":1000,"theta":1.5}"#,            // theta out of (0,1)
            r#"{"id":1,"n":1000,"theta":1e999}"#,          // theta = +inf
            r#"{"id":1,"n":1000,"dist":"normal","sigma":-1}"#, // sampler wedge
            r#"{"id":1,"n":1000,"dist":"normal","sigma":1e999}"#, // sigma inf
            r#"{"id":1,"n":1000,"dist":"gauss"}"#,         // unknown dist
            r#"{"id":1,"n":1000,"seed":-3}"#,              // negative integer
            r#"{"id":1,"n":1000,"digest":"yes"}"#,         // non-bool digest
            r#"{"id":-1,"n":1000}"#,                       // negative id
            r#"{"id":1.5,"n":1000}"#,                      // fractional id
        ] {
            assert!(decode(bad, &limits()).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn non_finite_inline_coordinates_are_rejected() {
        // 1e999 overflows to +inf during parsing — the classic smuggle
        let e = decode_err(r#"{"id":9,"points":[[1e999,0.2],[0.3,0.4],[0.1,0.1],[0.2,0.2]],"gammas":[[1,0],[1,0],[1,0],[1,0]]}"#);
        assert_eq!(e.id, Some(9));
        assert!(format!("{:#}", e.err).contains("non-finite"), "{:#}", e.err);
        // mismatched lengths
        assert!(decode(
            r#"{"id":9,"points":[[0.1,0.2],[0.3,0.4]],"gammas":[[1,0]]}"#,
            &limits()
        )
        .is_err());
        // inline + generator fields conflict
        assert!(decode(
            r#"{"id":9,"n":4,"points":[[0.1,0.2]],"gammas":[[1,0]]}"#,
            &limits()
        )
        .is_err());
    }

    #[test]
    fn digest_is_bit_sensitive_and_stable() {
        let a = [C64::new(1.0, 2.0), C64::new(3.0, 4.0)];
        let mut b = a;
        assert_eq!(digest64(&a), digest64(&b));
        b[1].im = f64::from_bits(b[1].im.to_bits() ^ 1); // one ulp
        assert_ne!(digest64(&a), digest64(&b));
        // pinned value: the digest is part of the wire contract
        assert_eq!(format!("{:016x}", digest64(&[])), "cbf29ce484222325");
    }

    #[test]
    fn replies_render_as_strict_json() {
        let ok = reply_ok(4, "pooled", 8, 1.2345678, &[C64::new(1.0, -2.5)], false);
        let s = ok.to_string();
        assert!(s.contains(r#""status":"ok""#), "{s}");
        assert!(s.contains(r#""engine":"pooled""#), "{s}");
        assert!(s.contains(r#""workers":8"#), "{s}");
        // round-trips through the strict parser
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("id").and_then(Json::as_usize), Some(4));
        let err = reply_error(None, "broken").to_string();
        assert!(err.contains(r#""id":null"#), "{err}");
        let shed = reply_overloaded(2, 40).to_string();
        assert!(shed.contains(r#""retry_after_ms":40"#), "{shed}");
        let exp = reply_expired(3, 12.5).to_string();
        assert!(exp.contains(r#""status":"expired""#), "{exp}");
    }
}
