//! The daemon core: queueing, admission control, deadline-aware group
//! flushing, and the panic-isolation / degradation ladder.
//!
//! One [`Server`] owns a queue of accepted requests and a persistent
//! [`WorkerPool`]. Producers call [`Server::submit`] (admission control
//! answers sheds immediately); one engine thread runs
//! [`Server::engine_loop`], which repeatedly:
//!
//! 1. groups the queue by `(levels, p)` via [`BatchPlan::group`] — the
//!    same planner the batch subsystem uses, applied to in-flight traffic;
//! 2. flushes a group when it is **full** (`max_group` members), when its
//!    **oldest member nears its deadline** (`flush_fraction` of the
//!    deadline budget has elapsed), or when the server is **draining**;
//! 3. evaluates the group under `catch_unwind`. A panic anywhere inside —
//!    topology build, a pool worker, the dispatch path — tears down and
//!    rebuilds the pool, then *splits* the group and retries both halves
//!    one rung down the degradation ladder (taskgraph → pooled → serial),
//!    isolating a hostile request to a single-member serial evaluation
//!    before giving up on it with a structured `error` reply.
//!
//! Every accepted request is answered **exactly once** — `ok`, `error`, or
//! `expired` — in every branch of the ladder; shed requests are answered
//! `overloaded` at submit time and never enter the queue. The chaos suite
//! (`tests/serve_chaos.rs`, `fmm2d loadgen --faults`) drives injected
//! panics through all three sites and holds the daemon to that invariant.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::batch::{BatchPlan, ProblemShape};
use crate::dispatch::{Dispatcher, Engine, EngineChoice, Problem};
use crate::fmm::{self, CpuEngine, FmmOptions};
use crate::obs::metrics::{Counter, Gauge, Histogram, Registry};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

use super::protocol::{self, EvalRequest, Limits};

/// Configuration of one daemon instance.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Base evaluation options: `threads` fixes the pool width (and the
    /// bit-reproducibility contract of the replies), `pin`/`topo_threads`
    /// pass through. `pool`/`cpu_engine` are managed by the server.
    pub fmm: FmmOptions,
    /// Engine the ladder starts from: `taskgraph`, `parallel`, `serial`,
    /// or `auto` (per-group dispatch decision; resolves to `parallel` on
    /// an uncalibrated [`Dispatcher::fallback`]). `xla` is rejected.
    pub engine: Engine,
    /// Dispatcher for `--engine auto`; `None` loads the default profile.
    pub dispatcher: Option<Arc<Dispatcher>>,
    /// Flush a `(levels, p)` group at this many members.
    pub max_group: usize,
    /// Admission control: maximum queued requests before shedding.
    pub max_queue: usize,
    /// Admission control: maximum total queued points before shedding.
    pub max_queued_points: usize,
    /// Per-request point cap (decode-time `error`, not a shed).
    pub max_points: usize,
    /// Deadline for requests that name none (milliseconds).
    pub default_deadline_ms: u64,
    /// Flush a group once its oldest member has waited this fraction of
    /// its deadline budget (0 < f ≤ 1). The rest of the budget is left
    /// for the evaluation itself.
    pub flush_fraction: f64,
    /// Log recoveries and flush decisions to stderr.
    pub verbose: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            fmm: FmmOptions::default(),
            engine: Engine::Parallel,
            dispatcher: None,
            max_group: 8,
            max_queue: 256,
            max_queued_points: 2_000_000,
            max_points: 200_000,
            default_deadline_ms: 10_000,
            flush_fraction: 0.5,
            verbose: false,
        }
    }
}

/// Counters of one daemon run; snapshot via [`Server::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Accepted requests answered `ok`.
    pub ok: u64,
    /// Accepted requests answered `error` (evaluation error or ladder
    /// exhaustion).
    pub errors: u64,
    /// Accepted requests answered `expired` (deadline passed pre-eval).
    pub expired: u64,
    /// Requests shed by admission control (`overloaded`; never queued).
    pub shed: u64,
    /// Lines rejected at decode time (`error` with no admission).
    pub rejected: u64,
    /// Groups flushed, by trigger.
    pub flushes_full: u64,
    pub flushes_deadline: u64,
    pub flushes_drain: u64,
    /// Panics caught by the group isolation layer.
    pub recoveries: u64,
    /// Worker pools torn down and rebuilt after a caught panic.
    pub pool_rebuilds: u64,
    /// Ladder steps taken (an engine rung abandoned for a lower one).
    pub degraded: u64,
    /// Transient reply-write failures retried (failpoint `write`).
    pub write_retries: u64,
}

impl ServeStats {
    /// Accepted requests answered so far (the exactly-once ledger).
    pub fn answered(&self) -> u64 {
        self.ok + self.errors + self.expired
    }

    /// Two-line human summary for stderr.
    pub fn render(&self) -> String {
        format!(
            "serve: accepted {} (ok {}, errors {}, expired {}), shed {}, rejected {}\n\
             serve: flushes {} (full {}, deadline {}, drain {}), recoveries {}, \
             pool rebuilds {}, degraded {}, write retries {}",
            self.accepted,
            self.ok,
            self.errors,
            self.expired,
            self.shed,
            self.rejected,
            self.flushes_full + self.flushes_deadline + self.flushes_drain,
            self.flushes_full,
            self.flushes_deadline,
            self.flushes_drain,
            self.recoveries,
            self.pool_rebuilds,
            self.degraded,
            self.write_retries,
        )
    }
}

/// Pre-resolved handles into the server's [`Registry`] — one per ledger
/// counter plus the load gauges and latency/grouping histograms. The
/// exactly-once ledger (`serve.ok + serve.errors + serve.expired =
/// serve.accepted` at drain) lives in the same registry a client reads
/// through `{"op":"stats"}`, so the wire snapshot *is* the ledger.
struct Handles {
    accepted: Counter,
    ok: Counter,
    errors: Counter,
    expired: Counter,
    shed: Counter,
    rejected: Counter,
    flushes_full: Counter,
    flushes_deadline: Counter,
    flushes_drain: Counter,
    recoveries: Counter,
    pool_rebuilds: Counter,
    degraded: Counter,
    write_retries: Counter,
    /// Requests waiting in the queue (updated on submit and flush).
    queue_depth: Gauge,
    /// Total points waiting in the queue.
    queued_points: Gauge,
    /// Per-`ok`-reply latency, admission to reply (ms).
    latency_ms: Histogram,
    /// Members per flushed group (recorded as a raw count, not ms).
    group_size: Histogram,
}

impl Handles {
    fn new(r: &Registry) -> Handles {
        Handles {
            accepted: r.counter("serve.accepted"),
            ok: r.counter("serve.ok"),
            errors: r.counter("serve.errors"),
            expired: r.counter("serve.expired"),
            shed: r.counter("serve.shed"),
            rejected: r.counter("serve.rejected"),
            flushes_full: r.counter("serve.flushes_full"),
            flushes_deadline: r.counter("serve.flushes_deadline"),
            flushes_drain: r.counter("serve.flushes_drain"),
            recoveries: r.counter("serve.recoveries"),
            pool_rebuilds: r.counter("serve.pool_rebuilds"),
            degraded: r.counter("serve.degraded"),
            write_retries: r.counter("serve.write_retries"),
            queue_depth: r.gauge("serve.queue_depth"),
            queued_points: r.gauge("serve.queued_points"),
            latency_ms: r.histogram("serve.latency_ms"),
            group_size: r.histogram("serve.group_size"),
        }
    }
}

/// One accepted request waiting for its group to flush.
struct Pending {
    req: EvalRequest,
    levels: usize,
    arrived: Instant,
    /// Flush trigger: `arrived + flush_fraction · deadline`.
    due_at: Instant,
    /// Hard deadline: `arrived + deadline`.
    deadline: Instant,
}

struct QueueState {
    pending: Vec<Pending>,
    queued_points: usize,
    draining: bool,
}

/// A rung of the degradation ladder, carrying the worker count the reply
/// will advertise (potentials are bit-reproducible per rung × workers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Rung {
    TaskGraph(usize),
    Pooled(usize),
    Serial,
}

impl Rung {
    fn next(self) -> Option<Rung> {
        match self {
            Rung::TaskGraph(w) => Some(Rung::Pooled(w)),
            Rung::Pooled(_) => Some(Rung::Serial),
            Rung::Serial => None,
        }
    }

    fn label(self) -> &'static str {
        match self {
            Rung::TaskGraph(_) => "taskgraph",
            Rung::Pooled(_) => "pooled",
            Rung::Serial => "serial",
        }
    }

    fn workers(self) -> usize {
        match self {
            Rung::TaskGraph(w) | Rung::Pooled(w) => w,
            Rung::Serial => 1,
        }
    }
}

/// Poison-tolerant lock: a panic while holding one of these mutexes is
/// already routed through the recovery ladder, so waiters recover the
/// guard instead of cascading.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// The daemon core. See the module docs for the lifecycle.
pub struct Server {
    opts: ServeOptions,
    /// Resolved base engine (never `Auto` unless a calibrated dispatcher
    /// backs it, never `Xla`).
    engine: Engine,
    dispatcher: Option<Arc<Dispatcher>>,
    /// Fixed pool width (= the `workers` field of pooled/taskgraph
    /// replies).
    threads: usize,
    pool: Mutex<Arc<WorkerPool>>,
    state: Mutex<QueueState>,
    wake: Condvar,
    /// Per-instance metric registry (snapshot via [`Server::stats_json`]).
    metrics: Registry,
    m: Handles,
}

impl Server {
    pub fn new(opts: ServeOptions) -> Result<Server> {
        crate::ensure!(opts.max_group >= 1, "max_group must be >= 1");
        crate::ensure!(opts.max_queue >= 1, "max_queue must be >= 1");
        crate::ensure!(
            opts.flush_fraction > 0.0 && opts.flush_fraction <= 1.0,
            "flush_fraction must lie in (0, 1] (got {})",
            opts.flush_fraction
        );
        let threads = opts.fmm.effective_threads();
        let (engine, dispatcher) = match opts.engine {
            Engine::Xla => {
                crate::bail!("serve runs the CPU engines; --engine xla is not a serve target")
            }
            Engine::Auto => {
                let d = opts
                    .dispatcher
                    .clone()
                    .unwrap_or_else(|| Arc::new(Dispatcher::load_or_default(None)));
                if d.fallback {
                    // Satellite contract: a fresh deployment (no usable
                    // calibration profile) serves traffic on the pooled
                    // engine instead of trusting uncalibrated crossovers.
                    crate::obs::log::warn(
                        "serve",
                        "--engine auto without a calibration profile; \
                         resolving to the pooled engine (run `fmm2d calibrate`)",
                        &[],
                    );
                    (Engine::Parallel, None)
                } else {
                    (Engine::Auto, Some(d))
                }
            }
            e => (e, None),
        };
        let pool = Arc::new(WorkerPool::new(threads, opts.fmm.pin));
        let metrics = Registry::new();
        let m = Handles::new(&metrics);
        Ok(Server {
            engine,
            dispatcher,
            threads,
            pool: Mutex::new(pool),
            state: Mutex::new(QueueState {
                pending: Vec::new(),
                queued_points: 0,
                draining: false,
            }),
            wake: Condvar::new(),
            metrics,
            m,
            opts,
        })
    }

    /// Decode-time limits for [`protocol::decode`].
    pub fn limits(&self) -> Limits {
        Limits {
            max_points: self.opts.max_points,
            default_deadline_ms: self.opts.default_deadline_ms,
        }
    }

    /// Count one decode-time rejection (the producer already wrote the
    /// `error` reply).
    pub fn note_rejected(&self) {
        self.m.rejected.inc();
    }

    /// Count one transiently-failed-then-retried reply write.
    pub fn note_write_retry(&self) {
        self.m.write_retries.inc();
    }

    /// Admission control: accept `req` into the queue, or return the
    /// structured reply (`overloaded` with a backoff hint, or `error`
    /// while draining) that the producer must write instead. Accepted
    /// requests are guaranteed exactly one reply from the engine loop.
    pub fn submit(&self, req: EvalRequest) -> std::result::Result<(), Json> {
        let n = req.n();
        let mut st = locked(&self.state);
        if st.draining {
            self.m.rejected.inc();
            return Err(protocol::reply_error(
                Some(req.id),
                "server is draining and accepts no new requests",
            ));
        }
        if st.pending.len() >= self.opts.max_queue
            || st.queued_points + n > self.opts.max_queued_points
        {
            self.m.shed.inc();
            crate::obs::event(
                "serve",
                "shed",
                &[("n", n as f64), ("queue", st.pending.len() as f64)],
            );
            let retry = self.retry_after_ms(&st);
            return Err(protocol::reply_overloaded(req.id, retry));
        }
        self.m.accepted.inc();
        let now = Instant::now();
        let budget = Duration::from_millis(req.deadline_ms);
        let flush_after = budget.mul_f64(self.opts.flush_fraction);
        st.queued_points += n;
        st.pending.push(Pending {
            levels: req.levels(),
            arrived: now,
            due_at: now + flush_after,
            deadline: now + budget,
            req,
        });
        self.m.queue_depth.set(st.pending.len() as f64);
        self.m.queued_points.set(st.queued_points as f64);
        crate::obs::event(
            "serve",
            "enqueue",
            &[("n", n as f64), ("queue", st.pending.len() as f64)],
        );
        drop(st);
        self.wake.notify_all();
        Ok(())
    }

    /// Deterministic backoff hint: grows with queue pressure so a loadgen
    /// (or a real client) backs off harder the more overloaded we are.
    fn retry_after_ms(&self, st: &QueueState) -> u64 {
        10 + (200 * st.pending.len() as u64) / (self.opts.max_queue.max(1) as u64)
    }

    /// Begin draining: no new admissions; the engine loop flushes what is
    /// queued and returns once everything is answered.
    pub fn drain(&self) {
        locked(&self.state).draining = true;
        self.wake.notify_all();
    }

    /// Snapshot of the run counters.
    pub fn stats(&self) -> ServeStats {
        let m = &self.m;
        ServeStats {
            accepted: m.accepted.get(),
            ok: m.ok.get(),
            errors: m.errors.get(),
            expired: m.expired.get(),
            shed: m.shed.get(),
            rejected: m.rejected.get(),
            flushes_full: m.flushes_full.get(),
            flushes_deadline: m.flushes_deadline.get(),
            flushes_drain: m.flushes_drain.get(),
            recoveries: m.recoveries.get(),
            pool_rebuilds: m.pool_rebuilds.get(),
            degraded: m.degraded.get(),
            write_retries: m.write_retries.get(),
        }
    }

    /// Full registry snapshot (counters + gauges + histograms) as strict
    /// JSON — the payload of the `{"op":"stats"}` wire reply.
    pub fn stats_json(&self) -> Json {
        self.metrics.snapshot()
    }

    /// The engine loop: block until a group is due, flush it, repeat;
    /// returns once draining *and* the queue is empty. Run it on exactly
    /// one thread; `emit` receives every reply (it must be `Sync` because
    /// producers write shed replies concurrently through the same sink).
    pub fn engine_loop(&self, emit: &(dyn Fn(&Json) + Sync)) {
        loop {
            let group = {
                let mut st = locked(&self.state);
                loop {
                    if st.pending.is_empty() {
                        if st.draining {
                            return;
                        }
                        st = self
                            .wake
                            .wait_timeout(st, Duration::from_millis(50))
                            .unwrap_or_else(|p| p.into_inner())
                            .0;
                        continue;
                    }
                    let now = Instant::now();
                    if let Some(g) = self.take_due_group(&mut st, now) {
                        break g;
                    }
                    // Nothing due yet: sleep until the earliest due_at (or
                    // a submit/drain wakes us), capped for responsiveness.
                    let earliest = st.pending.iter().map(|p| p.due_at).min();
                    let wait = earliest
                        .map(|t| t.saturating_duration_since(now))
                        .unwrap_or(Duration::from_millis(50))
                        .clamp(Duration::from_millis(1), Duration::from_millis(50));
                    st = self
                        .wake
                        .wait_timeout(st, wait)
                        .unwrap_or_else(|p| p.into_inner())
                        .0;
                }
            };
            let rung = self.initial_rung(&group);
            self.run_ladder(group, rung, emit);
        }
    }

    /// Pick and remove the most urgent due `(levels, p)` group, if any.
    /// Groups come from [`BatchPlan::group`] over the queue (members stay
    /// in arrival order); a group is due when it is full, when its oldest
    /// member's flush timer fired, or when the server is draining.
    fn take_due_group(&self, st: &mut QueueState, now: Instant) -> Option<Vec<Pending>> {
        let shapes: Vec<ProblemShape> = st
            .pending
            .iter()
            .map(|p| ProblemShape {
                levels: p.levels,
                p: p.req.cfg.p,
                nmax: p.req.n(),
            })
            .collect();
        let plan = BatchPlan::group(&shapes, self.opts.max_group);
        // Most urgent = earliest due member; full groups pre-empt that
        // order (they cost no extra latency and free the most queue).
        let mut best: Option<(&[usize], bool, Instant)> = None;
        for g in &plan.groups {
            let full = g.len() >= self.opts.max_group;
            let earliest = g
                .members
                .iter()
                .map(|&i| st.pending[i].due_at)
                .min()
                .unwrap_or(now);
            let due = full || st.draining || earliest <= now;
            if !due {
                continue;
            }
            let better = match &best {
                None => true,
                Some((_, best_full, best_t)) => {
                    (full && !best_full) || (full == *best_full && earliest < *best_t)
                }
            };
            if better {
                best = Some((&g.members, full, earliest));
            }
        }
        let (members, full, _) = best?;
        let reason = if full {
            self.m.flushes_full.inc();
            "flush_full"
        } else if st.draining {
            self.m.flushes_drain.inc();
            "flush_drain"
        } else {
            self.m.flushes_deadline.inc();
            "flush_deadline"
        };
        self.m.group_size.record(members.len() as f64);
        crate::obs::event(
            "serve",
            reason,
            &[
                ("members", members.len() as f64),
                ("queue", st.pending.len() as f64),
            ],
        );
        let take: std::collections::BTreeSet<usize> = members.iter().copied().collect();
        let mut group = Vec::with_capacity(take.len());
        let mut kept = Vec::with_capacity(st.pending.len() - take.len());
        for (i, p) in st.pending.drain(..).enumerate() {
            if take.contains(&i) {
                st.queued_points -= p.req.n();
                group.push(p);
            } else {
                kept.push(p);
            }
        }
        st.pending = kept;
        self.m.queue_depth.set(st.pending.len() as f64);
        self.m.queued_points.set(st.queued_points as f64);
        Some(group)
    }

    /// Entry rung of the ladder for this group: the configured engine, or
    /// the dispatcher's per-group decision under `--engine auto`.
    fn initial_rung(&self, group: &[Pending]) -> Rung {
        let configured = match self.engine {
            Engine::Serial => Rung::Serial,
            Engine::TaskGraph => Rung::TaskGraph(self.threads),
            _ => Rung::Pooled(self.threads),
        };
        if self.engine != Engine::Auto {
            return configured;
        }
        let Some(d) = &self.dispatcher else {
            return configured;
        };
        let members: Vec<Problem> = group
            .iter()
            .map(|p| Problem::new(p.req.n(), p.levels, p.req.cfg.p, p.req.cfg.theta))
            .collect();
        let decision = d.select_group_capped(&members, Some(self.threads));
        match decision.choice {
            EngineChoice::Serial => Rung::Serial,
            EngineChoice::Pooled { workers } => Rung::Pooled(workers.clamp(1, self.threads)),
            EngineChoice::TaskGraph { workers } => Rung::TaskGraph(workers.clamp(1, self.threads)),
            // serve never executes XLA; take the strongest CPU rung
            EngineChoice::Xla => Rung::TaskGraph(self.threads),
        }
    }

    /// Evaluate `group` at `rung`, stepping down the ladder (and splitting
    /// the group) on caught panics. Emits exactly one reply per member.
    fn run_ladder(&self, group: Vec<Pending>, rung: Rung, emit: &(dyn Fn(&Json) + Sync)) {
        if group.is_empty() {
            return;
        }
        let now = Instant::now();
        let (live, dead): (Vec<Pending>, Vec<Pending>) =
            group.into_iter().partition(|p| now <= p.deadline);
        for p in dead {
            self.m.expired.inc();
            let waited = now.duration_since(p.arrived).as_secs_f64() * 1000.0;
            emit(&protocol::reply_expired(p.req.id, waited));
        }
        if live.is_empty() {
            return;
        }
        match self.try_eval(&live, rung) {
            Ok(replies) => {
                for (ok, reply) in replies {
                    if ok {
                        self.m.ok.inc();
                        if let Some(ms) = reply.get("latency_ms").and_then(Json::as_f64) {
                            self.m.latency_ms.record(ms);
                        }
                    } else {
                        self.m.errors.inc();
                    }
                    emit(&reply);
                }
            }
            Err(panic_msg) => {
                self.m.recoveries.inc();
                crate::obs::event("serve", "recovery", &[("members", live.len() as f64)]);
                self.rebuild_pool();
                if self.opts.verbose {
                    crate::obs::log::info(
                        "serve",
                        "recovered from panic",
                        &[
                            ("rung", rung.label().to_string()),
                            ("members", live.len().to_string()),
                            ("panic", panic_msg.clone()),
                        ],
                    );
                }
                let next = rung.next().unwrap_or(Rung::Serial);
                if next != rung {
                    self.m.degraded.inc();
                }
                if live.len() > 1 {
                    // Split to isolate the hostile member: both halves
                    // retry one rung down (bisection terminates at a
                    // single member on the serial rung).
                    let mut a = live;
                    let b = a.split_off(a.len() / 2);
                    self.run_ladder(a, next, emit);
                    self.run_ladder(b, next, emit);
                } else if rung != Rung::Serial {
                    self.run_ladder(live, next, emit);
                } else {
                    // A single member still panicking on the serial rung:
                    // this request is the fault. Answer it and move on.
                    for p in live {
                        self.m.errors.inc();
                        emit(&protocol::reply_error(
                            Some(p.req.id),
                            &format!("evaluation panicked at every engine rung: {panic_msg}"),
                        ));
                    }
                }
            }
        }
    }

    /// Evaluate every member of `group` at `rung` under one
    /// `catch_unwind`. Returns the replies (ok flag + json) or the panic
    /// message. Replies are only emitted by the caller *after* the whole
    /// group succeeded, so an unwound group re-evaluates members without
    /// ever double-answering.
    #[allow(clippy::type_complexity)]
    fn try_eval(
        &self,
        group: &[Pending],
        rung: Rung,
    ) -> std::result::Result<Vec<(bool, Json)>, String> {
        let pool = locked(&self.pool).clone();
        let _sp = crate::obs::span("serve", "evaluate")
            .arg("members", group.len() as f64)
            .arg("workers", rung.workers() as f64);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Deterministic fault injection for the chaos suite: a crash
            // in the serve dispatch path itself (`failpoints` builds only).
            #[cfg(feature = "failpoints")]
            if crate::util::failpoint::fire("dispatch") {
                // xtask: allow(no-panic) — deliberate fault-injection site,
                // compiled only under the non-default `failpoints` feature
                panic!("failpoint: dispatch");
            }
            let mut replies = Vec::with_capacity(group.len());
            for p in group {
                let (pts, gs) = p.req.materialize();
                let opts = FmmOptions {
                    cfg: p.req.cfg,
                    threads: Some(rung.workers()),
                    topo_threads: self.opts.fmm.topo_threads,
                    pin: self.opts.fmm.pin,
                    pool: Some(Arc::clone(&pool)),
                    cpu_engine: match rung {
                        Rung::TaskGraph(_) => CpuEngine::TaskGraph,
                        _ => CpuEngine::Barrier,
                    },
                    ..FmmOptions::default()
                };
                let reply = match fmm::evaluate(&pts, &gs, &opts) {
                    Ok(out) => {
                        let latency_ms =
                            p.arrived.elapsed().as_secs_f64() * 1000.0;
                        (
                            true,
                            protocol::reply_ok(
                                p.req.id,
                                rung.label(),
                                rung.workers(),
                                latency_ms,
                                &out.potentials,
                                p.req.digest,
                            ),
                        )
                    }
                    Err(e) => (
                        false,
                        protocol::reply_error(Some(p.req.id), &format!("{e:#}")),
                    ),
                };
                replies.push(reply);
            }
            replies
        }));
        caught.map_err(|p| payload_msg(&p))
    }

    /// Tear down the (possibly poisoned) pool and install a fresh one of
    /// the same width. Queued requests and the queue itself are untouched
    /// — only the compute substrate is replaced.
    fn rebuild_pool(&self) {
        self.m.pool_rebuilds.inc();
        let fresh = Arc::new(WorkerPool::new(self.threads, self.opts.fmm.pin));
        *locked(&self.pool) = fresh;
    }
}

fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::{decode, Request};
    use std::sync::Mutex as StdMutex;

    fn small_opts() -> ServeOptions {
        ServeOptions {
            fmm: FmmOptions {
                threads: Some(2),
                ..FmmOptions::default()
            },
            max_group: 4,
            ..ServeOptions::default()
        }
    }

    fn req(server: &Server, line: &str) -> EvalRequest {
        match decode(line, &server.limits()) {
            Ok(Request::Eval(r)) => *r,
            other => panic!("expected eval request, got {other:?}"),
        }
    }

    /// Submit-then-drain: `engine_loop` with `draining` set processes the
    /// whole queue synchronously on the calling thread — no spawns needed
    /// to unit-test the core.
    fn run_to_completion(server: &Server) -> Vec<Json> {
        // under --features failpoints our evaluations pass through the
        // global failpoint sites: serialize against tests that arm them
        #[cfg(feature = "failpoints")]
        let _fp = crate::util::failpoint::test_lock();
        server.drain();
        let replies = StdMutex::new(Vec::new());
        server.engine_loop(&|j: &Json| replies.lock().unwrap().push(j.clone()));
        replies.into_inner().unwrap()
    }

    #[test]
    fn xla_engine_is_rejected() {
        let err = Server::new(ServeOptions {
            engine: Engine::Xla,
            ..small_opts()
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("not a serve target"));
    }

    #[test]
    fn answers_every_accepted_request_exactly_once() {
        let server = Server::new(small_opts()).unwrap();
        for i in 0..6 {
            let line = format!(r#"{{"id":{i},"n":{},"seed":{i},"digest":true}}"#, 500 + i * 100);
            server.submit(req(&server, &line)).unwrap();
        }
        let replies = run_to_completion(&server);
        assert_eq!(replies.len(), 6);
        let mut ids: Vec<usize> = replies
            .iter()
            .map(|r| r.get("id").and_then(Json::as_usize).unwrap())
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        for r in &replies {
            assert_eq!(r.get("status").and_then(Json::as_str), Some("ok"));
        }
        let st = server.stats();
        assert_eq!(st.accepted, 6);
        assert_eq!(st.ok, 6);
        assert_eq!(st.answered(), 6);
    }

    #[test]
    fn overload_sheds_with_retry_hint_and_drain_rejects() {
        let server = Server::new(ServeOptions {
            max_queue: 2,
            ..small_opts()
        })
        .unwrap();
        server.submit(req(&server, r#"{"id":0,"n":500}"#)).unwrap();
        server.submit(req(&server, r#"{"id":1,"n":500}"#)).unwrap();
        let shed = server
            .submit(req(&server, r#"{"id":2,"n":500}"#))
            .unwrap_err();
        assert_eq!(shed.get("status").and_then(Json::as_str), Some("overloaded"));
        assert!(shed.get("retry_after_ms").and_then(Json::as_usize).unwrap() >= 10);
        server.drain();
        let rejected = server
            .submit(req(&server, r#"{"id":3,"n":500}"#))
            .unwrap_err();
        assert_eq!(rejected.get("status").and_then(Json::as_str), Some("error"));
        let replies = run_to_completion(&server);
        assert_eq!(replies.len(), 2, "only the two accepted requests answer");
        assert_eq!(server.stats().shed, 1);
    }

    #[test]
    fn queued_points_bound_sheds_big_requests() {
        let server = Server::new(ServeOptions {
            max_queued_points: 1000,
            ..small_opts()
        })
        .unwrap();
        server.submit(req(&server, r#"{"id":0,"n":800}"#)).unwrap();
        assert!(server.submit(req(&server, r#"{"id":1,"n":800}"#)).is_err());
        let replies = run_to_completion(&server);
        assert_eq!(replies.len(), 1);
    }

    #[test]
    fn expired_deadline_answers_expired_not_ok() {
        let server = Server::new(small_opts()).unwrap();
        server
            .submit(req(&server, r#"{"id":5,"n":600,"deadline_ms":0}"#))
            .unwrap();
        let replies = run_to_completion(&server);
        assert_eq!(replies.len(), 1);
        assert_eq!(
            replies[0].get("status").and_then(Json::as_str),
            Some("expired")
        );
        assert_eq!(server.stats().expired, 1);
    }

    #[test]
    fn groups_form_by_levels_and_p() {
        let server = Server::new(small_opts()).unwrap();
        // same n → same levels; two p values → two groups
        for i in 0..4 {
            let p = if i % 2 == 0 { 10 } else { 17 };
            server
                .submit(req(&server, &format!(r#"{{"id":{i},"n":900,"p":{p}}}"#)))
                .unwrap();
        }
        let replies = run_to_completion(&server);
        assert_eq!(replies.len(), 4);
        let st = server.stats();
        assert_eq!(st.flushes_full + st.flushes_deadline + st.flushes_drain, 2);
    }

    #[test]
    fn full_group_flushes_before_deadline() {
        let server = Server::new(ServeOptions {
            max_group: 2,
            ..small_opts()
        })
        .unwrap();
        // long deadlines: only the size trigger can flush these
        server
            .submit(req(&server, r#"{"id":0,"n":700,"deadline_ms":60000}"#))
            .unwrap();
        server
            .submit(req(&server, r#"{"id":1,"n":700,"deadline_ms":60000}"#))
            .unwrap();
        // Not draining: only the size trigger can flush, and it must do so
        // long before the 60 s deadlines. Run the loop on a helper thread
        // and stop it via drain() once both replies arrived.
        #[cfg(feature = "failpoints")]
        let _fp = crate::util::failpoint::test_lock();
        let replies = StdMutex::new(Vec::new());
        let emit = |j: &Json| replies.lock().unwrap().push(j.clone());
        std::thread::scope(|s| {
            let h = s.spawn(|| server.engine_loop(&emit));
            while server.stats().answered() < 2 {
                std::thread::sleep(Duration::from_millis(5));
            }
            server.drain();
            h.join().unwrap();
        });
        assert_eq!(server.stats().flushes_full, 1);
        assert_eq!(replies.into_inner().unwrap().len(), 2);
    }

    #[test]
    fn degenerate_inline_input_is_answered_exactly_once() {
        // Four coincident points are a degenerate pyramid input (every
        // median split ties). Whatever the evaluator decides — succeed or
        // error — the serve invariant is that the accepted request gets
        // exactly one structured reply and the daemon stays up. (The
        // panic-path variants live in the `failpoints` chaos suite.)
        let server = Server::new(small_opts()).unwrap();
        server
            .submit(req(
                &server,
                r#"{"id":0,"points":[[0.5,0.5],[0.5,0.5],[0.5,0.5],[0.5,0.5]],"gammas":[[1,0],[1,0],[1,0],[1,0]],"digest":true}"#,
            ))
            .unwrap();
        let replies = run_to_completion(&server);
        assert_eq!(replies.len(), 1);
        let status = replies[0].get("status").and_then(Json::as_str).unwrap();
        assert!(
            status == "ok" || status == "error",
            "answered exactly once, with a structured status: {status}"
        );
    }
}
