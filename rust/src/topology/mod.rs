//! The unified **topology build layer**: one entry point for the whole
//! topological phase of the algorithm — Sort (pyramid partitioning,
//! [`crate::tree`]) followed by Connect (θ-classification,
//! [`crate::connectivity`]) — with an engine selector.
//!
//! The paper's headline claim is that *all* steps run on the GPU,
//! "including the initial phase which assembles the topological
//! information" (§3.2, §4.1–4.3). On the CPU side of this reproduction the
//! equivalent requirement is that the topological phase must scale with
//! `--threads` like the computational phase does — otherwise it is the
//! serial prologue that bounds end-to-end and batch throughput. This
//! module owns that choice:
//!
//! * [`TopologyEngine::Serial`] — the reference path: serial quickselect
//!   partitioning and the serial CSR classification (the paper's CPU code,
//!   §4.1/§4.3);
//! * [`TopologyEngine::Parallel`] — both halves sharded over worker
//!   threads ([`Pyramid::build_threaded`],
//!   [`Connectivity::build_threaded`]; on the persistent pool when
//!   [`TopologyOptions::pool`] is set — zero spawns), bit-identical to the
//!   serial path (`tests/topology_parity.rs`);
//! * the existing [`PartitionEngine`] selects the partitioning *model*
//!   (CPU quickselect vs. the functional model of the CUDA two-pass
//!   scatter sort whose [`crate::tree::partition::SortStats`] feed the GPU
//!   cost simulator) orthogonally to the execution engine.
//!
//! [`build`] also measures the wall-clock of each half, so callers (the
//! drivers, the batch runner, the harness) report Sort/Connect timings
//! from one place instead of re-instrumenting the two calls at every call
//! site.

use std::time::Instant;

use crate::complex::C64;
use crate::connectivity::Connectivity;
use crate::tree::{PartitionEngine, Pyramid};
use crate::util::error::Result;

/// Execution engine of the topological phase.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologyEngine {
    /// The serial reference path (the paper's single-threaded CPU code).
    Serial,
    /// Sort and Connect sharded over scoped worker threads; output
    /// bit-identical to `Serial`.
    #[default]
    Parallel,
}

/// Options of one topology build.
#[derive(Clone, Debug)]
pub struct TopologyOptions {
    /// Well-separatedness parameter θ of the Connect classification.
    pub theta: f64,
    pub engine: TopologyEngine,
    /// Partitioning model of the Sort half (CPU quickselect or the GPU
    /// functional model feeding the cost simulator).
    pub partition: PartitionEngine,
    /// Worker threads for [`TopologyEngine::Parallel`]: `None` uses all
    /// available cores. Ignored by `Serial`.
    pub threads: Option<usize>,
    /// Persistent worker pool executing the parallel build's fan-outs
    /// ([`crate::util::pool::WorkerPool`]): `None` falls back to scoped
    /// spawns. Output is identical either way; the pool just spawns no
    /// threads. [`crate::fmm::FmmOptions::topology_options`] fills this in
    /// so a full `evaluate` is spawn-free end to end.
    pub pool: Option<std::sync::Arc<crate::util::pool::WorkerPool>>,
}

impl Default for TopologyOptions {
    fn default() -> Self {
        Self {
            theta: 0.5,
            engine: TopologyEngine::Parallel,
            partition: PartitionEngine::Cpu,
            threads: None,
            pool: None,
        }
    }
}

impl TopologyOptions {
    /// The serial reference configuration at the given θ.
    pub fn serial(theta: f64) -> Self {
        Self {
            theta,
            engine: TopologyEngine::Serial,
            ..Self::default()
        }
    }

    /// The parallel configuration at the given θ with an explicit worker
    /// count (`t ≤ 1` degenerates to the serial path).
    pub fn parallel(theta: f64, threads: usize) -> Self {
        Self {
            theta,
            engine: if threads > 1 {
                TopologyEngine::Parallel
            } else {
                TopologyEngine::Serial
            },
            threads: Some(threads.max(1)),
            ..Self::default()
        }
    }

    /// The same configuration executing on `pool` (see
    /// [`TopologyOptions::pool`]).
    pub fn on_pool(mut self, pool: std::sync::Arc<crate::util::pool::WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Resolved worker count (≥ 1): 1 for `Serial`, otherwise `threads`
    /// or the machine's available parallelism.
    pub fn effective_threads(&self) -> usize {
        match self.engine {
            TopologyEngine::Serial => 1,
            TopologyEngine::Parallel => self
                .threads
                .unwrap_or_else(crate::util::threadpool::available_threads)
                .max(1),
        }
    }
}

/// A fully built topology: the pyramid, its connectivity, and the measured
/// wall-clock of each half (the Sort and Connect rows of Table 5.1).
#[derive(Clone, Debug)]
pub struct Topology {
    pub pyramid: Pyramid,
    pub connectivity: Connectivity,
    /// Measured wall-clock of the Sort half (seconds).
    pub sort_s: f64,
    /// Measured wall-clock of the Connect half (seconds).
    pub connect_s: f64,
}

/// Build the full topology of one problem: Sort then Connect through the
/// selected engine. Errors (instead of panicking) on inputs that cannot
/// form a pyramid — mismatched array lengths, `levels == 0`, fewer
/// particles than leaf boxes — so CLI callers surface clean messages.
pub fn build(
    points: &[C64],
    gammas: &[C64],
    levels: usize,
    opts: &TopologyOptions,
) -> Result<Topology> {
    // Deterministic fault injection for the serve chaos suite: a panic here
    // models a crash in the topology prologue before any phase ran
    // (`failpoints` builds only; see `util::failpoint`).
    #[cfg(feature = "failpoints")]
    if crate::util::failpoint::fire("topology") {
        // xtask: allow(no-panic) — deliberate fault-injection site, compiled
        // only under the non-default `failpoints` feature
        panic!("failpoint: topology");
    }
    let nt = opts.effective_threads();
    let pool = if nt > 1 { opts.pool.as_deref() } else { None };
    let t = Instant::now();
    let sp = crate::obs::span("phase", "Sort")
        .arg("n", points.len() as f64)
        .arg("threads", nt as f64);
    let pyramid = match pool {
        Some(p) => Pyramid::build_on_pool(points, gammas, levels, opts.partition, nt, p)?,
        None => Pyramid::build_threaded(points, gammas, levels, opts.partition, nt)?,
    };
    drop(sp);
    let sort_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let sp = crate::obs::span("phase", "Connect").arg("theta", opts.theta);
    let connectivity = match pool {
        Some(p) => Connectivity::build_on_pool(&pyramid, opts.theta, nt, p),
        None => Connectivity::build_threaded(&pyramid, opts.theta, nt),
    };
    drop(sp);
    let connect_s = t.elapsed().as_secs_f64();
    // Debug builds run the structural validators on every topology, so the
    // whole debug test suite (the parity suites above all) doubles as
    // validator coverage; release callers opt in through `--check`.
    #[cfg(debug_assertions)]
    {
        pyramid.validate()?;
        connectivity.validate(&pyramid)?;
    }
    Ok(Topology {
        pyramid,
        connectivity,
        sort_s,
        connect_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    #[test]
    fn engines_agree_and_times_are_recorded() {
        let mut r = Pcg64::seed_from_u64(5);
        let (pts, gs) = workload::uniform_square(2000, &mut r);
        let serial = build(&pts, &gs, 3, &TopologyOptions::serial(0.5)).unwrap();
        let par = build(&pts, &gs, 3, &TopologyOptions::parallel(0.5, 4)).unwrap();
        assert_eq!(serial.pyramid.starts, par.pyramid.starts);
        assert_eq!(serial.connectivity.checks, par.connectivity.checks);
        assert_eq!(serial.connectivity.near.data, par.connectivity.near.data);
        assert!(serial.sort_s > 0.0 && serial.connect_s > 0.0);
        assert!(par.sort_s > 0.0 && par.connect_s > 0.0);
    }

    #[test]
    fn invalid_input_surfaces_an_error() {
        let (pts, gs) = {
            let mut r = Pcg64::seed_from_u64(6);
            workload::uniform_square(10, &mut r)
        };
        let err = build(&pts, &gs, 4, &TopologyOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("fewer particles"), "got: {err}");
    }

    #[test]
    fn pool_backed_build_is_identical() {
        let mut r = Pcg64::seed_from_u64(7);
        let (pts, gs) = workload::uniform_square(2000, &mut r);
        let serial = build(&pts, &gs, 3, &TopologyOptions::serial(0.5)).unwrap();
        let pool = std::sync::Arc::new(crate::util::pool::WorkerPool::new(4, false));
        let pooled = build(
            &pts,
            &gs,
            3,
            &TopologyOptions::parallel(0.5, 4).on_pool(pool),
        )
        .unwrap();
        assert_eq!(serial.pyramid.starts, pooled.pyramid.starts);
        assert_eq!(serial.connectivity.checks, pooled.connectivity.checks);
        assert_eq!(serial.connectivity.near.data, pooled.connectivity.near.data);
    }

    #[test]
    fn effective_threads_resolution() {
        assert_eq!(TopologyOptions::serial(0.5).effective_threads(), 1);
        assert_eq!(TopologyOptions::parallel(0.5, 3).effective_threads(), 3);
        assert_eq!(TopologyOptions::parallel(0.5, 0).effective_threads(), 1);
        assert!(TopologyOptions::default().effective_threads() >= 1);
    }
}
