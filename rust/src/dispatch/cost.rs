//! The dispatch cost model: per-phase work units and predicted engine
//! times.
//!
//! A problem is priced in two steps. [`Problem::counts`] estimates its
//! [`WorkCounts`] from `(n, levels, p, θ)` alone — before any tree exists
//! ([`WorkCounts::estimate`]) — and [`phase_units`] converts counts into
//! one scalar *work unit* total per phase. CPU predictions divide units by
//! the measured throughputs of a
//! [`CalibrationProfile`](super::profile::CalibrationProfile); the
//! simulated-GPU/XLA side is priced by the analytic
//! [`GpuSim`](crate::gpusim::model::GpuSim) model
//! ([`batched_compute_time_of`](crate::gpusim::model::GpuSim::batched_compute_time_of)
//! for groups, whose topology always builds on the CPU). [`EngineCost`]
//! carries the per-candidate totals that
//! [`Dispatcher::select`](super::select::Dispatcher::select) compares.

use crate::config::FmmConfig;
use crate::fmm::{Phase, WorkCounts, N_PHASES};

use super::profile::EngineRates;

/// Shape summary of one FMM problem — everything the dispatcher needs,
/// available before any tree is built.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Problem {
    /// Number of source points.
    pub n: usize,
    /// Refinement levels (Eq. 5.2 unless overridden).
    pub levels: usize,
    /// Expansion order.
    pub p: usize,
    /// Well-separatedness parameter θ.
    pub theta: f64,
}

impl Problem {
    pub fn new(n: usize, levels: usize, p: usize, theta: f64) -> Self {
        Self { n, levels, p, theta }
    }

    /// The problem an `(cfg, n)` evaluation would run (levels from
    /// Eq. 5.2 / the override, `p` and θ from the config).
    pub fn from_config(cfg: &FmmConfig, n: usize) -> Self {
        Self {
            n,
            levels: cfg.levels_for(n),
            p: cfg.p,
            theta: cfg.theta,
        }
    }

    /// Estimated work counts ([`WorkCounts::estimate`]).
    pub fn counts(&self) -> WorkCounts {
        WorkCounts::estimate(self.n, self.levels, self.p, self.theta)
    }
}

/// Per-phase work units of one evaluation — the architecture-independent
/// operation totals each phase's wall-clock is proportional to:
/// particles·levels (Sort), θ-checks (Connect), coefficient·particle
/// products (P2M/L2P, plus the M2P/P2L shortcut volume), shift-matrix
/// cells (M2M/M2L/L2L) and pairwise interactions (P2P). The calibration
/// pass and the predictor must use the *same* definitions — both call
/// this function.
pub fn phase_units(c: &WorkCounts) -> [f64; N_PHASES] {
    let p1 = (c.p + 1) as f64;
    let cells = p1 * p1;
    let nl = c.leaf_sizes.len().max(1) as f64;
    let avg_box = c.n as f64 / nl;
    let mut u = [0.0; N_PHASES];
    u[Phase::Sort as usize] = c.n as f64 * c.levels.max(1) as f64;
    u[Phase::Connect as usize] = c.connect_checks as f64;
    u[Phase::P2M as usize] = c.p2m_particles as f64 * p1;
    u[Phase::M2M as usize] = c.m2m_per_level.iter().sum::<usize>() as f64 * cells;
    u[Phase::M2L as usize] = c.m2l_per_level.iter().sum::<usize>() as f64 * cells
        + c.p2l_pairs as f64 * avg_box * p1;
    u[Phase::L2L as usize] = c.l2l_per_level.iter().sum::<usize>() as f64 * cells;
    u[Phase::L2P as usize] = c.n as f64 * p1 + c.m2p_pairs as f64 * avg_box * p1;
    u[Phase::P2P as usize] = c.p2p_pairs as f64;
    u
}

/// Predicted end-to-end seconds of `units` on an engine: work over rates
/// plus the engine's fixed per-evaluation overhead.
pub fn cpu_total(rates: &EngineRates, units: &[f64; N_PHASES]) -> f64 {
    units
        .iter()
        .zip(&rates.rates)
        .map(|(u, r)| u / r.max(1.0))
        .sum::<f64>()
        + rates.overhead_s
}

/// Predicted compute-only seconds (P2M … P2P, overhead included; Sort and
/// Connect excluded) — what `evaluate_on_tree` measures against a
/// prebuilt tree, and what the `pool-bench` predicted columns use.
pub fn cpu_compute(rates: &EngineRates, units: &[f64; N_PHASES]) -> f64 {
    units
        .iter()
        .zip(&rates.rates)
        .enumerate()
        .filter(|(i, _)| *i != Phase::Sort as usize && *i != Phase::Connect as usize)
        .map(|(_, (u, r))| u / r.max(1.0))
        .sum::<f64>()
        + rates.overhead_s
}

/// Predicted cost of one problem (or one batch group) on every candidate
/// engine — what [`Dispatcher::select`](super::select::Dispatcher::select)
/// compares and the `DispatchReport` prints.
/// Scope: for **single problems** the predictions are end to end (the
/// topology engine follows the choice, so Sort/Connect legitimately
/// differs per candidate); for **batch groups** they cover the compute
/// dispatch only — the runner builds every topology on the CPU per
/// problem whatever the group's engine, so that cost is common (see
/// [`Dispatcher::select_group`](super::select::Dispatcher::select_group)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineCost {
    /// Serial reference driver.
    pub serial_s: f64,
    /// Pooled engine (single problems: best calibrated worker count
    /// under the cap; groups: the entry nearest the executed budget).
    pub pooled_s: f64,
    /// Calibrated worker count backing the pooled prediction.
    pub pooled_workers: usize,
    /// Task-graph pipelined engine (same candidate rules as pooled, over
    /// the profile's task-graph entries).
    pub taskgraph_s: f64,
    /// Calibrated worker count backing the task-graph prediction.
    pub taskgraph_workers: usize,
    /// Simulated GPU / batched XLA dispatch
    /// ([`GpuSim`](crate::gpusim::model::GpuSim), transfers included).
    pub gpu_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fmm::PHASE_NAMES;

    #[test]
    fn units_cover_every_phase() {
        let c = WorkCounts::estimate(10_000, 3, 17, 0.5);
        let u = phase_units(&c);
        for (i, name) in PHASE_NAMES.iter().enumerate() {
            assert!(u[i] > 0.0, "{name} units must be positive");
        }
        // P2P dominates a 3-level 10k-point problem
        assert!(u[Phase::P2P as usize] > u[Phase::M2M as usize]);
    }

    #[test]
    fn cpu_times_scale_with_rates() {
        let c = WorkCounts::estimate(10_000, 3, 17, 0.5);
        let u = phase_units(&c);
        let slow = EngineRates {
            rates: [1.0e7; N_PHASES],
            overhead_s: 0.0,
        };
        let fast = EngineRates {
            rates: [4.0e7; N_PHASES],
            overhead_s: 0.0,
        };
        let (ts, tf) = (cpu_total(&slow, &u), cpu_total(&fast, &u));
        assert!((ts / tf - 4.0).abs() < 1e-9);
        assert!(cpu_compute(&slow, &u) < ts, "compute excludes Sort/Connect");
    }
}
