//! Autotuned multi-backend dispatch: pick the engine per problem and per
//! batch group from a calibrated cost model.
//!
//! The repo carries several interchangeable execution paths — the serial
//! reference driver, the pooled multithreaded engine, the task-graph
//! pipelined engine ([`crate::fmm::taskgraph`]), the scoped
//! spawn-per-phase baseline and the batched XLA/simulated-GPU path — and
//! until this subsystem existed the choice between them was a CLI flag.
//! Following the companion work on hybrid CPU/GPU balancing (Holm et al.,
//! arXiv:1311.1006) and the task-scheduling layer of Agullo et al.
//! (arXiv:1206.0115), `dispatch` owns that placement decision:
//!
//! 1. **Calibration** ([`profile`]): `fmm2d calibrate [--quick]` measures
//!    per-phase CPU throughput for the serial and pooled engines (per
//!    worker count) and persists a versioned JSON
//!    [`CalibrationProfile`] (`~/.cache/fmm2d/profile.json` or
//!    `--profile <file>`; strict parsing — version mismatches and unknown
//!    fields are rejected).
//! 2. **Cost model** ([`cost`]): [`Problem`] describes an evaluation by
//!    `(n, levels, p, θ)` alone;
//!    [`WorkCounts::estimate`](crate::fmm::WorkCounts::estimate) prices
//!    it *before any tree exists*, [`phase_units`] converts counts to
//!    work units, and the
//!    profile's measured throughputs plus
//!    [`GpuSim::batched_total_time`](crate::gpusim::model::GpuSim::batched_total_time)
//!    yield an [`EngineCost`] per candidate.
//! 3. **Selection** ([`select`]): [`Dispatcher::select`] resolves one
//!    problem, [`Dispatcher::select_group`] one shape-compatible batch
//!    group — small groups stay on the pool, large padded groups go to
//!    the batched XLA path (when the build can run it). Both `fmm2d run`
//!    and [`crate::batch::run`] expose the result as `--engine auto` /
//!    [`BatchEngine::Auto`](crate::batch::BatchEngine::Auto), and every
//!    decision (all candidate predictions + the measured time of the
//!    chosen engine) is surfaced in a [`DispatchReport`].
//!
//! Determinism: selection is pure arithmetic over the profile — the same
//! profile and the same problems always produce the same choices; the
//! chosen CPU engines agree with the explicitly-selected ones to ≤ 1e-12
//! (`tests/dispatch.rs`).

pub mod cost;
pub mod profile;
pub mod select;

pub use cost::{cpu_compute, cpu_total, phase_units, EngineCost, Problem};
pub use profile::{
    CalibrationOptions, CalibrationProfile, EngineRates, PooledRates, PROFILE_VERSION,
};
pub use select::{
    evaluate_auto, execute_cpu_choice, Decision, DispatchReport, Dispatcher, Engine,
    EngineChoice, ENGINE_NAMES,
};
