//! Engine selection: the [`Dispatcher`] picks where each problem (and
//! each batch group) runs, from the calibrated cost model.
//!
//! Candidates are the serial reference driver, the pooled multithreaded
//! engine (at the best calibrated worker count) and — when the build can
//! actually execute it ([`Dispatcher::allow_xla`], default: the `pjrt`
//! feature) — the batched XLA path priced by the simulated-GPU model.
//! Selection is pure arithmetic over the profile: the same profile and
//! the same problems always produce the same choices
//! (`tests/dispatch.rs`).
//!
//! Every decision is recorded as a [`Decision`] (all candidate
//! predictions, the choice, and — once the work ran — the measured time)
//! and surfaced through a [`DispatchReport`] by the CLI (`run`/`batch`
//! `--engine auto`, `dispatch-bench`), which is how calibration drift
//! stays visible.

use std::fmt;
use std::path::Path;
use std::str::FromStr;
use std::time::Instant;

use crate::complex::C64;
use crate::fmm::{self, FmmOptions, FmmOutput, WorkCounts, N_PHASES};
use crate::gpusim::model::GpuSim;
use crate::util::error::Result;

use super::cost::{self, EngineCost, Problem};
use super::profile::CalibrationProfile;

/// The CLI engine selector (`--engine`), shared by `run` and `batch` so
/// the name list and its error message exist exactly once.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// The serial reference driver.
    Serial,
    /// The pooled multithreaded engine (the default).
    #[default]
    Parallel,
    /// The task-graph pipelined engine ([`crate::fmm::taskgraph`]):
    /// dependency-gated phases on the same pool, no phase barriers.
    TaskGraph,
    /// The AOT-compiled XLA path (needs the `pjrt` feature).
    Xla,
    /// Resolve per problem / per batch group from the calibrated cost
    /// model ([`Dispatcher`]).
    Auto,
}

/// Valid `--engine` names, in parse order.
pub const ENGINE_NAMES: [&str; 5] = ["serial", "parallel", "taskgraph", "xla", "auto"];

impl FromStr for Engine {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Engine> {
        match s {
            "serial" => Ok(Engine::Serial),
            "parallel" => Ok(Engine::Parallel),
            "taskgraph" => Ok(Engine::TaskGraph),
            "xla" => Ok(Engine::Xla),
            "auto" => Ok(Engine::Auto),
            other => Err(crate::anyhow!(
                "unknown engine '{other}': expected one of {}",
                ENGINE_NAMES.join("|")
            )),
        }
    }
}

impl fmt::Display for Engine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Engine::Serial => "serial",
            Engine::Parallel => "parallel",
            Engine::TaskGraph => "taskgraph",
            Engine::Xla => "xla",
            Engine::Auto => "auto",
        })
    }
}

/// A resolved placement for one problem or batch group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// The serial reference driver.
    Serial,
    /// The pooled multithreaded engine at the given worker count.
    Pooled { workers: usize },
    /// The task-graph pipelined engine at the given worker count.
    TaskGraph { workers: usize },
    /// The batched XLA / simulated-GPU path.
    Xla,
}

impl fmt::Display for EngineChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineChoice::Serial => f.write_str("serial"),
            EngineChoice::Pooled { workers } => write!(f, "pooled({workers})"),
            EngineChoice::TaskGraph { workers } => write!(f, "taskgraph({workers})"),
            EngineChoice::Xla => f.write_str("xla"),
        }
    }
}

/// One dispatch decision: what was predicted for every candidate, what
/// was chosen, and (once run) what it actually took.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Human-readable target, e.g. `n=20000 L4 p17` or
    /// `group L2 p17 ×64 (n=128000)`.
    pub label: String,
    /// Problems behind this decision (1 for a single evaluation).
    pub members: usize,
    pub choice: EngineChoice,
    /// Predicted seconds per candidate engine.
    pub cost: EngineCost,
    /// Predicted seconds of the chosen engine.
    pub predicted_s: f64,
    /// Measured wall-clock of the chosen engine, filled in by whoever ran
    /// the work (`None` until then). For batch groups this is the group's
    /// *dispatch* (compute) wall-clock — the topology prologue is shared
    /// by all CPU candidates and timed separately.
    pub measured_s: Option<f64>,
}

impl Decision {
    /// Engine-family key of the choice, without the worker count — the
    /// metric/trace label (`dispatch.drift.<key>`).
    pub fn engine_key(&self) -> &'static str {
        match self.choice {
            EngineChoice::Serial => "serial",
            EngineChoice::Pooled { .. } => "pooled",
            EngineChoice::TaskGraph { .. } => "taskgraph",
            EngineChoice::Xla => "xla",
        }
    }

    /// Relative prediction error `measured/predicted − 1` (0 while
    /// unmeasured or when the prediction degenerated to zero).
    pub fn drift(&self) -> f64 {
        match self.measured_s {
            Some(m) if self.predicted_s > 0.0 => m / self.predicted_s - 1.0,
            _ => 0.0,
        }
    }

    /// Self-observability of the dispatcher (DESIGN.md §12): record this
    /// decision's predicted-vs-measured outcome as a `dispatch` trace
    /// event and fold it into the rolling per-engine drift gauge
    /// `dispatch.drift.<engine>` of the global metrics registry. Call
    /// after `measured_s` is filled; a no-op before that.
    pub fn record_drift(&self) {
        let Some(measured) = self.measured_s else {
            return;
        };
        let drift = self.drift();
        crate::obs::event(
            "dispatch",
            self.engine_key(),
            &[
                ("predicted_s", self.predicted_s),
                ("measured_s", measured),
                ("drift", drift),
                ("members", self.members as f64),
            ],
        );
        crate::obs::metrics::global()
            .gauge(&format!("dispatch.drift.{}", self.engine_key()))
            .ewma(drift, 0.2);
    }
}

/// The decisions of one `--engine auto` invocation, rendered by the CLI.
#[derive(Clone, Debug, Default)]
pub struct DispatchReport {
    pub decisions: Vec<Decision>,
}

impl DispatchReport {
    /// Aligned text table: every candidate's predicted time, the choice,
    /// and measured-over-predicted drift where a measurement exists.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let width = self
            .decisions
            .iter()
            .map(|d| d.label.len())
            .max()
            .unwrap_or(0)
            .max(8);
        let mut out = String::new();
        let _ = writeln!(out, "# dispatch report (seconds; predicted per candidate)");
        let _ = writeln!(
            out,
            "{:<width$} {:>12} {:>12} {:>12} {:>12} {:>14} {:>12} {:>12} {:>9}",
            "target",
            "serial",
            "pooled",
            "taskgraph",
            "gpu/xla",
            "chosen",
            "predicted",
            "measured",
            "meas/pred"
        );
        for d in &self.decisions {
            let measured = d
                .measured_s
                .map(|m| format!("{m:>12.6}"))
                .unwrap_or_else(|| format!("{:>12}", "-"));
            let drift = d
                .measured_s
                .map(|m| format!("{:>9.2}", m / d.predicted_s.max(1e-12)))
                .unwrap_or_else(|| format!("{:>9}", "-"));
            let _ = writeln!(
                out,
                "{:<width$} {:>12.6} {:>12.6} {:>12.6} {:>12.6} {:>14} {:>12.6} {measured} {drift}",
                d.label,
                d.cost.serial_s,
                d.cost.pooled_s,
                d.cost.taskgraph_s,
                d.cost.gpu_s,
                d.choice.to_string(),
                d.predicted_s,
            );
        }
        out
    }
}

/// The autotuned engine selector: a calibration profile plus the GPU cost
/// simulator. Construction is cheap; selection is pure arithmetic.
#[derive(Clone, Debug)]
pub struct Dispatcher {
    pub profile: CalibrationProfile,
    /// Prices the batched XLA candidate
    /// ([`GpuSim::batched_total_time`]).
    pub sim: GpuSim,
    /// Whether the XLA candidate may be *chosen* (it is always priced for
    /// the report). Defaults to whether this build can execute it — the
    /// `pjrt` feature.
    pub allow_xla: bool,
    /// True when this dispatcher runs on the *built-in* fallback rates
    /// because no usable calibration profile existed (missing, corrupt, or
    /// stale version). Long-lived consumers — `fmm2d serve` — use this to
    /// resolve `--engine auto` to the pooled engine instead of trusting
    /// uncalibrated crossovers; one-shot CLI runs keep the fallback
    /// predictions (the report labels them).
    pub fallback: bool,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new(CalibrationProfile::fallback())
    }
}

impl Dispatcher {
    pub fn new(profile: CalibrationProfile) -> Self {
        Self {
            profile,
            sim: GpuSim::c2075(),
            allow_xla: cfg!(feature = "pjrt"),
            fallback: false,
        }
    }

    /// Builder: override whether the XLA candidate may be chosen.
    pub fn with_xla(mut self, allow: bool) -> Self {
        self.allow_xla = allow;
        self
    }

    /// Builder: override the GPU architecture model.
    pub fn with_sim(mut self, sim: GpuSim) -> Self {
        self.sim = sim;
        self
    }

    /// Load a profile from `path` (strict: version/unknown-field errors
    /// surface).
    pub fn load(path: &Path) -> Result<Dispatcher> {
        Ok(Dispatcher::new(CalibrationProfile::load(path)?))
    }

    /// Load from `path`, or the default profile location, or — when no
    /// usable profile exists — the built-in fallback rates with
    /// [`Dispatcher::fallback`] set. Never errors (the library entry
    /// points stay usable before the first `calibrate`; a fresh deployment
    /// must serve traffic before it has measured anything), and warns on
    /// stderr *once per process* why it fell back — a corrupt or
    /// stale-version file that exists, or no file at all — so a missing or
    /// broken profile cannot silently skew decisions forever.
    pub fn load_or_default(path: Option<&Path>) -> Dispatcher {
        let candidate = path
            .map(Path::to_path_buf)
            .unwrap_or_else(CalibrationProfile::default_path);
        match CalibrationProfile::load(&candidate) {
            Ok(p) => Dispatcher::new(p),
            Err(e) => {
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    if candidate.exists() {
                        crate::obs::log::warn(
                            "dispatch",
                            "ignoring dispatch profile; using built-in fallback rates \
                             (re-run `fmm2d calibrate`)",
                            &[
                                ("path", candidate.display().to_string()),
                                ("error", format!("{e:#}")),
                            ],
                        );
                    } else {
                        crate::obs::log::warn(
                            "dispatch",
                            "no dispatch profile; using built-in fallback rates (run \
                             `fmm2d calibrate` to enable measured `auto` decisions)",
                            &[("path", candidate.display().to_string())],
                        );
                    }
                });
                Dispatcher {
                    fallback: true,
                    ..Dispatcher::default()
                }
            }
        }
    }

    // ---- single problems ----------------------------------------------

    /// Predicted cost of one problem on every candidate engine.
    pub fn predict(&self, p: &Problem) -> EngineCost {
        self.predict_capped(p, None)
    }

    /// [`Dispatcher::predict`] with the pooled candidate restricted to at
    /// most `cap` workers (the CLI's `--threads`).
    pub fn predict_capped(&self, p: &Problem, cap: Option<usize>) -> EngineCost {
        let c = p.counts();
        let u = cost::phase_units(&c);
        let serial_s = cost::cpu_total(&self.profile.serial, &u);
        let (pooled_s, pooled_workers) =
            best_entry(&self.profile.pooled, serial_s, cap, |rates| {
                cost::cpu_total(rates, &u)
            });
        let (taskgraph_s, taskgraph_workers) =
            best_entry(&self.profile.taskgraph, serial_s, cap, |rates| {
                cost::cpu_total(rates, &u)
            });
        EngineCost {
            serial_s,
            pooled_s,
            pooled_workers,
            taskgraph_s,
            taskgraph_workers,
            gpu_s: self.sim.total_time(&c),
        }
    }

    /// Pick the engine for one problem ([`Dispatcher::predict`] + argmin;
    /// ties keep the earlier candidate in serial → pooled → taskgraph →
    /// xla order).
    pub fn select(&self, p: &Problem) -> Decision {
        self.select_capped(p, None)
    }

    /// [`Dispatcher::select`] with a pooled worker cap.
    pub fn select_capped(&self, p: &Problem, cap: Option<usize>) -> Decision {
        let cost = self.predict_capped(p, cap);
        let (choice, predicted_s) = self.pick(&cost);
        Decision {
            label: format!("n={} L{} p{}", p.n, p.levels, p.p),
            members: 1,
            choice,
            cost,
            predicted_s,
            measured_s: None,
        }
    }

    // ---- batch groups --------------------------------------------------

    /// Pick the engine for one shape-compatible batch group.
    ///
    /// Group candidates are priced over the **compute dispatch only**
    /// (P2M … P2P; [`cost::cpu_compute`] and
    /// [`GpuSim::batched_compute_time_of`]): the batch runner builds
    /// every topology on the CPU per problem regardless of the group's
    /// engine, so Sort/Connect is a common cost no choice can avoid —
    /// and the group's `measured_s` covers exactly that dispatch. The
    /// pooled candidate mirrors the runner's actual rule at the executed
    /// thread budget: groups with at least as many members as workers
    /// stream through the problem-claiming dispatch (each worker running
    /// the serial driver), smaller groups run the per-problem pooled
    /// engine; the XLA candidate is one batched fixed-shape dispatch.
    pub fn select_group(&self, members: &[Problem]) -> Decision {
        self.select_group_capped(members, None)
    }

    /// [`Dispatcher::select_group`] with the thread budget the batch
    /// runner will actually execute with (`None` = all cores). The
    /// pooled prediction uses the calibrated entry nearest that budget,
    /// which is also the `workers` it reports.
    pub fn select_group_capped(&self, members: &[Problem], cap: Option<usize>) -> Decision {
        let counts: Vec<WorkCounts> = members.iter().map(Problem::counts).collect();
        let units: Vec<[f64; N_PHASES]> = counts.iter().map(cost::phase_units).collect();
        let serial_each: Vec<f64> = units
            .iter()
            .map(|u| cost::cpu_compute(&self.profile.serial, u))
            .collect();
        let serial_s: f64 = serial_each.iter().sum();
        let max_serial = serial_each.iter().cloned().fold(0.0f64, f64::max);
        // the runner dispatches with its configured thread budget, not
        // with whatever counts the profile happens to carry — predict at
        // that budget, priced with the largest calibrated entry the
        // budget can honor (entries above the cap would flatter the
        // pooled candidate; like `best_pooled`, fall back to serial when
        // none qualifies)
        let nt = cap
            .unwrap_or_else(crate::util::threadpool::available_threads)
            .max(1);
        let group_time = |e: &super::profile::PooledRates| {
            if members.len() >= nt.max(2) {
                // problem-claiming dispatch: nt workers run the
                // serial driver, bounded below by the widest member
                (serial_s / nt as f64).max(max_serial) + e.rates.overhead_s
            } else {
                units.iter().map(|u| cost::cpu_compute(&e.rates, u)).sum()
            }
        };
        let (pooled_s, pooled_workers) = match self.profile.pooled_within(nt) {
            Some(e) => (group_time(e), e.workers),
            None => (serial_s, 1),
        };
        // the task-graph batch path shares the problem-claiming dispatch
        // for wide groups and runs the per-problem task-graph engine for
        // narrow ones — the same candidate shape, its own calibration
        let (taskgraph_s, taskgraph_workers) = match self.profile.taskgraph_within(nt) {
            Some(e) => (group_time(e), e.workers),
            None => (serial_s, 1),
        };
        let cost = EngineCost {
            serial_s,
            pooled_s,
            pooled_workers,
            taskgraph_s,
            taskgraph_workers,
            gpu_s: self.sim.batched_compute_time_of(&counts),
        };
        let (choice, predicted_s) = self.pick(&cost);
        let (levels, p) = members
            .first()
            .map(|m| (m.levels, m.p))
            .unwrap_or((0, 0));
        Decision {
            label: format!(
                "group L{levels} p{p} ×{} (n={})",
                members.len(),
                members.iter().map(|m| m.n).sum::<usize>()
            ),
            members: members.len(),
            choice,
            cost,
            predicted_s,
            measured_s: None,
        }
    }

    /// Predicted compute-only seconds (P2M … P2P) of one problem on the
    /// serial engine, the pooled engine and the task-graph engine
    /// calibrated nearest to `workers` — the `pool-bench` predicted
    /// columns.
    pub fn predict_compute(&self, p: &Problem, workers: usize) -> (f64, f64, f64) {
        let u = cost::phase_units(&p.counts());
        let serial = cost::cpu_compute(&self.profile.serial, &u);
        let pooled = self
            .profile
            .pooled_near(workers)
            .map(|e| cost::cpu_compute(&e.rates, &u))
            .unwrap_or(serial);
        let taskgraph = self
            .profile
            .taskgraph_near(workers)
            .map(|e| cost::cpu_compute(&e.rates, &u))
            .unwrap_or(pooled);
        (serial, pooled, taskgraph)
    }

    // ---- internals -----------------------------------------------------

    fn pick(&self, c: &EngineCost) -> (EngineChoice, f64) {
        let mut choice = EngineChoice::Serial;
        let mut best = c.serial_s;
        if c.pooled_s < best {
            choice = EngineChoice::Pooled {
                workers: c.pooled_workers,
            };
            best = c.pooled_s;
        }
        if c.taskgraph_s < best {
            choice = EngineChoice::TaskGraph {
                workers: c.taskgraph_workers,
            };
            best = c.taskgraph_s;
        }
        if self.allow_xla && c.gpu_s < best {
            choice = EngineChoice::Xla;
            best = c.gpu_s;
        }
        (choice, best)
    }
}

/// Best calibrated candidate of one engine under the worker cap:
/// `(seconds, workers)`, falling back to the serial prediction when no
/// entry qualifies.
fn best_entry(
    entries: &[super::profile::PooledRates],
    serial_s: f64,
    cap: Option<usize>,
    time_of: impl Fn(&super::profile::EngineRates) -> f64,
) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut best_w = 0;
    for e in entries {
        if cap.is_some_and(|c| e.workers > c) {
            continue;
        }
        let t = time_of(&e.rates);
        if t < best {
            best = t;
            best_w = e.workers;
        }
    }
    if best.is_finite() {
        (best, best_w)
    } else {
        (serial_s, 1)
    }
}

/// Execute a decision's CPU engine through [`fmm::evaluate`] — the single
/// choice-to-execution mapping shared by [`evaluate_auto`] and the CLI —
/// filling the decision's `measured_s`. Callers that can run the PJRT
/// runtime route [`EngineChoice::Xla`] decisions there instead of calling
/// this; here an Xla choice falls back to the pooled CPU engine under the
/// caller's thread setting.
pub fn execute_cpu_choice(
    points: &[C64],
    gammas: &[C64],
    opts: &FmmOptions,
    decision: &mut Decision,
) -> Result<FmmOutput> {
    let threads = match decision.choice {
        EngineChoice::Serial => Some(1),
        EngineChoice::Pooled { workers } | EngineChoice::TaskGraph { workers } => Some(workers),
        EngineChoice::Xla => opts.threads,
    };
    let cpu_engine = match decision.choice {
        EngineChoice::TaskGraph { .. } => fmm::CpuEngine::TaskGraph,
        EngineChoice::Serial | EngineChoice::Pooled { .. } => fmm::CpuEngine::Barrier,
        EngineChoice::Xla => opts.cpu_engine,
    };
    let run_opts = FmmOptions {
        threads,
        cpu_engine,
        ..opts.clone()
    };
    let t = Instant::now();
    let out = fmm::evaluate(points, gammas, &run_opts)?;
    decision.measured_s = Some(t.elapsed().as_secs_f64());
    decision.record_drift();
    Ok(out)
}

/// Evaluate one problem with the engine the dispatcher picks — the
/// library form of `fmm2d run --engine auto`
/// ([`Dispatcher::select_capped`] + [`execute_cpu_choice`]). Returns the
/// output and the [`Decision`] with `measured_s` filled in.
pub fn evaluate_auto(
    points: &[C64],
    gammas: &[C64],
    opts: &FmmOptions,
    dispatcher: &Dispatcher,
) -> Result<(FmmOutput, Decision)> {
    let problem = Problem::from_config(&opts.cfg, points.len());
    let mut dec = dispatcher.select_capped(&problem, opts.threads);
    let out = execute_cpu_choice(points, gammas, opts, &mut dec)?;
    Ok((out, dec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::profile::{EngineRates, PooledRates, PROFILE_VERSION};

    fn profile() -> CalibrationProfile {
        CalibrationProfile {
            version: PROFILE_VERSION,
            serial: EngineRates {
                rates: [1.0e8; N_PHASES],
                overhead_s: 0.0,
            },
            pooled: vec![PooledRates {
                workers: 4,
                rates: EngineRates {
                    rates: [3.2e8; N_PHASES],
                    overhead_s: 5.0e-4,
                },
            }],
            // slightly slower than pooled so the existing pooled-choice
            // assertions stay meaningful
            taskgraph: vec![PooledRates {
                workers: 4,
                rates: EngineRates {
                    rates: [3.0e8; N_PHASES],
                    overhead_s: 5.0e-4,
                },
            }],
        }
    }

    #[test]
    fn engine_names_round_trip() {
        for name in ENGINE_NAMES {
            let e: Engine = name.parse().unwrap();
            assert_eq!(e.to_string(), name);
        }
        let err = "warp-drive".parse::<Engine>().unwrap_err().to_string();
        assert!(err.contains("serial|parallel|taskgraph|xla|auto"), "{err}");
    }

    #[test]
    fn faster_taskgraph_rates_win_the_pick() {
        let mut p = profile();
        p.taskgraph[0].rates.rates = [6.4e8; N_PHASES];
        let d = Dispatcher::new(p).with_xla(false);
        let dec = d.select(&Problem::new(50_000, 5, 17, 0.5));
        assert!(
            matches!(dec.choice, EngineChoice::TaskGraph { workers: 4 }),
            "calibrated-faster taskgraph must be chosen, got {}",
            dec.choice
        );
    }

    #[test]
    fn taskgraph_tie_keeps_pooled() {
        let mut p = profile();
        p.taskgraph = p.pooled.clone();
        let d = Dispatcher::new(p).with_xla(false);
        let dec = d.select(&Problem::new(50_000, 5, 17, 0.5));
        assert!(
            matches!(dec.choice, EngineChoice::Pooled { .. }),
            "exact tie must keep the earlier candidate, got {}",
            dec.choice
        );
    }

    #[test]
    fn pooled_cap_falls_back_to_serial() {
        let d = Dispatcher::new(profile()).with_xla(false);
        let p = Problem::new(50_000, 5, 17, 0.5);
        let c = d.predict_capped(&p, Some(1));
        assert_eq!(c.pooled_workers, 1);
        assert_eq!(c.pooled_s, c.serial_s);
        assert_eq!(
            d.select_capped(&p, Some(1)).choice,
            EngineChoice::Serial,
            "capped at one worker the serial driver must win"
        );
    }

    #[test]
    fn report_renders_choice_and_drift() {
        let d = Dispatcher::new(profile()).with_xla(false);
        let mut dec = d.select(&Problem::new(20_000, 4, 17, 0.5));
        dec.measured_s = Some(dec.predicted_s * 2.0);
        let s = DispatchReport {
            decisions: vec![dec],
        }
        .render();
        assert!(s.contains("n=20000 L4 p17"), "{s}");
        assert!(s.contains("2.0"), "drift column missing: {s}");
    }

    #[test]
    fn missing_profile_falls_back_with_flag_set() {
        let d = Dispatcher::load_or_default(Some(std::path::Path::new(
            "/nonexistent/fmm2d-no-such-profile.json",
        )));
        assert!(d.fallback, "missing profile must set the fallback flag");
        assert!(
            !Dispatcher::new(profile()).fallback,
            "a real profile must not"
        );
    }

    #[test]
    fn empty_group_is_serial_and_free() {
        let d = Dispatcher::new(profile()).with_xla(false);
        let dec = d.select_group(&[]);
        assert_eq!(dec.members, 0);
        assert_eq!(dec.cost.serial_s, 0.0);
    }
}
