//! Calibration profiles: measured per-phase CPU throughputs, persisted as
//! versioned JSON.
//!
//! A [`CalibrationProfile`] is the measured half of the dispatch cost
//! model: for the serial reference driver and for the pooled
//! multithreaded engine (per calibrated worker count) it records, per
//! phase of [`crate::fmm::PHASE_NAMES`], how many *work units* the engine
//! retires per second (see [`crate::dispatch::cost::phase_units`] for the
//! unit definitions) plus a fixed per-evaluation dispatch overhead. The
//! profile is produced by [`CalibrationProfile::measure`] — a short pass
//! of real evaluations (`fmm2d calibrate`, `--quick` for the CI smoke
//! variant) — and persisted with the in-tree JSON utilities
//! ([`crate::util::json`]; no external dependencies) under
//! [`CalibrationProfile::default_path`] or an explicit `--profile` path.
//!
//! The format is versioned ([`PROFILE_VERSION`]) and strict: parsing
//! rejects version mismatches *and* unknown fields, so a stale or foreign
//! file fails loudly instead of silently skewing dispatch decisions
//! (`tests/dispatch.rs`).

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::fmm::{self, FmmOptions, N_PHASES, PHASE_NAMES};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::workload;

use super::cost::phase_units;

/// Format version of the persisted profile; bumped whenever the rate
/// semantics change so stale files are rejected, not misread.
/// v2 added the task-graph engine's rate entries. v3: the measured P2P and
/// M2L rates reflect the tiled SoA / panel micro-kernels (DESIGN.md §10) —
/// profiles calibrated against the pre-tile kernels would skew `--engine
/// auto` toward the wrong side of the crossovers.
pub const PROFILE_VERSION: usize = 3;

/// Measured throughput of one engine: work units per second per phase
/// (ordered as [`PHASE_NAMES`]) plus a fixed per-evaluation overhead.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineRates {
    /// Work units per second per phase (Sort … P2P).
    pub rates: [f64; N_PHASES],
    /// Fixed per-evaluation overhead in seconds (pool fan-out latency,
    /// allocation churn) — what makes tiny problems prefer the serial
    /// driver.
    pub overhead_s: f64,
}

impl EngineRates {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "rates",
            Json::Arr(self.rates.iter().map(|&r| Json::Num(r)).collect()),
        )
        .set("overhead_s", Json::Num(self.overhead_s));
        j
    }

    fn from_json(v: &Json, what: &str) -> Result<Self> {
        check_fields(v, &["rates", "overhead_s"], what)?;
        let arr = v
            .get("rates")
            .and_then(Json::as_arr)
            .with_context(|| format!("{what}: missing 'rates' array"))?;
        if arr.len() != N_PHASES {
            crate::bail!(
                "{what}: expected {N_PHASES} phase rates ({}), got {}",
                PHASE_NAMES.join("/"),
                arr.len()
            );
        }
        let mut rates = [0.0; N_PHASES];
        for (i, x) in arr.iter().enumerate() {
            let r = x
                .as_f64()
                .with_context(|| format!("{what}: rates[{i}] is not a number"))?;
            if !r.is_finite() || r <= 0.0 {
                crate::bail!("{what}: rates[{i}] = {r} must be finite and positive");
            }
            rates[i] = r;
        }
        let overhead_s = v
            .get("overhead_s")
            .and_then(Json::as_f64)
            .with_context(|| format!("{what}: missing 'overhead_s'"))?;
        if !overhead_s.is_finite() || overhead_s < 0.0 {
            crate::bail!("{what}: overhead_s = {overhead_s} must be finite and non-negative");
        }
        Ok(EngineRates { rates, overhead_s })
    }
}

/// [`EngineRates`] of one multicore engine at one calibrated worker count
/// (used by both the pooled barrier engine and the task-graph engine).
#[derive(Clone, Debug, PartialEq)]
pub struct PooledRates {
    pub workers: usize,
    pub rates: EngineRates,
}

/// A full calibration profile: serial rates plus pooled and task-graph
/// rates per calibrated worker count. See the module docs for provenance
/// and persistence.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationProfile {
    pub version: usize,
    pub serial: EngineRates,
    /// Pooled barrier-engine rates, ascending by worker count.
    pub pooled: Vec<PooledRates>,
    /// Task-graph engine rates, ascending by worker count. The engine's
    /// per-phase times are normalized so they sum to the *overlapped*
    /// wall-clock ([`crate::fmm::taskgraph`]), so these rates price its
    /// phase overlap honestly: a total predicted from them is a predicted
    /// wall time.
    pub taskgraph: Vec<PooledRates>,
}

/// Options of one calibration pass ([`CalibrationProfile::measure`]).
#[derive(Clone, Debug)]
pub struct CalibrationOptions {
    /// Small sizes only — seconds instead of tens of seconds; the CI smoke
    /// configuration (`fmm2d calibrate --quick`).
    pub quick: bool,
    pub seed: u64,
    /// Pin pool workers to cores during the pooled measurements.
    pub pin: bool,
    /// Worker counts to calibrate the pooled engine at; empty = powers of
    /// two up to the machine plus the machine itself (`--quick`: machine
    /// only).
    pub worker_counts: Vec<usize>,
}

impl Default for CalibrationOptions {
    fn default() -> Self {
        Self {
            quick: false,
            seed: 1,
            pin: false,
            worker_counts: Vec::new(),
        }
    }
}

impl CalibrationOptions {
    fn resolved_worker_counts(&self) -> Vec<usize> {
        if !self.worker_counts.is_empty() {
            let mut ws = self.worker_counts.clone();
            ws.sort_unstable();
            ws.dedup();
            return ws;
        }
        let avail = crate::util::threadpool::available_threads().max(1);
        if self.quick {
            return vec![avail];
        }
        let mut ws = Vec::new();
        let mut w = 2;
        while w < avail {
            ws.push(w);
            w *= 2;
        }
        ws.push(avail);
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    fn sizes(&self) -> &'static [usize] {
        if self.quick {
            &[1_500, 12_000]
        } else {
            &[1_500, 12_000, 48_000]
        }
    }
}

/// Problem size used to measure the fixed per-evaluation overhead.
const TINY_N: usize = 400;

impl CalibrationProfile {
    /// Run the calibration pass: evaluate a few deterministic uniform
    /// workloads through the serial driver and through the pooled engine
    /// at every requested worker count, and convert the measured per-phase
    /// wall-clock into work-unit throughputs. The per-evaluation overhead
    /// of each engine is backed out of a tiny run (measured total minus
    /// the work the fitted rates predict).
    pub fn measure(opts: &CalibrationOptions) -> Result<CalibrationProfile> {
        let serial = measure_engine(Some(1), fmm::CpuEngine::Barrier, opts)?;
        let mut pooled = Vec::new();
        let mut taskgraph = Vec::new();
        for w in opts.resolved_worker_counts() {
            pooled.push(PooledRates {
                workers: w,
                rates: measure_engine(Some(w), fmm::CpuEngine::Barrier, opts)?,
            });
            taskgraph.push(PooledRates {
                workers: w,
                rates: measure_engine(Some(w), fmm::CpuEngine::TaskGraph, opts)?,
            });
        }
        Ok(CalibrationProfile {
            version: PROFILE_VERSION,
            serial,
            pooled,
            taskgraph,
        })
    }

    /// Built-in rough rates used when no profile file exists yet: a
    /// plausible single-core throughput with a near-linear pooled speedup
    /// on all available cores. Good enough to make `--engine auto` work
    /// out of the box; `fmm2d calibrate` replaces it with measurements.
    pub fn fallback() -> CalibrationProfile {
        // units/s of a generic desktop core (order-of-magnitude only)
        let serial = EngineRates {
            rates: [
                5.0e7, // Sort: particles·levels
                4.0e7, // Connect: θ-criterion checks
                1.5e8, // P2M: coefficient·particle units
                4.0e8, // M2M: shift-matrix cells
                6.0e8, // M2L: shift-matrix cells (matrix operator)
                4.0e8, // L2L: shift-matrix cells
                1.5e8, // L2P: coefficient·particle units
                1.2e8, // P2P: pairwise interactions
            ],
            overhead_s: 0.0,
        };
        let avail = crate::util::threadpool::available_threads().max(1);
        let speedup = (0.75 * avail as f64).max(1.0);
        let pooled = EngineRates {
            rates: serial.rates.map(|r| r * speedup),
            overhead_s: 150.0e-6,
        };
        CalibrationProfile {
            version: PROFILE_VERSION,
            serial,
            // the fallback prices the task-graph engine identically to the
            // pooled engine: the strict-less-than pick order then keeps
            // pooled until a real `calibrate` measures the overlap win
            taskgraph: vec![PooledRates {
                workers: avail,
                rates: pooled.clone(),
            }],
            pooled: vec![PooledRates {
                workers: avail,
                rates: pooled,
            }],
        }
    }

    /// The pooled entry calibrated closest to `workers` (ties prefer the
    /// smaller count); `None` when the profile carries no pooled rates.
    pub fn pooled_near(&self, workers: usize) -> Option<&PooledRates> {
        near_in(&self.pooled, workers)
    }

    /// The largest calibrated pooled entry **not exceeding** `workers` —
    /// the only entry a run capped at `workers` can honestly be priced
    /// with; `None` when every entry needs more workers than allowed.
    pub fn pooled_within(&self, workers: usize) -> Option<&PooledRates> {
        within_in(&self.pooled, workers)
    }

    /// [`Self::pooled_near`], over the task-graph entries.
    pub fn taskgraph_near(&self, workers: usize) -> Option<&PooledRates> {
        near_in(&self.taskgraph, workers)
    }

    /// [`Self::pooled_within`], over the task-graph entries.
    pub fn taskgraph_within(&self, workers: usize) -> Option<&PooledRates> {
        within_in(&self.taskgraph, workers)
    }

    // ---- persistence ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let entries = |es: &[PooledRates]| {
            Json::Arr(
                es.iter()
                    .map(|e| {
                        let mut o = e.rates.to_json();
                        o.set("workers", Json::Num(e.workers as f64));
                        o
                    })
                    .collect(),
            )
        };
        let mut j = Json::obj();
        j.set("version", Json::Num(self.version as f64))
            .set("serial", self.serial.to_json())
            .set("pooled", entries(&self.pooled))
            .set("taskgraph", entries(&self.taskgraph));
        j
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a profile document, rejecting version mismatches and unknown
    /// fields (see the module docs).
    pub fn parse(s: &str) -> Result<CalibrationProfile> {
        let v = Json::parse(s).context("parsing calibration profile")?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<CalibrationProfile> {
        check_fields(
            v,
            &["version", "serial", "pooled", "taskgraph"],
            "calibration profile",
        )?;
        let version = v.req_usize("version")?;
        if version != PROFILE_VERSION {
            crate::bail!(
                "calibration profile version {version} does not match the supported \
                 version {PROFILE_VERSION}; re-run `fmm2d calibrate`"
            );
        }
        let serial = EngineRates::from_json(
            v.get("serial").context("missing 'serial' rates")?,
            "serial rates",
        )?;
        let pooled = parse_entries(v, "pooled")?;
        let taskgraph = parse_entries(v, "taskgraph")?;
        Ok(CalibrationProfile {
            version,
            serial,
            pooled,
            taskgraph,
        })
    }

    /// Default on-disk location: `$XDG_CACHE_HOME/fmm2d/profile.json`
    /// (falling back to `~/.cache`, then `./.cache`).
    pub fn default_path() -> PathBuf {
        let base = std::env::var_os("XDG_CACHE_HOME")
            .map(PathBuf::from)
            .or_else(|| std::env::var_os("HOME").map(|h| PathBuf::from(h).join(".cache")))
            .unwrap_or_else(|| PathBuf::from(".cache"));
        base.join("fmm2d").join("profile.json")
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<CalibrationProfile> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&s)
    }

    /// Human-readable rate table (Munits/s per phase and engine).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "# dispatch calibration profile (v{})", self.version);
        let _ = write!(out, "{:<12} {:>12}", "engine", "overhead_us");
        for name in PHASE_NAMES {
            let _ = write!(out, " {name:>9}");
        }
        let _ = writeln!(out, "   (Munits/s)");
        let mut row = |label: &str, r: &EngineRates| {
            let _ = write!(out, "{label:<12} {:>12.1}", r.overhead_s * 1e6);
            for rate in r.rates {
                let _ = write!(out, " {:>9.1}", rate / 1e6);
            }
            let _ = writeln!(out);
        };
        row("serial", &self.serial);
        for e in &self.pooled {
            row(&format!("pooled({})", e.workers), &e.rates);
        }
        for e in &self.taskgraph {
            row(&format!("taskgraph({})", e.workers), &e.rates);
        }
        out
    }
}

/// The entry calibrated closest to `workers` (ties prefer the smaller
/// count) — shared by the pooled and task-graph lookups.
fn near_in(entries: &[PooledRates], workers: usize) -> Option<&PooledRates> {
    entries.iter().min_by_key(|e| {
        let d = e.workers.abs_diff(workers);
        (d, e.workers)
    })
}

/// The largest calibrated entry not exceeding `workers` — shared by the
/// pooled and task-graph lookups.
fn within_in(entries: &[PooledRates], workers: usize) -> Option<&PooledRates> {
    entries
        .iter()
        .filter(|e| e.workers <= workers)
        .max_by_key(|e| e.workers)
}

/// Parse one engine's `[{workers, rates, overhead_s}]` array, sorted
/// ascending by worker count.
fn parse_entries(v: &Json, key: &str) -> Result<Vec<PooledRates>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing '{key}' rate array"))?;
    let mut entries = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let what = format!("{key}[{i}] rates");
        check_fields(e, &["workers", "rates", "overhead_s"], &what)?;
        let workers = e.req_usize("workers")?;
        if workers == 0 {
            crate::bail!("{what}: workers must be at least 1");
        }
        // re-check without 'workers' is unnecessary: EngineRates' parser
        // only reads its two fields and the field check above already
        // constrained the full set
        let rates = {
            let mut o = Json::obj();
            o.set("rates", e.get("rates").cloned().unwrap_or(Json::Null))
                .set(
                    "overhead_s",
                    e.get("overhead_s").cloned().unwrap_or(Json::Null),
                );
            EngineRates::from_json(&o, &what)?
        };
        entries.push(PooledRates { workers, rates });
    }
    entries.sort_by_key(|e| e.workers);
    Ok(entries)
}

/// Measure one engine's rates: accumulate work units and per-phase seconds
/// over the calibration sizes, then divide; back the overhead out of a
/// tiny run.
fn measure_engine(
    threads: Option<usize>,
    engine: fmm::CpuEngine,
    opts: &CalibrationOptions,
) -> Result<EngineRates> {
    let fmm_opts = |threads: Option<usize>| FmmOptions {
        threads,
        pin: opts.pin,
        cpu_engine: engine,
        ..FmmOptions::default()
    };
    // warm the pool (and the allocator) so the first timed run is not
    // charged for thread spawns
    {
        let mut r = Pcg64::seed_from_u64(opts.seed ^ 0xbeef);
        let (pts, gs) = workload::uniform_square(TINY_N, &mut r);
        let _ = fmm::evaluate(&pts, &gs, &fmm_opts(threads))?;
    }
    let mut units_sum = [0.0f64; N_PHASES];
    let mut secs_sum = [0.0f64; N_PHASES];
    for (k, &n) in opts.sizes().iter().enumerate() {
        let mut r = Pcg64::seed_from_u64(opts.seed.wrapping_add(k as u64));
        let (pts, gs) = workload::uniform_square(n, &mut r);
        let out = fmm::evaluate(&pts, &gs, &fmm_opts(threads))?;
        let u = phase_units(&out.counts);
        for i in 0..N_PHASES {
            units_sum[i] += u[i];
            secs_sum[i] += out.times.0[i];
        }
    }
    let mut rates = [0.0f64; N_PHASES];
    for i in 0..N_PHASES {
        rates[i] = (units_sum[i] / secs_sum[i].max(1e-9)).max(1.0);
    }
    // overhead: measured tiny total minus what the rates predict for it
    let overhead_s = {
        let mut r = Pcg64::seed_from_u64(opts.seed ^ 0xfeed);
        let (pts, gs) = workload::uniform_square(TINY_N, &mut r);
        let t = Instant::now();
        let out = fmm::evaluate(&pts, &gs, &fmm_opts(threads))?;
        let measured = t.elapsed().as_secs_f64();
        let predicted: f64 = phase_units(&out.counts)
            .iter()
            .zip(&rates)
            .map(|(u, r)| u / r)
            .sum();
        (measured - predicted).max(0.0)
    };
    Ok(EngineRates { rates, overhead_s })
}

/// Reject JSON objects carrying fields this version does not understand.
fn check_fields(v: &Json, known: &[&str], what: &str) -> Result<()> {
    match v {
        Json::Obj(m) => {
            for k in m.keys() {
                if !known.contains(&k.as_str()) {
                    crate::bail!(
                        "unknown field '{k}' in {what}; this build understands {}",
                        known.join(", ")
                    );
                }
            }
            Ok(())
        }
        _ => crate::bail!("{what}: expected a JSON object"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CalibrationProfile {
        CalibrationProfile {
            version: PROFILE_VERSION,
            serial: EngineRates {
                rates: [1.0e8; N_PHASES],
                overhead_s: 0.0,
            },
            pooled: vec![
                PooledRates {
                    workers: 2,
                    rates: EngineRates {
                        rates: [1.7e8; N_PHASES],
                        overhead_s: 1.0e-4,
                    },
                },
                PooledRates {
                    workers: 8,
                    rates: EngineRates {
                        rates: [6.0e8; N_PHASES],
                        overhead_s: 2.0e-4,
                    },
                },
            ],
            taskgraph: vec![PooledRates {
                workers: 8,
                rates: EngineRates {
                    rates: [7.0e8; N_PHASES],
                    overhead_s: 2.5e-4,
                },
            }],
        }
    }

    #[test]
    fn round_trip() {
        let p = sample();
        let back = CalibrationProfile::parse(&p.to_json_string()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn pooled_near_picks_closest() {
        let p = sample();
        assert_eq!(p.pooled_near(1).unwrap().workers, 2);
        assert_eq!(p.pooled_near(4).unwrap().workers, 2); // tie → smaller
        assert_eq!(p.pooled_near(6).unwrap().workers, 8);
        assert_eq!(p.pooled_near(64).unwrap().workers, 8);
    }

    #[test]
    fn pooled_within_respects_the_cap() {
        let p = sample(); // entries at 2 and 8 workers
        assert!(p.pooled_within(1).is_none());
        assert_eq!(p.pooled_within(2).unwrap().workers, 2);
        assert_eq!(p.pooled_within(7).unwrap().workers, 2);
        assert_eq!(p.pooled_within(64).unwrap().workers, 8);
    }

    #[test]
    fn rejects_bad_rates() {
        let mut p = sample();
        p.serial.rates[0] = -1.0;
        assert!(CalibrationProfile::parse(&p.to_json_string()).is_err());
    }

    #[test]
    fn summary_lists_engines() {
        let s = sample().summary();
        assert!(s.contains("serial"));
        assert!(s.contains("pooled(8)"));
        assert!(s.contains("taskgraph(8)"));
        assert!(s.contains("P2P"));
    }

    #[test]
    fn taskgraph_lookups_mirror_pooled() {
        let p = sample(); // one taskgraph entry at 8 workers
        assert_eq!(p.taskgraph_near(2).unwrap().workers, 8);
        assert!(p.taskgraph_within(7).is_none());
        assert_eq!(p.taskgraph_within(8).unwrap().workers, 8);
    }
}
