//! Micro/macro benchmark substrate (criterion replacement for the offline
//! environment): warmup, adaptive repetition targeting a minimum measuring
//! window, and robust summary statistics.

use crate::util::stats::Summary;
use std::time::Instant;

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded samples.
    pub samples: usize,
    /// Lower bound on total measured time; iterations per sample are scaled
    /// so `samples × iters × t_iter ≳ min_time` (seconds).
    pub min_time: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: 2,
            samples: 10,
            min_time: 0.5,
        }
    }
}

impl BenchConfig {
    /// Fast configuration for long-running macro benchmarks.
    pub fn macro_bench() -> Self {
        Self {
            warmup: 1,
            samples: 3,
            min_time: 0.0,
        }
    }
}

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub summary: Summary,
    /// Iterations per recorded sample.
    pub iters: usize,
}

impl BenchResult {
    /// Seconds per iteration (median).
    pub fn secs(&self) -> f64 {
        self.summary.median
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.6} ms/iter (±{:.1}%, n={} × {})",
            self.name,
            self.secs() * 1e3,
            100.0 * self.summary.rel_spread(),
            self.summary.n,
            self.iters
        )
    }
}

/// Measure `f`, returning per-iteration timing statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // warmup + calibration
    let mut t_iter = 0.0;
    for _ in 0..cfg.warmup.max(1) {
        let t = Instant::now();
        f();
        t_iter = t.elapsed().as_secs_f64();
    }
    let iters = if cfg.min_time > 0.0 && t_iter > 0.0 {
        ((cfg.min_time / cfg.samples as f64 / t_iter).ceil() as usize).clamp(1, 1_000_000)
    } else {
        1
    };

    let mut xs = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        xs.push(t.elapsed().as_secs_f64() / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&xs),
        iters,
    }
}

/// Prevent the optimizer from discarding a computed value
/// (`std::hint::black_box` is stable since 1.66; thin wrapper for clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig {
            warmup: 1,
            samples: 3,
            min_time: 0.01,
        };
        let mut acc = 0u64;
        let r = bench("spin", &cfg, || {
            for i in 0..10_000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.secs() > 0.0);
        assert_eq!(r.summary.n, 3);
        assert!(r.iters >= 1);
        assert!(r.report().contains("spin"));
    }
}
