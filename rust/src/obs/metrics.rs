//! The metrics registry: named counters, gauges and log-bucketed
//! histograms with lock-free hot paths.
//!
//! A [`Registry`] is an *instance*, not a global: each [`crate::serve::Server`]
//! owns one, so concurrent serve sessions in one process (the loadgen
//! tests run several) never share counters and exact-count assertions
//! stay exact. Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap
//! clones of `Arc`'d atomics — resolve them once by name, then update
//! without any lock. A process-wide registry ([`global`]) exists for
//! cross-cutting gauges like the dispatcher's rolling drift.
//!
//! Naming convention: `<subsystem>.<metric>` (e.g. `serve.accepted`,
//! `serve.queue_depth`, `dispatch.drift.pooled`). Histograms record
//! milliseconds; snapshots report `count`, `sum_ms`, `max_ms` and
//! bucket-resolved `p50/p95/p99` upper bounds (power-of-two microsecond
//! buckets, so quantiles are exact to within a factor of two).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::util::json::Json;

const N_BUCKETS: usize = 64;

/// Monotone counter handle.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (f64 stored as bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Exponential moving average update: `g ← (1−α)·g + α·v`. Not
    /// atomic as a whole (racing writers may lose an update), which is
    /// fine for a telemetry gauge.
    pub fn ewma(&self, v: f64, alpha: f64) {
        let old = self.get();
        let next = if old == 0.0 {
            v
        } else {
            old * (1.0 - alpha) + v * alpha
        };
        self.set(next);
    }
}

struct HistogramCore {
    count: AtomicU64,
    /// Sum of recorded values in whole microseconds (saturating).
    sum_us: AtomicU64,
    max_us: AtomicU64,
    /// Bucket `i` holds values whose microsecond count has bit length `i`
    /// (bucket 0 is exactly zero): power-of-two bucketing.
    buckets: [AtomicU64; N_BUCKETS],
}

/// Log-bucketed histogram handle; records milliseconds.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

fn bucket_of(us: u64) -> usize {
    (u64::BITS - us.leading_zeros()) as usize
}

impl Histogram {
    pub fn record(&self, ms: f64) {
        let us = if ms.is_finite() && ms > 0.0 {
            (ms * 1000.0).round() as u64
        } else {
            0
        };
        let c = &self.0;
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum_us.fetch_add(us, Ordering::Relaxed);
        c.max_us.fetch_max(us, Ordering::Relaxed);
        c.buckets[bucket_of(us).min(N_BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Bucket-resolved quantile: the upper bound (ms) of the bucket
    /// containing the q-th recorded value. 0.0 when empty.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.0.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // bucket i covers us ∈ [2^(i−1), 2^i − 1]; report 2^i µs
                let upper_us = if i == 0 { 0u64 } else { 1u64 << i.min(63) };
                return upper_us as f64 / 1000.0;
            }
        }
        self.0.max_us.load(Ordering::Relaxed) as f64 / 1000.0
    }

    fn snapshot(&self) -> Json {
        let c = &self.0;
        let mut j = Json::obj();
        j.set("count", Json::Num(c.count.load(Ordering::Relaxed) as f64))
            .set(
                "sum_ms",
                Json::Num(c.sum_us.load(Ordering::Relaxed) as f64 / 1000.0),
            )
            .set(
                "max_ms",
                Json::Num(c.max_us.load(Ordering::Relaxed) as f64 / 1000.0),
            )
            .set("p50_ms", Json::Num(self.quantile_ms(0.50)))
            .set("p95_ms", Json::Num(self.quantile_ms(0.95)))
            .set("p99_ms", Json::Num(self.quantile_ms(0.99)));
        j
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    histograms: BTreeMap<String, Histogram>,
}

/// A named-metric registry. Handle resolution takes the registry lock;
/// handle updates never do.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

fn locked(m: &Mutex<Inner>) -> MutexGuard<'_, Inner> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut i = locked(&self.inner);
        i.counters
            .entry(name.to_string())
            .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
            .clone()
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut i = locked(&self.inner);
        i.gauges
            .entry(name.to_string())
            .or_insert_with(|| Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits()))))
            .clone()
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut i = locked(&self.inner);
        i.histograms
            .entry(name.to_string())
            .or_insert_with(|| {
                Histogram(Arc::new(HistogramCore {
                    count: AtomicU64::new(0),
                    sum_us: AtomicU64::new(0),
                    max_us: AtomicU64::new(0),
                    buckets: std::array::from_fn(|_| AtomicU64::new(0)),
                }))
            })
            .clone()
    }

    /// One strict-JSON snapshot of every metric:
    /// `{"counters":{…},"gauges":{…},"histograms":{…}}`.
    pub fn snapshot(&self) -> Json {
        let i = locked(&self.inner);
        let mut counters = Json::obj();
        for (k, c) in &i.counters {
            counters.set(k, Json::Num(c.get() as f64));
        }
        let mut gauges = Json::obj();
        for (k, g) in &i.gauges {
            let v = g.get();
            gauges.set(k, Json::Num(if v.is_finite() { v } else { 0.0 }));
        }
        let mut hists = Json::obj();
        for (k, h) in &i.histograms {
            hists.set(k, h.snapshot());
        }
        let mut j = Json::obj();
        j.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists);
        j
    }
}

/// The process-wide registry for cross-cutting metrics (dispatch drift
/// gauges). Subsystem-scoped metrics (serve) use their own instance.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let r = Registry::new();
        let c = r.counter("t.hits");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name resolves to the same underlying atomic
        assert_eq!(r.counter("t.hits").get(), 5);
        let g = r.gauge("t.depth");
        g.set(3.5);
        assert_eq!(r.gauge("t.depth").get(), 3.5);
        g.ewma(1.5, 0.5);
        assert!((g.get() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let r = Registry::new();
        let h = r.histogram("t.lat_ms");
        for _ in 0..90 {
            h.record(1.0); // 1000 µs → bucket 10
        }
        for _ in 0..10 {
            h.record(100.0); // 100000 µs → bucket 17
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_ms(0.50);
        assert!(p50 >= 1.0 && p50 <= 2.1, "p50 {p50}");
        let p99 = h.quantile_ms(0.99);
        assert!(p99 >= 100.0 && p99 <= 140.0, "p99 {p99}");
        // zero and non-finite recordings land in bucket 0, not a panic
        h.record(0.0);
        h.record(f64::NAN);
        assert_eq!(h.count(), 102);
    }

    #[test]
    fn snapshot_is_strict_json() {
        let r = Registry::new();
        r.counter("a.n").add(2);
        r.gauge("a.g").set(1.25);
        r.histogram("a.h").record(5.0);
        let s = r.snapshot().to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(
            back.get("counters").and_then(|c| c.get("a.n")).and_then(Json::as_usize),
            Some(2)
        );
        assert_eq!(
            back.get("gauges").and_then(|g| g.get("a.g")).and_then(Json::as_f64),
            Some(1.25)
        );
        let h = back.get("histograms").and_then(|h| h.get("a.h")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_usize), Some(1));
        assert!(h.get("p50_ms").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
