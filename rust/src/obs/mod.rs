//! The flight recorder: zero-overhead-when-off span tracing, the serve
//! metrics registry ([`metrics`]), the leveled structured-stderr logger
//! ([`log`]), and the trace summarizer behind `fmm2d trace-report`
//! ([`report`]).
//!
//! ## Span tracing
//!
//! Every engine, the task-graph scheduler, the worker pool, the topology
//! build, the batch runner and the serve lifecycle carry instrumentation
//! points of the form
//!
//! ```ignore
//! let _sp = obs::span("phase", "P2M").arg("boxes", nb as f64);
//! ```
//!
//! When tracing is **off** (the default), [`span`] reads one relaxed
//! atomic, returns a guard holding `None`, and the guard's `Drop` is a
//! branch on that `None` — no clock reads, no allocation, no locks. The
//! instrumented code paths are bitwise-identical with tracing on or off
//! (asserted in `tests/obs.rs`), because recording only ever *observes*
//! timestamps.
//!
//! When tracing is **on** ([`enable`], armed by `--trace FILE`), each
//! thread records completed spans into its own fixed-capacity ring buffer
//! (registered once per thread, overwritten oldest-first when full with a
//! drop counter — the hot path never allocates after the ring exists and
//! never contends: the per-ring mutex is only ever taken by its owner
//! thread and by [`drain`]). Timestamps are `Instant`-based nanoseconds
//! from a process-wide epoch, so they are non-negative and monotone.
//!
//! [`drain`] collects and clears all rings; [`export_chrome`] renders the
//! result as strict Chrome trace-event JSON (`ph:"X"` complete events,
//! microsecond timestamps, per-thread `thread_name` metadata) through
//! [`crate::util::json`] — the file loads directly in Perfetto /
//! `chrome://tracing`.
//!
//! ## Categories
//!
//! | cat         | emitted by                                        |
//! |-------------|---------------------------------------------------|
//! | `phase`     | serial/pooled engine phase blocks, topology build |
//! | `topo`      | nested pyramid/classify sub-spans of the build    |
//! | `task`      | task-graph per-task spans (name = phase)          |
//! | `worker`    | worker-pool job occupancy (one span per fan-out)  |
//! | `batch`     | batch-runner group prologue/compute               |
//! | `serve`     | request lifecycle events (enqueue/flush/…)        |
//! | `dispatch`  | dispatcher predicted-vs-measured drift events     |
//! | `taskgraph` | scheduler critical-path summary event             |

pub mod log;
pub mod metrics;
pub mod report;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Maximum number of numeric key/value args one span can carry.
pub const MAX_ARGS: usize = 4;

/// Default per-thread ring capacity (spans) used by [`ObsOptions`].
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Recorder configuration (`--trace FILE` enables with the defaults).
#[derive(Clone, Copy, Debug)]
pub struct ObsOptions {
    /// Fixed span capacity of each per-thread ring buffer. When a ring
    /// fills, the oldest spans are overwritten and counted as dropped.
    pub capacity: usize,
}

impl Default for ObsOptions {
    fn default() -> Self {
        Self {
            capacity: DEFAULT_CAPACITY,
        }
    }
}

/// One recorded span (or instant event, when `dur_ns == 0` by
/// construction of [`event`]).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub cat: &'static str,
    pub name: &'static str,
    /// Start, nanoseconds from the recorder epoch (non-negative).
    pub t0_ns: u64,
    pub dur_ns: u64,
    /// Recorder thread id (ring registration order; stable per thread).
    pub tid: u32,
    pub n_args: u8,
    pub args: [(&'static str, f64); MAX_ARGS],
}

// 0 = disabled; otherwise the current enable-generation (see GEN).
static STATE: AtomicU64 = AtomicU64::new(0);
// Monotone enable-generation counter. Rings stamp themselves with the
// generation they were (re)armed under, so spans from an earlier session
// never leak into a later drain.
static GEN: AtomicU64 = AtomicU64::new(0);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Is the recorder armed? One relaxed atomic load — this is the whole
/// disabled-path cost of every instrumentation point.
#[inline]
pub fn enabled() -> bool {
    STATE.load(Ordering::Relaxed) != 0
}

/// Arm the recorder with per-thread rings of `opts.capacity` spans.
/// Re-arming starts a fresh generation: spans recorded under a previous
/// enable are discarded, every ring restarts empty at the new capacity.
pub fn enable(opts: &ObsOptions) {
    epoch(); // pin the epoch before any span can start
    CAPACITY.store(opts.capacity.max(1), Ordering::Relaxed);
    let gen = GEN.fetch_add(1, Ordering::Relaxed) + 1;
    STATE.store(gen, Ordering::Relaxed);
}

/// Disarm the recorder. Already-recorded spans stay drainable; new
/// instrumentation points become no-ops again.
pub fn disable() {
    STATE.store(0, Ordering::Relaxed);
}

struct Ring {
    gen: u64,
    cap: usize,
    /// Overwrite cursor once `spans` is full (index of the oldest span).
    next: usize,
    dropped: u64,
    spans: Vec<Span>,
}

impl Ring {
    fn new() -> Ring {
        Ring {
            gen: 0,
            cap: 0,
            next: 0,
            dropped: 0,
            spans: Vec::new(),
        }
    }

    fn rearm(&mut self, cap: usize, gen: u64) {
        self.gen = gen;
        self.cap = cap;
        self.next = 0;
        self.dropped = 0;
        self.spans.clear();
        self.spans.reserve(cap.min(1 << 12)); // grow lazily past 4k
    }

    fn push(&mut self, s: Span) {
        if self.spans.len() < self.cap {
            self.spans.push(s);
        } else {
            // full: overwrite the oldest span, count the casualty
            self.spans[self.next] = s;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn take(&mut self) -> Vec<Span> {
        let mut v = std::mem::take(&mut self.spans);
        if self.next > 0 {
            v.rotate_left(self.next); // restore chronological order
        }
        self.next = 0;
        v
    }
}

struct RegEntry {
    cell: Arc<Mutex<Ring>>,
    thread_name: String,
}

static REGISTRY: Mutex<Vec<RegEntry>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: RefCell<Option<(Arc<Mutex<Ring>>, u32)>> = const { RefCell::new(None) };
}

fn record(cat: &'static str, name: &'static str, t0_ns: u64, dur_ns: u64, args: &[(&'static str, f64)]) {
    let gen = STATE.load(Ordering::Relaxed);
    if gen == 0 {
        return; // disabled between span start and drop
    }
    // try_with: a span finishing during thread teardown (TLS destroyed)
    // is silently dropped rather than aborting the thread
    let _ = LOCAL.try_with(|l| {
        let mut slot = l.borrow_mut();
        if slot.is_none() {
            let ring = Arc::new(Mutex::new(Ring::new()));
            let mut reg = locked(&REGISTRY);
            let tid = reg.len() as u32;
            reg.push(RegEntry {
                cell: Arc::clone(&ring),
                thread_name: std::thread::current().name().unwrap_or("?").to_string(),
            });
            *slot = Some((ring, tid));
        }
        if let Some((ring, tid)) = slot.as_ref() {
            let mut r = locked(ring);
            if r.gen != gen {
                r.rearm(CAPACITY.load(Ordering::Relaxed), gen);
            }
            let mut s = Span {
                cat,
                name,
                t0_ns,
                dur_ns,
                tid: *tid,
                n_args: args.len().min(MAX_ARGS) as u8,
                args: [("", 0.0); MAX_ARGS],
            };
            s.args[..s.n_args as usize].copy_from_slice(&args[..s.n_args as usize]);
            r.push(s);
        }
    });
}

/// RAII span: records `[creation, drop)` into the current thread's ring
/// when tracing is enabled; a pure no-op (no clock read) otherwise.
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    start: Option<Instant>,
    n_args: u8,
    args: [(&'static str, f64); MAX_ARGS],
}

/// Open a span. The guard records on drop.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    SpanGuard {
        cat,
        name,
        start: enabled().then(Instant::now),
        n_args: 0,
        args: [("", 0.0); MAX_ARGS],
    }
}

impl SpanGuard {
    /// Attach a numeric arg (builder form; silently ignored when the
    /// recorder is off or the arg slots are full).
    #[inline]
    pub fn arg(mut self, key: &'static str, v: f64) -> Self {
        self.push_arg(key, v);
        self
    }

    /// Attach a numeric arg to an already-constructed guard (for values
    /// only known mid-span).
    #[inline]
    pub fn push_arg(&mut self, key: &'static str, v: f64) {
        if self.start.is_some() && (self.n_args as usize) < MAX_ARGS {
            self.args[self.n_args as usize] = (key, v);
            self.n_args += 1;
        }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let dur_ns = t0.elapsed().as_nanos() as u64;
            let t0_ns = t0.saturating_duration_since(epoch()).as_nanos() as u64;
            record(
                self.cat,
                self.name,
                t0_ns,
                dur_ns,
                &self.args[..self.n_args as usize],
            );
        }
    }
}

/// Record an instant event (zero-duration span) with numeric args.
#[inline]
pub fn event(cat: &'static str, name: &'static str, args: &[(&'static str, f64)]) {
    if !enabled() {
        return;
    }
    let t0_ns = Instant::now().saturating_duration_since(epoch()).as_nanos() as u64;
    record(cat, name, t0_ns, 0, args);
}

/// A drained trace: all spans from all threads (chronological by start),
/// per-tid thread names, and the total count of ring-overwritten spans.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
    /// Thread names indexed by [`Span::tid`].
    pub threads: Vec<String>,
    pub dropped: u64,
}

/// Collect and clear every ring of the current generation. Spans recorded
/// under earlier enables are skipped (their rings re-arm lazily).
pub fn drain() -> Trace {
    let gen = GEN.load(Ordering::Relaxed);
    let reg = locked(&REGISTRY);
    let mut spans = Vec::new();
    let mut dropped = 0u64;
    let mut threads = Vec::with_capacity(reg.len());
    for e in reg.iter() {
        threads.push(e.thread_name.clone());
        let mut r = locked(&e.cell);
        if r.gen == gen {
            dropped += r.dropped;
            r.dropped = 0;
            spans.append(&mut r.take());
        }
    }
    drop(reg);
    spans.sort_by_key(|s| (s.t0_ns, s.tid));
    Trace {
        spans,
        threads,
        dropped,
    }
}

/// Total busy seconds over all spans of one category.
pub fn busy_seconds(spans: &[Span], cat: &str) -> f64 {
    let mut ns = 0u64;
    for s in spans {
        if s.cat == cat {
            ns = ns.saturating_add(s.dur_ns);
        }
    }
    ns as f64 * 1e-9
}

/// Render a trace as strict Chrome trace-event JSON (the object form:
/// `{"traceEvents":[…]}` plus a `dropped` tally), loadable in Perfetto.
/// Timestamps are microseconds from the recorder epoch — non-negative and
/// sorted ascending.
pub fn export_chrome(trace: &Trace) -> Json {
    let mut events = Vec::with_capacity(trace.spans.len() + trace.threads.len());
    for (tid, tname) in trace.threads.iter().enumerate() {
        let mut meta = Json::obj();
        let mut args = Json::obj();
        args.set("name", Json::Str(tname.clone()));
        meta.set("name", Json::Str("thread_name".into()))
            .set("ph", Json::Str("M".into()))
            .set("pid", Json::Num(1.0))
            .set("tid", Json::Num(tid as f64))
            .set("args", args);
        events.push(meta);
    }
    for s in &trace.spans {
        let mut ev = Json::obj();
        let mut args = Json::obj();
        for (k, v) in &s.args[..s.n_args as usize] {
            args.set(k, Json::Num(*v));
        }
        ev.set("name", Json::Str(s.name.into()))
            .set("cat", Json::Str(s.cat.into()))
            .set("ph", Json::Str("X".into()))
            .set("ts", Json::Num(s.t0_ns as f64 / 1000.0))
            .set("dur", Json::Num(s.dur_ns as f64 / 1000.0))
            .set("pid", Json::Num(1.0))
            .set("tid", Json::Num(s.tid as f64))
            .set("args", args);
        events.push(ev);
    }
    let mut root = Json::obj();
    root.set("traceEvents", Json::Arr(events))
        .set("dropped", Json::Num(trace.dropped as f64));
    root
}

/// Drain the recorder and write the Chrome trace to `path`.
pub fn write_chrome_file(path: &std::path::Path) -> Result<Trace> {
    let trace = drain();
    let json = export_chrome(&trace);
    std::fs::write(path, json.to_string())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global; unit tests here and integration
    // tests in tests/obs.rs each serialize their enable/disable windows.
    fn lock() -> MutexGuard<'static, ()> {
        static T: Mutex<()> = Mutex::new(());
        locked(&T)
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let _g = lock();
        disable();
        let _ = drain();
        {
            let _sp = span("test", "quiet").arg("x", 1.0);
        }
        event("test", "quiet_event", &[("y", 2.0)]);
        assert!(!enabled());
        let tr = drain();
        assert!(
            tr.spans.iter().all(|s| s.cat != "test"),
            "disabled recorder must not record"
        );
    }

    #[test]
    fn spans_and_events_roundtrip() {
        let _g = lock();
        enable(&ObsOptions::default());
        {
            let mut sp = span("test", "outer").arg("a", 1.5);
            sp.push_arg("b", 2.5);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        event("test", "marker", &[("k", 9.0)]);
        disable();
        let tr = drain();
        let outer = tr
            .spans
            .iter()
            .find(|s| s.cat == "test" && s.name == "outer")
            .expect("span recorded");
        assert!(outer.dur_ns >= 1_000_000, "slept 1ms inside");
        assert_eq!(outer.n_args, 2);
        assert_eq!(outer.args[0], ("a", 1.5));
        assert_eq!(outer.args[1], ("b", 2.5));
        let marker = tr
            .spans
            .iter()
            .find(|s| s.name == "marker")
            .expect("event recorded");
        assert_eq!(marker.dur_ns, 0);
        // second drain is empty: drain clears
        assert!(drain().spans.iter().all(|s| s.cat != "test"));
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let _g = lock();
        enable(&ObsOptions { capacity: 4 });
        for i in 0..10 {
            event("ringtest", "seq", &[("i", i as f64)]);
        }
        disable();
        let tr = drain();
        let seqs: Vec<f64> = tr
            .spans
            .iter()
            .filter(|s| s.cat == "ringtest")
            .map(|s| s.args[0].1)
            .collect();
        assert_eq!(seqs, vec![6.0, 7.0, 8.0, 9.0], "oldest dropped first");
        // concurrently-running lib tests may record (and drop) spans on
        // their own rings during our armed window: lower bound only
        assert!(tr.dropped >= 6, "dropped {} < 6", tr.dropped);
    }

    #[test]
    fn chrome_export_is_strict_json_with_sane_timestamps() {
        let _g = lock();
        enable(&ObsOptions::default());
        for _ in 0..3 {
            let _sp = span("exporttest", "work");
        }
        disable();
        let tr = drain();
        let json = export_chrome(&tr);
        let back = Json::parse(&json.to_string()).expect("strict parse");
        let events = back.get("traceEvents").and_then(Json::as_arr).unwrap();
        let mut last_ts = -1.0;
        for e in events {
            if e.get("ph").and_then(Json::as_str) == Some("X") {
                let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                let dur = e.get("dur").and_then(Json::as_f64).unwrap();
                assert!(ts >= 0.0 && dur >= 0.0);
                assert!(ts >= last_ts, "X events sorted by ts");
                last_ts = ts;
            }
        }
    }
}
