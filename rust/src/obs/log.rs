//! Leveled, structured stderr logging: the one sanctioned home of
//! diagnostic prints (`cargo xtask lint` denies raw `eprintln!` in
//! `src/` outside this module and `main.rs` — the `no-adhoc-log` rule).
//!
//! Lines are `key=value` structured:
//!
//! ```text
//! level=warn target=dispatch msg="no calibration profile" path=/x/y.json
//! ```
//!
//! The level is process-global (`--log-level error|warn|info|debug`,
//! default `info`); values containing spaces, quotes or `=` are quoted
//! with `"` / `\` escaping so the lines stay machine-splittable.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::error::Result;

/// Severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> Result<Level> {
        match s {
            "error" => Ok(Level::Error),
            "warn" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => crate::bail!("unknown log level '{other}': expected error|warn|info|debug"),
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a record at `l` be emitted?
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn push_value(line: &mut String, v: &str) {
    let needs_quote =
        v.is_empty() || v.contains([' ', '"', '=', '\n', '\t', '\r', '\\']);
    if !needs_quote {
        line.push_str(v);
        return;
    }
    line.push('"');
    for c in v.chars() {
        match c {
            '"' => line.push_str("\\\""),
            '\\' => line.push_str("\\\\"),
            '\n' => line.push_str("\\n"),
            '\t' => line.push_str("\\t"),
            '\r' => line.push_str("\\r"),
            c => line.push(c),
        }
    }
    line.push('"');
}

/// Emit one structured record. `kv` pairs follow the message.
pub fn emit(l: Level, target: &str, msg: &str, kv: &[(&str, String)]) {
    if !enabled(l) {
        return;
    }
    let mut line = String::with_capacity(64);
    line.push_str("level=");
    line.push_str(l.name());
    line.push_str(" target=");
    push_value(&mut line, target);
    line.push_str(" msg=");
    push_value(&mut line, msg);
    for (k, v) in kv {
        line.push(' ');
        line.push_str(k);
        line.push('=');
        push_value(&mut line, v);
    }
    eprintln!("{line}");
}

pub fn error(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Error, target, msg, kv);
}

pub fn warn(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Warn, target, msg, kv);
}

pub fn info(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Info, target, msg, kv);
}

pub fn debug(target: &str, msg: &str, kv: &[(&str, String)]) {
    emit(Level::Debug, target, msg, kv);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("warn").unwrap(), Level::Warn);
        assert_eq!(Level::parse("debug").unwrap(), Level::Debug);
        assert!(Level::parse("verbose").is_err());
        assert!(Level::Error < Level::Warn && Level::Warn < Level::Info);
    }

    #[test]
    fn quoting_keeps_lines_splittable() {
        let mut s = String::new();
        push_value(&mut s, "plain");
        assert_eq!(s, "plain");
        let mut s = String::new();
        push_value(&mut s, "two words");
        assert_eq!(s, "\"two words\"");
        let mut s = String::new();
        push_value(&mut s, "a\"b\\c\nd");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
        let mut s = String::new();
        push_value(&mut s, "");
        assert_eq!(s, "\"\"");
    }
}
