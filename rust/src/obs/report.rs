//! `fmm2d trace-report FILE` — summarize a Chrome trace produced by
//! `--trace`: per-phase wall/busy, task-graph busy and critical path vs
//! achieved wall, worker occupancy, serve lifecycle tallies, and the top
//! dispatch predicted-vs-measured drift offenders.
//!
//! Works on any strict trace-event JSON with the categories this crate
//! emits (see [`crate::obs`] module docs); unknown categories are
//! ignored, so the report is forward-compatible with later
//! instrumentation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::Result;
use crate::util::json::Json;

struct Ev {
    name: String,
    cat: String,
    ts_us: f64,
    dur_us: f64,
    tid: usize,
    args: Json,
}

fn arg(e: &Ev, key: &str) -> Option<f64> {
    e.args.get(key).and_then(Json::as_f64)
}

/// Aggregate of one span name: count, total busy, and the covering wall
/// interval.
#[derive(Clone, Copy)]
struct Agg {
    count: usize,
    busy_us: f64,
    t_min: f64,
    t_max: f64,
    first: f64,
}

impl Agg {
    fn new(ts: f64, dur: f64) -> Agg {
        Agg {
            count: 1,
            busy_us: dur,
            t_min: ts,
            t_max: ts + dur,
            first: ts,
        }
    }

    fn fold(&mut self, ts: f64, dur: f64) {
        self.count += 1;
        self.busy_us += dur;
        self.t_min = self.t_min.min(ts);
        self.t_max = self.t_max.max(ts + dur);
    }

    fn wall_us(&self) -> f64 {
        (self.t_max - self.t_min).max(0.0)
    }
}

fn aggregate<'a>(evs: impl Iterator<Item = &'a Ev>) -> Vec<(String, Agg)> {
    let mut by_name: BTreeMap<&str, Agg> = BTreeMap::new();
    for e in evs {
        match by_name.get_mut(e.name.as_str()) {
            Some(a) => a.fold(e.ts_us, e.dur_us),
            None => {
                by_name.insert(&e.name, Agg::new(e.ts_us, e.dur_us));
            }
        }
    }
    let mut v: Vec<(String, Agg)> = by_name
        .into_iter()
        .map(|(k, a)| (k.to_string(), a))
        .collect();
    // timeline order: by first occurrence
    v.sort_by(|a, b| a.1.first.total_cmp(&b.1.first));
    v
}

fn ms(us: f64) -> f64 {
    us / 1000.0
}

fn section_spans(out: &mut String, title: &str, rows: &[(String, Agg)]) {
    if rows.is_empty() {
        return;
    }
    let _ = writeln!(out, "\n{title}");
    let _ = writeln!(
        out,
        "  {:<14} {:>8} {:>12} {:>12} {:>8}",
        "name", "count", "busy_ms", "wall_ms", "busy/wall"
    );
    for (name, a) in rows {
        let wall = a.wall_us();
        let ratio = if wall > 0.0 { a.busy_us / wall } else { 0.0 };
        let _ = writeln!(
            out,
            "  {:<14} {:>8} {:>12.3} {:>12.3} {:>8.2}",
            name,
            a.count,
            ms(a.busy_us),
            ms(wall),
            ratio
        );
    }
}

fn section_occupancy(
    out: &mut String,
    title: &str,
    evs: &[&Ev],
    names: &BTreeMap<usize, String>,
) {
    if evs.is_empty() {
        return;
    }
    let mut per_tid: BTreeMap<usize, f64> = BTreeMap::new();
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    for e in evs {
        *per_tid.entry(e.tid).or_insert(0.0) += e.dur_us;
        t_min = t_min.min(e.ts_us);
        t_max = t_max.max(e.ts_us + e.dur_us);
    }
    let window = (t_max - t_min).max(0.0);
    let mut total_busy = 0.0;
    let _ = writeln!(out, "\n{title} (window {:.3} ms)", ms(window));
    let _ = writeln!(out, "  {:<26} {:>12} {:>10}", "thread", "busy_ms", "occup");
    for (tid, busy) in &per_tid {
        total_busy += busy;
        let occ = if window > 0.0 { busy / window } else { 0.0 };
        let label = match names.get(tid) {
            Some(n) => format!("{tid}:{n}"),
            None => format!("{tid}"),
        };
        let _ = writeln!(out, "  {:<26} {:>12.3} {:>10.2}", label, ms(*busy), occ);
    }
    if window > 0.0 {
        let _ = writeln!(
            out,
            "  mean busy workers: {:.2} over {} thread(s)",
            total_busy / window,
            per_tid.len()
        );
    }
}

/// Render the human summary of a parsed Chrome trace.
pub fn render(trace: &Json) -> Result<String> {
    let events = trace
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::anyhow!("not a Chrome trace: missing 'traceEvents' array"))?;

    let mut evs: Vec<Ev> = Vec::new();
    let mut thread_names: BTreeMap<usize, String> = BTreeMap::new();
    for e in events {
        match e.get("ph").and_then(Json::as_str) {
            Some("M") => {
                if e.get("name").and_then(Json::as_str) == Some("thread_name") {
                    if let (Some(tid), Some(n)) = (
                        e.get("tid").and_then(Json::as_usize),
                        e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str),
                    ) {
                        thread_names.insert(tid, n.to_string());
                    }
                }
            }
            Some("X") => {
                let ts = e.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
                let dur = e.get("dur").and_then(Json::as_f64).unwrap_or(0.0);
                crate::ensure!(
                    ts >= 0.0 && dur >= 0.0 && ts.is_finite() && dur.is_finite(),
                    "invalid trace: negative or non-finite ts/dur"
                );
                evs.push(Ev {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string(),
                    cat: e.get("cat").and_then(Json::as_str).unwrap_or("").to_string(),
                    ts_us: ts,
                    dur_us: dur,
                    tid: e.get("tid").and_then(Json::as_usize).unwrap_or(0),
                    args: e.get("args").cloned().unwrap_or_else(Json::obj),
                });
            }
            _ => {}
        }
    }

    let dropped = trace.get("dropped").and_then(Json::as_f64).unwrap_or(0.0);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace: {} span(s) across {} thread(s), {} dropped",
        evs.len(),
        thread_names.len().max(
            evs.iter().map(|e| e.tid + 1).max().unwrap_or(0)
        ),
        dropped as u64
    );

    let of = |cat: &str| evs.iter().filter(move |e| e.cat == cat);

    section_spans(&mut out, "phases (barrier engines + topology)", &aggregate(of("phase")));
    section_spans(&mut out, "task-graph tasks (by phase)", &aggregate(of("task")));
    section_spans(&mut out, "batch groups", &aggregate(of("batch")));

    let workers: Vec<&Ev> = of("worker").collect();
    section_occupancy(&mut out, "worker occupancy", &workers, &thread_names);
    if workers.is_empty() {
        let tasks: Vec<&Ev> = of("task").collect();
        section_occupancy(
            &mut out,
            "worker occupancy (from task spans)",
            &tasks,
            &thread_names,
        );
    }

    let cps: Vec<&Ev> = evs
        .iter()
        .filter(|e| e.cat == "taskgraph" && e.name == "critical_path")
        .collect();
    if !cps.is_empty() {
        let _ = writeln!(out, "\ntask-graph critical path");
        let _ = writeln!(
            out,
            "  {:>12} {:>12} {:>10} {:>8}",
            "critical_ms", "wall_ms", "headroom", "nodes"
        );
        for e in &cps {
            let cp = arg(e, "critical_path_s").unwrap_or(0.0);
            let wall = arg(e, "wall_s").unwrap_or(0.0);
            let head = if cp > 0.0 { wall / cp } else { 0.0 };
            let _ = writeln!(
                out,
                "  {:>12.3} {:>12.3} {:>9.2}x {:>8}",
                cp * 1000.0,
                wall * 1000.0,
                head,
                arg(e, "nodes").unwrap_or(0.0) as usize
            );
        }
    }

    let serve: Vec<&Ev> = of("serve").collect();
    if !serve.is_empty() {
        let mut tally: BTreeMap<&str, usize> = BTreeMap::new();
        for e in &serve {
            *tally.entry(e.name.as_str()).or_insert(0) += 1;
        }
        let _ = writeln!(out, "\nserve lifecycle");
        for (name, n) in tally {
            let _ = writeln!(out, "  {name:<16} {n:>8}");
        }
    }

    let mut drifts: Vec<&Ev> = of("dispatch").collect();
    if !drifts.is_empty() {
        let _ = writeln!(out, "\ndispatch drift (top offenders)");
        let _ = writeln!(
            out,
            "  {:<10} {:>12} {:>12} {:>9}",
            "engine", "pred_ms", "meas_ms", "drift"
        );
        drifts.sort_by(|a, b| {
            arg(b, "drift")
                .unwrap_or(0.0)
                .abs()
                .total_cmp(&arg(a, "drift").unwrap_or(0.0).abs())
        });
        for e in drifts.iter().take(5) {
            let _ = writeln!(
                out,
                "  {:<10} {:>12.3} {:>12.3} {:>8.1}%",
                e.name,
                arg(e, "predicted_s").unwrap_or(0.0) * 1000.0,
                arg(e, "measured_s").unwrap_or(0.0) * 1000.0,
                arg(e, "drift").unwrap_or(0.0) * 100.0
            );
        }
    }

    Ok(out)
}

/// Load a trace file and render its summary.
pub fn render_file(path: &std::path::Path) -> Result<String> {
    use crate::util::error::Context;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let json = Json::parse(&text)
        .with_context(|| format!("parsing trace {}", path.display()))?;
    render(&json)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{export_chrome, Span, Trace, MAX_ARGS};

    fn span(cat: &'static str, name: &'static str, t0: u64, dur: u64, tid: u32) -> Span {
        Span {
            cat,
            name,
            t0_ns: t0,
            dur_ns: dur,
            tid,
            n_args: 0,
            args: [("", 0.0); MAX_ARGS],
        }
    }

    #[test]
    fn report_summarizes_phases_workers_and_critical_path() {
        let mut spans = vec![
            span("phase", "P2M", 0, 2_000_000, 0),
            span("phase", "M2L", 2_000_000, 3_000_000, 0),
            span("worker", "job", 0, 4_000_000, 1),
            span("worker", "job", 0, 2_000_000, 2),
        ];
        let mut cp = span("taskgraph", "critical_path", 5_000_000, 0, 0);
        cp.n_args = 2;
        cp.args[0] = ("critical_path_s", 0.004);
        cp.args[1] = ("wall_s", 0.005);
        spans.push(cp);
        let trace = Trace {
            spans,
            threads: vec!["main".into(), "fmm2d-pool-0".into(), "fmm2d-pool-1".into()],
            dropped: 0,
        };
        let text = render(&export_chrome(&trace)).unwrap();
        assert!(text.contains("P2M"), "{text}");
        assert!(text.contains("M2L"), "{text}");
        assert!(text.contains("worker occupancy"), "{text}");
        assert!(text.contains("mean busy workers"), "{text}");
        assert!(text.contains("critical path"), "{text}");
        assert!(text.contains("fmm2d-pool-0"), "{text}");
    }

    #[test]
    fn report_rejects_non_traces() {
        assert!(render(&Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(
            r#"{"traceEvents":[{"ph":"X","name":"x","cat":"phase","ts":-5,"dur":1,"tid":0}]}"#,
        )
        .unwrap();
        assert!(render(&bad).is_err(), "negative ts must be rejected");
    }
}
