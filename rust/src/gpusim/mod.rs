//! GPU execution-cost simulator (placeholder — filled in by task #8).
pub mod model;
