//! GPU execution-cost simulator standing in for the paper's Tesla C2075 /
//! GTX 480 testbed: [`model`] predicts per-phase GPU times from measured
//! [`crate::fmm::WorkCounts`], including the batched-dispatch accounting
//! ([`model::GpuSim::batched_total_time`]) that charges one kernel launch
//! per phase per batch *group* instead of per problem.
pub mod model;
