//! # fmm2d — adaptive fast multipole methods, three-layer Rust + JAX + Pallas
//!
//! Reproduction of Goude & Engblom, *Adaptive fast multipole methods on the
//! GPU* (2012). The crate contains:
//!
//! * the **topological phase** of the paper — asymmetric-adaptive pyramid
//!   construction by median splits ([`tree`]) and θ-criterion connectivity
//!   ([`connectivity`]), unified behind the engine-selectable build layer
//!   [`topology`] (serial reference or multicore, bit-identical outputs);
//! * the **computational phase** — multipole/local expansion operators
//!   ([`expansion`]), a serial CPU driver ([`fmm`]) and the O(N²) baseline
//!   ([`direct`]);
//! * the **micro-kernel layer** — padded SoA leaf tiles and the blocked
//!   FMA harmonic P2P kernels shared by every CPU engine and the direct
//!   baselines ([`tiles`], DESIGN.md §10), with per-kernel throughput vs
//!   a measured roofline reported by `fmm2d kernel-bench`;
//! * the **data-parallel path** — packing of the pyramid into fixed-shape
//!   tensors ([`packing`]) executed through AOT-compiled XLA artifacts via
//!   PJRT (`runtime`, behind the non-default `pjrt` cargo feature: the
//!   default build carries no native dependencies);
//! * the **multithreaded CPU engine** — every computational phase sharded
//!   over worker threads with writer-side (no-lock) destination ownership
//!   ([`fmm::parallel`]), executed on a **persistent affinity-aware worker
//!   pool** ([`util::pool`]: threads spawned once per process, parked
//!   between fan-outs, sticky per-worker scratch, optional core pinning;
//!   the scoped spawn-per-phase variant is kept as the `pool-bench`
//!   reference);
//! * the **batch execution subsystem** — many small FMM problems grouped
//!   by compatible artifact shape and dispatched together, one pooled CPU
//!   execution or one batched XLA invocation per group ([`batch`]);
//! * the **autotuned dispatch subsystem** — per-problem and per-group
//!   engine selection from a calibrated cost model (measured CPU phase
//!   throughputs vs. the simulated-GPU batch price), persisted as a
//!   versioned JSON profile and exposed as `--engine auto`
//!   ([`dispatch`]);
//! * a **GPU execution-cost simulator** ([`gpusim`]) standing in for the
//!   paper's Tesla C2075 / GTX 480 testbed;
//! * the **serving layer** — `fmm2d serve`, a fault-tolerant line-JSON
//!   daemon with deadline-aware request batching, admission control, a
//!   panic-isolation degradation ladder, and a deterministic
//!   fault-injection harness plus load generator (`fmm2d loadgen`)
//!   ([`serve`], [`util::failpoint`], behind the non-default `failpoints`
//!   feature for the chaos sites);
//! * the **flight recorder** — zero-overhead-when-off span tracing across
//!   every engine, scheduler, batch and serve layer, exported as Chrome
//!   trace-event JSON (`--trace`, `fmm2d trace-report`), plus the serve
//!   metrics registry and the leveled structured logger ([`obs`]);
//! * the **evaluation harness** regenerating every table and figure of the
//!   paper ([`harness`], [`bench`], [`workload`]).
//!
//! See `DESIGN.md` for the full inventory and the per-experiment index.

// Index-driven `for b in 0..nb` loops mirror the paper's box arithmetic and
// are used pervasively throughout the crate.
#![allow(clippy::needless_range_loop)]

pub mod batch;
pub mod bench;
pub mod complex;
pub mod config;
pub mod connectivity;
pub mod direct;
pub mod dispatch;
pub mod expansion;
pub mod fmm;
pub mod geometry;
pub mod gpusim;
pub mod harness;
pub mod obs;
pub mod packing;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod tiles;
pub mod topology;
pub mod tree;
pub mod util;
pub mod workload;

pub use complex::C64;
pub use config::FmmConfig;
