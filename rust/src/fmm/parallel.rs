//! The multithreaded FMM execution engine.
//!
//! Every computational phase of the serial driver
//! ([`super::evaluate_on_tree_serial`]) is sharded over
//! `std::thread::scope` workers with **writer-side ownership**: each thread
//! owns a disjoint contiguous slice of the *destination* boxes (P2M/L2P/P2P
//! over leaf ranges, M2M/M2L/L2L over box ranges per level), matching the
//! paper's directed no-write-conflict list layout (§4.3), so the engine
//! needs no locks or atomics. The only cross-thread reduction is the
//! symmetric P2P path (§4.2), whose scattered `Φ_j −= Γ_i r` updates go to
//! per-thread full-length accumulators merged in thread order — the run is
//! deterministic for a fixed thread count.
//!
//! Work counts are *identical* to the serial engine (asserted by
//! `tests/parallel_parity.rs`): every count is derived from the same tree
//! and connectivity structure, so `gpusim` consumes the same
//! [`WorkCounts`] no matter which engine measured the tree. Destination
//! ranges are balanced by per-box work estimates
//! ([`weighted_ranges`]) because the symmetric P2P load is triangular and
//! the M2L in-degree varies on adaptive meshes.

use std::time::Instant;

use super::{CoeffPyramid, FmmOptions, Phase, PhaseTimes, WorkCounts};
use crate::complex::{C64, ZERO};
use crate::connectivity::Connectivity;
use crate::expansion::matrices::{M2lOperator, M2lScratch};
use crate::expansion::shifts::{l2l_with, m2l_with, m2m_scaled_with, ShiftScratch};
use crate::expansion::{l2p, m2p, p2l, p2m, Coeffs, Kernel};
use crate::tree::{boxes_at_level, Pyramid};
use crate::util::threadpool::{ranges, scoped_chunks_mut, split_lengths_mut, weighted_ranges};

/// The computational phase on a prebuilt tree, executed by `nt ≥ 1` worker
/// threads. Returns leaf-ordered potentials plus timings/counts
/// (Sort/Connect slots left zero), exactly like the serial driver.
pub fn evaluate_on_tree_parallel(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
    nt: usize,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    let p = opts.cfg.p;
    let stride = p + 1;
    let levels = pyr.levels;
    let nl = pyr.n_leaves();
    let n = pyr.particles.len();
    let nt = nt.clamp(1, nl);
    let mut times = PhaseTimes::default();
    let mut counts = WorkCounts {
        n,
        levels,
        p,
        leaf_sizes: (0..nl)
            .map(|b| (pyr.starts[b + 1] - pyr.starts[b]) as u32)
            .collect(),
        connect_checks: con.checks,
        sort: pyr.sort_stats,
        ..Default::default()
    };

    // SoA copies of the permuted particles, shared read-only by all workers
    let pos_v: Vec<C64> = pyr.particles.iter().map(|q| q.pos).collect();
    let gam_v: Vec<C64> = pyr.particles.iter().map(|q| q.gamma).collect();
    let pos: &[C64] = &pos_v;
    let gam: &[C64] = &gam_v;

    let mut multipole = CoeffPyramid::zeros(levels, p);
    let mut local = CoeffPyramid::zeros(levels, p);

    // ---- P2M: leaf multipole expansions, sharded over leaf ranges ------
    let t = Instant::now();
    {
        let centers = pyr.centers(levels);
        let rs = ranges(nl, nt);
        scoped_chunks_mut(&mut multipole.levels[levels], stride, &rs, |r, chunk| {
            let mut acc = Coeffs::zero(p);
            for (k, b) in (r.start..r.end).enumerate() {
                let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
                acc.clear();
                p2m(opts.kernel, centers[b], &pos[lo..hi], &gam[lo..hi], &mut acc);
                chunk[k * stride..(k + 1) * stride].copy_from_slice(&acc.0);
            }
        });
        counts.p2m_particles = n;
    }
    times.0[Phase::P2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2M: upward pass, sharded over *parent* ranges per level ------
    //
    // A thread owns a parent box together with its four (contiguous)
    // children, so the accumulation order into each parent matches the
    // serial driver exactly.
    let t = Instant::now();
    counts.m2m_per_level = vec![0; levels + 1];
    for l in (1..=levels).rev() {
        counts.m2m_per_level[l] = boxes_at_level(l);
        let (parents, children) = {
            // split-borrow the two levels
            let (lo, hi) = multipole.levels.split_at_mut(l);
            (&mut lo[l - 1], &hi[0])
        };
        let children: &[C64] = children;
        let child_centers = pyr.centers(l);
        let parent_centers = pyr.centers(l - 1);
        let rs = ranges(boxes_at_level(l - 1), nt);
        scoped_chunks_mut(parents, stride, &rs, |r, chunk| {
            let mut scratch = ShiftScratch::new();
            for (k, bp) in (r.start..r.end).enumerate() {
                let zp = parent_centers[bp];
                let parent = &mut chunk[k * stride..(k + 1) * stride];
                for bc in 4 * bp..4 * bp + 4 {
                    let zc = child_centers[bc];
                    let child = &children[bc * stride..(bc + 1) * stride];
                    if (zc - zp).norm_sqr() == 0.0 {
                        for (pa, ch) in parent.iter_mut().zip(child) {
                            *pa += *ch;
                        }
                    } else {
                        m2m_scaled_with(child, zc, parent, zp, &mut scratch);
                    }
                }
            }
        });
    }
    times.0[Phase::M2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2L (+ P2L): sharded over destination-box ranges per level ----
    let t = Instant::now();
    counts.m2l_per_level = vec![0; levels + 1];
    let m2l_op = (opts.kernel == Kernel::Harmonic).then(|| M2lOperator::new(p));
    for l in 1..=levels {
        counts.m2l_per_level[l] = con.weak[l].len();
        let nb = boxes_at_level(l);
        let centers = pyr.centers(l);
        let (mults, locs) = (&multipole.levels[l], &mut local.levels[l]);
        let mults: &[C64] = mults;
        // balance by per-destination in-degree (varies on adaptive meshes)
        let w: Vec<u64> = (0..nb)
            .map(|b| con.weak[l].sources(b).len() as u64)
            .collect();
        let rs = weighted_ranges(&w, nt);
        scoped_chunks_mut(locs, stride, &rs, |r, chunk| {
            let mut scratch = ShiftScratch::new();
            let mut m2l_scratch = M2lScratch::default();
            for (k, b) in (r.start..r.end).enumerate() {
                let zo = centers[b];
                let dst = &mut chunk[k * stride..(k + 1) * stride];
                for &s in con.weak[l].sources(b) {
                    let su = s as usize;
                    let src = &mults[su * stride..(su + 1) * stride];
                    match &m2l_op {
                        Some(op) => op.apply(src, centers[su], dst, zo, &mut m2l_scratch),
                        None => m2l_with(src, centers[su], dst, zo, &mut scratch),
                    }
                }
            }
        });
    }
    // P2L shortcuts (finest level; timed with M2L — they substitute for it)
    {
        counts.p2l_pairs = con.p2l.len();
        let centers = pyr.centers(levels);
        let rs = ranges(nl, nt);
        scoped_chunks_mut(&mut local.levels[levels], stride, &rs, |r, chunk| {
            for (k, b) in (r.start..r.end).enumerate() {
                if con.p2l.sources(b).is_empty() {
                    continue;
                }
                let dst = &mut chunk[k * stride..(k + 1) * stride];
                let mut acc = Coeffs(dst.to_vec());
                for &s in con.p2l.sources(b) {
                    let su = s as usize;
                    let (lo, hi) = (pyr.starts[su], pyr.starts[su + 1]);
                    p2l(opts.kernel, centers[b], &pos[lo..hi], &gam[lo..hi], &mut acc);
                }
                dst.copy_from_slice(&acc.0);
            }
        });
    }
    times.0[Phase::M2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2L: push local expansions down, sharded over child ranges ----
    let t = Instant::now();
    counts.l2l_per_level = vec![0; levels + 1];
    for l in 1..levels {
        counts.l2l_per_level[l + 1] = boxes_at_level(l + 1);
        let (parents, children) = {
            let (lo, hi) = local.levels.split_at_mut(l + 1);
            (&lo[l], &mut hi[0])
        };
        let parents: &[C64] = parents;
        let parent_centers = pyr.centers(l);
        let child_centers = pyr.centers(l + 1);
        let rs = ranges(boxes_at_level(l + 1), nt);
        scoped_chunks_mut(children, stride, &rs, |r, chunk| {
            let mut scratch = ShiftScratch::new();
            for (k, b) in (r.start..r.end).enumerate() {
                let zp = parent_centers[b >> 2];
                let zc = child_centers[b];
                let parent = &parents[(b >> 2) * stride..((b >> 2) + 1) * stride];
                let child = &mut chunk[k * stride..(k + 1) * stride];
                l2l_with(parent, zp, child, zc, &mut scratch);
            }
        });
    }
    times.0[Phase::L2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2P (+ M2P): sharded over leaf ranges; each worker owns the
    // contiguous particle slice of its boxes --------------------------
    let t = Instant::now();
    counts.m2p_pairs = con.m2p.len();
    let mut phi = vec![ZERO; n];
    {
        let centers_v = pyr.centers(levels);
        let centers: &[C64] = &centers_v;
        let mlev: &[C64] = &multipole.levels[levels];
        let llev: &[C64] = &local.levels[levels];
        let w: Vec<u64> = (0..nl)
            .map(|b| {
                let nb = (pyr.starts[b + 1] - pyr.starts[b]) as u64;
                nb * (1 + con.m2p.sources(b).len() as u64)
            })
            .collect();
        let rs = weighted_ranges(&w, nt);
        let lens: Vec<usize> = rs
            .iter()
            .map(|r| pyr.starts[r.end] - pyr.starts[r.start])
            .collect();
        let chunks = split_lengths_mut(&mut phi, &lens);
        std::thread::scope(|s| {
            for (r, chunk) in rs.iter().zip(chunks) {
                let r = r.clone();
                s.spawn(move || {
                    let base = pyr.starts[r.start];
                    for b in r.start..r.end {
                        let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
                        let loc = Coeffs(llev[b * stride..(b + 1) * stride].to_vec());
                        for i in lo..hi {
                            chunk[i - base] = l2p(centers[b], &loc, pos[i]);
                        }
                        for &src in con.m2p.sources(b) {
                            let su = src as usize;
                            let msrc = Coeffs(mlev[su * stride..(su + 1) * stride].to_vec());
                            for i in lo..hi {
                                chunk[i - base] += m2p(centers[su], &msrc, pos[i]);
                            }
                        }
                    }
                });
            }
        });
    }
    times.0[Phase::L2P as usize] = t.elapsed().as_secs_f64();

    // ---- P2P: near field -----------------------------------------------
    //
    // Work counts are derived from the list structure up front (identical
    // for both formulations and to the serial driver — see
    // `work_counts_consistent`): per destination box the streamed source
    // total, and in closed form Σ_b n_b·src_b − N ordered pairs.
    let t = Instant::now();
    counts.p2p_src_per_box = (0..nl)
        .map(|b| {
            con.near
                .sources(b)
                .iter()
                .map(|&s| (pyr.starts[s as usize + 1] - pyr.starts[s as usize]) as u32)
                .sum()
        })
        .collect();
    counts.p2p_pairs = counts
        .leaf_sizes
        .iter()
        .zip(&counts.p2p_src_per_box)
        .map(|(&nb, &src)| nb as usize * src as usize)
        .sum::<usize>()
        - n;
    let xs_v: Vec<f64> = pos.iter().map(|z| z.re).collect();
    let ys_v: Vec<f64> = pos.iter().map(|z| z.im).collect();
    let gre_v: Vec<f64> = gam.iter().map(|z| z.re).collect();
    let gim_v: Vec<f64> = gam.iter().map(|z| z.im).collect();
    let (xs, ys, gre, gim): (&[f64], &[f64], &[f64], &[f64]) = (&xs_v, &ys_v, &gre_v, &gim_v);
    if opts.symmetric_p2p && opts.kernel == Kernel::Harmonic {
        // CPU formulation (§4.2): each unordered box pair visited once by
        // the thread owning the lower-numbered box; the scattered Φ_j
        // updates go to per-thread accumulators merged in thread order.
        // The owner of box b does all pairs with sources ≥ b — a
        // triangular load, so ranges are balanced by pair weight.
        let w: Vec<u64> = (0..nl)
            .map(|b| {
                let nb = (pyr.starts[b + 1] - pyr.starts[b]) as u64;
                let srcs: u64 = con
                    .near
                    .sources(b)
                    .iter()
                    .filter(|&&s| s as usize >= b)
                    .map(|&s| (pyr.starts[s as usize + 1] - pyr.starts[s as usize]) as u64)
                    .sum();
                nb * srcs
            })
            .collect();
        let rs = weighted_ranges(&w, nt);
        let mut partials: Vec<(Vec<f64>, Vec<f64>)> = Vec::with_capacity(rs.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = rs
                .iter()
                .map(|r| {
                    let r = r.clone();
                    s.spawn(move || {
                        let mut phr = vec![0.0f64; n];
                        let mut phm = vec![0.0f64; n];
                        for b in r.start..r.end {
                            let (blo, bhi) = (pyr.starts[b], pyr.starts[b + 1]);
                            for &src in con.near.sources(b) {
                                let su = src as usize;
                                if su < b {
                                    continue; // owned by the other side
                                }
                                let (slo, shi) = (pyr.starts[su], pyr.starts[su + 1]);
                                for i in blo..bhi {
                                    let (xi, yi) = (xs[i], ys[i]);
                                    let (gri, gii) = (gre[i], gim[i]);
                                    let j0 = if su == b { i + 1 } else { slo };
                                    let (mut ar, mut ai) = (0.0f64, 0.0f64);
                                    for j in j0..shi {
                                        // r = 1/(z_j − z_i); Φ_i += Γ_j r;
                                        // Φ_j −= Γ_i r
                                        let dx = xs[j] - xi;
                                        let dy = ys[j] - yi;
                                        let inv = 1.0 / (dx * dx + dy * dy);
                                        let rr = dx * inv;
                                        let ri = -dy * inv;
                                        ar += gre[j] * rr - gim[j] * ri;
                                        ai += gre[j] * ri + gim[j] * rr;
                                        phr[j] -= gri * rr - gii * ri;
                                        phm[j] -= gri * ri + gii * rr;
                                    }
                                    phr[i] += ar;
                                    phm[i] += ai;
                                }
                            }
                        }
                        (phr, phm)
                    })
                })
                .collect();
            for h in handles {
                partials.push(h.join().expect("P2P worker panicked"));
            }
        });
        // Merge sharded over particle ranges; every worker folds the
        // per-thread accumulators for its slice in thread order, so the
        // result is independent of merge parallelism. (The accumulators
        // cost O(threads × N) transient memory — the price of the
        // lock-free symmetric formulation; the directed path below has no
        // reduction at all and is the better choice when memory-bound.)
        let partials: &[(Vec<f64>, Vec<f64>)] = &partials;
        let merge_rs = ranges(n, nt);
        let merge_lens: Vec<usize> = merge_rs.iter().map(|r| r.end - r.start).collect();
        let chunks = split_lengths_mut(&mut phi, &merge_lens);
        std::thread::scope(|s| {
            for (r, chunk) in merge_rs.iter().zip(chunks) {
                let r = r.clone();
                s.spawn(move || {
                    for (phr, phm) in partials {
                        for (k, i) in (r.start..r.end).enumerate() {
                            chunk[k] += C64::new(phr[i], phm[i]);
                        }
                    }
                });
            }
        });
    } else {
        // directed formulation (the GPU layout, §4.3): pure writer-side
        // sharding over destination boxes, no reduction at all.
        let w: Vec<u64> = (0..nl)
            .map(|b| counts.leaf_sizes[b] as u64 * counts.p2p_src_per_box[b] as u64)
            .collect();
        let rs = weighted_ranges(&w, nt);
        let lens: Vec<usize> = rs
            .iter()
            .map(|r| pyr.starts[r.end] - pyr.starts[r.start])
            .collect();
        let chunks = split_lengths_mut(&mut phi, &lens);
        std::thread::scope(|s| {
            for (r, chunk) in rs.iter().zip(chunks) {
                let r = r.clone();
                s.spawn(move || {
                    let base = pyr.starts[r.start];
                    for b in r.start..r.end {
                        let (blo, bhi) = (pyr.starts[b], pyr.starts[b + 1]);
                        for &src in con.near.sources(b) {
                            let su = src as usize;
                            let (slo, shi) = (pyr.starts[su], pyr.starts[su + 1]);
                            for i in blo..bhi {
                                let zi = pos[i];
                                let mut acc = chunk[i - base];
                                if su == b {
                                    for j in slo..shi {
                                        if j != i {
                                            acc += opts.kernel.eval(zi, pos[j], gam[j]);
                                        }
                                    }
                                } else {
                                    for j in slo..shi {
                                        acc += opts.kernel.eval(zi, pos[j], gam[j]);
                                    }
                                }
                                chunk[i - base] = acc;
                            }
                        }
                    }
                });
            }
        });
    }
    times.0[Phase::P2P as usize] = t.elapsed().as_secs_f64();

    (phi, times, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FmmConfig;
    use crate::util::rng::Pcg64;
    use crate::workload;

    #[test]
    fn parallel_matches_serial_on_a_small_tree() {
        let mut r = Pcg64::seed_from_u64(17);
        let (pts, gs) = workload::uniform_square(1500, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 2);
        let con = Connectivity::build(&pyr, 0.5);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 12,
                levels_override: Some(2),
                ..FmmConfig::default()
            },
            ..Default::default()
        };
        let (serial, _, cs) = super::super::evaluate_on_tree_serial(&pyr, &con, &opts);
        let (par, _, cp) = evaluate_on_tree_parallel(&pyr, &con, &opts, 3);
        for (a, b) in serial.iter().zip(&par) {
            assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
        }
        assert_eq!(cs.p2p_pairs, cp.p2p_pairs);
        assert_eq!(cs.p2p_src_per_box, cp.p2p_src_per_box);
        assert_eq!(cs.m2l_per_level, cp.m2l_per_level);
    }

    #[test]
    fn one_thread_degenerates_gracefully() {
        let mut r = Pcg64::seed_from_u64(23);
        let (pts, gs) = workload::uniform_square(600, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 2);
        let con = Connectivity::build(&pyr, 0.5);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 8,
                levels_override: Some(2),
                ..FmmConfig::default()
            },
            symmetric_p2p: false,
            ..Default::default()
        };
        let (serial, _, _) = super::super::evaluate_on_tree_serial(&pyr, &con, &opts);
        // directed P2P + per-box phases are bitwise-deterministic shards
        let (par, _, _) = evaluate_on_tree_parallel(&pyr, &con, &opts, 1);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.re, b.re);
            assert_eq!(a.im, b.im);
        }
    }
}
