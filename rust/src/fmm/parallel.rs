//! The multithreaded FMM execution engine.
//!
//! Every computational phase of the serial driver
//! ([`super::evaluate_on_tree_serial`]) is sharded over worker threads with
//! **writer-side ownership**: each worker owns a disjoint contiguous slice
//! of the *destination* boxes (P2M/L2P/P2P over leaf ranges, M2M/M2L/L2L
//! over box ranges per level), matching the paper's directed
//! no-write-conflict list layout (§4.3), so the engine needs no locks or
//! atomics in any kernel. The only cross-thread reduction is the symmetric
//! P2P path (§4.2), whose scattered `Φ_j −= Γ_i r` updates go to per-task
//! full-length accumulators merged in task order — the run is
//! deterministic for a fixed worker count.
//!
//! The engine exists in two variants with identical sharding and
//! arithmetic:
//!
//! * **Pooled** ([`evaluate_on_tree_pool`]) — the production path: every
//!   phase is a fan-out on a persistent [`WorkerPool`], so a full
//!   evaluation performs **zero thread spawns** (asserted by
//!   `tests/zero_spawn.rs`); per-worker `ShiftScratch`/`M2lScratch` and
//!   the pool-owned P2P accumulators are allocated once per pool, not once
//!   per phase.
//! * **Scoped** ([`evaluate_on_tree_parallel`]) — the historical
//!   spawn-per-phase engine over `std::thread::scope`, kept as the
//!   dispatch-overhead baseline that `pool-bench` compares against.
//!
//! Work counts are *identical* to the serial engine (asserted by
//! `tests/parallel_parity.rs` and `tests/pool_parity.rs`): every count is
//! derived from the same tree and connectivity structure, so `gpusim`
//! consumes the same [`WorkCounts`] no matter which engine measured the
//! tree. Destination ranges are balanced by per-box work estimates
//! ([`weighted_ranges`]) because the symmetric P2P load is triangular and
//! the M2L in-degree varies on adaptive meshes.
//!
//! Besides the per-problem engines above, this module provides the batch
//! entry points [`evaluate_trees_on_pool`] (pool workers claim whole
//! problems off a shared queue — the production path of
//! [`crate::batch`]) and the scoped [`evaluate_trees_pooled`] reference.
//! Per-problem results stay bitwise-identical to the serial driver — the
//! CPU counterpart of amortizing GPU launch overhead across a
//! packed-tensor batch.

use std::ops::Range;
use std::time::Instant;

use super::{CoeffPyramid, FmmOptions, Phase, PhaseTimes, WorkCounts};
use crate::complex::{C64, ZERO};
use crate::connectivity::Connectivity;
use crate::expansion::matrices::{M2lOperator, M2lScratch};
use crate::expansion::shifts::{l2l_with, m2l_with, m2m_scaled_with, ShiftScratch};
use crate::expansion::{l2p_slice, m2p_slice, p2l_slice, p2m_slice, Kernel};
use crate::tiles::{accum_harmonic, accum_scatter_harmonic, LeafTiles};
use crate::tree::{boxes_at_level, Pyramid};
use crate::util::pool::{note_spawn, Accum, WorkerPool};
use crate::util::threadpool::{ranges, scoped_chunks_mut, split_lengths_mut, weighted_ranges};

/// Per-destination-box M2L weights (in-degree varies on adaptive meshes).
pub(crate) fn m2l_weights(con: &Connectivity, l: usize, nb: usize) -> Vec<u64> {
    (0..nb)
        .map(|b| con.weak[l].sources(b).len() as u64)
        .collect()
}

/// Per-leaf L2P weights: particles × (own expansion + M2P sources).
pub(crate) fn l2p_weights(pyr: &Pyramid, con: &Connectivity, nl: usize) -> Vec<u64> {
    (0..nl)
        .map(|b| {
            let nb = (pyr.starts[b + 1] - pyr.starts[b]) as u64;
            nb * (1 + con.m2p.sources(b).len() as u64)
        })
        .collect()
}

/// Per-leaf symmetric-P2P pair weights (box `b` owns all pairs with
/// sources `≥ b` — a triangular load).
pub(crate) fn p2p_symmetric_weights(pyr: &Pyramid, con: &Connectivity, nl: usize) -> Vec<u64> {
    (0..nl)
        .map(|b| {
            let nb = (pyr.starts[b + 1] - pyr.starts[b]) as u64;
            let srcs: u64 = con
                .near
                .sources(b)
                .iter()
                .filter(|&&s| s as usize >= b)
                .map(|&s| (pyr.starts[s as usize + 1] - pyr.starts[s as usize]) as u64)
                .sum();
            nb * srcs
        })
        .collect()
}

/// The P2M inner loop of one leaf range (shared by the scoped and pooled
/// engines so their arithmetic is identical — as are all `*_range`
/// kernels below: each engine only supplies its own fan-out and scratch).
#[allow(clippy::too_many_arguments)] // internal kernel plumbing, not API
pub(crate) fn p2m_range(
    r: Range<usize>,
    chunk: &mut [C64],
    pyr: &Pyramid,
    centers: &[C64],
    pos: &[C64],
    gam: &[C64],
    kernel: Kernel,
    stride: usize,
) {
    for (k, b) in r.enumerate() {
        let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
        p2m_slice(
            kernel,
            centers[b],
            &pos[lo..hi],
            &gam[lo..hi],
            &mut chunk[k * stride..(k + 1) * stride],
        );
    }
}

/// The M2M inner loop of one *parent* range: a task owns a parent box
/// together with its four (contiguous) children, so the accumulation
/// order into each parent matches the serial driver exactly.
pub(crate) fn m2m_range(
    r: Range<usize>,
    chunk: &mut [C64],
    children: &[C64],
    child_centers: &[C64],
    parent_centers: &[C64],
    stride: usize,
    scratch: &mut ShiftScratch,
) {
    for (k, bp) in r.enumerate() {
        let zp = parent_centers[bp];
        let parent = &mut chunk[k * stride..(k + 1) * stride];
        for bc in 4 * bp..4 * bp + 4 {
            let zc = child_centers[bc];
            let child = &children[bc * stride..(bc + 1) * stride];
            if (zc - zp).norm_sqr() == 0.0 {
                for (pa, ch) in parent.iter_mut().zip(child) {
                    *pa += *ch;
                }
            } else {
                m2m_scaled_with(child, zc, parent, zp, scratch);
            }
        }
    }
}

/// The M2L inner loop of one destination range at level `l`.
#[allow(clippy::too_many_arguments)] // internal kernel plumbing, not API
pub(crate) fn m2l_range(
    r: Range<usize>,
    chunk: &mut [C64],
    con: &Connectivity,
    l: usize,
    centers: &[C64],
    mults: &[C64],
    stride: usize,
    m2l_op: Option<&M2lOperator>,
    shift: &mut ShiftScratch,
    m2l_scratch: &mut M2lScratch,
) {
    for (k, b) in r.enumerate() {
        let zo = centers[b];
        let dst = &mut chunk[k * stride..(k + 1) * stride];
        let srcs = con.weak[l].sources(b);
        match m2l_op {
            // harmonic hot path: one blocked matrix-panel application over
            // the destination's whole weak list (source order preserved —
            // see `M2lOperator::apply_panel`)
            Some(op) => op.apply_panel(mults, stride, srcs, centers, dst, zo, m2l_scratch),
            None => {
                for &s in srcs {
                    let su = s as usize;
                    let src = &mults[su * stride..(su + 1) * stride];
                    m2l_with(src, centers[su], dst, zo, shift);
                }
            }
        }
    }
}

/// Walk the near-field box pairs of destination range `r` in the
/// connectivity's source order — the one box-pair iteration all three
/// near-field formulations share (the symmetric and directed kernels below
/// plus the serial driver's count pass), so the tile micro-kernels are
/// wired in exactly once. `skip_lower` applies the symmetric ownership
/// rule (§4.2: the unordered pair `{b, su}` belongs to the side with the
/// lower box number).
pub(crate) fn near_pairs(
    con: &Connectivity,
    r: Range<usize>,
    skip_lower: bool,
    mut f: impl FnMut(usize, usize),
) {
    for b in r {
        for &src in con.near.sources(b) {
            let su = src as usize;
            if skip_lower && su < b {
                continue; // owned by the other side
            }
            f(b, su);
        }
    }
}

/// The P2L-shortcut inner loop of one finest-level range.
#[allow(clippy::too_many_arguments)] // internal kernel plumbing, not API
pub(crate) fn p2l_shortcut_range(
    r: Range<usize>,
    chunk: &mut [C64],
    pyr: &Pyramid,
    con: &Connectivity,
    centers: &[C64],
    pos: &[C64],
    gam: &[C64],
    kernel: Kernel,
    stride: usize,
) {
    for (k, b) in r.enumerate() {
        if con.p2l.sources(b).is_empty() {
            continue;
        }
        let dst = &mut chunk[k * stride..(k + 1) * stride];
        for &s in con.p2l.sources(b) {
            let su = s as usize;
            let (lo, hi) = (pyr.starts[su], pyr.starts[su + 1]);
            p2l_slice(kernel, centers[b], &pos[lo..hi], &gam[lo..hi], dst);
        }
    }
}

/// The L2L inner loop of one *child* range.
pub(crate) fn l2l_range(
    r: Range<usize>,
    chunk: &mut [C64],
    parents: &[C64],
    parent_centers: &[C64],
    child_centers: &[C64],
    stride: usize,
    scratch: &mut ShiftScratch,
) {
    for (k, b) in r.enumerate() {
        let zp = parent_centers[b >> 2];
        let zc = child_centers[b];
        let parent = &parents[(b >> 2) * stride..((b >> 2) + 1) * stride];
        let child = &mut chunk[k * stride..(k + 1) * stride];
        l2l_with(parent, zp, child, zc, scratch);
    }
}

/// The symmetric-P2P inner loop of one destination range, accumulating
/// into `phr`/`phm` (shared by the serial driver and every parallel
/// engine so their arithmetic is identical). Runs the blocked tile
/// micro-kernel ([`accum_scatter_harmonic`]) per box pair; because the
/// symmetric formulation scatters into the *source* particles, the source
/// loop is bounded to the tile's true population (scalar tail), never the
/// padded width.
pub(crate) fn p2p_symmetric_range(
    r: Range<usize>,
    pyr: &Pyramid,
    con: &Connectivity,
    tiles: &LeafTiles,
    phr: &mut [f64],
    phm: &mut [f64],
) {
    let nmax = tiles.nmax;
    near_pairs(con, r, true, |b, su| {
        let bt = b * nmax;
        let slen = tiles.len[su];
        let sxs = &tiles.xs[su * nmax..su * nmax + slen];
        let sys = &tiles.ys[su * nmax..su * nmax + slen];
        let sgre = &tiles.gre[su * nmax..su * nmax + slen];
        let sgim = &tiles.gim[su * nmax..su * nmax + slen];
        let blo = pyr.starts[b];
        let jbase = pyr.starts[su];
        for ii in 0..tiles.len[b] {
            let i = blo + ii;
            let (xi, yi) = (tiles.xs[bt + ii], tiles.ys[bt + ii]);
            let (gri, gii) = (tiles.gre[bt + ii], tiles.gim[bt + ii]);
            let j0 = if su == b { ii + 1 } else { 0 };
            // r = 1/(z_j − z_i); Φ_i += Γ_j r; Φ_j −= Γ_i r
            let (ar, ai) = accum_scatter_harmonic(
                sxs, sys, sgre, sgim, j0, slen, xi, yi, gri, gii, jbase, phr, phm,
            );
            phr[i] += ar;
            phm[i] += ai;
        }
    });
}

/// The directed-P2P inner loop of one destination range (GPU layout,
/// §4.3): pure writer-side sharding, no reduction at all. The harmonic
/// kernel runs the blocked tile micro-kernel ([`accum_harmonic`]) over the
/// full padded width — destination-side accumulation only, so padded
/// slots are exact no-ops and non-self tiles need no tail; the general
/// kernel (Log: `ln`/`atan2`-bound) keeps the per-pair evaluation.
#[allow(clippy::too_many_arguments)] // internal kernel plumbing, not API
pub(crate) fn p2p_directed_range(
    r: Range<usize>,
    chunk: &mut [C64],
    pyr: &Pyramid,
    con: &Connectivity,
    tiles: &LeafTiles,
    pos: &[C64],
    gam: &[C64],
    kernel: Kernel,
) {
    let base = pyr.starts[r.start];
    if kernel == Kernel::Harmonic {
        let nmax = tiles.nmax;
        near_pairs(con, r, false, |b, su| {
            let bt = b * nmax;
            let sxs = &tiles.xs[tiles.tile(su)];
            let sys = &tiles.ys[tiles.tile(su)];
            let sgre = &tiles.gre[tiles.tile(su)];
            let sgim = &tiles.gim[tiles.tile(su)];
            let blo = pyr.starts[b];
            for ii in 0..tiles.len[b] {
                let i = blo + ii;
                let (xi, yi) = (tiles.xs[bt + ii], tiles.ys[bt + ii]);
                let (ar, ai) = if su == b {
                    // self tile: skip slot ii by splitting the run
                    let lo = accum_harmonic(sxs, sys, sgre, sgim, 0, ii, xi, yi);
                    let hi = accum_harmonic(sxs, sys, sgre, sgim, ii + 1, nmax, xi, yi);
                    (lo.0 + hi.0, lo.1 + hi.1)
                } else {
                    accum_harmonic(sxs, sys, sgre, sgim, 0, nmax, xi, yi)
                };
                chunk[i - base] += C64::new(ar, ai);
            }
        });
    } else {
        near_pairs(con, r, false, |b, su| {
            let (blo, bhi) = (pyr.starts[b], pyr.starts[b + 1]);
            let (slo, shi) = (pyr.starts[su], pyr.starts[su + 1]);
            for i in blo..bhi {
                let zi = pos[i];
                let mut acc = chunk[i - base];
                if su == b {
                    for j in slo..shi {
                        if j != i {
                            acc += kernel.eval(zi, pos[j], gam[j]);
                        }
                    }
                } else {
                    for j in slo..shi {
                        acc += kernel.eval(zi, pos[j], gam[j]);
                    }
                }
                chunk[i - base] = acc;
            }
        });
    }
}

/// The L2P (+ M2P) inner loop of one leaf range (shared by both engines).
#[allow(clippy::too_many_arguments)] // internal kernel plumbing, not API
pub(crate) fn l2p_range(
    r: Range<usize>,
    chunk: &mut [C64],
    pyr: &Pyramid,
    con: &Connectivity,
    centers: &[C64],
    mlev: &[C64],
    llev: &[C64],
    pos: &[C64],
    stride: usize,
) {
    let base = pyr.starts[r.start];
    for b in r {
        let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
        let loc = &llev[b * stride..(b + 1) * stride];
        for i in lo..hi {
            chunk[i - base] = l2p_slice(centers[b], loc, pos[i]);
        }
        for &src in con.m2p.sources(b) {
            let su = src as usize;
            let msrc = &mlev[su * stride..(su + 1) * stride];
            for i in lo..hi {
                chunk[i - base] += m2p_slice(centers[su], msrc, pos[i]);
            }
        }
    }
}

/// The computational phase on a prebuilt tree, executed through the
/// **persistent worker pool**: every phase is one pool fan-out — zero
/// thread spawns — with per-worker scratch and pool-owned symmetric-P2P
/// accumulators reused across phases, problems and batches. Returns
/// leaf-ordered potentials plus timings/counts (Sort/Connect slots left
/// zero), exactly like the serial driver; results are bitwise-identical
/// to the scoped engine at the same worker count.
pub fn evaluate_on_tree_pool(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
    pool: &WorkerPool,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    let p = opts.cfg.p;
    let stride = p + 1;
    let levels = pyr.levels;
    let nl = pyr.n_leaves();
    let n = pyr.particles.len();
    let nt = opts
        .effective_threads()
        .min(pool.n_workers())
        .clamp(1, nl);
    let mut times = PhaseTimes::default();
    // identical to the serial driver's measured values — see the scoped
    // engine below and `structural_counts_match_measured`
    let counts = super::structural_counts(pyr, con, p);

    // SoA copies of the permuted particles, shared read-only by all workers
    let pos_v: Vec<C64> = pyr.particles.iter().map(|q| q.pos).collect();
    let gam_v: Vec<C64> = pyr.particles.iter().map(|q| q.gamma).collect();
    let pos: &[C64] = &pos_v;
    let gam: &[C64] = &gam_v;

    let mut multipole = CoeffPyramid::zeros(levels, p);
    let mut local = CoeffPyramid::zeros(levels, p);

    // ---- P2M: leaf multipole expansions, sharded over leaf ranges ------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "P2M").arg("workers", nt as f64);
    {
        let centers = pyr.centers(levels);
        let rs = ranges(nl, nt);
        pool.run_chunks_mut(&mut multipole.levels[levels], stride, &rs, |r, chunk, _ws| {
            p2m_range(r, chunk, pyr, &centers, pos, gam, opts.kernel, stride);
        });
    }
    drop(sp);
    times.0[Phase::P2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2M: upward pass, sharded over *parent* ranges per level ------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "M2M");
    for l in (1..=levels).rev() {
        let (parents, children) = {
            // split-borrow the two levels
            let (lo, hi) = multipole.levels.split_at_mut(l);
            (&mut lo[l - 1], &hi[0])
        };
        let children: &[C64] = children;
        let child_centers = pyr.centers(l);
        let parent_centers = pyr.centers(l - 1);
        let rs = ranges(boxes_at_level(l - 1), nt);
        pool.run_chunks_mut(parents, stride, &rs, |r, chunk, ws| {
            m2m_range(
                r,
                chunk,
                children,
                &child_centers,
                &parent_centers,
                stride,
                &mut ws.shift,
            );
        });
    }
    drop(sp);
    times.0[Phase::M2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2L (+ P2L): sharded over destination-box ranges per level ----
    let t = Instant::now();
    let sp = crate::obs::span("phase", "M2L");
    let m2l_op = (opts.kernel == Kernel::Harmonic).then(|| M2lOperator::new(p));
    for l in 1..=levels {
        let nb = boxes_at_level(l);
        let centers = pyr.centers(l);
        let (mults, locs) = (&multipole.levels[l], &mut local.levels[l]);
        let mults: &[C64] = mults;
        let rs = weighted_ranges(&m2l_weights(con, l, nb), nt);
        pool.run_chunks_mut(locs, stride, &rs, |r, chunk, ws| {
            m2l_range(
                r,
                chunk,
                con,
                l,
                &centers,
                mults,
                stride,
                m2l_op.as_ref(),
                &mut ws.shift,
                &mut ws.m2l,
            );
        });
    }
    // P2L shortcuts (finest level; timed with M2L — they substitute for it)
    {
        let centers = pyr.centers(levels);
        let rs = ranges(nl, nt);
        pool.run_chunks_mut(&mut local.levels[levels], stride, &rs, |r, chunk, _ws| {
            p2l_shortcut_range(r, chunk, pyr, con, &centers, pos, gam, opts.kernel, stride);
        });
    }
    drop(sp);
    times.0[Phase::M2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2L: push local expansions down, sharded over child ranges ----
    let t = Instant::now();
    let sp = crate::obs::span("phase", "L2L");
    for l in 1..levels {
        let (parents, children) = {
            let (lo, hi) = local.levels.split_at_mut(l + 1);
            (&lo[l], &mut hi[0])
        };
        let parents: &[C64] = parents;
        let parent_centers = pyr.centers(l);
        let child_centers = pyr.centers(l + 1);
        let rs = ranges(boxes_at_level(l + 1), nt);
        pool.run_chunks_mut(children, stride, &rs, |r, chunk, ws| {
            l2l_range(
                r,
                chunk,
                parents,
                &parent_centers,
                &child_centers,
                stride,
                &mut ws.shift,
            );
        });
    }
    drop(sp);
    times.0[Phase::L2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2P (+ M2P): sharded over leaf ranges; each task owns the
    // contiguous particle slice of its boxes --------------------------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "L2P");
    let mut phi = vec![ZERO; n];
    {
        let centers_v = pyr.centers(levels);
        let centers: &[C64] = &centers_v;
        let mlev: &[C64] = &multipole.levels[levels];
        let llev: &[C64] = &local.levels[levels];
        let rs = weighted_ranges(&l2p_weights(pyr, con, nl), nt);
        let lens: Vec<usize> = rs
            .iter()
            .map(|r| pyr.starts[r.end] - pyr.starts[r.start])
            .collect();
        let chunks = split_lengths_mut(&mut phi, &lens);
        let tasks: Vec<(Range<usize>, &mut [C64])> = rs.iter().cloned().zip(chunks).collect();
        pool.run_tasks(tasks, |_k, (r, chunk), _ws| {
            l2p_range(r, chunk, pyr, con, centers, mlev, llev, pos, stride);
        });
    }
    drop(sp);
    times.0[Phase::L2P as usize] = t.elapsed().as_secs_f64();

    // ---- P2P: near field -----------------------------------------------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "P2P");
    // padded SoA leaf tiles (DESIGN.md §10), shared read-only by all tasks
    let tiles_v = LeafTiles::build(pyr);
    let tiles = &tiles_v;
    if opts.symmetric_p2p && opts.kernel == Kernel::Harmonic {
        // CPU formulation (§4.2): the scattered Φ_j updates go to the
        // pool's persistent per-task accumulators, merged in task order —
        // same reduction order as the scoped engine, no allocation per
        // evaluation after the first.
        let rs = weighted_ranges(&p2p_symmetric_weights(pyr, con, nl), nt);
        let mut accs = pool.take_accums();
        // hard invariant, not a debug assert: zip-truncation below would
        // silently drop P2P ranges (wrong potentials, no panic)
        assert!(
            accs.len() >= rs.len(),
            "accumulator lease shorter than the range list ({} < {})",
            accs.len(),
            rs.len()
        );
        {
            let tasks: Vec<(Range<usize>, &mut Accum)> =
                rs.iter().cloned().zip(accs.iter_mut()).collect();
            pool.run_tasks(tasks, |_k, (r, acc), _ws| {
                acc.reset(n);
                p2p_symmetric_range(r, pyr, con, tiles, &mut acc.re, &mut acc.im);
            });
        }
        // Merge sharded over particle ranges; every task folds the
        // accumulators for its slice in task order, so the result is
        // independent of merge parallelism.
        {
            let parts: &[Accum] = &accs[..rs.len()];
            let merge_rs = ranges(n, nt);
            let merge_lens: Vec<usize> = merge_rs.iter().map(|r| r.end - r.start).collect();
            let chunks = split_lengths_mut(&mut phi, &merge_lens);
            let tasks: Vec<(Range<usize>, &mut [C64])> =
                merge_rs.iter().cloned().zip(chunks).collect();
            pool.run_tasks(tasks, |_k, (r, chunk), _ws| {
                for a in parts {
                    for (k, i) in (r.start..r.end).enumerate() {
                        chunk[k] += C64::new(a.re[i], a.im[i]);
                    }
                }
            });
        }
        pool.return_accums(accs);
    } else {
        // directed formulation (the GPU layout, §4.3): pure writer-side
        // sharding over destination boxes, no reduction at all.
        let w: Vec<u64> = (0..nl)
            .map(|b| counts.leaf_sizes[b] as u64 * counts.p2p_src_per_box[b] as u64)
            .collect();
        let rs = weighted_ranges(&w, nt);
        let lens: Vec<usize> = rs
            .iter()
            .map(|r| pyr.starts[r.end] - pyr.starts[r.start])
            .collect();
        let chunks = split_lengths_mut(&mut phi, &lens);
        let tasks: Vec<(Range<usize>, &mut [C64])> = rs.iter().cloned().zip(chunks).collect();
        pool.run_tasks(tasks, |_k, (r, chunk), _ws| {
            p2p_directed_range(r, chunk, pyr, con, tiles, pos, gam, opts.kernel);
        });
    }
    drop(sp);
    times.0[Phase::P2P as usize] = t.elapsed().as_secs_f64();

    (phi, times, counts)
}

/// The computational phase on a prebuilt tree, executed by `nt ≥ 1`
/// **scoped** worker threads (a fresh `std::thread::scope` per phase).
/// Kept as the dispatch-overhead reference that `pool-bench` measures the
/// persistent pool against; production dispatch goes through
/// [`evaluate_on_tree_pool`]. Returns leaf-ordered potentials plus
/// timings/counts (Sort/Connect slots left zero), exactly like the serial
/// driver.
pub fn evaluate_on_tree_parallel(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
    nt: usize,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    let p = opts.cfg.p;
    let stride = p + 1;
    let levels = pyr.levels;
    let nl = pyr.n_leaves();
    let n = pyr.particles.len();
    let nt = nt.clamp(1, nl);
    let mut times = PhaseTimes::default();
    // Every work count is a pure function of the tree + connectivity, so
    // this engine takes them wholesale from `structural_counts` instead of
    // re-deriving them per phase (identical to the serial driver's measured
    // values — asserted by `structural_counts_match_measured` and
    // `tests/parallel_parity.rs`).
    let counts = super::structural_counts(pyr, con, p);

    // SoA copies of the permuted particles, shared read-only by all workers
    let pos_v: Vec<C64> = pyr.particles.iter().map(|q| q.pos).collect();
    let gam_v: Vec<C64> = pyr.particles.iter().map(|q| q.gamma).collect();
    let pos: &[C64] = &pos_v;
    let gam: &[C64] = &gam_v;

    let mut multipole = CoeffPyramid::zeros(levels, p);
    let mut local = CoeffPyramid::zeros(levels, p);

    // ---- P2M: leaf multipole expansions, sharded over leaf ranges ------
    let t = Instant::now();
    {
        let centers = pyr.centers(levels);
        let rs = ranges(nl, nt);
        scoped_chunks_mut(&mut multipole.levels[levels], stride, &rs, |r, chunk| {
            p2m_range(r, chunk, pyr, &centers, pos, gam, opts.kernel, stride);
        });
    }
    times.0[Phase::P2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2M: upward pass, sharded over *parent* ranges per level ------
    let t = Instant::now();
    for l in (1..=levels).rev() {
        let (parents, children) = {
            // split-borrow the two levels
            let (lo, hi) = multipole.levels.split_at_mut(l);
            (&mut lo[l - 1], &hi[0])
        };
        let children: &[C64] = children;
        let child_centers = pyr.centers(l);
        let parent_centers = pyr.centers(l - 1);
        let rs = ranges(boxes_at_level(l - 1), nt);
        scoped_chunks_mut(parents, stride, &rs, |r, chunk| {
            let mut scratch = ShiftScratch::new();
            m2m_range(
                r,
                chunk,
                children,
                &child_centers,
                &parent_centers,
                stride,
                &mut scratch,
            );
        });
    }
    times.0[Phase::M2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2L (+ P2L): sharded over destination-box ranges per level ----
    let t = Instant::now();
    let m2l_op = (opts.kernel == Kernel::Harmonic).then(|| M2lOperator::new(p));
    for l in 1..=levels {
        let nb = boxes_at_level(l);
        let centers = pyr.centers(l);
        let (mults, locs) = (&multipole.levels[l], &mut local.levels[l]);
        let mults: &[C64] = mults;
        // balance by per-destination in-degree (varies on adaptive meshes)
        let rs = weighted_ranges(&m2l_weights(con, l, nb), nt);
        scoped_chunks_mut(locs, stride, &rs, |r, chunk| {
            let mut scratch = ShiftScratch::new();
            let mut m2l_scratch = M2lScratch::default();
            m2l_range(
                r,
                chunk,
                con,
                l,
                &centers,
                mults,
                stride,
                m2l_op.as_ref(),
                &mut scratch,
                &mut m2l_scratch,
            );
        });
    }
    // P2L shortcuts (finest level; timed with M2L — they substitute for it)
    {
        let centers = pyr.centers(levels);
        let rs = ranges(nl, nt);
        scoped_chunks_mut(&mut local.levels[levels], stride, &rs, |r, chunk| {
            p2l_shortcut_range(r, chunk, pyr, con, &centers, pos, gam, opts.kernel, stride);
        });
    }
    times.0[Phase::M2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2L: push local expansions down, sharded over child ranges ----
    let t = Instant::now();
    for l in 1..levels {
        let (parents, children) = {
            let (lo, hi) = local.levels.split_at_mut(l + 1);
            (&lo[l], &mut hi[0])
        };
        let parents: &[C64] = parents;
        let parent_centers = pyr.centers(l);
        let child_centers = pyr.centers(l + 1);
        let rs = ranges(boxes_at_level(l + 1), nt);
        scoped_chunks_mut(children, stride, &rs, |r, chunk| {
            let mut scratch = ShiftScratch::new();
            l2l_range(
                r,
                chunk,
                parents,
                &parent_centers,
                &child_centers,
                stride,
                &mut scratch,
            );
        });
    }
    times.0[Phase::L2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2P (+ M2P): sharded over leaf ranges; each worker owns the
    // contiguous particle slice of its boxes --------------------------
    let t = Instant::now();
    let mut phi = vec![ZERO; n];
    {
        let centers_v = pyr.centers(levels);
        let centers: &[C64] = &centers_v;
        let mlev: &[C64] = &multipole.levels[levels];
        let llev: &[C64] = &local.levels[levels];
        let rs = weighted_ranges(&l2p_weights(pyr, con, nl), nt);
        let lens: Vec<usize> = rs
            .iter()
            .map(|r| pyr.starts[r.end] - pyr.starts[r.start])
            .collect();
        let chunks = split_lengths_mut(&mut phi, &lens);
        // xtask: allow(no-spawn) — scoped reference engine, kept as the
        // spawn-per-phase baseline the pool engine is benchmarked against
        std::thread::scope(|s| {
            for (r, chunk) in rs.iter().zip(chunks) {
                let r = r.clone();
                note_spawn();
                s.spawn(move || {
                    l2p_range(r, chunk, pyr, con, centers, mlev, llev, pos, stride);
                });
            }
        });
    }
    times.0[Phase::L2P as usize] = t.elapsed().as_secs_f64();

    // ---- P2P: near field -----------------------------------------------
    //
    // Work counts (`p2p_src_per_box`, the closed-form Σ_b n_b·src_b − N
    // pair total) come from `structural_counts` above — identical for both
    // formulations and to the serial driver (`work_counts_consistent`).
    let t = Instant::now();
    // padded SoA leaf tiles (DESIGN.md §10), shared read-only by all tasks
    let tiles_v = LeafTiles::build(pyr);
    let tiles = &tiles_v;
    if opts.symmetric_p2p && opts.kernel == Kernel::Harmonic {
        // CPU formulation (§4.2): each unordered box pair visited once by
        // the thread owning the lower-numbered box; the scattered Φ_j
        // updates go to per-thread accumulators merged in thread order.
        let rs = weighted_ranges(&p2p_symmetric_weights(pyr, con, nl), nt);
        let mut partials: Vec<(Vec<f64>, Vec<f64>)> = Vec::with_capacity(rs.len());
        // xtask: allow(no-spawn) — scoped reference engine (see L2P above)
        std::thread::scope(|s| {
            let handles: Vec<_> = rs
                .iter()
                .map(|r| {
                    let r = r.clone();
                    note_spawn();
                    s.spawn(move || {
                        let mut phr = vec![0.0f64; n];
                        let mut phm = vec![0.0f64; n];
                        p2p_symmetric_range(r, pyr, con, tiles, &mut phr, &mut phm);
                        (phr, phm)
                    })
                })
                .collect();
            for h in handles {
                // xtask: allow(no-panic) — a worker panic here is already a
                // bug being re-raised; there is no caller-facing Result
                partials.push(h.join().expect("P2P worker panicked"));
            }
        });
        // Merge sharded over particle ranges; every worker folds the
        // per-thread accumulators for its slice in thread order, so the
        // result is independent of merge parallelism. (The accumulators
        // cost O(threads × N) transient memory — the price of the
        // lock-free symmetric formulation; the pooled engine reuses
        // pool-owned buffers instead of allocating them here.)
        let partials: &[(Vec<f64>, Vec<f64>)] = &partials;
        let merge_rs = ranges(n, nt);
        let merge_lens: Vec<usize> = merge_rs.iter().map(|r| r.end - r.start).collect();
        let chunks = split_lengths_mut(&mut phi, &merge_lens);
        // xtask: allow(no-spawn) — scoped reference engine (see L2P above)
        std::thread::scope(|s| {
            for (r, chunk) in merge_rs.iter().zip(chunks) {
                let r = r.clone();
                note_spawn();
                s.spawn(move || {
                    for (phr, phm) in partials {
                        for (k, i) in (r.start..r.end).enumerate() {
                            chunk[k] += C64::new(phr[i], phm[i]);
                        }
                    }
                });
            }
        });
    } else {
        // directed formulation (the GPU layout, §4.3): pure writer-side
        // sharding over destination boxes, no reduction at all.
        let w: Vec<u64> = (0..nl)
            .map(|b| counts.leaf_sizes[b] as u64 * counts.p2p_src_per_box[b] as u64)
            .collect();
        let rs = weighted_ranges(&w, nt);
        let lens: Vec<usize> = rs
            .iter()
            .map(|r| pyr.starts[r.end] - pyr.starts[r.start])
            .collect();
        let chunks = split_lengths_mut(&mut phi, &lens);
        // xtask: allow(no-spawn) — scoped reference engine (see L2P above)
        std::thread::scope(|s| {
            for (r, chunk) in rs.iter().zip(chunks) {
                let r = r.clone();
                note_spawn();
                s.spawn(move || {
                    p2p_directed_range(r, chunk, pyr, con, tiles, pos, gam, opts.kernel);
                });
            }
        });
    }
    times.0[Phase::P2P as usize] = t.elapsed().as_secs_f64();

    (phi, times, counts)
}

/// Evaluate many prebuilt trees through the **persistent worker pool**:
/// workers claim problems dynamically off a shared queue and run the
/// serial driver ([`super::evaluate_on_tree_serial`]) on each claim — the
/// production batch-group dispatch ([`crate::batch`]), performing zero
/// thread spawns. Per-problem results (potentials, times, counts) are
/// bitwise-identical to the serial driver; result order matches input
/// order regardless of which worker ran which problem.
pub fn evaluate_trees_on_pool(
    problems: &[(&Pyramid, &Connectivity)],
    opts: &FmmOptions,
    pool: &WorkerPool,
) -> Vec<(Vec<C64>, PhaseTimes, WorkCounts)> {
    if problems.is_empty() {
        return Vec::new();
    }
    type Out = (Vec<C64>, PhaseTimes, WorkCounts);
    let limit = opts.effective_threads().min(pool.n_workers());
    let out: Vec<std::sync::Mutex<Option<Out>>> =
        (0..problems.len()).map(|_| std::sync::Mutex::new(None)).collect();
    {
        let out = &out;
        pool.run_dynamic(
            (0..problems.len()).collect::<Vec<usize>>(),
            limit,
            |_k, i, _ws| {
                let (pyr, con) = problems[i];
                // xtask: allow(no-panic) — uncontended one-shot slot; a
                // poisoned lock means a worker already panicked
                *out[i].lock().unwrap() = Some(super::evaluate_on_tree_serial(pyr, con, opts));
            },
        );
    }
    out.into_iter()
        // xtask: allow(no-panic) — run_dynamic returns only after every
        // claimed index ran, so each slot is infallibly filled
        .map(|m| m.into_inner().unwrap().expect("every problem evaluated"))
        .collect()
}

/// Evaluate many prebuilt trees through **one** scoped worker pool: `nt`
/// workers claim problems from a shared atomic queue and run the serial
/// driver ([`super::evaluate_on_tree_serial`]) on each claim, so the
/// thread-spawn cost is paid once per batch group instead of once per
/// phase per problem. Kept as the scoped reference next to
/// [`evaluate_trees_on_pool`] (which spawns nothing at all). Per-problem
/// results are bitwise-identical to the serial driver; result order
/// matches input order regardless of which worker ran which problem.
pub fn evaluate_trees_pooled(
    problems: &[(&Pyramid, &Connectivity)],
    opts: &FmmOptions,
    nt: usize,
) -> Vec<(Vec<C64>, PhaseTimes, WorkCounts)> {
    if problems.is_empty() {
        return Vec::new();
    }
    let nt = nt.clamp(1, problems.len());
    if nt == 1 {
        return problems
            .iter()
            .map(|&(pyr, con)| super::evaluate_on_tree_serial(pyr, con, opts))
            .collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut collected = Vec::with_capacity(problems.len());
    // xtask: allow(no-spawn) — scoped reference engine for batch groups,
    // kept next to the spawn-free evaluate_trees_on_pool
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..nt)
            .map(|_| {
                let next = &next;
                note_spawn();
                s.spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= problems.len() {
                            break;
                        }
                        let (pyr, con) = problems[i];
                        mine.push((i, super::evaluate_on_tree_serial(pyr, con, opts)));
                    }
                    mine
                })
            })
            .collect();
        for h in handles {
            // xtask: allow(no-panic) — re-raising a worker panic, no
            // caller-facing Result to plumb it into
            collected.extend(h.join().expect("pooled batch worker panicked"));
        }
    });
    collected.sort_by_key(|(i, _)| *i);
    collected.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FmmConfig;
    use crate::util::rng::Pcg64;
    use crate::workload;

    #[test]
    fn parallel_matches_serial_on_a_small_tree() {
        let mut r = Pcg64::seed_from_u64(17);
        let (pts, gs) = workload::uniform_square(1500, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 12,
                levels_override: Some(2),
                ..FmmConfig::default()
            },
            ..Default::default()
        };
        let (serial, _, cs) = super::super::evaluate_on_tree_serial(&pyr, &con, &opts);
        let (par, _, cp) = evaluate_on_tree_parallel(&pyr, &con, &opts, 3);
        for (a, b) in serial.iter().zip(&par) {
            assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
        }
        assert_eq!(cs.p2p_pairs, cp.p2p_pairs);
        assert_eq!(cs.p2p_src_per_box, cp.p2p_src_per_box);
        assert_eq!(cs.m2l_per_level, cp.m2l_per_level);
    }

    #[test]
    fn pool_engine_is_bitwise_identical_to_scoped() {
        let mut r = Pcg64::seed_from_u64(29);
        let (pts, gs) = workload::normal_cloud(2000, 0.1, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        for symmetric in [true, false] {
            let opts = FmmOptions {
                cfg: FmmConfig {
                    p: 11,
                    levels_override: Some(3),
                    ..FmmConfig::default()
                },
                symmetric_p2p: symmetric,
                threads: Some(3),
                ..Default::default()
            };
            let pool = WorkerPool::new(3, false);
            let (scoped, _, cs) = evaluate_on_tree_parallel(&pyr, &con, &opts, 3);
            let (pooled, _, cp) = evaluate_on_tree_pool(&pyr, &con, &opts, &pool);
            assert_eq!(scoped.len(), pooled.len());
            for (a, b) in scoped.iter().zip(&pooled) {
                // identical sharding + identical reduction order ⇒ bitwise
                assert_eq!(a.re, b.re, "symmetric={symmetric}");
                assert_eq!(a.im, b.im, "symmetric={symmetric}");
            }
            assert_eq!(cs.p2p_pairs, cp.p2p_pairs);
            assert_eq!(cs.p2p_src_per_box, cp.p2p_src_per_box);
        }
    }

    #[test]
    fn pooled_batch_is_bitwise_serial_in_input_order() {
        let mut r = Pcg64::seed_from_u64(31);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 9,
                levels_override: Some(2),
                ..FmmConfig::default()
            },
            ..Default::default()
        };
        // heterogeneous sizes so workers finish out of order
        let trees: Vec<(Pyramid, Connectivity)> = [500usize, 1500, 700, 1100, 600]
            .iter()
            .map(|&n| {
                let (pts, gs) = workload::uniform_square(n, &mut r);
                let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
                let con = Connectivity::build(&pyr, 0.5);
                (pyr, con)
            })
            .collect();
        let refs: Vec<(&Pyramid, &Connectivity)> =
            trees.iter().map(|(p, c)| (p, c)).collect();
        let pool = WorkerPool::new(3, false);
        for pooled in [
            evaluate_trees_pooled(&refs, &opts, 3),
            evaluate_trees_on_pool(&refs, &opts, &pool),
        ] {
            assert_eq!(pooled.len(), trees.len());
            for ((pyr, con), (phi, _, counts)) in trees.iter().zip(&pooled) {
                let (serial, _, cs) = super::super::evaluate_on_tree_serial(pyr, con, &opts);
                assert_eq!(serial.len(), phi.len());
                for (a, b) in serial.iter().zip(phi) {
                    assert_eq!(a.re, b.re);
                    assert_eq!(a.im, b.im);
                }
                assert_eq!(cs.p2p_pairs, counts.p2p_pairs);
                assert_eq!(cs.n, counts.n);
            }
        }
    }

    #[test]
    fn one_thread_degenerates_gracefully() {
        let mut r = Pcg64::seed_from_u64(23);
        let (pts, gs) = workload::uniform_square(600, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 8,
                levels_override: Some(2),
                ..FmmConfig::default()
            },
            symmetric_p2p: false,
            ..Default::default()
        };
        let (serial, _, _) = super::super::evaluate_on_tree_serial(&pyr, &con, &opts);
        // directed P2P + per-box phases are bitwise-deterministic shards
        let (par, _, _) = evaluate_on_tree_parallel(&pyr, &con, &opts, 1);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.re, b.re);
            assert_eq!(a.im, b.im);
        }
        let pool = WorkerPool::new(1, false);
        let (pooled, _, _) = evaluate_on_tree_pool(&pyr, &con, &opts, &pool);
        for (a, b) in serial.iter().zip(&pooled) {
            assert_eq!(a.re, b.re);
            assert_eq!(a.im, b.im);
        }
    }
}
