//! The CPU FMM drivers: the paper's serial reference implementation
//! (§4: single-threaded, symmetry-exploiting, scaled shift operators) and
//! the multithreaded execution engine ([`parallel`]) that shards every
//! computational phase over the persistent worker pool
//! ([`crate::util::pool`]; the scoped spawn-per-phase variant is kept as
//! the benchmark reference).
//!
//! Both drivers are fully *phase-instrumented*: they report wall-clock time
//! and work counts for every phase of Table 5.1 (Sort, Connect, P2M, M2M,
//! M2L, L2L, L2P, P2P), which the evaluation harness uses directly and the
//! GPU cost simulator consumes as its workload description. The two
//! engines produce *identical* [`WorkCounts`] — only the wall-clock
//! differs.

pub mod parallel;
pub mod taskgraph;

use std::time::Instant;

use crate::complex::{C64, ZERO};
use crate::config::FmmConfig;
use crate::connectivity::Connectivity;
use crate::expansion::matrices::{M2lOperator, M2lScratch};
use crate::expansion::shifts::{l2l_with, m2l_with, m2m_scaled_with, ShiftScratch};
use crate::expansion::{l2p_slice, m2p_slice, p2l_slice, p2m_slice, Kernel};
use crate::tree::{boxes_at_level, partition::SortStats, Pyramid};

/// Phases of the algorithm, in execution order (Table 5.1 vocabulary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Sort = 0,
    Connect = 1,
    P2M = 2,
    M2M = 3,
    M2L = 4,
    L2L = 5,
    L2P = 6,
    P2P = 7,
}

pub const N_PHASES: usize = 8;
pub const PHASE_NAMES: [&str; N_PHASES] =
    ["Sort", "Connect", "P2M", "M2M", "M2L", "L2L", "L2P", "P2P"];

/// Wall-clock seconds per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes(pub [f64; N_PHASES]);

impl PhaseTimes {
    #[inline]
    pub fn get(&self, ph: Phase) -> f64 {
        self.0[ph as usize]
    }

    pub fn total(&self) -> f64 {
        self.0.iter().sum()
    }

    pub fn add(&mut self, other: &PhaseTimes) {
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += *b;
        }
    }

    pub fn scale(&mut self, s: f64) {
        for a in self.0.iter_mut() {
            *a *= s;
        }
    }
}

/// Work counts per phase — the architecture-independent description of one
/// FMM evaluation, from which `gpusim` predicts GPU time.
#[derive(Clone, Debug, Default)]
pub struct WorkCounts {
    pub n: usize,
    pub levels: usize,
    pub p: usize,
    /// Leaf populations (finest-level box sizes).
    pub leaf_sizes: Vec<u32>,
    /// Per level `1..=L`: number of M2L shifts.
    pub m2l_per_level: Vec<usize>,
    /// Per level `1..=L`: number of M2M shifts (= boxes at that level).
    pub m2m_per_level: Vec<usize>,
    /// Per level `1..=L`: number of L2L shifts into that level.
    pub l2l_per_level: Vec<usize>,
    /// P2P: pairwise kernel evaluations actually performed.
    pub p2p_pairs: usize,
    /// P2P: per destination box, the total count of source particles
    /// streamed through the cache (GPU model granularity).
    pub p2p_src_per_box: Vec<u32>,
    /// Finest-level shortcut pair counts.
    pub p2l_pairs: usize,
    pub m2p_pairs: usize,
    /// Particle↔expansion conversions.
    pub p2m_particles: usize,
    /// θ-criterion evaluations in the connect phase.
    pub connect_checks: usize,
    /// Partitioning statistics.
    pub sort: SortStats,
}

fn add_aligned(dst: &mut Vec<usize>, src: &[usize]) {
    if dst.len() < src.len() {
        dst.resize(src.len(), 0);
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

impl WorkCounts {
    /// Fold another problem's counts into this one (batch aggregation,
    /// [`crate::batch`]): scalars add, per-leaf vectors concatenate (the
    /// group's boxes all dispatch together), per-level vectors add
    /// element-wise aligned at the root, and `levels`/`p` take the
    /// maximum over the batch.
    pub fn absorb(&mut self, other: &WorkCounts) {
        self.n += other.n;
        self.levels = self.levels.max(other.levels);
        self.p = self.p.max(other.p);
        self.leaf_sizes.extend_from_slice(&other.leaf_sizes);
        self.p2p_src_per_box.extend_from_slice(&other.p2p_src_per_box);
        add_aligned(&mut self.m2l_per_level, &other.m2l_per_level);
        add_aligned(&mut self.m2m_per_level, &other.m2m_per_level);
        add_aligned(&mut self.l2l_per_level, &other.l2l_per_level);
        self.p2p_pairs += other.p2p_pairs;
        self.p2l_pairs += other.p2l_pairs;
        self.m2p_pairs += other.m2p_pairs;
        self.p2m_particles += other.p2m_particles;
        self.connect_checks += other.connect_checks;
        self.sort.merge(&other.sort);
    }

    /// Cheap a-priori estimate of the counts of an `(n, levels, p)`
    /// problem — no tree, no particle data, O(levels) arithmetic plus one
    /// `O(4^levels)` leaf-vector fill.
    ///
    /// The estimate models the pyramid as an idealized grid of congruent
    /// square boxes per level. On that geometry the θ-criterion depends
    /// only on the integer grid offset between two boxes, so the whole
    /// connectivity recursion collapses to a per-level sum over the finite
    /// set of *near* offsets, with exact boundary-aware pair counting —
    /// the M2L/near/check totals are **exact** for the idealized grid.
    /// Median splits balance leaf populations for *any* input
    /// distribution, so `leaf_sizes` and the per-level M2M/L2L counts are
    /// exact for the real tree too; the list-degree-dependent counts
    /// (`m2l_per_level`, `p2p_pairs`, `connect_checks`) track the real
    /// adaptive tree within a tolerance band that widens with clustering
    /// (pinned in `tests/dispatch.rs`). Equal radii make the interchanged
    /// criterion coincide with the plain one, so the idealized geometry
    /// has no P2L/M2P shortcuts.
    ///
    /// This is what lets the dispatch cost model ([`crate::dispatch`])
    /// price a problem *before* any tree is built.
    pub fn estimate(n: usize, levels: usize, p: usize, theta: f64) -> WorkCounts {
        let levels = levels.max(1);
        let nl: usize = 1 << (2 * levels);
        let nf = n as f64;

        // median splits balance leaf populations: ⌊n/4^L⌋ or ⌈n/4^L⌉ each
        let (base, rem) = (n / nl, n % nl);
        let leaf_sizes: Vec<u32> = (0..nl)
            .map(|b| (base + usize::from(b < rem)) as u32)
            .collect();

        // Congruent square boxes of side 2^-l have radius √2·2^-l/2, so
        // the θ-criterion R + θ·r ≤ θ·d reads, in grid-offset units o:
        // well separated ⇔ |o| ≥ (1+θ)/(√2·θ) = thr (θ = 1/2 gives
        // thr² = 4.5: offsets (±2,±1) and beyond are weak).
        let thr2 = {
            let t = (1.0 + theta) * std::f64::consts::FRAC_1_SQRT_2 / theta;
            t * t
        };
        let near = |dx: i64, dy: i64| ((dx * dx + dy * dy) as f64) < thr2;
        let reach = thr2.sqrt().ceil() as i64;
        let near_offsets: Vec<(i64, i64)> = (-reach..=reach)
            .flat_map(|dx| (-reach..=reach).map(move |dy| (dx, dy)))
            .filter(|&(dx, dy)| near(dx, dy))
            .collect();
        // per-axis child-corner differences c_src − c_dst with multiplicity
        const CORNER: [(i64, f64); 3] = [(-1, 1.0), (0, 2.0), (1, 1.0)];

        let mut m2l_per_level = vec![0usize; levels + 1];
        let mut m2m_per_level = vec![0usize; levels + 1];
        let mut l2l_per_level = vec![0usize; levels + 1];
        let mut checks = 0.0f64;
        let mut near_leaf_pairs = 0.0f64;
        for l in 1..=levels {
            // a pair of level-l boxes is examined iff its *parent* offset
            // is near (children of the parent's strong list, §2); parent
            // pairs at offset (dx, dy) in the 2^(l−1)-wide grid count
            // (g−|dx|)⁺·(g−|dy|)⁺, each contributing 4×4 child pairs
            let g = 1i64 << (l - 1);
            let mut weak_l = 0.0;
            let mut near_l = 0.0;
            for &(dx, dy) in &near_offsets {
                let pairs = ((g - dx.abs()).max(0) * (g - dy.abs()).max(0)) as f64;
                if pairs == 0.0 {
                    continue;
                }
                checks += pairs * 16.0;
                for (ex, wx) in CORNER {
                    for (ey, wy) in CORNER {
                        let w = pairs * wx * wy;
                        if near(2 * dx + ex, 2 * dy + ey) {
                            near_l += w;
                        } else {
                            weak_l += w;
                        }
                    }
                }
            }
            m2l_per_level[l] = weak_l.round() as usize;
            m2m_per_level[l] = boxes_at_level(l);
            if l >= 2 {
                l2l_per_level[l] = boxes_at_level(l);
            }
            if l == levels {
                near_leaf_pairs = near_l;
            }
        }
        // finest level: one interchanged check per off-diagonal strong pair
        checks += (near_leaf_pairs - nl as f64).max(0.0);

        let nd = nf / nl as f64;
        let src_avg = near_leaf_pairs * nd / nl as f64;
        let p2p_src_per_box = vec![src_avg.round() as u32; nl];
        let p2p_pairs = (near_leaf_pairs * nd * nd - nf).max(0.0).round() as usize;

        WorkCounts {
            n,
            levels,
            p,
            leaf_sizes,
            m2l_per_level,
            m2m_per_level,
            l2l_per_level,
            p2p_pairs,
            p2p_src_per_box,
            p2l_pairs: 0,
            m2p_pairs: 0,
            p2m_particles: n,
            connect_checks: checks.round() as usize,
            sort: SortStats {
                // boxes × 3 splits per refined level: Σ 3·4^l = 4^L − 1
                splits: nl - 1,
                elements_visited: 3 * n * levels,
                passes: 2 * (nl - 1),
                scattered: 2 * n * levels,
            },
        }
    }
}

/// Work counts derived from the tree + connectivity structure alone,
/// without running any engine. Identical to what the CPU drivers measure
/// on the same tree (asserted in `structural_counts_match_measured`); used
/// by execution paths that cannot instrument phases, like the batched XLA
/// dispatch ([`crate::batch`]).
pub fn structural_counts(pyr: &Pyramid, con: &Connectivity, p: usize) -> WorkCounts {
    let levels = pyr.levels;
    let nl = pyr.n_leaves();
    let n = pyr.particles.len();
    let leaf_sizes: Vec<u32> = (0..nl)
        .map(|b| (pyr.starts[b + 1] - pyr.starts[b]) as u32)
        .collect();
    let p2p_src_per_box: Vec<u32> = (0..nl)
        .map(|b| {
            con.near
                .sources(b)
                .iter()
                .map(|&s| (pyr.starts[s as usize + 1] - pyr.starts[s as usize]) as u32)
                .sum()
        })
        .collect();
    let p2p_pairs = leaf_sizes
        .iter()
        .zip(&p2p_src_per_box)
        .map(|(&nb, &src)| nb as usize * src as usize)
        .sum::<usize>()
        - n;
    let mut m2l_per_level = vec![0; levels + 1];
    let mut m2m_per_level = vec![0; levels + 1];
    let mut l2l_per_level = vec![0; levels + 1];
    for l in 1..=levels {
        m2l_per_level[l] = con.weak[l].len();
        m2m_per_level[l] = boxes_at_level(l);
        if l >= 2 {
            l2l_per_level[l] = boxes_at_level(l);
        }
    }
    WorkCounts {
        n,
        levels,
        p,
        leaf_sizes,
        m2l_per_level,
        m2m_per_level,
        l2l_per_level,
        p2p_pairs,
        p2p_src_per_box,
        p2l_pairs: con.p2l.len(),
        m2p_pairs: con.m2p.len(),
        p2m_particles: n,
        connect_checks: con.checks,
        sort: pyr.sort_stats,
    }
}

/// Which multicore engine runs the computational phase when
/// [`FmmOptions::effective_threads`] resolves above one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CpuEngine {
    /// The pooled barrier engine ([`parallel::evaluate_on_tree_pool`]):
    /// all eight phases as global fork-joins on the persistent pool.
    #[default]
    Barrier,
    /// The task-graph pipelined engine
    /// ([`taskgraph::evaluate_on_tree_taskgraph`]): the same shards,
    /// dependency-gated instead of barrier-separated, so P2P overlaps the
    /// multipole chain. Bitwise-identical results to [`Self::Barrier`].
    TaskGraph,
}

/// Options of one evaluation.
#[derive(Clone, Debug)]
pub struct FmmOptions {
    pub cfg: FmmConfig,
    pub kernel: Kernel,
    /// Use the CPU symmetry trick in the near field (§4.2). The directed
    /// (GPU-layout) evaluation is used when false.
    pub symmetric_p2p: bool,
    /// Worker threads for the computational phase: `Some(1)` forces the
    /// serial reference driver, `Some(t)` uses `t` workers, `None` (the
    /// default) uses the machine's available parallelism.
    pub threads: Option<usize>,
    /// Worker threads for the topological phase (Sort + Connect,
    /// [`crate::topology`]): `Some(1)` forces the serial build, `Some(t)`
    /// uses `t` workers, `None` (the default) follows `threads` — so
    /// `--threads` accelerates the whole evaluation, not just the
    /// computational phase. Both engines build bit-identical topologies.
    pub topo_threads: Option<usize>,
    /// Best-effort core pinning (worker *i* → core *i*, `--pin`): consulted
    /// when `pool` is `None` to pick the pinned flavor of the process-wide
    /// shared pool ([`crate::util::pool::global`]).
    pub pin: bool,
    /// The persistent worker pool executing this evaluation
    /// ([`crate::util::pool::WorkerPool`]). `None` (the default) resolves
    /// to the process-wide shared pool, so after the first evaluation no
    /// code path spawns threads. Own a pool explicitly to isolate
    /// workloads or control its size/pinning/lifetime.
    pub pool: Option<std::sync::Arc<crate::util::pool::WorkerPool>>,
    /// Multicore engine flavor for the computational phase (ignored when
    /// the resolved thread count is 1, which always runs the serial
    /// reference driver). See [`CpuEngine`].
    pub cpu_engine: CpuEngine,
}

impl Default for FmmOptions {
    fn default() -> Self {
        Self {
            cfg: FmmConfig::default(),
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads: None,
            topo_threads: None,
            pin: false,
            pool: None,
            cpu_engine: CpuEngine::default(),
        }
    }
}

impl FmmOptions {
    /// Resolved worker-thread count (≥ 1).
    pub fn effective_threads(&self) -> usize {
        self.threads
            .unwrap_or_else(crate::util::threadpool::available_threads)
            .max(1)
    }

    /// Resolved topology worker count (≥ 1): `topo_threads` if set,
    /// otherwise the computational `threads` setting.
    pub fn effective_topo_threads(&self) -> usize {
        match self.topo_threads {
            Some(t) => t.max(1),
            None => self.effective_threads(),
        }
    }

    /// The worker pool these options select: the explicit [`Self::pool`]
    /// if set, otherwise the process-wide shared pool (pinned flavor per
    /// [`Self::pin`]).
    pub fn shared_pool(&self) -> std::sync::Arc<crate::util::pool::WorkerPool> {
        match &self.pool {
            Some(p) => std::sync::Arc::clone(p),
            None => crate::util::pool::global(self.pin),
        }
    }

    /// The topology build configuration implied by these options. Carries
    /// the resolved pool whenever the topology engine is parallel, so the
    /// Sort/Connect prologue spawns no threads either.
    pub fn topology_options(&self) -> crate::topology::TopologyOptions {
        let nt = self.effective_topo_threads();
        let mut topo = crate::topology::TopologyOptions::parallel(self.cfg.theta, nt);
        if nt > 1 {
            topo.pool = Some(self.shared_pool());
        }
        topo
    }
}

/// Result of one evaluation.
#[derive(Clone, Debug)]
pub struct FmmOutput {
    /// Potential at every input point, in the caller's original order.
    pub potentials: Vec<C64>,
    pub times: PhaseTimes,
    pub counts: WorkCounts,
}

/// Coefficient pyramid: per level, a flat `4^l × (p+1)` array.
pub(crate) struct CoeffPyramid {
    pub p: usize,
    pub levels: Vec<Vec<C64>>,
}

impl CoeffPyramid {
    fn zeros(levels: usize, p: usize) -> Self {
        Self {
            p,
            levels: (0..=levels)
                .map(|l| vec![ZERO; boxes_at_level(l) * (p + 1)])
                .collect(),
        }
    }

    #[inline]
    pub fn of(&self, l: usize, b: usize) -> &[C64] {
        &self.levels[l][b * (self.p + 1)..(b + 1) * (self.p + 1)]
    }

    #[inline]
    fn of_mut(&mut self, l: usize, b: usize) -> &mut [C64] {
        &mut self.levels[l][b * (self.p + 1)..(b + 1) * (self.p + 1)]
    }
}

/// Evaluate Eq. (1.1) at all source points with the adaptive FMM.
///
/// The topological phase (Sort + Connect) goes through the unified
/// [`crate::topology`] build layer with the engine selected by
/// [`FmmOptions::effective_topo_threads`]; errors on inputs that cannot
/// form a pyramid (e.g. an explicit `levels_override` that exceeds the
/// particle count) instead of panicking.
pub fn evaluate(
    points: &[C64],
    gammas: &[C64],
    opts: &FmmOptions,
) -> crate::util::error::Result<FmmOutput> {
    let levels = opts.cfg.levels_for(points.len());
    let topo = crate::topology::build(points, gammas, levels, &opts.topology_options())?;

    let (phi_leaf, mut times, counts) = evaluate_on_tree(&topo.pyramid, &topo.connectivity, opts);
    times.0[Phase::Sort as usize] = topo.sort_s;
    times.0[Phase::Connect as usize] = topo.connect_s;

    Ok(FmmOutput {
        potentials: topo.pyramid.unpermute(&phi_leaf),
        times,
        counts,
    })
}

/// The computational phase on a prebuilt tree: returns leaf-ordered
/// potentials plus timings/counts (Sort/Connect slots left zero).
///
/// Exposed so the harness can time the computational part against *fixed*
/// trees — exactly what the paper does ("the sorting was performed on the
/// CPU to ensure identical multipole trees", §5).
///
/// Dispatches between the serial reference driver and the pooled
/// multithreaded engine according to [`FmmOptions::effective_threads`];
/// multicore runs execute on the persistent worker pool resolved by
/// [`FmmOptions::shared_pool`] (zero thread spawns once the pool exists).
/// The scoped spawn-per-phase engine remains available directly as
/// [`parallel::evaluate_on_tree_parallel`] — it is the `pool-bench`
/// comparison baseline, not a dispatch target.
pub fn evaluate_on_tree(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    let nt = opts.effective_threads().min(pyr.n_leaves());
    if nt > 1 {
        let pool = opts.shared_pool();
        return match opts.cpu_engine {
            CpuEngine::Barrier => parallel::evaluate_on_tree_pool(pyr, con, opts, &pool),
            CpuEngine::TaskGraph => {
                taskgraph::evaluate_on_tree_taskgraph(pyr, con, opts, &pool)
            }
        };
    }
    evaluate_on_tree_serial(pyr, con, opts)
}

/// The serial reference driver (the paper's single-threaded CPU code, §4).
pub fn evaluate_on_tree_serial(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    let p = opts.cfg.p;
    let levels = pyr.levels;
    let nl = pyr.n_leaves();
    let mut times = PhaseTimes::default();
    let mut counts = WorkCounts {
        n: pyr.particles.len(),
        levels,
        p,
        leaf_sizes: (0..nl)
            .map(|b| (pyr.starts[b + 1] - pyr.starts[b]) as u32)
            .collect(),
        connect_checks: con.checks,
        sort: pyr.sort_stats,
        ..Default::default()
    };

    // SoA copies of the permuted particles (used by every particle phase)
    let pos: Vec<C64> = pyr.particles.iter().map(|q| q.pos).collect();
    let gam: Vec<C64> = pyr.particles.iter().map(|q| q.gamma).collect();

    let mut multipole = CoeffPyramid::zeros(levels, p);
    let mut local = CoeffPyramid::zeros(levels, p);
    let mut scratch = ShiftScratch::new();

    // ---- P2M: leaf multipole expansions -------------------------------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "P2M").arg("leaves", nl as f64);
    {
        let centers = pyr.centers(levels);
        for b in 0..nl {
            let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
            // accumulate straight into the (zeroed) pyramid storage — no
            // per-box Coeffs temporary
            p2m_slice(
                opts.kernel,
                centers[b],
                &pos[lo..hi],
                &gam[lo..hi],
                multipole.of_mut(levels, b),
            );
        }
        counts.p2m_particles = pyr.particles.len();
    }
    drop(sp);
    times.0[Phase::P2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2M: upward pass ---------------------------------------------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "M2M");
    counts.m2m_per_level = vec![0; levels + 1];
    for l in (1..=levels).rev() {
        let (parents, children) = {
            // split-borrow the two levels
            let (lo, hi) = multipole.levels.split_at_mut(l);
            (&mut lo[l - 1], &hi[0])
        };
        let child_centers = pyr.centers(l);
        let parent_centers = pyr.centers(l - 1);
        for b in 0..boxes_at_level(l) {
            let zc = child_centers[b];
            let zp = parent_centers[b >> 2];
            let child = &children[b * (p + 1)..(b + 1) * (p + 1)];
            let parent = &mut parents[(b >> 2) * (p + 1)..((b >> 2) + 1) * (p + 1)];
            if (zc - zp).norm_sqr() == 0.0 {
                for (pa, ch) in parent.iter_mut().zip(child) {
                    *pa += *ch;
                }
            } else {
                m2m_scaled_with(child, zc, parent, zp, &mut scratch);
            }
            counts.m2m_per_level[l] += 1;
        }
    }
    drop(sp);
    times.0[Phase::M2M as usize] = t.elapsed().as_secs_f64();

    // ---- M2L: the downward pass's far-field input ----------------------
    //
    // Hot path: the harmonic kernel (a_0 = 0) goes through the precomputed
    // constant-matrix operator (vectorizable dot products — EXPERIMENTS.md
    // §Perf); the general kernel keeps the paper-style recurrence, whose
    // a_0 terms the matrix path omits.
    let t = Instant::now();
    let sp = crate::obs::span("phase", "M2L");
    counts.m2l_per_level = vec![0; levels + 1];
    let m2l_op = (opts.kernel == Kernel::Harmonic).then(|| M2lOperator::new(p));
    let mut m2l_scratch = M2lScratch::default();
    for l in 1..=levels {
        let centers = pyr.centers(l);
        let (mults, locs) = (&multipole.levels[l], &mut local.levels[l]);
        for b in 0..boxes_at_level(l) {
            let zo = centers[b];
            let dst = &mut locs[b * (p + 1)..(b + 1) * (p + 1)];
            let srcs = con.weak[l].sources(b);
            match &m2l_op {
                // one destination-grouped panel over all weak sources
                // (same blocked kernel as the parallel engines, §10)
                Some(op) => op.apply_panel(mults, p + 1, srcs, centers, dst, zo, &mut m2l_scratch),
                None => {
                    for &s in srcs {
                        let su = s as usize;
                        let src = &mults[su * (p + 1)..(su + 1) * (p + 1)];
                        m2l_with(src, centers[su], dst, zo, &mut scratch);
                    }
                }
            }
            counts.m2l_per_level[l] += srcs.len();
        }
    }
    // P2L shortcuts (finest level; timed with M2L — they substitute for it)
    {
        let centers = pyr.centers(levels);
        for b in 0..nl {
            let dst = local.of_mut(levels, b);
            for &s in con.p2l.sources(b) {
                let su = s as usize;
                let (lo, hi) = (pyr.starts[su], pyr.starts[su + 1]);
                // accumulate in place — p2l only adds to the coefficients,
                // so the copy-out/copy-back through a Coeffs temporary the
                // driver used to do was pure allocation churn
                p2l_slice(opts.kernel, centers[b], &pos[lo..hi], &gam[lo..hi], dst);
                counts.p2l_pairs += 1;
            }
        }
    }
    drop(sp);
    times.0[Phase::M2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2L: push local expansions down -------------------------------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "L2L");
    counts.l2l_per_level = vec![0; levels + 1];
    for l in 1..levels {
        let (parents, children) = {
            let (lo, hi) = local.levels.split_at_mut(l + 1);
            (&lo[l], &mut hi[0])
        };
        let parent_centers = pyr.centers(l);
        let child_centers = pyr.centers(l + 1);
        for b in 0..boxes_at_level(l + 1) {
            let zp = parent_centers[b >> 2];
            let zc = child_centers[b];
            let parent = &parents[(b >> 2) * (p + 1)..((b >> 2) + 1) * (p + 1)];
            let child = &mut children[b * (p + 1)..(b + 1) * (p + 1)];
            l2l_with(parent, zp, child, zc, &mut scratch);
            counts.l2l_per_level[l + 1] += 1;
        }
    }
    drop(sp);
    times.0[Phase::L2L as usize] = t.elapsed().as_secs_f64();

    // ---- L2P (+ M2P): far-field potential at the particles -------------
    let t = Instant::now();
    let sp = crate::obs::span("phase", "L2P");
    let mut phi = vec![ZERO; pyr.particles.len()];
    {
        let centers = pyr.centers(levels);
        for b in 0..nl {
            let (lo, hi) = (pyr.starts[b], pyr.starts[b + 1]);
            // evaluate straight from the pyramid storage — the driver used
            // to copy every box's coefficients into a Coeffs per box
            let loc = local.of(levels, b);
            for i in lo..hi {
                phi[i] = l2p_slice(centers[b], loc, pos[i]);
            }
            for &s in con.m2p.sources(b) {
                let su = s as usize;
                let msrc = multipole.of(levels, su);
                for i in lo..hi {
                    phi[i] += m2p_slice(centers[su], msrc, pos[i]);
                }
                counts.m2p_pairs += 1;
            }
        }
    }
    drop(sp);
    times.0[Phase::L2P as usize] = t.elapsed().as_secs_f64();

    // ---- P2P: near field ------------------------------------------------
    //
    // Routed through the same blocked SoA tile micro-kernels as every
    // parallel engine ([`parallel::p2p_symmetric_range`] /
    // [`parallel::p2p_directed_range`] over [`crate::tiles::LeafTiles`],
    // DESIGN.md §10 — the CPU-side counterpart of the paper's
    // SSE-intrinsics P2P, §4.4), so a whole-range serial call is bitwise
    // what a one-thread parallel run computes. Work counts are integer
    // identities of the box-pair structure, tallied in a separate
    // arithmetic-free pass with the semantics the measured loops had:
    // `p2p_src_per_box` counts every source of every destination
    // (directed/GPU semantics, formulation-independent — asserted in
    // `work_counts_consistent`), `p2p_pairs` counts kernel evaluations of
    // the chosen formulation.
    let t = Instant::now();
    let sp = crate::obs::span("phase", "P2P");
    counts.p2p_src_per_box = vec![0; nl];
    let tiles = crate::tiles::LeafTiles::build(pyr);
    let symmetric = opts.symmetric_p2p && opts.kernel == Kernel::Harmonic;
    parallel::near_pairs(con, 0..nl, false, |b, su| {
        let nb = pyr.starts[b + 1] - pyr.starts[b];
        let ns = pyr.starts[su + 1] - pyr.starts[su];
        counts.p2p_src_per_box[b] += ns as u32;
        if symmetric && su < b {
            return; // pair owned (and counted) by the other side
        }
        counts.p2p_pairs += if su == b {
            // self pairs: n·(n−1) ordered evaluations either way (the
            // symmetric path does half the reciprocals for the same count)
            nb * nb.saturating_sub(1)
        } else if symmetric {
            2 * nb * ns // one shared reciprocal serves both directions
        } else {
            nb * ns
        };
    });
    if symmetric {
        // CPU formulation (§4.2): each unordered box pair visited once,
        // shared reciprocal serves both directions.
        let mut phr: Vec<f64> = vec![0.0; phi.len()];
        let mut phm: Vec<f64> = vec![0.0; phi.len()];
        parallel::p2p_symmetric_range(0..nl, pyr, con, &tiles, &mut phr, &mut phm);
        for (p_, (r, m)) in phi.iter_mut().zip(phr.iter().zip(&phm)) {
            *p_ += C64::new(*r, *m);
        }
    } else {
        // directed formulation (the GPU layout, §4.3)
        parallel::p2p_directed_range(0..nl, &mut phi, pyr, con, &tiles, &pos, &gam, opts.kernel);
    }
    drop(sp);
    times.0[Phase::P2P as usize] = t.elapsed().as_secs_f64();

    (phi, times, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::direct;
    use crate::util::rng::Pcg64;
    use crate::util::stats::max_rel_error;
    use crate::workload;

    fn run_case(
        n: usize,
        p: usize,
        levels: Option<usize>,
        kernel: Kernel,
        symmetric: bool,
        dist: workload::Distribution,
        seed: u64,
    ) -> (f64, FmmOutput) {
        let mut r = Pcg64::seed_from_u64(seed);
        let (pts, mut gs) = dist.generate(n, &mut r);
        if kernel == Kernel::Log {
            // the log potential is FMM-reproducible for *real* strengths
            // only: a complex Γ couples the branch-dependent arg() into the
            // real part of Γ·log(·)
            for g in gs.iter_mut() {
                g.im = 0.0;
            }
        }
        let opts = FmmOptions {
            cfg: FmmConfig {
                p,
                levels_override: levels,
                ..FmmConfig::default()
            },
            kernel,
            symmetric_p2p: symmetric,
            threads: None,
            ..FmmOptions::default()
        };
        let out = evaluate(&pts, &gs, &opts).unwrap();
        let exact = direct::eval_symmetric(kernel, &pts, &gs);
        // Eq. (5.3): relative max error, on |Φ| for the harmonic kernel
        let (a, e): (Vec<f64>, Vec<f64>) = if kernel == Kernel::Harmonic {
            (
                out.potentials.iter().map(|c| c.abs()).collect(),
                exact.iter().map(|c| c.abs()).collect(),
            )
        } else {
            (
                out.potentials.iter().map(|c| c.re).collect(),
                exact.iter().map(|c| c.re).collect(),
            )
        };
        (max_rel_error(&a, &e, 1e-12), out)
    }

    #[test]
    fn matches_direct_uniform_p17() {
        // p=17 ⇒ TOL ≈ 1e-6 per the paper (§5.1)
        let (err, _) = run_case(
            2000,
            17,
            Some(2),
            Kernel::Harmonic,
            true,
            workload::Distribution::Uniform,
            42,
        );
        assert!(err < 1e-5, "relative error {err:e} too large for p=17");
    }

    #[test]
    fn accuracy_improves_with_p() {
        let mut prev = f64::INFINITY;
        for p in [5, 10, 20] {
            let (err, _) = run_case(
                1500,
                p,
                Some(2),
                Kernel::Harmonic,
                true,
                workload::Distribution::Uniform,
                7,
            );
            assert!(
                err < prev,
                "error did not decrease at p={p}: {err:e} !< {prev:e}"
            );
            prev = err;
        }
        assert!(prev < 1e-6, "p=20 error {prev:e}");
    }

    #[test]
    fn directed_p2p_matches_symmetric() {
        let mut r = Pcg64::seed_from_u64(3);
        let (pts, gs) = workload::uniform_square(1200, &mut r);
        let base = FmmOptions {
            cfg: FmmConfig {
                p: 17,
                levels_override: Some(2),
                ..FmmConfig::default()
            },
            ..Default::default()
        };
        let sym = evaluate(&pts, &gs, &base).unwrap();
        let dir = evaluate(
            &pts,
            &gs,
            &FmmOptions {
                symmetric_p2p: false,
                ..base
            },
        )
        .unwrap();
        for (a, b) in sym.potentials.iter().zip(&dir.potentials) {
            assert!((*a - *b).abs() < 1e-10 * a.abs().max(1.0));
        }
    }

    #[test]
    fn nonuniform_distributions_stay_accurate() {
        for (dist, seed) in [
            (workload::Distribution::Normal { sigma: 0.1 }, 11),
            (workload::Distribution::Layer { sigma: 0.05 }, 12),
        ] {
            let (err, out) = run_case(3000, 17, Some(3), Kernel::Harmonic, true, dist, seed);
            assert!(err < 2e-5, "{}: error {err:e}", dist.name());
            // non-uniform meshes at θ=1/2 and 3+ levels exercise the
            // adaptive shortcuts
            assert!(
                out.counts.p2l_pairs + out.counts.m2p_pairs > 0,
                "{}: expected P2L/M2P shortcuts",
                dist.name()
            );
        }
    }

    #[test]
    fn log_kernel_end_to_end() {
        let (err, _) = run_case(
            1000,
            25,
            Some(2),
            Kernel::Log,
            false,
            workload::Distribution::Uniform,
            13,
        );
        assert!(err < 1e-6, "log kernel error {err:e}");
    }

    #[test]
    fn work_counts_consistent() {
        let mut r = Pcg64::seed_from_u64(5);
        let (pts, gs) = workload::uniform_square(4000, &mut r);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 10,
                levels_override: Some(3),
                ..FmmConfig::default()
            },
            ..Default::default()
        };
        let out = evaluate(&pts, &gs, &opts).unwrap();
        let c = &out.counts;
        assert_eq!(c.n, 4000);
        assert_eq!(c.levels, 3);
        assert_eq!(c.leaf_sizes.iter().map(|&x| x as usize).sum::<usize>(), 4000);
        assert_eq!(c.p2m_particles, 4000);
        // M2M: one shift per non-root box
        assert_eq!(
            c.m2m_per_level.iter().sum::<usize>(),
            4 + 16 + 64
        );
        // L2L: one shift per box below level 1
        assert_eq!(c.l2l_per_level.iter().sum::<usize>(), 16 + 64);
        assert!(c.m2l_per_level.iter().sum::<usize>() > 0);
        assert!(c.p2p_pairs > 0);
        assert!(c.connect_checks > 0);

        // Regression: the symmetric (CPU, §4.2) and directed (GPU layout,
        // §4.3) P2P formulations must report identical work counts — the
        // gpusim cost model reads `p2p_src_per_box` with directed
        // semantics regardless of which CPU path measured the tree.
        let dir = evaluate(
            &pts,
            &gs,
            &FmmOptions {
                symmetric_p2p: false,
                ..opts
            },
        )
        .unwrap();
        assert_eq!(c.p2p_src_per_box, dir.counts.p2p_src_per_box);
        assert_eq!(c.p2p_pairs, dir.counts.p2p_pairs);
        // and both agree with the closed form Σ_b n_b·src_b − N
        let closed: usize = c
            .leaf_sizes
            .iter()
            .zip(&c.p2p_src_per_box)
            .map(|(&n_b, &src)| n_b as usize * src as usize)
            .sum::<usize>()
            - c.n;
        assert_eq!(c.p2p_pairs, closed);
    }

    #[test]
    fn structural_counts_match_measured() {
        let mut r = Pcg64::seed_from_u64(8);
        let (pts, gs) = workload::uniform_square(3000, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 9,
                levels_override: Some(3),
                ..FmmConfig::default()
            },
            ..Default::default()
        };
        let (_, _, measured) = evaluate_on_tree_serial(&pyr, &con, &opts);
        let s = structural_counts(&pyr, &con, 9);
        assert_eq!(s.n, measured.n);
        assert_eq!(s.levels, measured.levels);
        assert_eq!(s.p, measured.p);
        assert_eq!(s.leaf_sizes, measured.leaf_sizes);
        assert_eq!(s.m2l_per_level, measured.m2l_per_level);
        assert_eq!(s.m2m_per_level, measured.m2m_per_level);
        assert_eq!(s.l2l_per_level, measured.l2l_per_level);
        assert_eq!(s.p2p_pairs, measured.p2p_pairs);
        assert_eq!(s.p2p_src_per_box, measured.p2p_src_per_box);
        assert_eq!(s.p2l_pairs, measured.p2l_pairs);
        assert_eq!(s.m2p_pairs, measured.m2p_pairs);
        assert_eq!(s.p2m_particles, measured.p2m_particles);
        assert_eq!(s.connect_checks, measured.connect_checks);
    }

    #[test]
    fn absorb_aggregates_counts() {
        let mut r = Pcg64::seed_from_u64(9);
        let (pa, ga) = workload::uniform_square(1000, &mut r);
        let (pb, gb) = workload::uniform_square(2500, &mut r);
        let pyr_a = Pyramid::build(&pa, &ga, 2).unwrap();
        let con_a = Connectivity::build(&pyr_a, 0.5);
        let pyr_b = Pyramid::build(&pb, &gb, 3).unwrap();
        let con_b = Connectivity::build(&pyr_b, 0.5);
        let a = structural_counts(&pyr_a, &con_a, 8);
        let b = structural_counts(&pyr_b, &con_b, 12);
        let mut agg = WorkCounts::default();
        agg.absorb(&a);
        agg.absorb(&b);
        assert_eq!(agg.n, 3500);
        assert_eq!(agg.levels, 3);
        assert_eq!(agg.p, 12);
        assert_eq!(agg.leaf_sizes.len(), 16 + 64);
        assert_eq!(agg.p2p_pairs, a.p2p_pairs + b.p2p_pairs);
        assert_eq!(agg.p2m_particles, 3500);
        assert_eq!(agg.m2m_per_level.len(), 4);
        assert_eq!(agg.m2m_per_level[1], 4 + 4);
        assert_eq!(agg.m2m_per_level[3], 64);
    }

    #[test]
    fn estimate_exact_invariants() {
        // the distribution-independent parts of the estimate are exact
        let e = WorkCounts::estimate(4000, 3, 10, 0.5);
        assert_eq!(e.n, 4000);
        assert_eq!(e.levels, 3);
        assert_eq!(e.p, 10);
        assert_eq!(e.p2m_particles, 4000);
        assert_eq!(e.leaf_sizes.len(), 64);
        assert_eq!(e.leaf_sizes.iter().map(|&x| x as usize).sum::<usize>(), 4000);
        assert_eq!(e.m2m_per_level, vec![0, 4, 16, 64]);
        assert_eq!(e.l2l_per_level, vec![0, 0, 16, 64]);
        // level 1 has no well-separated pairs at θ = 1/2
        assert_eq!(e.m2l_per_level[1], 0);
        assert!(e.m2l_per_level[2] > 0 && e.m2l_per_level[3] > e.m2l_per_level[2]);
        assert!(e.p2p_pairs > 0 && e.connect_checks > 0);
        assert_eq!(e.p2l_pairs + e.m2p_pairs, 0);
    }

    #[test]
    fn times_are_recorded() {
        let mut r = Pcg64::seed_from_u64(6);
        let (pts, gs) = workload::uniform_square(2000, &mut r);
        let out = evaluate(
            &pts,
            &gs,
            &FmmOptions {
                cfg: FmmConfig {
                    levels_override: Some(2),
                    ..FmmConfig::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(out.times.total() > 0.0);
        assert!(out.times.get(Phase::P2P) > 0.0);
        assert!(out.times.get(Phase::Sort) > 0.0);
    }
}
