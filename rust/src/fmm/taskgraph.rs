//! The task-graph pipelined FMM engine: the pooled barrier engine's
//! phases, re-expressed as a dependency graph and executed without global
//! phase barriers (DESIGN.md §9).
//!
//! The barrier engines ([`super::parallel`]) leave every worker idle at
//! each of the eight phase boundaries even though the dependence structure
//! is much looser: P2P is independent of the *entire* multipole chain, and
//! the per-level M2M/M2L/L2L recursions only couple level to level. Agullo
//! et al. (arXiv:1206.0115) pipeline exactly these phases over a runtime
//! system; this module does the same on the in-tree scheduler
//! ([`crate::util::sched`]): one **node** per phase×level shard group,
//! one **task** per shard, dependency edges
//!
//! ```text
//! P2M ─ M2M(L) ─ M2M(L−1) ─ … ─ M2M(1)
//!  │      └ M2L(L) ─ P2L ┐   └ M2L(l) ┐
//!  │                     ├ L2L(l→l+1) ┤  (write-order edges per L level)
//!  │                     └─────┬──────┘
//!  └───────────┬─ L2P ←────────┘
//!  P2P(acc) ─┐ │
//!            └ merge          (symmetric; directed: P2P ← L2P)
//! ```
//!
//! so P2P overlaps the whole multipole pipeline and level `l` work
//! overlaps level `l±1` work, scheduled on the persistent [`WorkerPool`]
//! via a dependency-gated ready queue (zero thread spawns, one pool epoch
//! per evaluation).
//!
//! **Bitwise parity.** Shard boundaries ([`ranges`]/[`weighted_ranges`] at
//! the same `nt`), per-shard kernels (the shared `*_range` functions of
//! [`super::parallel`]) and every reduction order are *identical* to the
//! pooled engine: accumulation chains into one memory location are either
//! intra-task (M2M into a parent, M2L source order per destination) or
//! ordered by dependency edges (M2L → P2L → L2L per local level, L2P →
//! P2P into `Φ`, symmetric-P2P partials folded in accumulator index order
//! by the merge tasks). With writer-side ownership enforced at runtime by
//! [`RangedBuf`], *any* dependency-respecting schedule therefore produces
//! bitwise-identical output — fuzzed across seeds, worker counts and
//! claim-order jitter by `tests/taskgraph_parity.rs`.
//!
//! Phase times are measured per task and normalized so they sum to the
//! overlapped wall clock (`Σ times = wall`), which keeps the calibration
//! profile ([`crate::dispatch`]) pricing this engine honestly: predicted
//! totals equal predicted wall time, overlap included.

use std::ops::Range;
use std::sync::Mutex;
use std::time::Instant;

use super::parallel::{
    l2l_range, l2p_range, l2p_weights, m2l_range, m2l_weights, m2m_range, p2l_shortcut_range,
    p2m_range, p2p_directed_range, p2p_symmetric_range, p2p_symmetric_weights,
};
use super::{CoeffPyramid, FmmOptions, Phase, PhaseTimes, WorkCounts, N_PHASES, PHASE_NAMES};
use crate::complex::{C64, ZERO};
use crate::connectivity::Connectivity;
use crate::expansion::matrices::M2lOperator;
use crate::expansion::Kernel;
use crate::tree::{boxes_at_level, Pyramid};
use crate::util::pool::{Accum, RangedBuf, WorkerPool, WorkerScratch};
use crate::util::sched::{Graph, Jitter, NodeId};
use crate::util::threadpool::{ranges, weighted_ranges};

/// Wrap a task so its wall-clock is charged to `ph`. The per-phase sums
/// are normalized against the overlapped wall clock after the run.
fn timed<'a>(
    secs: &'a Mutex<[f64; N_PHASES]>,
    ph: Phase,
    f: impl FnOnce(&mut WorkerScratch) + Send + 'a,
) -> impl FnOnce(&mut WorkerScratch) + Send + 'a {
    move |ws| {
        let t = Instant::now();
        let sp = crate::obs::span("task", PHASE_NAMES[ph as usize]);
        f(ws);
        drop(sp);
        let dt = t.elapsed().as_secs_f64();
        if let Ok(mut g) = secs.lock() {
            g[ph as usize] += dt;
        }
    }
}

/// The computational phase on a prebuilt tree, executed as one dependency
/// graph on the persistent worker pool — no phase barriers, zero thread
/// spawns. Results are bitwise-identical to
/// [`super::parallel::evaluate_on_tree_pool`] at the same thread count
/// (see the module docs for the argument; asserted across fuzzed
/// schedules by `tests/taskgraph_parity.rs`).
pub fn evaluate_on_tree_taskgraph(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
    pool: &WorkerPool,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    evaluate_on_tree_taskgraph_seeded(pyr, con, opts, pool, None)
}

/// [`evaluate_on_tree_taskgraph`] with injected schedule noise — the
/// schedule-fuzz hook (`tests/taskgraph_parity.rs`). `None` is the
/// production schedule.
pub fn evaluate_on_tree_taskgraph_seeded(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
    pool: &WorkerPool,
    jitter: Option<Jitter>,
) -> (Vec<C64>, PhaseTimes, WorkCounts) {
    let (phi, times, counts, _) = evaluate_on_tree_taskgraph_stats(pyr, con, opts, pool, jitter);
    (phi, times, counts)
}

/// Aggregate schedule statistics of one task-graph run — what the
/// `pool-bench` overlap column prints.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapStats {
    /// Overlapped wall clock of the whole graph run.
    pub wall_s: f64,
    /// Sum of per-task seconds across every phase (the un-normalized
    /// totals behind [`PhaseTimes`]'s Σ = wall convention).
    pub busy_s: f64,
}

impl OverlapStats {
    /// Mean number of simultaneously busy workers, `busy / wall` — 1.0
    /// is a fully serialized schedule, values toward the worker count
    /// mean the phases genuinely overlapped.
    pub fn ratio(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.busy_s / self.wall_s
        } else {
            0.0
        }
    }
}

/// [`evaluate_on_tree_taskgraph_seeded`], also returning the raw
/// wall/busy split the normalized [`PhaseTimes`] intentionally hides.
pub fn evaluate_on_tree_taskgraph_stats(
    pyr: &Pyramid,
    con: &Connectivity,
    opts: &FmmOptions,
    pool: &WorkerPool,
    jitter: Option<Jitter>,
) -> (Vec<C64>, PhaseTimes, WorkCounts, OverlapStats) {
    let p = opts.cfg.p;
    let stride = p + 1;
    let levels = pyr.levels;
    let nl = pyr.n_leaves();
    let n = pyr.particles.len();
    let nt = opts
        .effective_threads()
        .min(pool.n_workers())
        .clamp(1, nl);
    let kernel = opts.kernel;
    // identical to the serial driver's measured values (same derivation as
    // the barrier engines)
    let counts = super::structural_counts(pyr, con, p);

    // SoA copies of the permuted particles, shared read-only by all tasks
    let pos_v: Vec<C64> = pyr.particles.iter().map(|q| q.pos).collect();
    let gam_v: Vec<C64> = pyr.particles.iter().map(|q| q.gamma).collect();
    let pos: &[C64] = &pos_v;
    let gam: &[C64] = &gam_v;
    // padded SoA leaf tiles (DESIGN.md §10), shared read-only by all tasks
    let tiles_v = crate::tiles::LeafTiles::build(pyr);
    let tiles: &crate::tiles::LeafTiles = &tiles_v;
    let centers_v: Vec<Vec<C64>> = (0..=levels).map(|l| pyr.centers(l)).collect();
    let centers: &[Vec<C64>] = &centers_v;
    let m2l_op = (kernel == Kernel::Harmonic).then(|| M2lOperator::new(p));
    let m2l_op = &m2l_op;

    // Coefficient pyramids and Φ behind runtime-checked range borrows:
    // tasks of concurrent nodes take disjoint write chunks and whole-buffer
    // reads, which the ledger admits (and would reject on any scheduler
    // bug — writer-side ownership stays armed, see `RangedBuf`).
    let mbufs_v: Vec<RangedBuf<C64>> = CoeffPyramid::zeros(levels, p)
        .levels
        .into_iter()
        .map(RangedBuf::new)
        .collect();
    let lbufs_v: Vec<RangedBuf<C64>> = CoeffPyramid::zeros(levels, p)
        .levels
        .into_iter()
        .map(RangedBuf::new)
        .collect();
    let phibuf = RangedBuf::new(vec![ZERO; n]);
    let (mbufs, lbufs): (&[RangedBuf<C64>], &[RangedBuf<C64>]) = (&mbufs_v, &lbufs_v);

    let symmetric = opts.symmetric_p2p && kernel == Kernel::Harmonic;
    let p2p_rs: Vec<Range<usize>> = if symmetric {
        weighted_ranges(&p2p_symmetric_weights(pyr, con, nl), nt)
    } else {
        let w: Vec<u64> = (0..nl)
            .map(|b| counts.leaf_sizes[b] as u64 * counts.p2p_src_per_box[b] as u64)
            .collect();
        weighted_ranges(&w, nt)
    };
    // Symmetric P2P partials go to the pool's leased accumulators, wrapped
    // in range-checked buffers: the trim/size half of `Accum::reset` runs
    // here, the O(workers × N) zero-fill runs inside the tasks (parallel;
    // values identical to the pooled engine's task-side `reset`).
    let (accbufs_v, acc_rest) = if symmetric {
        let mut accs = pool.take_accums();
        // hard invariant, as in the pooled engine: silently folding fewer
        // accumulators than ranges would drop P2P contributions
        assert!(
            accs.len() >= p2p_rs.len(),
            "accumulator lease shorter than the range list ({} < {})",
            accs.len(),
            p2p_rs.len()
        );
        let rest = accs.split_off(p2p_rs.len());
        let bufs: Vec<(RangedBuf<f64>, RangedBuf<f64>)> = accs
            .into_iter()
            .map(|mut a| {
                a.prepare(n);
                (RangedBuf::new(a.re), RangedBuf::new(a.im))
            })
            .collect();
        (bufs, rest)
    } else {
        (Vec::new(), Vec::new())
    };
    let accbufs: &[(RangedBuf<f64>, RangedBuf<f64>)] = &accbufs_v;

    let phase_secs = Mutex::new([0.0f64; N_PHASES]);
    let t_run = Instant::now();
    {
        let secs = &phase_secs;
        let mut g = Graph::new();

        // ---- P2M: leaf multipole expansions --------------------------------
        let p2m = g.node(&[]);
        for r in ranges(nl, nt) {
            g.add_task(
                p2m,
                timed(secs, Phase::P2M, move |_ws| {
                    let mut w = mbufs[levels].write(r.start * stride..r.end * stride);
                    p2m_range(r, &mut w, pyr, &centers[levels], pos, gam, kernel, stride);
                }),
            );
        }

        // ---- M2M: upward chain, one node per level -------------------------
        // `m_prod[l]` is the node that finalizes M[l] (P2M for the finest
        // level — which also covers `levels == 0`, where P2M writes M[0]).
        let mut m_prod: Vec<NodeId> = vec![p2m; levels + 1];
        for l in (1..=levels).rev() {
            let node = g.node(&[m_prod[l]]);
            for r in ranges(boxes_at_level(l - 1), nt) {
                g.add_task(
                    node,
                    timed(secs, Phase::M2M, move |ws| {
                        let src = mbufs[l].read(0..mbufs[l].len());
                        let mut w = mbufs[l - 1].write(r.start * stride..r.end * stride);
                        m2m_range(
                            r,
                            &mut w,
                            &src,
                            &centers[l],
                            &centers[l - 1],
                            stride,
                            &mut ws.shift,
                        );
                    }),
                );
            }
            m_prod[l - 1] = node;
        }

        // ---- M2L: one node per level, gated only on that level's M ---------
        // `l_prods[l]` collects the nodes writing L[l] *in serial program
        // order* — the write-order dependency edges that keep accumulation
        // into each local coefficient in the barrier engines' order
        // (M2L, then P2L at the finest level, then L2L from above).
        let mut l_prods: Vec<Vec<NodeId>> = vec![Vec::new(); levels + 1];
        for l in 1..=levels {
            let node = g.node(&[m_prod[l]]);
            let nb = boxes_at_level(l);
            for r in weighted_ranges(&m2l_weights(con, l, nb), nt) {
                g.add_task(
                    node,
                    timed(secs, Phase::M2L, move |ws| {
                        let src = mbufs[l].read(0..mbufs[l].len());
                        let mut w = lbufs[l].write(r.start * stride..r.end * stride);
                        m2l_range(
                            r,
                            &mut w,
                            con,
                            l,
                            &centers[l],
                            &src,
                            stride,
                            m2l_op.as_ref(),
                            &mut ws.shift,
                            &mut ws.m2l,
                        );
                    }),
                );
            }
            l_prods[l].push(node);
        }

        // ---- P2L shortcuts (finest level; charged to M2L like the barrier
        // engines — they substitute for it) --------------------------------
        {
            let node = g.node(&l_prods[levels]);
            for r in ranges(nl, nt) {
                g.add_task(
                    node,
                    timed(secs, Phase::M2L, move |_ws| {
                        let mut w = lbufs[levels].write(r.start * stride..r.end * stride);
                        p2l_shortcut_range(
                            r,
                            &mut w,
                            pyr,
                            con,
                            &centers[levels],
                            pos,
                            gam,
                            kernel,
                            stride,
                        );
                    }),
                );
            }
            l_prods[levels].push(node);
        }

        // ---- L2L: downward chain; level l → l+1 waits for every earlier
        // producer of both levels (read source + write order) ---------------
        for l in 1..levels {
            let deps: Vec<NodeId> = l_prods[l].iter().chain(&l_prods[l + 1]).copied().collect();
            let node = g.node(&deps);
            for r in ranges(boxes_at_level(l + 1), nt) {
                g.add_task(
                    node,
                    timed(secs, Phase::L2L, move |ws| {
                        let src = lbufs[l].read(0..lbufs[l].len());
                        let mut w = lbufs[l + 1].write(r.start * stride..r.end * stride);
                        l2l_range(
                            r,
                            &mut w,
                            &src,
                            &centers[l],
                            &centers[l + 1],
                            stride,
                            &mut ws.shift,
                        );
                    }),
                );
            }
            l_prods[l + 1].push(node);
        }

        // ---- L2P (+ M2P): needs the finished finest M and L levels — but
        // *not* the upward M2M chain above the finest level ------------------
        let l2p = {
            let mut deps = l_prods[levels].clone();
            deps.push(m_prod[levels]);
            let node = g.node(&deps);
            for r in weighted_ranges(&l2p_weights(pyr, con, nl), nt) {
                g.add_task(
                    node,
                    timed(secs, Phase::L2P, move |_ws| {
                        let mlev = mbufs[levels].read(0..mbufs[levels].len());
                        let llev = lbufs[levels].read(0..lbufs[levels].len());
                        let mut w = phibuf.write(pyr.starts[r.start]..pyr.starts[r.end]);
                        l2p_range(
                            r,
                            &mut w,
                            pyr,
                            con,
                            &centers[levels],
                            &mlev,
                            &llev,
                            pos,
                            stride,
                        );
                    }),
                );
            }
            node
        };

        // ---- P2P: fully concurrent with the whole multipole chain ----------
        if symmetric {
            // accumulation into leased per-task buffers needs nothing at all
            let acc_node = g.node(&[]);
            for (k, r) in p2p_rs.iter().enumerate() {
                let r = r.clone();
                g.add_task(
                    acc_node,
                    timed(secs, Phase::P2P, move |_ws| {
                        let (bre, bim) = &accbufs[k];
                        let mut wre = bre.write(0..n);
                        let mut wim = bim.write(0..n);
                        wre.fill(0.0);
                        wim.fill(0.0);
                        p2p_symmetric_range(r, pyr, con, tiles, &mut wre, &mut wim);
                    }),
                );
            }
            // the merge folds partials into Φ in accumulator index order —
            // the same fixed reduction order as the barrier engines
            let merge = g.node(&[l2p, acc_node]);
            for r in ranges(n, nt) {
                g.add_task(
                    merge,
                    timed(secs, Phase::P2P, move |_ws| {
                        let mut w = phibuf.write(r.clone());
                        for (bre, bim) in accbufs {
                            let are = bre.read(r.clone());
                            let aim = bim.read(r.clone());
                            for k in 0..(r.end - r.start) {
                                w[k] += C64::new(are[k], aim[k]);
                            }
                        }
                    }),
                );
            }
        } else {
            // directed formulation: read-modify-write of the L2P results
            let node = g.node(&[l2p]);
            for r in p2p_rs.iter().cloned() {
                g.add_task(
                    node,
                    timed(secs, Phase::P2P, move |_ws| {
                        let mut chunk = phibuf.write(pyr.starts[r.start]..pyr.starts[r.end]);
                        p2p_directed_range(r, &mut chunk, pyr, con, tiles, pos, gam, kernel);
                    }),
                );
            }
        }

        g.run(pool, nt, jitter);
    }
    let wall = t_run.elapsed().as_secs_f64();

    // Return the leased accumulators (used ones recovered from their range
    // wrappers) so subsequent evaluations reuse the allocations.
    if symmetric {
        let mut accs: Vec<Accum> = accbufs_v
            .into_iter()
            .map(|(re, im)| Accum {
                re: re.into_inner(),
                im: im.into_inner(),
            })
            .collect();
        accs.extend(acc_rest);
        pool.return_accums(accs);
    }

    // Per-phase task seconds, normalized so Σ phases = overlapped wall
    // clock — the calibration-facing convention (see the module docs).
    let secs = match phase_secs.into_inner() {
        Ok(s) => s,
        Err(e) => e.into_inner(),
    };
    let mut total = 0.0;
    for s in &secs {
        total += *s;
    }
    let mut times = PhaseTimes::default();
    if total > 0.0 {
        for i in 0..N_PHASES {
            times.0[i] = secs[i] / total * wall;
        }
    }
    let stats = OverlapStats {
        wall_s: wall,
        busy_s: total,
    };

    (phibuf.into_inner(), times, counts, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FmmConfig;
    use crate::util::rng::Pcg64;
    use crate::workload;

    #[test]
    fn taskgraph_is_bitwise_identical_to_pooled() {
        let mut r = Pcg64::seed_from_u64(41);
        let (pts, gs) = workload::uniform_square(2500, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        for symmetric in [true, false] {
            let opts = FmmOptions {
                cfg: FmmConfig {
                    p: 10,
                    levels_override: Some(3),
                    ..FmmConfig::default()
                },
                symmetric_p2p: symmetric,
                threads: Some(3),
                ..Default::default()
            };
            let pool = WorkerPool::new(3, false);
            let (pooled, _, cp) =
                super::super::parallel::evaluate_on_tree_pool(&pyr, &con, &opts, &pool);
            let (tg, _, ct) = evaluate_on_tree_taskgraph(&pyr, &con, &opts, &pool);
            assert_eq!(pooled.len(), tg.len());
            for (a, b) in pooled.iter().zip(&tg) {
                assert_eq!(a.re, b.re, "symmetric={symmetric}");
                assert_eq!(a.im, b.im, "symmetric={symmetric}");
            }
            assert_eq!(cp.p2p_pairs, ct.p2p_pairs);
            assert_eq!(cp.m2l_per_level, ct.m2l_per_level);
        }
    }

    #[test]
    fn taskgraph_handles_single_level_trees() {
        let mut r = Pcg64::seed_from_u64(43);
        let (pts, gs) = workload::uniform_square(300, &mut r);
        let pyr = Pyramid::build(&pts, &gs, 1).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: 8,
                levels_override: Some(1),
                ..FmmConfig::default()
            },
            threads: Some(2),
            ..Default::default()
        };
        let pool = WorkerPool::new(2, false);
        let (pooled, _, _) =
            super::super::parallel::evaluate_on_tree_pool(&pyr, &con, &opts, &pool);
        let (tg, _, _) = evaluate_on_tree_taskgraph(&pyr, &con, &opts, &pool);
        for (a, b) in pooled.iter().zip(&tg) {
            assert_eq!(a.re, b.re);
            assert_eq!(a.im, b.im);
        }
    }
}
