//! FMM run configuration: expansion order, box population target, θ, and the
//! level-selection rule of the paper (Eq. 5.2).

/// Parameters of one FMM evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FmmConfig {
    /// Number of expansion terms `p` in Eqs. (2.2)–(2.3). The paper uses
    /// p = 17 for TOL ≈ 1e-6.
    pub p: usize,
    /// Desired number of sources per finest-level box, `N_d` (≈45 optimal on
    /// the paper's GPU; ≈35 on its CPU).
    pub n_per_box: usize,
    /// Well-separatedness parameter θ ∈ (0,1); the paper fixes θ = 1/2.
    pub theta: f64,
    /// Optional explicit level count; `None` applies Eq. (5.2).
    pub levels_override: Option<usize>,
}

impl Default for FmmConfig {
    fn default() -> Self {
        Self {
            p: 17,
            n_per_box: 45,
            theta: 0.5,
            levels_override: None,
        }
    }
}

impl FmmConfig {
    pub fn new(p: usize, n_per_box: usize) -> Self {
        Self {
            p,
            n_per_box,
            ..Self::default()
        }
    }

    /// Number of levels from Eq. (5.2):
    /// `N_l = ceil(0.5 * log2(5N / (8 N_d)))`, clamped to ≥ 1 so a tree
    /// always has at least one refinement (4 leaf boxes).
    pub fn levels_for(&self, n: usize) -> usize {
        if let Some(l) = self.levels_override {
            return l.max(1);
        }
        levels_rule(n, self.n_per_box)
    }

    /// Number of finest-level boxes `4^L`.
    pub fn leaf_boxes_for(&self, n: usize) -> usize {
        1usize << (2 * self.levels_for(n))
    }

    /// The paper's p ↔ TOL relation: `p ~ log TOL / log θ` (§2). Returns the
    /// smallest p whose geometric bound `θ^p` is below `tol`.
    pub fn p_for_tolerance(tol: f64, theta: f64) -> usize {
        assert!(tol > 0.0 && tol < 1.0 && theta > 0.0 && theta < 1.0);
        (tol.ln() / theta.ln()).ceil() as usize
    }

    /// Geometric a-priori error estimate `θ^p` for this configuration.
    pub fn tolerance_estimate(&self) -> f64 {
        self.theta.powi(self.p as i32)
    }

    /// Validate field ranges at a service/API boundary. The library itself
    /// tolerates unusual-but-workable configurations (sweeps explore them),
    /// so this is called where untrusted input enters — the serve request
    /// decoder — not from `fmm::evaluate`.
    pub fn validate(&self) -> crate::util::error::Result<()> {
        crate::ensure!(
            (1..=64).contains(&self.p),
            "p must be in 1..=64 (got {})",
            self.p
        );
        crate::ensure!(
            (1..=4096).contains(&self.n_per_box),
            "n_per_box must be in 1..=4096 (got {})",
            self.n_per_box
        );
        crate::ensure!(
            self.theta.is_finite() && self.theta > 0.0 && self.theta < 1.0,
            "theta must lie in (0,1) (got {})",
            self.theta
        );
        if let Some(l) = self.levels_override {
            crate::ensure!(
                (1..=crate::tree::MAX_LEVELS).contains(&l),
                "levels must be in 1..={} (got {l})",
                crate::tree::MAX_LEVELS
            );
        }
        Ok(())
    }
}

/// Eq. (5.2) as a free function.
pub fn levels_rule(n: usize, n_d: usize) -> usize {
    assert!(n_d > 0);
    let arg = 5.0 * n as f64 / (8.0 * n_d as f64);
    if arg <= 1.0 {
        return 1;
    }
    let l = (0.5 * arg.log2()).ceil() as usize;
    l.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_rule_matches_paper_example() {
        // §5.1: with N_d = 45, the rule gives 8 levels for
        // N ∈ (18·2^16, 72·2^16].
        let nd = 45;
        assert_eq!(levels_rule(18 * (1 << 16) + 1, nd), 8);
        assert_eq!(levels_rule(45 * (1 << 16), nd), 8);
        assert_eq!(levels_rule(72 * (1 << 16), nd), 8);
        assert_eq!(levels_rule(72 * (1 << 16) + 1, nd), 9);
        assert_eq!(levels_rule(18 * (1 << 16), nd), 7);
    }

    #[test]
    fn levels_rule_small_inputs() {
        assert_eq!(levels_rule(1, 45), 1);
        assert_eq!(levels_rule(100, 45), 1);
        // 5*1000/(8*45) = 13.9 -> 0.5*log2 = 1.9 -> 2
        assert_eq!(levels_rule(1000, 45), 2);
    }

    #[test]
    fn p_for_tolerance_inverse_of_estimate() {
        let p = FmmConfig::p_for_tolerance(1e-6, 0.5);
        assert_eq!(p, 20); // 0.5^20 ≈ 9.5e-7 ≤ 1e-6 < 0.5^19
        let cfg = FmmConfig { p, ..Default::default() };
        assert!(cfg.tolerance_estimate() <= 1e-6);
        let cfg19 = FmmConfig { p: 19, ..Default::default() };
        assert!(cfg19.tolerance_estimate() > 1e-6);
    }

    #[test]
    fn leaf_boxes_power_of_four() {
        let cfg = FmmConfig::default();
        let n = 45 * (1 << 16);
        assert_eq!(cfg.levels_for(n), 8);
        assert_eq!(cfg.leaf_boxes_for(n), 4usize.pow(8));
    }

    #[test]
    fn validate_accepts_defaults_and_rejects_out_of_range() {
        assert!(FmmConfig::default().validate().is_ok());
        let bad = [
            FmmConfig { p: 0, ..Default::default() },
            FmmConfig { p: 65, ..Default::default() },
            FmmConfig { n_per_box: 0, ..Default::default() },
            FmmConfig { theta: 0.0, ..Default::default() },
            FmmConfig { theta: 1.0, ..Default::default() },
            FmmConfig { theta: f64::NAN, ..Default::default() },
            FmmConfig { levels_override: Some(0), ..Default::default() },
            FmmConfig { levels_override: Some(17), ..Default::default() },
        ];
        for cfg in bad {
            assert!(cfg.validate().is_err(), "{cfg:?}");
        }
    }

    #[test]
    fn override_wins() {
        let cfg = FmmConfig {
            levels_override: Some(3),
            ..Default::default()
        };
        assert_eq!(cfg.levels_for(10_000_000), 3);
    }
}
