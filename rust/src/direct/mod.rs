//! Direct O(N²) summation baselines (paper §5.3, Fig. 5.5/5.6).
//!
//! Two CPU variants, matching §4.2:
//!
//! * [`eval_symmetric`] exploits the antisymmetry of the harmonic kernel —
//!   one complex reciprocal serves the (i,j) and (j,i) contributions,
//!   "almost a factor of two" as the paper says; this is the variant its
//!   CPU comparisons use;
//! * [`eval_plain`] evaluates every ordered pair — the formulation the
//!   GPU code uses (no f64 atomics on the C2075 ⇒ no scatter-adds).
//!
//! [`eval_separate`] covers the `{y_i} ≠ {x_j}` case of Eq. (1.2).
//!
//! All three baselines run on the same blocked SoA micro-kernels as the
//! FMM engines' P2P phase ([`crate::tiles`], DESIGN.md §10): the input is
//! packed once into one padded tile and the inner loops are the shared
//! FMA accumulators, so the O(N²) reference exercises exactly the
//! arithmetic the tree code uses.

use crate::complex::{C64, ZERO};
use crate::expansion::Kernel;
use crate::tiles::{
    accum_harmonic, accum_harmonic_guarded, accum_log, accum_scatter_harmonic, PackedPoints,
};

/// Direct potential at every source point, all ordered pairs (`j ≠ i`).
pub fn eval_plain(kernel: Kernel, points: &[C64], gammas: &[C64]) -> Vec<C64> {
    let n = points.len();
    let t = PackedPoints::pack(points, gammas);
    let mut phi = vec![ZERO; n];
    for i in 0..n {
        let (xi, yi) = (t.xs[i], t.ys[i]);
        // skip slot i by splitting the run; the harmonic upper range may
        // extend over the padding (exact no-ops), the log one must not
        // (`ln` turns the sentinel into NaN — see `accum_log`)
        let (lo, hi) = match kernel {
            Kernel::Harmonic => (
                accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, i, xi, yi),
                accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, i + 1, t.padded(), xi, yi),
            ),
            Kernel::Log => (
                accum_log(&t.xs, &t.ys, &t.gre, &t.gim, 0, i, xi, yi),
                accum_log(&t.xs, &t.ys, &t.gre, &t.gim, i + 1, n, xi, yi),
            ),
        };
        phi[i] = C64::new(lo.0 + hi.0, lo.1 + hi.1);
    }
    phi
}

/// Direct potential at every source point using the pairwise symmetry of
/// the harmonic kernel: `Γ_j/(z_j−z_i)` and `Γ_i/(z_i−z_j)` share one
/// reciprocal ("almost a factor of two", §4.2), via the same scattering
/// micro-kernel as the FMM engines' symmetric P2P.
pub fn eval_symmetric(kernel: Kernel, points: &[C64], gammas: &[C64]) -> Vec<C64> {
    if kernel != Kernel::Harmonic {
        // The log kernel cannot take the symmetric path: only its *real*
        // part is symmetric (ln|z_i−z_j| = ln|z_j−z_i|), while the
        // imaginary part arg(z_i−z_j) = arg(z_j−z_i) ± π flips by a full π
        // across the principal branch cut, so one evaluation cannot serve
        // both directions. Route through the (tiled) plain path instead —
        // bitwise the same ordered-pair sum `eval_plain` computes.
        return eval_plain(kernel, points, gammas);
    }
    let n = points.len();
    let t = PackedPoints::pack(points, gammas);
    let mut phr = vec![0.0f64; n];
    let mut phm = vec![0.0f64; n];
    for i in 0..n {
        let (xi, yi) = (t.xs[i], t.ys[i]);
        let (gri, gii) = (t.gre[i], t.gim[i]);
        // j > i only; the scatter side writes real particles, so the range
        // stops at the true population (scalar tail), never the padding
        let (ar, ai) = accum_scatter_harmonic(
            &t.xs, &t.ys, &t.gre, &t.gim, i + 1, n, xi, yi, gri, gii, 0, &mut phr, &mut phm,
        );
        phr[i] += ar;
        phm[i] += ai;
    }
    phr.iter().zip(&phm).map(|(&r, &m)| C64::new(r, m)).collect()
}

/// Direct potential of `sources` evaluated at separate `targets`
/// (Eq. 1.2 with disjoint evaluation set; no self-exclusion needed as long
/// as no target coincides with a source — coincident pairs are skipped,
/// which the harmonic path does branchlessly in
/// [`accum_harmonic_guarded`]).
pub fn eval_separate(
    kernel: Kernel,
    targets: &[C64],
    sources: &[C64],
    gammas: &[C64],
) -> Vec<C64> {
    if kernel == Kernel::Harmonic {
        let t = PackedPoints::pack(sources, gammas);
        return targets
            .iter()
            .map(|&zt| {
                let (ar, ai) =
                    accum_harmonic_guarded(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.padded(), zt.re, zt.im);
                C64::new(ar, ai)
            })
            .collect();
    }
    targets
        .iter()
        .map(|&zt| {
            let mut acc = ZERO;
            for (&s, &g) in sources.iter().zip(gammas) {
                if s != zt {
                    acc += kernel.eval(zt, s, g);
                }
            }
            acc
        })
        .collect()
}

/// Number of kernel evaluations of the plain direct sum (for the GPU cost
/// model and the Fig. 5.5 work accounting).
pub fn pair_count(n: usize) -> usize {
    n * n.saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    #[test]
    fn symmetric_matches_plain_harmonic() {
        let mut r = Pcg64::seed_from_u64(1);
        let (pts, gs) = workload::uniform_square(200, &mut r);
        let a = eval_plain(Kernel::Harmonic, &pts, &gs);
        let b = eval_symmetric(Kernel::Harmonic, &pts, &gs);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (*x - *y).abs() <= 1e-11 * x.abs().max(1.0),
                "{x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn log_kernel_falls_back() {
        let mut r = Pcg64::seed_from_u64(2);
        let (pts, gs) = workload::uniform_square(50, &mut r);
        let a = eval_plain(Kernel::Log, &pts, &gs);
        let b = eval_symmetric(Kernel::Log, &pts, &gs);
        assert_eq!(a, b);
    }

    #[test]
    fn separate_targets() {
        let mut r = Pcg64::seed_from_u64(3);
        let (src, gs) = workload::uniform_square(100, &mut r);
        let (tgt, _) = workload::uniform_square(37, &mut r);
        let phi = eval_separate(Kernel::Harmonic, &tgt, &src, &gs);
        assert_eq!(phi.len(), 37);
        // spot check one target against a manual sum
        let t = tgt[5];
        let manual: C64 = src
            .iter()
            .zip(&gs)
            .map(|(&s, &g)| g * (s - t).recip())
            .sum();
        assert!((phi[5] - manual).abs() < 1e-12 * manual.abs().max(1.0));
    }

    #[test]
    fn two_body_antisymmetry() {
        let pts = [C64::new(0.25, 0.5), C64::new(0.75, 0.5)];
        let gs = [C64::new(1.0, 0.0), C64::new(1.0, 0.0)];
        let phi = eval_symmetric(Kernel::Harmonic, &pts, &gs);
        // Γ/(z1−z0) = 1/0.5 = 2 at point 0; −2 at point 1
        assert!((phi[0] - C64::new(2.0, 0.0)).abs() < 1e-14);
        assert!((phi[1] - C64::new(-2.0, 0.0)).abs() < 1e-14);
    }

    #[test]
    fn pair_count_formula() {
        assert_eq!(pair_count(0), 0);
        assert_eq!(pair_count(1), 0);
        assert_eq!(pair_count(10), 90);
    }
}
