//! Direct O(N²) summation baselines (paper §5.3, Fig. 5.5/5.6).
//!
//! Two CPU variants, matching §4.2:
//!
//! * [`eval_symmetric`] exploits the antisymmetry of the harmonic kernel —
//!   one complex reciprocal serves the (i,j) and (j,i) contributions,
//!   "almost a factor of two" as the paper says; this is the variant its
//!   CPU comparisons use;
//! * [`eval_plain`] evaluates every ordered pair — the formulation the
//!   GPU code uses (no f64 atomics on the C2075 ⇒ no scatter-adds).
//!
//! [`eval_separate`] covers the `{y_i} ≠ {x_j}` case of Eq. (1.2).

use crate::complex::{C64, ZERO};
use crate::expansion::Kernel;

/// Direct potential at every source point, all ordered pairs (`j ≠ i`).
pub fn eval_plain(kernel: Kernel, points: &[C64], gammas: &[C64]) -> Vec<C64> {
    let n = points.len();
    let mut phi = vec![ZERO; n];
    for i in 0..n {
        let zi = points[i];
        let mut acc = ZERO;
        for j in 0..n {
            if j != i {
                acc += kernel.eval(zi, points[j], gammas[j]);
            }
        }
        phi[i] = acc;
    }
    phi
}

/// Direct potential at every source point using the pairwise symmetry of
/// the harmonic kernel: `Γ_j/(z_j−z_i)` and `Γ_i/(z_i−z_j)` share one
/// reciprocal. Falls back to [`eval_plain`] for the log kernel (whose
/// imaginary part is not antisymmetric across the branch cut).
pub fn eval_symmetric(kernel: Kernel, points: &[C64], gammas: &[C64]) -> Vec<C64> {
    if kernel != Kernel::Harmonic {
        return eval_plain(kernel, points, gammas);
    }
    let n = points.len();
    let mut phi = vec![ZERO; n];
    for i in 0..n {
        let zi = points[i];
        let gi = gammas[i];
        let mut acc = phi[i];
        for j in i + 1..n {
            // r = 1/(z_j − z_i): contribution Γ_j·r at i and −Γ_i·r at j
            let r = (points[j] - zi).recip();
            acc += gammas[j] * r;
            phi[j] -= gi * r;
        }
        phi[i] = acc;
    }
    phi
}

/// Direct potential of `sources` evaluated at separate `targets`
/// (Eq. 1.2 with disjoint evaluation set; no self-exclusion needed as long
/// as no target coincides with a source — coincident pairs are skipped).
pub fn eval_separate(
    kernel: Kernel,
    targets: &[C64],
    sources: &[C64],
    gammas: &[C64],
) -> Vec<C64> {
    targets
        .iter()
        .map(|&t| {
            let mut acc = ZERO;
            for (&s, &g) in sources.iter().zip(gammas) {
                if s != t {
                    acc += kernel.eval(t, s, g);
                }
            }
            acc
        })
        .collect()
}

/// Number of kernel evaluations of the plain direct sum (for the GPU cost
/// model and the Fig. 5.5 work accounting).
pub fn pair_count(n: usize) -> usize {
    n * n.saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::workload;

    #[test]
    fn symmetric_matches_plain_harmonic() {
        let mut r = Pcg64::seed_from_u64(1);
        let (pts, gs) = workload::uniform_square(200, &mut r);
        let a = eval_plain(Kernel::Harmonic, &pts, &gs);
        let b = eval_symmetric(Kernel::Harmonic, &pts, &gs);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (*x - *y).abs() <= 1e-11 * x.abs().max(1.0),
                "{x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn log_kernel_falls_back() {
        let mut r = Pcg64::seed_from_u64(2);
        let (pts, gs) = workload::uniform_square(50, &mut r);
        let a = eval_plain(Kernel::Log, &pts, &gs);
        let b = eval_symmetric(Kernel::Log, &pts, &gs);
        assert_eq!(a, b);
    }

    #[test]
    fn separate_targets() {
        let mut r = Pcg64::seed_from_u64(3);
        let (src, gs) = workload::uniform_square(100, &mut r);
        let (tgt, _) = workload::uniform_square(37, &mut r);
        let phi = eval_separate(Kernel::Harmonic, &tgt, &src, &gs);
        assert_eq!(phi.len(), 37);
        // spot check one target against a manual sum
        let t = tgt[5];
        let manual: C64 = src
            .iter()
            .zip(&gs)
            .map(|(&s, &g)| g * (s - t).recip())
            .sum();
        assert!((phi[5] - manual).abs() < 1e-12 * manual.abs().max(1.0));
    }

    #[test]
    fn two_body_antisymmetry() {
        let pts = [C64::new(0.25, 0.5), C64::new(0.75, 0.5)];
        let gs = [C64::new(1.0, 0.0), C64::new(1.0, 0.0)];
        let phi = eval_symmetric(Kernel::Harmonic, &pts, &gs);
        // Γ/(z1−z0) = 1/0.5 = 2 at point 0; −2 at point 1
        assert!((phi[0] - C64::new(2.0, 0.0)).abs() < 1e-14);
        assert!((phi[1] - C64::new(-2.0, 0.0)).abs() < 1e-14);
    }

    #[test]
    fn pair_count_formula() {
        assert_eq!(pair_count(0), 0);
        assert_eq!(pair_count(1), 0);
        assert_eq!(pair_count(10), 90);
    }
}
