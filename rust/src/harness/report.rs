//! Text rendering of harness results (paper-style rows/series) plus JSON
//! run records for EXPERIMENTS.md provenance.

use crate::util::json::Json;
use std::fmt::Write as _;

/// A column-aligned series table: one x column plus named y series.
pub struct SeriesTable {
    pub title: String,
    pub x_name: String,
    pub series_names: Vec<String>,
    pub rows: Vec<(f64, Vec<f64>)>,
}

impl SeriesTable {
    pub fn new(title: &str, x_name: &str, series: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            x_name: x_name.to_string(),
            series_names: series.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.series_names.len());
        self.rows.push((x, ys));
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {}", self.title);
        let _ = write!(out, "{:>12}", self.x_name);
        for name in &self.series_names {
            let _ = write!(out, " {name:>14}");
        }
        let _ = writeln!(out);
        for (x, ys) in &self.rows {
            let _ = write!(out, "{x:>12.4}");
            for y in ys {
                let _ = write!(out, " {y:>14.6}");
            }
            let _ = writeln!(out);
        }
        out
    }

    /// JSON record (written under `results/`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("title", Json::Str(self.title.clone()))
            .set("x", Json::Str(self.x_name.clone()))
            .set(
                "series",
                Json::Arr(
                    self.series_names
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|(x, ys)| {
                            let mut row = vec![Json::Num(*x)];
                            row.extend(ys.iter().map(|y| Json::Num(*y)));
                            Json::Arr(row)
                        })
                        .collect(),
                ),
            );
        j
    }

    /// Persist the JSON record to `results/<name>.json`; best-effort (the
    /// rendering to stdout is the primary output).
    pub fn save(&self, name: &str) {
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/{name}.json");
        if std::fs::write(&path, self.to_json().to_string()).is_ok() {
            crate::obs::log::info("harness", "saved results", &[("path", path)]);
        }
    }
}

/// Render a percentage-distribution table (Table 5.1 layout).
pub fn render_distribution(title: &str, entries: &[(&str, f64)]) -> String {
    let total: f64 = entries.iter().map(|(_, t)| t).sum();
    let mut out = String::new();
    let _ = writeln!(out, "# {title}");
    let _ = writeln!(out, "{:<10} {:>10} {:>8}", "Part", "time [s]", "share");
    for (name, t) in entries {
        let pct = 100.0 * t / total;
        let pct_s = if pct < 1.0 {
            "< 1 %".to_string()
        } else {
            format!("{pct:.0} %")
        };
        let _ = writeln!(out, "{name:<10} {t:>10.4} {pct_s:>8}");
    }
    let _ = writeln!(out, "{:<10} {total:>10.4} {:>8}", "total", "100 %");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_table_renders_and_serializes() {
        let mut t = SeriesTable::new("Fig X", "N", &["cpu", "gpu"]);
        t.push(100.0, vec![1.0, 0.1]);
        t.push(200.0, vec![2.0, 0.15]);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("cpu"));
        assert!(s.lines().count() >= 4);
        let j = t.to_json().to_string();
        assert!(j.contains("\"rows\""));
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("series").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn distribution_table() {
        let s = render_distribution(
            "Table 5.1",
            &[("P2P", 0.43), ("Sort", 0.30), ("L2L", 0.004)],
        );
        assert!(s.contains("P2P"));
        assert!(s.contains("< 1 %"));
        assert!(s.contains("total"));
    }
}
