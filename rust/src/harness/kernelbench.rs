//! Per-kernel roofline bench: `fmm2d kernel-bench`.
//!
//! Measures the attained throughput of each micro-kernel (the tiled P2P
//! accumulators and the blocked M2L panel, DESIGN.md §10) and reports it
//! against a **measured** roofline (Williams et al.): the compute roof is
//! the FMA throughput of this machine as timed on independent `mul_add`
//! chains, the memory roof is a streaming read sum, and every kernel's
//! attainable ceiling is `min(compute, intensity × bandwidth)` at its
//! nominal arithmetic intensity.
//!
//! Flop counts are *nominal*: an FMA is 2 flops, a divide (and, for the
//! log kernel, `ln`/`atan2`) is counted as 1 — so the attained GFLOP/s of
//! divide/libm-heavy kernels *understates* their hardware utilization.
//! Byte counts assume the tile streams from memory once per pass (4 f64
//! lanes per source slot; the scatter kernel adds a read-modify-write
//! pair), which is the DRAM-resident worst case — the working sets here
//! are cache-resident, so the memory roof is a lower bound on what the
//! kernels actually see. Both conventions are fixed and documented so the
//! numbers are comparable across commits, which is what the bench is for.

use std::hint::black_box;
use std::time::Instant;

use crate::complex::C64;
use crate::expansion::matrices::{M2lOperator, M2lScratch};
use crate::tiles::{accum_harmonic, accum_log, accum_scatter_harmonic, PackedPoints};
use crate::util::rng::Pcg64;

/// Nominal flops per source slot of [`accum_harmonic`]: 2 subs, 1 mul +
/// 1 FMA (=2) for `d²`, 1 divide, 2 muls for `r`, 4 FMAs (=8) for the
/// split accumulators.
pub const FLOPS_P2P_GATHER: f64 = 16.0;
/// [`accum_scatter_harmonic`]: the gather body plus 4 scatter FMAs.
pub const FLOPS_P2P_SCATTER: f64 = 24.0;
/// [`accum_log`]: 2 subs, 3 for `d²`, 1 mul, `ln` + `atan2` counted as 1
/// each, 4 FMAs (=8).
pub const FLOPS_P2P_LOG: f64 = 16.0;

/// Nominal flops of one blocked M2L translation at order `p`
/// ([`M2lOperator::apply_panel`]): pre-scale `12p` (two complex multiplies
/// per coefficient), panel core `4p(p+1)` (two FMAs per matrix entry),
/// post-scale + reduction `14(p+1)` per row (one complex multiply-add and
/// one complex multiply).
pub fn flops_m2l(p: usize) -> f64 {
    let pf = p as f64;
    12.0 * pf + 4.0 * pf * (pf + 1.0) + 14.0 * (pf + 1.0)
}

/// Options of one `kernel-bench` invocation.
#[derive(Clone, Debug)]
pub struct KernelBenchOpts {
    /// Shrink every measurement to CI-smoke size (sub-second total).
    pub quick: bool,
    pub seed: u64,
}

impl Default for KernelBenchOpts {
    fn default() -> Self {
        Self {
            quick: false,
            seed: 1,
        }
    }
}

/// One measured kernel.
#[derive(Clone, Debug)]
pub struct RooflineRow {
    pub name: String,
    /// Total nominal flops executed during the timed region.
    pub flops: f64,
    /// Total nominal bytes streamed (the DRAM-worst-case convention).
    pub bytes: f64,
    pub secs: f64,
}

impl RooflineRow {
    pub fn gflops(&self) -> f64 {
        self.flops / self.secs.max(1e-12) / 1e9
    }

    /// Nominal arithmetic intensity, flops per byte.
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes.max(1.0)
    }
}

/// The full report: two measured machine roofs plus per-kernel rows.
#[derive(Clone, Debug)]
pub struct KernelReport {
    pub quick: bool,
    pub seed: u64,
    /// Compute roof: measured FMA-chain throughput, GFLOP/s.
    pub peak_gflops: f64,
    /// Memory roof: measured streaming-read bandwidth, GB/s.
    pub bw_gbs: f64,
    pub rows: Vec<RooflineRow>,
}

impl KernelReport {
    /// The roofline ceiling of `row`: `min(peak, intensity × bandwidth)`.
    pub fn roof_gflops(&self, row: &RooflineRow) -> f64 {
        self.peak_gflops.min(row.intensity() * self.bw_gbs)
    }

    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# kernel-bench (seed {}{})",
            self.seed,
            if self.quick { ", --quick" } else { "" }
        );
        let _ = writeln!(
            out,
            "machine roofs: compute {:.2} GFLOP/s (FMA chains), memory {:.2} GB/s (stream sum)",
            self.peak_gflops, self.bw_gbs
        );
        let _ = writeln!(
            out,
            "{:<14} {:>10} {:>12} {:>10} {:>8}",
            "kernel", "GFLOP/s", "AI [fl/B]", "roof", "%roof"
        );
        for r in &self.rows {
            let roof = self.roof_gflops(r);
            let _ = writeln!(
                out,
                "{:<14} {:>10.2} {:>12.2} {:>10.2} {:>7.1}%",
                r.name,
                r.gflops(),
                r.intensity(),
                roof,
                100.0 * r.gflops() / roof.max(1e-12)
            );
        }
        out
    }
}

/// Problem sizes of one run; tests use a miniature instance.
#[derive(Clone, Copy, Debug)]
pub struct Sizes {
    /// FMA-chain iterations of the compute-roof measurement.
    pub peak_iters: u64,
    /// f64 elements (per pass) of the bandwidth measurement.
    pub bw_len: usize,
    pub bw_passes: usize,
    /// Source count of the P2P sweeps.
    pub p2p_src: usize,
    /// Target count of the gather/log sweeps.
    pub p2p_tgt: usize,
    pub p2p_passes: usize,
    /// Expansion order and weak-list length of the M2L panel.
    pub m2l_p: usize,
    pub m2l_srcs: usize,
    pub m2l_passes: usize,
}

impl Sizes {
    pub fn for_opts(quick: bool) -> Self {
        if quick {
            Self {
                peak_iters: 4_000_000,
                bw_len: 2 << 20, // 16 MB
                bw_passes: 3,
                p2p_src: 1024,
                p2p_tgt: 128,
                p2p_passes: 2,
                m2l_p: 17,
                m2l_srcs: 27,
                m2l_passes: 2_000,
            }
        } else {
            Self {
                peak_iters: 40_000_000,
                bw_len: 8 << 20, // 64 MB
                bw_passes: 6,
                p2p_src: 4096,
                p2p_tgt: 512,
                p2p_passes: 10,
                m2l_p: 17,
                m2l_srcs: 27,
                m2l_passes: 50_000,
            }
        }
    }
}

/// Compute roof: 8 independent FMA dependency chains (enough to cover the
/// FMA latency×throughput product of current cores), nominal 2 flops each.
fn measure_peak_gflops(iters: u64) -> f64 {
    let a = black_box(1.000000001f64);
    let b = black_box(1e-9f64);
    let mut acc = [1.0f64, 1.25, 1.5, 1.75, 2.0, 2.25, 2.5, 2.75];
    let t = Instant::now();
    for _ in 0..iters {
        for x in acc.iter_mut() {
            *x = a.mul_add(*x, b);
        }
    }
    let secs = t.elapsed().as_secs_f64();
    black_box(acc);
    2.0 * 8.0 * iters as f64 / secs.max(1e-12) / 1e9
}

/// Memory roof: streaming read sum with 4 split accumulators.
fn measure_bandwidth_gbs(len: usize, passes: usize) -> f64 {
    let v: Vec<f64> = (0..len).map(|i| (i % 17) as f64 * 0.25).collect();
    let mut acc = [0.0f64; 4];
    let t = Instant::now();
    for _ in 0..passes {
        let mut i = 0;
        while i + 4 <= v.len() {
            acc[0] += v[i];
            acc[1] += v[i + 1];
            acc[2] += v[i + 2];
            acc[3] += v[i + 3];
            i += 4;
        }
        black_box(&acc);
    }
    let secs = t.elapsed().as_secs_f64();
    (len * passes * 8) as f64 / secs.max(1e-12) / 1e9
}

fn random_points(r: &mut Pcg64, n: usize) -> (Vec<C64>, Vec<C64>) {
    let pts = (0..n)
        .map(|_| C64::new(r.uniform_in(0.0, 1.0), r.uniform_in(0.0, 1.0)))
        .collect();
    let gs = (0..n)
        .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
        .collect();
    (pts, gs)
}

/// Run the bench at explicit sizes (the CLI passes [`Sizes::for_opts`]).
pub fn run_sized(opts: &KernelBenchOpts, s: &Sizes) -> KernelReport {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let peak_gflops = measure_peak_gflops(s.peak_iters);
    let bw_gbs = measure_bandwidth_gbs(s.bw_len, s.bw_passes);
    let mut rows = Vec::new();

    let (pts, gs) = random_points(&mut rng, s.p2p_src);
    let tile = PackedPoints::pack(&pts, &gs);
    let (tpts, _) = random_points(&mut rng, s.p2p_tgt);

    // p2p-gather: destination-side accumulation over the full padded tile
    {
        let mut sink = (0.0, 0.0);
        let t = Instant::now();
        for _ in 0..s.p2p_passes {
            for zt in &tpts {
                let (ar, ai) = accum_harmonic(
                    &tile.xs,
                    &tile.ys,
                    &tile.gre,
                    &tile.gim,
                    0,
                    tile.padded(),
                    zt.re,
                    zt.im,
                );
                sink.0 += ar;
                sink.1 += ai;
            }
        }
        let secs = t.elapsed().as_secs_f64();
        black_box(sink);
        let pairs = (s.p2p_passes * s.p2p_tgt * tile.padded()) as f64;
        rows.push(RooflineRow {
            name: "p2p-gather".into(),
            flops: FLOPS_P2P_GATHER * pairs,
            bytes: 32.0 * pairs,
            secs,
        });
    }

    // p2p-scatter: the symmetric formulation over all unordered pairs
    {
        let n = tile.n;
        let mut phr = vec![0.0f64; n];
        let mut phm = vec![0.0f64; n];
        let t = Instant::now();
        for _ in 0..s.p2p_passes {
            for i in 0..n {
                let (ar, ai) = accum_scatter_harmonic(
                    &tile.xs,
                    &tile.ys,
                    &tile.gre,
                    &tile.gim,
                    i + 1,
                    n,
                    tile.xs[i],
                    tile.ys[i],
                    tile.gre[i],
                    tile.gim[i],
                    0,
                    &mut phr,
                    &mut phm,
                );
                phr[i] += ar;
                phm[i] += ai;
            }
        }
        let secs = t.elapsed().as_secs_f64();
        black_box(&phr);
        let pairs = (s.p2p_passes * n * (n - 1) / 2) as f64;
        rows.push(RooflineRow {
            name: "p2p-scatter".into(),
            flops: FLOPS_P2P_SCATTER * pairs,
            bytes: 64.0 * pairs,
            secs,
        });
    }

    // p2p-log: bounded to the true population (padding is unsafe under ln)
    {
        let mut sink = (0.0, 0.0);
        let t = Instant::now();
        for _ in 0..s.p2p_passes {
            for zt in &tpts {
                let (ar, ai) = accum_log(
                    &tile.xs, &tile.ys, &tile.gre, &tile.gim, 0, tile.n, zt.re, zt.im,
                );
                sink.0 += ar;
                sink.1 += ai;
            }
        }
        let secs = t.elapsed().as_secs_f64();
        black_box(sink);
        let pairs = (s.p2p_passes * s.p2p_tgt * tile.n) as f64;
        rows.push(RooflineRow {
            name: "p2p-log".into(),
            flops: FLOPS_P2P_LOG * pairs,
            bytes: 32.0 * pairs,
            secs,
        });
    }

    // m2l-panel: one destination's weak list, the blocked panel kernel
    {
        let p = s.m2l_p;
        let stride = p + 1;
        let op = M2lOperator::new(p);
        let nboxes = s.m2l_srcs;
        let mut mults = vec![crate::complex::ZERO; nboxes * stride];
        let mut centers = vec![crate::complex::ZERO; nboxes];
        for b in 0..nboxes {
            for k in 1..=p {
                mults[b * stride + k] =
                    C64::new(rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0));
            }
            // well-separated source centers (θ-criterion distances)
            centers[b] = C64::new(rng.uniform_in(2.0, 4.0), rng.uniform_in(2.0, 4.0));
        }
        let srcs: Vec<u32> = (0..nboxes as u32).collect();
        let z_o = C64::new(0.0, 0.0);
        let mut local = vec![crate::complex::ZERO; stride];
        let mut scratch = M2lScratch::default();
        let t = Instant::now();
        for _ in 0..s.m2l_passes {
            op.apply_panel(&mults, stride, &srcs, &centers, &mut local, z_o, &mut scratch);
        }
        let secs = t.elapsed().as_secs_f64();
        black_box(&local);
        let translations = (s.m2l_passes * nboxes) as f64;
        rows.push(RooflineRow {
            name: "m2l-panel".into(),
            flops: flops_m2l(p) * translations,
            // nominal traffic: the source's coefficients in; T and the
            // panel state are cache-resident by construction
            bytes: 16.0 * (p as f64 + 1.0) * translations,
            secs,
        });
    }

    KernelReport {
        quick: opts.quick,
        seed: opts.seed,
        peak_gflops,
        bw_gbs,
        rows,
    }
}

/// Run the bench at the sizes implied by `opts`.
pub fn run(opts: &KernelBenchOpts) -> KernelReport {
    run_sized(opts, &Sizes::for_opts(opts.quick))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Miniature sizes so the test finishes in milliseconds.
    fn tiny() -> Sizes {
        Sizes {
            peak_iters: 10_000,
            bw_len: 1 << 14,
            bw_passes: 2,
            p2p_src: 64,
            p2p_tgt: 8,
            p2p_passes: 1,
            m2l_p: 5,
            m2l_srcs: 4,
            m2l_passes: 10,
        }
    }

    #[test]
    fn report_measures_every_kernel() {
        let opts = KernelBenchOpts {
            quick: true,
            seed: 7,
        };
        let r = run_sized(&opts, &tiny());
        assert!(r.peak_gflops > 0.0 && r.peak_gflops.is_finite());
        assert!(r.bw_gbs > 0.0 && r.bw_gbs.is_finite());
        let names: Vec<&str> = r.rows.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, ["p2p-gather", "p2p-scatter", "p2p-log", "m2l-panel"]);
        for row in &r.rows {
            assert!(row.flops > 0.0 && row.bytes > 0.0 && row.secs >= 0.0);
            assert!(row.gflops().is_finite() && row.intensity() > 0.0);
            assert!(r.roof_gflops(row) > 0.0);
        }
        let text = r.render();
        assert!(text.contains("p2p-gather") && text.contains("m2l-panel"));
        assert!(text.contains("machine roofs"));
    }

    #[test]
    fn m2l_flop_model_is_quadratic() {
        // sanity of the documented closed form
        assert_eq!(flops_m2l(1), 12.0 + 8.0 + 28.0);
        assert!(flops_m2l(17) > flops_m2l(8));
    }
}
