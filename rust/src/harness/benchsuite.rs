//! The repo's strict performance baseline: `fmm2d bench-suite`.
//!
//! Runs a **fixed matrix** of end-to-end evaluations (sizes ×
//! distributions × engines), takes the median of `reps` timed runs after a
//! warmup, and writes a versioned `BENCH_<date>.json` record under
//! `results/`. When a previous record exists (or `--baseline` names one),
//! the suite prints per-case ratios against it — so a perf PR carries
//! before/after evidence from one command, and a regression shows up as a
//! ratio, not an anecdote.
//!
//! The record format follows the calibration profile's persistence rules
//! (`dispatch/profile.rs`): versioned, strict parsing — unknown fields and
//! version mismatches are errors, never silently ignored — so stale
//! baselines fail loudly instead of producing nonsense ratios.

use std::path::{Path, PathBuf};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::config::FmmConfig;
use crate::fmm::{self, CpuEngine, FmmOptions};
use crate::harness::runner::workload_for;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::workload::Distribution;

/// Format version of the `BENCH_<date>.json` record.
pub const BENCH_VERSION: usize = 1;

/// Options of one bench-suite invocation.
#[derive(Clone, Debug)]
pub struct BenchSuiteOpts {
    /// Add the paper-scale size to the matrix.
    pub full: bool,
    pub seed: u64,
    /// Timed repetitions per case (the median is recorded).
    pub reps: usize,
    /// Worker cap of the parallel engine (`None` = all cores).
    pub threads: Option<usize>,
    pub pin: bool,
}

impl Default for BenchSuiteOpts {
    fn default() -> Self {
        Self {
            full: false,
            seed: 1,
            reps: 5,
            threads: None,
            pin: false,
        }
    }
}

/// One measured cell of the matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchCase {
    pub engine: String,
    pub dist: String,
    pub n: usize,
    /// Median wall-clock of the timed repetitions (seconds).
    pub median_s: f64,
    pub points_per_s: f64,
}

/// A full bench-suite record (what `BENCH_<date>.json` holds).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub version: usize,
    /// `YYYYMMDD`, also embedded in the default file name.
    pub date: String,
    pub seed: u64,
    pub reps: usize,
    /// Resolved parallel-engine worker count.
    pub threads: usize,
    pub cases: Vec<BenchCase>,
}

/// The fixed size axis: small enough that the default suite finishes in
/// minutes, wide enough that serial/parallel separate clearly.
fn sizes(full: bool) -> Vec<usize> {
    let mut s = vec![2_000, 8_000, 32_000];
    if full {
        s.push(100_000);
    }
    s
}

fn dists() -> [Distribution; 3] {
    [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.1 },
        Distribution::Layer { sigma: 0.1 },
    ]
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).expect("bench times are finite"));
    xs[xs.len() / 2]
}

/// Run the fixed matrix and assemble the record.
pub fn run(opts: &BenchSuiteOpts) -> Result<BenchRecord> {
    let mut pairs = Vec::new();
    for d in dists() {
        for n in sizes(opts.full) {
            pairs.push((d, n));
        }
    }
    run_matrix(opts, &pairs)
}

/// The measurement loop over an explicit `(distribution, n)` list (the
/// public [`run`] passes the fixed matrix; tests pass a tiny one).
pub fn run_matrix(opts: &BenchSuiteOpts, matrix: &[(Distribution, usize)]) -> Result<BenchRecord> {
    let reps = opts.reps.max(1);
    let engines: [(&str, Option<usize>, CpuEngine); 3] = [
        ("serial", Some(1), CpuEngine::Barrier),
        ("parallel", opts.threads, CpuEngine::Barrier),
        ("taskgraph", opts.threads, CpuEngine::TaskGraph),
    ];
    let threads = FmmOptions {
        threads: opts.threads,
        ..FmmOptions::default()
    }
    .effective_threads();
    let mut cases = Vec::new();
    for &(dist, n) in matrix {
        let (pts, gs) = workload_for(dist, n, opts.seed);
        for (name, engine_threads, cpu_engine) in engines {
            let fopts = FmmOptions {
                cfg: FmmConfig::default(),
                threads: engine_threads,
                pin: opts.pin,
                cpu_engine,
                ..FmmOptions::default()
            };
            // warmup: first contact pays pool spawn-up and page faults
            let _ = fmm::evaluate(&pts, &gs, &fopts)?;
            let mut times = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t = Instant::now();
                let _ = fmm::evaluate(&pts, &gs, &fopts)?;
                times.push(t.elapsed().as_secs_f64());
            }
            let median_s = median(&mut times);
            cases.push(BenchCase {
                engine: name.to_string(),
                dist: dist.name().to_string(),
                n,
                median_s,
                points_per_s: n as f64 / median_s.max(1e-12),
            });
        }
    }
    Ok(BenchRecord {
        version: BENCH_VERSION,
        date: date_string(),
        seed: opts.seed,
        reps,
        threads,
        cases,
    })
}

// ---- calendar ----------------------------------------------------------
// std has no date formatting; the civil-from-days conversion is the
// standard Gregorian algorithm (exact for the whole proleptic calendar).

fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Today as `YYYYMMDD` (UTC).
pub fn date_string() -> String {
    let secs = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    format!("{y:04}{m:02}{d:02}")
}

// ---- persistence -------------------------------------------------------

impl BenchRecord {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("version", Json::Num(self.version as f64))
            .set("date", Json::Str(self.date.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("reps", Json::Num(self.reps as f64))
            .set("threads", Json::Num(self.threads as f64))
            .set(
                "cases",
                Json::Arr(
                    self.cases
                        .iter()
                        .map(|c| {
                            let mut o = Json::obj();
                            o.set("engine", Json::Str(c.engine.clone()))
                                .set("dist", Json::Str(c.dist.clone()))
                                .set("n", Json::Num(c.n as f64))
                                .set("median_s", Json::Num(c.median_s))
                                .set("points_per_s", Json::Num(c.points_per_s));
                            o
                        })
                        .collect(),
                ),
            );
        j
    }

    pub fn parse(s: &str) -> Result<BenchRecord> {
        let v = Json::parse(s).context("parsing bench record")?;
        check_fields(
            &v,
            &["version", "date", "seed", "reps", "threads", "cases"],
            "bench record",
        )?;
        let version = v.req_usize("version")?;
        if version != BENCH_VERSION {
            crate::bail!(
                "bench record version {version} does not match the supported \
                 version {BENCH_VERSION}; re-run `fmm2d bench-suite`"
            );
        }
        let arr = v
            .get("cases")
            .and_then(Json::as_arr)
            .context("missing 'cases' array")?;
        let mut cases = Vec::with_capacity(arr.len());
        for (i, c) in arr.iter().enumerate() {
            let what = format!("cases[{i}]");
            check_fields(
                c,
                &["engine", "dist", "n", "median_s", "points_per_s"],
                &what,
            )?;
            cases.push(BenchCase {
                engine: c.req_str("engine")?.to_string(),
                dist: c.req_str("dist")?.to_string(),
                n: c.req_usize("n")?,
                median_s: req_f64(c, "median_s", &what)?,
                points_per_s: req_f64(c, "points_per_s", &what)?,
            });
        }
        Ok(BenchRecord {
            version,
            date: v.req_str("date")?.to_string(),
            seed: v.req_usize("seed")? as u64,
            reps: v.req_usize("reps")?,
            threads: v.req_usize("threads")?,
            cases,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<BenchRecord> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&s)
    }

    /// The default output path of this record: `<dir>/BENCH_<date>.json`.
    pub fn default_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("BENCH_{}.json", self.date))
    }

    /// Human-readable measurement table.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# bench-suite {} (seed {}, median of {}, parallel workers {})",
            self.date, self.seed, self.reps, self.threads
        );
        let _ = writeln!(
            out,
            "{:<10} {:<8} {:>8} {:>12} {:>14}",
            "engine", "dist", "N", "median [s]", "points/s"
        );
        for c in &self.cases {
            let _ = writeln!(
                out,
                "{:<10} {:<8} {:>8} {:>12.6} {:>14.3e}",
                c.engine, c.dist, c.n, c.median_s, c.points_per_s
            );
        }
        out
    }
}

fn req_f64(v: &Json, key: &str, what: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| crate::anyhow!("{what}: missing/invalid number field '{key}'"))
}

/// Reject JSON objects carrying fields this version does not understand
/// (same policy as the calibration profile).
fn check_fields(v: &Json, known: &[&str], what: &str) -> Result<()> {
    match v {
        Json::Obj(m) => {
            for k in m.keys() {
                if !known.contains(&k.as_str()) {
                    crate::bail!(
                        "unknown field '{k}' in {what}; this build understands {}",
                        known.join(", ")
                    );
                }
            }
            Ok(())
        }
        _ => crate::bail!("{what}: expected a JSON object"),
    }
}

// ---- baseline comparison -----------------------------------------------

/// The newest `BENCH_*.json` in `dir` whose name sorts strictly before
/// `BENCH_<date>.json` (dates are `YYYYMMDD`, so lexicographic order is
/// chronological). `None` when no earlier record exists.
pub fn find_baseline(dir: &Path, date: &str) -> Option<PathBuf> {
    let current = format!("BENCH_{date}.json");
    let mut best: Option<String> = None;
    for entry in std::fs::read_dir(dir).ok()?.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let earlier = name.starts_with("BENCH_") && name.ends_with(".json") && name < current;
        if earlier && best.as_deref().map(|b| name.as_str() > b).unwrap_or(true) {
            best = Some(name);
        }
    }
    best.map(|n| dir.join(n))
}

/// Per-case ratio table of `current` against `baseline` (ratio > 1 means
/// the current run is slower). Returns the rendered report and the worst
/// ratio over matched cases (1.0 when nothing matched).
pub fn compare(current: &BenchRecord, baseline: &BenchRecord) -> (String, f64) {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# vs baseline {} (seed {}, parallel workers {})",
        baseline.date, baseline.seed, baseline.threads
    );
    let _ = writeln!(
        out,
        "{:<10} {:<8} {:>8} {:>12} {:>12} {:>8}",
        "engine", "dist", "N", "base [s]", "now [s]", "ratio"
    );
    let mut worst = 1.0f64;
    let mut matched = 0usize;
    for c in &current.cases {
        let Some(b) = baseline
            .cases
            .iter()
            .find(|b| b.engine == c.engine && b.dist == c.dist && b.n == c.n)
        else {
            continue;
        };
        matched += 1;
        let ratio = c.median_s / b.median_s.max(1e-12);
        worst = worst.max(ratio);
        let _ = writeln!(
            out,
            "{:<10} {:<8} {:>8} {:>12.6} {:>12.6} {:>8.3}",
            c.engine, c.dist, c.n, b.median_s, c.median_s, ratio
        );
    }
    let _ = writeln!(
        out,
        "matched {matched}/{} cases; worst ratio {worst:.3}",
        current.cases.len()
    );
    (out, worst)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(date: &str, median_s: f64) -> BenchRecord {
        BenchRecord {
            version: BENCH_VERSION,
            date: date.to_string(),
            seed: 1,
            reps: 3,
            threads: 4,
            cases: vec![BenchCase {
                engine: "parallel".into(),
                dist: "uniform".into(),
                n: 2000,
                median_s,
                points_per_s: 2000.0 / median_s,
            }],
        }
    }

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(18_993), (2022, 1, 1));
        assert_eq!(civil_from_days(11_016), (2000, 2, 29)); // leap day
        assert_eq!(civil_from_days(-1), (1969, 12, 31)); // pre-epoch
        let today = date_string();
        assert_eq!(today.len(), 8);
        assert!(today.as_str() >= "20260101", "clock sanity: {today}");
    }

    #[test]
    fn median_is_order_insensitive() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [5.0]), 5.0);
        assert_eq!(median(&mut [4.0, 1.0, 3.0, 2.0]), 3.0);
    }

    #[test]
    fn record_round_trips_and_parses_strictly() {
        let r = record("20260807", 0.25);
        let parsed = BenchRecord::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed, r);

        // version mismatch is an error, not a guess
        let bumped = r.to_json().to_string().replace("\"version\":1", "\"version\":9");
        assert!(BenchRecord::parse(&bumped).unwrap_err().to_string().contains("version"));

        // unknown fields are rejected (strict schema)
        let extra = r
            .to_json()
            .to_string()
            .replace("\"seed\":1", "\"seed\":1,\"frobnicate\":2");
        assert!(BenchRecord::parse(&extra)
            .unwrap_err()
            .to_string()
            .contains("frobnicate"));
    }

    #[test]
    fn comparison_ratios_and_baseline_discovery() {
        let base = record("20260801", 0.2);
        let now = record("20260807", 0.3);
        let (report, worst) = compare(&now, &base);
        assert!((worst - 1.5).abs() < 1e-9, "worst={worst}");
        assert!(report.contains("1.500"), "{report}");

        let dir = std::env::temp_dir().join(format!("fmm2d_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        base.save(&base.default_path(&dir)).unwrap();
        now.save(&now.default_path(&dir)).unwrap();
        // the newest record older than "today" is the baseline; the current
        // day's own record is never its own baseline
        let found = find_baseline(&dir, "20260807").unwrap();
        assert!(found.ends_with("BENCH_20260801.json"), "{found:?}");
        assert!(find_baseline(&dir, "20260801").is_none());
        let loaded = BenchRecord::load(&found).unwrap();
        assert_eq!(loaded, base);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tiny_matrix_measures_every_engine() {
        let opts = BenchSuiteOpts {
            reps: 2,
            threads: Some(2),
            ..BenchSuiteOpts::default()
        };
        let r = run_matrix(&opts, &[(Distribution::Uniform, 300)]).unwrap();
        assert_eq!(r.cases.len(), 3); // serial + parallel + taskgraph
        let lanes: Vec<&str> = r.cases.iter().map(|c| c.engine.as_str()).collect();
        assert_eq!(lanes, ["serial", "parallel", "taskgraph"]);
        for c in &r.cases {
            assert!(c.median_s > 0.0 && c.points_per_s > 0.0);
            assert_eq!(c.n, 300);
        }
        assert_eq!(r.reps, 2);
        assert!(r.version == BENCH_VERSION && r.date.len() == 8);
    }
}
