//! Shared measurement machinery of the harness.

use std::time::Instant;

use crate::complex::C64;
use crate::config::FmmConfig;
use crate::expansion::Kernel;
use crate::fmm::{self, FmmOptions, Phase, PhaseTimes, WorkCounts};
use crate::gpusim::model::GpuSim;
use crate::topology;
use crate::tree::{PartitionEngine, Pyramid};
use crate::util::rng::Pcg64;
use crate::workload::Distribution;

/// One measured CPU run paired with the simulated GPU prediction for the
/// identical tree and work.
#[derive(Clone, Debug)]
pub struct RunPair {
    pub n: usize,
    pub levels: usize,
    /// Measured serial CPU phase times (symmetric P2P, one-sided lists).
    pub cpu: PhaseTimes,
    /// Simulated GPU phase times (directed lists, Algorithms 3.1–3.7).
    pub gpu: PhaseTimes,
    /// Simulated host↔device transfer time ("Other" of Table 5.1).
    pub gpu_transfer: f64,
    pub counts: WorkCounts,
    /// Potentials (original order) of the CPU run, for error checks.
    pub potentials: Vec<C64>,
}

impl RunPair {
    pub fn cpu_total(&self) -> f64 {
        self.cpu.total()
    }

    pub fn gpu_total(&self) -> f64 {
        self.gpu.total() + self.gpu_transfer
    }

    pub fn speedup(&self, ph: Phase) -> f64 {
        self.cpu.get(ph) / self.gpu.get(ph).max(1e-12)
    }

    pub fn total_speedup(&self) -> f64 {
        self.cpu_total() / self.gpu_total().max(1e-12)
    }
}

/// Measure one configuration: CPU wall-clock per phase + GPU prediction.
///
/// `threads` selects the CPU engine: `Some(1)` (the harness default) is the
/// paper's serial reference driver, `Some(t)`/`None` run the multithreaded
/// engine ([`crate::fmm::parallel`]) with `t`/all cores — the work counts
/// fed to the GPU model are identical either way. `pin` (the harness
/// `--pin` flag) selects the core-pinned flavor of the shared worker pool
/// for the multithreaded series.
pub fn run_pair(
    points: &[C64],
    gammas: &[C64],
    cfg: &FmmConfig,
    sim: &GpuSim,
    threads: Option<usize>,
    pin: bool,
) -> RunPair {
    let levels = cfg.levels_for(points.len());

    // CPU topological phase (measured; the topology engine follows
    // `threads`, so the serial harness baseline stays paper-faithful while
    // `--threads` accelerates Sort/Connect along with the compute)
    let opts = FmmOptions {
        cfg: *cfg,
        kernel: Kernel::Harmonic,
        symmetric_p2p: true,
        threads,
        pin,
        ..FmmOptions::default()
    };
    let topo = topology::build(points, gammas, levels, &opts.topology_options())
        .expect("harness workloads satisfy the pyramid invariants");
    let (pyr, con) = (topo.pyramid, topo.connectivity);

    // CPU computational phase (symmetric P2P; engine per `threads`)
    let (phi_leaf, mut cpu, mut counts) = fmm::evaluate_on_tree(&pyr, &con, &opts);
    cpu.0[Phase::Sort as usize] = topo.sort_s;
    cpu.0[Phase::Connect as usize] = topo.connect_s;

    // GPU sort statistics come from the functional model of Algorithm 3.2
    // (identical splits, CUDA-shaped work counters)
    let pyr_gpu = Pyramid::build_with(points, gammas, levels, PartitionEngine::GpuModel)
        .expect("harness workloads satisfy the pyramid invariants");
    counts.sort = pyr_gpu.sort_stats;
    // the GPU P2P is directed (§4.2): its pair count is Σ_b n_b·src_b − n,
    // already captured by p2p_src_per_box/leaf_sizes which the model uses

    let gpu = sim.phase_times(&counts);
    let gpu_transfer = sim.transfer_time(&counts);

    RunPair {
        n: points.len(),
        levels,
        cpu,
        gpu,
        gpu_transfer,
        counts,
        potentials: pyr.unpermute(&phi_leaf),
    }
}

/// Deterministic workload for experiment `seed`.
pub fn workload_for(dist: Distribution, n: usize, seed: u64) -> (Vec<C64>, Vec<C64>) {
    let mut r = Pcg64::seed_from_u64(seed);
    dist.generate(n, &mut r)
}

/// Measured direct CPU evaluation time (symmetric kernel, as the paper's
/// comparisons use). For `n > cap`, measures at `cap` and extrapolates
/// quadratically — the paper measures the full range on its testbed; the
/// extrapolation is exact in the O(N²) regime and flagged in the output.
pub fn direct_cpu_time(points: &[C64], gammas: &[C64], cap: usize) -> (f64, bool) {
    let n = points.len();
    if n <= cap {
        let t = Instant::now();
        let phi = crate::direct::eval_symmetric(Kernel::Harmonic, points, gammas);
        std::hint::black_box(&phi);
        (t.elapsed().as_secs_f64(), false)
    } else {
        let t = Instant::now();
        let phi =
            crate::direct::eval_symmetric(Kernel::Harmonic, &points[..cap], &gammas[..cap]);
        std::hint::black_box(&phi);
        let t_cap = t.elapsed().as_secs_f64();
        let scale = (n as f64 / cap as f64).powi(2);
        (t_cap * scale, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_pair_produces_consistent_record() {
        let (pts, gs) = workload_for(Distribution::Uniform, 3000, 1);
        let cfg = FmmConfig {
            p: 10,
            levels_override: Some(3),
            ..FmmConfig::default()
        };
        let pair = run_pair(&pts, &gs, &cfg, &GpuSim::c2075(), Some(1), false);
        assert_eq!(pair.n, 3000);
        assert_eq!(pair.levels, 3);
        assert!(pair.cpu_total() > 0.0);
        assert!(pair.gpu_total() > 0.0);
        assert!(pair.counts.sort.scattered > 0, "gpu sort stats attached");
        assert_eq!(pair.potentials.len(), 3000);
    }

    #[test]
    fn run_pair_parallel_engine_matches_serial_counts() {
        let (pts, gs) = workload_for(Distribution::Uniform, 3000, 1);
        let cfg = FmmConfig {
            p: 10,
            levels_override: Some(3),
            ..FmmConfig::default()
        };
        let sim = GpuSim::c2075();
        let serial = run_pair(&pts, &gs, &cfg, &sim, Some(1), false);
        let par = run_pair(&pts, &gs, &cfg, &sim, Some(4), false);
        // identical work description ⇒ identical GPU prediction
        assert_eq!(serial.counts.p2p_pairs, par.counts.p2p_pairs);
        assert_eq!(serial.counts.p2p_src_per_box, par.counts.p2p_src_per_box);
        assert_eq!(serial.counts.m2l_per_level, par.counts.m2l_per_level);
        assert!((serial.gpu_total() - par.gpu_total()).abs() < 1e-12);
        for (a, b) in serial.potentials.iter().zip(&par.potentials) {
            assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
        }
    }

    #[test]
    fn direct_time_extrapolation_flags() {
        let (pts, gs) = workload_for(Distribution::Uniform, 4000, 2);
        let (_, extrapolated) = direct_cpu_time(&pts, &gs, 8000);
        assert!(!extrapolated);
        let (t_big, extrapolated) = direct_cpu_time(&pts, &gs, 1000);
        assert!(extrapolated);
        let (t_small, _) = direct_cpu_time(&pts[..1000], &gs[..1000], 8000);
        // extrapolated 4k estimate ≈ 16× the measured 1k time (loose bound:
        // the two 1k measurements are separate samples and can jitter)
        assert!(t_big > 4.0 * t_small, "{t_big} vs {t_small}");
    }
}
