//! Evaluation harness: regenerates every table and figure of the paper's
//! §5 (see DESIGN.md §3 for the per-experiment index).
//!
//! Each figure has a dedicated entry point invoked by the `fmm2d` CLI
//! (`fmm2d fig5-1`, `fmm2d table5-1`, …). Experiments run at a scaled-down
//! default size (so the whole suite completes in minutes on a laptop) and
//! accept `--full` for paper-scale runs; the *shape* claims (who wins,
//! crossovers, discontinuities) are size-stable and asserted in
//! EXPERIMENTS.md against both.
//!
//! CPU times are measured from the serial driver; "GPU" times come from the
//! calibrated cost model ([`crate::gpusim`]) fed with the measured work
//! counts of the same tree (the substitution documented in DESIGN.md §1).

pub mod benchsuite;
pub mod figures;
pub mod kernelbench;
pub mod report;
pub mod runner;

pub use figures::*;
pub use runner::*;
