//! One entry point per paper table/figure (DESIGN.md §3 maps each to the
//! paper). All functions print the paper-style series to stdout and save a
//! JSON record under `results/`.

use crate::batch::{self, BatchOptions, BatchProblem};
use crate::config::FmmConfig;
use crate::expansion::Kernel;
use crate::fmm::{self, FmmOptions, Phase, PHASE_NAMES};
use crate::gpusim::model::GpuSim;
use crate::util::stats::{linear_fit, max_rel_error};
use crate::workload::Distribution;

use super::report::{render_distribution, SeriesTable};
use super::runner::{direct_cpu_time, run_pair, workload_for};

/// Global options of a harness invocation.
#[derive(Clone, Copy, Debug)]
pub struct HarnessOpts {
    /// Paper-scale sizes (hours) instead of scaled defaults (minutes).
    pub full: bool,
    pub seed: u64,
    /// Simulate the GTX 480 instead of the Tesla C2075.
    pub gtx480: bool,
    /// CPU engine for the measured side: `Some(1)` (default) keeps the
    /// paper-faithful serial baseline; `Some(t)`/`None` regenerate every
    /// figure with the multithreaded engine (`--threads` on the CLI).
    pub threads: Option<usize>,
    /// Pin pool workers to cores (`--pin`): steadier multithreaded series
    /// on otherwise idle machines.
    pub pin: bool,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        Self {
            full: false,
            seed: 20120424, // the paper's submission year/month, why not
            gtx480: false,
            threads: Some(1),
            pin: false,
        }
    }
}

impl HarnessOpts {
    pub fn sim(&self) -> GpuSim {
        if self.gtx480 {
            GpuSim::gtx480()
        } else {
            GpuSim::c2075()
        }
    }
}

fn cfg_with(p: usize, n_per_box: usize) -> FmmConfig {
    FmmConfig {
        p,
        n_per_box,
        ..FmmConfig::default()
    }
}

/// Figure 5.1 — speedup of the particle-bound phases as a function of the
/// number of sources per box N_d (warp/thread-granularity dips).
pub fn fig5_1(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let levels = if o.full { 6 } else { 4 };
    let mut t = SeriesTable::new(
        "Fig 5.1: speedup of individual parts vs N_d (GPU = cost model)",
        "N_d",
        &["P2M", "L2P", "P2P", "total"],
    );
    let step = if o.full { 1 } else { 2 };
    for nd in (4..=96).step_by(step) {
        let n = nd * (1usize << (2 * levels));
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let cfg = FmmConfig {
            p: 17,
            n_per_box: nd,
            levels_override: Some(levels),
            ..FmmConfig::default()
        };
        let pair = run_pair(&pts, &gs, &cfg, &sim, o.threads, o.pin);
        t.push(
            nd as f64,
            vec![
                pair.speedup(Phase::P2M),
                pair.speedup(Phase::L2P),
                pair.speedup(Phase::P2P),
                pair.total_speedup(),
            ],
        );
    }
    t
}

/// Figure 5.2 — normalized total time vs N_d for CPU and GPU; the paper
/// finds optima near 35 (CPU) and 45 (GPU).
pub fn fig5_2(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let n = if o.full { 1_000_000 } else { 60_000 };
    let mut rows = Vec::new();
    for nd in (10..=100).step_by(5) {
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let pair = run_pair(&pts, &gs, &cfg_with(17, nd), &sim, o.threads, o.pin);
        rows.push((nd as f64, pair.cpu_total(), pair.gpu_total()));
    }
    let min_cpu = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    let min_gpu = rows.iter().map(|r| r.2).fold(f64::INFINITY, f64::min);
    let mut t = SeriesTable::new(
        "Fig 5.2: total time vs N_d, normalized per platform (min = 1)",
        "N_d",
        &["cpu", "gpu(sim)"],
    );
    for (nd, c, g) in rows {
        t.push(nd, vec![c / min_cpu, g / min_gpu]);
    }
    t
}

/// Table 5.1 — time distribution of the GPU algorithm at N_d = 45.
pub fn table5_1(o: &HarnessOpts) -> (String, SeriesTable) {
    let sim = o.sim();
    let levels = if o.full { 8 } else { 6 };
    let n = 45 * (1usize << (2 * levels));
    let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
    let cfg = FmmConfig {
        p: 17,
        n_per_box: 45,
        levels_override: Some(levels),
        ..FmmConfig::default()
    };
    let pair = run_pair(&pts, &gs, &cfg, &sim, o.threads, o.pin);
    let mut entries: Vec<(&str, f64)> = PHASE_NAMES
        .iter()
        .enumerate()
        .map(|(i, name)| (*name, pair.gpu.0[i]))
        .collect();
    entries.push(("Other", pair.gpu_transfer));
    // order by the paper's table: biggest first
    entries.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let text = render_distribution(
        &format!("Table 5.1: GPU time distribution (N = {n}, N_d = 45, p = 17)"),
        &entries,
    );
    let mut t = SeriesTable::new("Table 5.1 record", "phase_idx", &["gpu_s", "cpu_s"]);
    for (i, _) in PHASE_NAMES.iter().enumerate() {
        t.push(i as f64, vec![pair.gpu.0[i], pair.cpu.0[i]]);
    }
    t.push(-1.0, vec![pair.gpu_transfer, 0.0]);
    (text, t)
}

/// Figure 5.3 — speedup of the expansion phases vs the number of multipole
/// coefficients p (shared-memory occupancy cliff at p = 42).
pub fn fig5_3(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let n = if o.full { 1_000_000 } else { 50_000 };
    let mut t = SeriesTable::new(
        "Fig 5.3: speedup vs number of coefficients p (M2L cliff at 42)",
        "p",
        &["P2M", "M2M", "M2L", "L2L", "L2P", "m2l_blocks"],
    );
    let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
    for p in (4..=60).step_by(2) {
        let pair = run_pair(&pts, &gs, &cfg_with(p, 45), &sim, o.threads, o.pin);
        t.push(
            p as f64,
            vec![
                pair.speedup(Phase::P2M),
                pair.speedup(Phase::M2M),
                pair.speedup(Phase::M2L),
                pair.speedup(Phase::L2L),
                pair.speedup(Phase::L2P),
                sim.m2l_active_blocks(p) as f64,
            ],
        );
    }
    t
}

/// Figure 5.4 — optimal N_d as a function of p (≈ linear growth).
pub fn fig5_4(o: &HarnessOpts) -> (SeriesTable, (f64, f64)) {
    let sim = o.sim();
    let n = if o.full { 500_000 } else { 40_000 };
    let mut t = SeriesTable::new(
        "Fig 5.4: optimal N_d vs p",
        "p",
        &["opt_Nd_gpu", "opt_Nd_cpu"],
    );
    let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for p in (8..=48).step_by(8) {
        let (mut best_gpu, mut best_cpu) = ((f64::INFINITY, 0), (f64::INFINITY, 0));
        for nd in (15..=120).step_by(5) {
            let pair = run_pair(&pts, &gs, &cfg_with(p, nd), &sim, o.threads, o.pin);
            if pair.gpu_total() < best_gpu.0 {
                best_gpu = (pair.gpu_total(), nd);
            }
            if pair.cpu_total() < best_cpu.0 {
                best_cpu = (pair.cpu_total(), nd);
            }
        }
        t.push(p as f64, vec![best_gpu.1 as f64, best_cpu.1 as f64]);
        xs.push(p as f64);
        ys.push(best_gpu.1 as f64);
    }
    let fit = linear_fit(&xs, &ys);
    (t, fit)
}

fn n_sweep(full: bool) -> Vec<usize> {
    let max_pow = if full { 21 } else { 18 };
    (7..=max_pow).map(|k| 1usize << k).collect()
}

/// Figure 5.5 — total time vs N: FMM and direct summation on both
/// platforms; the paper's GPU break-even vs direct is near N ≈ 3500.
pub fn fig5_5(o: &HarnessOpts) -> (SeriesTable, f64) {
    let sim = o.sim();
    let cap = 20_000; // measured direct up to here, quadratic beyond
    let mut t = SeriesTable::new(
        "Fig 5.5: total time vs N (p = 17); direct-CPU extrapolated beyond cap",
        "N",
        &["fmm_cpu", "fmm_gpu(sim)", "direct_cpu", "direct_gpu(sim)"],
    );
    let mut break_even = f64::NAN;
    let mut prev: Option<(f64, f64, f64)> = None; // (n, fmm_gpu, dir_gpu)
    for n in n_sweep(o.full) {
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let pair = run_pair(&pts, &gs, &cfg_with(17, 45), &sim, o.threads, o.pin);
        let (dir_cpu, _extr) = direct_cpu_time(&pts, &gs, cap);
        let dir_gpu = sim.direct_time(n);
        let fmm_gpu = pair.gpu_total();
        t.push(
            n as f64,
            vec![pair.cpu_total(), fmm_gpu, dir_cpu, dir_gpu],
        );
        if let Some((pn, pf, pd)) = prev {
            if break_even.is_nan() && pf > pd && fmm_gpu <= dir_gpu {
                // log-linear interpolation of the crossover
                let f = (pf / pd).ln() / ((pf / pd).ln() - (fmm_gpu / dir_gpu).ln());
                break_even = pn * (n as f64 / pn).powf(f);
            }
        }
        prev = Some((n as f64, fmm_gpu, dir_gpu));
    }
    (t, break_even)
}

/// Figure 5.6 — overall speedup vs N (paper: FMM ≈ 11, direct ≈ 15 at
/// large N against the symmetric CPU code).
pub fn fig5_6(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let cap = 20_000;
    let mut t = SeriesTable::new(
        "Fig 5.6: speedup vs N (GPU = cost model / measured CPU)",
        "N",
        &["fmm", "direct"],
    );
    for n in n_sweep(o.full) {
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let pair = run_pair(&pts, &gs, &cfg_with(17, 45), &sim, o.threads, o.pin);
        let (dir_cpu, _) = direct_cpu_time(&pts, &gs, cap);
        t.push(
            n as f64,
            vec![
                pair.cpu_total() / pair.gpu_total(),
                dir_cpu / sim.direct_time(n),
            ],
        );
    }
    t
}

/// Figure 5.7 — per-phase speedup vs N.
pub fn fig5_7(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let mut t = SeriesTable::new(
        "Fig 5.7: speedup of individual parts vs N",
        "N",
        &["Sort", "Connect", "P2M", "M2M", "M2L", "L2L", "L2P", "P2P"],
    );
    for n in n_sweep(o.full) {
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let pair = run_pair(&pts, &gs, &cfg_with(17, 45), &sim, o.threads, o.pin);
        t.push(
            n as f64,
            (0..8).map(|i| pair.cpu.0[i] / pair.gpu.0[i].max(1e-12)).collect(),
        );
    }
    t
}

/// Figure 5.8 — total time vs N for the three point distributions.
pub fn fig5_8(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let mut t = SeriesTable::new(
        "Fig 5.8: time vs N for uniform / normal(0.1) / layer(0.1) (cpu, gpu-sim)",
        "N",
        &[
            "uni_cpu", "uni_gpu", "nrm_cpu", "nrm_gpu", "lay_cpu", "lay_gpu",
        ],
    );
    for n in n_sweep(o.full) {
        let mut ys = Vec::new();
        for dist in [
            Distribution::Uniform,
            Distribution::Normal { sigma: 0.1 },
            Distribution::Layer { sigma: 0.1 },
        ] {
            let (pts, gs) = workload_for(dist, n, o.seed);
            let pair = run_pair(&pts, &gs, &cfg_with(17, 45), &sim, o.threads, o.pin);
            ys.push(pair.cpu_total());
            ys.push(pair.gpu_total());
        }
        t.push(n as f64, ys);
    }
    t
}

/// Figure 5.9 — robustness of adaptivity: time under increasingly
/// non-uniform inputs, normalized to the uniform distribution. The paper
/// finds the GPU degrades *less* than the CPU (P2P has the highest
/// speedup, and non-uniformity grows mostly P2P).
pub fn fig5_9(o: &HarnessOpts) -> SeriesTable {
    let sim = o.sim();
    let n = if o.full { 1_000_000 } else { 80_000 };
    let (pts_u, gs_u) = workload_for(Distribution::Uniform, n, o.seed);
    let base = run_pair(&pts_u, &gs_u, &cfg_with(17, 45), &sim, o.threads, o.pin);
    let (cpu_u, gpu_u) = (base.cpu_total(), base.gpu_total());
    let mut t = SeriesTable::new(
        "Fig 5.9: non-uniform time / uniform time vs sigma",
        "sigma",
        &["normal_cpu", "normal_gpu", "layer_cpu", "layer_gpu"],
    );
    for sigma in [0.2, 0.15, 0.1, 0.07, 0.05, 0.03, 0.02] {
        let mut ys = Vec::new();
        for mk in [
            Distribution::Normal { sigma },
            Distribution::Layer { sigma },
        ] {
            let (pts, gs) = workload_for(mk, n, o.seed);
            let pair = run_pair(&pts, &gs, &cfg_with(17, 45), &sim, o.threads, o.pin);
            ys.push(pair.cpu_total() / cpu_u);
            ys.push(pair.gpu_total() / gpu_u);
        }
        t.push(sigma, ys);
    }
    t
}

/// Accuracy validation (Eq. 5.3): TOL vs p against direct summation; the
/// paper quotes p = 17 ⇒ TOL ≈ 1e-6.
pub fn validate(o: &HarnessOpts) -> SeriesTable {
    let n = 3000;
    let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
    let exact = crate::direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    let exact_abs: Vec<f64> = exact.iter().map(|c| c.abs()).collect();
    let mut t = SeriesTable::new(
        "Validation: relative max error (Eq. 5.3) vs p; bound ~ theta^p",
        "p",
        &["tol_measured", "theta_pow_p"],
    );
    for p in (4..=28).step_by(2) {
        let cfg = FmmConfig {
            p,
            levels_override: Some(3),
            ..FmmConfig::default()
        };
        let opts = crate::fmm::FmmOptions {
            cfg,
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads: o.threads,
            pin: o.pin,
            ..Default::default()
        };
        let out = crate::fmm::evaluate(&pts, &gs, &opts)
            .expect("harness workloads satisfy the pyramid invariants");
        let approx: Vec<f64> = out.potentials.iter().map(|c| c.abs()).collect();
        let err = max_rel_error(&approx, &exact_abs, 1e-12);
        t.push(p as f64, vec![err, cfg.tolerance_estimate()]);
    }
    t
}

/// Ablation: the θ parameter (the paper fixes θ = 1/2 as "performing well
/// in practice", §2). Sweeps θ and reports the work-mix shift (near-field
/// vs far-field), total CPU time and accuracy at fixed p — quantifying the
/// design choice.
pub fn ablate_theta(o: &HarnessOpts) -> SeriesTable {
    let n = if o.full { 500_000 } else { 40_000 };
    let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
    let exact = if n <= 50_000 {
        Some(crate::direct::eval_symmetric(Kernel::Harmonic, &pts, &gs))
    } else {
        None
    };
    let mut t = SeriesTable::new(
        "Ablation: θ sweep at p = 17 (paper fixes θ = 1/2)",
        "theta",
        &["cpu_total_s", "p2p_pairs_M", "m2l_shifts_k", "tol"],
    );
    for theta in [0.35, 0.4, 0.45, 0.5, 0.55, 0.6, 0.7, 0.8] {
        let cfg = FmmConfig {
            p: 17,
            n_per_box: 45,
            theta,
            levels_override: None,
        };
        let opts = crate::fmm::FmmOptions {
            cfg,
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads: o.threads,
            pin: o.pin,
            ..Default::default()
        };
        let out = crate::fmm::evaluate(&pts, &gs, &opts)
            .expect("harness workloads satisfy the pyramid invariants");
        let tol = exact
            .as_ref()
            .map(|e| {
                let a: Vec<f64> = out.potentials.iter().map(|c| c.abs()).collect();
                let ev: Vec<f64> = e.iter().map(|c| c.abs()).collect();
                max_rel_error(&a, &ev, 1e-12)
            })
            .unwrap_or(f64::NAN);
        t.push(
            theta,
            vec![
                out.times.total(),
                out.counts.p2p_pairs as f64 / 1e6,
                out.counts.m2l_per_level.iter().sum::<usize>() as f64 / 1e3,
                tol,
            ],
        );
    }
    t
}

/// Ablation: scaled (Alg 3.4(b)-style) vs unscaled (3.4(a)-style) vs
/// matrix-operator M2L inner kernels — per-shift cost at several p.
pub fn ablate_shift_kernels(_o: &HarnessOpts) -> SeriesTable {
    use crate::bench::{bench, black_box, BenchConfig};
    use crate::complex::C64;
    use crate::expansion::matrices::{M2lOperator, M2lScratch};
    use crate::expansion::shifts::{m2l_unscaled, m2l_with, ShiftScratch};
    use crate::expansion::Coeffs;
    use crate::util::rng::Pcg64;

    let cfgb = BenchConfig {
        warmup: 1,
        samples: 5,
        min_time: 0.05,
    };
    let mut t = SeriesTable::new(
        "Ablation: M2L kernel variants, µs per shift",
        "p",
        &["recurrence", "unscaled", "matrix_op"],
    );
    let mut r = Pcg64::seed_from_u64(2);
    for p in [8usize, 17, 25, 42] {
        let mut a = vec![C64::new(0.0, 0.0); p + 1];
        for k in 1..=p {
            a[k] = C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0));
        }
        let (z_i, z_o) = (C64::new(0.1, 0.2), C64::new(1.4, -0.3));
        let mut out = vec![C64::new(0.0, 0.0); p + 1];
        let mut s = ShiftScratch::new();
        let rec = bench("rec", &cfgb, || {
            m2l_with(&a, z_i, &mut out, z_o, &mut s);
            black_box(&out);
        });
        let mut acc = Coeffs::zero(p);
        let uns = bench("uns", &cfgb, || {
            m2l_unscaled(&Coeffs(a.clone()), z_i, &mut acc, z_o);
            black_box(&acc);
        });
        let op = M2lOperator::new(p);
        let mut ms = M2lScratch::default();
        let mat = bench("mat", &cfgb, || {
            op.apply(&a, z_i, &mut out, z_o, &mut ms);
            black_box(&out);
        });
        t.push(
            p as f64,
            vec![rec.secs() * 1e6, uns.secs() * 1e6, mat.secs() * 1e6],
        );
    }
    t
}

/// Batched vs sequential throughput on the CPU engines (the `batch-bench`
/// CLI command): K small problems dispatched through [`batch::run`]
/// (grouped, pooled workers) against the same problems evaluated one
/// after another through the per-problem multithreaded engine. The batch
/// is run twice — with the sequential prologue (PR-2 shape: every
/// topology built before the first dispatch) and with the overlapped
/// prologue (topology producers feeding the group runner) — so the gain
/// of overlapping the last serial stage is visible per K.
pub fn batch_throughput(o: &HarnessOpts) -> SeriesTable {
    let counts: &[usize] = if o.full { &[8, 32, 128, 512] } else { &[8, 32, 96] };
    let n = if o.full { 4000 } else { 2000 };
    // the dispatcher's predicted batch time sits next to the measured
    // columns so calibration drift is visible (fallback rates unless
    // `fmm2d calibrate` has written a profile)
    let dispatcher = crate::dispatch::Dispatcher::load_or_default(None);
    let mut t = SeriesTable::new(
        "Batched vs sequential throughput (K problems, parallel CPU engine)",
        "K",
        &[
            "seq_s",
            "batch_seqprologue_s",
            "pred_seqprologue_s",
            "batch_overlap_s",
            "overlap_prob_per_s",
            "speedup_vs_seq",
            "overlap_gain",
        ],
    );
    let fmm_opts = FmmOptions {
        cfg: FmmConfig::default(),
        kernel: Kernel::Harmonic,
        symmetric_p2p: true,
        threads: o.threads,
        pin: o.pin,
        ..Default::default()
    };
    for &k in counts {
        let problems: Vec<BatchProblem> = (0..k)
            .map(|i| {
                let (points, gammas) =
                    workload_for(Distribution::Uniform, n, o.seed.wrapping_add(i as u64));
                BatchProblem { points, gammas }
            })
            .collect();
        // warmup (untimed): touch every problem once so page faults,
        // allocator growth and cache state don't bias whichever variant
        // happens to run first
        std::hint::black_box(
            batch::run(
                &problems,
                &BatchOptions {
                    fmm: fmm_opts.clone(),
                    overlap: false,
                    ..Default::default()
                },
            )
            .expect("CPU batch engines cannot fail"),
        );
        // sequential: one full per-problem evaluation after another
        let t0 = std::time::Instant::now();
        for pr in &problems {
            std::hint::black_box(
                fmm::evaluate(&pr.points, &pr.gammas, &fmm_opts)
                    .expect("harness workloads satisfy the pyramid invariants"),
            );
        }
        let seq = t0.elapsed().as_secs_f64();
        // batched, sequential prologue (all trees before the first dispatch)
        let t0 = std::time::Instant::now();
        let out = batch::run(
            &problems,
            &BatchOptions {
                fmm: fmm_opts.clone(),
                overlap: false,
                ..Default::default()
            },
        )
        .expect("CPU batch engines cannot fail");
        std::hint::black_box(&out);
        let bat_seq = t0.elapsed().as_secs_f64();
        // batched, overlapped prologue (the default)
        let t0 = std::time::Instant::now();
        let out = batch::run(
            &problems,
            &BatchOptions {
                fmm: fmm_opts.clone(),
                ..Default::default()
            },
        )
        .expect("CPU batch engines cannot fail");
        std::hint::black_box(&out);
        let bat = t0.elapsed().as_secs_f64();
        // predicted pooled time for the same K problems: the group
        // prediction covers the compute dispatch, so add the per-problem
        // topology term — that sum corresponds to the *sequential
        // prologue* column (the overlapped column hides topology behind
        // group compute, so it legitimately beats this prediction)
        let members: Vec<crate::dispatch::Problem> = problems
            .iter()
            .map(|pr| crate::dispatch::Problem::from_config(&fmm_opts.cfg, pr.points.len()))
            .collect();
        let nt = fmm_opts.effective_threads();
        let compute_pred = dispatcher.select_group_capped(&members, Some(nt)).cost.pooled_s;
        let topo_rates = dispatcher
            .profile
            .pooled_near(nt)
            .map(|e| &e.rates)
            .unwrap_or(&dispatcher.profile.serial);
        let topo_pred: f64 = members
            .iter()
            .map(|m| {
                let u = crate::dispatch::phase_units(&m.counts());
                crate::dispatch::cpu_total(topo_rates, &u)
                    - crate::dispatch::cpu_compute(topo_rates, &u)
            })
            .sum();
        let pred = compute_pred + topo_pred;
        t.push(
            k as f64,
            vec![
                seq,
                bat_seq,
                pred,
                bat,
                k as f64 / bat.max(1e-12),
                seq / bat.max(1e-12),
                bat_seq / bat.max(1e-12),
            ],
        );
    }
    t
}

/// The `topo-bench` CLI command: wall-clock of the topological phase —
/// Sort and Connect, serial vs the parallel topology engine — against the
/// computational phase per N, so the phase split (and what `--threads`
/// buys on the prologue) is visible in BENCH output.
pub fn topo_bench(o: &HarnessOpts) -> SeriesTable {
    use crate::topology::{self, TopologyOptions};

    let threads = o
        .threads
        .unwrap_or_else(crate::util::threadpool::available_threads)
        .max(1);
    let mut t = SeriesTable::new(
        &format!(
            "Topology pipeline: Sort/Connect serial vs parallel ({threads} workers) vs compute"
        ),
        "N",
        &[
            "sort_serial_s",
            "sort_par_s",
            "connect_serial_s",
            "connect_par_s",
            "compute_s",
            "topo_share_serial",
        ],
    );
    let max_pow = if o.full { 21 } else { 18 };
    for n in (10..=max_pow).map(|k| 1usize << k) {
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let cfg = cfg_with(17, 45);
        let levels = cfg.levels_for(n);
        let serial = topology::build(&pts, &gs, levels, &TopologyOptions::serial(cfg.theta))
            .expect("harness workloads satisfy the pyramid invariants");
        let par = topology::build(
            &pts,
            &gs,
            levels,
            &TopologyOptions::parallel(cfg.theta, threads),
        )
        .expect("harness workloads satisfy the pyramid invariants");
        let opts = FmmOptions {
            cfg,
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads: o.threads,
            pin: o.pin,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let (phi, _, _) = fmm::evaluate_on_tree(&serial.pyramid, &serial.connectivity, &opts);
        std::hint::black_box(&phi);
        let compute = t0.elapsed().as_secs_f64();
        let topo_serial = serial.sort_s + serial.connect_s;
        t.push(
            n as f64,
            vec![
                serial.sort_s,
                par.sort_s,
                serial.connect_s,
                par.connect_s,
                compute,
                topo_serial / (topo_serial + compute).max(1e-12),
            ],
        );
    }
    t
}

/// The `pool-bench` CLI command: per-phase wall-clock of the persistent
/// worker pool against the scoped spawn-per-phase engine and the serial
/// driver, on a fixed prebuilt tree per N (best-of-reps), plus the
/// task-graph pipelined engine's wall-clock and its overlap ratio
/// (mean simultaneously busy workers, busy/wall). Returns one
/// table per measured worker count — `--threads T` pins a single count,
/// the default sweeps powers of two up to the machine. The acceptance
/// claims this table carries: at N ≥ 10⁴ the pool loses no phase to the
/// scoped engine, at N ≤ 10³ it cuts the end-to-end dispatch
/// overhead that per-phase spawn/join used to pay, and the task-graph
/// engine's overlap column stays > 1 wherever multiple phases have work.
pub fn pool_bench(o: &HarnessOpts) -> Vec<SeriesTable> {
    use crate::fmm::parallel::{evaluate_on_tree_parallel, evaluate_on_tree_pool};
    use crate::fmm::taskgraph::evaluate_on_tree_taskgraph_stats;
    use crate::fmm::PhaseTimes;
    use crate::topology::{self, TopologyOptions};
    use crate::util::pool::WorkerPool;

    let max_t = crate::util::threadpool::available_threads().max(2);
    let thread_counts: Vec<usize> = match o.threads {
        None => {
            let mut ts = vec![2usize];
            while ts.last().unwrap() * 2 <= max_t {
                let next = ts.last().unwrap() * 2;
                ts.push(next);
            }
            ts
        }
        // an explicit --threads is honored exactly — t = 1 (one pool
        // worker vs one scoped thread vs serial) is a meaningful
        // dispatch-mechanism data point
        Some(t) => vec![t],
    };
    let ns: Vec<usize> = if o.full {
        vec![1_000, 10_000, 100_000, 1_000_000]
    } else {
        vec![600, 1_000, 10_000, 60_000]
    };
    // dispatcher predictions (compute-only, matching what this bench
    // measures) next to the measured totals — calibration drift shows as
    // pred/measured pulling away from 1 (fallback rates unless
    // `fmm2d calibrate` has written a profile)
    let dispatcher = crate::dispatch::Dispatcher::load_or_default(None);
    let mut tables = Vec::new();
    for &t in &thread_counts {
        let pool = WorkerPool::new(t, o.pin);
        let mut table = SeriesTable::new(
            &format!(
                "pool-bench: persistent pool vs scoped spawns vs serial, {t} workers (seconds)"
            ),
            "N",
            &[
                "p2m_scope", "p2m_pool", "m2m_scope", "m2m_pool", "m2l_scope", "m2l_pool",
                "l2l_scope", "l2l_pool", "l2p_scope", "l2p_pool", "p2p_scope", "p2p_pool",
                "total_serial", "pred_serial", "total_scope", "total_pool", "pred_pool",
                "total_tg", "pred_tg", "tg_overlap",
            ],
        );
        for &n in &ns {
            let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
            let cfg = cfg_with(17, 45);
            let levels = cfg.levels_for(n);
            let topo =
                topology::build(&pts, &gs, levels, &TopologyOptions::parallel(cfg.theta, t))
                    .expect("harness workloads satisfy the pyramid invariants");
            let (pyr, con) = (&topo.pyramid, &topo.connectivity);
            let opts = FmmOptions {
                cfg,
                threads: Some(t),
                pin: o.pin,
                ..Default::default()
            };
            let reps = if n <= 1_000 {
                9
            } else if n <= 10_000 {
                3
            } else {
                1
            };
            // best-of-reps per phase and per total: spawn/scheduling noise
            // is one-sided, so minima compare dispatch mechanisms fairly
            let measure = |run: &dyn Fn() -> PhaseTimes| -> (PhaseTimes, f64) {
                let mut best = run();
                let mut best_total = best.total();
                for _ in 1..reps {
                    let sample = run();
                    best_total = best_total.min(sample.total());
                    for (b, v) in best.0.iter_mut().zip(&sample.0) {
                        *b = (*b).min(*v);
                    }
                }
                (best, best_total)
            };
            let (_, serial_total) =
                measure(&|| fmm::evaluate_on_tree_serial(pyr, con, &opts).1);
            let (scope_t, scope_total) =
                measure(&|| evaluate_on_tree_parallel(pyr, con, &opts, t).1);
            let (pool_t, pool_total) =
                measure(&|| evaluate_on_tree_pool(pyr, con, &opts, &pool).1);
            // the task-graph lane, best-of-reps like the others; the
            // overlap column is busy/wall of the *best* wall-clock run
            // (mean simultaneously busy workers — 1.0 means the schedule
            // degenerated to a serialized chain)
            let mut tg_total = f64::INFINITY;
            let mut tg_overlap = 0.0;
            for _ in 0..reps {
                // With the recorder on, mean busy workers comes from the
                // per-task spans instead of OverlapStats' internal sums —
                // the same clock the Chrome trace shows, so the column
                // matches what Perfetto renders. Each rep drains the ring
                // first so the busy sum covers exactly this run (the
                // exported pool-bench trace keeps the other lanes' spans).
                let tracing = crate::obs::enabled();
                if tracing {
                    let _ = crate::obs::drain();
                }
                let (_, _, _, stats) =
                    evaluate_on_tree_taskgraph_stats(pyr, con, &opts, &pool, None);
                let overlap = if tracing && stats.wall_s > 0.0 {
                    let tr = crate::obs::drain();
                    crate::obs::busy_seconds(&tr.spans, "task") / stats.wall_s
                } else {
                    stats.ratio()
                };
                if stats.wall_s < tg_total {
                    tg_total = stats.wall_s;
                    tg_overlap = overlap;
                }
            }
            let problem = crate::dispatch::Problem::from_config(&cfg, n);
            let (pred_serial, pred_pool, pred_tg) = dispatcher.predict_compute(&problem, t);
            table.push(
                n as f64,
                vec![
                    scope_t.get(Phase::P2M),
                    pool_t.get(Phase::P2M),
                    scope_t.get(Phase::M2M),
                    pool_t.get(Phase::M2M),
                    scope_t.get(Phase::M2L),
                    pool_t.get(Phase::M2L),
                    scope_t.get(Phase::L2L),
                    pool_t.get(Phase::L2L),
                    scope_t.get(Phase::L2P),
                    pool_t.get(Phase::L2P),
                    scope_t.get(Phase::P2P),
                    pool_t.get(Phase::P2P),
                    serial_total,
                    pred_serial,
                    scope_total,
                    pool_total,
                    pred_pool,
                    tg_total,
                    pred_tg,
                    tg_overlap,
                ],
            );
        }
        tables.push(table);
    }
    tables
}

/// The `dispatch-bench` CLI command: predicted time per candidate engine
/// next to the measured time of the engine the dispatcher actually picks
/// — for single problems across N and for homogeneous batch groups
/// across K. Calibrates a fresh profile inline (quick sizes unless
/// `--full`) so the table reflects *this* machine, not a stale file; the
/// `choice` column is 0 = serial, 1 = pooled, 2 = xla, 3 = taskgraph.
pub fn dispatch_bench(o: &HarnessOpts) -> Vec<SeriesTable> {
    use crate::dispatch::{
        evaluate_auto, CalibrationOptions, CalibrationProfile, Dispatcher, EngineChoice,
    };

    let profile = CalibrationProfile::measure(&CalibrationOptions {
        quick: !o.full,
        seed: o.seed,
        pin: o.pin,
        worker_counts: o.threads.map(|t| vec![t]).unwrap_or_default(),
    })
    .expect("calibration workloads satisfy the pyramid invariants");
    // honor --gtx480 like every other harness subcommand
    let dispatcher = Dispatcher::new(profile).with_sim(o.sim());
    let choice_code = |c: &EngineChoice| match c {
        EngineChoice::Serial => 0.0,
        EngineChoice::Pooled { .. } => 1.0,
        EngineChoice::Xla => 2.0,
        EngineChoice::TaskGraph { .. } => 3.0,
    };
    let cols = [
        "pred_serial_s",
        "pred_pooled_s",
        "pool_w",
        "pred_gpu_s",
        "choice",
        "measured_s",
        "meas/pred",
    ];

    let mut single = SeriesTable::new(
        "dispatch-bench: single problems — predicted per candidate, auto choice, measured",
        "N",
        &cols,
    );
    let fmm_opts = FmmOptions {
        cfg: FmmConfig::default(),
        threads: o.threads,
        pin: o.pin,
        ..Default::default()
    };
    let ns: &[usize] = if o.full {
        &[300, 1_000, 5_000, 20_000, 100_000]
    } else {
        &[300, 1_000, 5_000, 20_000]
    };
    for &n in ns {
        let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
        let (out, dec) = evaluate_auto(&pts, &gs, &fmm_opts, &dispatcher)
            .expect("harness workloads satisfy the pyramid invariants");
        std::hint::black_box(&out.potentials);
        let measured = dec.measured_s.unwrap_or(f64::NAN);
        single.push(
            n as f64,
            vec![
                dec.cost.serial_s,
                dec.cost.pooled_s,
                dec.cost.pooled_workers as f64,
                dec.cost.gpu_s,
                choice_code(&dec.choice),
                measured,
                measured / dec.predicted_s.max(1e-12),
            ],
        );
    }

    let n = 2000;
    let mut grouped = SeriesTable::new(
        "dispatch-bench: homogeneous batch groups of K × 2000 points",
        "K",
        &cols,
    );
    let ks: &[usize] = if o.full { &[4, 16, 64, 256] } else { &[4, 16, 64] };
    for &k in ks {
        let problems: Vec<BatchProblem> = (0..k)
            .map(|i| {
                let (points, gammas) =
                    workload_for(Distribution::Uniform, n, o.seed.wrapping_add(i as u64));
                BatchProblem { points, gammas }
            })
            .collect();
        let opts = BatchOptions {
            fmm: fmm_opts.clone(),
            engine: crate::batch::BatchEngine::Auto,
            dispatcher: Some(std::sync::Arc::new(dispatcher.clone())),
            ..Default::default()
        };
        let out = batch::run(&problems, &opts).expect("CPU batch engines cannot fail");
        std::hint::black_box(&out.potentials);
        let report = out.report.expect("auto batches carry a dispatch report");
        let dec = &report.decisions[0]; // homogeneous sizes: one group
        // the report's measured_s is the group's compute dispatch — the
        // same scope the group predictions are priced over
        let measured = dec.measured_s.unwrap_or(f64::NAN);
        grouped.push(
            k as f64,
            vec![
                dec.cost.serial_s,
                dec.cost.pooled_s,
                dec.cost.pooled_workers as f64,
                dec.cost.gpu_s,
                choice_code(&dec.choice),
                measured,
                measured / dec.predicted_s.max(1e-12),
            ],
        );
    }
    vec![single, grouped]
}

/// Calibration report: the quantities the cost model is fitted against
/// (paper's headline ratios) — run after any model change.
pub fn calibrate(o: &HarnessOpts) -> String {
    use std::fmt::Write as _;
    let sim = o.sim();
    let mut out = String::new();
    let _ = writeln!(out, "# Calibration vs the paper's headline ratios");
    // direct N-body speedup at a large N (paper: ~15 vs symmetric CPU)
    let n = 30_000;
    let (pts, gs) = workload_for(Distribution::Uniform, n, o.seed);
    let (dir_cpu, _) = direct_cpu_time(&pts, &gs, n);
    let dir_gpu = sim.direct_time(n);
    let _ = writeln!(
        out,
        "direct N-body speedup @N={n}: {:.1} (paper ≈ 15)",
        dir_cpu / dir_gpu
    );
    // FMM total speedup at the Table 5.1 config, scaled
    let levels = 6;
    let nf = 45 * (1usize << (2 * levels));
    let (pts, gs) = workload_for(Distribution::Uniform, nf, o.seed);
    let cfg = FmmConfig {
        p: 17,
        n_per_box: 45,
        levels_override: Some(levels),
        ..FmmConfig::default()
    };
    let pair = run_pair(&pts, &gs, &cfg, &sim, o.threads, o.pin);
    let _ = writeln!(
        out,
        "FMM total speedup @N={nf}: {:.1} (paper ≈ 11)",
        pair.total_speedup()
    );
    let _ = writeln!(out, "GPU phase shares (paper Table 5.1: P2P 43%, Sort 30%, M2L 11%, P2M 5%, L2P 2%, Connect 1%):");
    let total = pair.gpu_total();
    for (i, name) in PHASE_NAMES.iter().enumerate() {
        let _ = writeln!(out, "  {name:<8} {:5.1} %", 100.0 * pair.gpu.0[i] / total);
    }
    let _ = writeln!(
        out,
        "  {:<8} {:5.1} %",
        "Other",
        100.0 * pair.gpu_transfer / total
    );
    // measured CPU wall-clock per phase next to the model's prediction:
    // the Sort/Connect rows used to be model-only, which left the
    // topology half of the cost model uncalibratable against reality
    let _ = writeln!(
        out,
        "measured CPU wall-clock vs cost-model prediction per phase (s):"
    );
    for (i, name) in PHASE_NAMES.iter().enumerate() {
        let _ = writeln!(
            out,
            "  {name:<8} measured {:>10.6} | model {:>10.6} | cpu/model {:>6.1}",
            pair.cpu.0[i],
            pair.gpu.0[i],
            pair.cpu.0[i] / pair.gpu.0[i].max(1e-12)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> HarnessOpts {
        HarnessOpts::default()
    }

    #[test]
    fn validate_reports_paper_tolerance() {
        let t = validate(&quick());
        // find p=18 row (close to the paper's 17): error must be ≤ 1e-5
        let row = t.rows.iter().find(|(x, _)| *x == 18.0).unwrap();
        assert!(row.1[0] < 1e-5, "p=18 error {}", row.1[0]);
        // monotone-ish decay: p=28 much better than p=4
        let first = t.rows.first().unwrap().1[0];
        let last = t.rows.last().unwrap().1[0];
        assert!(last < first * 1e-4);
    }

    #[test]
    fn fig5_9_gpu_degrades_less_than_cpu() {
        // the paper's §5.4 claim, at a reduced size for test time
        let mut o = quick();
        o.seed = 5;
        let sim = o.sim();
        let n = 20_000;
        let (pts_u, gs_u) = workload_for(Distribution::Uniform, n, o.seed);
        let base = run_pair(&pts_u, &gs_u, &cfg_with(17, 45), &sim, o.threads, o.pin);
        let (pts, gs) = workload_for(Distribution::Normal { sigma: 0.05 }, n, o.seed);
        let hard = run_pair(&pts, &gs, &cfg_with(17, 45), &sim, o.threads, o.pin);
        let cpu_ratio = hard.cpu_total() / base.cpu_total();
        let gpu_ratio = hard.gpu_total() / base.gpu_total();
        assert!(
            gpu_ratio < cpu_ratio * 1.2,
            "gpu {gpu_ratio:.2} should not degrade much more than cpu {cpu_ratio:.2}"
        );
    }
}
