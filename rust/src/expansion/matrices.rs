//! Dense-matrix forms of the shift operators — the TPU/MXU mapping.
//!
//! On the GPU the paper evaluates the shift cores as triangular recurrences
//! in shared memory. On a TPU the natural formulation (DESIGN.md
//! §Hardware-Adaptation) is: pre-scale (diagonal) → multiply by a *constant*
//! structure matrix (MXU) → post-scale (diagonal). This module builds those
//! constant matrices; `python/compile/kernels/m2l.py` bakes the same matrix
//! into the Pallas kernel, and the tests here pin the two layers to the same
//! linear map.

use super::Coeffs;
use crate::complex::{C64, ZERO};

/// Table of binomial coefficients `C(n, k)` up to `n < size`, f64-valued
/// (exact for the n ranges used here: C(120, 60) < 2^53·2^14 — beyond exact
/// integers in f64 for p > 26, but the *relative* error stays at machine-ε
/// because each entry is built by one addition of same-sign numbers).
pub struct BinomTable {
    size: usize,
    c: Vec<f64>,
}

impl BinomTable {
    pub fn new(size: usize) -> Self {
        let mut c = vec![0.0; size * size];
        for n in 0..size {
            c[n * size] = 1.0;
            for k in 1..=n {
                c[n * size + k] = c[(n - 1) * size + k - 1]
                    + if k <= n - 1 { c[(n - 1) * size + k] } else { 0.0 };
            }
        }
        Self { size, c }
    }

    /// `C(n, k)`; zero outside the triangle.
    #[inline]
    pub fn c(&self, n: usize, k: usize) -> f64 {
        if k > n || n >= self.size {
            0.0
        } else {
            self.c[n * self.size + k]
        }
    }
}

/// The constant M2L structure matrix `T[l][k] = C(k+l−1, l)` for
/// `l = 0..=p`, `k = 0..=p` (column 0 is zero: `a_0` is handled separately).
/// The scaled M2L map is `b̂ = T â` with `â_k = a_k r^{−k}`,
/// `b_l = (−1)^l r^{−l} b̂_l`.
pub fn m2l_matrix(p: usize) -> Vec<Vec<f64>> {
    let binom = BinomTable::new(2 * p + 1);
    (0..=p)
        .map(|l| {
            (0..=p)
                .map(|k| if k == 0 { 0.0 } else { binom.c(k + l - 1, l) })
                .collect()
        })
        .collect()
}

/// The constant M2M structure matrix `S[l][k] = C(l−1, k−1)` (`k ≥ 1`).
/// Scaled map: `â_k = a_k d^{−k}`, `a'_l = d^l (S â)_l` (plus `a_0` terms).
pub fn m2m_matrix(p: usize) -> Vec<Vec<f64>> {
    let binom = BinomTable::new(p + 1);
    (0..=p)
        .map(|l| {
            (0..=p)
                .map(|k| {
                    if k == 0 || l == 0 || k > l {
                        0.0
                    } else {
                        binom.c(l - 1, k - 1)
                    }
                })
                .collect()
        })
        .collect()
}

/// The constant L2L structure matrix `U[l][k] = (−1)^{k−l} C(k, l)` (k ≥ l).
/// Scaled map with `r = z_p − z_c`: `b̂_k = b_k r^k`, `b'_l = r^{−l} (U b̂)_l`.
pub fn l2l_matrix(p: usize) -> Vec<Vec<f64>> {
    let binom = BinomTable::new(p + 1);
    (0..=p)
        .map(|l| {
            (0..=p)
                .map(|k| {
                    if k < l {
                        0.0
                    } else {
                        let s = if (k - l) % 2 == 0 { 1.0 } else { -1.0 };
                        s * binom.c(k, l)
                    }
                })
                .collect()
        })
        .collect()
}

/// Apply M2L through the dense matrix (the data-parallel formulation):
/// used for cross-validation against the recurrence and as the oracle the
/// Pallas kernel is tested against.
pub fn m2l_via_matrix(mat: &[Vec<f64>], multipole: &Coeffs, z_i: C64, local: &mut Coeffs, z_o: C64) {
    let p = multipole.order();
    debug_assert_eq!(mat.len(), p + 1);
    let r = z_o - z_i;
    let ir = r.recip();
    // pre-scale
    let irk = ir.powi_table(p);
    let ahat: Vec<C64> = (0..=p).map(|k| multipole.0[k] * irk[k]).collect();
    // constant matrix application (4 real GEMVs in the batched TPU version)
    let a0 = multipole.0[0];
    let mut sign = 1.0;
    for l in 0..=p {
        let mut acc = ZERO;
        for k in 1..=p {
            acc += ahat[k] * mat[l][k];
        }
        acc = acc * irk[l] * sign;
        if a0 != ZERO {
            if l == 0 {
                acc += a0 * r.ln();
            } else {
                acc -= a0 * sign / l as f64 * irk[l];
            }
        }
        local.0[l] += acc;
        sign = -sign;
    }
}

/// Flatten a structure matrix row-major into f64 (the layout `aot.py` bakes
/// into the HLO constant; kept in one place so layer parity is testable).
pub fn flatten_row_major(mat: &[Vec<f64>]) -> Vec<f64> {
    mat.iter().flat_map(|row| row.iter().copied()).collect()
}

/// Precomputed M2L operator: the dense-matrix evaluation of the shift.
///
/// The triangular recurrence ([`super::shifts::m2l_with`]) has a strictly
/// sequential inner dependency chain (`c[j] -= c[j-1]`), which defeats
/// SIMD; this form trades ~2× the flops for fully vectorizable dot
/// products against the *constant* structure matrix — the CPU analogue of
/// the MXU mapping, and ~3–4× faster at p = 17 in practice (see
/// EXPERIMENTS.md §Perf, where this replaced the recurrence in the serial
/// driver's hot loop).
#[derive(Clone, Debug)]
pub struct M2lOperator {
    p: usize,
    /// Row-major `T[l][k] = C(k+l−1, l)`, `(p+1)²` entries.
    t: Vec<f64>,
}

impl M2lOperator {
    pub fn new(p: usize) -> Self {
        Self {
            p,
            t: flatten_row_major(&m2l_matrix(p)),
        }
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.p
    }

    /// Accumulate the M2L translation of `multipole` (around `z_i`) into
    /// `local` (around `z_o`). `a_0` must be zero (harmonic kernel) — the
    /// general-kernel path stays on [`super::shifts::m2l_with`].
    pub fn apply(
        &self,
        multipole: &[C64],
        z_i: C64,
        local: &mut [C64],
        z_o: C64,
        scratch: &mut M2lScratch,
    ) {
        let p = self.p;
        debug_assert_eq!(multipole.len(), p + 1);
        debug_assert_eq!(local.len(), p + 1);
        debug_assert_eq!(multipole[0], ZERO, "matrix path requires a_0 = 0");
        let r = z_o - z_i;
        let ir = r.recip();

        // pre-scale into split re/im arrays (SoA ⇒ vectorizable core)
        scratch.re.resize(p + 1, 0.0);
        scratch.im.resize(p + 1, 0.0);
        let mut pw = ir;
        for k in 1..=p {
            let v = multipole[k] * pw;
            scratch.re[k] = v.re;
            scratch.im[k] = v.im;
            pw *= ir;
        }

        // constant-matrix core + post-scale, row by row
        let mut irl = crate::complex::ONE; // (−1)^l r^{−l}
        let neg_ir = -ir;
        for l in 0..=p {
            let row = &self.t[l * (p + 1)..(l + 1) * (p + 1)];
            let mut acc_re = 0.0;
            let mut acc_im = 0.0;
            // k = 0 contributes 0 (column 0 is zero); keep full-width loop
            // for the vectorizer
            for k in 0..=p {
                acc_re += row[k] * scratch.re[k];
                acc_im += row[k] * scratch.im[k];
            }
            local[l] += C64::new(acc_re, acc_im) * irl;
            irl *= neg_ir;
        }
    }

    /// Accumulate the M2L translations of **all** `srcs` (one destination
    /// box's weak-interaction list) into `local` as a single blocked
    /// matrix-panel sweep (DESIGN.md §10): every source is pre-scaled into
    /// a k-major `(p+1) × S` panel, then each row `l` of the constant
    /// structure matrix is swept once across the panel — `S` fused dot
    /// products per row — and reduced over sources with the post-scale
    /// factor `(−1)^l r_s^{−l}` carried per source in Horner order (one
    /// complex multiply per source per row, no `powi` tables). Loading the
    /// `T` row once per `l` regardless of list length is what makes the
    /// kernel compute-bound; the adaptive mesh's median splits leave no
    /// reusable offset classes to block over (box centers are not a lattice),
    /// so the panel is grouped by *destination* instead.
    ///
    /// `mults` is the level's coefficient slab with row stride `stride`;
    /// `src_centers` is indexed by the global box ids in `srcs`. Equivalent
    /// to repeated [`Self::apply`] up to floating-point reassociation (each
    /// coefficient sums its sources in list order here, instead of
    /// accumulating one whole translation at a time). As for [`Self::apply`],
    /// every source must have `a_0 = 0`.
    #[allow(clippy::too_many_arguments)] // internal kernel plumbing, not API
    pub fn apply_panel(
        &self,
        mults: &[C64],
        stride: usize,
        srcs: &[u32],
        src_centers: &[C64],
        local: &mut [C64],
        z_o: C64,
        scratch: &mut M2lScratch,
    ) {
        let p = self.p;
        debug_assert!(stride >= p + 1);
        debug_assert_eq!(local.len(), p + 1);
        let ns = srcs.len();
        if ns == 0 {
            return;
        }

        // pre-scale every source into the k-major panel (lane = source)
        scratch.pre_re.resize((p + 1) * ns, 0.0);
        scratch.pre_im.resize((p + 1) * ns, 0.0);
        scratch.dot_re.resize(ns, 0.0);
        scratch.dot_im.resize(ns, 0.0);
        scratch.cur_re.resize(ns, 0.0);
        scratch.cur_im.resize(ns, 0.0);
        scratch.nir_re.resize(ns, 0.0);
        scratch.nir_im.resize(ns, 0.0);
        for (s, &src) in srcs.iter().enumerate() {
            let su = src as usize;
            let m = &mults[su * stride..su * stride + p + 1];
            debug_assert_eq!(m[0], ZERO, "matrix path requires a_0 = 0");
            let ir = (z_o - src_centers[su]).recip();
            let mut pw = ir;
            for k in 1..=p {
                let v = m[k] * pw;
                scratch.pre_re[k * ns + s] = v.re;
                scratch.pre_im[k * ns + s] = v.im;
                pw *= ir;
            }
            scratch.cur_re[s] = 1.0; // (−1)^l r_s^{−l}, advanced per row below
            scratch.cur_im[s] = 0.0;
            scratch.nir_re[s] = -ir.re;
            scratch.nir_im[s] = -ir.im;
        }

        // matrix-panel core: T row l × panel → S dots, post-scale, reduce
        for l in 0..=p {
            let row = &self.t[l * (p + 1)..(l + 1) * (p + 1)];
            scratch.dot_re.fill(0.0);
            scratch.dot_im.fill(0.0);
            // column 0 of T is zero (a_0 handled separately), start at k = 1
            for k in 1..=p {
                let c = row[k];
                let base = k * ns;
                for s in 0..ns {
                    scratch.dot_re[s] = c.mul_add(scratch.pre_re[base + s], scratch.dot_re[s]);
                    scratch.dot_im[s] = c.mul_add(scratch.pre_im[base + s], scratch.dot_im[s]);
                }
            }
            let mut acc_re = 0.0;
            let mut acc_im = 0.0;
            for s in 0..ns {
                let (dr, di) = (scratch.dot_re[s], scratch.dot_im[s]);
                let (cr, ci) = (scratch.cur_re[s], scratch.cur_im[s]);
                acc_re += dr * cr - di * ci;
                acc_im += dr * ci + di * cr;
                let (nr, ni) = (scratch.nir_re[s], scratch.nir_im[s]);
                scratch.cur_re[s] = cr * nr - ci * ni;
                scratch.cur_im[s] = cr * ni + ci * nr;
            }
            local[l] += C64::new(acc_re, acc_im);
        }
    }
}

/// Scratch for [`M2lOperator::apply`] and [`M2lOperator::apply_panel`].
#[derive(Clone, Debug, Default)]
pub struct M2lScratch {
    re: Vec<f64>,
    im: Vec<f64>,
    // panel state (`apply_panel`): k-major pre-scaled coefficients, the
    // per-row dot accumulators, and the per-source Horner factor
    // (−1)^l r^{−l} with its per-row update −r^{−1}
    pre_re: Vec<f64>,
    pre_im: Vec<f64>,
    dot_re: Vec<f64>,
    dot_im: Vec<f64>,
    cur_re: Vec<f64>,
    cur_im: Vec<f64>,
    nir_re: Vec<f64>,
    nir_im: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expansion::shifts::{l2l, m2l, m2m_scaled};
    use crate::util::rng::Pcg64;

    fn rand_coeffs(r: &mut Pcg64, p: usize) -> Coeffs {
        let mut c = Coeffs(
            (0..=p)
                .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
                .collect::<Vec<_>>(),
        );
        c.0[0] = ZERO;
        c
    }

    #[test]
    fn binom_table_small_values() {
        let b = BinomTable::new(12);
        assert_eq!(b.c(0, 0), 1.0);
        assert_eq!(b.c(5, 2), 10.0);
        assert_eq!(b.c(10, 5), 252.0);
        assert_eq!(b.c(3, 5), 0.0);
        assert_eq!(b.c(11, 0), 1.0);
    }

    #[test]
    fn binom_pascal_identity() {
        let b = BinomTable::new(40);
        for n in 1..40 {
            for k in 1..n {
                assert_eq!(b.c(n, k), b.c(n - 1, k - 1) + b.c(n - 1, k));
            }
        }
    }

    #[test]
    fn m2l_matrix_matches_recurrence() {
        let mut r = Pcg64::seed_from_u64(20);
        for p in [1usize, 5, 17, 42] {
            let mat = m2l_matrix(p);
            let m = rand_coeffs(&mut r, p);
            let z_i = C64::new(0.2, -0.1);
            let z_o = C64::new(-1.1, 0.9);
            let mut via_mat = Coeffs::zero(p);
            let mut via_rec = Coeffs::zero(p);
            m2l_via_matrix(&mat, &m, z_i, &mut via_mat, z_o);
            m2l(&m, z_i, &mut via_rec, z_o);
            for j in 0..=p {
                let err = (via_mat.0[j] - via_rec.0[j]).abs();
                assert!(err / via_rec.0[j].abs().max(1.0) < 1e-11, "p={p} j={j}");
            }
        }
    }

    #[test]
    fn m2m_matrix_is_the_triangular_core() {
        // Apply the scaled M2M through the matrix explicitly and compare.
        let mut r = Pcg64::seed_from_u64(21);
        let p = 17;
        let mat = m2m_matrix(p);
        let c = rand_coeffs(&mut r, p);
        let z_c = C64::new(0.25, 0.75);
        let z_p = C64::new(0.5, 0.5);
        let d = z_c - z_p;
        let id = d.recip();
        let idk = id.powi_table(p);
        let dk = d.powi_table(p);
        let ahat: Vec<C64> = (0..=p).map(|k| c.0[k] * idk[k]).collect();
        let mut via_mat = Coeffs::zero(p);
        for l in 1..=p {
            let mut acc = ZERO;
            for k in 1..=l {
                acc += ahat[k] * mat[l][k];
            }
            via_mat.0[l] = acc * dk[l];
        }
        let mut via_rec = Coeffs::zero(p);
        m2m_scaled(&c, z_c, &mut via_rec, z_p);
        for j in 0..=p {
            assert!((via_mat.0[j] - via_rec.0[j]).abs() < 1e-11, "j={j}");
        }
    }

    #[test]
    fn l2l_matrix_is_the_triangular_core() {
        let mut r = Pcg64::seed_from_u64(22);
        let p = 17;
        let mat = l2l_matrix(p);
        let parent = rand_coeffs(&mut r, p);
        let z_p = C64::new(0.5, 0.5);
        let z_c = C64::new(0.7, 0.3);
        let rr = z_p - z_c;
        let rk = rr.powi_table(p);
        let irk = rr.recip().powi_table(p);
        let bhat: Vec<C64> = (0..=p).map(|k| parent.0[k] * rk[k]).collect();
        let mut via_mat = Coeffs::zero(p);
        for l in 0..=p {
            let mut acc = ZERO;
            for k in l..=p {
                acc += bhat[k] * mat[l][k];
            }
            via_mat.0[l] = acc * irk[l];
        }
        let mut via_rec = Coeffs::zero(p);
        l2l(&parent, z_p, &mut via_rec, z_c);
        for j in 0..=p {
            let err = (via_mat.0[j] - via_rec.0[j]).abs();
            assert!(err / via_rec.0[j].abs().max(1.0) < 1e-11, "j={j}");
        }
    }

    #[test]
    fn flatten_layout() {
        let m = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(flatten_row_major(&m), vec![1.0, 2.0, 3.0, 4.0]);
    }
}

#[cfg(test)]
mod operator_tests {
    use super::*;
    use crate::expansion::shifts::m2l;
    use crate::util::rng::Pcg64;

    #[test]
    fn m2l_operator_matches_recurrence() {
        let mut r = Pcg64::seed_from_u64(30);
        for p in [1usize, 2, 8, 17, 42] {
            let op = M2lOperator::new(p);
            assert_eq!(op.order(), p);
            let mut m = Coeffs::zero(p);
            for k in 1..=p {
                m.0[k] = C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0));
            }
            let z_i = C64::new(0.3, -0.2);
            let z_o = C64::new(-1.0, 1.1);
            let mut via_op = Coeffs::zero(p);
            let mut scratch = M2lScratch::default();
            op.apply(&m.0, z_i, &mut via_op.0, z_o, &mut scratch);
            let mut via_rec = Coeffs::zero(p);
            m2l(&m, z_i, &mut via_rec, z_o);
            for j in 0..=p {
                let err = (via_op.0[j] - via_rec.0[j]).abs();
                assert!(
                    err / via_rec.0[j].abs().max(1.0) < 1e-11,
                    "p={p} j={j}: {err:e}"
                );
            }
        }
    }

    #[test]
    fn m2l_panel_matches_repeated_apply() {
        // the blocked panel must agree with per-source `apply` (and hence,
        // transitively, with the recurrence) for a scattered weak list
        let mut r = Pcg64::seed_from_u64(31);
        for p in [1usize, 2, 8, 17, 42] {
            let op = M2lOperator::new(p);
            let stride = p + 1;
            let nboxes = 7;
            let mut mults = vec![ZERO; nboxes * stride];
            let mut centers = vec![ZERO; nboxes];
            for b in 0..nboxes {
                for k in 1..=p {
                    mults[b * stride + k] =
                        C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0));
                }
                centers[b] = C64::new(r.uniform_in(2.0, 4.0), r.uniform_in(-4.0, -2.0));
            }
            let z_o = C64::new(-0.3, 0.4);
            let srcs: Vec<u32> = vec![5, 0, 3, 6, 1];
            let mut scratch = M2lScratch::default();
            let mut via_panel = vec![ZERO; p + 1];
            op.apply_panel(
                &mults,
                stride,
                &srcs,
                &centers,
                &mut via_panel,
                z_o,
                &mut scratch,
            );
            let mut via_apply = vec![ZERO; p + 1];
            for &s in &srcs {
                let su = s as usize;
                op.apply(
                    &mults[su * stride..(su + 1) * stride],
                    centers[su],
                    &mut via_apply,
                    z_o,
                    &mut scratch,
                );
            }
            for j in 0..=p {
                let err = (via_panel[j] - via_apply[j]).abs();
                assert!(
                    err / via_apply[j].abs().max(1.0) < 1e-11,
                    "p={p} j={j}: {err:e}"
                );
            }
        }
    }

    #[test]
    fn m2l_panel_accumulates_and_ignores_empty_lists() {
        let p = 5;
        let op = M2lOperator::new(p);
        let stride = p + 1;
        let mut mults = vec![ZERO; 2 * stride];
        mults[stride + 1] = C64::new(1.0, -0.5);
        let centers = [C64::new(3.0, 0.0), C64::new(0.0, 3.0)];
        let z_o = C64::new(0.0, 0.0);
        let mut scratch = M2lScratch::default();
        let mut out = vec![ZERO; p + 1];
        op.apply_panel(&mults, stride, &[1], &centers, &mut out, z_o, &mut scratch);
        let once = out.clone();
        op.apply_panel(&mults, stride, &[1], &centers, &mut out, z_o, &mut scratch);
        for j in 0..=p {
            assert!((out[j] - once[j] * 2.0).abs() < 1e-14, "j={j}");
        }
        op.apply_panel(&mults, stride, &[], &centers, &mut out, z_o, &mut scratch);
        for j in 0..=p {
            assert!(
                (out[j] - once[j] * 2.0).abs() < 1e-14,
                "empty weak list must be a no-op (j={j})"
            );
        }
    }

    #[test]
    fn m2l_operator_accumulates() {
        // repeated apply accumulates (+=), required by the driver loop
        let p = 5;
        let op = M2lOperator::new(p);
        let mut m = Coeffs::zero(p);
        m.0[1] = C64::new(1.0, 0.0);
        let mut out = Coeffs::zero(p);
        let mut scratch = M2lScratch::default();
        let (z_i, z_o) = (C64::new(0.0, 0.0), C64::new(2.0, 0.0));
        op.apply(&m.0, z_i, &mut out.0, z_o, &mut scratch);
        let once = out.clone();
        op.apply(&m.0, z_i, &mut out.0, z_o, &mut scratch);
        for j in 0..=p {
            assert!((out.0[j] - once.0[j] * 2.0).abs() < 1e-14);
        }
    }
}
