//! Multipole and local expansions (paper Eqs. 2.2–2.3) and the particle-side
//! operators P2M, P2L, M2P, L2P.
//!
//! Conventions (fixed throughout the repo, validated against direct
//! summation in the tests):
//!
//! * a source of strength `Γ` at `z_s` contributes `Γ/(z_s − z)` to the
//!   potential at `z` for the [`Kernel::Harmonic`] kernel (paper Eq. 5.1,
//!   the vortex/harmonic kernel, `a_0 = 0`), and `Γ·log(z − z_s)` for
//!   [`Kernel::Log`] (the extension exercising the `a_0` paths of all shift
//!   operators; its imaginary part is branch-cut sensitive, so log-kernel
//!   comparisons are on the real part);
//! * multipole expansion around `z_0`:
//!   `M(z) = a_0 log(z−z_0) + Σ_{j≥1} a_j (z−z_0)^{−j}`;
//! * local expansion around `z_0`: `L(z) = Σ_{j≥0} b_j (z−z_0)^j`.
//!
//! The shift operators (M2M/M2L/L2L, Algorithms 3.4–3.6) live in
//! [`shifts`]; their dense-matrix forms (the TPU/MXU mapping of
//! DESIGN.md §Hardware-Adaptation) in [`matrices`].

pub mod matrices;
pub mod shifts;

use crate::complex::{C64, ZERO};

/// Interaction kernel `G` of Eq. (1.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// `G(z, z_j) = Γ_j / (z_j − z)` — the paper's harmonic potential
    /// (Eq. 5.1). Multipole coefficient `a_0` is identically zero.
    Harmonic,
    /// `G(z, z_j) = Γ_j · log(z − z_j)` — logarithmic potential; populates
    /// `a_0` and exercises every `a_0`-term of the shift operators.
    Log,
}

impl Kernel {
    /// Pairwise direct evaluation: contribution at `z` of a source at `zs`.
    #[inline(always)]
    pub fn eval(self, z: C64, zs: C64, gamma: C64) -> C64 {
        match self {
            Kernel::Harmonic => gamma * (zs - z).recip(),
            Kernel::Log => gamma * (z - zs).ln(),
        }
    }
}

/// Coefficients of one expansion (multipole `a_0..a_p` or local `b_0..b_p`);
/// a thin newtype so multipole/local cannot be mixed accidentally.
#[derive(Clone, Debug, PartialEq)]
pub struct Coeffs(pub Vec<C64>);

impl Coeffs {
    /// Zero expansion of order `p` (holds `p+1` terms).
    pub fn zero(p: usize) -> Self {
        Coeffs(vec![ZERO; p + 1])
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.0.len() - 1
    }

    pub fn add_assign(&mut self, other: &Coeffs) {
        debug_assert_eq!(self.0.len(), other.0.len());
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += *b;
        }
    }

    pub fn clear(&mut self) {
        self.0.fill(ZERO);
    }

    pub fn is_zero(&self) -> bool {
        self.0.iter().all(|c| *c == ZERO)
    }
}

/// P2M: accumulate the multipole expansion of `sources`/`gammas` around `z0`
/// into `acc` (paper §3.3.1).
///
/// Harmonic: `a_j += −Γ (z_s−z_0)^{j−1}`, `j ≥ 1`.
/// Log: `a_0 += Γ`, `a_j += −Γ (z_s−z_0)^j / j`.
pub fn p2m(kernel: Kernel, z0: C64, sources: &[C64], gammas: &[C64], acc: &mut Coeffs) {
    p2m_slice(kernel, z0, sources, gammas, &mut acc.0);
}

/// Slice form of [`p2m`] — the drivers accumulate straight into the box's
/// coefficient storage instead of building a `Coeffs` temporary per box.
pub fn p2m_slice(kernel: Kernel, z0: C64, sources: &[C64], gammas: &[C64], acc: &mut [C64]) {
    let p = acc.len() - 1;
    match kernel {
        Kernel::Harmonic => {
            for (&zs, &g) in sources.iter().zip(gammas) {
                let t = zs - z0;
                let mut pw = -g; // −Γ t^{j−1} starting at j = 1
                for j in 1..=p {
                    acc[j] += pw;
                    pw *= t;
                }
            }
        }
        Kernel::Log => {
            for (&zs, &g) in sources.iter().zip(gammas) {
                let t = zs - z0;
                acc[0] += g;
                let mut pw = t; // t^j
                for j in 1..=p {
                    acc[j] += (-g) * pw / j as f64;
                    pw *= t;
                }
            }
        }
    }
}

/// P2L: accumulate the *local* expansion around `z0` of far-away particles
/// (the finest-level shortcut of §2: sources of a strongly-coupled larger
/// box shifted directly into the smaller box's local expansion).
///
/// Harmonic: `b_l += Γ / (z_s−z_0)^{l+1}`.
/// Log: `b_0 += Γ log(z_0−z_s)`, `b_l −= Γ / (l (z_s−z_0)^l)`.
pub fn p2l(kernel: Kernel, z0: C64, sources: &[C64], gammas: &[C64], acc: &mut Coeffs) {
    p2l_slice(kernel, z0, sources, gammas, &mut acc.0);
}

/// Slice form of [`p2l`] — accumulates straight into the destination box's
/// local-expansion storage (no per-box copy-out/copy-back).
pub fn p2l_slice(kernel: Kernel, z0: C64, sources: &[C64], gammas: &[C64], acc: &mut [C64]) {
    let p = acc.len() - 1;
    match kernel {
        Kernel::Harmonic => {
            for (&zs, &g) in sources.iter().zip(gammas) {
                let it = (zs - z0).recip();
                let mut pw = g * it; // Γ / t^{l+1}
                for l in 0..=p {
                    acc[l] += pw;
                    pw *= it;
                }
            }
        }
        Kernel::Log => {
            for (&zs, &g) in sources.iter().zip(gammas) {
                let t = zs - z0;
                acc[0] += g * (-t).ln();
                let it = t.recip();
                let mut pw = it; // 1/t^l
                for l in 1..=p {
                    acc[l] -= g * pw / l as f64;
                    pw *= it;
                }
            }
        }
    }
}

/// L2P: evaluate the local expansion at `z` by Horner's rule (§3.3.4).
#[inline]
pub fn l2p(z0: C64, coeffs: &Coeffs, z: C64) -> C64 {
    l2p_slice(z0, &coeffs.0, z)
}

/// Slice form of [`l2p`] — evaluates directly from the coefficient pyramid
/// storage (the drivers used to copy every box's coefficients into a
/// `Coeffs` temporary per box before evaluating).
#[inline]
pub fn l2p_slice(z0: C64, coeffs: &[C64], z: C64) -> C64 {
    let w = z - z0;
    let mut acc = ZERO;
    for &b in coeffs.iter().rev() {
        acc = acc * w + b;
    }
    acc
}

/// M2P: evaluate the multipole expansion directly at `z` (§3.3.4's special
/// case — valid only outside the box radius; Horner in `1/(z−z_0)`).
#[inline]
pub fn m2p(z0: C64, coeffs: &Coeffs, z: C64) -> C64 {
    m2p_slice(z0, &coeffs.0, z)
}

/// Slice form of [`m2p`] (see [`l2p_slice`]).
#[inline]
pub fn m2p_slice(z0: C64, coeffs: &[C64], z: C64) -> C64 {
    let t = z - z0;
    let it = t.recip();
    // Σ_{j≥1} a_j t^{−j} = it·(a_1 + it·(a_2 + …)), then the a_0 log term.
    let mut acc = ZERO;
    for &a in coeffs.iter().skip(1).rev() {
        acc = (acc + a) * it;
    }
    if coeffs[0] != ZERO {
        acc += coeffs[0] * t.ln();
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_c(r: &mut Pcg64, lo: f64, hi: f64) -> C64 {
        C64::new(r.uniform_in(lo, hi), r.uniform_in(lo, hi))
    }

    /// Direct sum of the kernel over sources.
    fn direct(kernel: Kernel, z: C64, zs: &[C64], g: &[C64]) -> C64 {
        zs.iter().zip(g).map(|(&s, &q)| kernel.eval(z, s, q)).sum()
    }

    #[test]
    fn p2m_converges_to_direct_harmonic() {
        let mut r = Pcg64::seed_from_u64(1);
        let z0 = C64::new(0.5, 0.5);
        // sources inside radius 0.2 of z0; evaluation at distance ≳ 3x
        let zs: Vec<C64> = (0..20)
            .map(|_| z0 + rand_c(&mut r, -0.14, 0.14))
            .collect();
        let g: Vec<C64> = (0..20).map(|_| rand_c(&mut r, -1.0, 1.0)).collect();
        let mut m = Coeffs::zero(30);
        p2m(Kernel::Harmonic, z0, &zs, &g, &mut m);
        assert_eq!(m.0[0], ZERO, "harmonic kernel must have a_0 = 0");
        for zeval in [C64::new(1.5, 0.5), C64::new(0.5, -0.7), C64::new(-0.4, 1.4)] {
            let exact = direct(Kernel::Harmonic, zeval, &zs, &g);
            let approx = m2p(z0, &m, zeval);
            assert!(
                (approx - exact).abs() / exact.abs() < 1e-12,
                "zeval={zeval:?}: {approx:?} vs {exact:?}"
            );
        }
    }

    #[test]
    fn p2m_converges_to_direct_log() {
        let mut r = Pcg64::seed_from_u64(2);
        let z0 = C64::new(0.0, 0.0);
        let zs: Vec<C64> = (0..10).map(|_| rand_c(&mut r, -0.1, 0.1)).collect();
        let g: Vec<C64> = (0..10)
            .map(|_| C64::real(r.uniform_in(-1.0, 1.0)))
            .collect();
        let mut m = Coeffs::zero(40);
        p2m(Kernel::Log, z0, &zs, &g, &mut m);
        let zeval = C64::new(1.1, 0.3);
        let exact = direct(Kernel::Log, zeval, &zs, &g);
        let approx = m2p(z0, &m, zeval);
        // log kernel: compare real part (imaginary part is branch sensitive)
        assert!((approx.re - exact.re).abs() / exact.re.abs().max(1.0) < 1e-12);
    }

    #[test]
    fn p2l_converges_to_direct_harmonic() {
        let mut r = Pcg64::seed_from_u64(3);
        let z0 = C64::new(0.0, 0.0);
        // sources far from z0, evaluation near z0
        let zs: Vec<C64> = (0..15)
            .map(|_| C64::new(2.0, 1.0) + rand_c(&mut r, -0.2, 0.2))
            .collect();
        let g: Vec<C64> = (0..15).map(|_| rand_c(&mut r, -1.0, 1.0)).collect();
        let mut l = Coeffs::zero(40);
        p2l(Kernel::Harmonic, z0, &zs, &g, &mut l);
        for zeval in [C64::new(0.2, -0.1), C64::new(-0.25, 0.2), ZERO] {
            let exact = direct(Kernel::Harmonic, zeval, &zs, &g);
            let approx = l2p(z0, &l, zeval);
            assert!(
                (approx - exact).abs() / exact.abs() < 1e-11,
                "{approx:?} vs {exact:?}"
            );
        }
    }

    #[test]
    fn p2l_converges_to_direct_log() {
        let mut r = Pcg64::seed_from_u64(4);
        let z0 = C64::new(0.0, 0.0);
        let zs: Vec<C64> = (0..8)
            .map(|_| C64::new(-1.5, 2.0) + rand_c(&mut r, -0.1, 0.1))
            .collect();
        let g: Vec<C64> = (0..8)
            .map(|_| C64::real(r.uniform_in(-1.0, 1.0)))
            .collect();
        let mut l = Coeffs::zero(40);
        p2l(Kernel::Log, z0, &zs, &g, &mut l);
        let zeval = C64::new(0.15, 0.1);
        let exact = direct(Kernel::Log, zeval, &zs, &g);
        let approx = l2p(z0, &l, zeval);
        assert!((approx.re - exact.re).abs() / exact.re.abs().max(1.0) < 1e-12);
    }

    #[test]
    fn truncation_error_decays_like_ratio_pow_p() {
        // |error| ~ (r_src / d)^p for the multipole expansion: doubling p
        // should square the error ratio (geometric decay).
        let mut r = Pcg64::seed_from_u64(5);
        let z0 = ZERO;
        let zs: Vec<C64> = (0..10).map(|_| rand_c(&mut r, -0.25, 0.25)).collect();
        let g: Vec<C64> = (0..10).map(|_| rand_c(&mut r, -1.0, 1.0)).collect();
        let zeval = C64::new(1.0, 0.4); // ratio ≈ 0.35/1.08 ≈ 0.33
        let exact = direct(Kernel::Harmonic, zeval, &zs, &g);
        let mut errs = Vec::new();
        for p in [5, 10, 20] {
            let mut m = Coeffs::zero(p);
            p2m(Kernel::Harmonic, z0, &zs, &g, &mut m);
            errs.push((m2p(z0, &m, zeval) - exact).abs());
        }
        assert!(errs[1] < errs[0] * 1e-1, "{errs:?}");
        assert!(errs[2] < errs[1] * 1e-2, "{errs:?}");
    }

    #[test]
    fn l2p_horner_matches_naive() {
        let mut r = Pcg64::seed_from_u64(6);
        let p = 17;
        let b = Coeffs(
            (0..=p)
                .map(|_| rand_c(&mut r, -1.0, 1.0))
                .collect::<Vec<_>>(),
        );
        let z0 = C64::new(0.3, -0.2);
        let z = C64::new(0.5, 0.1);
        let w = z - z0;
        let naive: C64 = (0..=p).map(|j| b.0[j] * w.powi(j as i32)).sum();
        let horner = l2p(z0, &b, z);
        assert!((naive - horner).abs() < 1e-13 * naive.abs().max(1.0));
    }

    #[test]
    fn coeffs_utils() {
        let mut a = Coeffs::zero(3);
        assert!(a.is_zero());
        assert_eq!(a.order(), 3);
        let b = Coeffs(vec![ZERO, C64::real(1.0), ZERO, ZERO]);
        a.add_assign(&b);
        assert_eq!(a, b);
        a.clear();
        assert!(a.is_zero());
    }
}
