//! The three translation operators of the FMM computational phase:
//! M2M (Algorithm 3.4), L2L (Algorithm 3.5) and M2L (Algorithm 3.6).
//!
//! Each operator exists in two forms:
//!
//! * the **unscaled** form — direct accumulation with explicit powers of the
//!   shift vector (Algorithm 3.4(a) for M2M; series forms for the others),
//!   kept as the readable reference;
//! * the **scaled** form — the paper's pre-scale → *constant triangular
//!   core of pure additions* → post-scale factorization (Algorithms 3.4(b),
//!   3.5, 3.6). The triangular cores are what make the operators
//!   data-parallel-friendly: after the O(p) scaling passes, the O(p²) core
//!   touches no shift-dependent data at all. On the GPU the paper runs the
//!   core in shared memory with two threads per shift; on the TPU mapping
//!   (DESIGN.md §Hardware-Adaptation) the same core becomes a constant
//!   matrix multiplied on the MXU — see [`super::matrices`].
//!
//! **Transcription note on Algorithm 3.6.** The M2L pseudocode as printed in
//! the paper does not reproduce the M2L linear map under our (or any
//! sign-flipped) convention — we verified this symbolically by comparing the
//! map it induces on unit coefficient vectors against the Taylor-series
//! operator, over all loop-direction/order variants. We therefore derive an
//! equivalent triangular factorization from scratch: writing the scaled map
//! as `b(w) = A(1/(1+w))` in generating-function form, Horner evaluation of
//! `A` interleaves "add `â_k` to `c_0`" steps with divisions by `(1+w)`,
//! each of which is one in-place alternating-prefix pass
//! `c_j := c_j − c_{j−1}`. The result has exactly the pre-scale /
//! add-only-triangular-core / post-scale structure (and operation count)
//! of the paper's algorithm and is validated against the series form to
//! machine precision up to p = 60 in the tests below.

use super::Coeffs;
use crate::complex::{C64, ZERO};

/// Reusable scratch space for the shift operators: the drivers call the
/// shifts millions of times, so the working vectors must not be allocated
/// per call (see EXPERIMENTS.md §Perf).
#[derive(Clone, Debug, Default)]
pub struct ShiftScratch {
    buf: Vec<C64>,
    buf2: Vec<C64>,
}

impl ShiftScratch {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn zeroed(&mut self, n: usize) -> &mut [C64] {
        self.buf.clear();
        self.buf.resize(n, ZERO);
        &mut self.buf
    }

    #[inline]
    fn zeroed_pair(&mut self, n: usize) -> (&mut [C64], &mut [C64]) {
        self.buf.clear();
        self.buf.resize(n, ZERO);
        self.buf2.clear();
        self.buf2.resize(n, ZERO);
        (&mut self.buf, &mut self.buf2)
    }
}

/// M2M, unscaled (Algorithm 3.4(a) semantics): translate a multipole
/// expansion from child center `z_c` to parent center `z_p`, *accumulating*
/// into `parent`.
///
/// `a'_l = Σ_{k=1..l} C(l−1,k−1) a_k d^{l−k} − a_0 d^l/l`, `d = z_c − z_p`.
pub fn m2m_unscaled(child: &Coeffs, z_c: C64, parent: &mut Coeffs, z_p: C64) {
    let p = child.order();
    debug_assert_eq!(parent.order(), p);
    let d = z_c - z_p;
    // work in a scratch copy: triangular pass of Alg 3.4(a) with the
    // d-multiplication kept inside the core.
    let mut a = child.0.clone();
    for k in (2..=p).rev() {
        for j in k..=p {
            let prev = a[j - 1];
            a[j] += d * prev;
        }
    }
    // a_0 log-term correction and accumulation
    let a0 = child.0[0];
    let mut dl = d; // d^l
    parent.0[0] += a0;
    for l in 1..=p {
        parent.0[l] += a[l] - a0 * dl / l as f64;
        dl *= d;
    }
}

/// M2M, scaled (Algorithm 3.4(b)): identical map, factored as
/// pre-scale (`â_k = a_k/d^k`) → add-only triangular core → post-scale.
/// Requires `d ≠ 0`; the FMM never shifts by zero (child ≠ parent center
/// for non-degenerate boxes) — callers with `d = 0` must add coefficients
/// directly instead.
pub fn m2m_scaled(child: &Coeffs, z_c: C64, parent: &mut Coeffs, z_p: C64) {
    m2m_scaled_with(&child.0, z_c, &mut parent.0, z_p, &mut ShiftScratch::new())
}

/// Slice-based M2M with caller-provided scratch — the driver hot path.
pub fn m2m_scaled_with(
    child: &[C64],
    z_c: C64,
    parent: &mut [C64],
    z_p: C64,
    scratch: &mut ShiftScratch,
) {
    let p = child.len() - 1;
    debug_assert_eq!(parent.len(), p + 1);
    let d = z_c - z_p;
    debug_assert!(d.norm_sqr() > 0.0, "m2m_scaled with zero shift");
    let id = d.recip();

    // pre-scale
    let a = scratch.zeroed(p + 1);
    let mut pw = id; // d^{-k}
    for k in 1..=p {
        a[k] = child[k] * pw;
        pw *= id;
    }
    // triangular core: pure complex additions (re/im independent — the
    // property the paper exploits for two threads per shift)
    for k in (2..=p).rev() {
        for j in k..=p {
            let prev = a[j - 1];
            a[j] += prev;
        }
    }
    // post-scale + a_0 terms
    let a0 = child[0];
    parent[0] += a0;
    let mut dl = d;
    for l in 1..=p {
        parent[l] += a[l] * dl - a0 * dl / l as f64;
        dl *= d;
    }
}

/// L2L (Algorithm 3.5): translate a local expansion from parent center `z_p`
/// to child center `z_c`, accumulating into `child`.
///
/// `b'_l = Σ_{k≥l} C(k,l) b_k d^{k−l}`, `d = z_c − z_p`. Scaled form with
/// `r = z_p − z_c` exactly as printed in the paper (verified against the
/// series form).
pub fn l2l(parent: &Coeffs, z_p: C64, child: &mut Coeffs, z_c: C64) {
    l2l_with(&parent.0, z_p, &mut child.0, z_c, &mut ShiftScratch::new())
}

/// Slice-based L2L with caller-provided scratch — the driver hot path.
pub fn l2l_with(parent: &[C64], z_p: C64, child: &mut [C64], z_c: C64, scratch: &mut ShiftScratch) {
    let p = parent.len() - 1;
    debug_assert_eq!(child.len(), p + 1);
    let r = z_p - z_c;
    if r.norm_sqr() == 0.0 {
        for (c, q) in child.iter_mut().zip(parent) {
            *c += *q;
        }
        return;
    }
    // pre-scale: b̂_k = b_k r^k
    let b = scratch.zeroed(p + 1);
    let mut pw = crate::complex::ONE;
    for k in 0..=p {
        b[k] = parent[k] * pw;
        pw *= r;
    }
    // triangular core (paper lines 5–9): subtract-only passes
    for k in 0..=p {
        for j in (p - k)..p {
            let next = b[j + 1];
            b[j] -= next;
        }
    }
    // post-scale: /r^l
    let ir = r.recip();
    let mut pw = crate::complex::ONE;
    for l in 0..=p {
        child[l] += b[l] * pw;
        pw *= ir;
    }
}

/// L2L, unscaled series form (reference for cross-validation).
pub fn l2l_unscaled(parent: &Coeffs, z_p: C64, child: &mut Coeffs, z_c: C64) {
    let p = parent.order();
    let d = z_c - z_p;
    let binom = super::matrices::BinomTable::new(p + 1);
    for l in 0..=p {
        let mut acc = ZERO;
        let mut dp = crate::complex::ONE; // d^{k-l}
        for k in l..=p {
            acc += parent.0[k] * binom.c(k, l) * dp;
            dp *= d;
        }
        child.0[l] += acc;
    }
}

/// M2L (Algorithm 3.6 role): convert the multipole expansion around `z_i`
/// into a local expansion around `z_o`, accumulating into `local`.
///
/// Series: with `r = z_o − z_i`, `â_k = a_k/r^k`,
/// `b_l = (−1)^l r^{−l} Σ_{k≥1} C(k+l−1, l) â_k  +  a_0-terms`, where the
/// `a_0` terms are `b_0 += a_0 log r`, `b_l −= a_0 (−1)^l/(l r^l)`.
///
/// Implemented via the Horner/alternating-prefix factorization described in
/// the module docs: O(p) complex multiplications (scaling) + O(p²) complex
/// additions (core), the same cost signature as the paper's algorithm.
pub fn m2l(multipole: &Coeffs, z_i: C64, local: &mut Coeffs, z_o: C64) {
    m2l_with(&multipole.0, z_i, &mut local.0, z_o, &mut ShiftScratch::new())
}

/// Slice-based M2L with caller-provided scratch — the driver hot path
/// (the single most executed shift of the whole algorithm, Table 5.1).
pub fn m2l_with(
    multipole: &[C64],
    z_i: C64,
    local: &mut [C64],
    z_o: C64,
    scratch: &mut ShiftScratch,
) {
    let p = multipole.len() - 1;
    debug_assert_eq!(local.len(), p + 1);
    let r = z_o - z_i;
    debug_assert!(r.norm_sqr() > 0.0, "m2l with coincident centers");
    let ir = r.recip();

    let (ahat, c) = scratch.zeroed_pair(p + 1);

    // pre-scale: â_k = a_k / r^k
    let mut pw = ir;
    for k in 1..=p {
        ahat[k] = multipole[k] * pw;
        pw *= ir;
    }

    // Horner core: c := (c + â_k e_0) / (1 + w), divisions by (1+w) as
    // in-place alternating-prefix passes. Add-only triangular core.
    for k in (1..=p).rev() {
        c[0] += ahat[k];
        for j in 1..=p {
            let prev = c[j - 1];
            c[j] -= prev;
        }
    }

    // post-scale (+ a_0 terms): b_l += c_l / r^l
    let a0 = multipole[0];
    let has_a0 = a0 != ZERO;
    if has_a0 {
        local[0] += c[0] + a0 * r.ln();
    } else {
        local[0] += c[0];
    }
    let mut pw = ir; // r^{-l}
    let mut sign = -1.0; // (−1)^l
    for l in 1..=p {
        if has_a0 {
            local[l] += (c[l] - a0 * sign / l as f64) * pw;
        } else {
            local[l] += c[l] * pw;
        }
        pw *= ir;
        sign = -sign;
    }
}

/// M2L, unscaled series form (reference for cross-validation; O(p²)
/// multiplications — the form the paper improves upon).
pub fn m2l_unscaled(multipole: &Coeffs, z_i: C64, local: &mut Coeffs, z_o: C64) {
    let p = multipole.order();
    let r = z_o - z_i;
    let ir = r.recip();
    let binom = super::matrices::BinomTable::new(2 * p + 1);
    let irk = ir.powi_table(p); // r^{-k}
    let a0 = multipole.0[0];
    let mut sign_l = 1.0;
    let mut irl = crate::complex::ONE;
    for l in 0..=p {
        let mut acc = ZERO;
        for k in 1..=p {
            acc += multipole.0[k] * irk[k] * binom.c(k + l - 1, l);
        }
        acc = acc * irl * sign_l;
        if a0 != ZERO {
            if l == 0 {
                acc += a0 * r.ln();
            } else {
                acc -= a0 * sign_l / l as f64 * irl;
            }
        }
        local.0[l] += acc;
        sign_l = -sign_l;
        irl *= ir;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expansion::{l2p, m2p, p2m, Kernel};
    use crate::util::rng::Pcg64;

    fn rand_c(r: &mut Pcg64) -> C64 {
        C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0))
    }

    fn rand_coeffs(r: &mut Pcg64, p: usize, a0: bool) -> Coeffs {
        let mut c = Coeffs((0..=p).map(|_| rand_c(r)).collect());
        if !a0 {
            c.0[0] = ZERO;
        }
        c
    }

    #[test]
    fn m2m_scaled_matches_unscaled() {
        let mut r = Pcg64::seed_from_u64(10);
        for p in [1usize, 2, 5, 17, 40, 60] {
            let child = rand_coeffs(&mut r, p, true);
            let z_c = C64::new(0.25, 0.25);
            let z_p = C64::new(0.5, 0.5);
            let mut out_a = Coeffs::zero(p);
            let mut out_b = Coeffs::zero(p);
            m2m_unscaled(&child, z_c, &mut out_a, z_p);
            m2m_scaled(&child, z_c, &mut out_b, z_p);
            for j in 0..=p {
                let err = (out_a.0[j] - out_b.0[j]).abs();
                let scale = out_a.0[j].abs().max(1.0);
                assert!(err / scale < 1e-12, "p={p} j={j}: {err}");
            }
        }
    }

    #[test]
    fn m2m_preserves_far_field() {
        // P2M at child center, M2M to parent center, evaluate far away:
        // must equal P2M directly at parent center.
        let mut r = Pcg64::seed_from_u64(11);
        let p = 25;
        let z_c = C64::new(0.25, 0.75);
        let z_p = C64::new(0.5, 0.5);
        let zs: Vec<C64> = (0..12).map(|_| z_c + rand_c(&mut r) * 0.1).collect();
        let g: Vec<C64> = (0..12).map(|_| rand_c(&mut r)).collect();

        for kernel in [Kernel::Harmonic, Kernel::Log] {
            let mut mc = Coeffs::zero(p);
            p2m(kernel, z_c, &zs, &g, &mut mc);
            let mut mp = Coeffs::zero(p);
            m2m_scaled(&mc, z_c, &mut mp, z_p);

            let mut mp_direct = Coeffs::zero(p);
            p2m(kernel, z_p, &zs, &g, &mut mp_direct);

            let zeval = C64::new(3.0, -2.0);
            let via_shift = m2p(z_p, &mp, zeval);
            let direct = m2p(z_p, &mp_direct, zeval);
            assert!(
                (via_shift.re - direct.re).abs() < 1e-10 * direct.re.abs().max(1.0),
                "{kernel:?}"
            );
            assert!(
                (via_shift.im - direct.im).abs() < 1e-10 * direct.im.abs().max(1.0),
                "{kernel:?}"
            );
        }
    }

    #[test]
    fn m2l_matches_series_reference() {
        let mut r = Pcg64::seed_from_u64(12);
        for p in [1usize, 2, 3, 8, 17, 42, 60] {
            let m = rand_coeffs(&mut r, p, true);
            let z_i = C64::new(0.1, 0.1);
            let z_o = C64::new(1.3, -0.4);
            let mut fast = Coeffs::zero(p);
            let mut slow = Coeffs::zero(p);
            m2l(&m, z_i, &mut fast, z_o);
            m2l_unscaled(&m, z_i, &mut slow, z_o);
            for j in 0..=p {
                let err = (fast.0[j] - slow.0[j]).abs();
                let scale = slow.0[j].abs().max(1.0);
                assert!(err / scale < 1e-11, "p={p} j={j}: {err:e}");
            }
        }
    }

    #[test]
    fn m2l_converts_field_correctly() {
        // Multipole of sources near z_i, M2L to z_o (well separated),
        // evaluate local expansion near z_o: must match direct sum.
        let mut r = Pcg64::seed_from_u64(13);
        let p = 30;
        let z_i = ZERO;
        let z_o = C64::new(2.0, 1.0);
        let zs: Vec<C64> = (0..10).map(|_| rand_c(&mut r) * 0.2).collect();
        let g: Vec<C64> = (0..10).map(|_| rand_c(&mut r)).collect();

        for kernel in [Kernel::Harmonic, Kernel::Log] {
            let mut m = Coeffs::zero(p);
            p2m(kernel, z_i, &zs, &g, &mut m);
            let mut loc = Coeffs::zero(p);
            m2l(&m, z_i, &mut loc, z_o);
            let zeval = z_o + C64::new(0.15, -0.2);
            let approx = l2p(z_o, &loc, zeval);
            let exact: C64 = zs
                .iter()
                .zip(&g)
                .map(|(&s, &q)| kernel.eval(zeval, s, q))
                .sum();
            // real part: valid for both kernels; imaginary only for harmonic
            assert!(
                (approx.re - exact.re).abs() < 1e-9 * exact.re.abs().max(1.0),
                "{kernel:?}: {approx:?} vs {exact:?}"
            );
            if kernel == Kernel::Harmonic {
                assert!((approx.im - exact.im).abs() < 1e-9 * exact.im.abs().max(1.0));
            }
        }
    }

    #[test]
    fn l2l_matches_unscaled_and_preserves_values() {
        let mut r = Pcg64::seed_from_u64(14);
        for p in [1usize, 4, 17, 42] {
            let parent = rand_coeffs(&mut r, p, true);
            let z_p = C64::new(0.5, 0.5);
            let z_c = C64::new(0.3, 0.65);
            let mut a = Coeffs::zero(p);
            let mut b = Coeffs::zero(p);
            l2l(&parent, z_p, &mut a, z_c);
            l2l_unscaled(&parent, z_p, &mut b, z_c);
            for j in 0..=p {
                let err = (a.0[j] - b.0[j]).abs();
                assert!(err / b.0[j].abs().max(1.0) < 1e-11, "p={p} j={j}");
            }
            // L2L of a full-order expansion is exact: same value at a point
            // (within truncation of the re-expansion, exact for polynomials)
            let z = C64::new(0.35, 0.6);
            let v_parent = l2p(z_p, &parent, z);
            let v_child = l2p(z_c, &a, z);
            assert!(
                (v_parent - v_child).abs() < 1e-10 * v_parent.abs().max(1.0),
                "p={p}"
            );
        }
    }

    #[test]
    fn l2l_zero_shift_is_identity() {
        let mut r = Pcg64::seed_from_u64(15);
        let parent = rand_coeffs(&mut r, 9, true);
        let z = C64::new(0.1, 0.9);
        let mut out = Coeffs::zero(9);
        l2l(&parent, z, &mut out, z);
        assert_eq!(out, parent);
    }

    #[test]
    fn m2m_composition_along_tree_path() {
        // Shifting child→parent→grandparent must equal child→grandparent.
        let mut r = Pcg64::seed_from_u64(16);
        let p = 20;
        let c = rand_coeffs(&mut r, p, true);
        let z0 = C64::new(0.1, 0.2);
        let z1 = C64::new(0.4, 0.3);
        let z2 = C64::new(0.9, 0.8);
        let mut via = Coeffs::zero(p);
        let mut tmp = Coeffs::zero(p);
        m2m_scaled(&c, z0, &mut tmp, z1);
        m2m_scaled(&tmp, z1, &mut via, z2);
        let mut direct = Coeffs::zero(p);
        m2m_scaled(&c, z0, &mut direct, z2);
        for j in 0..=p {
            let err = (via.0[j] - direct.0[j]).abs();
            assert!(err / direct.0[j].abs().max(1.0) < 1e-10, "j={j}");
        }
    }

    #[test]
    fn operators_are_linear() {
        let mut r = Pcg64::seed_from_u64(17);
        let p = 12;
        let x = rand_coeffs(&mut r, p, false);
        let y = rand_coeffs(&mut r, p, false);
        let z_i = ZERO;
        let z_o = C64::new(1.5, 0.7);
        let mut xy_sum = Coeffs::zero(p);
        let mut sum_xy = Coeffs::zero(p);
        // M2L(x) + M2L(y)
        m2l(&x, z_i, &mut xy_sum, z_o);
        m2l(&y, z_i, &mut xy_sum, z_o);
        // M2L(x + y)
        let mut both = x.clone();
        both.add_assign(&y);
        m2l(&both, z_i, &mut sum_xy, z_o);
        for j in 0..=p {
            assert!((xy_sum.0[j] - sum_xy.0[j]).abs() < 1e-11);
        }
    }
}
