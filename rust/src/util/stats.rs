//! Timing statistics for the benchmark substrate.
//!
//! The paper reports that measured times showed "a surprisingly small spread";
//! we report median/mean/stddev/min so EXPERIMENTS.md can make the same
//! observation quantitatively.

/// Summary statistics over a sample of measurements (seconds, counts, …).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    /// Compute summary statistics of a sample. Empty samples yield zeros.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        };
        Self {
            n,
            mean,
            sd: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            median,
        }
    }

    /// Relative spread `sd / mean` (0 when mean is 0).
    pub fn rel_spread(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.sd / self.mean
        }
    }
}

/// Maximum relative error `max |a-b| / max(|b|, floor)` between two fields —
/// the paper's tolerance metric, Eq. (5.3), with an absolute floor to avoid
/// division by ~0 at isolated near-cancellation points.
pub fn max_rel_error(approx: &[f64], exact: &[f64], floor: f64) -> f64 {
    assert_eq!(approx.len(), exact.len());
    approx
        .iter()
        .zip(exact)
        .map(|(a, e)| (a - e).abs() / e.abs().max(floor))
        .fold(0.0, f64::max)
}

/// Simple ordinary-least-squares fit `y ≈ a + b·x`; returns `(a, b)`.
/// Used to check the paper's "optimal N_d grows ≈linearly with p" (Fig. 5.4).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let sx: f64 = x.iter().sum();
    let sy: f64 = y.iter().sum();
    let sxx: f64 = x.iter().map(|v| v * v).sum();
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-15);
        assert!((s.median - 2.5).abs() < 1e-15);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.sd - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_odd_median_and_empty() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
    }

    #[test]
    fn rel_error_metric() {
        let e = max_rel_error(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.003], 1e-30);
        assert!((e - 0.003 / 3.003).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 0.5 * v).collect();
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b - 0.5).abs() < 1e-12);
    }
}
