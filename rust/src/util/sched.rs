//! Dependency-gated task scheduling on the persistent worker pool.
//!
//! The barrier engines run every FMM phase as a global fan-out: no task of
//! phase *k+1* starts before the last task of phase *k* retires, even when
//! the two touch unrelated data (P2P vs the whole multipole chain; level
//! `l` vs level `l+1`). This module provides the runtime underneath the
//! task-graph engine ([`crate::fmm::taskgraph`]) that removes those
//! barriers: a [`Graph`] of **nodes** (one per phase×level shard group)
//! connected by dependency edges, executed by pool workers draining a
//! **ready queue** gated on per-node counters.
//!
//! Protocol (all counter updates under **one** mutex, the reduction the
//! model check in `tests/pool_model.rs` verifies):
//!
//! * `pending[n]` — dependency nodes of `n` not yet complete. When it
//!   reaches zero the node becomes *ready*: its tasks are pushed onto the
//!   shared ready queue (a node with no tasks completes immediately and
//!   cascades).
//! * `unfinished[n]` — tasks of `n` not yet retired. A worker pops a
//!   `(node, task)` pair, claims the task closure from its one-shot slot,
//!   runs it **outside** the lock, then decrements; reaching zero
//!   completes the node, decrements every successor's `pending`, and
//!   wakes the waiters.
//! * Termination: `nodes_remaining == 0`. Deadlock freedom is structural —
//!   [`Graph::node`] only accepts already-created nodes as dependencies,
//!   so the graph is acyclic by construction, and an acyclic graph always
//!   has a ready task while incomplete nodes remain and nothing is in
//!   flight.
//!
//! **Determinism**: the scheduler adds no nondeterminism to *results*.
//! Every task owns a disjoint `&mut` destination range (writer-side
//! ownership, enforced at runtime by [`crate::util::pool::RangedBuf`]) and
//! every cross-task reduction is folded in fixed task order by a
//! *consumer* task, so any dependency-respecting execution order produces
//! bitwise-identical output. The schedule-fuzz suite
//! (`tests/taskgraph_parity.rs`) drives this with [`Jitter`]: seeded
//! per-worker busy-wait pauses before every claim perturb the schedule
//! without touching the arithmetic.
//!
//! Workers are the pool's own threads — [`Graph::run`] issues a single
//! [`WorkerPool::run_tasks`] fan-out of drain loops, so a whole evaluation
//! is **one** pool epoch and spawns nothing. Called *from* a pool worker
//! (nested use, e.g. the batch runner), the fan-out degrades to inline
//! execution and the first drain loop retires the entire graph serially.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::util::pool::{WorkerPool, WorkerScratch};

/// Handle to a node created by [`Graph::node`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(usize);

type Task<'g> = Box<dyn FnOnce(&mut WorkerScratch) + Send + 'g>;

struct Node<'g> {
    /// Dependency node indices, sorted and deduplicated (all `< self`).
    deps: Vec<usize>,
    tasks: Vec<Task<'g>>,
}

/// A dependency graph of tasks, built once and consumed by [`Graph::run`].
/// Task closures may borrow the caller's stack (`'g`): `run` blocks until
/// every task has retired, which is the lifetime barrier.
#[derive(Default)]
pub struct Graph<'g> {
    nodes: Vec<Node<'g>>,
}

impl<'g> Graph<'g> {
    pub fn new() -> Self {
        Graph::default()
    }

    /// Create a node depending on `deps`. Dependencies must already exist —
    /// which is also what makes every graph acyclic by construction.
    pub fn node(&mut self, deps: &[NodeId]) -> NodeId {
        let mut ds: Vec<usize> = deps
            .iter()
            .map(|d| {
                assert!(d.0 < self.nodes.len(), "dependency on a node created later");
                d.0
            })
            .collect();
        ds.sort_unstable();
        ds.dedup();
        let id = self.nodes.len();
        self.nodes.push(Node {
            deps: ds,
            tasks: Vec::new(),
        });
        NodeId(id)
    }

    /// Attach a task to `n`. Tasks of one node may run concurrently with
    /// each other (and with tasks of any dependency-unrelated node) — the
    /// caller guarantees they own disjoint destinations.
    pub fn add_task(&mut self, n: NodeId, f: impl FnOnce(&mut WorkerScratch) + Send + 'g) {
        self.nodes[n.0].tasks.push(Box::new(f));
    }

    /// Number of nodes created so far.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of tasks attached so far.
    pub fn n_tasks(&self) -> usize {
        self.nodes.iter().map(|n| n.tasks.len()).sum()
    }

    /// Execute the graph on `width` pool workers (clamped to `1..=` pool
    /// width by the pool itself) and block until every task has retired.
    /// `jitter` injects seeded schedule noise for the fuzz suites — `None`
    /// in production.
    pub fn run(self, pool: &WorkerPool, width: usize, jitter: Option<Jitter>) {
        let n = self.nodes.len();
        if n == 0 {
            return;
        }
        // Flight-recorder support (zero cost unless tracing is armed): keep
        // the dependency lists, time each task, and reduce to the graph's
        // critical path afterwards. The tracing decision is latched here so
        // a mid-run toggle cannot tear the bookkeeping.
        let tracing = crate::obs::enabled();
        let dep_lists: Vec<Vec<usize>> = if tracing {
            self.nodes.iter().map(|nd| nd.deps.clone()).collect()
        } else {
            Vec::new()
        };
        let n_tasks = self.n_tasks();
        let t_run = tracing.then(std::time::Instant::now);
        // Per node: the longest single task (ns) — with unbounded workers a
        // node completes after its slowest task, so these are the critical
        // path's node weights.
        let node_max_v: Vec<AtomicU64> = (0..if tracing { n } else { 0 })
            .map(|_| AtomicU64::new(0))
            .collect();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut pending = vec![0usize; n];
        for (i, nd) in self.nodes.iter().enumerate() {
            pending[i] = nd.deps.len();
            for &d in &nd.deps {
                succs[d].push(i);
            }
        }
        let slots: Vec<Vec<Mutex<Option<Task<'g>>>>> = self
            .nodes
            .into_iter()
            .map(|nd| nd.tasks.into_iter().map(|t| Mutex::new(Some(t))).collect())
            .collect();
        let mut st = RunState {
            ready: VecDeque::new(),
            pending,
            unfinished: slots.iter().map(|s| s.len()).collect(),
            nodes_remaining: n,
            poisoned: false,
        };
        // Seed the ready queue with the dependency-free nodes (task-less
        // roots complete immediately and cascade into their successors).
        for i in 0..n {
            if st.pending[i] == 0 {
                if st.unfinished[i] == 0 {
                    complete_node(&mut st, &succs, i);
                } else {
                    enqueue_tasks(&mut st, i);
                }
            }
        }
        let sync = (Mutex::new(st), Condvar::new());
        let width = width.max(1);
        let (slots, succs, sync) = (&slots, &succs, &sync);
        let node_max: &[AtomicU64] = &node_max_v;
        pool.run_tasks((0..width).collect::<Vec<usize>>(), move |w, _t, ws| {
            drain(slots, succs, sync, node_max, jitter.map(|j| j.for_worker(w)), ws);
        });
        if let Some(t0) = t_run {
            let wall = t0.elapsed().as_secs_f64();
            // Longest path through the DAG: dependencies always precede
            // their dependents in index order (enforced by `node`), so one
            // forward sweep computes every earliest finish.
            let mut ef = vec![0u64; n];
            let mut cp = 0u64;
            for i in 0..n {
                let start = dep_lists[i].iter().map(|&d| ef[d]).max().unwrap_or(0);
                ef[i] = start.saturating_add(node_max_v[i].load(Ordering::Relaxed));
                cp = cp.max(ef[i]);
            }
            crate::obs::event(
                "taskgraph",
                "critical_path",
                &[
                    ("critical_path_s", cp as f64 * 1e-9),
                    ("wall_s", wall),
                    ("nodes", n as f64),
                    ("tasks", n_tasks as f64),
                ],
            );
        }
    }
}

struct RunState {
    /// Claimable `(node, task)` pairs; every pair is enqueued exactly once
    /// (when its node's last dependency completes).
    ready: VecDeque<(usize, usize)>,
    /// Per node: dependency nodes not yet complete.
    pending: Vec<usize>,
    /// Per node: tasks not yet retired.
    unfinished: Vec<usize>,
    /// Nodes not yet complete; `0` terminates every drain loop.
    nodes_remaining: usize,
    /// A task panicked: abandon the run (the catching worker re-raises,
    /// and the pool re-raises to the submitting caller).
    poisoned: bool,
}

fn enqueue_tasks(st: &mut RunState, i: usize) {
    for t in 0..st.unfinished[i] {
        st.ready.push_back((i, t));
    }
}

/// Called under the lock when node `i` retires its last task (or is a
/// task-less node whose last dependency completed): cascade completion
/// into the successors.
fn complete_node(st: &mut RunState, succs: &[Vec<usize>], i: usize) {
    let mut done = vec![i];
    while let Some(d) = done.pop() {
        st.nodes_remaining -= 1;
        for &s in &succs[d] {
            st.pending[s] -= 1;
            if st.pending[s] == 0 {
                if st.unfinished[s] == 0 {
                    done.push(s);
                } else {
                    enqueue_tasks(st, s);
                }
            }
        }
    }
}

type Sync_<'g> = (Mutex<RunState>, Condvar);

fn drain<'g>(
    slots: &[Vec<Mutex<Option<Task<'g>>>>],
    succs: &[Vec<usize>],
    sync: &Sync_<'g>,
    node_max: &[AtomicU64],
    mut jitter: Option<JitterState>,
    ws: &mut WorkerScratch,
) {
    let (mx, cv) = sync;
    loop {
        if let Some(j) = jitter.as_mut() {
            j.pause();
        }
        let (i, t) = {
            let mut st = mx.lock().unwrap();
            loop {
                if st.poisoned || st.nodes_remaining == 0 {
                    return;
                }
                if let Some(pair) = st.ready.pop_front() {
                    break pair;
                }
                st = cv.wait(st).unwrap();
            }
        };
        let task = slots[i][t]
            .lock()
            .unwrap()
            .take()
            .expect("each (node, task) pair is enqueued exactly once");
        // A panicking task must not leave the other drain loops waiting on
        // a node that will never complete: poison the run, wake everyone,
        // re-raise (the pool forwards the payload to the caller).
        let t_task = (!node_max.is_empty()).then(std::time::Instant::now);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(ws)));
        if let Some(t0) = t_task {
            node_max[i].fetch_max(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        let mut st = mx.lock().unwrap();
        match result {
            Ok(()) => {
                st.unfinished[i] -= 1;
                if st.unfinished[i] == 0 {
                    complete_node(&mut st, succs, i);
                    cv.notify_all();
                }
            }
            Err(p) => {
                st.poisoned = true;
                cv.notify_all();
                drop(st);
                std::panic::resume_unwind(p);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Schedule fuzzing
// ---------------------------------------------------------------------------

/// Seeded schedule noise: every worker busy-waits a pseudorandom
/// `0..max_ns` nanoseconds before each claim attempt, perturbing claim
/// order and wakeup interleavings without touching any arithmetic. Used by
/// `tests/taskgraph_parity.rs` to fuzz schedules that must all produce
/// bitwise-identical results.
#[derive(Clone, Copy, Debug)]
pub struct Jitter {
    pub seed: u64,
    pub max_ns: u64,
}

impl Jitter {
    fn for_worker(self, w: usize) -> JitterState {
        JitterState {
            s: self.seed ^ (w as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            max_ns: self.max_ns,
        }
    }
}

struct JitterState {
    s: u64,
    max_ns: u64,
}

impl JitterState {
    /// One splitmix64 step → busy-wait below `max_ns`.
    fn pause(&mut self) {
        if self.max_ns == 0 {
            return;
        }
        self.s = self.s.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        let ns = (z ^ (z >> 31)) % self.max_ns;
        let t = std::time::Instant::now();
        while (t.elapsed().as_nanos() as u64) < ns {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Record the completion order of nodes via a shared log.
    fn log_task<'g>(
        log: &'g Mutex<Vec<usize>>,
        tag: usize,
    ) -> impl FnOnce(&mut WorkerScratch) + Send + 'g {
        move |_ws| log.lock().unwrap().push(tag)
    }

    #[test]
    fn diamond_respects_dependencies() {
        let pool = WorkerPool::new(4, false);
        for seed in 0..20u64 {
            let log = Mutex::new(Vec::new());
            let mut g = Graph::new();
            let a = g.node(&[]);
            let b = g.node(&[a]);
            let c = g.node(&[a]);
            let d = g.node(&[b, c]);
            g.add_task(a, log_task(&log, 0));
            g.add_task(b, log_task(&log, 1));
            g.add_task(c, log_task(&log, 2));
            g.add_task(d, log_task(&log, 3));
            g.run(
                &pool,
                4,
                Some(Jitter {
                    seed,
                    max_ns: 20_000,
                }),
            );
            let order = log.into_inner().unwrap();
            assert_eq!(order.len(), 4);
            let pos = |x: usize| order.iter().position(|&v| v == x).unwrap();
            assert!(pos(0) < pos(1) && pos(0) < pos(2), "{order:?}");
            assert!(pos(3) > pos(1) && pos(3) > pos(2), "{order:?}");
        }
    }

    #[test]
    fn empty_nodes_cascade() {
        let pool = WorkerPool::new(2, false);
        let hits = AtomicUsize::new(0);
        let mut g = Graph::new();
        let root = g.node(&[]); // no tasks
        let mid = g.node(&[root]); // no tasks
        let leaf = g.node(&[mid]);
        g.add_task(leaf, |_ws| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        g.run(&pool, 2, None);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        // a fully empty graph terminates too
        Graph::new().run(&pool, 2, None);
        let mut g = Graph::new();
        g.node(&[]);
        g.run(&pool, 2, None);
    }

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = WorkerPool::new(3, false);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let mut g = Graph::new();
        let a = g.node(&[]);
        let b = g.node(&[a]);
        for k in 0..32 {
            let h = &hits[k];
            g.add_task(a, move |_ws| {
                h.fetch_add(1, Ordering::Relaxed);
            });
            let h = &hits[32 + k];
            g.add_task(b, move |_ws| {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        g.run(&pool, 3, Some(Jitter { seed: 7, max_ns: 5_000 }));
        for (k, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {k}");
        }
    }

    #[test]
    fn independent_chains_can_interleave() {
        // two independent chains; completion counters see both advance —
        // structural smoke test that nothing serializes the whole graph
        let pool = WorkerPool::new(2, false);
        let done = AtomicUsize::new(0);
        let mut g = Graph::new();
        let mut prev: Option<NodeId> = None;
        for _ in 0..5 {
            let deps: Vec<NodeId> = prev.into_iter().collect();
            let n = g.node(&deps);
            g.add_task(n, |_ws| {
                done.fetch_add(1, Ordering::Relaxed);
            });
            prev = Some(n);
        }
        let solo = g.node(&[]);
        g.add_task(solo, |_ws| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        g.run(&pool, 2, None);
        assert_eq!(done.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn nested_run_from_a_pool_worker_degrades_inline() {
        let pool = std::sync::Arc::new(WorkerPool::new(2, false));
        let p2 = std::sync::Arc::clone(&pool);
        let total = AtomicUsize::new(0);
        pool.run_tasks(vec![(); 2], |_k, (), _ws| {
            let mut g = Graph::new();
            let a = g.node(&[]);
            let b = g.node(&[a]);
            g.add_task(a, |_ws| {
                total.fetch_add(1, Ordering::Relaxed);
            });
            g.add_task(b, |_ws| {
                total.fetch_add(10, Ordering::Relaxed);
            });
            g.run(&p2, 2, None);
        });
        assert_eq!(total.load(Ordering::Relaxed), 22);
    }

    #[test]
    fn task_panic_propagates_without_wedging() {
        let pool = WorkerPool::new(3, false);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Graph::new();
            let a = g.node(&[]);
            let b = g.node(&[a]);
            g.add_task(a, |_ws| panic!("graph task boom"));
            g.add_task(b, |_ws| {});
            g.run(&pool, 3, None);
        }));
        assert!(caught.is_err(), "caller must observe the task panic");
        // the pool (and a fresh graph) still work afterwards
        let ok = AtomicUsize::new(0);
        let mut g = Graph::new();
        let a = g.node(&[]);
        g.add_task(a, |_ws| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        g.run(&pool, 3, None);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }
}
