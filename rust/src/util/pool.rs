//! The persistent, affinity-aware worker pool — the execution resource of
//! every multicore path in the crate (DESIGN.md §6).
//!
//! The scoped engines (PR 1–3) spawn and join a fresh `std::thread::scope`
//! per *phase*: eight phases per evaluation, plus per-level scopes in Sort
//! and per-group scopes in the batch runner. The paper's Table 5.1 makes
//! per-phase dispatch overhead a first-class cost, and spawn/join noise is
//! exactly what a calibrated CPU-vs-GPU dispatch decision must not see.
//! [`WorkerPool`] replaces all of that with `n` long-lived threads that
//! *park between tasks*: a [`WorkerPool::run_tasks`] fan-out wakes them,
//! every worker runs its statically assigned tasks, and the caller blocks
//! until the whole fan-out has finished (a scoped API — task closures may
//! freely borrow the caller's stack).
//!
//! Invariants preserved from the scoped engines:
//!
//! * **Writer-side ownership** — a task owns a disjoint `&mut` slice of the
//!   destination data ([`WorkerPool::run_chunks_mut`]); kernels take no
//!   locks (the only locks are the one-shot task-claim `Mutex<Option<T>>`
//!   takes at fan-out boundaries).
//! * **Sticky worker identity** — task `k` always runs on worker
//!   `k % n_workers`, and every worker owns a [`WorkerScratch`] allocated
//!   once for the worker's lifetime (`ShiftScratch`/`M2lScratch` reused
//!   across phases, problems and batches, not re-created per phase), so
//!   repeated fan-outs of the same shape touch the same caches. The
//!   symmetric-P2P accumulators live in pool-owned [`Accum`] buffers
//!   ([`WorkerPool::take_accums`]) with the same task-index stickiness.
//! * **Determinism** — static task→worker assignment keeps every reduction
//!   in *task* order, so results are independent of OS scheduling and
//!   bitwise-reproducible for a fixed worker count (asserted against the
//!   scoped engine by `tests/pool_parity.rs`).
//!
//! Affinity: with `pin = true` (CLI `--pin`, [`crate::fmm::FmmOptions::pin`])
//! worker `i` pins itself to core `i` via `sched_setaffinity` on Linux —
//! best-effort (failures are ignored) and a no-op elsewhere.
//!
//! The module also owns the crate's **spawn accounting**: every thread
//! spawn anywhere in the crate calls [`note_spawn`], and
//! `tests/zero_spawn.rs` asserts that a full `evaluate` performs *zero*
//! spawns once the pool exists.

// This module owns the only `unsafe` in the crate (enforced by
// `cargo xtask lint`); unsafe operations inside unsafe fns still need
// explicit blocks so each one carries its own SAFETY argument.
#![deny(unsafe_op_in_unsafe_fn)]

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::expansion::matrices::M2lScratch;
use crate::expansion::shifts::ShiftScratch;
use crate::util::threadpool::split_lengths_mut;

// ---------------------------------------------------------------------------
// Spawn accounting (test hook)
// ---------------------------------------------------------------------------

static SPAWN_COUNT: AtomicUsize = AtomicUsize::new(0);

/// Record one thread spawn. Called by **every** spawn site in the crate
/// (pool worker construction, the scoped reference engines, batch topology
/// producers), so tests can assert that a code path spawns no threads.
#[inline]
pub fn note_spawn() {
    SPAWN_COUNT.fetch_add(1, Ordering::Relaxed);
}

/// Total thread spawns recorded so far, process-wide.
pub fn spawn_count() -> usize {
    SPAWN_COUNT.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Per-worker state
// ---------------------------------------------------------------------------

/// Per-worker scratch, allocated once per worker thread and handed `&mut`
/// to every task it runs — the shift-operator and M2L working vectors are
/// reused across phases, levels, problems and batches instead of being
/// re-created per phase closure.
#[derive(Default)]
pub struct WorkerScratch {
    pub shift: ShiftScratch,
    pub m2l: M2lScratch,
}

/// One persistent symmetric-P2P accumulator pair (`Φ` real/imag parts over
/// all particles). Owned by the pool and leased to the P2P phase via
/// [`WorkerPool::take_accums`], so the `O(threads × N)` buffers are
/// allocated once per pool, not once per evaluation.
#[derive(Default)]
pub struct Accum {
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

impl Accum {
    /// Zero the accumulator for `n` particles, reusing capacity — but not
    /// unconditionally: a buffer whose retained high-water mark dwarfs the
    /// request is released first, so one huge evaluation on a long-lived
    /// (e.g. process-global) pool does not pin `O(workers × max-N)` memory
    /// forever once the workload moves back to small problems.
    pub fn reset(&mut self, n: usize) {
        self.prepare(n);
        self.re.fill(0.0);
        self.im.fill(0.0);
    }

    /// The retention/sizing half of [`Accum::reset`] without the
    /// zero-fill: the task-graph engine applies the trim policy and sizes
    /// the buffers on the caller, then zeroes them *inside* the P2P tasks
    /// so the `O(workers × N)` memset runs in parallel. Values are
    /// identical to `reset` once the task-side fill has run.
    pub fn prepare(&mut self, n: usize) {
        const SLACK: usize = 4;
        const KEEP_BELOW: usize = 1 << 16; // ≤ 512 KiB per vec: always keep
        if self.re.capacity() > SLACK * n.max(KEEP_BELOW) {
            self.re = Vec::new();
            self.im = Vec::new();
        }
        self.re.resize(n, 0.0);
        self.im.resize(n, 0.0);
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// A type-erased fan-out job: a pointer to the caller's closure plus its
/// monomorphized trampoline. Only ever alive while the submitting
/// [`WorkerPool::broadcast`] call blocks, which is what makes the borrow
/// sound (the closure and everything it captures outlive the job).
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    // SAFETY: `call` may only be invoked with the `data` it was paired
    // with at construction (`call_erased::<F>` alongside a `*const F`),
    // while the erased closure is still alive — both upheld because jobs
    // never outlive the `broadcast` call that builds them.
    call: unsafe fn(*const (), usize, &mut WorkerScratch),
}

// SAFETY: the job pointer crosses threads, but `broadcast` does not return
// until every worker is done with it, and the pointee is `Sync` (enforced
// by the `F: Sync` bound at the only construction site).
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per fan-out; workers run the job exactly once per epoch.
    epoch: u64,
    job: Option<Job>,
    /// The first `participants` workers take part in the current epoch —
    /// a fan-out capped below the pool width wakes only the workers it
    /// needs, so per-phase dispatch cost scales with the *requested*
    /// parallelism, not the machine width.
    participants: usize,
    /// Participating workers still running the current epoch's job.
    active: usize,
    /// Workers whose job closure panicked this epoch (re-raised by the
    /// caller; the worker itself survives and keeps serving).
    panicked: usize,
    /// First panic payload of the epoch, resumed in the submitting caller
    /// so the original message/location is preserved.
    payload: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// The submitting caller waits here for `active == 0`. (Workers wait
    /// via `thread::park`, woken individually by `unpark` — see
    /// `WorkerPool::broadcast`.)
    done_cv: Condvar,
    /// Live worker threads of *this* pool (shutdown test hook).
    live: AtomicUsize,
}

thread_local! {
    static ON_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// `true` when the current thread is a pool worker — fan-out entry points
/// degrade to inline execution instead of deadlocking on their own pool.
fn on_pool_worker() -> bool {
    ON_POOL_WORKER.with(|f| f.get())
}

/// The persistent worker pool. See the module docs for the execution model
/// and invariants; construction spawns the workers once, [`Drop`] parks
/// none — it signals shutdown and joins them all.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Thread handles for targeted `unpark` wake-ups, worker order.
    workers: Vec<std::thread::Thread>,
    /// Serializes concurrent fan-outs from different caller threads (the
    /// batch runner's producers and consumer may share one pool).
    run_lock: Mutex<()>,
    /// Persistent symmetric-P2P accumulators, `n_workers` of them.
    accums: Mutex<Vec<Accum>>,
    n_workers: usize,
    pinned: bool,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.n_workers)
            .field("pinned", &self.pinned)
            .finish()
    }
}

impl WorkerPool {
    /// Spawn a pool of `threads` parked workers (clamped to `1..=256`).
    /// With `pin`, worker `i` pins itself to core `i mod cores`
    /// (best-effort, Linux only).
    pub fn new(threads: usize, pin: bool) -> Self {
        let n = threads.clamp(1, 256);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                participants: 0,
                active: 0,
                panicked: 0,
                payload: None,
                shutdown: false,
            }),
            done_cv: Condvar::new(),
            live: AtomicUsize::new(0),
        });
        let handles: Vec<JoinHandle<()>> = (0..n)
            .map(|id| {
                note_spawn();
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fmm2d-pool-{id}"))
                    .spawn(move || worker_loop(&shared, id, pin))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        let workers = handles.iter().map(|h| h.thread().clone()).collect();
        WorkerPool {
            shared,
            handles,
            workers,
            run_lock: Mutex::new(()),
            accums: Mutex::new(Vec::new()),
            n_workers: n,
            pinned: pin,
        }
    }

    /// Number of worker threads.
    #[inline]
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Whether workers were asked to pin themselves to cores.
    #[inline]
    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// Run `f(worker_id, scratch)` once on each of the first `limit`
    /// workers and block until all have finished. The closure may borrow
    /// the caller's stack freely — this call is the lifetime barrier.
    /// Only the participating workers are woken (`unpark` per worker), so
    /// a fan-out capped below the pool width costs the capped amount.
    fn broadcast<F>(&self, limit: usize, f: F)
    where
        F: Fn(usize, &mut WorkerScratch) + Sync,
    {
        /// Monomorphized trampoline recovering `F` from the erased pointer.
        ///
        /// SAFETY: callers must pass the `data` pointer this trampoline was
        /// paired with, while the erased closure is still alive.
        unsafe fn call_erased<F>(data: *const (), id: usize, ws: &mut WorkerScratch)
        where
            F: Fn(usize, &mut WorkerScratch) + Sync,
        {
            // SAFETY: `data` is the `&f` erased in `broadcast` below, which
            // blocks until every worker has finished this epoch, so the
            // closure is alive; `F: Sync` makes concurrent calls sound.
            unsafe { (*(data as *const F))(id, ws) }
        }

        let participants = limit.clamp(1, self.n_workers);
        let guard = self.run_lock.lock().unwrap();
        let job = Job {
            data: &f as *const F as *const (),
            call: call_erased::<F>,
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert_eq!(st.active, 0, "fan-out submitted while one is running");
            st.job = Some(job);
            st.epoch += 1;
            st.participants = participants;
            st.active = participants;
        }
        // `unpark` is sticky: a worker that checks the state after this
        // and then parks consumes the pending token immediately, so there
        // is no lost-wakeup window.
        for w in &self.workers[..participants] {
            w.unpark();
        }
        let (panicked, payload) = {
            let mut st = self.shared.state.lock().unwrap();
            while st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            (std::mem::take(&mut st.panicked), st.payload.take())
        };
        drop(guard);
        if let Some(p) = payload {
            // re-raise the first worker panic with its original payload
            std::panic::resume_unwind(p);
        }
        assert_eq!(panicked, 0, "{panicked} pool worker task(s) panicked");
    }

    /// Fan `tasks` out over the workers with **static assignment** (task
    /// `k` → worker `k % n_workers`, each worker in ascending `k`) and
    /// block until all are done. Static assignment is what keeps
    /// reductions in task order — deterministic for a fixed worker count —
    /// and task↔worker cache affinity stable across repeated fan-outs.
    ///
    /// Called from a pool worker (nested use), runs everything inline.
    pub fn run_tasks<T, F>(&self, tasks: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(usize, T, &mut WorkerScratch) + Sync,
    {
        if tasks.is_empty() {
            return;
        }
        if on_pool_worker() {
            let mut ws = WorkerScratch::default();
            for (k, t) in tasks.into_iter().enumerate() {
                f(k, t, &mut ws);
            }
            return;
        }
        let nw = self.n_workers;
        let slots: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        // task k runs on worker k % nw, so only the first min(tasks, nw)
        // workers participate — the rest stay parked
        let participants = slots.len().min(nw);
        let slots = &slots;
        let f = &f;
        self.broadcast(participants, move |w, ws| {
            let mut k = w;
            while k < slots.len() {
                let t = slots[k]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("each task is claimed exactly once");
                f(k, t, ws);
                k += nw;
            }
        });
    }

    /// Like [`WorkerPool::run_tasks`] but with **dynamic claiming**: up to
    /// `limit` idle workers take the next unclaimed task off a shared
    /// counter (workers beyond the limit return immediately — callers with
    /// a thread budget below the pool width stay within it). Use when
    /// per-task cost varies a lot (whole heterogeneous problems in a batch
    /// group) and each task's result is order-independent.
    pub fn run_dynamic<T, F>(&self, tasks: Vec<T>, limit: usize, f: F)
    where
        T: Send,
        F: Fn(usize, T, &mut WorkerScratch) + Sync,
    {
        if tasks.is_empty() {
            return;
        }
        if limit == 0 || on_pool_worker() {
            let mut ws = WorkerScratch::default();
            for (k, t) in tasks.into_iter().enumerate() {
                f(k, t, &mut ws);
            }
            return;
        }
        let slots: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let participants = limit.min(slots.len()).min(self.n_workers);
        let next = AtomicUsize::new(0);
        let (slots, next, f) = (&slots, &next, &f);
        self.broadcast(participants, move |_w, ws| loop {
            let k = next.fetch_add(1, Ordering::Relaxed);
            if k >= slots.len() {
                break;
            }
            let t = slots[k]
                .lock()
                .unwrap()
                .take()
                .expect("each task is claimed exactly once");
            f(k, t, ws);
        });
    }

    /// The writer-side sharding primitive (pool analog of
    /// `threadpool::scoped_chunks_mut`): run `f(range, chunk, scratch)` for
    /// every range, where `chunk` is the disjoint destination slice
    /// `data[range.start*stride .. range.end*stride]`. `ranges` must tile
    /// `0..data.len()/stride`.
    pub fn run_chunks_mut<T, F>(&self, data: &mut [T], stride: usize, ranges: &[Range<usize>], f: F)
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T], &mut WorkerScratch) + Sync,
    {
        let lens: Vec<usize> = ranges.iter().map(|r| (r.end - r.start) * stride).collect();
        let chunks = split_lengths_mut(data, &lens);
        let tasks: Vec<(Range<usize>, &mut [T])> = ranges.iter().cloned().zip(chunks).collect();
        self.run_tasks(tasks, |_k, (r, chunk), ws| f(r, chunk, ws));
    }

    /// Pool analog of `threadpool::scoped_map`: apply `f` to every item on
    /// the workers and collect the results in item order.
    pub fn map_items<I, O, F>(&self, items: Vec<I>, f: F) -> Vec<O>
    where
        I: Send,
        O: Send,
        F: Fn(I) -> O + Sync,
    {
        let n = items.len();
        let out: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
        {
            let (out, f) = (&out, &f);
            self.run_tasks(items, move |k, item, _ws| {
                *out[k].lock().unwrap() = Some(f(item));
            });
        }
        out.into_iter()
            .map(|m| m.into_inner().unwrap().expect("every task ran"))
            .collect()
    }

    /// Lease `n_workers` persistent symmetric-P2P accumulators from the
    /// pool's free list (topped up with fresh ones when concurrent
    /// evaluations hold the stored sets). Callers [`Accum::reset`] the
    /// ones they use and give them back via [`WorkerPool::return_accums`]
    /// so subsequent evaluations reuse the allocations.
    pub fn take_accums(&self) -> Vec<Accum> {
        let mut out = {
            let mut g = self.accums.lock().unwrap();
            let keep = g.len().saturating_sub(self.n_workers);
            g.split_off(keep)
        };
        while out.len() < self.n_workers {
            out.push(Accum::default());
        }
        out
    }

    /// Return leased accumulators to the pool's free list. Concurrent
    /// leases *extend* the list rather than replacing it (nothing is
    /// silently dropped); retention is bounded to two lease-sets — beyond
    /// steady-state concurrency the excess is freed. A lease lost to a
    /// panic is not a memory leak (the `Vec`s drop with it), merely a
    /// forfeited reuse: the next lease tops up with fresh buffers.
    pub fn return_accums(&self, accums: Vec<Accum>) {
        let mut g = self.accums.lock().unwrap();
        g.extend(accums);
        let cap = 2 * self.n_workers;
        if g.len() > cap {
            let excess = g.len() - cap;
            g.drain(..excess);
        }
    }

    /// Signal shutdown and join all workers (what [`Drop`] does).
    fn shutdown_inner(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        for w in &self.workers {
            w.unpark();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Tear the pool down (signal + join) and report how many of its
    /// workers are still alive — `0` on a clean shutdown. Test hook for
    /// the drop-then-rebuild contract (`tests/pool_parity.rs`).
    pub fn shutdown_and_count(mut self) -> usize {
        self.shutdown_inner();
        self.shared.live.load(Ordering::SeqCst)
        // Drop runs again on `self` but is idempotent: handles are drained.
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(shared: &Shared, id: usize, pin: bool) {
    shared.live.fetch_add(1, Ordering::SeqCst);
    ON_POOL_WORKER.with(|f| f.set(true));
    if pin {
        pin_current_thread(id);
    }
    let mut scratch = WorkerScratch::default();
    let mut seen = 0u64;
    loop {
        // Wait parked until this worker participates in a new epoch (or
        // shutdown). Spurious `park` returns just re-check the state; a
        // worker skipped by several capped fan-outs catches up on the
        // epoch counter without running their (long gone) jobs.
        let job = loop {
            let st = shared.state.lock().unwrap();
            if st.shutdown {
                drop(st);
                shared.live.fetch_sub(1, Ordering::SeqCst);
                return;
            }
            if st.epoch != seen {
                seen = st.epoch;
                if id < st.participants {
                    break st.job.expect("epoch bumped with a job installed");
                }
            }
            drop(st);
            std::thread::park();
        };
        // A panicking task must not wedge the pool: catch it, finish the
        // epoch, and let the submitting caller re-raise.
        let sp = crate::obs::span("worker", "job");
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Deterministic fault injection for the serve chaos suite: a
            // worker dying mid-task (`failpoints` builds only). Inside the
            // catch so it rides the normal panic-recovery path.
            #[cfg(feature = "failpoints")]
            if crate::util::failpoint::fire("pool-worker") {
                panic!("failpoint: pool-worker");
            }
            // SAFETY: the job was installed by the `broadcast` call that is
            // still blocked on this epoch, so `job.data` points at its live
            // closure and `job.call` is the matching monomorphized
            // trampoline.
            unsafe { (job.call)(job.data, id, &mut scratch) }
        }));
        drop(sp);
        let mut st = shared.state.lock().unwrap();
        if let Err(p) = result {
            st.panicked += 1;
            if st.payload.is_none() {
                st.payload = Some(p);
            }
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// Range-checked shared buffers (task-graph support)
// ---------------------------------------------------------------------------

/// A shared buffer handing out **range-scoped** borrows checked at
/// runtime. The task-graph engine ([`crate::fmm::taskgraph`]) runs tasks
/// of *different phases* concurrently: one task writes a disjoint chunk of
/// a destination buffer while tasks of another node read the whole buffer
/// one level up — a borrow structure the compile-time checker cannot
/// express when the set of live borrows is decided by a dependency graph
/// resolved at runtime. `RangedBuf` enforces the aliasing rules
/// dynamically instead: a mutex-guarded ledger of active borrows rejects
/// (panics on) any overlap involving a writer, which is exactly what makes
/// the raw-pointer slices handed out sound. The scheduler's dependency
/// edges make rejections unreachable in the engine; the ledger is the
/// armed proof obligation, not a hot-path cost (one lock per *task*, not
/// per element).
///
/// The type lives here — not next to its only consumer — because this
/// module is the crate's sanctioned home for `unsafe` (see the module
/// docs; enforced by `cargo xtask lint`).
pub struct RangedBuf<T> {
    /// Owns the allocation. Elements are only ever touched through `base`;
    /// the cell is read again only by `into_inner(self)`, when no guard
    /// can be alive.
    data: std::cell::UnsafeCell<Vec<T>>,
    /// Base pointer of the allocation, captured at construction. The
    /// vector is never grown or shrunk afterwards (no such API exists on
    /// `RangedBuf`), so the pointer stays valid for the buffer's lifetime.
    base: *mut T,
    len: usize,
    ledger: Mutex<Ledger>,
}

#[derive(Default)]
struct Ledger {
    next: u64,
    /// Active borrows: `(guard id, element range, exclusive?)`.
    active: Vec<(u64, Range<usize>, bool)>,
}

// SAFETY: moving a `RangedBuf` between threads moves the owned `Vec<T>`
// plus a pointer into its (heap) allocation; sound whenever `T: Send`.
unsafe impl<T: Send> Send for RangedBuf<T> {}
// SAFETY: every cross-thread access path goes through the ledger, which
// admits overlapping ranges only for read/read sharing (`&[T]` on several
// threads — needs `T: Sync`) and hands disjoint ranges to writers
// (`&mut [T]` used from another thread — needs `T: Send`).
unsafe impl<T: Send + Sync> Sync for RangedBuf<T> {}

impl<T> RangedBuf<T> {
    pub fn new(mut data: Vec<T>) -> Self {
        let base = data.as_mut_ptr();
        let len = data.len();
        RangedBuf {
            data: std::cell::UnsafeCell::new(data),
            base,
            len,
            ledger: Mutex::new(Ledger::default()),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Recover the underlying vector. Taking `self` by value statically
    /// guarantees no guard is alive.
    pub fn into_inner(self) -> Vec<T> {
        self.data.into_inner()
    }

    fn ledger(&self) -> std::sync::MutexGuard<'_, Ledger> {
        // Overlap violations panic *while holding* this lock; guards being
        // dropped during the resulting unwind must still release their
        // entries, so poisoning is deliberately ignored (the ledger is
        // consistent at every panic site — the violating entry was never
        // inserted).
        self.ledger
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn admit(&self, r: &Range<usize>, write: bool) -> u64 {
        assert!(
            r.start <= r.end && r.end <= self.len,
            "range {r:?} out of bounds for RangedBuf of len {}",
            self.len
        );
        let mut led = self.ledger();
        for (_, held, excl) in &led.active {
            let overlap = r.start < held.end && held.start < r.end;
            assert!(
                !(overlap && (write || *excl)),
                "conflicting range borrows: requested {:?} ({}) overlaps held {:?} ({})",
                r,
                if write { "write" } else { "read" },
                held,
                if *excl { "write" } else { "read" },
            );
        }
        let id = led.next;
        led.next += 1;
        led.active.push((id, r.clone(), write));
        id
    }

    fn release(&self, id: u64) {
        let mut led = self.ledger();
        if let Some(k) = led.active.iter().position(|(i, _, _)| *i == id) {
            led.active.swap_remove(k);
        }
    }

    /// Borrow `r` shared. Panics if any *exclusive* borrow overlaps it.
    pub fn read(&self, r: Range<usize>) -> RangedRead<'_, T> {
        let (start, len) = (r.start, r.end - r.start);
        let id = self.admit(&r, false);
        // SAFETY: `base` points at the start of a live allocation of
        // `self.len` elements and the ledger just admitted
        // `start..start + len` as in bounds.
        let ptr = unsafe { self.base.add(start) } as *const T;
        RangedRead {
            buf: self,
            id,
            ptr,
            len,
        }
    }

    /// Borrow `r` exclusively. Panics if *any* borrow overlaps it.
    pub fn write(&self, r: Range<usize>) -> RangedWrite<'_, T> {
        let (start, len) = (r.start, r.end - r.start);
        let id = self.admit(&r, true);
        // SAFETY: as in `read`; the admitted entry is exclusive.
        let ptr = unsafe { self.base.add(start) };
        RangedWrite {
            buf: self,
            id,
            ptr,
            len,
        }
    }
}

/// Shared borrow of a [`RangedBuf`] range (`Deref` to `[T]`).
pub struct RangedRead<'b, T> {
    buf: &'b RangedBuf<T>,
    id: u64,
    ptr: *const T,
    len: usize,
}

impl<T> std::ops::Deref for RangedRead<'_, T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        // SAFETY: the ledger entry held by this guard keeps every
        // overlapping exclusive borrow out until `Drop` releases it, and
        // `ptr..ptr + len` was admitted as in bounds.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl<T> Drop for RangedRead<'_, T> {
    fn drop(&mut self) {
        self.buf.release(self.id);
    }
}

/// Exclusive borrow of a [`RangedBuf`] range (`DerefMut` to `[T]`).
pub struct RangedWrite<'b, T> {
    buf: &'b RangedBuf<T>,
    id: u64,
    ptr: *mut T,
    len: usize,
}

impl<T> std::ops::Deref for RangedWrite<'_, T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        // SAFETY: the exclusive ledger entry held by this guard keeps
        // every overlapping borrow out until `Drop` releases it.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl<T> std::ops::DerefMut for RangedWrite<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: as in `deref` — the entry is exclusive, so handing out
        // `&mut` cannot alias any other live guard.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl<T> Drop for RangedWrite<'_, T> {
    fn drop(&mut self) {
        self.buf.release(self.id);
    }
}

// ---------------------------------------------------------------------------
// Affinity
// ---------------------------------------------------------------------------

/// Pin the calling thread to core `worker % cores`. Best-effort: failures
/// (restricted cpusets, exotic kernels) are silently ignored, and the
/// function is a no-op off Linux.
#[cfg(target_os = "linux")]
fn pin_current_thread(worker: usize) {
    // 16 × 64 bits = 1024 CPUs, the kernel's historical CPU_SETSIZE.
    const MASK_WORDS: usize = 16;
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let cores = crate::util::threadpool::available_threads().max(1);
    let core = worker % cores;
    if core >= MASK_WORDS * 64 {
        return;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[core / 64] |= 1u64 << (core % 64);
    // SAFETY: plain FFI call; the mask pointer is valid for the size
    // passed, pid 0 means the calling thread, and the return value is
    // deliberately ignored (best-effort pinning).
    unsafe {
        sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
    }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_worker: usize) {}

// ---------------------------------------------------------------------------
// Process-wide shared pools
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
static GLOBAL_PINNED: OnceLock<Arc<WorkerPool>> = OnceLock::new();

/// The process-wide shared pool (lazily built with one worker per
/// available core), in an unpinned and a pinned flavor. Evaluations whose
/// [`crate::fmm::FmmOptions::pool`] is `None` resolve here, so independent
/// callers in one process share workers instead of spawning their own.
pub fn global(pin: bool) -> Arc<WorkerPool> {
    let cell = if pin { &GLOBAL_PINNED } else { &GLOBAL };
    Arc::clone(cell.get_or_init(|| {
        Arc::new(WorkerPool::new(
            crate::util::threadpool::available_threads(),
            pin,
        ))
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_tasks_runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3, false);
        let hits: Vec<AtomicUsize> = (0..10).map(|_| AtomicUsize::new(0)).collect();
        pool.run_tasks((0..10).collect::<Vec<usize>>(), |k, t, _ws| {
            assert_eq!(k, t);
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn run_dynamic_covers_all_tasks() {
        let pool = WorkerPool::new(4, false);
        for limit in [1usize, 2, 4, 9] {
            let sum = AtomicUsize::new(0);
            pool.run_dynamic((1..=100).collect::<Vec<usize>>(), limit, |_k, t, _ws| {
                sum.fetch_add(t, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 5050, "limit={limit}");
        }
    }

    #[test]
    fn chunks_mut_writes_disjoint_slices() {
        let pool = WorkerPool::new(5, false);
        let n = 37;
        let stride = 3;
        let mut data = vec![0usize; n * stride];
        let rs = crate::util::threadpool::ranges(n, 5);
        pool.run_chunks_mut(&mut data, stride, &rs, |r, chunk, _ws| {
            for (k, b) in (r.start..r.end).enumerate() {
                for j in 0..stride {
                    chunk[k * stride + j] = b * stride + j + 1;
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i + 1);
        }
    }

    #[test]
    fn map_items_preserves_order_and_reuses_pool() {
        let pool = WorkerPool::new(3, false);
        for round in 0..4u64 {
            let out = pool.map_items((0..9u64).collect(), |i| i * i + round);
            assert_eq!(out, (0..9u64).map(|i| i * i + round).collect::<Vec<_>>());
        }
        assert!(pool.map_items(Vec::<u32>::new(), |i| i).is_empty());
    }

    #[test]
    fn tasks_are_statically_assigned_to_workers() {
        // the determinism/stickiness contract: task k runs on worker
        // k % n_workers — observed through the worker thread's name
        // ("fmm2d-pool-{id}"), so a regression to dynamic claiming fails
        let pool = WorkerPool::new(2, false);
        let seen: Vec<Mutex<Option<String>>> = (0..7).map(|_| Mutex::new(None)).collect();
        pool.run_tasks((0..7).collect::<Vec<usize>>(), |k, t, _ws| {
            assert_eq!(k, t);
            *seen[k].lock().unwrap() =
                Some(std::thread::current().name().unwrap_or("?").to_string());
        });
        for (k, s) in seen.iter().enumerate() {
            assert_eq!(
                s.lock().unwrap().as_deref(),
                Some(format!("fmm2d-pool-{}", k % 2).as_str()),
                "task {k} ran on the wrong worker"
            );
        }
    }

    #[test]
    fn accums_are_leased_and_reused() {
        let pool = WorkerPool::new(2, false);
        let mut a = pool.take_accums();
        assert_eq!(a.len(), 2);
        a[0].reset(5);
        a[0].re[3] = 7.0;
        let ptr = a[0].re.as_ptr();
        pool.return_accums(a);
        let b = pool.take_accums();
        // same allocation comes back (reuse, not reallocation)
        assert_eq!(b[0].re.as_ptr(), ptr);
        pool.return_accums(b);
    }

    #[test]
    fn concurrent_leases_extend_the_free_list() {
        let pool = WorkerPool::new(2, false);
        // two overlapping leases (concurrent evaluations on one pool)
        let mut a = pool.take_accums();
        let mut b = pool.take_accums();
        assert_eq!((a.len(), b.len()), (2, 2));
        for x in a.iter_mut().chain(b.iter_mut()) {
            x.reset(8); // materialize real allocations to compare by ptr
        }
        let ptrs: Vec<*const f64> = a.iter().chain(&b).map(|x| x.re.as_ptr()).collect();
        pool.return_accums(a);
        pool.return_accums(b); // extends — must not drop the first set
        let c = pool.take_accums();
        let d = pool.take_accums();
        // both retained sets come back (no reallocation): every buffer is
        // one of the originals
        for x in c.iter().chain(&d) {
            assert!(ptrs.contains(&x.re.as_ptr()));
        }
        pool.return_accums(c);
        pool.return_accums(d);
    }

    #[test]
    fn nested_fanout_from_a_worker_runs_inline() {
        let pool = Arc::new(WorkerPool::new(2, false));
        let p2 = Arc::clone(&pool);
        let total = AtomicUsize::new(0);
        pool.run_tasks(vec![10usize, 20], |_k, t, _ws| {
            // a fan-out issued from a worker must not deadlock
            p2.run_tasks(vec![t, t], |_kk, tt, _ws2| {
                total.fetch_add(tt, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 60);
    }

    #[test]
    fn shutdown_leaves_no_workers_behind() {
        let pool = WorkerPool::new(4, false);
        pool.run_tasks(vec![1, 2, 3], |_k, _t, _ws| {});
        assert_eq!(pool.shutdown_and_count(), 0);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2, false);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_tasks(vec![0usize, 1], |_k, t, _ws| {
                if t == 1 {
                    panic!("task boom");
                }
            });
        }));
        assert!(caught.is_err(), "caller must observe the task panic");
        // the pool is still serviceable afterwards
        let sum = AtomicUsize::new(0);
        pool.run_tasks(vec![5usize, 6], |_k, t, _ws| {
            sum.fetch_add(t, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 11);
    }

    #[test]
    fn pinned_pool_works() {
        // best-effort pinning must never break execution
        let pool = WorkerPool::new(2, true);
        assert!(pool.pinned());
        let out = pool.map_items(vec![1u32, 2, 3], |i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
        assert_eq!(pool.shutdown_and_count(), 0);
    }

    #[test]
    fn ranged_buf_disjoint_writes_and_overlapping_reads() {
        let buf = RangedBuf::new(vec![0u32; 10]);
        {
            let mut a = buf.write(0..5);
            let mut b = buf.write(5..10);
            a.fill(1);
            b.fill(2);
        }
        {
            let r1 = buf.read(0..10);
            let r2 = buf.read(3..8); // read/read overlap is fine
            assert_eq!(r1[0], 1);
            assert_eq!(r2[4], 2);
        }
        let v = buf.into_inner();
        assert_eq!(v, [1, 1, 1, 1, 1, 2, 2, 2, 2, 2]);
    }

    #[test]
    fn ranged_buf_guards_release_on_drop() {
        let buf = RangedBuf::new(vec![0u8; 4]);
        drop(buf.write(0..4));
        drop(buf.write(0..4)); // same range again: previous guard released
        drop(buf.read(0..4));
        drop(buf.write(0..4));
    }

    #[test]
    fn ranged_buf_rejects_write_write_overlap() {
        let buf = RangedBuf::new(vec![0u8; 8]);
        let _w = buf.write(0..5);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| buf.write(4..8)));
        assert!(err.is_err(), "overlapping writes must panic");
        // the rejected borrow left no ledger entry behind
        drop(_w);
        drop(buf.write(4..8));
    }

    #[test]
    fn ranged_buf_rejects_read_write_overlap() {
        let buf = RangedBuf::new(vec![0u8; 8]);
        let _r = buf.read(2..6);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| buf.write(5..7)));
        assert!(err.is_err(), "write overlapping a read must panic");
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| buf.read(9..10)));
        assert!(err.is_err(), "out-of-bounds range must panic");
        drop(buf.write(6..8)); // disjoint write is fine while reading
    }

    #[test]
    fn ranged_buf_is_shareable_across_pool_workers() {
        let pool = WorkerPool::new(3, false);
        let buf = RangedBuf::new(vec![0usize; 30]);
        let rs = crate::util::threadpool::ranges(30, 5);
        {
            let buf = &buf;
            pool.run_tasks(rs, |_k, r, _ws| {
                let mut w = buf.write(r.clone());
                for (k, i) in r.enumerate() {
                    w[k] = i * 2;
                }
            });
        }
        let v = buf.into_inner();
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * 2);
        }
    }

    #[test]
    fn accum_prepare_then_fill_matches_reset() {
        let mut a = Accum::default();
        a.reset(6);
        a.re[3] = 5.0;
        a.im[2] = -1.0;
        a.prepare(6);
        a.re.fill(0.0);
        a.im.fill(0.0);
        let mut b = Accum::default();
        b.reset(6);
        assert_eq!(a.re, b.re);
        assert_eq!(a.im, b.im);
        // prepare resizes without losing the allocation
        let ptr = a.re.as_ptr();
        a.prepare(4);
        assert_eq!(a.re.len(), 4);
        assert_eq!(a.re.as_ptr(), ptr);
    }

    #[test]
    fn spawn_counter_records_pool_construction() {
        // "fan-outs spawn nothing" needs a process to itself and lives in
        // tests/zero_spawn.rs; here only the construction census is
        // assertable (other tests spawn concurrently in this process)
        let before = spawn_count();
        let _pool = WorkerPool::new(3, false);
        assert!(spawn_count() >= before + 3);
    }
}
