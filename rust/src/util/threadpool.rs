//! Chunking helpers plus the *scoped* (spawn-per-phase) thread fan-outs.
//!
//! Built on `std::thread::scope` only — the offline environment has no
//! rayon. The engines parallelize by *writer-side sharding*: every phase
//! partitions its destination boxes into contiguous ranges and each thread
//! owns a disjoint `&mut` slice of the destination data, matching the
//! paper's directed no-write-conflict list layout (§4.3), so no locks or
//! atomics are needed anywhere.
//!
//! The scoped fan-outs here ([`scoped_map`], [`scoped_chunks_mut`]) remain
//! as the reference engine that `pool-bench` compares against; production
//! paths run on the persistent worker pool ([`crate::util::pool`]), which
//! pays the thread-spawn cost once per pool instead of once per phase.
//! Every spawn below is recorded via [`crate::util::pool::note_spawn`].

use std::ops::Range;

/// Number of worker threads when the caller does not specify one.
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `0..n` into at most `chunks` contiguous, near-equal ranges (the
/// leading `n % chunks` ranges are one longer). Returns fewer ranges when
/// `n < chunks`; never returns an empty range.
pub fn ranges(n: usize, chunks: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, n);
    let base = n / chunks;
    let extra = n % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split `0..weights.len()` into at most `chunks` contiguous ranges of
/// near-equal total weight (greedy prefix partitioning). Balances
/// triangular or list-driven workloads — P2P above all, whose symmetric
/// formulation gives box `b` all pairs with sources `≥ b` — across threads.
pub fn weighted_ranges(weights: &[u64], chunks: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, n);
    let mut remaining: u64 = weights.iter().sum();
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0usize;
    for c in 0..chunks {
        let chunks_left = chunks - c;
        if chunks_left == 1 {
            out.push(start..n);
            start = n;
            break;
        }
        // leave at least one item for every remaining chunk
        let max_end = n - (chunks_left - 1);
        let target = remaining / chunks_left as u64;
        let mut end = start + 1;
        let mut acc = weights[start];
        while end < max_end && acc + weights[end] / 2 <= target {
            acc += weights[end];
            end += 1;
        }
        remaining -= acc;
        out.push(start..end);
        start = end;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split `data` into consecutive disjoint mutable slices of the given
/// lengths (which must sum to exactly `data.len()`).
pub fn split_lengths_mut<'a, T>(mut data: &'a mut [T], lens: &[usize]) -> Vec<&'a mut [T]> {
    debug_assert_eq!(lens.iter().sum::<usize>(), data.len());
    let mut out = Vec::with_capacity(lens.len());
    for &len in lens {
        let rest = std::mem::take(&mut data);
        let (head, tail) = rest.split_at_mut(len);
        out.push(head);
        data = tail;
    }
    out
}

/// Fan `items` out over one scoped worker thread each and collect the
/// results in item order — the spawn/join scaffolding shared by the
/// parallel topology builds ([`crate::tree`], [`crate::connectivity`]).
/// Callers pass at most ~one item per core; an item typically carries a
/// box range (plus, for writers, its disjoint `&mut` destination slice).
pub fn scoped_map<I, T, F>(items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Sync,
{
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .into_iter()
            .map(|item| {
                let f = &f;
                crate::util::pool::note_spawn();
                s.spawn(move || f(item))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scoped worker panicked"))
            .collect()
    })
}

/// Run `f(range, chunk)` on one scoped thread per range, where `chunk` is
/// the disjoint destination slice `data[range.start*stride ..
/// range.end*stride]` — the writer-side sharding primitive. `ranges` must
/// tile `0..data.len()/stride`.
pub fn scoped_chunks_mut<T, F>(data: &mut [T], stride: usize, ranges: &[Range<usize>], f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    let lens: Vec<usize> = ranges.iter().map(|r| (r.end - r.start) * stride).collect();
    let chunks = split_lengths_mut(data, &lens);
    std::thread::scope(|s| {
        for (r, chunk) in ranges.iter().zip(chunks) {
            let r = r.clone();
            let f = &f;
            crate::util::pool::note_spawn();
            s.spawn(move || f(r, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_without_gaps() {
        for (n, c) in [(10, 3), (4, 8), (1, 1), (100, 7), (8, 8)] {
            let rs = ranges(n, c);
            assert!(rs.len() <= c);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            assert!(rs.iter().all(|r| !r.is_empty()));
            // near-equal: lengths differ by at most one
            let lens: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "{lens:?}");
        }
        assert!(ranges(0, 4).is_empty());
    }

    #[test]
    fn weighted_ranges_balance_triangular_load() {
        // triangular weights, as in the symmetric P2P (box b owns pairs ≥ b)
        let n = 64;
        let w: Vec<u64> = (0..n).map(|b| (n - b) as u64).collect();
        let rs = weighted_ranges(&w, 4);
        assert_eq!(rs.len(), 4);
        assert_eq!(rs[0].start, 0);
        assert_eq!(rs.last().unwrap().end, n);
        for win in rs.windows(2) {
            assert_eq!(win[0].end, win[1].start);
        }
        let total: u64 = w.iter().sum();
        for r in &rs {
            let chunk: u64 = w[r.start..r.end].iter().sum();
            // every chunk within 2x of the ideal quarter share
            assert!(chunk * 4 <= total * 2, "chunk {chunk} of {total} in {r:?}");
        }
    }

    #[test]
    fn weighted_ranges_degenerate_inputs() {
        assert!(weighted_ranges(&[], 4).is_empty());
        let rs = weighted_ranges(&[0, 0, 0], 8);
        assert_eq!(rs.last().unwrap().end, 3);
        let rs1 = weighted_ranges(&[5, 5], 1);
        assert_eq!(rs1, vec![0..2]);
    }

    #[test]
    fn split_lengths_mut_partitions() {
        let mut v: Vec<u32> = (0..10).collect();
        let parts = split_lengths_mut(&mut v, &[3, 0, 4, 3]);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0], &[0, 1, 2]);
        assert_eq!(parts[2], &[3, 4, 5, 6]);
        assert_eq!(parts[3], &[7, 8, 9]);
    }

    #[test]
    fn scoped_map_preserves_item_order() {
        let items: Vec<usize> = (0..9).collect();
        let out = scoped_map(items, |i| i * i);
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49, 64]);
        let empty: Vec<usize> = Vec::new();
        assert!(scoped_map(empty, |i: usize| i).is_empty());
    }

    #[test]
    fn scoped_chunks_write_disjoint_slices() {
        let n = 37;
        let stride = 3;
        let mut data = vec![0usize; n * stride];
        let rs = ranges(n, 5);
        scoped_chunks_mut(&mut data, stride, &rs, |r, chunk| {
            for (k, b) in (r.start..r.end).enumerate() {
                for j in 0..stride {
                    chunk[k * stride + j] = b * stride + j + 1;
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i + 1);
        }
    }
}
