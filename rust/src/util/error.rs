//! Minimal error-handling substrate (the build environment is offline, so
//! `anyhow` is replaced by a local equivalent with the same ergonomics).
//!
//! Provides [`Error`] — a chain of human-readable messages, outermost
//! context first — the [`Result`] alias, the [`Context`] extension trait for
//! `Result`/`Option`, and the [`anyhow!`](crate::anyhow) /
//! [`bail!`](crate::bail) / [`ensure!`](crate::ensure) macros exported at
//! the crate root.
//!
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! prints the whole chain separated by `": "`, mirroring anyhow's
//! formatting that `main.rs` relies on for error reports.

use std::fmt;

/// An error: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Error from a single message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a context message (the `.context()` layering).
    pub fn push_context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like anyhow, `Error` deliberately does NOT implement `std::error::Error`,
// which keeps this blanket conversion (and thereby `?` on any std error)
// coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context()` / `.with_context()` for results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_fail() -> Result<usize> {
        let n = "not-a-number".parse::<usize>().context("parsing the knob")?;
        Ok(n)
    }

    #[test]
    fn context_chains_and_formats() {
        let e = parse_fail().unwrap_err();
        assert_eq!(e.chain().len(), 2);
        assert_eq!(format!("{e}"), "parsing the knob");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing the knob: "), "got: {full}");
        assert!(full.contains("invalid digit"), "got: {full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too large: {x}");
            if x == 7 {
                crate::bail!("seven is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too large: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "seven is right out");
        let e = crate::anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
    }
}
