//! Property-based testing substrate (proptest is unavailable offline).
//!
//! A deliberately small harness: seeded case generation from [`rng::Pcg64`],
//! many cases per property, and on failure a report of the seed and case
//! index so the exact case can be replayed deterministically. No shrinking —
//! generators here produce already-small cases by construction.

use super::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Honor FMM2D_PROP_CASES so CI can crank coverage up without edits.
        let cases = std::env::var("FMM2D_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Self {
            cases,
            seed: 0xF44_2D00,
        }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with seed/case info on
/// the first failure (returning `Err(msg)` from the property).
pub fn forall<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Pcg64::seed_from_u64(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}",
                seed = cfg.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Assert two floats are close under combined absolute/relative tolerance.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol}, diff {})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            Config { cases: 32, seed: 1 },
            |r| r.uniform(),
            |x| {
                if (0.0..1.0).contains(x) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(
            Config { cases: 8, seed: 2 },
            |r| r.below(10),
            |x| {
                if *x < 5 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-10).is_ok());
        assert!(close(1.0, 1.1, 1e-3).is_err());
        assert!(close(1e9, 1e9 + 1.0, 1e-8).is_ok()); // relative scaling
    }
}
