//! Small self-contained substrates that would normally come from crates.io
//! (the build environment is offline): deterministic RNG, minimal JSON,
//! statistics, a CLI argument parser, an error-context substrate, scoped
//! threading helpers, the persistent worker pool and a property-testing
//! helper.

pub mod cli;
pub mod error;
pub mod failpoint;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sched;
pub mod stats;
pub mod threadpool;
