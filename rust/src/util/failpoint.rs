//! Deterministic fault injection for the serve chaos suite.
//!
//! A *failpoint* is a named site in the code that can be armed to fail on a
//! deterministic schedule. The real machinery only exists when the crate is
//! built with the non-default `failpoints` feature; without it, [`fire`]
//! compiles to an inline `false` (release binaries carry no injection
//! branches) and [`arm`] returns an error so `--faults` fails loudly
//! instead of silently testing nothing.
//!
//! Schedules are counted, not random, so a chaos run is reproducible:
//! `arm("topology=every:5,dispatch=once:3")` makes the `topology` site fire
//! on its 5th, 10th, 15th… hit and the `dispatch` site on exactly its 3rd.
//! Hit counters are process-global and only advance while a site is armed.
//!
//! The shipped sites (see `DESIGN.md` §11 for the catalog):
//!
//! | site          | location                         | models                      |
//! |---------------|----------------------------------|-----------------------------|
//! | `topology`    | `topology::build` prologue       | crash building the tree     |
//! | `dispatch`    | serve group evaluation           | crash in the compute phase  |
//! | `pool-worker` | `WorkerPool` worker task         | a worker dying mid-task     |
//! | `write`       | serve response writer            | transient reply-write error |

use crate::util::error::Result;

/// Names of every failpoint site compiled into the crate. [`arm`] rejects
/// specs naming anything else, so a typo in `--faults` cannot silently arm
/// nothing.
pub const SITES: [&str; 4] = ["topology", "dispatch", "pool-worker", "write"];

#[cfg(feature = "failpoints")]
mod imp {
    use super::SITES;
    use crate::util::error::Result;
    use std::collections::BTreeMap;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    #[derive(Clone, Copy, Debug)]
    enum Trigger {
        /// Fire on every K-th hit (K, 2K, 3K, …).
        Every(u64),
        /// Fire on exactly the N-th hit.
        Once(u64),
    }

    #[derive(Debug, Default)]
    struct Site {
        trigger: Option<Trigger>,
        hits: u64,
        fired: u64,
    }

    #[derive(Debug, Default)]
    pub(super) struct Registry {
        sites: BTreeMap<&'static str, Site>,
    }

    fn registry() -> MutexGuard<'static, Registry> {
        static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(Registry::default()))
            .lock()
            .unwrap_or_else(|p| p.into_inner())
    }

    fn canonical(name: &str) -> Result<&'static str> {
        SITES
            .iter()
            .find(|s| **s == name)
            .copied()
            .ok_or_else(|| {
                crate::anyhow!(
                    "unknown failpoint '{name}': known sites are {}",
                    SITES.join(", ")
                )
            })
    }

    fn parse_trigger(s: &str) -> Result<Trigger> {
        let (kind, count) = s
            .split_once(':')
            .ok_or_else(|| crate::anyhow!("bad failpoint trigger '{s}': want every:K or once:N"))?;
        let k: u64 = count
            .parse()
            .map_err(|_| crate::anyhow!("bad failpoint count '{count}' in '{s}'"))?;
        crate::ensure!(k >= 1, "failpoint count must be >= 1 in '{s}'");
        match kind {
            "every" => Ok(Trigger::Every(k)),
            "once" => Ok(Trigger::Once(k)),
            other => crate::bail!("bad failpoint trigger kind '{other}' in '{s}': want every or once"),
        }
    }

    pub(super) fn arm(spec: &str) -> Result<()> {
        // Parse the whole spec before touching the registry, so a bad spec
        // arms nothing.
        let mut parsed = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, trig) = part
                .split_once('=')
                .ok_or_else(|| crate::anyhow!("bad failpoint spec '{part}': want name=every:K or name=once:N"))?;
            parsed.push((canonical(name.trim())?, parse_trigger(trig.trim())?));
        }
        crate::ensure!(!parsed.is_empty(), "empty failpoint spec");
        let mut reg = registry();
        for (name, trig) in parsed {
            let site = reg.sites.entry(name).or_default();
            site.trigger = Some(trig);
            site.hits = 0;
            site.fired = 0;
        }
        // Injected panics are expected traffic during a chaos run: keep the
        // default hook (real test failures, unexpected panics) but silence
        // the per-panic stderr line for payloads we planted ourselves.
        quiet_failpoint_panics();
        Ok(())
    }

    fn quiet_failpoint_panics() {
        use std::sync::Once;
        static HOOK: Once = Once::new();
        HOOK.call_once(|| {
            let prev = std::panic::take_hook();
            std::panic::set_hook(Box::new(move |info| {
                let planted = info
                    .payload()
                    .downcast_ref::<&str>()
                    .map(|s| s.starts_with("failpoint:"))
                    .or_else(|| {
                        info.payload()
                            .downcast_ref::<String>()
                            .map(|s| s.starts_with("failpoint:"))
                    })
                    .unwrap_or(false);
                if !planted {
                    prev(info);
                }
            }));
        });
    }

    pub(super) fn disarm_all() {
        registry().sites.clear();
    }

    pub(super) fn fire(name: &str) -> bool {
        let mut reg = registry();
        let Some(site) = reg.sites.get_mut(name) else {
            return false;
        };
        let Some(trigger) = site.trigger else {
            return false;
        };
        site.hits += 1;
        let fire = match trigger {
            Trigger::Every(k) => site.hits % k == 0,
            Trigger::Once(n) => site.hits == n,
        };
        if fire {
            site.fired += 1;
        }
        fire
    }

    pub(super) fn fired_total() -> u64 {
        registry().sites.values().map(|s| s.fired).sum()
    }
}

/// Arm failpoints from a comma-separated spec: `name=every:K` fires the
/// site on every K-th hit, `name=once:N` on exactly the N-th. Re-arming a
/// site resets its counters; sites not named keep their current schedule.
/// Errors on unknown site names, malformed triggers, and — in builds
/// without the `failpoints` feature — on any spec at all.
#[cfg(feature = "failpoints")]
pub fn arm(spec: &str) -> Result<()> {
    imp::arm(spec)
}

/// Without the `failpoints` feature there is nothing to arm: fail loudly so
/// `--faults` is never a silent no-op.
#[cfg(not(feature = "failpoints"))]
pub fn arm(_spec: &str) -> Result<()> {
    crate::bail!(
        "this build has no fault-injection support: rebuild with `--features failpoints` to use --faults"
    )
}

/// Disarm every site and reset all counters.
#[cfg(feature = "failpoints")]
pub fn disarm_all() {
    imp::disarm_all();
}

/// No-op without the `failpoints` feature.
#[cfg(not(feature = "failpoints"))]
pub fn disarm_all() {}

/// Count a hit at site `name` and report whether it should fail now.
/// Callers decide *how* to fail (panic, transient error, …) — the registry
/// only decides *when*.
#[cfg(feature = "failpoints")]
pub fn fire(name: &str) -> bool {
    imp::fire(name)
}

/// Inline `false` without the `failpoints` feature: the optimizer removes
/// the site entirely.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn fire(_name: &str) -> bool {
    false
}

/// Total number of injections that actually fired since arming (all sites).
#[cfg(feature = "failpoints")]
pub fn fired_total() -> u64 {
    imp::fired_total()
}

/// Serialize test scenarios that arm sites or evaluate through them: the
/// registry is process-global, so concurrent tests in one binary would
/// otherwise perturb each other's hit counters (or eat each other's
/// injected panics). Every test that touches an armed site — in this
/// module, in `serve`, or in the chaos integration suite — holds this
/// guard for its whole scenario.
#[cfg(feature = "failpoints")]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Zero without the `failpoints` feature.
#[cfg(not(feature = "failpoints"))]
pub fn fired_total() -> u64 {
    0
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    // The registry is process-global and these tests run in one binary with
    // the rest of the lib suite (including serve tests that evaluate through
    // the `dispatch`/`write` sites): hold `test_lock` for each scenario.

    #[test]
    fn every_and_once_schedules_are_deterministic() {
        let _g = test_lock();
        disarm_all();
        arm("dispatch=every:3,write=once:2").unwrap();
        let every: Vec<bool> = (0..9).map(|_| fire("dispatch")).collect();
        assert_eq!(
            every,
            [false, false, true, false, false, true, false, false, true]
        );
        let once: Vec<bool> = (0..4).map(|_| fire("write")).collect();
        assert_eq!(once, [false, true, false, false]);
        assert_eq!(fired_total(), 4);
        disarm_all();
        assert!(!fire("dispatch"));
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let _g = test_lock();
        disarm_all();
        arm("write=every:1").unwrap();
        assert!(!fire("dispatch"));
        assert!(fire("write"));
        disarm_all();
    }

    #[test]
    fn bad_specs_are_rejected_and_arm_nothing() {
        let _g = test_lock();
        disarm_all();
        assert!(arm("bogus-site=every:2").is_err());
        assert!(arm("dispatch").is_err());
        assert!(arm("dispatch=every:0").is_err());
        assert!(arm("dispatch=sometimes:3").is_err());
        assert!(arm("").is_err());
        // the failed arms must not have armed the valid prefix
        assert!(!fire("dispatch"));
    }
}
