//! Deterministic pseudo-random number generation.
//!
//! All experiments in the paper use randomly sampled source points; for
//! reproducibility every workload in this repo is generated from an explicit
//! seed through this module. The generator is PCG64 (O'Neill 2014), seeded
//! via SplitMix64 — both implemented here because the offline environment
//! carries no `rand` crate.

/// SplitMix64: used to expand a single `u64` seed into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A PCG XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream derived from the seed).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let mut rng = Self {
            state: 0,
            inc: ((i0 as u128) << 64 | i1 as u128) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng
            .state
            .wrapping_add((s0 as u128) << 64 | s1 as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let state = self.state;
        self.state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (state >> 122) as u32;
        let xsl = ((state >> 64) as u64) ^ (state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Marsaglia's polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg64::seed_from_u64(7);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            s += u;
        }
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::seed_from_u64(11);
        let mut hist = [0usize; 7];
        for _ in 0..70_000 {
            hist[r.below(7) as usize] += 1;
        }
        for h in hist {
            assert!((h as f64 - 10_000.0).abs() < 600.0, "hist={hist:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(13);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((v - 1.0).abs() < 0.02, "var={v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut w = v.clone();
        w.sort_unstable();
        assert_eq!(w, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
