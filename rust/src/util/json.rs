//! Minimal JSON value model, writer and parser.
//!
//! Used for run records emitted by the harness (EXPERIMENTS.md provenance)
//! and for the artifact `.meta` manifests written by `python/compile/aot.py`.
//! Self-built because no `serde_json` is available offline. Supports the
//! subset actually exchanged: objects, arrays, strings, finite numbers,
//! booleans and null — which is all `aot.py` emits.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value (numbers are f64; manifests only carry small integers).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value (panics on non-objects — builder use only).
    pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), v);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch `key` as usize or fail loudly with context.
    pub fn req_usize(&self, key: &str) -> crate::util::error::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::anyhow!("missing/invalid integer field '{key}'"))
    }

    /// Fetch `key` as str or fail loudly with context.
    pub fn req_str(&self, key: &str) -> crate::util::error::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| crate::anyhow!("missing/invalid string field '{key}'"))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s).unwrap();
        s
    }

    fn write(&self, out: &mut String) -> fmt::Result {
        use fmt::Write;
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(out, "{}", *x as i64)?
                } else {
                    write!(out, "{x}")?
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out)?;
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out)?;
                    out.push(':');
                    v.write(out)?;
                }
                out.push('}');
            }
        }
        Ok(())
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> crate::util::error::Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            crate::bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> crate::util::error::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            crate::bail!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> crate::util::error::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            crate::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> crate::util::error::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => crate::bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> crate::util::error::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => crate::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> crate::util::error::Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => crate::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> crate::util::error::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => crate::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => crate::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> crate::util::error::Result<Json> {
        let start = self.i;
        while self
            .peek()
            .map(|c| matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("fmm_l3_p17".into()))
            .set("levels", Json::Num(3.0))
            .set("dims", Json::Arr(vec![Json::Num(64.0), Json::Num(18.0)]))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested_with_whitespace() {
        let s = r#" { "a" : [ 1 , 2.5 , { "b" : "x\ny" } ] , "c" : -3e-2 } "#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -0.03);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn escapes_written_correctly() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 7, "s": "hi"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(v.req_usize("missing").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""å""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "å");
    }
}
