//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `fmm2d <subcommand> [--key value]... [--flag]...`.
//! Subcommands register the options they understand; unknown options are an
//! error so typos fail fast instead of silently using defaults.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program and subcommand names).
    /// `--key value` and `--key=value` are both accepted; a `--key` followed
    /// by another option or nothing is a boolean flag.
    pub fn parse(argv: &[String]) -> crate::util::error::Result<Self> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.opts.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Typed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::util::error::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| crate::anyhow!("--{name} {s}: {e}")),
        }
    }

    /// Required typed option.
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> crate::util::error::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let s = self
            .get(name)
            .ok_or_else(|| crate::anyhow!("missing required option --{name}"))?;
        s.parse::<T>()
            .map_err(|e| crate::anyhow!("--{name} {s}: {e}"))
    }

    /// Enumerated-string option: returns `default` when absent, errors
    /// when the given value is not one of `choices` (typos fail fast with
    /// the valid alternatives listed).
    ///
    /// For enums that exist as types, prefer a `FromStr` impl routed
    /// through [`Args::get_or`] — the `--engine` selector does this via
    /// [`crate::dispatch::Engine`], so the name list and its error
    /// message live in exactly one place instead of per call site.
    pub fn get_choice(
        &self,
        name: &str,
        choices: &[&str],
        default: &str,
    ) -> crate::util::error::Result<String> {
        debug_assert!(choices.contains(&default));
        let v = self.get(name).unwrap_or(default);
        if choices.contains(&v) {
            Ok(v.to_string())
        } else {
            Err(crate::anyhow!(
                "--{name} {v}: expected one of {}",
                choices.join("|")
            ))
        }
    }

    /// Error out if any provided `--option` is not in `known` (flags
    /// included). The cross-cutting observability options — `--trace FILE`
    /// (flight-recorder Chrome trace) and `--log-level L` — are handled
    /// centrally by `main` and accepted by every subcommand.
    pub fn check_known(&self, known: &[&str]) -> crate::util::error::Result<()> {
        const GLOBAL: [&str; 2] = ["trace", "log-level"];
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) && !GLOBAL.contains(&k.as_str()) {
                crate::bail!("unknown option --{k}; known: {}", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        // note: positionals go before flags — "--flag value" is read as an
        // option under the simple grammar
        let a = Args::parse(&sv(&["pos1", "--n", "1000", "--p=17", "--verbose"])).unwrap();
        assert_eq!(a.get("n"), Some("1000"));
        assert_eq!(a.get("p"), Some("17"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn typed_access() {
        let a = Args::parse(&sv(&["--n", "4096"])).unwrap();
        assert_eq!(a.get_or("n", 0usize).unwrap(), 4096);
        assert_eq!(a.get_or("m", 7usize).unwrap(), 7);
        assert!(a.req::<usize>("m").is_err());
        assert!(a.get_or("n", 0.0f64).is_ok());
    }

    #[test]
    fn unknown_rejected() {
        let a = Args::parse(&sv(&["--oops", "1"])).unwrap();
        assert!(a.check_known(&["n", "p"]).is_err());
        let b = Args::parse(&sv(&["--n", "1"])).unwrap();
        assert!(b.check_known(&["n"]).is_ok());
        // the global observability options pass every subcommand's check
        let c = Args::parse(&sv(&["--trace", "t.json", "--log-level", "debug"])).unwrap();
        assert!(c.check_known(&["n"]).is_ok());
    }

    #[test]
    fn choice_options() {
        let a = Args::parse(&sv(&["--engine", "serial"])).unwrap();
        assert_eq!(
            a.get_choice("engine", &["serial", "parallel"], "parallel")
                .unwrap(),
            "serial"
        );
        assert_eq!(
            a.get_choice("dist", &["uniform", "normal"], "uniform").unwrap(),
            "uniform"
        );
        let b = Args::parse(&sv(&["--engine", "warp-drive"])).unwrap();
        let err = b
            .get_choice("engine", &["serial", "parallel"], "parallel")
            .unwrap_err()
            .to_string();
        assert!(err.contains("serial|parallel"), "{err}");
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&sv(&["--n", "5", "--fast"])).unwrap();
        assert!(a.flag("fast"));
        assert_eq!(a.get_or("n", 0u32).unwrap(), 5);
    }

    #[test]
    fn negative_number_value() {
        // "--shift -3" parses as flag+positional under the simple grammar,
        // so numeric negatives must use the = form; verify that works.
        let a = Args::parse(&sv(&["--shift=-3"])).unwrap();
        assert_eq!(a.get_or("shift", 0i32).unwrap(), -3);
    }
}
