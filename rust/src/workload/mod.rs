//! Workload generators for the paper's experiments (§5, Fig. 5.8):
//! (i) uniform in the unit square, (ii) normal clouds N(0, σ²) and
//! (iii) the 'layer' distribution (uniform x, normal y) — all rejected to
//! fit exactly within the unit square, as the paper does.

use crate::complex::C64;
use crate::util::error::Result;
use crate::util::rng::Pcg64;

/// Distribution of source points.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Distribution {
    /// Homogeneous in `[0,1]²` — the paper's §5.1–5.3 default.
    Uniform,
    /// Isotropic normal centered in the square with standard deviation σ,
    /// rejection-sampled into `[0,1]²` (paper uses σ² = 1/100 in Fig. 5.8).
    Normal { sigma: f64 },
    /// 'Layer': x uniform, y normal with standard deviation σ,
    /// rejection-sampled into the square.
    Layer { sigma: f64 },
}

impl Distribution {
    /// Parse a distribution by CLI/wire name (`uniform`, `normal`,
    /// `layer`), validating its parameters. This is the boundary
    /// constructor used by the CLI and the serve request decoder — prefer
    /// it over building the enum directly from untrusted input.
    pub fn from_name(name: &str, sigma: f64) -> Result<Distribution> {
        let d = match name {
            "uniform" => Distribution::Uniform,
            "normal" => Distribution::Normal { sigma },
            "layer" => Distribution::Layer { sigma },
            other => crate::bail!("unknown distribution '{other}': expected uniform|normal|layer"),
        };
        d.validate()?;
        Ok(d)
    }

    /// Reject parameters that would wedge or poison the sampler: the
    /// normal/layer generators rejection-sample into the unit square, so a
    /// non-finite or non-positive σ loops forever (NaN never satisfies the
    /// containment test) and a huge σ accepts almost nothing.
    pub fn validate(&self) -> Result<()> {
        match *self {
            Distribution::Uniform => Ok(()),
            Distribution::Normal { sigma } | Distribution::Layer { sigma } => {
                crate::ensure!(
                    sigma.is_finite() && sigma > 0.0,
                    "sigma must be finite and positive (got {sigma})"
                );
                crate::ensure!(
                    sigma <= 100.0,
                    "sigma {sigma} would reject almost every sample into [0,1]²; use sigma <= 100"
                );
                Ok(())
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            Distribution::Uniform => "uniform".into(),
            Distribution::Normal { sigma } => format!("normal(sigma={sigma})"),
            Distribution::Layer { sigma } => format!("layer(sigma={sigma})"),
        }
    }

    /// Sample one point inside the unit square.
    pub fn sample(&self, r: &mut Pcg64) -> C64 {
        match *self {
            Distribution::Uniform => C64::new(r.uniform(), r.uniform()),
            Distribution::Normal { sigma } => loop {
                let x = r.normal_with(0.5, sigma);
                let y = r.normal_with(0.5, sigma);
                if (0.0..=1.0).contains(&x) && (0.0..=1.0).contains(&y) {
                    return C64::new(x, y);
                }
            },
            Distribution::Layer { sigma } => {
                let x = r.uniform();
                loop {
                    let y = r.normal_with(0.5, sigma);
                    if (0.0..=1.0).contains(&y) {
                        return C64::new(x, y);
                    }
                }
            }
        }
    }

    /// Sample `n` points plus unit-magnitude random complex strengths
    /// (vortex-sheet-like circulations; strengths in `[-1,1]` real and
    /// imaginary as in the distributed reference scripts).
    pub fn generate(&self, n: usize, r: &mut Pcg64) -> (Vec<C64>, Vec<C64>) {
        let pts = (0..n).map(|_| self.sample(r)).collect();
        let gs = (0..n)
            .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
            .collect();
        (pts, gs)
    }
}

/// Uniform points + strengths in the unit square.
pub fn uniform_square(n: usize, r: &mut Pcg64) -> (Vec<C64>, Vec<C64>) {
    Distribution::Uniform.generate(n, r)
}

/// Normal cloud (σ standard deviation), rejected into the unit square.
pub fn normal_cloud(n: usize, sigma: f64, r: &mut Pcg64) -> (Vec<C64>, Vec<C64>) {
    Distribution::Normal { sigma }.generate(n, r)
}

/// Layer distribution (uniform x, N(0.5, σ²) y).
pub fn layer(n: usize, sigma: f64, r: &mut Pcg64) -> (Vec<C64>, Vec<C64>) {
    Distribution::Layer { sigma }.generate(n, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_distributions_stay_in_unit_square() {
        let mut r = Pcg64::seed_from_u64(1);
        for dist in [
            Distribution::Uniform,
            Distribution::Normal { sigma: 0.1 },
            Distribution::Layer { sigma: 0.05 },
        ] {
            let (pts, gs) = dist.generate(5000, &mut r);
            assert_eq!(pts.len(), 5000);
            assert_eq!(gs.len(), 5000);
            for p in &pts {
                assert!((0.0..=1.0).contains(&p.re), "{} x={}", dist.name(), p.re);
                assert!((0.0..=1.0).contains(&p.im), "{} y={}", dist.name(), p.im);
            }
        }
    }

    #[test]
    fn normal_cloud_is_concentrated() {
        let mut r = Pcg64::seed_from_u64(2);
        let (pts, _) = normal_cloud(20_000, 0.1, &mut r);
        let inside_2sigma = pts
            .iter()
            .filter(|p| (p.re - 0.5).abs() < 0.2 && (p.im - 0.5).abs() < 0.2)
            .count();
        // ~0.954² ≈ 91% of samples within ±2σ in both coordinates
        assert!(inside_2sigma as f64 > 0.85 * 20_000.0);
    }

    #[test]
    fn layer_spreads_x_but_not_y() {
        let mut r = Pcg64::seed_from_u64(3);
        let (pts, _) = layer(20_000, 0.05, &mut r);
        let x_spread = pts.iter().filter(|p| p.re < 0.25).count();
        let y_spread = pts.iter().filter(|p| (p.im - 0.5).abs() > 0.25).count();
        assert!(x_spread as f64 > 0.2 * 20_000.0, "x should be uniform");
        assert!((y_spread as f64) < 0.01 * 20_000.0, "y should be tight");
    }

    #[test]
    fn from_name_parses_and_validates() {
        assert_eq!(
            Distribution::from_name("uniform", f64::NAN).unwrap(),
            Distribution::Uniform
        );
        assert_eq!(
            Distribution::from_name("normal", 0.1).unwrap(),
            Distribution::Normal { sigma: 0.1 }
        );
        assert!(Distribution::from_name("gauss", 0.1).is_err());
        // parameters that would wedge the rejection sampler are rejected
        for bad in [f64::NAN, f64::INFINITY, 0.0, -1.0, 1e300] {
            assert!(Distribution::from_name("normal", bad).is_err(), "{bad}");
            assert!(Distribution::from_name("layer", bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed_from_u64(9);
        let mut b = Pcg64::seed_from_u64(9);
        let (pa, _) = uniform_square(100, &mut a);
        let (pb, _) = uniform_square(100, &mut b);
        assert_eq!(pa, pb);
    }
}
