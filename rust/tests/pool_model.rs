//! Exhaustive-interleaving model checks of the [`WorkerPool`] protocol
//! and of the task-graph scheduler's ready-counter protocol
//! (`util/sched.rs`), plus a deterministic stress harness on the real
//! pool.
//!
//! The offline toolchain has no `loom`, so the model checker is built
//! in-tree: the pool's park/unpark epoch broadcast is transcribed into a
//! small state machine (one caller, `n` workers) and a DFS with state
//! memoization explores **every** interleaving of its atomic steps. The
//! reduction is sound because the real protocol keeps all shared state
//! under one `Mutex` — any execution is a serialization of its lock-held
//! critical sections, so modelling each section as one atomic step loses
//! no behaviour. `std::thread::park`'s sticky unpark token is modelled
//! exactly (an unpark before the park makes the park return immediately);
//! the caller's condvar wait is modelled as "runnable once `active == 0`",
//! which is the one place the model trusts std (a missed condvar notify
//! would not show up here — the TSan CI lane covers that side).
//!
//! Checked properties, over every reachable interleaving:
//!
//! * no deadlock — some thread can always step until the program is done;
//! * exactly-once — each fan-out of width `f` runs on workers `0..f`
//!   exactly once, and on no other worker;
//! * epoch catch-up — a worker skipped by narrow fan-outs still advances
//!   its epoch and neither re-runs old jobs nor wedges shutdown;
//! * shutdown joins — after `shutdown` every worker exits and `join`
//!   completes.
//!
//! The checker itself is proven live by a negative model: with the sticky
//! unpark token removed, it must find the classic lost-wakeup deadlock.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fmm2d::util::pool::{self, WorkerPool};
use fmm2d::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// The protocol model
// ---------------------------------------------------------------------------

/// Worker program counter. `Check`/`Run`/`Park` are the worker's atomic
/// steps; `Blocked` is parked-with-no-token; `Exited` is joinable.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Pc {
    Check,
    Run,
    Park,
    Blocked,
    Exited,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct Worker {
    pc: Pc,
    /// Last epoch this worker has observed (pool.rs `seen`).
    seen: u8,
    /// Parked with no token (a real `park()` that blocked).
    parked: bool,
    /// Sticky unpark token (an `unpark()` delivered before the `park()`).
    token: bool,
    /// Epochs whose job this worker executed, in order.
    runs: Vec<u8>,
}

/// Caller operations, flattened into one program.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Lock: bump epoch, set participants/active, install the job.
    Install(u8),
    /// Unpark worker `j`.
    Unpark(usize),
    /// Condvar wait until `active == 0` (runnable only when it is).
    Wait,
    /// Lock: set the shutdown flag.
    SetShutdown,
    /// Join: runnable only when every worker has exited.
    Join,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct Model {
    epoch: u8,
    participants: u8,
    active: u8,
    shutdown: bool,
    /// Index into the caller's op program.
    op: usize,
    workers: Vec<Worker>,
}

impl Model {
    fn new(n_workers: usize) -> Self {
        Model {
            epoch: 0,
            participants: 0,
            active: 0,
            shutdown: false,
            op: 0,
            workers: vec![
                Worker {
                    pc: Pc::Check,
                    seen: 0,
                    parked: false,
                    token: false,
                    runs: Vec::new(),
                };
                n_workers
            ],
        }
    }
}

struct Checker {
    ops: Vec<Op>,
    fanouts: Vec<usize>,
    /// Model the sticky unpark token (true = faithful to std::thread).
    sticky_unpark: bool,
    visited: HashSet<Model>,
    states: usize,
}

impl Checker {
    fn program(n_workers: usize, fanouts: &[usize]) -> Vec<Op> {
        let mut ops = Vec::new();
        for &f in fanouts {
            ops.push(Op::Install(f as u8));
            for j in 0..f {
                ops.push(Op::Unpark(j));
            }
            ops.push(Op::Wait);
        }
        ops.push(Op::SetShutdown);
        for j in 0..n_workers {
            ops.push(Op::Unpark(j));
        }
        ops.push(Op::Join);
        ops
    }

    fn check(n_workers: usize, fanouts: &[usize], sticky_unpark: bool) -> Result<usize, String> {
        let mut c = Checker {
            ops: Self::program(n_workers, fanouts),
            fanouts: fanouts.to_vec(),
            sticky_unpark,
            visited: HashSet::new(),
            states: 0,
        };
        c.explore(Model::new(n_workers))?;
        Ok(c.states)
    }

    fn unpark(&self, w: &mut Worker) {
        if w.parked {
            w.parked = false;
            w.pc = Pc::Check;
        } else if self.sticky_unpark {
            w.token = true;
        }
        // without the sticky token, an unpark of a not-yet-parked worker
        // is lost — the broken protocol the negative test must catch
    }

    /// DFS over every interleaving from `s`. Err carries a description of
    /// the deadlock or violated invariant.
    fn explore(&mut self, s: Model) -> Result<(), String> {
        if !self.visited.insert(s.clone()) {
            return Ok(());
        }
        self.states += 1;

        if s.op == self.ops.len() {
            // terminal: every worker exited (Join guaranteed it) and ran
            // exactly the epochs it participated in, in order
            for (i, w) in s.workers.iter().enumerate() {
                let expected: Vec<u8> = self
                    .fanouts
                    .iter()
                    .enumerate()
                    .filter(|&(_k, &f)| i < f)
                    .map(|(k, _)| (k + 1) as u8)
                    .collect();
                if w.runs != expected {
                    return Err(format!(
                        "worker {i} ran epochs {:?}, expected {:?}",
                        w.runs, expected
                    ));
                }
            }
            return Ok(());
        }

        let mut stepped = false;

        // caller move
        if let Some(next) = self.caller_step(&s)? {
            stepped = true;
            self.explore(next)?;
        }

        // worker moves
        for i in 0..s.workers.len() {
            if let Some(next) = Self::worker_step(&s, i) {
                stepped = true;
                self.explore(next)?;
            }
        }

        if !stepped {
            return Err(format!(
                "deadlock: no runnable thread at caller op {:?} ({}), workers {:?}",
                self.ops[s.op],
                s.op,
                s.workers
                    .iter()
                    .map(|w| (w.pc, w.parked, w.token))
                    .collect::<Vec<_>>()
            ));
        }
        Ok(())
    }

    /// The caller's next atomic step, if runnable. Err on a violated
    /// fan-out invariant (checked at the `Wait` barrier).
    fn caller_step(&self, s: &Model) -> Result<Option<Model>, String> {
        let mut n = s.clone();
        match self.ops[s.op] {
            Op::Install(f) => {
                n.epoch += 1;
                n.participants = f;
                n.active = f;
            }
            Op::Unpark(j) => {
                let mut w = n.workers[j].clone();
                self.unpark(&mut w);
                n.workers[j] = w;
            }
            Op::Wait => {
                if s.active != 0 {
                    return Ok(None);
                }
                // the fan-out just completed: exactly-once on participants,
                // never on bystanders
                for (i, w) in s.workers.iter().enumerate() {
                    let c = w.runs.iter().filter(|&&e| e == s.epoch).count();
                    let want = usize::from((i as u8) < s.participants);
                    if c != want {
                        return Err(format!(
                            "epoch {}: worker {i} ran it {c} times, expected {want}",
                            s.epoch
                        ));
                    }
                }
            }
            Op::SetShutdown => n.shutdown = true,
            Op::Join => {
                if s.workers.iter().any(|w| w.pc != Pc::Exited) {
                    return Ok(None);
                }
            }
        }
        n.op += 1;
        Ok(Some(n))
    }

    /// Worker `i`'s next atomic step, if runnable.
    fn worker_step(s: &Model, i: usize) -> Option<Model> {
        let mut n = s.clone();
        let w = &mut n.workers[i];
        match w.pc {
            Pc::Check => {
                // the worker_loop's lock-held re-check
                if s.shutdown {
                    w.pc = Pc::Exited;
                } else if s.epoch != w.seen {
                    w.seen = s.epoch;
                    // catch-up: seen advances even when not participating
                    w.pc = if (i as u8) < s.participants {
                        Pc::Run
                    } else {
                        Pc::Park
                    };
                } else {
                    w.pc = Pc::Park;
                }
            }
            Pc::Run => {
                // job execution + the lock-held active decrement
                let e = w.seen;
                w.runs.push(e);
                w.pc = Pc::Check;
                n.active -= 1;
            }
            Pc::Park => {
                // std::thread::park with the sticky token semantics
                if w.token {
                    w.token = false;
                    w.pc = Pc::Check;
                } else {
                    w.parked = true;
                    w.pc = Pc::Blocked;
                }
            }
            Pc::Blocked | Pc::Exited => return None,
        }
        Some(n)
    }
}

#[test]
fn pool_protocol_is_deadlock_free_and_exactly_once() {
    // widths including 1 (everyone else must catch up), full width, and a
    // narrow-wide-narrow sequence that forces epoch skipping; the floors
    // guard against a degenerate search (a near-linear trace would mean
    // the explorer stopped branching, not that the protocol is verified)
    for (n, fanouts, min_states) in [
        (1, vec![1, 1, 1], 30),
        (2, vec![2, 1, 2], 200),
        (2, vec![1, 2], 100),
        (3, vec![3, 1, 2], 1000),
        (3, vec![1, 3], 500),
    ] {
        let states = Checker::check(n, &fanouts, true)
            .unwrap_or_else(|e| panic!("n={n} fanouts={fanouts:?}: {e}"));
        assert!(
            states > min_states,
            "n={n} fanouts={fanouts:?}: only {states} states explored"
        );
    }
}

#[test]
fn checker_finds_the_lost_wakeup_without_sticky_tokens() {
    // negative model: strip park/unpark's sticky token and the classic
    // missed-wakeup must surface as a deadlock — proof the checker can
    // actually catch protocol bugs (the model-level analog of the lint
    // fixture corpus)
    let err = Checker::check(2, &[2, 1], false).expect_err("lost wakeup must be found");
    assert!(err.contains("deadlock"), "unexpected failure mode: {err}");
}

#[test]
fn shutdown_during_narrow_fanouts_joins_every_worker() {
    // workers beyond the fan-out width spend the whole program parked;
    // shutdown must still join them (exercises the unpark-all in shutdown)
    for n in [2usize, 3, 4] {
        Checker::check(n, &[1], true).unwrap_or_else(|e| panic!("n={n}: {e}"));
    }
}

// ---------------------------------------------------------------------------
// The ready-counter protocol of the task-graph scheduler (util/sched.rs)
// ---------------------------------------------------------------------------
// Same methodology as the pool model above: the scheduler keeps all shared
// state (pending counters, ready queue, in-flight count) under one mutex,
// so each lock-held critical section is one atomic step and exhaustive
// DFS over step interleavings covers every real execution. Claims are
// modelled from *any* ready-queue position — a superset of the real
// pop-front order that also covers the jittered schedules of
// `tests/taskgraph_parity.rs`.
//
// Checked properties, over every reachable interleaving:
//
// * dependency safety — no task starts before all its deps completed;
// * exactly-once — every task runs once, on exactly one worker;
// * termination — some thread can always step until all tasks are done
//   (deadlock freedom; completion cascades through empty nodes too).
//
// The checker is proven live by negative models: a completion that skips
// the counter decrement must deadlock, and one that over-decrements must
// release a task before its dependencies — both must be *found*.

/// DAG under test: `deps[i]` lists the nodes task `i` waits on.
type Dag = Vec<Vec<usize>>;

/// Faulty counter-decrement variants the checker must catch.
#[derive(Clone, Copy, PartialEq, Debug)]
enum CounterBug {
    /// Completion never decrements the dependents' pending counters.
    SkipDecrement,
    /// Completion decrements every dependent twice.
    DoubleDecrement,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct SchedModel {
    /// Remaining not-yet-completed dependency count per task.
    pending: Vec<u8>,
    /// Tasks whose counter reached zero and were enqueued.
    ready: Vec<usize>,
    /// Per worker: the task it is currently executing.
    running: Vec<Option<usize>>,
    started: Vec<bool>,
    done: Vec<bool>,
}

impl SchedModel {
    fn new(dag: &Dag, n_workers: usize) -> Self {
        let pending: Vec<u8> = dag.iter().map(|d| d.len() as u8).collect();
        let ready = (0..dag.len()).filter(|&t| pending[t] == 0).collect();
        SchedModel {
            pending,
            ready,
            running: vec![None; n_workers],
            started: vec![false; dag.len()],
            done: vec![false; dag.len()],
        }
    }
}

struct SchedChecker {
    dag: Dag,
    /// Reverse edges: `dependents[i]` lists the tasks waiting on `i`.
    dependents: Vec<Vec<usize>>,
    bug: Option<CounterBug>,
    visited: HashSet<SchedModel>,
    states: usize,
}

impl SchedChecker {
    fn check(dag: &Dag, n_workers: usize, bug: Option<CounterBug>) -> Result<usize, String> {
        let mut dependents = vec![Vec::new(); dag.len()];
        for (t, deps) in dag.iter().enumerate() {
            for &d in deps {
                dependents[d].push(t);
            }
        }
        let mut c = SchedChecker {
            dag: dag.clone(),
            dependents,
            bug,
            visited: HashSet::new(),
            states: 0,
        };
        c.explore(SchedModel::new(dag, n_workers))?;
        Ok(c.states)
    }

    fn explore(&mut self, s: SchedModel) -> Result<(), String> {
        if !self.visited.insert(s.clone()) {
            return Ok(());
        }
        self.states += 1;

        if s.done.iter().all(|&d| d) {
            return Ok(()); // terminal: everything ran (exactly-once held per step)
        }

        let mut stepped = false;

        // claim: any idle worker takes any ready task (any position —
        // covers every wakeup/claim order the jitter hook can produce)
        for w in 0..s.running.len() {
            if s.running[w].is_some() {
                continue;
            }
            for slot in 0..s.ready.len() {
                let mut n = s.clone();
                let t = n.ready.remove(slot);
                // dependency safety at the moment of claim
                if let Some(&d) = self.dag[t].iter().find(|&&d| !s.done[d]) {
                    return Err(format!("task {t} claimed before its dependency {d} completed"));
                }
                if s.started[t] {
                    return Err(format!("task {t} claimed twice"));
                }
                n.started[t] = true;
                n.running[w] = Some(t);
                stepped = true;
                self.explore(n)?;
            }
        }

        // complete: a running worker finishes its task and cascades the
        // ready counters (the step under test — bugs injected here)
        for w in 0..s.running.len() {
            let Some(t) = s.running[w] else { continue };
            let mut n = s.clone();
            n.running[w] = None;
            n.done[t] = true;
            let decrements: usize = match self.bug {
                Some(CounterBug::SkipDecrement) => 0,
                Some(CounterBug::DoubleDecrement) => 2,
                None => 1,
            };
            for &dep in &self.dependents[t] {
                for _ in 0..decrements {
                    n.pending[dep] = n.pending[dep].saturating_sub(1);
                }
                if n.pending[dep] == 0 && !n.started[dep] && !n.ready.contains(&dep) {
                    n.ready.push(dep);
                }
            }
            stepped = true;
            self.explore(n)?;
        }

        if !stepped {
            return Err(format!(
                "deadlock: tasks {:?} never became ready (pending {:?})",
                s.done
                    .iter()
                    .enumerate()
                    .filter(|(_, &d)| !d)
                    .map(|(t, _)| t)
                    .collect::<Vec<_>>(),
                s.pending,
            ));
        }
        Ok(())
    }
}

/// The diamond the task-graph engine is built from (A → {B, C} → D), the
/// shape where both a lost decrement and a premature release are visible.
fn diamond() -> Dag {
    vec![vec![], vec![0], vec![0], vec![1, 2]]
}

#[test]
fn ready_counter_protocol_is_safe_and_deadlock_free() {
    // diamond, chain, independent fan, and the engine's real shape in
    // miniature (P2P parallel to a multipole chain joining at a merge);
    // state floors guard against a degenerate non-branching search
    let fmm_shape: Dag = vec![
        vec![],        // 0: P2M
        vec![0],       // 1: M2M
        vec![1],       // 2: M2L
        vec![2],       // 3: L2L
        vec![3],       // 4: L2P
        vec![],        // 5: P2P accumulate
        vec![4, 5],    // 6: merge
    ];
    for (dag, workers, min_states) in [
        (diamond(), 1, 8),
        (diamond(), 2, 30),
        (diamond(), 3, 30),
        (vec![vec![], vec![0], vec![1]], 2, 6), // chain
        (vec![vec![], vec![], vec![]], 2, 20),  // fully independent
        (fmm_shape, 2, 100),
    ] {
        let states = SchedChecker::check(&dag, workers, None)
            .unwrap_or_else(|e| panic!("dag={dag:?} workers={workers}: {e}"));
        assert!(
            states > min_states,
            "dag={dag:?} workers={workers}: only {states} states explored"
        );
    }
}

#[test]
fn checker_catches_a_skipped_counter_decrement_as_deadlock() {
    let err = SchedChecker::check(&diamond(), 2, Some(CounterBug::SkipDecrement))
        .expect_err("a lost decrement must strand the dependents");
    assert!(err.contains("deadlock"), "unexpected failure mode: {err}");
}

#[test]
fn checker_catches_an_over_decrement_as_a_premature_claim() {
    let err = SchedChecker::check(&diamond(), 2, Some(CounterBug::DoubleDecrement))
        .expect_err("an over-decrement must release a task early");
    assert!(
        err.contains("before its dependency"),
        "unexpected failure mode: {err}"
    );
}

// ---------------------------------------------------------------------------
// Accumulator leasing (real pool: take/return are plain data ops)
// ---------------------------------------------------------------------------

#[test]
fn accumulator_leases_are_complete_and_bounded() {
    let pool = WorkerPool::new(3, false);
    let nw = pool.n_workers();

    // every take yields a full lease, topped up when the free list is short
    let a = pool.take_accums();
    let b = pool.take_accums(); // free list empty: all fresh
    assert_eq!(a.len(), nw);
    assert_eq!(b.len(), nw);

    // mark a's buffers so reuse is observable
    let mut a = a;
    for acc in &mut a {
        acc.re.resize(4096, 1.0);
    }
    pool.return_accums(a);
    pool.return_accums(b);
    // free list now holds 2×nw — exactly the documented retention cap
    pool.return_accums(pool.take_accums()); // churn once: still capped

    // the next lease must reuse the marked (capacity-bearing) buffers:
    // take_accums splits off the *last* nw, and returns extend the back
    let c = pool.take_accums();
    assert_eq!(c.len(), nw);
    assert!(
        c.iter().any(|acc| acc.re.capacity() >= 4096),
        "lease did not reuse returned buffers"
    );

    // over-returning beyond the cap must shrink, not grow, the free list:
    // interleave extra returns in every order of two concurrent lessees
    for order in 0..4u32 {
        let x = pool.take_accums();
        let y = pool.take_accums();
        match order {
            0 => {
                pool.return_accums(x);
                pool.return_accums(y);
            }
            1 => {
                pool.return_accums(y);
                pool.return_accums(x);
            }
            2 => {
                pool.return_accums(x);
                pool.return_accums(Vec::new()); // empty return is a no-op
                pool.return_accums(y);
            }
            _ => {
                pool.return_accums(Vec::new());
                pool.return_accums(y);
                pool.return_accums(x);
            }
        }
        // leases stay complete regardless of interleaving
        let z = pool.take_accums();
        assert_eq!(z.len(), nw);
        pool.return_accums(z);
    }
}

// ---------------------------------------------------------------------------
// Deterministic stress harness (schedules the model cannot reach: real
// preemption, many concurrent callers, nested fan-outs, panics)
// ---------------------------------------------------------------------------

#[test]
fn concurrent_callers_stress_the_pool_without_spawns_or_corruption() {
    let pool = Arc::new(WorkerPool::new(4, false));

    // warm up, then census: the whole stress run must spawn nothing
    pool.run_tasks(vec![0usize; 4], |_k, _t, _ws| {});
    let spawns_before = pool::spawn_count();

    let callers = 4;
    let rounds = 60;
    let total = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for c in 0..callers {
            let pool = Arc::clone(&pool);
            let total = Arc::clone(&total);
            s.spawn(move || {
                let mut rng = Pcg64::seed_from_u64(7 + c as u64);
                for round in 0..rounds {
                    // seeded shape: task count 1..=17, three fan-out kinds
                    let k = 1 + (rng.next_u64() % 17) as usize;
                    let items: Vec<usize> = (0..k).collect();
                    match round % 3 {
                        0 => {
                            let out = pool.map_items(items, |i| i * i);
                            assert_eq!(out, (0..k).map(|i| i * i).collect::<Vec<_>>());
                        }
                        1 => {
                            let ran = AtomicUsize::new(0);
                            pool.run_tasks(items, |_k, i, _ws| {
                                ran.fetch_add(i + 1, Ordering::Relaxed);
                            });
                            assert_eq!(ran.load(Ordering::Relaxed), k * (k + 1) / 2);
                        }
                        _ => {
                            let ran = AtomicUsize::new(0);
                            pool.run_dynamic(items, 3, |_k, i, _ws| {
                                ran.fetch_add(i + 1, Ordering::Relaxed);
                            });
                            assert_eq!(ran.load(Ordering::Relaxed), k * (k + 1) / 2);
                        }
                    }
                    total.fetch_add(k, Ordering::Relaxed);
                }
            });
        }
    });
    assert!(total.load(Ordering::Relaxed) >= callers * rounds);

    // a panicking fan-out interleaved with survivors: the panic propagates
    // to its caller and the pool keeps serving
    let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run_tasks(vec![0usize; 3], |k, _t, _ws| {
            if k == 1 {
                panic!("stress-panic");
            }
        });
    }));
    assert!(boom.is_err(), "worker panic must reach the caller");
    let out = pool.map_items((0..9usize).collect(), |i| i + 1);
    assert_eq!(out, (1..=9usize).collect::<Vec<_>>());

    assert_eq!(
        pool::spawn_count(),
        spawns_before,
        "the stress run must perform zero thread spawns"
    );
}
