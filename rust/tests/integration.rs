//! Cross-module integration tests: tree → connectivity → serial FMM →
//! baselines, plus the harness machinery (everything except the PJRT
//! runtime, which has its own suite in `runtime_e2e.rs`).

use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::connectivity::Connectivity;
use fmm2d::direct;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{evaluate, evaluate_on_tree, FmmOptions, Phase};
use fmm2d::gpusim::model::GpuSim;
use fmm2d::harness::{run_pair, workload_for};
use fmm2d::packing::{pack_fmm, required_pads, unpack_potentials, ArtifactMeta};
use fmm2d::tree::{PartitionEngine, Pyramid};
use fmm2d::util::rng::Pcg64;
use fmm2d::util::stats::max_rel_error;
use fmm2d::workload::{self, Distribution};

fn rel_err_abs(a: &[C64], b: &[C64]) -> f64 {
    let av: Vec<f64> = a.iter().map(|z| z.abs()).collect();
    let bv: Vec<f64> = b.iter().map(|z| z.abs()).collect();
    max_rel_error(&av, &bv, 1e-12)
}

#[test]
fn fmm_matches_direct_across_distributions_and_sizes() {
    for (dist, n, tol) in [
        (Distribution::Uniform, 1_000, 1e-5),
        (Distribution::Uniform, 8_000, 1e-5),
        (Distribution::Normal { sigma: 0.1 }, 5_000, 2e-5),
        (Distribution::Layer { sigma: 0.05 }, 5_000, 2e-5),
    ] {
        let (pts, gs) = workload_for(dist, n, 42);
        let out = evaluate(&pts, &gs, &FmmOptions::default()).unwrap();
        let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
        let err = rel_err_abs(&out.potentials, &exact);
        assert!(err < tol, "{} n={n}: {err:e}", dist.name());
    }
}

#[test]
fn level_rule_consistency_with_explicit_levels() {
    // Eq. (5.2) levels vs explicitly overridden levels: same answer
    let (pts, gs) = workload_for(Distribution::Uniform, 6_000, 1);
    let auto = evaluate(&pts, &gs, &FmmOptions::default()).unwrap();
    let cfg = FmmConfig {
        levels_override: Some(FmmConfig::default().levels_for(6_000)),
        ..FmmConfig::default()
    };
    let manual = evaluate(
        &pts,
        &gs,
        &FmmOptions {
            cfg,
            ..FmmOptions::default()
        },
    )
    .unwrap();
    for (a, b) in auto.potentials.iter().zip(&manual.potentials) {
        assert!((*a - *b).abs() < 1e-12 * a.abs().max(1.0));
    }
}

#[test]
fn both_partition_engines_yield_identical_trees() {
    let (pts, gs) = workload_for(Distribution::Normal { sigma: 0.1 }, 4_000, 3);
    let a = Pyramid::build_with(&pts, &gs, 3, PartitionEngine::Cpu).unwrap();
    let b = Pyramid::build_with(&pts, &gs, 3, PartitionEngine::GpuModel).unwrap();
    // identical leaf populations and rect geometry (the paper required CPU
    // sorting for its comparisons because the CUDA sort was
    // non-deterministic; our functional model is deterministic by design)
    assert_eq!(a.starts, b.starts);
    for l in 0..=3 {
        for (ra, rb) in a.rects[l].iter().zip(&b.rects[l]) {
            assert!((ra.x0 - rb.x0).abs() < 1e-12);
            assert!((ra.x1 - rb.x1).abs() < 1e-12);
            assert!((ra.y0 - rb.y0).abs() < 1e-12);
            assert!((ra.y1 - rb.y1).abs() < 1e-12);
        }
    }
    // and identical FMM results on both trees
    let con_a = Connectivity::build(&a, 0.5);
    let con_b = Connectivity::build(&b, 0.5);
    let opts = FmmOptions::default();
    let (phi_a, _, _) = evaluate_on_tree(&a, &con_a, &opts);
    let (phi_b, _, _) = evaluate_on_tree(&b, &con_b, &opts);
    let pa = a.unpermute(&phi_a);
    let pb = b.unpermute(&phi_b);
    for (x, y) in pa.iter().zip(&pb) {
        assert!((*x - *y).abs() < 1e-12 * x.abs().max(1.0));
    }
}

#[test]
fn packing_roundtrip_preserves_every_particle() {
    let (pts, gs) = workload_for(Distribution::Layer { sigma: 0.08 }, 2_000, 5);
    let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    let need = required_pads(&pyr, &con);
    // synthesize a matching meta via the JSON path (as aot.py would emit)
    let meta = synth_meta(&need, 17);
    let packed = pack_fmm(&pyr, &con, &meta).unwrap();
    // reconstruct: potentials = position encode, roundtrip through unpack
    let nl = pyr.n_leaves();
    let mut pot_re = vec![0.0; nl * meta.nmax];
    let mut pot_im = vec![0.0; nl * meta.nmax];
    for b in 0..nl {
        for (i, q) in pyr.leaf(b).iter().enumerate() {
            pot_re[b * meta.nmax + i] = q.pos.re;
            pot_im[b * meta.nmax + i] = q.pos.im;
        }
    }
    let out = unpack_potentials(&pyr, meta.nmax, &pot_re, &pot_im);
    for (z, p) in out.iter().zip(&pts) {
        assert_eq!(*z, *p);
    }
    assert_eq!(packed.tensors.len(), meta.inputs.len());
}

fn synth_meta(need: &fmm2d::packing::PadRequirements, p: usize) -> ArtifactMeta {
    use fmm2d::tree::boxes_at_level;
    let levels = need.levels;
    let nl = boxes_at_level(levels);
    let nbtot = (boxes_at_level(levels + 1) - 1) / 3;
    let mut inputs = vec![
        format!(r#"{{"name":"pos_re","shape":[{nl},{}],"dtype":"f64"}}"#, need.nmax),
        format!(r#"{{"name":"pos_im","shape":[{nl},{}],"dtype":"f64"}}"#, need.nmax),
        format!(r#"{{"name":"gam_re","shape":[{nl},{}],"dtype":"f64"}}"#, need.nmax),
        format!(r#"{{"name":"gam_im","shape":[{nl},{}],"dtype":"f64"}}"#, need.nmax),
        format!(r#"{{"name":"mask","shape":[{nl},{}],"dtype":"f64"}}"#, need.nmax),
        format!(r#"{{"name":"ctr_re","shape":[{nbtot}],"dtype":"f64"}}"#),
        format!(r#"{{"name":"ctr_im","shape":[{nbtot}],"dtype":"f64"}}"#),
    ];
    for l in 1..=levels {
        inputs.push(format!(
            r#"{{"name":"m2l_idx_{l}","shape":[{},{}],"dtype":"i32"}}"#,
            boxes_at_level(l),
            need.kfar[l - 1]
        ));
    }
    inputs.push(format!(
        r#"{{"name":"near_idx","shape":[{nl},{}],"dtype":"i32"}}"#,
        need.knear
    ));
    inputs.push(format!(
        r#"{{"name":"p2l_idx","shape":[{nl},{}],"dtype":"i32"}}"#,
        need.ksp
    ));
    inputs.push(format!(
        r#"{{"name":"m2p_idx","shape":[{nl},{}],"dtype":"i32"}}"#,
        need.ksp
    ));
    let kfar = need
        .kfar
        .iter()
        .map(|k| k.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let text = format!(
        r#"{{"name":"synth","kind":"fmm","levels":{levels},"p":{p},"nmax":{},"kfar":[{kfar}],"knear":{},"ksp":{},"nbtot":{nbtot},"inputs":[{}],"outputs":[]}}"#,
        need.nmax,
        need.knear,
        need.ksp,
        inputs.join(",")
    );
    ArtifactMeta::parse(&text).unwrap()
}

#[test]
fn gpusim_pipeline_over_real_counts() {
    let (pts, gs) = workload_for(Distribution::Uniform, 20_000, 9);
    // serial CPU baseline (the speedup claims below are vs the paper's
    // single-threaded reference driver)
    let pair = run_pair(&pts, &gs, &FmmConfig::default(), &GpuSim::c2075(), Some(1));
    // simulated GPU beats the measured CPU on every heavy phase at this N
    assert!(pair.speedup(Phase::P2P) > 1.0);
    assert!(pair.speedup(Phase::M2L) > 1.0);
    assert!(pair.total_speedup() > 1.0);
    // and the potentials it carried along are right
    let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    assert!(rel_err_abs(&pair.potentials, &exact) < 1e-5);
}

#[test]
fn direct_baselines_consistency() {
    let (pts, gs) = workload_for(Distribution::Uniform, 500, 11);
    let plain = direct::eval_plain(Kernel::Harmonic, &pts, &gs);
    let symm = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    let via_targets = direct::eval_separate(Kernel::Harmonic, &pts, &pts, &gs);
    for i in 0..pts.len() {
        assert!((plain[i] - symm[i]).abs() < 1e-11 * plain[i].abs().max(1.0));
        // separate-targets path skips the self-pair by coincidence test
        assert!((plain[i] - via_targets[i]).abs() < 1e-11 * plain[i].abs().max(1.0));
    }
}

#[test]
fn workcounts_scale_as_theory_predicts() {
    // §2: M2L work ~ N (per-level roughly equal), P2P pairs ~ N·N_d
    let cfg = FmmConfig {
        p: 10,
        ..FmmConfig::default()
    };
    let (pts1, gs1) = workload_for(Distribution::Uniform, 20_000, 13);
    let (pts2, gs2) = workload_for(Distribution::Uniform, 80_000, 13);
    let o1 = evaluate(&pts1, &gs1, &FmmOptions { cfg, ..Default::default() }).unwrap();
    let o2 = evaluate(&pts2, &gs2, &FmmOptions { cfg, ..Default::default() }).unwrap();
    let m2l1: usize = o1.counts.m2l_per_level.iter().sum();
    let m2l2: usize = o2.counts.m2l_per_level.iter().sum();
    let ratio = m2l2 as f64 / m2l1 as f64;
    assert!(
        (2.0..8.0).contains(&ratio),
        "4x points should give ~4x M2L shifts, got {ratio:.1}x"
    );
    let p2p_per_n_1 = o1.counts.p2p_pairs as f64 / 20_000.0;
    let p2p_per_n_2 = o2.counts.p2p_pairs as f64 / 80_000.0;
    assert!(
        (0.4..2.5).contains(&(p2p_per_n_2 / p2p_per_n_1)),
        "P2P pairs per particle should stay bounded: {p2p_per_n_1:.0} vs {p2p_per_n_2:.0}"
    );
}

#[test]
fn empty_shortcut_lists_on_very_uniform_grids() {
    // a near-regular grid yields no P2L/M2P (all leaf radii comparable)
    let mut pts = Vec::new();
    let mut rng = Pcg64::seed_from_u64(17);
    for i in 0..64 {
        for j in 0..64 {
            pts.push(C64::new(
                (i as f64 + 0.5 + 0.01 * rng.uniform()) / 64.0,
                (j as f64 + 0.5 + 0.01 * rng.uniform()) / 64.0,
            ));
        }
    }
    let gs = vec![C64::new(1.0, 0.0); pts.len()];
    let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    assert_eq!(con.p2l.len(), 0, "regular grid should need no P2L");
    assert_eq!(con.m2p.len(), 0);
    // and the potential is still correct
    let opts = FmmOptions::default();
    let (phi, _, _) = evaluate_on_tree(&pyr, &con, &opts);
    let pot = pyr.unpermute(&phi);
    let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    assert!(rel_err_abs(&pot, &exact) < 1e-5);
}

#[test]
fn workload_module_shapes() {
    let mut r = Pcg64::seed_from_u64(21);
    let (p1, g1) = workload::uniform_square(100, &mut r);
    let (p2, _) = workload::normal_cloud(100, 0.05, &mut r);
    let (p3, _) = workload::layer(100, 0.05, &mut r);
    assert_eq!((p1.len(), g1.len(), p2.len(), p3.len()), (100, 100, 100, 100));
}
